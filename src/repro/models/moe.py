"""Fine-grained mixture-of-experts (DeepSeekMoE-style): ``n_shared`` always-on
experts + ``n_routed`` routed experts with top-k softmax gating.

Dispatch is the sort-based capacity-bounded grouped-GEMM formulation:
token replicas are sorted by expert id, packed into an [E, C, D] buffer
(drop-on-overflow with router-weight priority implicitly by arrival order),
pushed through batched expert GEMMs, and combined back with gate weights.
The [E, ...] tensors shard over the ``model`` mesh axis (expert parallelism);
GSPMD materialises the token exchange as collectives.  An explicit shard_map
all-to-all variant lives in ``repro/sharding/moe_shardmap.py`` and is used by
the perf work.

This layer is also where the paper's Model-2 partial hosting plugs in: an
``expert_mask`` [E] of resident experts (see serve/partial.py) zeroes
non-resident experts' contributions, exactly "requests routed to missing
experts go to the cloud".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, mlp_init, mlp_apply


def moe_init(key, cfg, dtype):
    d, e, fe = cfg.d_model, cfg.n_routed_experts, cfg.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router kept fp32
        # expert weights stacked on a leading E axis (shards over `model`)
        "w_gate": (jax.random.normal(ks[1], (e, d, fe), dtype) / np.sqrt(d)).astype(dtype),
        "w_in": (jax.random.normal(ks[2], (e, d, fe), dtype) / np.sqrt(d)).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (e, fe, d), dtype) / np.sqrt(fe)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, cfg.n_shared_experts * fe, dtype)
    return p


def route(router_w, x_flat, top_k: int):
    """Returns (weights [N,k], ids [N,k], aux_loss scalar)."""
    logits = (x_flat.astype(jnp.float32) @ router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    e = router_w.shape[1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], e), axis=0)
    aux = e * jnp.sum(me * ce)
    return w, ids, aux


def dispatch_compute_combine(p, x_flat, w, ids, capacity: int,
                             expert_mask=None):
    """Sort-based grouped expert compute.

    x_flat [N, D]; w/ids [N, k]; returns [N, D].
    """
    n, d = x_flat.shape
    k = ids.shape[1]
    e = p["w_in"].shape[0]
    nk = n * k

    e_flat = ids.reshape(nk)
    tok_flat = jnp.repeat(jnp.arange(n), k)
    w_flat = w.reshape(nk)

    order = jnp.argsort(e_flat)            # stable
    se = e_flat[order]
    st = tok_flat[order]
    sw = w_flat[order]

    # position of each replica within its expert's group
    counts = jnp.bincount(se, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(nk) - starts[se]
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, capacity - 1)

    buf = jnp.zeros((e, capacity, d), x_flat.dtype)
    src = jnp.where(keep[:, None], x_flat[st], 0.0)
    buf = buf.at[se, pos_c].add(src)       # scatter-add; dropped -> slot C-1 adds 0

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    hi = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    h = jax.nn.silu(h) * hi
    out = jnp.einsum("ecf,efd->ecd", h, p["w_out"])
    if expert_mask is not None:
        out = out * expert_mask[:, None, None].astype(out.dtype)

    gathered = out[se, pos_c]              # [nk, D]
    contrib = jnp.where(keep[:, None], gathered * sw[:, None].astype(out.dtype), 0.0)
    y = jnp.zeros((n, d), out.dtype).at[st].add(contrib)
    return y


def moe_apply(p, cfg, x, expert_mask=None, capacity_factor=None):
    """x [B, S, D] -> [B, S, D] (+ aux loss, edge-serviceable flag per token
    when an expert_mask is active).

    When a mesh context is active (distributed step builders install one) and
    the expert count divides the model axis, dispatch runs under shard_map
    with per-data-shard local sorting and a single psum combine — see
    repro/sharding/moe_shardmap.py.  Otherwise the single-device sort-based
    path below is used (tests, small runs)."""
    from repro.sharding.context import current_ctx
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    w, ids, aux = route(p["router"], x_flat, cfg.moe_top_k)
    n = b * s
    ctx = current_ctx()
    k = cfg.moe_top_k
    if (ctx is not None and ctx.tp > 1
            and cfg.n_routed_experts % ctx.tp == 0 and b % ctx.dp == 0):
        from repro.sharding.moe_shardmap import moe_shardmap_apply
        y = moe_shardmap_apply(ctx, x, w.reshape(b, s, k), ids.reshape(b, s, k),
                               p["w_gate"], p["w_in"], p["w_out"],
                               expert_mask, capacity_factor)
        y = y.reshape(n, d)
    else:
        capacity = int(np.ceil(n * k * capacity_factor / cfg.n_routed_experts))
        capacity = max(capacity, k)
        y = dispatch_compute_combine(p, x_flat, w, ids, capacity, expert_mask)
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], x_flat)
    served_fully = None
    if expert_mask is not None:
        served_fully = jnp.all(expert_mask[ids] > 0, axis=-1).reshape(b, s)
    return y.reshape(b, s, d), aux, served_fully


def expert_popularity(p, x_flat, top_k: int):
    """Router statistics used to build the Model-2 g(alpha) curve: empirical
    routing frequency per expert (see core/gcurve.py:moe_expert_gcurve)."""
    _, ids, _ = route(p["router"], x_flat, top_k)
    e = p["w_in"].shape[0]
    return jnp.bincount(ids.reshape(-1), length=e) / ids.size
