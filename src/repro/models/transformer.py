"""Composable decoder stack.

A model is a sequence of *segments*; each segment is ``(kind, n)`` — n
structurally-identical layers whose params are stacked on a leading axis and
executed under ``lax.scan`` (+ optional remat).  Heterogeneous architectures
(DeepSeek's dense-first-layer, zamba2's shared attention block, the VLM's
interleaved cross-attention) are just segment lists.

Kinds:
  dense        pre-norm GQA/MHA/MQA self-attn + pre-norm SwiGLU MLP
  moe          self-attn + fine-grained MoE (shared + routed top-k)
  mla_dense    MLA self-attn + MLP
  mla_moe      MLA self-attn + MoE
  ssm          Mamba2 block
  cross        gated cross-attn (to vision/audio stream) + MLP
  shared_ref   one application of the model-level weight-tied attn+MLP block
               (zamba2); params live at params["shared_block"], but each
               occurrence keeps its own KV cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_mod
from repro.models import mamba2 as ssm_mod
from repro.models import moe as moe_mod
from repro.models.layers import (embed_init, embed_apply, mlp_init, mlp_apply,
                                 rms_norm, dense_init, unembed_apply)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    segments: Tuple[Tuple[str, int], ...]
    d_head: int = 0                      # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rotary_dim: int = 0                  # 0 -> full head dim
    # MLA
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    mla_nope_dim: int = 128
    mla_rope_dim: int = 64
    mla_v_dim: int = 128
    # MoE
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0
    # SSM
    ssm_state: int = 0
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_chunk: int = 128
    # frontends (stubs; see DESIGN.md)
    frontend: Optional[str] = None       # None | "vision" | "audio"
    frontend_dim: int = 0                # raw embedding dim from the stub
    frontend_tokens: int = 0             # img patches / audio frames
    # numerics / lowering
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_impl: str = "xla_flash"
    attn_chunk: int = 1024
    moe_capacity_factor: float = 1.25
    loss_chunk: int = 512
    tie_embeddings: bool = False
    # analysis mode: lower loop-free so compiled.cost_analysis() counts every
    # iteration (XLA prices a while body once) — see launch/dryrun.py
    scan_unroll: bool = False
    remat_policy: str = "full"           # full | dots (save dot outputs)
    decode_impl: str = "auto"            # auto | flash_decode (seq-sharded KV)
    fsdp_experts: bool = False           # shard expert F-dim over data (FSDP)

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def n_layers(self) -> int:
        return sum(n for _, n in self.segments)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------------
# Param init
# ----------------------------------------------------------------------

def _layer_init(kind: str, key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind in ("dense", "moe"):
        p = {"norm_attn": jnp.zeros((d,), dtype),
             "attn": attn_mod.gqa_init(ks[0], cfg, dtype),
             "norm_ffn": jnp.zeros((d,), dtype)}
        p["ffn"] = (moe_mod.moe_init(ks[1], cfg, dtype) if kind == "moe"
                    else mlp_init(ks[1], d, cfg.d_ff, dtype))
        return p
    if kind in ("mla_dense", "mla_moe"):
        p = {"norm_attn": jnp.zeros((d,), dtype),
             "attn": attn_mod.mla_init(ks[0], cfg, dtype),
             "norm_ffn": jnp.zeros((d,), dtype)}
        p["ffn"] = (moe_mod.moe_init(ks[1], cfg, dtype) if kind == "mla_moe"
                    else mlp_init(ks[1], d, cfg.d_ff, dtype))
        return p
    if kind == "ssm":
        return {"norm": jnp.zeros((d,), dtype),
                "mixer": ssm_mod.mamba2_init(ks[0], cfg, dtype)}
    if kind == "cross":
        return {"norm_attn": jnp.zeros((d,), dtype),
                "attn": attn_mod.cross_init(ks[0], cfg, dtype),
                "norm_ffn": jnp.zeros((d,), dtype),
                "ffn": mlp_init(ks[1], d, cfg.d_ff, dtype),
                "gate_ffn": jnp.zeros((), dtype)}
    if kind == "shared_ref":
        return {}                        # tied weights at params["shared_block"]
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key):
    dtype = cfg.param_dtype
    ks = jax.random.split(key, len(cfg.segments) + 4)
    params = {}
    if cfg.frontend is None or cfg.frontend == "vision":
        params["embed"] = embed_init(ks[-1], cfg.vocab_size, cfg.d_model, dtype)
    if cfg.frontend is not None:
        params["frontend_proj"] = dense_init(ks[-2], cfg.frontend_dim, cfg.d_model, dtype)
    if any(kind == "shared_ref" for kind, _ in cfg.segments):
        params["shared_block"] = _layer_init("dense", ks[-3], cfg, dtype)
    segs = []
    for i, (kind, n) in enumerate(cfg.segments):
        if kind == "shared_ref":
            segs.append({})
            continue
        layer_keys = jax.random.split(ks[i], n)
        stacked = jax.vmap(lambda k: _layer_init(kind, k, cfg, dtype))(layer_keys)
        segs.append(stacked)
    params["segments"] = segs
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[-4], cfg.d_model, cfg.vocab_size, dtype)
    return params


# ----------------------------------------------------------------------
# Layer bodies
# ----------------------------------------------------------------------

def _apply_attn_layer(kind, p, cfg, x, positions, cache, cache_pos, extras):
    if kind in ("dense", "moe"):
        h, new_kv = attn_mod.gqa_apply(p["attn"], cfg,
                                       rms_norm(x, p["norm_attn"]), positions,
                                       cfg.attn_impl, cache, cache_pos)
        x = x + h
        hin = rms_norm(x, p["norm_ffn"])
        if kind == "moe":
            y, aux, _ = moe_mod.moe_apply(p["ffn"], cfg, hin,
                                          expert_mask=extras.get("expert_mask"),
                                          capacity_factor=cfg.moe_capacity_factor)
        else:
            y, aux = mlp_apply(p["ffn"], hin), 0.0
        return x + y, new_kv, aux
    if kind in ("mla_dense", "mla_moe"):
        h, new_kv = attn_mod.mla_apply(p["attn"], cfg, rms_norm(x, p["norm_attn"]),
                                       positions, cfg.attn_impl, cache, cache_pos)
        x = x + h
        hin = rms_norm(x, p["norm_ffn"])
        if kind == "mla_moe":
            y, aux, _ = moe_mod.moe_apply(p["ffn"], cfg, hin,
                                          expert_mask=extras.get("expert_mask"),
                                          capacity_factor=cfg.moe_capacity_factor)
        else:
            y, aux = mlp_apply(p["ffn"], hin), 0.0
        return x + y, new_kv, aux
    if kind == "ssm":
        sstate = cache[0] if cache is not None else None
        cstate = cache[1] if cache is not None else None
        y, hT, new_conv = ssm_mod.mamba2_apply(p["mixer"], cfg, rms_norm(x, p["norm"]),
                                               ssm_state=sstate, conv_state=cstate)
        new_cache = (hT, new_conv) if cache is not None else None
        return x + y, new_cache, 0.0
    if kind == "cross":
        vis = extras["frontend_embeds"]
        h = attn_mod.cross_apply(p["attn"], cfg,
                                 rms_norm(x, p["norm_attn"]), vis, cfg.attn_impl)
        x = x + h
        y = mlp_apply(p["ffn"], rms_norm(x, p["norm_ffn"]))
        gate = jnp.tanh(p["gate_ffn"].astype(jnp.float32)).astype(y.dtype)
        return x + gate * y, None, 0.0
    raise ValueError(kind)


def _segment_forward(kind, seg_params, cfg, x, positions, seg_cache, cache_pos, extras):
    """Scan over one segment's stacked layers."""
    if kind == "shared_ref":
        p = extras["shared_block"]
        x, new_kv, aux = _apply_attn_layer("dense", p, cfg, x, positions,
                                           seg_cache, cache_pos, extras)
        return x, new_kv, aux

    def body(carry, inp):
        xc, aux_acc = carry
        p, cache_l = inp
        fn = lambda xx: _apply_attn_layer(kind, p, cfg, xx, positions,
                                          cache_l, cache_pos, extras)
        if cfg.remat:
            pol = (jax.checkpoint_policies.checkpoint_dots
                   if cfg.remat_policy == "dots" else None)
            fn = jax.checkpoint(fn, prevent_cse=False, policy=pol)
        xc, new_cache, aux = fn(xc)
        return (xc, aux_acc + jnp.float32(aux)), new_cache

    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                        (seg_params, seg_cache),
                                        unroll=True if cfg.scan_unroll else 1)
    return x, new_caches, aux


def forward(params, cfg: ModelConfig, batch, caches=None, cache_pos=None,
            n_segments: int | None = None):
    """Run the stack.

    batch: dict with "tokens" [B,S] (and for frontends "frontend_embeds"
    [B, Nf, frontend_dim]); for audio the tokens are EnCodec codes and the
    frontend embeds are *added* at the input (stub), for vision they feed the
    cross-attn layers.
    caches: pytree matching ``make_caches`` (None = training/prefill-nocache).
    n_segments: truncate the stack (partial-hosting layer-prefix plans).

    Returns (hidden [B,S,D], new_caches, aux_losses).
    """
    dtype = cfg.compute_dtype
    extras = {}
    if "expert_mask" in batch:
        extras["expert_mask"] = batch["expert_mask"]
    if cfg.frontend is not None:
        fe = batch["frontend_embeds"].astype(dtype) @ params["frontend_proj"]
        extras["frontend_embeds"] = fe
    if "tokens" in batch and "embed" in params:
        x = embed_apply(params["embed"], batch["tokens"]).astype(dtype)
        if cfg.frontend == "audio":
            x = x + extras["frontend_embeds"][:, :x.shape[1], :]
    else:  # pure-embedding input (audio stub without codes)
        x = extras["frontend_embeds"]
    if "shared_block" in params:
        extras["shared_block"] = params["shared_block"]

    b, s = x.shape[:2]
    if cache_pos is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    else:
        positions = cache_pos + jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    segs = cfg.segments if n_segments is None else cfg.segments[:n_segments]
    new_caches = []
    aux_total = 0.0
    for i, (kind, n) in enumerate(segs):
        seg_cache = caches[i] if caches is not None else (
            None if kind == "shared_ref" else _none_cache(kind, n))
        x, ncache, aux = _segment_forward(kind, params["segments"][i], cfg, x,
                                          positions, seg_cache,
                                          cache_pos if cache_pos is not None else 0,
                                          extras)
        new_caches.append(ncache)
        aux_total = aux_total + aux
    x = rms_norm(x, params["final_norm"])
    return x, new_caches, aux_total


def _none_cache(kind, n):
    return None


def logits_fn(params, cfg: ModelConfig, hidden):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return unembed_apply(w, hidden)


# ----------------------------------------------------------------------
# Loss (sequence-chunked vocab CE so [B,S,V] never materialises)
# ----------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, hidden, labels, mask=None):
    """hidden [B,S,D], labels [B,S] (next-token ids). fp32 CE, chunked on S."""
    b, s, d = hidden.shape
    chunk = min(cfg.loss_chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((b, s), jnp.float32), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    hc = hidden.reshape(b, n_chunks, chunk, d)
    lc = labels.reshape(b, n_chunks, chunk)
    mc = mask.reshape(b, n_chunks, chunk)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    def body(carry, inp):
        tot, cnt = carry
        h, l, m = inp                                    # [b,chunk,*]
        logits = unembed_apply(w, h)                     # fp32 [b,chunk,V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * m
        return (tot + jnp.sum(nll), cnt + jnp.sum(m)), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)),
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0), jnp.moveaxis(mc, 1, 0)),
        unroll=True if cfg.scan_unroll else 1)
    return tot / jnp.maximum(cnt, 1.0)


# ----------------------------------------------------------------------
# KV / state caches
# ----------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """Shapes/dtypes for every segment's cache (used both to allocate and to
    build ShapeDtypeStructs for the dry-run)."""
    dt = cfg.compute_dtype
    hd = cfg.head_dim
    specs = []
    for kind, n in cfg.segments:
        if kind in ("dense", "moe"):
            specs.append((
                (n, batch, max_len, cfg.n_kv_heads, hd, dt),   # K
                (n, batch, max_len, cfg.n_kv_heads, hd, dt),   # V
            ))
        elif kind in ("mla_dense", "mla_moe"):
            specs.append((
                (n, batch, max_len, cfg.kv_lora_rank, dt),
                (n, batch, max_len, cfg.mla_rope_dim, dt),
            ))
        elif kind == "ssm":
            di = cfg.ssm_d_inner
            conv_dim = di + 2 * cfg.ssm_n_groups * cfg.ssm_state
            specs.append((
                (n, batch, cfg.ssm_n_heads, di // cfg.ssm_n_heads, cfg.ssm_state,
                 jnp.float32),
                (n, batch, cfg.ssm_d_conv - 1, conv_dim, dt),
            ))
        elif kind == "shared_ref":
            specs.append((
                (batch, max_len, cfg.n_kv_heads, hd, dt),
                (batch, max_len, cfg.n_kv_heads, hd, dt),
            ))
        elif kind == "cross":
            specs.append(None)      # vision K/V recomputed from static embeds
        else:
            raise ValueError(kind)
    return specs


def make_caches(cfg: ModelConfig, batch: int, max_len: int):
    out = []
    for spec in cache_spec(cfg, batch, max_len):
        if spec is None:
            out.append(None)
        else:
            out.append(tuple(jnp.zeros(s[:-1], s[-1]) for s in spec))
    return out
