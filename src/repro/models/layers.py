"""Primitive layers (pure functions over explicit param dicts).

Parameters are plain pytrees of jnp arrays; every init function takes a PRNG
key and returns the dict for one layer.  Compute dtype is bf16 by default
with fp32 accumulation where it matters (norms, softmax, CE); param dtype is
configurable (fp32 for tiny CPU tests, bf16 for the dry-run memory story).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, stddev, dtype):
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def dense_init(key, d_in, d_out, dtype, scale: float | None = None):
    std = scale if scale is not None else (1.0 / np.sqrt(d_in))
    return truncated_normal(key, (d_in, d_out), std, dtype)


def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------
# Rotary position embeddings (full or partial, NEOX interleaving not used —
# llama-style half-rotation).
# ----------------------------------------------------------------------

def rope_freqs(rotary_dim: int, theta: float) -> jnp.ndarray:
    inv = 1.0 / (theta ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim))
    return inv  # [rotary_dim // 2]


def apply_rope(x, positions, theta: float, rotary_dim: int | None = None):
    """x: [..., S, H, hd]; positions: [..., S] int32. Rotates the first
    ``rotary_dim`` features (partial RoPE for stablelm-style configs)."""
    hd = x.shape[-1]
    rd = rotary_dim if rotary_dim is not None else hd
    inv = rope_freqs(rd, theta)                          # [rd/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, rd/2]
    cos = jnp.cos(ang)[..., None, :]                     # [..., S, 1, rd/2]
    sin = jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :rd], x[..., rd:]
    x1, x2 = jnp.split(x_rot, 2, axis=-1)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), x_pass], axis=-1)


# ----------------------------------------------------------------------
# Gated MLP (SwiGLU) — the dense FFN used by every assigned LM arch.
# ----------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_in": dense_init(k2, d_model, d_ff, dtype),
        "w_out": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
    return h @ p["w_out"]


# ----------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------

def embed_init(key, vocab, d_model, dtype):
    return truncated_normal(key, (vocab, d_model), 1.0 / np.sqrt(d_model), dtype)


def embed_apply(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed_apply(w, x):
    """x [.., D] @ w [D, V] -> fp32 logits."""
    return (x @ w).astype(jnp.float32)
