"""Attention variants: GQA/MHA/MQA self-attention, MLA (DeepSeek-V2
multi-head latent attention), and cross-attention (VLM).

Three interchangeable inner implementations, selected by ``impl``:

  * ``naive``     — materialises the [S, S] score matrix (tiny tests only).
  * ``xla_flash`` — KV-chunked online-softmax scan: O(S*chunk) live memory,
                    the XLA-compiled stand-in for the Pallas kernel; this is
                    what the dry-run lowers so prefill_32k fits.
  * ``pallas``    — the TPU kernel in repro/kernels/flash_attention.py
                    (interpret=True on CPU).

All paths accept GQA (n_kv <= n_q, n_q % n_kv == 0) and a causal flag, and
return [B, S, Hq, hd].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def attention_naive(q, k, v, causal: bool, q_offset=0):
    """q [B,Sq,Hq,hd], k/v [B,Skv,Hkv,hd]."""
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    q = q.reshape(b, sq, hkv, hq // hkv, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(skv)
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(b, sq, hq, hd)


def attention_xla_flash(q, k, v, causal: bool, q_offset=0, chunk: int = 1024,
                        unroll: bool = False):
    """Online-softmax attention, scanning over KV chunks. Numerically matches
    naive to ~1e-3 in bf16 / 1e-5 in fp32."""
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = (q.reshape(b, sq, hkv, g, hd) / np.sqrt(hd)).astype(jnp.float32)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, hkv, hd)
    vc = v.reshape(b, n_chunks, chunk, hkv, hd)
    qpos = jnp.arange(sq) + q_offset

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp
        kpos = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb.astype(jnp.float32))
        valid = kpos[None, :] < skv
        if causal:
            valid = valid & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(valid[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(n_chunks)),
        unroll=True if unroll else 1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, hq, hd).astype(q.dtype)


def attend(q, k, v, causal: bool, impl: str = "naive", q_offset=0, chunk: int = 1024,
           unroll: bool = False):
    if impl == "naive":
        return attention_naive(q, k, v, causal, q_offset)
    if impl == "xla_flash":
        return attention_xla_flash(q, k, v, causal, q_offset, chunk, unroll)
    if impl == "pallas":
        from repro.kernels.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, q_offset=q_offset)
    raise ValueError(f"unknown attention impl {impl}")


# ----------------------------------------------------------------------
# Standard (GQA) attention layer
# ----------------------------------------------------------------------

def gqa_init(key, cfg, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * hd, dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype),
        "wo": dense_init(ks[3], hq * hd, d, dtype, scale=1.0 / np.sqrt(hq * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def gqa_qkv(p, cfg, x, positions):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    rd = cfg.rotary_dim or hd
    q = apply_rope(q, positions, cfg.rope_theta, rd)
    k = apply_rope(k, positions, cfg.rope_theta, rd)
    return q, k, v


def gqa_apply(p, cfg, x, positions, impl, kv_cache=None, cache_pos=None):
    """Self-attention. If kv_cache=(k,v) [B,Smax,Hkv,hd] is given, new k/v are
    written at ``cache_pos`` and attention runs over the cache (decode).

    decode_impl == 'flash_decode' + an active mesh context routes the
    single-token decode through the sequence-sharded KV path
    (serve/flash_decode.py): O(B*H*hd) wire bytes instead of gathering the
    cache (the GQA-few-KV-heads collective pathology)."""
    from repro.sharding.context import current_ctx
    q, k, v = gqa_qkv(p, cfg, x, positions)
    if kv_cache is not None:
        ck, cv = kv_cache
        ctx = current_ctx()
        if (cfg.decode_impl == "flash_decode" and x.shape[1] == 1
                and ctx is not None and ctx.tp > 1
                and ck.shape[1] % ctx.tp == 0):
            from repro.serve.flash_decode import flash_decode_update
            bs = (ctx.batch_axes if len(ctx.batch_axes) > 1
                  else (ctx.batch_axes[0] if ctx.batch_axes else None))
            out, ck, cv = flash_decode_update(
                q, k, v, ck, cv, cache_pos, mesh=ctx.mesh,
                axis=ctx.model_axis, batch_spec=bs)
            new_cache = (ck, cv)
            b, sflat = x.shape[:2]
            y = out.reshape(b, sflat, cfg.n_heads * cfg.head_dim) @ p["wo"]
            return y, new_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_pos, axis=1)
        # mask beyond current position handled by causal mask via q_offset
        out = attend(q, ck, cv, causal=True, impl=impl, q_offset=cache_pos,
                     chunk=cfg.attn_chunk, unroll=cfg.scan_unroll)
        new_cache = (ck, cv)
    else:
        out = attend(q, k, v, causal=True, impl=impl,
                     chunk=cfg.attn_chunk, unroll=cfg.scan_unroll)
        new_cache = None
    b, s = x.shape[:2]
    y = out.reshape(b, s, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return y, new_cache


# ----------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2).  The KV cache stores only
# the compressed latent c_kv [kv_lora] + the shared rope key [rope_dim].
# ----------------------------------------------------------------------

def mla_init(key, cfg, dtype):
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    p = {
        "w_dq": dense_init(ks[0], d, ql, dtype),
        "q_norm": jnp.zeros((ql,), dtype),
        "w_uq": dense_init(ks[1], ql, h * (dn + dr), dtype),
        "w_dkv": dense_init(ks[2], d, kl + dr, dtype),
        "kv_norm": jnp.zeros((kl,), dtype),
        "w_uk": dense_init(ks[3], kl, h * dn, dtype),
        "w_uv": dense_init(ks[4], kl, h * dv, dtype),
        "wo": dense_init(ks[5], h * dv, d, dtype, scale=1.0 / np.sqrt(h * dv)),
    }
    return p


def mla_apply(p, cfg, x, positions, impl, kv_cache=None, cache_pos=None):
    """kv_cache = (c_kv [B,Smax,kv_lora], k_rope [B,Smax,rope_dim])."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
    kl = cfg.kv_lora_rank

    q = rms_norm(x @ p["w_dq"], p["q_norm"]) @ p["w_uq"]
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ p["w_dkv"]
    c_kv = rms_norm(dkv[..., :kl], p["kv_norm"])
    k_rope = apply_rope(dkv[..., None, kl:], positions, cfg.rope_theta)[:, :, 0]

    if kv_cache is not None:
        cc, cr = kv_cache
        cc = jax.lax.dynamic_update_slice_in_dim(cc, c_kv.astype(cc.dtype), cache_pos, 1)
        cr = jax.lax.dynamic_update_slice_in_dim(cr, k_rope.astype(cr.dtype), cache_pos, 1)
        c_kv, k_rope = cc, cr
        new_cache = (cc, cr)
        q_offset = cache_pos
    else:
        new_cache = None
        q_offset = 0

    skv = c_kv.shape[1]
    k_nope = (c_kv @ p["w_uk"]).reshape(b, skv, h, dn)
    vv = (c_kv @ p["w_uv"]).reshape(b, skv, h, dv)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, skv, h, dr))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    # pad v to match head dim for the shared attend() kernels, then slice
    pad = (dn + dr) - dv
    v_pad = jnp.pad(vv, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad > 0 else vv
    out = attend(q_full, k_full, v_pad, causal=True, impl=impl, q_offset=q_offset,
                 chunk=cfg.attn_chunk, unroll=cfg.scan_unroll)
    out = out[..., :dv]
    y = out.reshape(b, s, h * dv) @ p["wo"]
    return y, new_cache


# ----------------------------------------------------------------------
# Cross-attention (VLM layers: queries from text, keys/values from the
# projected vision embeddings; gated residual as in llama-3.2-vision).
# ----------------------------------------------------------------------

def cross_init(key, cfg, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], d, hq * hd, dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype),
        "wo": dense_init(ks[3], hq * hd, d, dtype, scale=1.0 / np.sqrt(hq * hd)),
        "q_norm": jnp.zeros((hd,), dtype),
        "k_norm": jnp.zeros((hd,), dtype),
        "gate_attn": jnp.zeros((), dtype),
    }


def cross_apply(p, cfg, x, vis, impl):
    """x [B,S,D] text stream; vis [B,Simg,D] projected patch embeddings."""
    b, s, _ = x.shape
    simg = vis.shape[1]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (vis @ p["wk"]).reshape(b, simg, hkv, hd)
    v = (vis @ p["wv"]).reshape(b, simg, hkv, hd)
    q = rms_norm(q, p["q_norm"])
    k = rms_norm(k, p["k_norm"])
    out = attend(q, k, v, causal=False, impl=impl, unroll=cfg.scan_unroll)
    y = out.reshape(b, s, hq * hd) @ p["wo"]
    return jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(y.dtype) * y
