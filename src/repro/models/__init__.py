from repro.models.transformer import (ModelConfig, init_params, forward,
                                      logits_fn, lm_loss, make_caches, cache_spec)

__all__ = ["ModelConfig", "init_params", "forward", "logits_fn", "lm_loss",
           "make_caches", "cache_spec"]
