"""Mamba2 (state-space duality) block: chunked SSD for train/prefill and the
O(1) recurrent step for decode.

Math per head (state size ds, head dim dh), discretised:
    la_t   = dt_t * A                    (A < 0, per head; la = log decay)
    h_t    = exp(la_t) h_{t-1} + dt_t * x_t B_t^T          [dh, ds]
    y_t    = h_t C_t + D * x_t

Chunked form over chunks of Q tokens with L = inclusive cumsum(la):
    y_t = sum_{j<=t} exp(L_t - L_j) (C_t . B_j) dt_j x_j  +  exp(L_t) C_t h_in
    h_out = sum_j exp(L_Q - L_j) dt_j x_j B_j^T + exp(L_Q) h_in

The intra-chunk term is the "attention-like" matmul the SSD paper exposes;
it maps onto the MXU and is also implemented as a Pallas kernel
(repro/kernels/ssd_scan.py) — this jnp version is its oracle and the
dry-run lowering path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, rms_norm


def mamba2_init(key, cfg, dtype):
    """Projections are kept SEPARATE (w_z/w_x/w_B/w_C/w_dt) rather than one
    fused in_proj: the per-head tensors (x, z, dt, and the SSD state) then
    column/row-shard cleanly over the ``model`` axis (tensor parallelism for
    SSM blocks), while the small group-shared B/C stay replicated.  See
    sharding/specs.py and EXPERIMENTS.md §Perf (zamba2 hillclimb)."""
    d = cfg.d_model
    di = cfg.ssm_d_inner
    ng, ds, nh = cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_n_heads
    gdim = ng * ds
    ks = jax.random.split(key, 8)
    p = {
        "w_z": dense_init(ks[0], d, di, dtype),
        "w_x": dense_init(ks[1], d, di, dtype),
        "w_B": dense_init(ks[2], d, gdim, dtype),
        "w_C": dense_init(ks[3], d, gdim, dtype),
        "w_dt": dense_init(ks[4], d, nh, dtype),
        "conv_x": (jax.random.normal(ks[5], (cfg.ssm_d_conv, di), dtype)
                   / np.sqrt(cfg.ssm_d_conv)),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_B": (jax.random.normal(ks[6], (cfg.ssm_d_conv, gdim), dtype)
                   / np.sqrt(cfg.ssm_d_conv)),
        "conv_B_b": jnp.zeros((gdim,), dtype),
        "conv_C": (jax.random.normal(ks[7], (cfg.ssm_d_conv, gdim), dtype)
                   / np.sqrt(cfg.ssm_d_conv)),
        "conv_C_b": jnp.zeros((gdim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "ssm_norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }
    return p


def _causal_conv(xbc, w, b, conv_state=None):
    """Depthwise causal conv over the sequence axis. xbc [B,S,C]; w [K,C].
    If conv_state [B,K-1,C] is given (decode), returns updated state."""
    kw = w.shape[0]
    if conv_state is None:
        pad = jnp.pad(xbc, ((0, 0), (kw - 1, 0), (0, 0)))
        new_state = pad[:, -(kw - 1):, :] if kw > 1 else None
    else:
        pad = jnp.concatenate([conv_state, xbc], axis=1)
        new_state = pad[:, -(kw - 1):, :]
    out = sum(pad[:, i:pad.shape[1] - (kw - 1 - i), :] * w[i] for i in range(kw))
    return jax.nn.silu(out + b), new_state


def ssd_chunked(x, dt, A, B, C, chunk: int = 128, h0=None, unroll: bool = False):
    """x [b,s,nh,dh]; dt [b,s,nh]; A [nh]; B,C [b,s,ng,ds].
    Returns (y [b,s,nh,dh], h_final [b,nh,dh,ds])."""
    b, s, nh, dh = x.shape
    ng, ds = B.shape[2], B.shape[3]
    rep = nh // ng
    nch = -(-s // chunk)
    pad = nch * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    xs = x.reshape(b, nch, chunk, nh, dh)
    dts = dt.reshape(b, nch, chunk, nh)
    Bs = B.reshape(b, nch, chunk, ng, ds)
    Cs = C.reshape(b, nch, chunk, ng, ds)

    la = dts * A[None, None, None, :]                    # [b,nc,Q,nh], negative
    L = jnp.cumsum(la, axis=2)                           # inclusive

    def chunk_step(h, inp):
        xq, dtq, bq, cq, lq = inp                        # [b,Q,...]
        # expand groups to heads
        bqh = jnp.repeat(bq, rep, axis=2)                # [b,Q,nh,ds]
        cqh = jnp.repeat(cq, rep, axis=2)
        u = xq * dtq[..., None]                          # [b,Q,nh,dh]
        # intra-chunk: scores[i,j] = (C_i . B_j) exp(L_i - L_j), i >= j
        g = jnp.einsum("bihn,bjhn->bhij", cqh.astype(jnp.float32),
                       bqh.astype(jnp.float32))
        dec = lq[:, :, None, :] - lq[:, None, :, :]      # [b,i,j,nh]
        dec = jnp.transpose(dec, (0, 3, 1, 2))
        iq = jnp.arange(xq.shape[1])
        causal = (iq[:, None] >= iq[None, :])[None, None]
        # mask in log space BEFORE exp: masked entries have dec > 0 and would
        # overflow, poisoning gradients through the where (0 * inf = nan).
        dec = jnp.where(causal, dec, -jnp.inf)
        m = jnp.where(causal, g, 0.0) * jnp.exp(dec)
        y_intra = jnp.einsum("bhij,bjhd->bihd", m, u.astype(jnp.float32))
        # inter-chunk: y += exp(L_i) C_i h_in
        y_inter = jnp.einsum("bihn,bhdn->bihd", cqh.astype(jnp.float32)
                             * jnp.exp(lq)[..., None].transpose(0, 1, 2, 3),
                             h)
        # state update: h_out = exp(L_Q) h_in + sum_j exp(L_Q - L_j) u_j B_j
        lQ = lq[:, -1, :]                                # [b,nh]
        w = jnp.exp(lQ[:, None, :] - lq)                 # [b,Q,nh]
        h_new = (jnp.exp(lQ)[:, :, None, None] * h
                 + jnp.einsum("bjhd,bjhn->bhdn", (u * w[..., None]).astype(jnp.float32),
                              bqh.astype(jnp.float32)))
        return h_new, (y_intra + y_inter).astype(x.dtype)

    if h0 is None:
        h0 = jnp.zeros((b, nh, dh, ds), jnp.float32)
    hT, ys = jax.lax.scan(chunk_step, h0,
                          (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(dts, 1, 0),
                           jnp.moveaxis(Bs, 1, 0), jnp.moveaxis(Cs, 1, 0),
                           jnp.moveaxis(L, 1, 0)), unroll=True if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nch * chunk, nh, dh)[:, :s]
    return y, hT


def ssd_reference(x, dt, A, B, C, h0=None):
    """Token-by-token recurrence — the semantic ground truth (tests)."""
    b, s, nh, dh = x.shape
    ng, ds = B.shape[2], B.shape[3]
    rep = nh // ng
    h = jnp.zeros((b, nh, dh, ds), jnp.float32) if h0 is None else h0
    ys = []
    for t in range(s):
        la = dt[:, t] * A[None, :]                       # [b,nh]
        bt = jnp.repeat(B[:, t], rep, axis=1)            # [b,nh,ds]
        ct = jnp.repeat(C[:, t], rep, axis=1)
        u = (x[:, t] * dt[:, t][..., None]).astype(jnp.float32)
        h = jnp.exp(la)[:, :, None, None] * h + u[..., None] * bt[:, :, None, :]
        ys.append(jnp.einsum("bhdn,bhn->bhd", h, ct.astype(jnp.float32)))
    return jnp.stack(ys, axis=1).astype(x.dtype), h


def mamba2_apply(p, cfg, x, ssm_state=None, conv_state=None, impl: str = "chunked"):
    """Full block. x [B,S,D].  For decode pass states (S=1).  The conv cache
    keeps the legacy concat layout [B, K-1, di + 2*ng*ds]."""
    b, s, d = x.shape
    di, ng, ds, nh = cfg.ssm_d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_n_heads
    gdim = ng * ds
    dh = di // nh
    z = x @ p["w_z"]
    xr = x @ p["w_x"]
    Br = x @ p["w_B"]
    Cr = x @ p["w_C"]
    dt_raw = x @ p["w_dt"]
    cs = (None, None, None)
    if conv_state is not None:
        cs = (conv_state[..., :di], conv_state[..., di:di + gdim],
              conv_state[..., di + gdim:])
    xi, ncx = _causal_conv(xr, p["conv_x"], p["conv_x_b"], cs[0])
    B, ncb = _causal_conv(Br, p["conv_B"], p["conv_B_b"], cs[1])
    C, ncc = _causal_conv(Cr, p["conv_C"], p["conv_C_b"], cs[2])
    new_conv = (None if ncx is None
                else jnp.concatenate([ncx, ncb, ncc], axis=-1))
    xi = xi.reshape(b, s, nh, dh)
    B = B.reshape(b, s, ng, ds)
    C = C.reshape(b, s, ng, ds)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    if impl == "pallas":
        from repro.kernels.ops import ssd_scan
        y, hT = ssd_scan(xi, dt, A, B, C, h0=ssm_state)
    elif s == 1 and ssm_state is not None:
        y, hT = ssd_reference(xi, dt, A, B, C, h0=ssm_state)
    else:
        y, hT = ssd_chunked(xi, dt, A, B, C, chunk=cfg.ssm_chunk, h0=ssm_state,
                            unroll=cfg.scan_unroll)
    y = y + p["D"][None, None, :, None] * xi.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"])
    out = y @ p["out_proj"]
    return out, hT, new_conv
