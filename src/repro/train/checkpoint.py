"""Fault-tolerant checkpointing.

Layout:
    <dir>/step_000042/
        arrays.npz            flat {path -> np.ndarray}
        manifest.json         step, tree structure, shapes/dtypes, extras
    <dir>/LATEST              text file with the last *committed* step

Write protocol: save to step_X.tmp/, fsync, atomic rename to step_X/, then
update LATEST (rename of a tmp pointer).  A crash mid-save never corrupts
the restore path; restore() reads LATEST and falls back to the newest
complete directory.

Elastic restore: arrays are host numpy; ``restore_sharded`` re-places them
onto ANY mesh via jax.device_put with freshly computed specs, so a 256-chip
checkpoint restores onto 512 chips (or 8 CPU devices in the tests) without
a resharding tool.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


SEP = "|"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    return f"?:{p}"


def save(ckpt_dir, step: int, tree, extras: Optional[Dict[str, Any]] = None,
         keep_last: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": sorted(flat),
        "extras": extras or {},
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # commit pointer
    ptr_tmp = ckpt_dir / "LATEST.tmp"
    ptr_tmp.write_text(str(step))
    os.rename(ptr_tmp, ckpt_dir / "LATEST")
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: Path, keep_last: int):
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
                   if p.is_dir() and not p.name.endswith(".tmp"))
    for s in steps[:-keep_last]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    ptr = ckpt_dir / "LATEST"
    if ptr.exists():
        s = int(ptr.read_text().strip())
        if (ckpt_dir / f"step_{s:08d}" / "manifest.json").exists():
            return s
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
                   if p.is_dir() and (p / "manifest.json").exists())
    return steps[-1] if steps else None


def restore(ckpt_dir, like_tree, step: Optional[int] = None
            ) -> Tuple[int, Any, Dict[str, Any]]:
    """Restore into the structure of ``like_tree`` (values replaced)."""
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    arrays = np.load(d / "arrays.npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    leaves = []
    for path, leaf in paths:
        key = SEP.join(_path_str(p) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return step, jax.tree_util.tree_unflatten(treedef, leaves), manifest["extras"]


def restore_sharded(ckpt_dir, like_tree, shardings, step: Optional[int] = None):
    """Elastic restore: place each leaf with the given sharding tree (may
    target a different mesh/device count than the checkpoint was written
    from)."""
    step, host_tree, extras = restore(ckpt_dir, like_tree, step)
    placed = jax.tree.map(
        lambda a, s: jax.device_put(a, s) if s is not None else jax.device_put(a),
        host_tree, shardings,
        is_leaf=lambda x: isinstance(x, np.ndarray))
    return step, placed, extras
