"""AdamW with fp32 first/second moments, global-norm clipping, cosine
schedule, and optional ZeRO-1 (optimizer-state sharding over the data axis).

Pure-function API (no framework dependency):

    state = adamw_init(params)
    new_params, new_state, metrics = adamw_update(params, grads, state, hp)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


def zero1_specs(param_spec_tree, params_shape, mesh: Mesh):
    """ZeRO-1: shard each fp32 moment over the ``data`` axis along the first
    dimension that is (a) currently unsharded and (b) divisible — halving+
    optimizer HBM on every data rank.  Falls back to the param's own spec."""
    dp = mesh.shape.get("data", 1)

    def one(spec, shp):
        if dp <= 1:
            return spec
        parts = list(spec) + [None] * (len(shp.shape) - len(spec))
        flat = [a for s in parts if s is not None
                for a in (s if isinstance(s, (tuple, list)) else (s,))]
        if "data" in flat:
            return spec          # already data-sharded (e.g. FSDP'd experts)
        for i, (s, dim) in enumerate(zip(parts, shp.shape)):
            if s is None and dim % dp == 0 and dim >= dp:
                parts[i] = "data"
                return P(*parts)
        return spec

    return jax.tree.map(one, param_spec_tree, params_shape,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_spec_tree, params_shape, mesh, zero1: bool):
    moment = (zero1_specs(param_spec_tree, params_shape, mesh)
              if zero1 else param_spec_tree)
    return {"m": moment, "v": moment, "step": P()}
