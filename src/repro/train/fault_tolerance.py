"""Fault-tolerance utilities: supervised stepping with checkpoint/restart,
straggler mitigation in the gradient accumulator, and int8 error-feedback
gradient compression for the DCN (pod) axis.

Designed for 1000+ node posture: every mechanism is a pure function or a
small supervisor object whose state lives in the checkpoint, so a restarted
job (possibly on a different mesh — see checkpoint.restore_sharded) resumes
bit-identically except for the skipped slots.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------------
# Straggler mitigation: deadline-based microbatch skip with rescale
# ----------------------------------------------------------------------

@dataclasses.dataclass
class AccumulatorReport:
    used: int
    skipped: int
    scale: float


def accumulate_with_deadline(grad_fns, deadline_s: Optional[float] = None,
                             min_fraction: float = 0.5):
    """Run a list of microbatch gradient thunks; if a deadline is given and
    passes, remaining thunks are skipped and the mean is rescaled over the
    completed subset (classic straggler mitigation / backup-worker drop).

    Skipping below ``min_fraction`` raises (the step would be too biased) —
    the supervisor then treats it as a failed step and retries.
    """
    total = len(grad_fns)
    acc = None
    used = 0
    t0 = time.monotonic()
    for fn in grad_fns:
        if deadline_s is not None and used > 0 and (time.monotonic() - t0) > deadline_s:
            break
        g = fn()
        acc = g if acc is None else jax.tree.map(jnp.add, acc, g)
        used += 1
    if used < max(1, int(np.ceil(min_fraction * total))):
        raise TimeoutError(f"only {used}/{total} microbatches before deadline")
    scale = 1.0 / used
    acc = jax.tree.map(lambda a: a * scale, acc)
    return acc, AccumulatorReport(used=used, skipped=total - used, scale=scale)


# ----------------------------------------------------------------------
# int8 error-feedback compression (cross-pod gradient traffic)
# ----------------------------------------------------------------------

def ef_int8_compress(g, err):
    """Quantise g+err to int8 with per-tensor scale; returns (q, scale,
    new_err).  Error feedback keeps the quantisation noise from biasing the
    optimizer (Seide et al. / EF-SGD)."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g32 - deq


def ef_int8_roundtrip(grads, err_state):
    """Tree version: compress+decompress every leaf (what the wire would
    carry across the pod axis), with persistent error-feedback state."""
    if err_state is None:
        err_state = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(ef_int8_compress, grads, err_state)
    deq = jax.tree.map(lambda t: t[0].astype(jnp.float32) * t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[2], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err


def compressed_bytes_fraction(grads) -> float:
    """Wire-bytes ratio of int8+scale vs fp32 (reported in §Perf)."""
    total = sum(l.size * 4 for l in jax.tree.leaves(grads))
    comp = sum(l.size * 1 + 4 for l in jax.tree.leaves(grads))
    return comp / total


# ----------------------------------------------------------------------
# Supervisor: retry/restore loop around a step function
# ----------------------------------------------------------------------

class TrainSupervisor:
    """Wraps (state, batch) -> state stepping with checkpoint/restart.

    On exception: restores the last committed checkpoint and retries the
    step, up to ``max_retries`` per step — the single-process analogue of a
    coordinator replacing a failed worker and resuming from the last
    checkpoint; the data pipeline is step-addressed so replays are exact.
    """

    def __init__(self, ckpt_dir, save_every: int = 50, max_retries: int = 2,
                 keep_last: int = 3):
        from repro.train import checkpoint as ckpt
        self._ckpt = ckpt
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_retries = max_retries
        self.keep_last = keep_last
        self.failures: list = []

    def resume_or_init(self, init_state):
        step = self._ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return 0, init_state
        s, state, _ = self._ckpt.restore(self.ckpt_dir, init_state)
        return s + 1, state

    def run(self, state, step_fn: Callable, batch_fn: Callable, n_steps: int,
            start_step: int = 0, fault_injector: Optional[Callable] = None):
        step = start_step
        consecutive_failures = 0
        while step < n_steps:
            try:
                if fault_injector is not None:
                    fault_injector(step, consecutive_failures)
                state = step_fn(state, batch_fn(step))
            except Exception as e:                        # noqa: BLE001
                self.failures.append((step, repr(e)))
                consecutive_failures += 1
                if consecutive_failures > self.max_retries:
                    raise
                # restore AND rewind to the checkpointed step: every step
                # between the checkpoint and the failure is replayed (the
                # data pipeline is step-addressed, so replays are exact).
                latest = self._ckpt.latest_step(self.ckpt_dir)
                if latest is not None:
                    _, state, _ = self._ckpt.restore(self.ckpt_dir, state)
                    step = latest + 1
                continue
            consecutive_failures = 0
            if (step + 1) % self.save_every == 0 or step == n_steps - 1:
                self._ckpt.save(self.ckpt_dir, step, state,
                                keep_last=self.keep_last)
            step += 1
        return state
