"""train_step / serve-step builders with explicit shardings (pjit).

These are the functions the multi-pod dry-run lowers and the drivers run.
Everything here is mesh-aware but allocation-free: builders return
(step_fn, in_shardings, out_shardings, abstract_inputs) so callers can
either ``jit(...).lower(...)`` (dry-run) or materialise real arrays
(examples / integration tests).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeSpec
from repro.models.transformer import (ModelConfig, forward, init_params,
                                      lm_loss, logits_fn, make_caches,
                                      cache_spec)
from repro.sharding.specs import (param_specs, cache_specs, batch_axes,
                                  axis_size)
from repro.sharding.context import shard_ctx
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   opt_state_specs)


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _replicate_params(spec: ArchSpec, mesh: Mesh = None) -> bool:
    """SSM archs run DP-only (params replicated) when their head/inner dims
    cannot divide the model axis (mamba2-130m: 24 heads on a 16-wide axis);
    zamba2 (64 heads, d_inner 4096) tensor-parallelises fine with the split
    SSM projections."""
    if spec.family not in ("ssm", "hybrid"):
        return False
    cfg = spec.model
    tp = mesh.shape.get("model", 1) if mesh is not None else 16
    return (cfg.ssm_n_heads % tp != 0) or (cfg.ssm_d_inner % tp != 0)


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def batch_struct(cfg: ModelConfig, batch: int, seq: int):
    out = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
           "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.frontend == "vision":
        out["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, cfg.frontend_dim), cfg.param_dtype)
    elif cfg.frontend == "audio":
        out["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch, seq, cfg.frontend_dim), cfg.param_dtype)
    return out


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: int, allow_model: bool):
    ba = batch_axes(mesh, batch, allow_model=allow_model)
    b = ba if len(ba) > 1 else (ba[0] if ba else None)
    out = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.frontend is not None:
        out["frontend_embeds"] = P(b, None, None)
    return out


# ----------------------------------------------------------------------
# Training
# ----------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    aux_weight: float = 0.01):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            hidden, _, aux = forward(p, cfg, batch)
            loss = lm_loss(p, cfg, hidden, batch["labels"])
            return loss + aux_weight * aux, (loss, aux)

        grads, (loss, aux) = jax.grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "aux": aux, **om}
        return new_params, new_opt, metrics

    return train_step


def build_train(spec: ArchSpec, mesh: Mesh, shape: ShapeSpec,
                opt_cfg: AdamWConfig = AdamWConfig(), zero1: bool = True):
    cfg = spec.model
    replicate = _replicate_params(spec, mesh)
    p_shape = abstract_params(cfg)
    p_spec, fallbacks = param_specs(cfg, mesh, p_shape, replicate_all=replicate)
    o_shape = jax.eval_shape(adamw_init, p_shape)
    o_spec = opt_state_specs(p_spec, p_shape, mesh, zero1=zero1)
    b_struct = batch_struct(cfg, shape.batch, shape.seq)
    b_spec = batch_specs(cfg, mesh, shape.batch, allow_model=replicate)

    raw_step = make_train_step(cfg, opt_cfg)
    baxes = batch_axes(mesh, shape.batch, allow_model=replicate)
    model_axis = None if replicate else "model"

    def step(params, opt_state, batch):
        with shard_ctx(mesh, baxes, model_axis=model_axis):
            return raw_step(params, opt_state, batch)

    in_shardings = (_ns(mesh, p_spec), _ns(mesh, o_spec), _ns(mesh, b_spec))
    out_shardings = (_ns(mesh, p_spec), _ns(mesh, o_spec), None)
    jitted = jax.jit(step, in_shardings=in_shardings, out_shardings=out_shardings,
                     donate_argnums=(0, 1))
    return {
        "fn": jitted,
        "abstract_inputs": (p_shape, o_shape, b_struct),
        "param_spec": p_spec, "opt_spec": o_spec, "batch_spec": b_spec,
        "fallbacks": fallbacks,
    }


# ----------------------------------------------------------------------
# Serving: prefill (long input, builds caches) and decode (1 token)
# ----------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, caches):
        hidden, new_caches, _ = forward(params, cfg, batch, caches=caches,
                                        cache_pos=jnp.int32(0))
        logits = logits_fn(params, cfg, hidden[:, -1:, :])
        return logits[:, 0], new_caches

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, batch, caches, cache_pos):
        hidden, new_caches, _ = forward(params, cfg, batch, caches=caches,
                                        cache_pos=cache_pos)
        logits = logits_fn(params, cfg, hidden[:, -1:, :])
        return logits[:, 0], new_caches

    return decode_step


def cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    out = []
    for entry in cache_spec(cfg, batch, max_len):
        if entry is None:
            out.append(None)
        else:
            out.append(tuple(jax.ShapeDtypeStruct(s[:-1], s[-1]) for s in entry))
    return out


def decode_batch_struct(cfg: ModelConfig, batch: int):
    out = {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    if cfg.frontend == "vision":
        out["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, cfg.frontend_dim), cfg.param_dtype)
    elif cfg.frontend == "audio":
        out["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch, 1, cfg.frontend_dim), cfg.param_dtype)
    return out


def decode_batch_specs(cfg: ModelConfig, mesh: Mesh, batch: int, allow_model: bool):
    ba = batch_axes(mesh, batch, allow_model=allow_model)
    b = ba if len(ba) > 1 else (ba[0] if ba else None)
    out = {"tokens": P(b, None)}
    if cfg.frontend is not None:
        out["frontend_embeds"] = P(b, None, None)
    return out


def build_serve(spec: ArchSpec, mesh: Mesh, shape: ShapeSpec):
    """shape.kind == "prefill": lower the prefill over shape.seq tokens.
    shape.kind == "decode": lower one decode step against a shape.seq cache."""
    cfg = spec.model
    replicate = _replicate_params(spec, mesh)
    p_shape = abstract_params(cfg)
    p_spec, fallbacks = param_specs(cfg, mesh, p_shape, replicate_all=replicate)
    c_struct = cache_struct(cfg, shape.batch, shape.seq)
    c_spec = cache_specs(cfg, mesh, shape.batch, replicate_all=replicate)

    def cspec_tree():
        return [None if s is None else s for s in c_spec]

    if shape.kind == "prefill":
        b_struct = batch_struct(cfg, shape.batch, shape.seq)
        del b_struct["labels"]
        b_spec = batch_specs(cfg, mesh, shape.batch, allow_model=replicate)
        del b_spec["labels"]
        raw = make_prefill_step(cfg)
        baxes = batch_axes(mesh, shape.batch, allow_model=replicate)

        def step(params, batch, caches):
            with shard_ctx(mesh, baxes, model_axis=None if replicate else "model"):
                return raw(params, batch, caches)

        in_shardings = (_ns(mesh, p_spec), _ns(mesh, b_spec), _ns(mesh, cspec_tree()))
        out_shardings = (None, _ns(mesh, cspec_tree()))
        jitted = jax.jit(step, in_shardings=in_shardings,
                         out_shardings=out_shardings, donate_argnums=(2,))
        abstract = (p_shape, b_struct, c_struct)
    elif shape.kind == "decode":
        b_struct = decode_batch_struct(cfg, shape.batch)
        b_spec = decode_batch_specs(cfg, mesh, shape.batch, allow_model=replicate)
        raw = make_decode_step(cfg)
        baxes = batch_axes(mesh, shape.batch, allow_model=replicate)

        def step(params, batch, caches, cache_pos):
            with shard_ctx(mesh, baxes, model_axis=None if replicate else "model"):
                return raw(params, batch, caches, cache_pos)

        pos = jax.ShapeDtypeStruct((), jnp.int32)
        in_shardings = (_ns(mesh, p_spec), _ns(mesh, b_spec),
                        _ns(mesh, cspec_tree()), None)
        out_shardings = (None, _ns(mesh, cspec_tree()))
        jitted = jax.jit(step, in_shardings=in_shardings,
                         out_shardings=out_shardings, donate_argnums=(2,))
        abstract = (p_shape, b_struct, c_struct, pos)
    else:
        raise ValueError(shape.kind)
    return {
        "fn": jitted, "abstract_inputs": abstract,
        "param_spec": p_spec, "cache_spec": c_spec, "batch_spec": b_spec,
        "fallbacks": fallbacks,
    }
