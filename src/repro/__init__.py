"""repro: Online Partial Service Hosting at the Edge (alpha-RetroRenting)
as a production-grade multi-pod JAX framework.

Subpackages:
  core      the paper's algorithms + analysis
  models    assigned-architecture model zoo (dense/GQA/MLA/MoE/SSM/hybrid)
  kernels   Pallas TPU kernels (flash attention, SSD scan, MoE gating)
  sharding  DP/TP/EP/SP partitioning rules
  train     optimizer, train loop, checkpointing, fault tolerance
  serve     batched serving engine + alpha-RR hosting controller
  data      deterministic synthetic pipelines
  configs   one module per assigned architecture
  launch    production mesh, multi-pod dry-run, roofline
"""

__version__ = "1.0.0"
