"""Multi-service hosting: N services sharing one edge node's storage.

The model (Online Service Caching and Routing at the Edge with Unknown
Arrivals, 2107.10446): each service keeps its own level set / g-curve /
fetch cost, the edge constrains the SUM of hosted fractions, and each
service sees its own arrival stream while the rent (spot price of the one
edge) is common.  This module maps that problem onto the existing fleet
engine along two complementary axes — no engine changes, both bitwise
N=1-identical to the single-service paths (tests/test_multi_service.py):

* **Per-service lanes** (online policies): service n of instance b is fleet
  row ``b * N + n`` of an ordinary [B*N] fleet (``ServiceFleet.lane_fleet``)
  driven by a ``tile_services``-salted scenario — every engine axis
  (chunking, streaming, meshes, ``n_seeds``, policy fan-out, the stepper)
  applies unchanged.  Independent lanes are capacity-OBLIVIOUS:
  ``capacity_overflow`` measures how far a lane schedule exceeds the shared
  capacity.
* **Joint states** (offline OPT): the feasible per-service level
  combinations of each instance become the states of a matrix-M
  ``HostingGrid`` (``costs.ServiceSet.joint_grid``), and ``joint_scenario``
  reduces the tiled per-service streams to one ``[B, chunk]`` joint slab
  (x summed, rent from lane 0, per-level service costs gathered per joint
  state).  ``offline_opt_services`` then runs the UNCHANGED fleet DP over
  the joint states — capacity-respecting by construction, proven against
  ``policies.offline_opt.brute_force_joint_opt``.

Engine-invariant documentation lives in docs/ARCHITECTURE.md and
docs/CONVENTIONS.md.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import (HostingGrid, ServiceSet, default_float_dtype,
                              joint_hosting_grid)
from repro.core.fleet import (FleetBatch, FleetOfflineResult, FleetResult,
                              evaluate_schedule_fleet, fleet_stepper,
                              offline_opt_fleet, run_fleet)
from repro.core.policies.alpha_rr import AlphaRR
from repro.core.policies.base import PolicyFns
from repro.core.scenarios.base import ObsSlab, Scenario
from repro.core.scenarios.combinators import tile_services


@dataclasses.dataclass(frozen=True)
class ServiceFleet:
    """B multi-service instances (one ``ServiceSet`` each, a common N) with
    per-instance horizons — the container both mappings start from."""

    sets: Tuple[ServiceSet, ...]
    T: np.ndarray                      # [B] per-instance horizons

    def __post_init__(self):
        object.__setattr__(self, "sets", tuple(self.sets))
        if not self.sets:
            raise ValueError("need at least one instance")
        Ns = {ss.N for ss in self.sets}
        if len(Ns) != 1:
            raise ValueError(f"instances must share one service count, "
                             f"got N in {sorted(Ns)}")
        object.__setattr__(
            self, "T",
            np.broadcast_to(np.asarray(self.T, np.int32),
                            (len(self.sets),)).copy())

    @property
    def B(self) -> int:
        return len(self.sets)

    @property
    def N(self) -> int:
        return self.sets[0].N

    def lane_grid(self) -> HostingGrid:
        """[B*N] single-service grid: service n of instance b is row
        ``b * N + n`` (instance-major, service-minor — the ``tile_services``
        row order)."""
        return HostingGrid.from_costs(
            [cc for ss in self.sets for cc in ss.services])

    def lane_fleet(self) -> FleetBatch:
        """The obs-less [B*N] lane fleet (pair with a tiled scenario)."""
        return FleetBatch.for_scenario(self.lane_grid(),
                                       np.repeat(self.T, self.N))

    def joint_grid(self) -> HostingGrid:
        """[B] matrix-M joint-state grid (mixed state counts padded)."""
        return joint_hosting_grid(list(self.sets))

    def joint_fleet(self) -> FleetBatch:
        """The obs-less [B] joint fleet (pair with ``joint_scenario``)."""
        return FleetBatch.for_scenario(self.joint_grid(), self.T)


def service_fleet(sets: Sequence[ServiceSet], T) -> ServiceFleet:
    """Construct a ``ServiceFleet`` (``T`` scalar or [B])."""
    return ServiceFleet(sets=tuple(sets), T=T)


def service_scenario(sfleet: ServiceFleet, scenario: Scenario) -> Scenario:
    """The [B*N] per-service form of ``scenario``: a [B]-row scenario is
    ``tile_services``-expanded (per-service key salting, shared rent); an
    already-[B*N]-row scenario passes through untouched."""
    B_sc = scenario.B
    if B_sc == sfleet.B * sfleet.N:
        return scenario
    if B_sc != sfleet.B:
        raise ValueError(f"scenario B={B_sc} matches neither B={sfleet.B} "
                         f"nor B*N={sfleet.B * sfleet.N}")
    return tile_services(scenario, sfleet.N)


def alpha_rr_per_service(sfleet: ServiceFleet) -> PolicyFns:
    """alpha-RR run independently per service: the plain ``AlphaRR`` policy
    batch on the lane fleet — each lane is bitwise a standalone
    single-service alpha-RR run (capacity-oblivious; see
    ``capacity_overflow``)."""
    return AlphaRR.fleet(sfleet.lane_fleet())


# ----------------------------------------------------------------------
# Per-service lane runs (online policies).
# ----------------------------------------------------------------------

@dataclasses.dataclass
class ServiceFleetResult:
    """A lane-fleet ``FleetResult`` plus the [B, N] row bookkeeping."""

    fleet: FleetResult
    B: int
    N: int

    def service_view(self, a) -> np.ndarray:
        """Reshape a lane-row-leading array to ``[P, B, N, S, ...]``
        (policy-major, instance, service, seed-minor — the engine's row
        layout with rows ``((p * B + b) * N + n) * S + s``)."""
        a = np.asarray(a)
        S = self.fleet.n_seeds
        P = self.fleet.n_policies
        return a.reshape((P, self.B, self.N, S) + a.shape[1:])

    @property
    def total(self) -> np.ndarray:
        return self.service_view(self.fleet.total)

    @property
    def edge_total(self) -> np.ndarray:
        """[P, B, S] cost of the whole edge (summed over services)."""
        return self.total.sum(axis=2)


def run_fleet_services(policy, sfleet: ServiceFleet, *,
                       scenario: Scenario, **kwargs) -> ServiceFleetResult:
    """``run_fleet`` over the per-service lanes: ``policy`` (or a fan-out
    list) is built against ``sfleet.lane_fleet()`` (e.g.
    ``alpha_rr_per_service``); ``scenario`` is [B]-row (auto-tiled) or
    already [B*N].  Every ``run_fleet`` keyword (chunking, ``stream=``,
    ``n_seeds=``, backends, meshes) passes straight through — at N=1 the
    call IS the single-service ``run_fleet`` call, bit for bit."""
    res = run_fleet(policy, sfleet.lane_fleet(),
                    scenario=service_scenario(sfleet, scenario), **kwargs)
    return ServiceFleetResult(fleet=res, B=sfleet.B, N=sfleet.N)


def fleet_stepper_services(policy, sfleet: ServiceFleet, *,
                           scenario: Optional[Scenario] = None, **kwargs):
    """``fleet_stepper`` over the per-service lanes (rows ``b * N + n``;
    readbacks are lane-row-shaped — reshape with
    ``ServiceFleetResult.service_view`` semantics)."""
    if scenario is not None:
        scenario = service_scenario(sfleet, scenario)
    return fleet_stepper(policy, sfleet.lane_fleet(), scenario=scenario,
                         **kwargs)


def evaluate_schedule_services(sfleet: ServiceFleet, r_hist, *,
                               scenario: Optional[Scenario] = None,
                               **kwargs) -> ServiceFleetResult:
    """Price per-service schedules (``r_hist`` [B, N, T] or [B*N, T]) on
    the lane fleet — ``evaluate_schedule_fleet`` with the same tiled
    observations the lanes ran on."""
    r = np.asarray(r_hist)
    if r.ndim == 3:
        r = r.reshape(sfleet.B * sfleet.N, r.shape[-1])
    if scenario is not None:
        scenario = service_scenario(sfleet, scenario)
    res = evaluate_schedule_fleet(sfleet.lane_fleet(), r, scenario=scenario,
                                  **kwargs)
    return ServiceFleetResult(fleet=res, B=sfleet.B, N=sfleet.N)


def hosted_fractions(sfleet: ServiceFleet, r_hist) -> np.ndarray:
    """[B, N, T] hosted fractions of lane schedules (``r_hist`` [B*N, T]
    or [B, N, T] level indices)."""
    r = np.asarray(r_hist, np.int64)
    if r.ndim == 3:
        r = r.reshape(sfleet.B * sfleet.N, r.shape[-1])
    if r.shape[0] != sfleet.B * sfleet.N:
        raise ValueError(f"r_hist has {r.shape[0]} rows, expected "
                         f"B*N={sfleet.B * sfleet.N} (peel seed/policy axes "
                         "first)")
    lv = np.asarray(sfleet.lane_grid().levels)
    fr = np.take_along_axis(lv, r, axis=1)
    return fr.reshape(sfleet.B, sfleet.N, -1)


def capacity_overflow(sfleet: ServiceFleet, r_hist) -> np.ndarray:
    """[B, T] ``max(0, sum_n hosted fraction - capacity)`` per slot — the
    shared-capacity violation of independent per-service schedules (the
    joint DP's schedules are 0 everywhere by construction)."""
    tot = hosted_fractions(sfleet, r_hist).sum(axis=1)
    cap = np.asarray([ss.cap for ss in sfleet.sets])[:, None]
    return np.maximum(tot - cap, 0.0)


# ----------------------------------------------------------------------
# Joint-state runs (capacity-respecting offline OPT).
# ----------------------------------------------------------------------

def _reshape_sub(params, B: int, N: int):
    return jax.tree_util.tree_map(
        lambda a: jnp.reshape(jnp.asarray(a),
                              (B, N) + jnp.shape(jnp.asarray(a))[1:]),
        params)


@functools.lru_cache(maxsize=32)
def _joint_fns(sub_init, sub_chunk, has_svc: bool, has_side: bool):
    """(init_fn, chunk_fn) of a joint-state scenario, memoized on the tiled
    scenario's *functions* (the ``_combine_fns`` convention, so the
    identity-keyed compile caches downstream hit across constructions).

    The wrapper vmaps the tiled per-service generator over its [N] axis and
    reduces the N sub-slabs to ONE joint slab: ``x`` summed, ``c`` from
    service lane 0 (one edge, one rent stream — ``tile_services``' shared
    rent group makes all lanes identical anyway), and the per-joint-state
    service channel gathered per service and summed — Model-2 slabs via
    ``idx`` column gathers, Model-1 via the per-state ``g_lane`` prices.
    Both reductions are one-term identities at N=1, which is the bitwise
    N=1 anchor of the joint DP path."""

    def init_fn(params):
        return jax.vmap(sub_init)(params["sub"])

    def chunk_fn(params, state, tids):
        st2, slab = jax.vmap(lambda p, s: sub_chunk(p, s, tids))(
            params["sub"], state)
        x_sub = slab.x                                  # [N, chunk]
        idx = params["idx"]                             # [N, J] int32
        if has_svc:
            svc_sub = slab.svc                          # [N, chunk, K]
            N, chunk = x_sub.shape
            J = idx.shape[-1]
            gathered = jnp.take_along_axis(
                svc_sub, jnp.broadcast_to(idx[:, None, :], (N, chunk, J)),
                axis=2)                                 # [N, chunk, J]
            svc = jnp.sum(gathered, axis=0)
        else:
            g_lane = params["g_lane"]                   # [N, J]
            svc = jnp.sum(x_sub[:, :, None].astype(g_lane.dtype)
                          * g_lane[:, None, :], axis=0)
        side = None if slab.side is None else slab.side[0]
        return st2, ObsSlab(x=jnp.sum(x_sub, axis=0), c=slab.c[0], svc=svc,
                            side=side)

    return init_fn, chunk_fn


def joint_scenario(sfleet: ServiceFleet, scenario: Scenario) -> Scenario:
    """Reduce a (possibly still untiled) per-service scenario to the [B]
    JOINT-state scenario that drives ``sfleet.joint_fleet()``: one slab per
    instance with per-joint-state service costs (see ``_joint_fns``).
    Padded joint states of mixed-J fleets gather their set's last real
    state — priced ``+inf`` by the grid mask, never selected."""
    tiled = service_scenario(sfleet, scenario)
    B, N = sfleet.B, sfleet.N
    J = max(ss.J for ss in sfleet.sets)
    idx = np.zeros((B, N, J), np.int32)
    g_lane = np.zeros((B, N, J), np.float32)
    for b, ss in enumerate(sfleet.sets):
        st = ss.joint_states()                          # [J_b, N]
        Jb = st.shape[0]
        idx[b, :, :Jb] = st.T
        idx[b, :, Jb:] = idx[b, :, Jb - 1:Jb]
        for n, cc in enumerate(ss.services):
            g_lane[b, n, :Jb] = np.asarray(cc.g, np.float32)[st[:, n]]
            g_lane[b, n, Jb:] = g_lane[b, n, Jb - 1]
    params = {"sub": _reshape_sub(tiled.params, B, N),
              "idx": jnp.asarray(idx),
              "g_lane": jnp.asarray(g_lane, default_float_dtype())}
    init_fn, chunk_fn = _joint_fns(tiled.init_fn, tiled.chunk_fn,
                                   tiled.has_svc, tiled.has_side)
    return Scenario(f"joint{N}({scenario.name})", init_fn, chunk_fn, params,
                    has_svc=True, has_side=tiled.has_side)


@dataclasses.dataclass
class ServiceOfflineResult:
    """Joint capacity-respecting OPT of a ``ServiceFleet``.

    ``joint`` is the raw fleet DP result on the joint-state grid
    (``joint.r_hist`` rows are JOINT-state indices); ``service_schedules``
    translates them back to per-service level indices."""

    joint: FleetOfflineResult
    sfleet: ServiceFleet

    @property
    def cost(self) -> np.ndarray:
        return self.joint.cost

    def service_schedules(self) -> np.ndarray:
        """[rows, N, T] per-service level-index schedules (rows are the
        DP result's rows: instance-major, seed-minor)."""
        st = np.asarray(self.joint.r_hist, np.int64)
        S = self.joint.n_seeds
        out = np.zeros((st.shape[0], self.sfleet.N, st.shape[1]), np.int64)
        for row in range(st.shape[0]):
            states = self.sfleet.sets[row // S].joint_states()
            out[row] = states[st[row]].T
        return out


def offline_opt_services(sfleet: ServiceFleet, *, scenario: Scenario,
                         **kwargs) -> ServiceOfflineResult:
    """The joint capacity-respecting OPT: the UNCHANGED fleet DP
    (``offline_opt_fleet`` — materialized or checkpointed, chunked or
    streamed, any ``dp_backend``) over the joint-state grid, driven by the
    joint scenario.  Every keyword passes through.  Feasibility is free:
    infeasible level combinations are simply not states."""
    res = offline_opt_fleet(sfleet.joint_fleet(),
                            scenario=joint_scenario(sfleet, scenario),
                            **kwargs)
    return ServiceOfflineResult(joint=res, sfleet=sfleet)


def offline_opt_per_service(sfleet: ServiceFleet, *, scenario: Scenario,
                            **kwargs) -> FleetOfflineResult:
    """The capacity-OBLIVIOUS per-service OPT: ``offline_opt_fleet`` on the
    independent lanes.  Summed over services it lower-bounds the joint
    optimum (relaxing the capacity constraint can only help), and equals it
    when capacity never binds — both directions are tested."""
    return offline_opt_fleet(sfleet.lane_fleet(),
                             scenario=service_scenario(sfleet, scenario),
                             **kwargs)
