"""HostingController: the paper's alpha-RR policy driving a real serving
runtime.

Each scheduler slot, the controller observes (request count, spot rent,
realized per-level service costs), advances alpha-RetroRenting one step, and
returns the *hosting plan* the engine must realise for the next slot
(none / partial / full — see serve/partial.py for what "partial" means per
architecture).  It accounts fetch/rent/service cost exactly as eq. (1) and
its state is a tiny pytree, checkpointed with the training/serving step so
decisions survive restarts (fault tolerance).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.costs import HostingCosts
from repro.core.policies.alpha_rr import AlphaRR
from repro.core.policies.base import SlotObs


@dataclasses.dataclass
class SlotRecord:
    slot: int
    level_idx: int
    level: float
    x: int
    rent: float
    service: float
    fetch: float

    @property
    def total(self) -> float:
        return self.rent + self.service + self.fetch


class HostingController:
    def __init__(self, costs: HostingCosts, policy_cls=AlphaRR):
        self.policy = policy_cls(costs)
        # all accounting uses the POLICY's own level grid: a no-partial
        # policy (RetroRenting) rebuilds a 2-level instance internally, and
        # its level indices must not be read against the 3-level grid.
        self.costs = self.policy.costs
        # bind the pure (init_fn, step_fn, params) once: params are pytrees
        # of arrays built from costs, and rebuilding them every live slot
        # (as policy.step() would) costs more than the step itself
        self._fns = self.policy.fns()
        self.state = self._fns.init_fn(self._fns.params)
        self.slot = 0
        self.records: list[SlotRecord] = []

    @property
    def level_idx(self) -> int:
        return int(self.state["r"])

    @property
    def level(self) -> float:
        return float(self.costs.levels[self.level_idx])

    def step(self, x_t: int, c_t: float, svc_t: Optional[np.ndarray] = None) -> int:
        """Advance one slot.  ``svc_t`` is the realized per-level service
        cost vector (Model 2); None uses the deterministic Model-1 costs.
        Returns the level index to host for the NEXT slot."""
        lv = np.asarray(self.costs.levels)
        g = np.asarray(self.costs.g)
        if svc_t is None:
            svc_t = g * float(x_t)
        svc_t = np.asarray(svc_t, np.float32)
        if svc_t.shape[0] != self.costs.K:
            raise ValueError(f"svc vector has {svc_t.shape[0]} levels, policy "
                             f"uses {self.costs.K} (pass costs matching the "
                             f"policy's grid)")
        r_prev = self.level_idx
        obs = SlotObs(jnp.int32(x_t), jnp.float32(c_t),
                      jnp.asarray(svc_t), jnp.int32(0))
        self.state = self._fns.step_fn(self._fns.params, self.state, obs)
        r_next = self.level_idx
        fetch = self.costs.M * max(lv[r_next] - lv[r_prev], 0.0)
        self.records.append(SlotRecord(
            slot=self.slot, level_idx=r_prev, level=float(lv[r_prev]),
            x=int(x_t), rent=float(c_t * lv[r_prev]),
            service=float(svc_t[r_prev]), fetch=float(fetch)))
        self.slot += 1
        return r_next

    # ---- accounting ---------------------------------------------------
    def total_cost(self) -> float:
        return float(sum(r.total for r in self.records))

    def cost_breakdown(self) -> Dict[str, float]:
        return {
            "fetch": float(sum(r.fetch for r in self.records)),
            "rent": float(sum(r.rent for r in self.records)),
            "service": float(sum(r.service for r in self.records)),
            "total": self.total_cost(),
        }

    def level_histogram(self) -> np.ndarray:
        h = np.zeros(self.costs.K, np.int64)
        for r in self.records:
            h[r.level_idx] += 1
        return h

    # ---- checkpointing (fault tolerance) -------------------------------
    def state_dict(self) -> Dict:
        return {
            "slot": self.slot,
            "policy_state": {k: np.asarray(v) for k, v in self.state.items()},
            "records": [(r.slot, r.level_idx, r.level, r.x, r.rent, r.service,
                         r.fetch) for r in self.records],
        }

    def load_state_dict(self, sd: Dict):
        self.slot = int(sd["slot"])
        self.state = {k: jnp.asarray(v) for k, v in sd["policy_state"].items()}
        self.records = [SlotRecord(*row) for row in sd["records"]]
