"""Time-varying rent-cost processes.

The paper models rent with an ARMA(4,2) process fit to AWS EC2 spot prices
[33] (the Kaggle dataset is not available offline — see DESIGN.md §2; we use
ARMA(4,2) with coefficients chosen to mimic slow-mean-reverting, positively
autocorrelated spot prices, and provide a Hannan-Rissanen fitter so any
user-supplied price series can be fit the way the paper describes [16]).

Also provides i.i.d. uniform rents and negatively-associated rents
(Assumption 7 uses negative association; antithetic pairs are NA).

Generation lives in ``core.scenarios.streams`` (counter-based streams that
fuse into the fleet scan); the functions here materialize those streams
over a whole horizon (bit-identical under the same key) for the classic
array-building API.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# Default ARMA(4,2) parameters: slowly mean-reverting with mild MA smoothing.
# (Stationary: AR roots outside the unit circle.)
DEFAULT_AR = (0.55, 0.20, 0.10, 0.05)
DEFAULT_MA = (0.40, 0.20)


def _mat1(stream, T: int):
    from repro.core.scenarios.base import materialize_stream
    return materialize_stream(stream, int(T))[0]


@dataclasses.dataclass(frozen=True)
class ARMAProcess:
    """ARMA(p, q):  (c_t - mu) = sum phi_i (c_{t-i} - mu) + eps_t + sum th_j eps_{t-j}."""

    mean: float
    ar: tuple = DEFAULT_AR
    ma: tuple = DEFAULT_MA
    sigma: float = 0.05
    c_min: float = 0.05
    c_max: float = 10.0

    def stream(self, key, B: int = 1):
        """This process as a fleet-fusable rent stream."""
        from repro.core.scenarios.streams import arma_rents
        return arma_rents(key, self.mean, B=B, ar=self.ar, ma=self.ma,
                          sigma=self.sigma, c_min=self.c_min,
                          c_max=self.c_max)

    def sample(self, key, T: int) -> jnp.ndarray:
        return _mat1(self.stream(key), T)


def iid_uniform(key, c_mean: float, half_width: float, T: int,
                c_min: float = 1e-3) -> jnp.ndarray:
    from repro.core.scenarios.streams import uniform_rents
    return _mat1(uniform_rents(key, c_mean, half_width, B=1, c_min=c_min), T)


def negatively_associated(key, c_mean: float, half_width: float, T: int) -> jnp.ndarray:
    """Antithetic-pair construction: (U, 1-U) pairs are negatively associated,
    satisfying Assumption 7's rent-process requirement."""
    from repro.core.scenarios.streams import na_rents
    return _mat1(na_rents(key, c_mean, half_width, B=1), T)


def constant(c: float, T: int) -> jnp.ndarray:
    return jnp.full((T,), c, dtype=jnp.float32)


# ----------------------------------------------------------------------
# Hannan–Rissanen two-stage ARMA fit (what "fit the model to price data"
# [16] means operationally).
# ----------------------------------------------------------------------

def fit_arma(series: np.ndarray, p: int = 4, q: int = 2,
             ar_order_long: int = 20) -> ARMAProcess:
    """Fit ARMA(p,q) by Hannan–Rissanen: (1) long-AR fit for residuals,
    (2) OLS of the series on its own lags and lagged residuals."""
    y = np.asarray(series, dtype=np.float64)
    mu = float(y.mean())
    z = y - mu
    T = len(z)
    m = min(ar_order_long, max(p + q, T // 10))
    # stage 1: long AR via least squares
    X1 = np.stack([z[m - i - 1:T - i - 1] for i in range(m)], axis=1)
    y1 = z[m:]
    a, *_ = np.linalg.lstsq(X1, y1, rcond=None)
    eps = np.zeros(T)
    eps[m:] = y1 - X1 @ a
    # stage 2: regress z_t on p lags of z and q lags of eps
    s = max(p, q) + m
    rows = []
    targ = []
    for t in range(s, T):
        rows.append(np.concatenate([z[t - p:t][::-1], eps[t - q:t][::-1]]))
        targ.append(z[t])
    X2 = np.asarray(rows)
    y2 = np.asarray(targ)
    b, *_ = np.linalg.lstsq(X2, y2, rcond=None)
    ar = tuple(float(v) for v in b[:p])
    ma = tuple(float(v) for v in b[p:p + q])
    resid = y2 - X2 @ b
    return ARMAProcess(mean=mu, ar=ar, ma=ma, sigma=float(resid.std()),
                       c_min=float(max(y.min() * 0.5, 1e-3)), c_max=float(y.max() * 1.5))


def aws_spot_like(key, c_mean: float, T: int, rel_sigma: float = 0.15,
                  c_min: float | None = None, c_max: float | None = None) -> jnp.ndarray:
    """Convenience: ARMA(4,2) with default coefficients, scaled to a target
    mean — the shape of the paper's EC2 spot-price rent process.  The
    stream form is ``scenarios.spot_rents`` (same defaults; same bits under
    the same key)."""
    proc = ARMAProcess(mean=c_mean, sigma=rel_sigma * c_mean,
                       c_min=c_min if c_min is not None else max(0.2 * c_mean, 1e-3),
                       c_max=c_max if c_max is not None else 3.0 * c_mean)
    return proc.sample(key, T)
