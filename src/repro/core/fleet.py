"""Fleet engine: device-sharded, mixed-horizon, time-chunked simulation.

This is the layer above ``simulator.run_policy_batch``: it runs a *fleet* —
B independent hosting instances with possibly different horizons T_i — as
one compiled program sharded over a 1-D device mesh, optionally streaming
the time axis in fixed-size chunks.  (Engine-wide layer map:
``docs/ARCHITECTURE.md``; the invariants new code must preserve:
``docs/CONVENTIONS.md``.)  Three orthogonal mechanisms, each a
bitwise no-op when unused:

**[B] sharding** — the instance axis is embarrassingly parallel, so the
vmapped per-instance core is wrapped in ``shard_map`` over the ``fleet``
mesh axis (``sharding.specs.fleet_mesh``).  B is padded up to a device
multiple with dummy instances (``T = 0``: every slot invalid, zero cost,
frozen state) and results are sliced back, so sharded output ==
``run_policy_batch`` output bit-for-bit on any device count.

**Mixed horizons** — a ``FleetBatch`` stacks per-instance horizons ``T``
next to [B, T_max]-padded observations.  ``simulator.sim_chunk_core``
freezes policy state and adds exactly ``0.0`` to every accumulator on slots
at or past an instance's own T (see ``policies.base.freeze_invalid``), so
each instance's totals/trace match a standalone run at its own horizon, and
the final speculative fetch is charged at each instance's own last slot.

**Time streaming** — the horizon is cut into fixed-size chunks with the
``(policy state, accumulator)`` carry threaded across chunk boundaries;
accumulation order is unchanged, so chunked == unchunked bit-for-bit.  Two
drivers share the same chunk kernel:

  * ``chunk_size=...`` — an outer ``lax.scan`` over chunks on device (one
    XLA program; obs stay resident);
  * ``stream=True``    — a host loop feeding one [B, chunk] slab at a time
    to a jitted sharded chunk-step, so a T=10^6 trace never materialises
    [B, T_max] on device (device memory is O(B * chunk)).

``offline_opt_fleet`` applies the same three mechanisms to the offline DP
(forward recursion chunked and frozen past T_i with identity backpointers;
padded K levels priced ``+inf`` as in ``offline_opt_batch``), and adds a
fourth of its own:

**Checkpointed backtracking** — ``checkpointed=True`` replaces the
materialized [B, T, K] backpointer table with a two-pass recursion: the
forward value pass stores one [B, K] frontier checkpoint per chunk (plus
the generator state for scenario-fused runs), and the backtrack pass
replays the chunks in reverse, recomputing each chunk's argmin table on
the fly from its checkpoint — the same counter-keyed regeneration the
fused simulator relies on.  Bit-identical to the materialized path
wherever both fit (the recomputed tables come from the identical
``offline_opt.dp_fwd_chunk`` at the identical frontier), with device
memory O(B * chunk * K): exact OPT now reaches the same T = 10^6-10^7
horizons as ``run_fleet(collect_trace=False)``.  ``stream=True`` drives
both passes from the host one slab at a time; ``collect_schedule=False``
skips the backtrack for cost-only pricing with no O(T) output at all;
``offline_dp_memory_stats`` exposes the XLA-reported memory of the
compiled core for either path (the regression-gated
``kernel_bench.offline_dp_streaming`` row asserts the ratio).

**Scenario fusion** — every entry point alternatively accepts
``scenario=...`` (a ``core.scenarios.Scenario``) in place of materialized
observations: the generator's ``chunk_fn`` runs *inside* the chunked scan,
emitting one [B, chunk] slab of arrivals/rents (plus optional Model-2
service draws and side-state) per chunk, with the generator state threaded
through the scan carry next to the policy state.  Device memory stays
O(B * chunk) at any horizon and **zero** observation bytes cross the
host->device boundary (the streaming driver ships only a scalar chunk
offset).  Because scenario streams are counter-keyed (see
``core/scenarios/base.py``), fused generation is bit-identical to
materializing the same scenario (``scenarios.materialize`` /
``FleetBatch.from_scenario``) and running the classic path — for every
policy, the offline DP, and schedule evaluation, under every
mesh x chunking x driver configuration (tests/test_scenarios.py).  Pass
``collect_trace=False`` to drop the [B, T] ``r_hist`` output, the one
remaining O(T) device buffer, for T >= 10^6 fleets.

**Monte-Carlo seed axis** — every scenario-driven entry point accepts
``n_seeds=S``: the engine replicates the fleet to [B*S] rows
(instance-major, seed-minor) with seed ``s`` folded into every stream key
via ``scenarios.combinators.replicate_seeds`` — ``fold_in(key, s)``
*before* the per-slot ``fold_in(key, t)`` — so replica row ``(b, s)`` is
bit-identical to running instance ``b`` standalone under
``with_seed(scenario, s)``.  Replication, padding to the device multiple
and result unflattening all happen inside (composing with
shard_map/chunking/streaming); results carry ``n_seeds`` and a
``seed_view`` reshaping any [B*S]-leading array to [B, S], and
``mc_summary`` collapses the seed axis into per-instance means and
Student-t 95% CI half-widths (tests/test_mc_driver.py).  ``antithetic=True``
pairs the replicas (2m, 2m+1) on flip-capable streams — shared pair fold,
odd member flips every uniform — cutting CI width at the same S.

**Persistent stepper & async ingestion** — the ``stream=True`` drivers
(simulation and the checkpointed-DP forward pass) are thin loops over ONE
persistent ``FleetStepper``: a pre-compiled slab step from the
module-level ``functools.lru_cache`` factories, with the ``(state,
accumulator)`` carry and the incoming slab buffers donated back to XLA
every call (``jax.jit(donate_argnums=...)``), so advancing a fleet one
chunk at a time triggers **zero retraces** after warmup and never copies
the carry.  Conventions new code must preserve:

  * every streamed step factory stays module-level and lru-cached with
    ``donate`` in its key — a stepper LOOKS UP its compiled step, so
    constructing steppers (or calling ``run_fleet(stream=True)``
    repeatedly) never retraces a warm config;
  * ``donate=True`` callers must never retain a reference to a carry or
    slab after passing it in (the buffer is invalidated); paths that must
    retain old carries — the ``collect_schedule=True`` DP forward, which
    checkpoints them for the backtrack — pass ``donate=False``;
  * the traced step bodies bump ``STREAM_TRACES``, keeping the
    zero-retrace claim a tested invariant (tests/test_fleet_stepper.py),
    and donation must never break the bit-identity suites.

``async_ingest=True`` (streamed obs-backed paths) swaps the inline slab
build for ``core.ingest.SlabPrefetcher``: a double-buffered daemon thread
prepares slab n+1 (host slicing, dtype casts, the host->device put) while
the device executes slab n — XLA execute releases the GIL, so host work
overlaps device compute instead of serializing with it.  Bit-identical to
the synchronous loop by construction (same slabs, same order; asserted in
the ``stream_overlap`` bench row).  ``fleet_stepper`` exposes the same
machinery as a public long-lived API for live serving
(``serve.scheduler.LiveFleetScheduler``): admit per-instance telemetry
one slab at a time, read back per-instance hosting levels/fractions, zero
recompiles at any step count.

**Policy fan-out** — ``run_fleet`` (and ``fleet_stepper``) accept a
*sequence* of policies: each generated [B, chunk] obs slab is produced
exactly ONCE per scan step and every policy *lane* steps against it inside
the same compiled program — plus, with ``with_opt_forward=True``, the
offline DP's [B, K] entry frontier per lane, so a whole competitive-ratio
panel (every online family AND the OPT denominators) prices one shared
sample path in a single generation pass.  Conventions:

  * a **lane** is a ``PolicyFns`` (scored on the fleet's own grid) or a
    ``policies.base.PolicyLane`` binding the pair to its own accounting
    grid (e.g. the endpoint restriction for RR) plus — mandatory for
    Model-2 service, where the slab is generated on the fleet grid — a
    [B, K_lane] ``svc_cols`` column map (``HostingGrid.endpoint_columns``
    builds the endpoint one).  This check is the policy-axis home of the
    old ``fused_policy_families`` same-stream-family validation: lanes
    share the stream *by construction*, the engine only verifies each
    lane can price it;
  * lane states are heterogeneous (different policies, different K), so
    the carry holds a TUPLE of per-lane ``(state, acc)`` pytrees and each
    lane runs literally its own ``sim_chunk_core`` call over the shared
    slab (``simulator.sim_chunk_lanes``) — identical op chain, identical
    in-carry reduction order, per-lane ``freeze_invalid`` — which is why
    ``policies=[p]`` fan-out == standalone ``run_fleet(p)`` and lane ``p``
    of a fan-out == its standalone restricted run hold *bitwise*, under
    every mesh x chunking x streaming x ``n_seeds`` x backend config
    (tests/test_policy_fanout.py);
  * ``with_opt_forward=True`` threads one DP frontier per lane (the
    lane's own lv/mask, ``dp_fwd_chunk`` — the exact chunk kernel every
    offline driver shares) through the same carry and returns
    ``FleetResult.opt_cost``, bit-identical to
    ``offline_opt_fleet(checkpointed=True, collect_schedule=False)`` on
    the matching restricted fleet;
  * results are **policy-major**: row ``(p * B + b) * S + s``; reshape
    with ``FleetResult.policy_view`` ([P, B*S] leading axes), then
    ``seed_view`` per policy.  Compile-cache keys grow the tuple of
    per-lane ``(init_fn, step_fn)`` pairs — fan-out factories stay
    module-level and lru-cached like every other core.

**Multi-host fleets** — with ``jax.distributed`` initialized
(``repro.sharding.distributed.initialize()``), the ``fleet`` mesh spans
every process and the instance axis is bounded by aggregate host RAM.
Conventions:

  * **Global vs local B.**  Callers pass PROCESS-LOCAL inputs: each
    process constructs a ``FleetBatch`` / policy / scenario holding only
    its own ``B_local`` rows (the same ``B_local`` on every process),
    and owns global rows ``[p * B_pad_local, (p + 1) * B_pad_local)`` —
    the mesh orders devices process-contiguously, and padding to a device
    multiple happens per process (``_prepare_fleet``), which makes the
    global pad a global-device multiple automatically.  Counter-keyed
    scenarios make shard construction trivially consistent: build the
    global key set, keep your ``B_local`` slice.
  * **Who feeds which slab shard.**  Every obs path assembles global
    arrays with ``jax.make_array_from_process_local_data``
    (``_dev_rows``): each host device-puts only its own ``[B_local, ...]``
    rows — slab ingestion (``_obs_slab_builder`` -> ``slab_feed`` /
    ``SlabPrefetcher``), stepper telemetry (``FleetStepper.step``), and
    whole-horizon transfers alike ship ZERO cross-host observation bytes.
    The compiled cores are unchanged: ``shard_map`` over the fleet axis
    has no collectives, so per-row compute is process-local by
    construction and N-process == 1-process bit-identity holds row for
    row (tests/test_multihost.py).
  * **``gather=`` semantics.**  Results (and stepper readbacks) default
    to process-local views — this process's ``B_local`` rows, matching
    its inputs.  ``gather=True`` allgathers the full ``[B_global]`` rows
    onto every process (one cross-host collective per array, the only
    cross-host traffic in the engine); it is a no-op on single-process
    meshes, so library code can pass it through unconditionally.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import math
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.costs import HostingCosts, HostingGrid, default_float_dtype
from repro.core.ingest import slab_feed
from repro.core.policies.base import PolicyFns, PolicyLane, as_policy_lanes
from repro.core.policies.offline_opt import (DP_BACKENDS, dp_backtrack,
                                             dp_backtrack_chunk,
                                             dp_fetch_matrix, dp_frontier0,
                                             dp_fwd_chunk)
from repro.core.scenarios.base import PRNG_BACKENDS, Scenario, chunk_geometry
from repro.core.scenarios.combinators import (replicate_seeds,
                                              with_prng_backend)
from repro.core.simulator import (SimResult, sim_acc0, sim_chunk_core,
                                  sim_chunk_lanes, schedule_chunk_core)
from repro.sharding.context import shard_ctx
from repro.sharding.specs import (FLEET_AXIS, fleet_mesh,
                                  mesh_is_multiprocess,
                                  mesh_local_device_count,
                                  mesh_process_count)


# ----------------------------------------------------------------------
# FleetBatch: stacked instances + per-instance horizons.
# ----------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FleetBatch:
    """B hosting instances stacked with per-instance horizons.

    Attributes:
      grid: stacked ``HostingGrid`` (K-padding conventions live there).
      x:    [B, T_max] int32 arrivals, zero-padded past each instance's T —
            or None for a scenario-driven fleet (``for_scenario``), whose
            observations are generated on device inside the scan.
      c:    [B, T_max] rent costs, zero-padded (None with a scenario).
      T:    [B] int32 per-instance horizons (T_i <= T_max).
      svc:  optional [B, T_max, K] realized Model-2 service costs; None means
            Model 1 (``g * x``), computed chunk-by-chunk on device so it is
            never materialised for the whole horizon.
      side: optional [B, T_max] int32 side-channel (e.g. Markov state).

    Slots with ``t >= T_i`` are *invalid*: the engine freezes policy state
    and accumulates exactly zero cost there, so padded tails never affect an
    instance (the padding values themselves are arbitrary).
    """

    grid: HostingGrid
    x: Optional[jnp.ndarray]
    c: Optional[jnp.ndarray]
    T: jnp.ndarray
    svc: Optional[jnp.ndarray] = None
    side: Optional[jnp.ndarray] = None

    # ---- pytree protocol ---------------------------------------------
    def tree_flatten(self):
        return (self.grid, self.x, self.c, self.T, self.svc, self.side), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # ---- constructors -------------------------------------------------
    # Obs arrays are built HOST-resident (numpy): the compiled device paths
    # transfer them at the jit boundary anyway, and the streaming driver
    # must be able to slab-feed a horizon that never fits on device.

    @staticmethod
    def from_instances(costs_list: Sequence[HostingCosts], xs, cs,
                       svcs=None, sides=None) -> "FleetBatch":
        """Stack per-instance traces of *mixed lengths* (lists of [T_i]
        arrays; ``svcs`` entries are [T_i, K_i]), padding T and K."""
        grid = HostingGrid.from_costs(costs_list)
        dt = default_float_dtype()
        B, K = grid.B, grid.K
        lens = [int(np.shape(xi)[0]) for xi in xs]
        T_max = max(lens)
        x = np.zeros((B, T_max), np.int32)
        c = np.zeros((B, T_max), dt)
        svc = None if svcs is None else np.zeros((B, T_max, K), dt)
        side = None if sides is None else np.zeros((B, T_max), np.int32)
        for i in range(B):
            x[i, :lens[i]] = np.asarray(xs[i])
            c[i, :lens[i]] = np.asarray(cs[i])
            if svcs is not None:
                si = np.asarray(svcs[i])
                svc[i, :lens[i], :si.shape[1]] = si
            if sides is not None:
                side[i, :lens[i]] = np.asarray(sides[i])
        return FleetBatch(grid=grid, x=x, c=c,
                          T=np.asarray(lens, np.int32), svc=svc, side=side)

    @staticmethod
    def from_dense(grid: HostingGrid, x, c, svc=None, side=None,
                   T=None) -> "FleetBatch":
        """Wrap already-stacked [B, T] (or broadcastable [T]) observations;
        ``T`` defaults to the uniform full horizon."""
        dt = default_float_dtype()
        B = grid.B
        x = np.asarray(x, np.int32)
        if x.ndim == 1:
            x = np.broadcast_to(x[None, :], (B, x.shape[0]))
        T_max = x.shape[1]
        c = np.asarray(c, dt)
        if c.ndim == 1:
            c = np.broadcast_to(c[None, :], (B, T_max))
        if svc is not None:
            svc = np.asarray(svc, dt)
            if svc.ndim == 2:
                svc = np.broadcast_to(svc[None], (B,) + svc.shape)
        if side is not None:
            side = np.asarray(side, np.int32)
            if side.ndim == 1:
                side = np.broadcast_to(side[None, :], (B, T_max))
        if T is None:
            T = np.full((B,), T_max, np.int32)
        else:
            T = np.broadcast_to(np.asarray(T, np.int32), (B,))
        return FleetBatch(grid=grid, x=x, c=c, T=T, svc=svc, side=side)

    @staticmethod
    def for_scenario(grid: HostingGrid, T) -> "FleetBatch":
        """A fleet with NO materialized observations: pass the matching
        ``scenario=...`` to the engine entry points and the obs are
        generated on device inside the scan.  ``T`` is a scalar or [B]
        per-instance horizon vector."""
        T = np.broadcast_to(np.asarray(T, np.int32), (grid.B,))
        return FleetBatch(grid=grid, x=None, c=None, T=T)

    @staticmethod
    def from_scenario(grid: HostingGrid, scenario: Scenario, T,
                      chunk_size: Optional[int] = None) -> "FleetBatch":
        """Materialize a scenario into a classic obs-backed fleet (the
        reference the fused path is proven bit-identical against)."""
        from repro.core.scenarios.base import materialize
        T = np.broadcast_to(np.asarray(T, np.int32), (grid.B,))
        x, c, svc, side = materialize(scenario, int(T.max()), chunk_size)
        return FleetBatch.from_dense(grid, x, c, svc=svc, side=side, T=T)

    # ---- derived ------------------------------------------------------
    @property
    def B(self) -> int:
        return self.grid.B

    @property
    def K(self) -> int:
        return self.grid.K

    @property
    def T_max(self) -> int:
        if self.x is None:
            return int(np.max(np.asarray(self.T)))
        return self.x.shape[1]

    def restrict_to_endpoints(self) -> "FleetBatch":
        """The no-partial-hosting view (RR / OPT): 2-level grid, service
        costs gathered down to the (0, top) columns.  The gather runs in
        numpy so a host-resident svc stays on the host (same values as
        ``HostingGrid.endpoint_service``, which works on device arrays)."""
        svc2 = None
        if self.svc is not None:
            svc = np.asarray(self.svc)
            top = np.asarray(self.grid.top_index())          # [B]
            hi = np.take_along_axis(
                svc, np.broadcast_to(top[:, None, None],
                                     svc.shape[:2] + (1,)), axis=2)
            svc2 = np.concatenate([svc[:, :, :1], hi], axis=2)
        return FleetBatch(grid=self.grid.restrict_to_endpoints(),
                          x=self.x, c=self.c, T=self.T, svc=svc2,
                          side=self.side)


def _pad_rows(a, B_pad, xp=jnp):
    """Pad the leading [B] axis to B_pad by replicating row 0 (the padded
    rows run with T=0, so their contents never matter)."""
    B = a.shape[0]
    if B == B_pad:
        return a
    rep = xp.broadcast_to(a[:1], (B_pad - B,) + a.shape[1:])
    return xp.concatenate([a, rep], axis=0)


def _pad_fleet(fleet: FleetBatch, B_pad: int, T_pad: int) -> FleetBatch:
    """Pad instances to ``B_pad`` (dummy rows, T=0) and the time axis to
    ``T_pad`` (invalid tail slots).

    Obs padding runs in numpy so host-resident obs STAY on the host — the
    compiled drivers transfer whole [B, T] blocks at the jit boundary, and
    the streaming driver must never move more than one slab to the device.
    The (small) grid stays a device pytree.  Scenario-driven fleets
    (``x is None``) have no obs to pad — only the grid and T rows.
    """
    x = None if fleet.x is None else np.asarray(fleet.x)
    c = None if fleet.c is None else np.asarray(fleet.c)
    T = np.asarray(fleet.T)
    svc = None if fleet.svc is None else np.asarray(fleet.svc)
    side = None if fleet.side is None else np.asarray(fleet.side)
    if T_pad > fleet.T_max and x is not None:
        dt_pad = T_pad - fleet.T_max
        x = np.pad(x, ((0, 0), (0, dt_pad)))
        c = np.pad(c, ((0, 0), (0, dt_pad)))
        if svc is not None:
            svc = np.pad(svc, ((0, 0), (0, dt_pad), (0, 0)))
        if side is not None:
            side = np.pad(side, ((0, 0), (0, dt_pad)))
    if B_pad > fleet.B:
        grid = HostingGrid(M=_pad_rows(fleet.grid.M, B_pad),
                           levels=_pad_rows(fleet.grid.levels, B_pad),
                           g=_pad_rows(fleet.grid.g, B_pad),
                           mask=_pad_rows(fleet.grid.mask, B_pad))
        if x is not None:
            x = _pad_rows(x, B_pad, np)
            c = _pad_rows(c, B_pad, np)
        T = np.concatenate([T, np.zeros((B_pad - fleet.B,), np.int32)])
        if svc is not None:
            svc = _pad_rows(svc, B_pad, np)
        if side is not None:
            side = _pad_rows(side, B_pad, np)
    else:
        grid = fleet.grid
    return FleetBatch(grid=grid, x=x, c=c, T=T, svc=svc, side=side)


def _prepare_fleet(fleet: FleetBatch, mesh: Optional[Mesh],
                   chunk_size: Optional[int]):
    """Shared prologue of every fleet entry point: resolve the mesh, pad B
    to a device multiple (dummy T=0 instances) and T to a chunk multiple.
    Returns ``(mesh, padded fleet, n_chunks, T_pad)`` — T_pad is explicit
    because scenario-driven fleets carry no obs array to read it from."""
    mesh = fleet_mesh() if mesh is None else mesh
    if mesh_is_multiprocess(mesh):
        # Each process holds only its own [B_local] rows; pad them to a
        # multiple of the LOCAL device count.  Because the mesh orders
        # devices process-contiguously (fleet_mesh sorts on process_index)
        # and every process contributes the same device count, the global
        # pad is automatically a global-device multiple and process p's
        # rows are global rows [p * B_pad_local, (p + 1) * B_pad_local).
        n_dev = mesh_local_device_count(mesh)
    else:
        n_dev = int(mesh.devices.size)
    B_pad = math.ceil(fleet.B / n_dev) * n_dev
    n_chunks, T_pad = chunk_geometry(fleet.T_max, chunk_size)
    return mesh, _pad_fleet(fleet, B_pad, T_pad), n_chunks, T_pad


# ----------------------------------------------------------------------
# Multi-host data movement: process-local rows <-> globally-sharded arrays.
# Every helper is an exact single-process no-op, so the 1-process code
# paths stay byte-for-byte what they were.
# ----------------------------------------------------------------------

def _dev_rows(mesh, a):
    """Device-put a [B_pad_local, ...] row block for this mesh: plain
    ``jnp.asarray`` on a single-process mesh; on a process-spanning mesh, a
    globally-sharded ``jax.Array`` assembled with
    ``jax.make_array_from_process_local_data`` (this process contributes
    only its own rows — zero cross-host bytes, the sharding metadata is the
    only thing every process agrees on)."""
    if not mesh_is_multiprocess(mesh):
        return jnp.asarray(a)
    a = np.asarray(a)
    sharding = NamedSharding(mesh, P(FLEET_AXIS))
    gshape = (a.shape[0] * mesh_process_count(mesh),) + a.shape[1:]
    return jax.make_array_from_process_local_data(sharding, a, gshape)


def _dev_tree(mesh, tree):
    """``_dev_rows`` over every [B]-leading leaf of a params pytree."""
    return jax.tree_util.tree_map(lambda a: _dev_rows(mesh, a), tree)


def _dev_replicated(mesh, a):
    """Device-put a replicated (P()) input: committed locally on a
    single-process mesh; left an UNCOMMITTED host value on a multi-process
    mesh, where jit treats it as same-on-every-process replicated data (a
    locally-committed array would be rejected by a multi-process jit)."""
    return np.asarray(a) if mesh_is_multiprocess(mesh) else jnp.asarray(a)


def _local_rows(a):
    """Host view of this process's rows: for a non-fully-addressable global
    array, the process-local shards concatenated in global row order
    ([B_pad_local, ...]); otherwise plain ``np.asarray``."""
    if isinstance(a, jax.Array) and not a.is_fully_addressable:
        shards = sorted(a.addressable_shards,
                        key=lambda s: s.index[0].start or 0)
        return np.concatenate([np.asarray(s.data) for s in shards], axis=0)
    return np.asarray(a)


def _gather_rows(mesh, a):
    """The ``gather=True`` opt-in: allgather process-local result rows to
    the full [B_global, ...] array on every process (one cross-host
    collective per array).  A no-op on single-process meshes and None."""
    if a is None or not mesh_is_multiprocess(mesh):
        return a
    from jax.experimental import multihost_utils
    # Gather the raw BIT PATTERN: a uint8 view widens the last axis by
    # itemsize, so the allgather never routes float64/int64 values through
    # jax's x64-disabled canonicalization (which would silently downcast —
    # gather=True must be dtype- and bit-exact).
    a = np.ascontiguousarray(a)
    out = np.asarray(multihost_utils.process_allgather(
        a.view(np.uint8), tiled=True))
    return out.view(a.dtype)


def _gather_result(res: "FleetResult", mesh) -> "FleetResult":
    g = lambda a: _gather_rows(mesh, a)
    return dataclasses.replace(
        res, total=g(res.total), fetch=g(res.fetch), rent=g(res.rent),
        service=g(res.service), r_hist=g(res.r_hist),
        level_slots=g(res.level_slots), T=g(res.T),
        opt_cost=g(res.opt_cost))


def _vmap_init(init_fn, params, mesh):
    """``vmap(init_fn)`` over [B]-stacked params with the output sharded
    like the inputs — on a process-spanning mesh the vmapped init runs
    under ``shard_map`` so every state leaf comes out P(fleet)-sharded
    (ready for the compiled step's in_specs with no resharding)."""
    if mesh_is_multiprocess(mesh):
        f = shard_map(jax.vmap(init_fn), mesh=mesh, in_specs=(P(FLEET_AXIS),),
                      out_specs=P(FLEET_AXIS), check_rep=False)
        return jax.jit(f)(params)
    return jax.jit(jax.vmap(init_fn))(params)


# ----------------------------------------------------------------------
# Results.
# ----------------------------------------------------------------------

@dataclasses.dataclass
class FleetResult:
    """[B]-structured results of one fleet simulation (padded instances and
    padded time already sliced away).

    With a Monte-Carlo axis (``n_seeds=S``) the row axis is the flattened
    [B_instances * S] replication, instance-major and seed-minor: row
    ``b * S + s`` is instance ``b`` under seed ``s``.  ``seed_view``
    reshapes any such array to [B_instances, S, ...].

    With a policy fan-out axis (``n_policies=P > 1``) the row axis is
    additionally POLICY-MAJOR: row ``(p * B_fleet + b) * S + s`` is lane
    ``p`` on fleet row ``b`` under seed ``s``.  ``policy_view`` peels the
    lane axis off any [P * B_fleet * S]-leading array (after which
    ``seed_view`` applies per lane); ``level_slots`` of hetero-K lanes are
    zero-padded to the widest lane's K, and ``opt_cost`` carries the
    co-executed per-lane DP optimum when run with ``with_opt_forward=True``.
    """

    total: np.ndarray         # [B]
    fetch: np.ndarray         # [B]
    rent: np.ndarray          # [B]
    service: np.ndarray       # [B]
    r_hist: Optional[np.ndarray]  # [B, T_max] (frozen past each T_i); None
                                  # when run with collect_trace=False
    level_slots: np.ndarray   # [B, K] slots spent at each level
    T: np.ndarray             # [B] per-instance horizons
    n_seeds: int = 1          # MC replicas per instance (B = B_instances * S)
    n_policies: int = 1       # fan-out lanes (B = P * B_fleet * S)
    opt_cost: Optional[np.ndarray] = None  # [B] offline DP optimum per row
                                           # (with_opt_forward=True only)

    @property
    def B(self) -> int:
        return self.total.shape[0]

    @property
    def B_instances(self) -> int:
        """Distinct instances (the pre-replication B; includes the policy
        axis when fanned out — peel that off first with ``policy_view``)."""
        return self.B // self.n_seeds

    def seed_view(self, a) -> np.ndarray:
        """Reshape a [B*S]-leading result array to [B_instances, S, ...]."""
        a = np.asarray(a)
        return a.reshape((self.B_instances, self.n_seeds) + a.shape[1:])

    def policy_view(self, a) -> np.ndarray:
        """Reshape a policy-major [P * B_fleet * S]-leading result array to
        [P, B_fleet * S, ...] — one row block per fan-out lane."""
        a = np.asarray(a)
        return a.reshape((self.n_policies, self.B // self.n_policies)
                         + a.shape[1:])

    @property
    def per_slot(self) -> np.ndarray:
        return self.total / self.T

    def instance(self, i: int) -> SimResult:
        if self.r_hist is None:
            raise ValueError("no r_hist: fleet ran with collect_trace=False")
        return SimResult(total=float(self.total[i]), fetch=float(self.fetch[i]),
                         rent=float(self.rent[i]), service=float(self.service[i]),
                         r_hist=self.r_hist[i, :int(self.T[i])],
                         level_slots=self.level_slots[i])


@dataclasses.dataclass
class FleetOfflineResult:
    cost: np.ndarray                    # [B]
    r_hist: Optional[np.ndarray]        # [B, T_max]; None when the DP ran
                                        # with collect_schedule=False
    sim: Optional[FleetResult]          # None with collect_schedule=False
    n_seeds: int = 1

    def seed_view(self, a) -> np.ndarray:
        """Reshape a [B*S]-leading result array to [B_instances, S, ...]."""
        a = np.asarray(a)
        B = self.cost.shape[0] // self.n_seeds
        return a.reshape((B, self.n_seeds) + a.shape[1:])


def _fleet_result(r_hist, sums, counts, B, T_max, T,
                  n_seeds: int = 1) -> FleetResult:
    # float64 host accumulation, matching run_policy_batch; on a
    # multi-process mesh the device arrays read back as THIS process's rows
    # (_local_rows), so B here is the process-local row count
    sums = _local_rows(sums)[:B].astype(np.float64)
    return FleetResult(
        total=sums.sum(axis=1),
        rent=sums[:, 0], service=sums[:, 1], fetch=sums[:, 2],
        r_hist=None if r_hist is None else _local_rows(r_hist)[:B, :T_max],
        level_slots=_local_rows(counts)[:B].astype(np.int64),
        T=np.asarray(T).astype(np.int64), n_seeds=n_seeds)


def _fanout_result(r_lanes, sums_lanes, counts_lanes, opt_lanes,
                   B, T_max, T, n_seeds, mesh, gather=False) -> FleetResult:
    """Policy-major assembly of a fan-out run: each lane's device rows are
    sliced to this process's B rows exactly as ``_fleet_result`` does
    (identical casts, identical reduction order — lane p of the result is
    bitwise the standalone result), then concatenated along the row axis.
    On a process-spanning mesh ``gather=True`` allgathers PER LANE before
    concatenating — gathering the concatenated rows would interleave
    processes into the policy-major layout.  ``level_slots`` of hetero-K
    lanes are zero-padded to the widest lane's K."""
    gr = (lambda a: _gather_rows(mesh, a)) if gather else (lambda a: a)
    P_n = len(sums_lanes)
    sums = np.concatenate(
        [gr(_local_rows(s)[:B].astype(np.float64)) for s in sums_lanes])
    counts = [gr(_local_rows(cnt)[:B].astype(np.int64))
              for cnt in counts_lanes]
    K_max = max(cnt.shape[1] for cnt in counts)
    counts = np.concatenate(
        [np.pad(cnt, ((0, 0), (0, K_max - cnt.shape[1]))) for cnt in counts])
    r_hist = None
    if r_lanes is not None:
        r_hist = np.concatenate(
            [gr(np.ascontiguousarray(_local_rows(r)[:B, :T_max]))
             for r in r_lanes])
    opt_cost = None
    if opt_lanes is not None:
        opt_cost = np.concatenate(
            [gr(_local_rows(o)[:B].astype(np.float64)) for o in opt_lanes])
    T_rows = gr(np.asarray(T).astype(np.int64))
    return FleetResult(
        total=sums.sum(axis=1), rent=sums[:, 0], service=sums[:, 1],
        fetch=sums[:, 2], r_hist=r_hist, level_slots=counts,
        T=np.tile(T_rows, P_n), n_seeds=n_seeds, n_policies=P_n,
        opt_cost=opt_cost)


# ----------------------------------------------------------------------
# Monte-Carlo summary over the seed axis.
# ----------------------------------------------------------------------

# two-sided 97.5% Student-t quantiles by degrees of freedom (n_seeds - 1);
# the normal 1.96 badly undercovers at the small n_seeds the sweeps use
_T975 = {1: 12.706, 2: 4.303, 3: 3.182, 4: 2.776, 5: 2.571, 6: 2.447,
         7: 2.365, 8: 2.306, 9: 2.262, 10: 2.228}


def student_t975(df: int) -> float:
    """Two-sided 97.5% Student-t quantile (95% CI width) for ``df`` degrees
    of freedom — the ONE table every MC aggregation path shares
    (``mc_summary`` here, ``benchmarks.common.mc_aggregate``)."""
    if df in _T975:
        return _T975[df]
    return 2.04 if df <= 30 else 1.96


def mc_stats(v, axis: int = -1):
    """(mean, ci95 half-width) over the seed axis of ``v`` (Student-t, same
    quantiles as ``mc_summary``); ci95 is zeros when that axis has one
    sample."""
    v = np.asarray(v, np.float64)
    S = v.shape[axis]
    mean = v.mean(axis=axis)
    if S <= 1:
        return mean, np.zeros_like(mean)
    ci = student_t975(S - 1) * v.std(axis=axis, ddof=1) / math.sqrt(S)
    return mean, ci


def mc_summary(result, fields=("total", "rent", "service", "fetch"),
               antithetic: bool = False):
    """Collapse a seed-replicated result's MC axis into arrays.

    Accepts a ``FleetResult`` (or ``FleetOfflineResult``, whose summarised
    field is ``cost``) produced with ``n_seeds=S``.  Returns a dict with
    ``n_seeds`` plus, per field, ``<f>_mean`` and ``<f>_ci95`` arrays of
    shape [B_instances] — the per-instance seed-mean and the two-sided 95%
    Student-t CI half-width (zeros at S == 1).

    ``antithetic=True`` (for results of ``run_fleet(...,
    antithetic=True)``) averages each replica pair (2m, 2m+1) into one
    pair-mean before the CI — the pairs are negatively correlated by
    construction, so the naive S-sample formula badly overstates the
    estimator's width; the S/2 pair-means are independent and give the
    valid (and much tighter) interval.  The reported mean is unchanged.
    """
    if isinstance(result, FleetOfflineResult):
        fields = tuple(f if f != "total" else "cost" for f in fields
                       if f in ("total", "cost"))
    if antithetic and result.n_seeds % 2:
        raise ValueError("antithetic summary needs an even n_seeds")
    out = {"n_seeds": result.n_seeds}
    for f in fields:
        v = result.seed_view(getattr(result, f))
        if antithetic:
            v = np.asarray(v, np.float64)
            v = (v[:, 0::2] + v[:, 1::2]) / 2.0
        mean, ci = mc_stats(v, axis=1)
        out[f"{f}_mean"] = mean
        out[f"{f}_ci95"] = ci
    return out


# ----------------------------------------------------------------------
# Compiled cores: vmap over instances, shard_map over the fleet axis.
# ----------------------------------------------------------------------

def _model1_svc(x, g):
    # identical elementwise to _batch_obs's full-horizon computation, so
    # computing it per chunk is bitwise equivalent
    return x[..., :, None].astype(g.dtype) * g[..., None, :]


def _chunked_drive(run_chunk, carry0, n_chunks: int, arrays):
    """The one chunk driver every fleet core shares (sim, DP fwd, schedule
    eval): cut each [T_pad, ...] array of ``arrays`` (None entries pass
    through) into ``n_chunks`` chunks, thread ``carry`` across them with an
    outer ``lax.scan``, and restitch the per-chunk ys.  ``run_chunk(carry,
    t0, *chunk_arrays) -> (carry', ys_chunk | None)``.  n_chunks == 1 calls
    ``run_chunk`` directly — chunked == unchunked is proven against that
    path, so keep any chunking change HERE, not in the cores."""
    T_pad = next(a for a in arrays if a is not None).shape[0]
    chunk = T_pad // n_chunks
    if n_chunks == 1:
        return run_chunk(carry0, jnp.asarray(0, jnp.int32), *arrays)
    xs = tuple(None if a is None
               else a.reshape((n_chunks, chunk) + a.shape[1:])
               for a in arrays)

    def outer(carry, inp):
        t0, *cks = inp
        return run_chunk(carry, t0, *cks)

    carry, ys = jax.lax.scan(
        outer, carry0, (jnp.arange(n_chunks, dtype=jnp.int32) * chunk,) + xs)
    if ys is not None:
        # ys may be a pytree (the fan-out cores emit one trace per lane);
        # for a single array the tree_map is the previous reshape verbatim
        ys = jax.tree_util.tree_map(
            lambda y: y.reshape((T_pad,) + y.shape[2:]), ys)
    return carry, ys


def _make_instance_core(init_fn, step_fn, include_final_fetch: bool,
                        n_chunks: int, has_svc: bool, has_side: bool,
                        collect_trace: bool = True):
    """Whole-horizon core for ONE instance: outer scan over T-chunks, inner
    ``sim_chunk_core`` per chunk.  Args: (params, lv, g, M, T_len, x, c
    [, svc][, side]) with [T_pad]-shaped obs, T_pad = n_chunks * chunk."""

    def core(params, lv, g, M, T_len, x, c, *opt):
        K = lv.shape[-1]
        svc = opt[0] if has_svc else None
        side = opt[1 if has_svc else 0] if has_side else None
        carry0 = (init_fn(params), sim_acc0(K, lv.dtype))

        def run_chunk(carry, t0, xck, cck, sck, sdck):
            if sck is None:
                sck = _model1_svc(xck, g)
            if sdck is None:
                sdck = jnp.zeros(xck.shape, jnp.int32)
            carry, r = sim_chunk_core(step_fn, include_final_fetch, params,
                                      lv, M, T_len, t0, carry, xck, cck,
                                      sck, sdck)
            return carry, (r if collect_trace else None)

        carry, r_hist = _chunked_drive(run_chunk, carry0, n_chunks,
                                       (x, c, svc, side))
        (_, acc) = carry
        if collect_trace:
            return r_hist, acc["sums"], acc["counts"]
        return acc["sums"], acc["counts"]

    return core


@functools.lru_cache(maxsize=64)
def _compiled_fleet_core(init_fn, step_fn, include_final_fetch: bool,
                         n_chunks: int, has_svc: bool, has_side: bool,
                         collect_trace: bool, mesh: Mesh):
    core = _make_instance_core(init_fn, step_fn, include_final_fetch,
                               n_chunks, has_svc, has_side, collect_trace)
    n_args = 7 + int(has_svc) + int(has_side)
    spec = P(FLEET_AXIS)
    n_out = 3 if collect_trace else 2
    sharded = shard_map(jax.vmap(core), mesh=mesh,
                        in_specs=(spec,) * n_args,
                        out_specs=(spec,) * n_out)
    return jax.jit(sharded)


def _slab_obs(slab, g):
    """Fill a generated slab's optional channels with the engine defaults
    (Model-1 service from the slab's own arrivals; zero side)."""
    svc = slab.svc if slab.svc is not None else _model1_svc(slab.x, g)
    side = (slab.side if slab.side is not None
            else jnp.zeros(slab.x.shape, jnp.int32))
    return slab.x, slab.c, svc, side


def _make_scenario_instance_core(init_fn, step_fn, sc_init, sc_chunk,
                                 include_final_fetch: bool, n_chunks: int,
                                 collect_trace: bool):
    """Fused core for ONE instance: the scenario's ``chunk_fn`` generates
    each [chunk] slab *inside* the outer scan (generator state threaded
    through the carry next to the policy state), then ``sim_chunk_core``
    consumes it.  Args: (pparams, sparams, lv, g, M, T_len, tids_all) where
    ``tids_all = arange(T_pad)`` is the only [T]-shaped input — replicated,
    never sharded, and the only thing resembling an obs array anywhere."""

    def core(pparams, sparams, lv, g, M, T_len, tids_all):
        K = lv.shape[-1]
        carry0 = (sc_init(sparams), (init_fn(pparams), sim_acc0(K, lv.dtype)))

        def run_chunk(carry, t0, tids):
            gen_state, sim = carry
            gen_state, slab = sc_chunk(sparams, gen_state, tids)
            x, c, svc, side = _slab_obs(slab, g)
            sim, r = sim_chunk_core(step_fn, include_final_fetch, pparams,
                                    lv, M, T_len, t0, sim, x, c, svc, side)
            return (gen_state, sim), (r if collect_trace else None)

        carry, r_hist = _chunked_drive(run_chunk, carry0, n_chunks,
                                       (tids_all,))
        (_, (_, acc)) = carry
        if collect_trace:
            return r_hist, acc["sums"], acc["counts"]
        return acc["sums"], acc["counts"]

    return core


@functools.lru_cache(maxsize=64)
def _compiled_scenario_core(init_fn, step_fn, sc_init, sc_chunk,
                            include_final_fetch: bool, n_chunks: int,
                            collect_trace: bool, mesh: Mesh):
    core = _make_scenario_instance_core(init_fn, step_fn, sc_init, sc_chunk,
                                        include_final_fetch, n_chunks,
                                        collect_trace)
    spec = P(FLEET_AXIS)
    n_out = 3 if collect_trace else 2
    sharded = shard_map(
        jax.vmap(core, in_axes=(0, 0, 0, 0, 0, 0, None)), mesh=mesh,
        in_specs=(spec,) * 6 + (P(),), out_specs=(spec,) * n_out,
        check_rep=False)  # generators may use while-loops (e.g. Poisson)
    return jax.jit(sharded)


# test hook: Python trace counts per streamed-step family.  The factories'
# step bodies bump their entry when (and only when) jax traces them, so
# ``sum(STREAM_TRACES.values())`` staying flat across N stepper steps IS
# the zero-retrace proof (tests/test_fleet_stepper.py asserts it).
# Donation is best-effort: on backends where a donated slab's shape can't
# alias any output (e.g. CPU host buffers of [B, chunk] telemetry) XLA
# simply skips the aliasing — correct, just not reusable.  Silence the
# advisory warning that would otherwise fire at every trace.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

STREAM_TRACES = collections.Counter()


@functools.lru_cache(maxsize=64)
def _compiled_stream_step(init_fn, step_fn, include_final_fetch: bool,
                          has_svc: bool, has_side: bool, mesh: Mesh,
                          donate: bool = False):
    """One [B, chunk] slab: (carry, chunk obs) -> (carry', r_chunk).  The
    host streaming loop drives this; device memory stays O(B * chunk).
    ``donate=True`` donates the carry and the incoming slab buffers to XLA
    (the caller must not reuse them — the stepper contract)."""

    def step(params, lv, g, M, T_len, t0, carry, xck, cck, *opt):
        STREAM_TRACES["sim_obs"] += 1
        sck = opt[0] if has_svc else _model1_svc(xck, g)
        sdck = (opt[1 if has_svc else 0] if has_side
                else jnp.zeros(xck.shape, jnp.int32))
        return sim_chunk_core(step_fn, include_final_fetch, params, lv, M,
                              T_len, t0, carry, xck, cck, sck, sdck)

    n_opt = int(has_svc) + int(has_side)
    in_axes = (0, 0, 0, 0, 0, None, 0, 0, 0) + (0,) * n_opt
    spec = P(FLEET_AXIS)
    in_specs = (spec,) * 5 + (P(),) + (spec,) * (3 + n_opt)
    sharded = shard_map(jax.vmap(step, in_axes=in_axes, out_axes=(0, 0)),
                        mesh=mesh, in_specs=in_specs, out_specs=(spec, spec))
    donate_argnums = tuple(range(6, 9 + n_opt)) if donate else ()
    return jax.jit(sharded, donate_argnums=donate_argnums)


@functools.lru_cache(maxsize=64)
def _compiled_scenario_stream_step(init_fn, step_fn, sc_init, sc_chunk,
                                   include_final_fetch: bool, chunk: int,
                                   collect_trace: bool, mesh: Mesh,
                                   donate: bool = False):
    """One fused-generation slab step for the host-driven streaming loop:
    the host ships a scalar chunk offset per iteration — zero observation
    bytes cross the host->device boundary.  ``donate=True`` donates the
    ``(gen_state, (policy state, acc))`` carry."""

    def step(pparams, sparams, lv, g, M, T_len, t0, carry):
        STREAM_TRACES["sim_scenario"] += 1
        tids = t0 + jnp.arange(chunk, dtype=jnp.int32)
        gen_state, sim = carry
        gen_state, slab = sc_chunk(sparams, gen_state, tids)
        x, c, svc, side = _slab_obs(slab, g)
        sim, r = sim_chunk_core(step_fn, include_final_fetch, pparams, lv, M,
                                T_len, t0, sim, x, c, svc, side)
        carry = (gen_state, sim)
        return (carry, r) if collect_trace else carry

    spec = P(FLEET_AXIS)
    in_axes = (0, 0, 0, 0, 0, 0, None, 0)
    in_specs = (spec,) * 6 + (P(),) + (spec,)
    out_specs = (spec, spec) if collect_trace else spec
    sharded = shard_map(jax.vmap(step, in_axes=in_axes), mesh=mesh,
                        in_specs=in_specs, out_specs=out_specs,
                        check_rep=False)
    return jax.jit(sharded, donate_argnums=(7,) if donate else ())


# ----------------------------------------------------------------------
# Policy fan-out cores: ONE generated [chunk] slab per step, P policy
# lanes (and, with with_opt, P offline-DP frontiers) consuming it inside
# the same compiled program.  See "Policy fan-out" in the module
# docstring.  Each core takes ``lanes`` — the tuple of per-lane
# (params, lv, g, M, mask, cols) device rows (_lane_arrays) — and emits a
# FLAT tuple of outputs (explicit out_specs need a flat shape):
# P x r_hist (collect_trace) + P x sums + P x counts + P x opt (with_opt).
# ----------------------------------------------------------------------

def _lane_svc(svc, x, g, cols, own_grid: bool, i: int):
    """The [chunk, K_lane] service slab lane ``i`` prices: the shared slab
    itself (fleet-grid lane), its ``svc_cols`` gather (own-grid lane under
    Model 2 — coupled uniforms make the gathered columns bitwise equal to
    generating on the lane grid directly), or Model-1 pricing ``g * x``
    from the lane's own g row.  Structural mismatches raise at trace time —
    the scenario-fused twin of the eager ``_check_lanes`` validation."""
    if svc is None:
        if cols is not None:
            raise ValueError(
                f"fan-out lane {i}: svc_cols= was given but the stream "
                "generates no Model-2 service channel — a Model-1 lane "
                "prices g * x from its own grid")
        return _model1_svc(x, g)
    if cols is None:
        if own_grid:
            raise ValueError(
                f"fan-out lane {i}: a lane on its own grid must map the "
                "shared Model-2 service slab onto its levels via svc_cols= "
                "(the stream is generated ONCE, on the fleet grid — "
                "HostingGrid.endpoint_columns builds the endpoint map)")
        return svc
    return jnp.take(svc, cols, axis=-1)


def _lane_dp_grid(lanes):
    """Per-lane hoisted (lv32, fetch_mat, kmask) for the co-executed DP —
    the same prologue every offline core computes once per instance."""
    out = []
    for (_params, lv, _g, M, mask, _cols) in lanes:
        lv32 = lv.astype(jnp.float32)
        out.append((lv32, dp_fetch_matrix(M.astype(jnp.float32), lv32), mask))
    return tuple(out)


def _fanout_chunk(lane_fns, lane_own, include_final_fetch, with_opt,
                  dp_backend, lanes, dp_grid, T_len, t0, sims, Js,
                  x, c, svc, side):
    """Advance every lane (and optionally every DP frontier) over ONE
    shared slab — the body every fan-out driver shares.  Returns
    (sims', Js', per-lane r chunks)."""
    n_lanes = len(lane_fns)
    svcs = tuple(_lane_svc(svc, x, lanes[i][2], lanes[i][5], lane_own[i], i)
                 for i in range(n_lanes))
    sims, rs = sim_chunk_lanes(
        tuple(fns[1] for fns in lane_fns), include_final_fetch,
        tuple(l[0] for l in lanes), tuple(l[1] for l in lanes),
        tuple(l[3] for l in lanes), T_len, t0, sims, x, c, svcs, side)
    if with_opt:
        tids = t0 + jnp.arange(x.shape[-1], dtype=jnp.int32)
        Js = tuple(
            dp_fwd_chunk(J, tids, c, svck, lv32, kmask, fetch_mat,
                         T_len, dp_backend)[0]
            for J, (lv32, fetch_mat, kmask), svck in zip(Js, dp_grid, svcs))
    return sims, Js, rs


def _make_fanout_instance_core(lane_fns, lane_own, include_final_fetch: bool,
                               n_chunks: int, has_svc: bool, has_side: bool,
                               collect_trace: bool, with_opt: bool,
                               dp_backend: str):
    """Whole-horizon fan-out core for ONE instance, obs-backed.
    Args: (lanes, T_len, x, c[, svc][, side])."""
    n_lanes = len(lane_fns)

    def core(lanes, T_len, x, c, *opt):
        svc = opt[0] if has_svc else None
        side = opt[1 if has_svc else 0] if has_side else None
        sims0 = tuple(
            (fns[0](l[0]), sim_acc0(l[1].shape[-1], l[1].dtype))
            for fns, l in zip(lane_fns, lanes))
        dp_grid = _lane_dp_grid(lanes) if with_opt else None
        carry0 = ((sims0, tuple(dp_frontier0(l[1].shape[-1]) for l in lanes))
                  if with_opt else sims0)

        def run_chunk(carry, t0, xck, cck, sck, sdck):
            sims, Js = carry if with_opt else (carry, None)
            if sdck is None:
                sdck = jnp.zeros(xck.shape, jnp.int32)
            sims, Js, rs = _fanout_chunk(
                lane_fns, lane_own, include_final_fetch, with_opt,
                dp_backend, lanes, dp_grid, T_len, t0, sims, Js,
                xck, cck, sck, sdck)
            carry = (sims, Js) if with_opt else sims
            return carry, (rs if collect_trace else None)

        carry, r_hists = _chunked_drive(run_chunk, carry0, n_chunks,
                                        (x, c, svc, side))
        sims, Js = carry if with_opt else (carry, None)
        outs = tuple(r_hists) if collect_trace else ()
        outs += tuple(acc["sums"] for (_, acc) in sims)
        outs += tuple(acc["counts"] for (_, acc) in sims)
        if with_opt:
            outs += tuple(jnp.min(J) for J in Js)
        return outs

    return core


def _make_fanout_scenario_core(lane_fns, lane_own, sc_init, sc_chunk,
                               include_final_fetch: bool, n_chunks: int,
                               collect_trace: bool, with_opt: bool,
                               dp_backend: str):
    """Fused-generation fan-out core for ONE instance: the scenario's
    ``chunk_fn`` emits each [chunk] slab exactly once inside the scan and
    every lane consumes it.  Args: (lanes, sparams, T_len, tids_all)."""

    def core(lanes, sparams, T_len, tids_all):
        sims0 = tuple(
            (fns[0](l[0]), sim_acc0(l[1].shape[-1], l[1].dtype))
            for fns, l in zip(lane_fns, lanes))
        dp_grid = _lane_dp_grid(lanes) if with_opt else None
        carry0 = (sc_init(sparams), sims0)
        if with_opt:
            carry0 += (tuple(dp_frontier0(l[1].shape[-1]) for l in lanes),)

        def run_chunk(carry, t0, tids):
            gen_state, sims = carry[0], carry[1]
            Js = carry[2] if with_opt else None
            gen_state, slab = sc_chunk(sparams, gen_state, tids)
            side = (slab.side if slab.side is not None
                    else jnp.zeros(slab.x.shape, jnp.int32))
            sims, Js, rs = _fanout_chunk(
                lane_fns, lane_own, include_final_fetch, with_opt,
                dp_backend, lanes, dp_grid, T_len, t0, sims, Js,
                slab.x, slab.c, slab.svc, side)
            carry = (gen_state, sims) + ((Js,) if with_opt else ())
            return carry, (rs if collect_trace else None)

        carry, r_hists = _chunked_drive(run_chunk, carry0, n_chunks,
                                        (tids_all,))
        sims = carry[1]
        outs = tuple(r_hists) if collect_trace else ()
        outs += tuple(acc["sums"] for (_, acc) in sims)
        outs += tuple(acc["counts"] for (_, acc) in sims)
        if with_opt:
            outs += tuple(jnp.min(J) for J in carry[2])
        return outs

    return core


@functools.lru_cache(maxsize=32)
def _compiled_fanout_core(lane_fns, lane_own, include_final_fetch: bool,
                          n_chunks: int, has_svc: bool, has_side: bool,
                          collect_trace: bool, with_opt: bool,
                          dp_backend: str, mesh: Mesh):
    core = _make_fanout_instance_core(lane_fns, lane_own, include_final_fetch,
                                      n_chunks, has_svc, has_side,
                                      collect_trace, with_opt, dp_backend)
    n_lanes = len(lane_fns)
    spec = P(FLEET_AXIS)
    n_args = 4 + int(has_svc) + int(has_side)
    n_out = n_lanes * (2 + int(collect_trace) + int(with_opt))
    sharded = shard_map(jax.vmap(core), mesh=mesh,
                        in_specs=(spec,) * n_args,
                        out_specs=(spec,) * n_out,
                        # pallas_call has no replication rule
                        check_rep=(not with_opt) or dp_backend == "xla")
    return jax.jit(sharded)


@functools.lru_cache(maxsize=32)
def _compiled_fanout_scenario_core(lane_fns, lane_own, sc_init, sc_chunk,
                                   include_final_fetch: bool, n_chunks: int,
                                   collect_trace: bool, with_opt: bool,
                                   dp_backend: str, mesh: Mesh):
    core = _make_fanout_scenario_core(lane_fns, lane_own, sc_init, sc_chunk,
                                      include_final_fetch, n_chunks,
                                      collect_trace, with_opt, dp_backend)
    n_lanes = len(lane_fns)
    spec = P(FLEET_AXIS)
    n_out = n_lanes * (2 + int(collect_trace) + int(with_opt))
    sharded = shard_map(jax.vmap(core, in_axes=(0, 0, 0, None)), mesh=mesh,
                        in_specs=(spec, spec, spec, P()),
                        out_specs=(spec,) * n_out, check_rep=False)
    return jax.jit(sharded)


@functools.lru_cache(maxsize=32)
def _compiled_fanout_stream_step(lane_fns, lane_own,
                                 include_final_fetch: bool, has_svc: bool,
                                 has_side: bool, collect_trace: bool,
                                 with_opt: bool, dp_backend: str, mesh: Mesh,
                                 donate: bool = False):
    """One fan-out slab step for the host streaming loop: the shared
    [B, chunk] slab in, every lane's (state, acc) — and DP frontier with
    ``with_opt`` — advanced in one compiled call.  Carry: ``(sims,)`` or
    ``(sims, Js)``, tuples of per-lane pytrees."""

    def step(lanes, T_len, t0, carry, xck, cck, *opt):
        STREAM_TRACES["sim_obs_fanout"] += 1
        sck = opt[0] if has_svc else None
        sdck = (opt[1 if has_svc else 0] if has_side
                else jnp.zeros(xck.shape, jnp.int32))
        sims = carry[0]
        Js = carry[1] if with_opt else None
        dp_grid = _lane_dp_grid(lanes) if with_opt else None
        sims, Js, rs = _fanout_chunk(
            lane_fns, lane_own, include_final_fetch, with_opt, dp_backend,
            lanes, dp_grid, T_len, t0, sims, Js, xck, cck, sck, sdck)
        carry = (sims, Js) if with_opt else (sims,)
        return (carry, rs) if collect_trace else carry

    n_opt = int(has_svc) + int(has_side)
    in_axes = (0, 0, None, 0, 0, 0) + (0,) * n_opt
    spec = P(FLEET_AXIS)
    in_specs = (spec, spec, P(), spec, spec, spec) + (spec,) * n_opt
    out_specs = (spec, spec) if collect_trace else spec
    sharded = shard_map(jax.vmap(step, in_axes=in_axes), mesh=mesh,
                        in_specs=in_specs, out_specs=out_specs,
                        check_rep=(not with_opt) or dp_backend == "xla")
    donate_argnums = tuple(range(3, 6 + n_opt)) if donate else ()
    return jax.jit(sharded, donate_argnums=donate_argnums)


@functools.lru_cache(maxsize=32)
def _compiled_fanout_scenario_stream_step(lane_fns, lane_own, sc_init,
                                          sc_chunk,
                                          include_final_fetch: bool,
                                          chunk: int, collect_trace: bool,
                                          with_opt: bool, dp_backend: str,
                                          mesh: Mesh, donate: bool = False):
    """One fused-generation fan-out slab step: the host ships one scalar
    offset per chunk, the generator runs once, every lane consumes its
    slab.  Carry: ``(gen_state, sims[, Js])``."""

    def step(lanes, sparams, T_len, t0, carry):
        STREAM_TRACES["sim_scenario_fanout"] += 1
        tids = t0 + jnp.arange(chunk, dtype=jnp.int32)
        gen_state, sims = carry[0], carry[1]
        Js = carry[2] if with_opt else None
        dp_grid = _lane_dp_grid(lanes) if with_opt else None
        gen_state, slab = sc_chunk(sparams, gen_state, tids)
        side = (slab.side if slab.side is not None
                else jnp.zeros(slab.x.shape, jnp.int32))
        sims, Js, rs = _fanout_chunk(
            lane_fns, lane_own, include_final_fetch, with_opt, dp_backend,
            lanes, dp_grid, T_len, t0, sims, Js, slab.x, slab.c, slab.svc,
            side)
        carry = (gen_state, sims) + ((Js,) if with_opt else ())
        return (carry, rs) if collect_trace else carry

    spec = P(FLEET_AXIS)
    in_axes = (0, 0, 0, None, 0)
    in_specs = (spec, spec, spec, P(), spec)
    out_specs = (spec, spec) if collect_trace else spec
    sharded = shard_map(jax.vmap(step, in_axes=in_axes), mesh=mesh,
                        in_specs=in_specs, out_specs=out_specs,
                        check_rep=False)
    return jax.jit(sharded, donate_argnums=(4,) if donate else ())


def _pad_params(params, B_pad: int):
    """Pad every [B]-leading leaf of a params pytree (policy or scenario)
    to B_pad by replicating row 0 (padded instances run with T = 0)."""
    return jax.tree_util.tree_map(
        lambda a: _pad_rows(jnp.asarray(a), B_pad), params)


def _policy_arrays(policy: PolicyFns, fleet: FleetBatch, B_pad: int, mesh):
    dt = default_float_dtype()
    params = _pad_params(policy.params, B_pad)
    lv = _pad_rows(fleet.grid.levels.astype(dt), B_pad)
    g = _pad_rows(fleet.grid.g.astype(dt), B_pad)
    M = _pad_rows(fleet.grid.M.astype(dt), B_pad)
    return (_dev_tree(mesh, params), _dev_rows(mesh, lv),
            _dev_rows(mesh, g), _dev_rows(mesh, M))


def _check_lanes(lanes, fleet: FleetBatch, has_svc: Optional[bool]):
    """Eager fan-out validation — the policy-axis home of the old
    ``fused_policy_families`` same-stream-family check: lanes share the
    stream by construction, the engine verifies each lane can PRICE it.
    ``has_svc`` is None when the service channel is only known at trace
    time (scenario-fused runs), where ``_lane_svc`` enforces the same
    rules on the generated slab's structure."""
    for i, lane in enumerate(lanes):
        if not isinstance(lane.fns, PolicyFns):
            raise TypeError(f"fan-out lane {i}: .fns must be a PolicyFns, "
                            f"got {type(lane.fns).__name__}")
        if lane.grid is not None and lane.grid.B != fleet.B:
            raise ValueError(
                f"fan-out lane {i} ({lane.name!r}): lane grid B="
                f"{lane.grid.B} != fleet B={fleet.B}")
        if lane.svc_cols is not None:
            if lane.grid is None:
                raise ValueError(
                    f"fan-out lane {i} ({lane.name!r}): svc_cols= without a "
                    "lane grid — a fleet-grid lane prices the shared svc "
                    "slab directly")
            if has_svc is False:
                raise ValueError(
                    f"fan-out lane {i} ({lane.name!r}): svc_cols= but the "
                    "fleet carries no Model-2 service channel — a Model-1 "
                    "lane prices g * x from its own grid")
            cols = np.asarray(lane.svc_cols)
            if cols.ndim != 2 or cols.shape[0] != fleet.B:
                raise ValueError(
                    f"fan-out lane {i} ({lane.name!r}): svc_cols must be "
                    f"[B={fleet.B}, K_lane], got shape {cols.shape}")
        elif lane.grid is not None and has_svc is True:
            raise ValueError(
                f"fan-out lane {i} ({lane.name!r}): a lane on its own grid "
                "must map the fleet's Model-2 service slab onto its levels "
                "via svc_cols= (HostingGrid.endpoint_columns builds the "
                "endpoint map)")


def _lane_arrays(lanes, padded: FleetBatch, S: int, mesh):
    """Per-lane device arg tuples of the fan-out cores — (params, lv, g, M,
    mask, cols) per lane, every row block seed-replicated (x S) and padded
    to the fleet's B_pad exactly as ``_policy_arrays``/``_replicate_mc`` do
    for the classic path.  Fleet-grid lanes (grid=None) reuse the padded
    fleet grid's rows untouched."""
    dt = default_float_dtype()
    B_pad = padded.B
    rep = lambda a: (jnp.asarray(a) if S == 1
                     else jnp.repeat(jnp.asarray(a), S, axis=0))
    out = []
    for lane in lanes:
        pol = _replicate_policy(lane.fns, S)
        params = _dev_tree(mesh, _pad_params(pol.params, B_pad))
        if lane.grid is None:
            grid, prep = padded.grid, (lambda a: a)
        else:
            grid, prep = lane.grid, (lambda a: _pad_rows(rep(a), B_pad))
        lv = _dev_rows(mesh, prep(grid.levels.astype(dt)))
        g = _dev_rows(mesh, prep(grid.g.astype(dt)))
        M = _dev_rows(mesh, prep(grid.M.astype(dt)))
        mask = _dev_rows(mesh, prep(grid.mask))
        cols = None
        if lane.svc_cols is not None:
            cols = _dev_rows(mesh, _pad_rows(
                rep(jnp.asarray(lane.svc_cols, jnp.int32)), B_pad))
        out.append((params, lv, g, M, mask, cols))
    return tuple(out)


def _check_scenario(scenario: Scenario, fleet: FleetBatch):
    if fleet.x is not None or fleet.c is not None:
        raise ValueError(
            "scenario=... needs an obs-less fleet (FleetBatch.for_scenario); "
            "materialized observations would be silently ignored")
    if scenario.B != fleet.B:
        raise ValueError(f"scenario B={scenario.B} != fleet B={fleet.B}")


def _replicate_mc(fleet: FleetBatch, scenario: Optional[Scenario],
                  n_seeds: Optional[int], antithetic: bool = False):
    """Expand a [B] fleet + scenario to the [B*S] Monte-Carlo replication
    (instance-major, seed-minor; seed folded into every stream key by
    ``replicate_seeds`` — ``antithetic=True`` pairs replicas (2m, 2m+1) on
    flip-capable streams).  Returns them unchanged when ``n_seeds`` is None.
    """
    if n_seeds is None:
        if antithetic:
            raise ValueError("antithetic=True needs n_seeds=")
        return fleet, scenario, 1
    if scenario is None:
        raise ValueError(
            "n_seeds= needs scenario=: materialized observations carry no "
            "seed axis to fold (stack replica rows yourself instead)")
    S = int(n_seeds)
    rep = lambda a: jnp.repeat(jnp.asarray(a), S, axis=0)
    grid = HostingGrid(M=rep(fleet.grid.M), levels=rep(fleet.grid.levels),
                       g=rep(fleet.grid.g), mask=rep(fleet.grid.mask))
    rfleet = FleetBatch(grid=grid, x=None, c=None,
                        T=np.repeat(np.asarray(fleet.T, np.int32), S))
    return rfleet, replicate_seeds(scenario, S, antithetic=antithetic), S


def _replicate_policy(policy: PolicyFns, S: int) -> PolicyFns:
    if S == 1:
        return policy
    return policy._replace(params=jax.tree_util.tree_map(
        lambda a: jnp.repeat(jnp.asarray(a), S, axis=0), policy.params))


def run_fleet(policy, fleet: FleetBatch, *,
              scenario: Optional[Scenario] = None,
              mesh: Optional[Mesh] = None, chunk_size: Optional[int] = None,
              include_final_fetch: bool = True,
              stream: bool = False, collect_trace: bool = True,
              n_seeds: Optional[int] = None,
              antithetic: bool = False,
              prng_backend: str = "xla",
              with_opt_forward: bool = False,
              dp_backend: str = "xla",
              async_ingest: bool = False,
              gather: bool = False) -> FleetResult:
    """Simulate a fleet: sharded over devices, chunked/streamed over time.

    Args:
      policy: pure-function policy batch whose params carry a leading [B]
        axis matching ``fleet.grid`` (``AlphaRR.fleet(fleet)``, ...).  For
        RR-style restrictions pass the restricted fleet
        (``fleet.restrict_to_endpoints()``), as with ``run_policy_batch``.
        Alternatively a SEQUENCE of policies — the fan-out axis: every
        entry (a ``PolicyFns``, or a ``policies.PolicyLane`` binding its
        own accounting grid + Model-2 ``svc_cols`` map) steps against the
        ONE shared obs stream inside the same compiled program, and the
        result comes back policy-major (``FleetResult.policy_view``) with
        lane p bitwise equal to its standalone run.  See "Policy fan-out"
        in the module docstring.
      fleet: the stacked instances (mixed horizons allowed).
      scenario: generate observations ON DEVICE inside the scan instead of
        reading them from ``fleet`` (which must then be obs-less:
        ``FleetBatch.for_scenario``).  Bit-identical to materializing the
        same scenario and running the classic path, with O(B * chunk)
        device memory and zero host->device observation transfer.
      mesh: 1-D device mesh with axis ``fleet`` (default: all devices).
      chunk_size: cut the horizon into chunks of this many slots (device-side
        outer scan).  None = one chunk.
      stream: drive the chunks from the host instead, one [B, chunk] slab at
        a time (requires ``chunk_size``); bit-identical to the scan driver.
        With a scenario the host ships only the scalar chunk offset.
      collect_trace: False drops the [B, T_max] ``r_hist`` output (the one
        O(T) device buffer) — totals/histograms are unchanged; use for
        T >= 10^6 horizons.
      n_seeds: run S Monte-Carlo replicas of every instance in the same
        compiled program (requires ``scenario=``): the seed is folded into
        every stream key *before* the per-slot counter fold
        (``scenarios.replicate_seeds``), so result row ``b * S + s`` is
        bit-identical to a standalone run of instance ``b`` under
        ``scenarios.with_seed(scenario, s)``.  The result carries
        ``n_seeds`` and a [B, S] ``seed_view``; collapse with
        ``mc_summary``.
      antithetic: pair the seed replicas (2m, 2m+1) antithetically on
        flip-capable streams (``scenarios.replicate_seeds(...,
        antithetic=True)``) — same estimator mean, tighter ``mc_summary``
        CIs on monotone statistics.  Requires an even ``n_seeds``.
      prng_backend: kernel backend for the scenario's counter-keyed
        uniforms ("xla" default — the canonical reference; "pallas" fuses
        the fold/salt/uniform chain via ``scenarios.with_prng_backend``).
        Bit-identical observations either way (requires ``scenario=``).
      with_opt_forward: co-execute the offline DP's [K] entry frontier per
        policy lane against the same shared stream (the cost-only forward
        pass — ``dp_fwd_chunk``, the offline drivers' own chunk kernel)
        and return ``FleetResult.opt_cost``: per row, bitwise the
        ``offline_opt_fleet(..., checkpointed=True,
        collect_schedule=False).cost`` of the lane's fleet.  A plain
        ``PolicyFns`` policy is treated as a single-lane fan-out.
      dp_backend: min-plus engine for the co-executed DP ("xla" default /
        "pallas"), exactly as in ``offline_opt_fleet``; only consulted
        with ``with_opt_forward=True``.
      async_ingest: with ``stream=True`` on an obs-backed fleet, prepare
        slab n+1 (host slicing + device put) on a background prefetch
        thread while the device executes slab n
        (``core.ingest.SlabPrefetcher``) — bit-identical to the
        synchronous loop, host work overlapped instead of serialized.
        A no-op with ``scenario=`` (fused generation ships no slabs).
      gather: on a process-spanning mesh, allgather the result rows so
        every process sees the full [B_global] fleet (one cross-host
        collective per result array).  Default False: results are this
        process's own rows, matching the local inputs.  A no-op on
        single-process meshes.  See "Multi-host fleets" above.

    Every configuration (any mesh size x any chunking x any driver x fused
    or materialized generation — and any ``prng_backend``) returns
    bit-identical results; see tests/test_fleet_engine.py,
    tests/test_scenarios.py, tests/test_mc_driver.py,
    tests/test_backend_dispatch.py and tests/test_policy_fanout.py.
    """
    lanes = as_policy_lanes(policy)
    if lanes is None and with_opt_forward:
        lanes = (PolicyLane(policy),)
    if lanes is not None:
        return _run_fleet_fanout(
            lanes, fleet, scenario=scenario, mesh=mesh,
            chunk_size=chunk_size, include_final_fetch=include_final_fetch,
            stream=stream, collect_trace=collect_trace, n_seeds=n_seeds,
            antithetic=antithetic, prng_backend=prng_backend,
            dp_backend=dp_backend, with_opt=with_opt_forward,
            async_ingest=async_ingest, gather=gather)
    if stream and chunk_size is None:
        raise ValueError("stream=True requires chunk_size")
    if async_ingest and not stream:
        raise ValueError("async_ingest=True requires stream=True (only the "
                         "host-driven driver ships slabs to prefetch)")
    _check_backends(dp_backend, prng_backend, scenario)
    fleet, scenario, S = _replicate_mc(fleet, scenario, n_seeds, antithetic)
    if scenario is not None:
        scenario = with_prng_backend(scenario, prng_backend)
    policy = _replicate_policy(policy, S)
    B, T_max = fleet.B, fleet.T_max
    mesh, padded, n_chunks, T_pad = _prepare_fleet(fleet, mesh, chunk_size)
    params, lv, g, M = _policy_arrays(policy, padded, padded.B, mesh)

    if scenario is not None:
        _check_scenario(scenario, fleet)
        sparams = _dev_tree(mesh, _pad_params(scenario.params, padded.B))
        if stream:
            res = _run_fleet_scenario_streamed(
                policy, scenario, padded, params, sparams, lv, g, M, mesh,
                n_chunks, T_pad, include_final_fetch, collect_trace,
                B, T_max, fleet.T, S)
            return _gather_result(res, mesh) if gather else res
        core = _compiled_scenario_core(policy.init_fn, policy.step_fn,
                                       scenario.init_fn, scenario.chunk_fn,
                                       include_final_fetch, n_chunks,
                                       collect_trace, mesh)
        tids_all = _dev_replicated(mesh, np.arange(T_pad, dtype=np.int32))
        with shard_ctx(mesh, (FLEET_AXIS,), model_axis=None):
            out = core(params, sparams, lv, g, M,
                       _dev_rows(mesh, padded.T), tids_all)
        r_hist, sums, counts = out if collect_trace else (None,) + out
        res = _fleet_result(r_hist, sums, counts, B, T_max, fleet.T, S)
        return _gather_result(res, mesh) if gather else res

    has_svc, has_side = fleet.svc is not None, fleet.side is not None
    if stream:
        res = _run_fleet_streamed(policy, padded, params, lv, g, M, mesh,
                                  n_chunks, include_final_fetch,
                                  collect_trace, B, T_max, fleet.T,
                                  async_ingest)
        return _gather_result(res, mesh) if gather else res

    core = _compiled_fleet_core(policy.init_fn, policy.step_fn,
                                include_final_fetch, n_chunks, has_svc,
                                has_side, collect_trace, mesh)
    args = (params, lv, g, M, _dev_rows(mesh, padded.T),
            _dev_rows(mesh, padded.x), _dev_rows(mesh, padded.c))
    if has_svc:
        args += (_dev_rows(mesh, padded.svc),)
    if has_side:
        args += (_dev_rows(mesh, padded.side),)
    with shard_ctx(mesh, (FLEET_AXIS,), model_axis=None):
        out = core(*args)
    r_hist, sums, counts = out if collect_trace else (None,) + out
    res = _fleet_result(r_hist, sums, counts, B, T_max, fleet.T)
    return _gather_result(res, mesh) if gather else res


def _run_fleet_fanout(lanes, fleet: FleetBatch, *, scenario, mesh,
                      chunk_size, include_final_fetch, stream, collect_trace,
                      n_seeds, antithetic, prng_backend, dp_backend,
                      with_opt, async_ingest, gather) -> FleetResult:
    """Driver of the policy fan-out axis (see the module docstring): ONE
    generation pass, P policy lanes (+ optional per-lane DP frontiers),
    chunked or streamed, returning a policy-major ``FleetResult``."""
    if stream and chunk_size is None:
        raise ValueError("stream=True requires chunk_size")
    if async_ingest and not stream:
        raise ValueError("async_ingest=True requires stream=True (only the "
                         "host-driven driver ships slabs to prefetch)")
    _check_backends(dp_backend, prng_backend, scenario)
    has_svc = None if scenario is not None else fleet.svc is not None
    _check_lanes(lanes, fleet, has_svc)
    fleet, scenario, S = _replicate_mc(fleet, scenario, n_seeds, antithetic)
    if scenario is not None:
        scenario = with_prng_backend(scenario, prng_backend)
    B, T_max = fleet.B, fleet.T_max
    mesh, padded, n_chunks, T_pad = _prepare_fleet(fleet, mesh, chunk_size)
    lane_args = _lane_arrays(lanes, padded, S, mesh)
    lane_fns = tuple((l.fns.init_fn, l.fns.step_fn) for l in lanes)
    lane_own = tuple(l.grid is not None for l in lanes)
    n_lanes = len(lanes)

    if scenario is not None:
        _check_scenario(scenario, fleet)
        sparams = _dev_tree(mesh, _pad_params(scenario.params, padded.B))
        if stream:
            return _run_fleet_fanout_streamed(
                lanes, lane_fns, lane_own, lane_args, scenario, padded,
                sparams, mesh, n_chunks, T_pad, include_final_fetch,
                collect_trace, with_opt, dp_backend, B, T_max, fleet.T, S,
                False, gather)
        core = _compiled_fanout_scenario_core(
            lane_fns, lane_own, scenario.init_fn, scenario.chunk_fn,
            include_final_fetch, n_chunks, collect_trace, with_opt,
            dp_backend, mesh)
        tids_all = _dev_replicated(mesh, np.arange(T_pad, dtype=np.int32))
        with shard_ctx(mesh, (FLEET_AXIS,), model_axis=None):
            outs = core(lane_args, sparams, _dev_rows(mesh, padded.T),
                        tids_all)
    else:
        has_side = padded.side is not None
        if stream:
            return _run_fleet_fanout_streamed(
                lanes, lane_fns, lane_own, lane_args, None, padded, None,
                mesh, n_chunks, T_pad, include_final_fetch, collect_trace,
                with_opt, dp_backend, B, T_max, fleet.T, S, async_ingest,
                gather)
        core = _compiled_fanout_core(
            lane_fns, lane_own, include_final_fetch, n_chunks, has_svc,
            has_side, collect_trace, with_opt, dp_backend, mesh)
        args = (lane_args, _dev_rows(mesh, padded.T),
                _dev_rows(mesh, padded.x), _dev_rows(mesh, padded.c))
        if has_svc:
            args += (_dev_rows(mesh, padded.svc),)
        if has_side:
            args += (_dev_rows(mesh, padded.side),)
        with shard_ctx(mesh, (FLEET_AXIS,), model_axis=None):
            outs = core(*args)
    i = 0
    r_lanes = None
    if collect_trace:
        r_lanes, i = outs[:n_lanes], n_lanes
    sums_lanes = outs[i:i + n_lanes]
    counts_lanes = outs[i + n_lanes:i + 2 * n_lanes]
    opt_lanes = outs[i + 2 * n_lanes:] if with_opt else None
    return _fanout_result(r_lanes, sums_lanes, counts_lanes, opt_lanes,
                          B, T_max, fleet.T, S, mesh, gather)


def _run_fleet_fanout_streamed(lanes, lane_fns, lane_own, lane_args,
                               scenario, padded, sparams, mesh, n_chunks,
                               T_pad, include_final_fetch, collect_trace,
                               with_opt, dp_backend, B, T_max, T_orig,
                               n_seeds, async_ingest, gather) -> FleetResult:
    """Host-driven fan-out streaming: a thin loop over the persistent
    fan-out ``FleetStepper`` (same donated-carry, zero-retrace contract as
    the single-policy streamed drivers)."""
    chunk = T_pad // n_chunks
    has_svc = scenario is None and padded.svc is not None
    has_side = scenario is None and padded.side is not None
    stepper = _make_fanout_stepper(lanes, lane_fns, lane_own, lane_args,
                                   scenario, padded, sparams, mesh, chunk,
                                   include_final_fetch, collect_trace,
                                   with_opt, dp_backend, True, has_svc,
                                   has_side, B, T_max, T_orig, n_seeds)
    if scenario is None:
        make_slab = _obs_slab_builder(padded, chunk, mesh, with_side=True)
        feed = slab_feed(make_slab, n_chunks, async_ingest)
    else:
        feed = (() for _ in range(n_chunks))
    r_parts = [[] for _ in lanes]
    for slabs in feed:
        rs = stepper.step_slabs(slabs)
        if collect_trace:
            for p, r in enumerate(rs):
                r_parts[p].append(_local_rows(r))
    r_hist = (tuple(np.concatenate(parts, axis=1) for parts in r_parts)
              if collect_trace else None)
    return stepper.result(r_hist, gather=gather)


def _sim_carry0(policy, params, B_pad, K, dt, mesh):
    return (_vmap_init(policy.init_fn, params, mesh),
            {"sums": _dev_rows(mesh, np.zeros((B_pad, 3), dt)),
             "counts": _dev_rows(mesh, np.zeros((B_pad, K), np.int32))})


# ----------------------------------------------------------------------
# FleetStepper: the one persistent slab-step implementation behind every
# streamed driver and the live-serving API.
# ----------------------------------------------------------------------

class FleetStepper:
    """Persistent, pre-compiled, donated-carry fleet stepper.

    ONE slab-step implementation behind three drivers
    (``_run_fleet_streamed``, ``_run_fleet_scenario_streamed``, the
    ``_dp_ckpt_streamed`` forward pass) and the public live-serving API
    (``fleet_stepper`` / ``serve.scheduler.LiveFleetScheduler``).  Holds a
    compiled step looked up from the module-level lru-cached factories
    (construction of a warm config never retraces), the device-resident
    carry, and the running slot offset; ``step_slabs`` advances the whole
    fleet one [B, chunk] slab and — with ``donate=True`` — hands the old
    carry and slab buffers back to XLA, so N steps allocate O(1) carries.

    Zero-recompile contract: the compiled step is a pure function of
    ``(policy/scenario fns, flags, mesh, donate)``, all shapes are fixed
    at construction, and ``T_len``/``t0`` are *traced* inputs — stepping
    past any horizon, or constructing a second stepper on the same
    config, triggers no new trace (``STREAM_TRACES`` is the test hook).

    Donation contract: after ``step_slabs`` returns, the previous carry
    and the slabs passed in are invalidated — callers must not retain
    references to them.  Paths that must (DP checkpoint collection) build
    their stepper with ``donate=False``.
    """

    def __init__(self, *, call, carry, chunk, mesh, has_out, kind,
                 scenario_mode, donate, B, B_pad, K, T_max, T_orig,
                 n_seeds=1, lv_host=None, with_svc=False, with_side=False,
                 fanout=False, n_policies=1, with_opt=False,
                 lane_lv_host=None):
        self._call = call
        self.carry = carry
        self.chunk = int(chunk)
        self._mesh = mesh
        self._has_out = has_out
        self._kind = kind                  # "sim" | "dp"
        self._scenario_mode = scenario_mode
        self.donate = donate
        self._B, self._B_pad, self._K = int(B), int(B_pad), int(K)
        self._T_max, self._T_orig = T_max, T_orig
        self._n_seeds = n_seeds
        self._lv_host = lv_host            # np [B_pad, K] level values
        self._with_svc, self._with_side = with_svc, with_side
        self._fanout = fanout              # multi-lane carry layout
        self.n_policies = int(n_policies)
        self._with_opt = with_opt          # co-executed DP frontiers
        self._lane_lv_host = lane_lv_host  # per-lane np [B_pad, K_p] levels
        self.t = 0                         # next slot offset
        self.steps = 0

    # ---- the one step ------------------------------------------------
    def step_slabs(self, slabs=()):
        """Advance one chunk on already-device-ready slab arrays (empty
        tuple for scenario-fused steppers).  Returns the step's [B_pad,
        chunk] output (hosting levels) or None for output-less steps."""
        # an uncommitted host scalar: valid as a replicated (P()) input on
        # both single- and multi-process meshes, identical trace either way
        t0 = np.int32(self.t)
        with shard_ctx(self._mesh, (FLEET_AXIS,), model_axis=None):
            out = self._call(self.carry, t0, tuple(slabs))
        if self._has_out:
            self.carry, y = out
        else:
            self.carry, y = out, None
        self.t += self.chunk
        self.steps += 1
        return y

    # ---- live telemetry admission (public sim steppers) --------------
    def _prep_slab(self, a, dtype, trailing=(), name="slab"):
        a = np.asarray(a)
        want = (self._B, self.chunk) + trailing
        if a.ndim == len(want) - 1 and self.chunk == 1:
            a = np.expand_dims(a, 1)                 # [B] -> [B, 1]
        if a.shape != want:
            raise ValueError(f"{name}: expected shape {want}, got {a.shape}")
        return _dev_rows(self._mesh, _pad_rows(a.astype(dtype, copy=False),
                                               self._B_pad, np))

    def step(self, x=None, c=None, svc=None, side=None):
        """Admit one chunk of live telemetry and advance the fleet.

        Obs-backed steppers take [B, chunk] ([B] when ``chunk == 1``)
        arrival counts ``x`` and rents ``c`` (plus [B, chunk, K] ``svc``
        and [B, chunk] ``side`` when constructed with those channels);
        scenario-fused steppers take no arguments (generation is on
        device).  Returns the [B, chunk] per-slot hosting levels when the
        stepper collects traces, else None.
        """
        if self._kind != "sim":
            raise ValueError("step() is for simulation steppers")
        if self._scenario_mode:
            if any(a is not None for a in (x, c, svc, side)):
                raise ValueError("scenario-fused stepper generates its own "
                                 "observations; step() takes no telemetry")
            out = self.step_slabs(())
        else:
            if x is None or c is None:
                raise ValueError("obs-backed stepper needs x= and c= slabs")
            dt = default_float_dtype()
            slabs = (self._prep_slab(x, np.int32, name="x"),
                     self._prep_slab(c, dt, name="c"))
            if self._with_svc:
                slabs += (self._prep_slab(svc, dt, (self._K,), name="svc"),)
            elif svc is not None:
                raise ValueError("stepper built without a svc channel")
            if self._with_side:
                slabs += (self._prep_slab(side, np.int32, name="side"),)
            elif side is not None:
                raise ValueError("stepper built without a side channel")
            out = self.step_slabs(slabs)
        if out is None:
            return None
        if self._fanout:
            # one [B, chunk] level block per lane, stacked policy-major
            return np.stack([_local_rows(r)[:self._B] for r in out])
        return _local_rows(out)[:self._B]

    # ---- readbacks ---------------------------------------------------
    # On a process-spanning mesh every readback is this process's own
    # [B_local] rows (matching the local telemetry it admits); pass
    # ``gather=True`` for the full [B_global] fleet view (one cross-host
    # collective).  ``gather`` is a no-op on single-process meshes.

    def _lane_sims(self):
        """The tuple of per-lane (state, acc) carries (fan-out steppers)."""
        return self.carry[1] if self._scenario_mode else self.carry[0]

    def _lane_Js(self):
        """The tuple of per-lane DP frontiers (with_opt fan-out steppers)."""
        if not self._with_opt:
            raise ValueError("opt readback needs with_opt_forward=True")
        return self.carry[2] if self._scenario_mode else self.carry[1]

    def _sim_carry(self, policy: int = 0):
        if self._kind != "sim":
            raise ValueError("simulation readback on a DP stepper")
        if self._fanout:
            return self._lane_sims()[policy]
        if policy:
            raise ValueError("policy= readback needs a fan-out stepper")
        return self.carry[1] if self._scenario_mode else self.carry

    def hosting_levels(self, gather: bool = False,
                       policy: int = 0) -> np.ndarray:
        """[B] current per-instance hosting level *indices* r_t (of fan-out
        lane ``policy``, on multi-policy steppers)."""
        state, _ = self._sim_carry(policy)
        r = _local_rows(state["r"])[:self._B].astype(np.int64)
        return _gather_rows(self._mesh, r) if gather else r

    def hosting_fractions(self, gather: bool = False,
                          policy: int = 0) -> np.ndarray:
        """[B] current per-instance hosting *fractions* (the level values
        ell_{r_t} in [0, 1]) — the live serving decision readback."""
        r = self.hosting_levels(policy=policy)
        lv = (self._lane_lv_host[policy] if self._fanout
              else self._lv_host)[:self._B]
        frac = np.take_along_axis(lv, r[:, None], axis=1)[:, 0]
        return _gather_rows(self._mesh, frac) if gather else frac

    def opt_cost(self, gather: bool = False,
                 policy: Optional[int] = None) -> np.ndarray:
        """Current offline-DP optimum of the slots stepped so far, from the
        co-executed frontiers (``with_opt_forward=True`` steppers): the
        host-side ``J.min(axis=1)`` every streamed DP driver uses.  [B] for
        one ``policy=`` lane, else [P, B] over all lanes."""
        Js = self._lane_Js()
        if policy is not None:
            Js = (Js[policy],)
        gr = ((lambda a: _gather_rows(self._mesh, a)) if gather
              else (lambda a: a))
        costs = [gr(_local_rows(J)[:self._B].min(axis=1).astype(np.float64))
                 for J in Js]
        return costs[0] if policy is not None else np.stack(costs)

    def frontier(self, gather: bool = False) -> np.ndarray:
        """[B, K] DP value frontier (DP steppers only)."""
        if self._kind != "dp":
            raise ValueError("frontier() is for DP steppers")
        J = self.carry[1] if self._scenario_mode else self.carry
        J = _local_rows(J)[:self._B]
        return _gather_rows(self._mesh, J) if gather else J

    def result(self, r_hist=None, gather: bool = False) -> FleetResult:
        """Totals accumulated so far as a ``FleetResult`` (bit-identical
        to one ``run_fleet`` call over the same slabs — the engine
        invariant).  ``r_hist``: optionally, the concatenated per-step
        level outputs to attach as the trace (on a fan-out stepper, a
        per-lane tuple — the result is policy-major, with ``opt_cost``
        attached when constructed with ``with_opt_forward=True``)."""
        if self._fanout:
            if self._kind != "sim":
                raise ValueError("simulation readback on a DP stepper")
            sims = self._lane_sims()
            opt_lanes = None
            if self._with_opt:
                opt_lanes = tuple(
                    _local_rows(J)[:self._B].min(axis=1)
                    for J in self._lane_Js())
            return _fanout_result(
                r_hist, tuple(acc["sums"] for (_, acc) in sims),
                tuple(acc["counts"] for (_, acc) in sims), opt_lanes,
                self._B, self._T_max, self._T_orig, self._n_seeds,
                self._mesh, gather)
        (_, acc) = self._sim_carry()
        res = _fleet_result(r_hist, acc["sums"], acc["counts"], self._B,
                            self._T_max, self._T_orig, self._n_seeds)
        return _gather_result(res, self._mesh) if gather else res


def _obs_slab_builder(padded: FleetBatch, chunk: int, mesh, with_side: bool):
    """make_slab(i) for obs-backed streaming: slice host-resident numpy
    obs and device-put one [B, chunk] slab — the unit of work
    ``SlabPrefetcher`` overlaps with device compute.  On a process-spanning
    mesh each process holds (and ships) only its own [B_local, chunk] rows;
    ``_dev_rows`` assembles the global slab from them with zero cross-host
    observation bytes (metadata-only assembly, safe on the prefetch
    thread)."""
    x_h, c_h = np.asarray(padded.x), np.asarray(padded.c)
    svc_h = None if padded.svc is None else np.asarray(padded.svc)
    side_h = (None if not with_side or padded.side is None
              else np.asarray(padded.side))

    def make_slab(i):
        sl = slice(i * chunk, (i + 1) * chunk)
        slabs = (_dev_rows(mesh, x_h[:, sl]), _dev_rows(mesh, c_h[:, sl]))
        if svc_h is not None:
            slabs += (_dev_rows(mesh, svc_h[:, sl]),)
        if side_h is not None:
            slabs += (_dev_rows(mesh, side_h[:, sl]),)
        return slabs

    return make_slab


def _make_sim_stepper(policy, scenario, padded, params, sparams, lv, g, M,
                      mesh, chunk, include_final_fetch, collect_trace,
                      donate, has_svc, has_side, B, T_max, T_orig, n_seeds):
    """Build a simulation ``FleetStepper`` (obs-backed or scenario-fused)
    from an already-padded fleet: looks up the compiled step, builds the
    initial carry, closes over the resident arrays."""
    T_dev = _dev_rows(mesh, padded.T)
    if scenario is not None:
        step = _compiled_scenario_stream_step(
            policy.init_fn, policy.step_fn, scenario.init_fn,
            scenario.chunk_fn, include_final_fetch, chunk, collect_trace,
            mesh, donate)
        carry = (_vmap_init(scenario.init_fn, sparams, mesh),
                 _sim_carry0(policy, params, padded.B, padded.K, lv.dtype,
                             mesh))

        def call(carry, t0, slabs):
            return step(params, sparams, lv, g, M, T_dev, t0, carry)

        has_out = collect_trace
    else:
        step = _compiled_stream_step(policy.init_fn, policy.step_fn,
                                     include_final_fetch, has_svc, has_side,
                                     mesh, donate)
        carry = _sim_carry0(policy, params, padded.B, padded.K, lv.dtype,
                            mesh)

        def call(carry, t0, slabs):
            return step(params, lv, g, M, T_dev, t0, carry, *slabs)

        has_out = True
    return FleetStepper(call=call, carry=carry, chunk=chunk, mesh=mesh,
                        has_out=has_out, kind="sim",
                        scenario_mode=scenario is not None, donate=donate,
                        B=B, B_pad=padded.B, K=padded.K, T_max=T_max,
                        T_orig=T_orig, n_seeds=n_seeds,
                        lv_host=_local_rows(lv), with_svc=has_svc,
                        with_side=has_side)


def _make_fanout_stepper(lanes, lane_fns, lane_own, lane_args, scenario,
                         padded, sparams, mesh, chunk, include_final_fetch,
                         collect_trace, with_opt, dp_backend, donate,
                         has_svc, has_side, B, T_max, T_orig, n_seeds):
    """Build a fan-out ``FleetStepper``: the compiled multi-lane slab step,
    the tuple-of-lane-carries (+ per-lane DP frontiers with ``with_opt``),
    per-lane level rows for the fraction readbacks."""
    T_dev = _dev_rows(mesh, padded.T)
    dt = default_float_dtype()
    B_pad = padded.B
    sims0 = tuple(
        (_vmap_init(fns[0], largs[0], mesh),
         {"sums": _dev_rows(mesh, np.zeros((B_pad, 3), dt)),
          "counts": _dev_rows(mesh, np.zeros((B_pad, largs[1].shape[-1]),
                                             np.int32))})
        for fns, largs in zip(lane_fns, lane_args))
    Js0 = ()
    if with_opt:
        Js0 = (tuple(
            _dev_rows(mesh, np.broadcast_to(
                np.asarray(dp_frontier0(largs[1].shape[-1])),
                (B_pad, largs[1].shape[-1])))
            for largs in lane_args),)
    if scenario is not None:
        step = _compiled_fanout_scenario_stream_step(
            lane_fns, lane_own, scenario.init_fn, scenario.chunk_fn,
            include_final_fetch, chunk, collect_trace, with_opt, dp_backend,
            mesh, donate)
        carry = (_vmap_init(scenario.init_fn, sparams, mesh), sims0) + Js0

        def call(carry, t0, slabs):
            return step(lane_args, sparams, T_dev, t0, carry)
    else:
        step = _compiled_fanout_stream_step(
            lane_fns, lane_own, include_final_fetch, has_svc, has_side,
            collect_trace, with_opt, dp_backend, mesh, donate)
        carry = (sims0,) + Js0

        def call(carry, t0, slabs):
            return step(lane_args, T_dev, t0, carry, *slabs)

    return FleetStepper(
        call=call, carry=carry, chunk=chunk, mesh=mesh,
        has_out=collect_trace, kind="sim",
        scenario_mode=scenario is not None, donate=donate, B=B, B_pad=B_pad,
        K=padded.K, T_max=T_max, T_orig=T_orig, n_seeds=n_seeds,
        lv_host=_local_rows(lane_args[0][1]), with_svc=has_svc,
        with_side=has_side, fanout=True, n_policies=len(lanes),
        with_opt=with_opt,
        lane_lv_host=tuple(_local_rows(a[1]) for a in lane_args))


def fleet_stepper(policy, fleet: FleetBatch, *,
                  scenario: Optional[Scenario] = None,
                  mesh: Optional[Mesh] = None, chunk_size: int = 1,
                  include_final_fetch: bool = True,
                  collect_trace: bool = True,
                  n_seeds: Optional[int] = None, antithetic: bool = False,
                  prng_backend: str = "xla",
                  with_opt_forward: bool = False,
                  dp_backend: str = "xla",
                  donate: bool = True) -> FleetStepper:
    """Long-lived stepping API for live fleets: pre-compile once, then
    ``step()`` the whole fleet one [B, chunk_size] telemetry slab at a
    time with zero retraces and a donated carry.

    ``policy`` may be a SEQUENCE of policies (``PolicyFns`` /
    ``PolicyLane`` lanes, as in ``run_fleet``): every admitted slab then
    steps all lanes in one compiled call — the live scheduler's
    shadow-scoring hook (``LiveFleetScheduler``), where candidate policies
    accumulate their would-have-been costs on the production telemetry.
    Readbacks take ``policy=`` lane indices; ``step()`` returns [P, B,
    chunk] levels; ``result()`` is policy-major.  ``with_opt_forward=True``
    co-advances each lane's offline-DP frontier (``opt_cost()`` readback —
    the exact hindsight optimum of the slots admitted so far).

    Obs-backed mode (``scenario=None``): telemetry arrives through
    ``step(x, c[, svc][, side])`` — the fleet only contributes its grid
    and per-instance horizons (``FleetBatch.for_scenario`` is the natural
    constructor; a fleet's materialized obs are NOT consumed here).  For
    an open-ended live fleet, construct with a generous horizon ``T`` —
    the horizon mask is a traced input, so it costs nothing, and slots
    past each instance's own T_i stay exact no-ops.

    Scenario-fused mode: ``step()`` takes no arguments; the generator
    advances on device (``n_seeds``/``antithetic``/``prng_backend``
    compose exactly as in ``run_fleet``).

    N ``step()`` calls are bit-identical to one ``run_fleet`` call over
    the same observations — the engine invariant, proven in
    tests/test_fleet_stepper.py across chunked/streamed x obs/scenario x
    ``n_seeds`` x device-count configs.  ``donate=False`` only if you
    must retain carry references across steps.
    """
    lanes = as_policy_lanes(policy)
    if lanes is None and with_opt_forward:
        lanes = (PolicyLane(policy),)
    _check_backends(dp_backend, prng_backend, scenario)
    if scenario is None and n_seeds is not None:
        raise ValueError("n_seeds= needs scenario= (as in run_fleet)")
    if lanes is not None:
        _check_lanes(lanes, fleet,
                     None if scenario is not None else fleet.svc is not None)
    fleet, scenario, S = _replicate_mc(fleet, scenario, n_seeds, antithetic)
    if scenario is not None:
        _check_scenario(scenario, fleet)
        scenario = with_prng_backend(scenario, prng_backend)
    B, T_max = fleet.B, fleet.T_max
    mesh, padded, _, _ = _prepare_fleet(fleet, mesh, int(chunk_size))
    sparams = (None if scenario is None
               else _dev_tree(mesh, _pad_params(scenario.params, padded.B)))
    has_svc = scenario is None and fleet.svc is not None
    has_side = scenario is None and fleet.side is not None
    if lanes is not None:
        lane_args = _lane_arrays(lanes, padded, S, mesh)
        lane_fns = tuple((l.fns.init_fn, l.fns.step_fn) for l in lanes)
        lane_own = tuple(l.grid is not None for l in lanes)
        return _make_fanout_stepper(lanes, lane_fns, lane_own, lane_args,
                                    scenario, padded, sparams, mesh,
                                    int(chunk_size), include_final_fetch,
                                    collect_trace, with_opt_forward,
                                    dp_backend, donate, has_svc, has_side,
                                    B, T_max, fleet.T, S)
    policy = _replicate_policy(policy, S)
    params, lv, g, M = _policy_arrays(policy, padded, padded.B, mesh)
    return _make_sim_stepper(policy, scenario, padded, params, sparams, lv,
                             g, M, mesh, int(chunk_size),
                             include_final_fetch, collect_trace, donate,
                             has_svc, has_side, B, T_max, fleet.T, S)


def _run_fleet_streamed(policy, padded, params, lv, g, M, mesh, n_chunks,
                        include_final_fetch, collect_trace, B, T_max, T_orig,
                        async_ingest=False):
    """Host-driven streaming: numpy slabs in, carry stays on device — a
    thin loop over the persistent ``FleetStepper`` (donated carry, zero
    retraces after warmup; ``async_ingest=True`` prefetches slab n+1 on a
    background thread while the device executes slab n)."""
    has_svc, has_side = padded.svc is not None, padded.side is not None
    chunk = padded.T_max // n_chunks
    stepper = _make_sim_stepper(policy, None, padded, params, None, lv, g, M,
                                mesh, chunk, include_final_fetch,
                                collect_trace, True, has_svc, has_side,
                                B, T_max, T_orig, 1)
    make_slab = _obs_slab_builder(padded, chunk, mesh, with_side=True)
    r_parts = []
    for slabs in slab_feed(make_slab, n_chunks, async_ingest):
        r_chunk = stepper.step_slabs(slabs)
        if collect_trace:
            r_parts.append(_local_rows(r_chunk))
    r_hist = np.concatenate(r_parts, axis=1) if collect_trace else None
    return stepper.result(r_hist)


def _run_fleet_scenario_streamed(policy, scenario, padded, params, sparams,
                                 lv, g, M, mesh, n_chunks, T_pad,
                                 include_final_fetch, collect_trace,
                                 B, T_max, T_orig, n_seeds=1):
    """Host-driven streaming with fused generation: per chunk the host
    ships ONE scalar (the chunk offset); obs never exist on the host.  A
    thin loop over the persistent ``FleetStepper``."""
    chunk = T_pad // n_chunks
    stepper = _make_sim_stepper(policy, scenario, padded, params, sparams,
                                lv, g, M, mesh, chunk, include_final_fetch,
                                collect_trace, True, False, False,
                                B, T_max, T_orig, n_seeds)
    r_parts = []
    for _ in range(n_chunks):
        r_chunk = stepper.step_slabs(())
        if collect_trace:
            r_parts.append(_local_rows(r_chunk))
    r_hist = np.concatenate(r_parts, axis=1) if collect_trace else None
    return stepper.result(r_hist)


# ----------------------------------------------------------------------
# Offline DP on a fleet: chunked forward recursion, frozen past T_i.
# The chunk-level recursion itself (``dp_fwd_chunk`` / ``dp_backtrack*``)
# lives in ``policies.offline_opt`` — ONE copy shared by every driver here.
# ``dp_backend`` threads through every core factory into that one call
# site (and into the compile-cache keys, so backends never share a trace).
# ----------------------------------------------------------------------

def _check_backends(dp_backend: str, prng_backend: str,
                    scenario=None) -> None:
    """Validate the engine entry points' backend arguments up front."""
    if dp_backend not in DP_BACKENDS:
        raise ValueError(f"dp_backend must be one of {DP_BACKENDS}, "
                         f"got {dp_backend!r}")
    if prng_backend not in PRNG_BACKENDS:
        raise ValueError(f"prng_backend must be one of {PRNG_BACKENDS}, "
                         f"got {prng_backend!r}")
    if prng_backend != "xla" and scenario is None:
        raise ValueError("prng_backend= needs scenario=: materialized "
                         "observations draw no slot uniforms to reroute")


def _make_dp_instance_core(n_chunks: int, has_svc: bool,
                           dp_backend: str = "xla"):
    """Forward DP + reverse backtrack for ONE instance, chunk-capable.

    Matches ``offline_opt._dp_core`` op-for-op on valid slots; invalid slots
    (t >= T_len) keep ``J`` frozen and write identity backpointers, so the
    backtracked schedule is constant past T_len and the cost is exactly the
    instance's own-horizon optimum.  Padded K levels are priced ``+inf``
    exactly as in ``offline_opt_batch``.  This is the *materialized* path:
    the whole [T_pad, K] argmin table is kept for the backtrack (see
    ``_make_dp_ckpt_instance_core`` for the O(chunk * K) alternative).
    """

    def core(M, lv, g, kmask, T_len, x, c, *opt):
        K = lv.shape[-1]
        svc = opt[0] if has_svc else None
        lv32 = lv.astype(jnp.float32)
        fetch_mat = dp_fetch_matrix(M.astype(jnp.float32), lv32)

        def fwd_chunk(J, t0, xck, cck, sck):
            if sck is None:
                sck = _model1_svc(xck, g)
            tids = t0 + jnp.arange(xck.shape[-1], dtype=jnp.int32)
            return dp_fwd_chunk(J, tids, cck, sck, lv32, kmask, fetch_mat,
                                T_len, dp_backend)

        J_T, args = _chunked_drive(fwd_chunk, dp_frontier0(K), n_chunks,
                                   (x, c, svc))
        return dp_backtrack(J_T, args)

    return core


def _make_dp_scenario_core(sc_init, sc_chunk, n_chunks: int,
                           dp_backend: str = "xla"):
    """Scenario-fused forward DP for ONE instance: slabs are generated
    inside the chunk scan (generator state in the carry next to J); the
    recursion itself is ``dp_fwd_chunk``, shared with the obs-backed core."""

    def core(sparams, M, lv, g, kmask, T_len, tids_all):
        K = lv.shape[-1]
        lv32 = lv.astype(jnp.float32)
        fetch_mat = dp_fetch_matrix(M.astype(jnp.float32), lv32)

        def fwd_chunk(carry, t0, tids):
            gen_state, J = carry
            gen_state, slab = sc_chunk(sparams, gen_state, tids)
            sck = slab.svc if slab.svc is not None else _model1_svc(slab.x, g)
            J, args = dp_fwd_chunk(J, tids, slab.c, sck, lv32, kmask,
                                   fetch_mat, T_len, dp_backend)
            return (gen_state, J), args

        carry0 = (sc_init(sparams), dp_frontier0(K))
        (_, J_T), args = _chunked_drive(fwd_chunk, carry0, n_chunks,
                                        (tids_all,))
        return dp_backtrack(J_T, args)

    return core


# ----------------------------------------------------------------------
# Checkpointed two-pass DP: forward stores one [K] frontier per chunk,
# backtrack replays chunks in reverse, recomputing argmins on the fly —
# no [T, K] (so no [B, T, K]) backpointer table ever exists.
# ----------------------------------------------------------------------

def _make_dp_ckpt_instance_core(n_chunks: int, has_svc: bool,
                                collect_schedule: bool,
                                dp_backend: str = "xla"):
    """Checkpointed DP for ONE instance, obs-backed.

    Pass 1 runs ``dp_fwd_chunk`` over the chunks, emitting each chunk's
    *entry* frontier (a [K] row) instead of its [chunk, K] argmin table;
    pass 2 scans the chunks in reverse, recomputing each table from its
    checkpoint with the *same* ``dp_fwd_chunk`` and backtracking through it
    (``dp_backtrack_chunk``), chaining ``k`` right-to-left.  The (k, arg)
    op sequence is identical to the materialized backtrack, so the result
    is bit-identical; peak memory drops from O(T * K) to
    O((chunk + n_chunks) * K) per instance.  ``collect_schedule=False``
    skips pass 2 entirely (cost only — nothing O(T) remains at all).
    """

    def core(M, lv, g, kmask, T_len, x, c, *opt):
        K = lv.shape[-1]
        svc = opt[0] if has_svc else None
        lv32 = lv.astype(jnp.float32)
        fetch_mat = dp_fetch_matrix(M.astype(jnp.float32), lv32)
        T_pad = x.shape[0]
        chunk = T_pad // n_chunks
        cut = lambda a: (None if a is None
                         else a.reshape((n_chunks, chunk) + a.shape[1:]))
        xs, cs, ss = cut(x), cut(c), cut(svc)
        t0s = jnp.arange(n_chunks, dtype=jnp.int32) * chunk

        def chunk_pass(J, t0, xck, cck, sck):
            if sck is None:
                sck = _model1_svc(xck, g)
            tids = t0 + jnp.arange(chunk, dtype=jnp.int32)
            return dp_fwd_chunk(J, tids, cck, sck, lv32, kmask, fetch_mat,
                                T_len, dp_backend)

        def fwd(J, inp):
            t0, xck, cck, sck = inp
            J2, _ = chunk_pass(J, t0, xck, cck, sck)
            return J2, J                    # checkpoint = chunk-ENTRY frontier

        J_T, ckpts = jax.lax.scan(fwd, dp_frontier0(K), (t0s, xs, cs, ss))
        cost = jnp.min(J_T)
        if not collect_schedule:
            return cost
        k_T = jnp.argmin(J_T)

        def bwd(k, inp):
            Jck, t0, xck, cck, sck = inp
            _, args = chunk_pass(Jck, t0, xck, cck, sck)
            return dp_backtrack_chunk(k, args)

        _, r = jax.lax.scan(bwd, k_T, (ckpts, t0s, xs, cs, ss), reverse=True)
        return cost, r.reshape(T_pad).astype(jnp.int32)

    return core


def _make_dp_ckpt_scenario_core(sc_init, sc_chunk, n_chunks: int,
                                collect_schedule: bool,
                                dp_backend: str = "xla"):
    """Checkpointed DP with fused generation: pass 1 additionally
    checkpoints the generator state at each chunk entry (small — recursion
    state only, the innovations are counter-keyed), so pass 2 regenerates
    each chunk's slab from ``(gen checkpoint, tids)`` and recomputes its
    argmin table — the same counter-keyed regeneration trick the fused
    simulator uses, applied to the backtrack."""

    def core(sparams, M, lv, g, kmask, T_len, tids_all):
        K = lv.shape[-1]
        lv32 = lv.astype(jnp.float32)
        fetch_mat = dp_fetch_matrix(M.astype(jnp.float32), lv32)
        T_pad = tids_all.shape[0]
        chunk = T_pad // n_chunks
        tcks = tids_all.reshape(n_chunks, chunk)

        def chunk_pass(J, gen_state, tids):
            gen2, slab = sc_chunk(sparams, gen_state, tids)
            sck = slab.svc if slab.svc is not None else _model1_svc(slab.x, g)
            J2, args = dp_fwd_chunk(J, tids, slab.c, sck, lv32, kmask,
                                    fetch_mat, T_len, dp_backend)
            return gen2, J2, args

        def fwd(carry, tids):
            gen_state, J = carry
            gen2, J2, _ = chunk_pass(J, gen_state, tids)
            return (gen2, J2), (gen_state, J)      # entry-state checkpoints

        carry0 = (sc_init(sparams), dp_frontier0(K))
        (_, J_T), (gen_ckpts, J_ckpts) = jax.lax.scan(fwd, carry0, tcks)
        cost = jnp.min(J_T)
        if not collect_schedule:
            return cost
        k_T = jnp.argmin(J_T)

        def bwd(k, inp):
            gen_ck, Jck, tids = inp
            _, _, args = chunk_pass(Jck, gen_ck, tids)
            return dp_backtrack_chunk(k, args)

        _, r = jax.lax.scan(bwd, k_T, (gen_ckpts, J_ckpts, tcks),
                            reverse=True)
        return cost, r.reshape(T_pad).astype(jnp.int32)

    return core


@functools.lru_cache(maxsize=32)
def _compiled_dp_core(n_chunks: int, has_svc: bool, mesh: Mesh,
                      dp_backend: str = "xla"):
    core = _make_dp_instance_core(n_chunks, has_svc, dp_backend)
    spec = P(FLEET_AXIS)
    sharded = shard_map(jax.vmap(core), mesh=mesh,
                        in_specs=(spec,) * (7 + int(has_svc)),
                        out_specs=(spec, spec),
                        # pallas_call has no replication rule
                        check_rep=dp_backend == "xla")
    return jax.jit(sharded)


@functools.lru_cache(maxsize=32)
def _compiled_dp_scenario_core(sc_init, sc_chunk, n_chunks: int, mesh: Mesh,
                               dp_backend: str = "xla"):
    core = _make_dp_scenario_core(sc_init, sc_chunk, n_chunks, dp_backend)
    spec = P(FLEET_AXIS)
    sharded = shard_map(jax.vmap(core, in_axes=(0, 0, 0, 0, 0, 0, None)),
                        mesh=mesh, in_specs=(spec,) * 6 + (P(),),
                        out_specs=(spec, spec), check_rep=False)
    return jax.jit(sharded)


@functools.lru_cache(maxsize=32)
def _compiled_dp_ckpt_core(n_chunks: int, has_svc: bool,
                           collect_schedule: bool, mesh: Mesh,
                           dp_backend: str = "xla"):
    core = _make_dp_ckpt_instance_core(n_chunks, has_svc, collect_schedule,
                                       dp_backend)
    spec = P(FLEET_AXIS)
    out_specs = (spec, spec) if collect_schedule else spec
    sharded = shard_map(jax.vmap(core), mesh=mesh,
                        in_specs=(spec,) * (7 + int(has_svc)),
                        out_specs=out_specs,
                        check_rep=dp_backend == "xla")
    return jax.jit(sharded)


@functools.lru_cache(maxsize=32)
def _compiled_dp_ckpt_scenario_core(sc_init, sc_chunk, n_chunks: int,
                                    collect_schedule: bool, mesh: Mesh,
                                    dp_backend: str = "xla"):
    core = _make_dp_ckpt_scenario_core(sc_init, sc_chunk, n_chunks,
                                       collect_schedule, dp_backend)
    spec = P(FLEET_AXIS)
    out_specs = (spec, spec) if collect_schedule else spec
    sharded = shard_map(jax.vmap(core, in_axes=(0, 0, 0, 0, 0, 0, None)),
                        mesh=mesh, in_specs=(spec,) * 6 + (P(),),
                        out_specs=out_specs, check_rep=False)
    return jax.jit(sharded)


# ---- streamed checkpointed drivers: the host drives the two passes one
# chunk at a time, so neither obs nor r_hist is ever device-resident whole.

@functools.lru_cache(maxsize=32)
def _compiled_dp_stream_fwd(has_svc: bool, mesh: Mesh,
                            dp_backend: str = "xla",
                            donate: bool = False):
    """One forward slab of the value recursion: ``J -> J'``.
    ``donate=True`` donates the frontier and slab buffers — only legal for
    cost-only solves (``collect_schedule=True`` retains old frontiers as
    backtrack checkpoints, so it must keep ``donate=False``)."""

    def step(M, lv, g, kmask, T_len, t0, J, xck, cck, *opt):
        STREAM_TRACES["dp_fwd_obs"] += 1
        lv32 = lv.astype(jnp.float32)
        fetch_mat = dp_fetch_matrix(M.astype(jnp.float32), lv32)
        sck = opt[0] if has_svc else _model1_svc(xck, g)
        tids = t0 + jnp.arange(xck.shape[-1], dtype=jnp.int32)
        J2, _ = dp_fwd_chunk(J, tids, cck, sck, lv32, kmask, fetch_mat,
                             T_len, dp_backend)
        return J2

    n_opt = int(has_svc)
    in_axes = (0, 0, 0, 0, 0, None, 0, 0, 0) + (0,) * n_opt
    spec = P(FLEET_AXIS)
    in_specs = (spec,) * 5 + (P(),) + (spec,) * (3 + n_opt)
    sharded = shard_map(jax.vmap(step, in_axes=in_axes), mesh=mesh,
                        in_specs=in_specs, out_specs=spec,
                        check_rep=dp_backend == "xla")
    donate_argnums = tuple(range(6, 9 + n_opt)) if donate else ()
    return jax.jit(sharded, donate_argnums=donate_argnums)


@functools.lru_cache(maxsize=32)
def _compiled_dp_stream_bwd(has_svc: bool, mesh: Mesh,
                            dp_backend: str = "xla"):
    """One backward slab: recompute the chunk's argmins from its checkpoint
    and backtrack through them — ``(J_ckpt, k) -> (k_entry, r_chunk)``."""

    def step(M, lv, g, kmask, T_len, t0, Jck, k, xck, cck, *opt):
        lv32 = lv.astype(jnp.float32)
        fetch_mat = dp_fetch_matrix(M.astype(jnp.float32), lv32)
        sck = opt[0] if has_svc else _model1_svc(xck, g)
        tids = t0 + jnp.arange(xck.shape[-1], dtype=jnp.int32)
        _, args = dp_fwd_chunk(Jck, tids, cck, sck, lv32, kmask, fetch_mat,
                               T_len, dp_backend)
        k0, rck = dp_backtrack_chunk(k, args)
        return k0, rck.astype(jnp.int32)

    n_opt = int(has_svc)
    in_axes = (0, 0, 0, 0, 0, None, 0, 0, 0, 0) + (0,) * n_opt
    spec = P(FLEET_AXIS)
    in_specs = (spec,) * 5 + (P(),) + (spec,) * (4 + n_opt)
    sharded = shard_map(jax.vmap(step, in_axes=in_axes), mesh=mesh,
                        in_specs=in_specs, out_specs=(spec, spec),
                        check_rep=dp_backend == "xla")
    return jax.jit(sharded)


@functools.lru_cache(maxsize=32)
def _compiled_dp_scenario_stream_fwd(sc_init, sc_chunk, chunk: int,
                                     mesh: Mesh, dp_backend: str = "xla",
                                     donate: bool = False):
    """One fused-generation forward slab: the host ships one scalar offset
    per chunk; ``(gen_state, J) -> (gen', J')``.  ``donate=True`` donates
    the carry — cost-only solves only (see ``_compiled_dp_stream_fwd``)."""

    def step(sparams, M, lv, g, kmask, T_len, t0, carry):
        STREAM_TRACES["dp_fwd_scenario"] += 1
        gen_state, J = carry
        lv32 = lv.astype(jnp.float32)
        fetch_mat = dp_fetch_matrix(M.astype(jnp.float32), lv32)
        tids = t0 + jnp.arange(chunk, dtype=jnp.int32)
        gen2, slab = sc_chunk(sparams, gen_state, tids)
        sck = slab.svc if slab.svc is not None else _model1_svc(slab.x, g)
        J2, _ = dp_fwd_chunk(J, tids, slab.c, sck, lv32, kmask, fetch_mat,
                             T_len, dp_backend)
        return gen2, J2

    spec = P(FLEET_AXIS)
    sharded = shard_map(
        jax.vmap(step, in_axes=(0, 0, 0, 0, 0, 0, None, 0)), mesh=mesh,
        in_specs=(spec,) * 6 + (P(), spec), out_specs=(spec, spec),
        check_rep=False)
    return jax.jit(sharded, donate_argnums=(7,) if donate else ())


@functools.lru_cache(maxsize=32)
def _compiled_dp_scenario_stream_bwd(sc_init, sc_chunk, chunk: int,
                                     mesh: Mesh, dp_backend: str = "xla"):
    """One fused-generation backward slab: regenerate the chunk from its
    generator-state checkpoint, recompute its argmins, backtrack."""

    def step(sparams, M, lv, g, kmask, T_len, t0, gen_ck, Jck, k):
        lv32 = lv.astype(jnp.float32)
        fetch_mat = dp_fetch_matrix(M.astype(jnp.float32), lv32)
        tids = t0 + jnp.arange(chunk, dtype=jnp.int32)
        _, slab = sc_chunk(sparams, gen_ck, tids)
        sck = slab.svc if slab.svc is not None else _model1_svc(slab.x, g)
        _, args = dp_fwd_chunk(Jck, tids, slab.c, sck, lv32, kmask, fetch_mat,
                               T_len, dp_backend)
        k0, rck = dp_backtrack_chunk(k, args)
        return k0, rck.astype(jnp.int32)

    spec = P(FLEET_AXIS)
    sharded = shard_map(
        jax.vmap(step, in_axes=(0, 0, 0, 0, 0, 0, None, 0, 0, 0)), mesh=mesh,
        in_specs=(spec,) * 6 + (P(),) + (spec,) * 3, out_specs=(spec, spec),
        check_rep=False)
    return jax.jit(sharded)


def _dp_grid_args(padded: FleetBatch, mesh):
    dt = default_float_dtype()
    return (_dev_rows(mesh, padded.grid.M.astype(dt)),
            _dev_rows(mesh, padded.grid.levels.astype(dt)),
            _dev_rows(mesh, padded.grid.g.astype(dt)),
            _dev_rows(mesh, padded.grid.mask),
            _dev_rows(mesh, padded.T))


def _dp_scan_core_args(scenario, padded, mesh, n_chunks, T_pad,
                       checkpointed: bool, collect_schedule: bool,
                       dp_backend: str = "xla"):
    """(compiled device-scan DP core, its args) for this config — shared by
    ``offline_opt_fleet`` and ``offline_dp_memory_stats`` so the probed
    program is exactly the executed one."""
    grid_args = _dp_grid_args(padded, mesh)
    if scenario is not None:
        sparams = _dev_tree(mesh, _pad_params(scenario.params, padded.B))
        if checkpointed:
            core = _compiled_dp_ckpt_scenario_core(
                scenario.init_fn, scenario.chunk_fn, n_chunks,
                collect_schedule, mesh, dp_backend)
        else:
            core = _compiled_dp_scenario_core(scenario.init_fn,
                                              scenario.chunk_fn, n_chunks,
                                              mesh, dp_backend)
        args = (sparams,) + grid_args + (
            _dev_replicated(mesh, np.arange(T_pad, dtype=np.int32)),)
    else:
        has_svc = padded.svc is not None
        if checkpointed:
            core = _compiled_dp_ckpt_core(n_chunks, has_svc, collect_schedule,
                                          mesh, dp_backend)
        else:
            core = _compiled_dp_core(n_chunks, has_svc, mesh, dp_backend)
        args = grid_args + (_dev_rows(mesh, padded.x),
                            _dev_rows(mesh, padded.c))
        if has_svc:
            args += (_dev_rows(mesh, padded.svc),)
    return core, args


def _dp_ckpt_streamed(scenario, padded, mesh, n_chunks, T_pad,
                      collect_schedule: bool, dp_backend: str = "xla",
                      async_ingest: bool = False):
    """Host-driven checkpointed DP: forward loop collecting per-chunk
    frontier (+ generator-state) checkpoints in a device-resident list,
    then a backward loop replaying the chunks in reverse.  With a scenario
    the host ships one scalar offset per chunk each way; obs-backed fleets
    slab-feed host-resident numpy arrays like ``_run_fleet_streamed``
    (``async_ingest=True`` prefetches the slabs of BOTH passes).

    The forward pass is a thin loop over the persistent ``FleetStepper``.
    Donation rule: cost-only solves donate the frontier carry; with
    ``collect_schedule=True`` the old carries ARE the backtrack
    checkpoints, so that path must run ``donate=False``.
    """
    chunk = T_pad // n_chunks
    grid_args = _dp_grid_args(padded, mesh)
    B_pad, K = padded.B, padded.K
    T_orig = None      # stepper result metadata, unused by DP readbacks
    donate = not collect_schedule
    J0 = _dev_rows(mesh, np.broadcast_to(np.asarray(dp_frontier0(K)),
                                         (B_pad, K)))
    if scenario is not None:
        sparams = _dev_tree(mesh, _pad_params(scenario.params, padded.B))
        fwd = _compiled_dp_scenario_stream_fwd(scenario.init_fn,
                                               scenario.chunk_fn, chunk,
                                               mesh, dp_backend, donate)
        bwd = _compiled_dp_scenario_stream_bwd(scenario.init_fn,
                                               scenario.chunk_fn, chunk,
                                               mesh, dp_backend)
        gen0 = _vmap_init(scenario.init_fn, sparams, mesh)
        carry0 = (gen0, J0)

        def call(carry, t0, slabs):
            return fwd(sparams, *grid_args, t0, carry)

        make_slab = None
    else:
        has_svc = padded.svc is not None
        fwd = _compiled_dp_stream_fwd(has_svc, mesh, dp_backend, donate)
        bwd = _compiled_dp_stream_bwd(has_svc, mesh, dp_backend)
        carry0 = J0

        def call(carry, t0, slabs):
            return fwd(*grid_args, t0, carry, *slabs)

        make_slab = _obs_slab_builder(padded, chunk, mesh, with_side=False)

    stepper = FleetStepper(call=call, carry=carry0, chunk=chunk, mesh=mesh,
                           has_out=False, kind="dp",
                           scenario_mode=scenario is not None, donate=donate,
                           B=B_pad, B_pad=B_pad, K=K, T_max=T_pad,
                           T_orig=T_orig)
    empty = lambda i: ()
    ckpts = []                 # device-resident [B, K] rows (+ gen states)
    for slabs in slab_feed(make_slab or empty, n_chunks,
                           async_ingest and make_slab is not None):
        if collect_schedule:   # cost-only never backtracks — don't retain
            ckpts.append(stepper.carry)  # dead device rows
        stepper.step_slabs(slabs)
    # local rows: each process backtracks (and returns) its own shard
    J_T = _local_rows(stepper.carry[1] if scenario is not None
                      else stepper.carry)
    cost = J_T.min(axis=1)
    if not collect_schedule:
        return cost, None
    k = _dev_rows(mesh, J_T.argmin(axis=1).astype(np.int32))
    r_parts = []
    rev = (empty if make_slab is None
           else (lambda j: make_slab(n_chunks - 1 - j)))
    with shard_ctx(mesh, (FLEET_AXIS,), model_axis=None):
        for j, slabs in enumerate(
                slab_feed(rev, n_chunks,
                          async_ingest and make_slab is not None)):
            i = n_chunks - 1 - j
            t0 = np.int32(i * chunk)
            if scenario is not None:
                gen_ck, Jck = ckpts[i]
                k, rck = bwd(sparams, *grid_args, t0, gen_ck, Jck, k)
            else:
                k, rck = bwd(*grid_args, t0, ckpts[i], k, *slabs)
            r_parts.append(_local_rows(rck))
    r_hist = np.concatenate(r_parts[::-1], axis=1)
    return cost, r_hist


def offline_dp_memory_stats(fleet: FleetBatch, *,
                            scenario: Optional[Scenario] = None,
                            mesh: Optional[Mesh] = None,
                            chunk_size: Optional[int] = None,
                            checkpointed: bool = False,
                            collect_schedule: bool = True,
                            n_seeds: Optional[int] = None,
                            antithetic: bool = False,
                            dp_backend: str = "xla",
                            prng_backend: str = "xla") -> dict:
    """XLA-reported memory of the compiled device-scan DP core for this
    config, WITHOUT running it: ``{"argument_bytes", "output_bytes",
    "temp_bytes"}``.  The probed program is built by the same
    ``_dp_scan_core_args`` (and the same MC replication) the solver uses,
    so the numbers describe exactly the executed computation —
    ``kernel_bench.offline_dp_streaming`` asserts its peak-memory ratio
    (materialized vs checkpointed backpointers) on ``temp_bytes``, where
    scan-carried intermediates such as the [B, T, K] argmin table live.
    Note the stats are per *program*: on a multi-device mesh each device
    runs one program over its B/n_devices shard."""
    if not collect_schedule and not checkpointed:
        # same contract as offline_opt_fleet — never report a program the
        # solver would refuse to run
        raise ValueError("collect_schedule=False requires checkpointed=True")
    _check_backends(dp_backend, prng_backend, scenario)
    fleet, scenario, _ = _replicate_mc(fleet, scenario, n_seeds, antithetic)
    if scenario is not None:
        _check_scenario(scenario, fleet)
        scenario = with_prng_backend(scenario, prng_backend)
    mesh, padded, n_chunks, T_pad = _prepare_fleet(fleet, mesh, chunk_size)
    core, args = _dp_scan_core_args(scenario, padded, mesh, n_chunks, T_pad,
                                    checkpointed, collect_schedule,
                                    dp_backend)
    stats = core.lower(*args).compile().memory_analysis()
    return {"argument_bytes": int(stats.argument_size_in_bytes),
            "output_bytes": int(stats.output_size_in_bytes),
            "temp_bytes": int(stats.temp_size_in_bytes)}


def offline_opt_fleet(fleet: FleetBatch, *,
                      scenario: Optional[Scenario] = None,
                      mesh: Optional[Mesh] = None,
                      chunk_size: Optional[int] = None,
                      n_seeds: Optional[int] = None,
                      antithetic: bool = False,
                      checkpointed: bool = False,
                      stream: bool = False,
                      collect_schedule: bool = True,
                      dp_backend: str = "xla",
                      prng_backend: str = "xla",
                      async_ingest: bool = False,
                      gather: bool = False) -> FleetOfflineResult:
    """Fleet alpha-OPT: the exact DP, sharded over devices and chunked over
    time, each instance solved at its own horizon.  With ``scenario=...``
    the observations are generated on device inside the forward recursion
    (and again inside the schedule evaluation) — bit-identical to the
    materialized run.  ``n_seeds=S`` solves S seed-replicas of every
    instance (same key-fold convention as ``run_fleet``; ``antithetic=True``
    pairs them — see ``scenarios.replicate_seeds``).

    ``checkpointed=True`` switches to the two-pass checkpointed recursion:
    the forward pass keeps one [B, K] value-frontier checkpoint per chunk
    and the backtrack replays each chunk in reverse from its checkpoint,
    recomputing argmins on the fly — **bit-identical** to the materialized
    path wherever both fit, but never materializing a [B, T, K] (or any
    [B, T]-shaped backpointer) array, which is what extends exact OPT to
    T = 10^6-10^7 horizons.  It composes with every other axis: mesh,
    mixed horizons, ``n_seeds``, ``chunk_size`` (the checkpoint grain) and
    ``stream=True`` (host-driven passes — requires ``chunk_size``; obs and
    ``r_hist`` then cross the host boundary one [B, chunk] slab at a time).
    ``collect_schedule=False`` (checkpointed only) skips the backtrack and
    the schedule evaluation altogether and returns cost-only results
    (``r_hist`` / ``sim`` are None) — the cheapest way to price OPT at
    horizons where even the [B, T] schedule is unwelcome.

    ``dp_backend`` selects the min-plus relaxation engine inside every
    driver above ("xla" default / "pallas" — see ``dp_fwd_chunk``);
    ``prng_backend`` the scenario's counter-keyed uniform engine (as in
    ``run_fleet``).  Backends are a pure performance knob: costs,
    schedules and sim results are bit-identical across every combination
    (tests/test_backend_dispatch.py).

    ``async_ingest=True`` (streamed, obs-backed fleets) prefetches the
    host->device obs slabs of both DP passes on a background thread —
    double buffering, bit-identical to the synchronous feed (see
    ``core/ingest.py``); a no-op for scenario-fused solves, which ship no
    slabs.

    ``gather=True`` (process-spanning meshes) allgathers cost / r_hist /
    sim rows to the full [B_global] fleet on every process; the default is
    this process's own rows, as in ``run_fleet``."""
    if stream and not checkpointed:
        raise ValueError("stream=True requires checkpointed=True (the "
                         "materialized backtrack needs the whole table)")
    if stream and chunk_size is None:
        raise ValueError("stream=True requires chunk_size")
    if async_ingest and not stream:
        raise ValueError("async_ingest=True requires stream=True (only the "
                         "host-driven passes feed slabs)")
    if not collect_schedule and not checkpointed:
        raise ValueError("collect_schedule=False requires checkpointed=True")
    _check_backends(dp_backend, prng_backend, scenario)
    fleet, scenario, S = _replicate_mc(fleet, scenario, n_seeds, antithetic)
    B, T_max = fleet.B, fleet.T_max
    mesh, padded, n_chunks, T_pad = _prepare_fleet(fleet, mesh, chunk_size)
    if scenario is not None:
        _check_scenario(scenario, fleet)
        scenario = with_prng_backend(scenario, prng_backend)
    if stream:
        cost, r_hist = _dp_ckpt_streamed(scenario, padded, mesh, n_chunks,
                                         T_pad, collect_schedule, dp_backend,
                                         async_ingest)
    else:
        core, args = _dp_scan_core_args(scenario, padded, mesh, n_chunks,
                                        T_pad, checkpointed, collect_schedule,
                                        dp_backend)
        with shard_ctx(mesh, (FLEET_AXIS,), model_axis=None):
            out = core(*args)
        cost, r_hist = out if collect_schedule else (out, None)
    cost = _local_rows(cost)[:B].astype(np.float64)
    if not collect_schedule:
        if gather:
            cost = _gather_rows(mesh, cost)
        return FleetOfflineResult(cost=cost, r_hist=None, sim=None,
                                  n_seeds=S)
    r_hist = _local_rows(r_hist)[:B, :T_max].astype(np.int64)
    # fleet/scenario are already seed-replicated here, so the evaluation
    # runs plain and only the result is re-tagged with the MC axis
    sim = evaluate_schedule_fleet(fleet, r_hist, scenario=scenario, mesh=mesh,
                                  chunk_size=chunk_size)
    sim = dataclasses.replace(sim, n_seeds=S)
    if gather:
        cost = _gather_rows(mesh, cost)
        r_hist = _gather_rows(mesh, r_hist)
        sim = _gather_result(sim, mesh)
    return FleetOfflineResult(cost=cost, r_hist=r_hist, sim=sim, n_seeds=S)


# ----------------------------------------------------------------------
# Schedule evaluation on a fleet.
# ----------------------------------------------------------------------

def _make_schedule_instance_core(n_chunks: int, has_svc: bool):
    def core(lv, g, M, T_len, r, x, c, *opt):
        K = lv.shape[-1]
        svc = opt[0] if has_svc else None
        carry0 = (jnp.asarray(0, jnp.int32), sim_acc0(K, lv.dtype))

        def run_chunk(carry, t0, rck, xck, cck, sck):
            if sck is None:
                sck = _model1_svc(xck, g)
            return schedule_chunk_core(lv, M, T_len, t0, carry, rck, cck, sck)

        carry, _ = _chunked_drive(run_chunk, carry0, n_chunks, (r, x, c, svc))
        (_, acc) = carry
        return acc["sums"], acc["counts"]

    return core


def _make_schedule_scenario_core(sc_init, sc_chunk, n_chunks: int):
    """Schedule evaluation with fused generation: the schedule ``r`` stays
    a resident array (it is the *input*), the obs it is priced on are
    generated chunk-by-chunk."""

    def core(sparams, lv, g, M, T_len, r, tids_all):
        K = lv.shape[-1]
        carry0 = (sc_init(sparams),
                  (jnp.asarray(0, jnp.int32), sim_acc0(K, lv.dtype)))

        def run_chunk(carry, t0, rck, tids):
            gen_state, sched = carry
            gen_state, slab = sc_chunk(sparams, gen_state, tids)
            sck = slab.svc if slab.svc is not None else _model1_svc(slab.x, g)
            sched, _ = schedule_chunk_core(lv, M, T_len, t0, sched, rck,
                                           slab.c, sck)
            return (gen_state, sched), None

        carry, _ = _chunked_drive(run_chunk, carry0, n_chunks, (r, tids_all))
        (_, (_, acc)) = carry
        return acc["sums"], acc["counts"]

    return core


@functools.lru_cache(maxsize=32)
def _compiled_schedule_core(n_chunks: int, has_svc: bool, mesh: Mesh):
    core = _make_schedule_instance_core(n_chunks, has_svc)
    spec = P(FLEET_AXIS)
    sharded = shard_map(jax.vmap(core), mesh=mesh,
                        in_specs=(spec,) * (7 + int(has_svc)),
                        out_specs=(spec, spec))
    return jax.jit(sharded)


@functools.lru_cache(maxsize=32)
def _compiled_schedule_scenario_core(sc_init, sc_chunk, n_chunks: int,
                                     mesh: Mesh):
    core = _make_schedule_scenario_core(sc_init, sc_chunk, n_chunks)
    spec = P(FLEET_AXIS)
    sharded = shard_map(jax.vmap(core, in_axes=(0, 0, 0, 0, 0, 0, None)),
                        mesh=mesh, in_specs=(spec,) * 6 + (P(),),
                        out_specs=(spec, spec), check_rep=False)
    return jax.jit(sharded)


def evaluate_schedule_fleet(fleet: FleetBatch, r_hist, *,
                            scenario: Optional[Scenario] = None,
                            mesh: Optional[Mesh] = None,
                            chunk_size: Optional[int] = None,
                            n_seeds: Optional[int] = None,
                            antithetic: bool = False,
                            prng_backend: str = "xla",
                            gather: bool = False) -> FleetResult:
    """Fleet ``evaluate_schedule``: ``r_hist`` is [B, T_max]; slots past each
    instance's T contribute nothing (and charge no fetch).  With
    ``scenario=...`` the priced observations are generated on device;
    ``n_seeds=S`` prices the schedules on S seed-replicas of the scenario
    (``r_hist`` rows may be [B] — repeated per replica — or the full
    [B*S] replication; ``antithetic=True`` pairs the replicas as in
    ``run_fleet``).  ``prng_backend`` selects the counter-keyed uniform
    engine as in ``run_fleet`` (bit-identical performance knob)."""
    dt = default_float_dtype()
    B_orig = fleet.B
    _check_backends("xla", prng_backend, scenario)
    fleet, scenario, S = _replicate_mc(fleet, scenario, n_seeds, antithetic)
    if scenario is not None:
        scenario = with_prng_backend(scenario, prng_backend)
    B, T_max = fleet.B, fleet.T_max
    mesh, padded, n_chunks, T_pad = _prepare_fleet(fleet, mesh, chunk_size)
    r = np.asarray(r_hist, np.int32)
    if S > 1 and r.shape[0] == B_orig:
        r = np.repeat(r, S, axis=0)
    if T_pad > T_max:
        r = np.pad(r, ((0, 0), (0, T_pad - T_max)))
    r = _pad_rows(r, padded.B, np)
    if scenario is not None:
        _check_scenario(scenario, fleet)
        sparams = _dev_tree(mesh, _pad_params(scenario.params, padded.B))
        core = _compiled_schedule_scenario_core(scenario.init_fn,
                                                scenario.chunk_fn,
                                                n_chunks, mesh)
        args = (sparams, _dev_rows(mesh, padded.grid.levels.astype(dt)),
                _dev_rows(mesh, padded.grid.g.astype(dt)),
                _dev_rows(mesh, padded.grid.M.astype(dt)),
                _dev_rows(mesh, padded.T), _dev_rows(mesh, r),
                _dev_replicated(mesh, np.arange(T_pad, dtype=np.int32)))
    else:
        has_svc = fleet.svc is not None
        core = _compiled_schedule_core(n_chunks, has_svc, mesh)
        args = (_dev_rows(mesh, padded.grid.levels.astype(dt)),
                _dev_rows(mesh, padded.grid.g.astype(dt)),
                _dev_rows(mesh, padded.grid.M.astype(dt)),
                _dev_rows(mesh, padded.T), _dev_rows(mesh, r),
                _dev_rows(mesh, padded.x), _dev_rows(mesh, padded.c))
        if has_svc:
            args += (_dev_rows(mesh, padded.svc),)
    with shard_ctx(mesh, (FLEET_AXIS,), model_axis=None):
        sums, counts = core(*args)
    # r (replicated + padded above) rather than the raw r_hist input, so the
    # returned trace matches the [B*S] row layout of the totals
    res = _fleet_result(r.astype(np.int64), sums, counts,
                        B, T_max, fleet.T, S)
    return _gather_result(res, mesh) if gather else res
