"""Closed-form results of the paper: Theorems 1, 2, 4 (adversarial) and the
Theorem-5 stochastic guarantee with its f/q/h machinery.

These are *reporting* functions: benchmarks plot them (the alpha-LB / LB
curves of Figs 1-6 and 12-15) and tests check the paper's qualitative
claims (bounds > 1, decay to 0 with M, the <= 6 corollary under
Assumption 6).

Printed-text notes (kept faithful, flagged here):
  * Theorem 5's middle case divides by (M + c) and the last by c as printed,
    although the proof's eqs. (23)/(28) normalise by c and p respectively;
    we implement the printed statement and expose the proof variant via
    ``denominator="proof"``.
  * The f/q/h expressions are upper bounds on a probability-weighted excess
    cost; outside their case regions some inner terms lose meaning, so the
    evaluators first check the case conditions and raise otherwise.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.costs import HostingCosts


# ----------------------------------------------------------------------
# Theorem 1 — when partial hosting is never used
# ----------------------------------------------------------------------

def thm1_no_partial(costs: HostingCosts) -> bool:
    """True iff alpha + g(alpha) >= 1, in which case alpha-RR never hosts
    partially and alpha-OPT abandons the partial level permanently."""
    return costs.alpha + costs.g_alpha >= 1.0


# ----------------------------------------------------------------------
# Theorem 2 / Corollary 3 — alpha-RR competitive-ratio upper bound
# ----------------------------------------------------------------------

def thm2_is_optimal_regime(costs: HostingCosts) -> bool:
    return costs.alpha * costs.c_min + costs.g_alpha >= 1.0 and costs.c_min >= 1.0


def thm2_ratio_upper(costs: HostingCosts) -> float:
    if thm2_is_optimal_regime(costs):
        return 1.0
    M, a, g = costs.M, costs.alpha, costs.g_alpha
    return 4.0 + 1.0 / M + max(1.0 / M, (1.0 - g) / (M * a))


def corollary3_six(costs: HostingCosts) -> float:
    """Under Assumption 6 the Theorem-2(b) bound is <= 6."""
    assert costs.assumption6_holds(), "Corollary 3 requires Assumption 6"
    b = thm2_ratio_upper(costs)
    assert b <= 6.0 + 1e-9
    return b


# ----------------------------------------------------------------------
# Theorem 4 — lower bound for any deterministic online policy
# ----------------------------------------------------------------------

def _f_uv(costs: HostingCosts, u: float, v: float) -> float:
    M, cmin = costs.M, costs.c_min

    def g(z):
        if abs(z - costs.alpha) < 1e-12:
            return costs.g_alpha
        if abs(z - 1.0) < 1e-12:
            return 0.0
        raise ValueError(z)

    return 1.0 + (u * M + u * cmin + g(u)) * (1.0 - v * cmin - g(v)) / (v * M)


def thm4_lower(costs: HostingCosts) -> float:
    """Lower bound on rho for any deterministic online policy with partial
    hosting allowed (the alpha-LB curves)."""
    a, g = costs.alpha, costs.g_alpha
    cmin = costs.c_min
    cond_partial = a * cmin + g < 1.0
    if cmin < 1.0 and cond_partial:                       # case (a)
        t1 = min(_f_uv(costs, a, a), _f_uv(costs, 1.0, 1.0))
        t2 = min(1.0 / (a * cmin + g), 1.0 / (cmin * 1.0 + 0.0))
        return max(min(t1, t2), 1.0)
    if cmin < 1.0:                                        # case (b)
        t1 = min(_f_uv(costs, a, 1.0), _f_uv(costs, 1.0, 1.0))
        return max(min(t1, 1.0 / cmin), 1.0)
    if cond_partial:                                      # case (c)
        t1 = min(_f_uv(costs, a, a), _f_uv(costs, 1.0, a))
        return max(min(t1, 1.0 / (a * cmin + g)), 1.0)
    return 1.0  # alpha-RR itself is optimal here (Theorem 2(a))


def thm4_lower_no_partial(costs: HostingCosts) -> float:
    """The [22] bound for policies restricted to {0, 1} (the LB curves):
    the u = v = 1 specialisation of Theorem 4."""
    cmin = costs.c_min
    if cmin >= 1.0:
        return 1.0
    return max(min(_f_uv(costs, 1.0, 1.0), 1.0 / cmin), 1.0)


# ----------------------------------------------------------------------
# Theorem 5 — stochastic guarantee (Model 2)
# ----------------------------------------------------------------------

def _sq(z):
    return z * z


def f_fn(lam, M, p, c, a, g, cmin, cmax):
    """f(lambda, M, p, c, alpha, g(alpha)) — valid when
    alpha*c/(1-g) < p < (1-alpha)*c/g (case 1)."""
    dA = p * (1 - g) - a * c            # > 0 in case 1
    dB = (1 - a) * c - p * g            # > 0 in case 1
    if dA <= 0 or dB <= 0:
        raise ValueError("f() outside its case region")
    nA = 1 + a * cmax - a * cmin
    nB = 1 + (1 - a) * (cmax - cmin)
    Mt = max(math.ceil(M * a / dA), math.ceil(M * (1 - a) / dB))
    dlA = math.exp(-4 * dA * a * M / _sq(nA))
    dlB = math.exp(-4 * dB * (1 - a) * M / _sq(nB))
    tA = lam * Mt * dlA * math.exp(-2 * (M / cmax + 1) * _sq(dA) / _sq(nA)) \
        / max(1 - math.exp(-2 * _sq(dA) / _sq(nA)), 1e-300)
    tB = lam * Mt * dlB * math.exp(-2 * ((1 - a) * M / max(1 - (1 - a) * cmin, 1e-9) + 1)
                                   * _sq(dB) / _sq(nB)) \
        / max(1 - math.exp(-2 * _sq(dB) / _sq(nB)), 1e-300)
    tF = math.exp(-2 * _sq(lam - 1) * _sq(M) * _sq(a) / (lam * Mt * _sq(1 + a * (cmax - cmin))))
    return max(M + p, M + c) * (tA + tB + tF)


def q_fn(lam, M, p, c, a, g, cmin, cmax):
    """q(...) — valid when p > max{c, (1-alpha)c/g} (case 2)."""
    dA = p - c
    dB = p * g - (1 - a) * c
    if dA <= 0 or dB <= 0:
        raise ValueError("q() outside its case region")
    nA = 1 + cmax - cmin
    nB = 1 + (1 - a) * (cmax - cmin)
    Mt = max(M / dA, math.ceil(M * (1 - a) / dB))
    dlA = math.exp(-4 * dA * a * M / _sq(nA))
    dlB = math.exp(-4 * dB * (1 - a) * M / _sq(nB))
    tA = dlA * lam * Mt * math.exp(-2 * (M / cmax + 1) * _sq(dA) / _sq(1 + cmax - a * cmin)) \
        / max(1 - math.exp(-2 * _sq(dA) / _sq(nA)), 1e-300)
    tB = dlB * lam * Mt * math.exp(-2 * (M / cmax + 1) * _sq(dB) / _sq(nB)) \
        / max(1 - math.exp(-2 * _sq(dB) / _sq(nB)), 1e-300)
    tE = math.exp(-2 * _sq(lam - 1) * _sq(M) * _sq(1 - a) / (lam * Mt * _sq(nB)))
    tF = math.exp(-2 * _sq(lam - 1) * _sq(M) * _sq(a) / (lam * Mt * _sq(1 + a * (cmax - cmin))))
    return max(a * M + a * c + g * p, M + c) * (tA + tB + tE + tF)


def h_fn(lam, M, p, c, a, g, cmin, cmax):
    """h(...) — valid when p < min{c, alpha*c/(1-g)} (case 3)."""
    dA = c - p
    dB = a * c - p * (1 - g)
    if dA <= 0 or dB <= 0:
        raise ValueError("h() outside its case region")
    nA = 1 + cmax - cmin
    nB = 1 + a * (cmax - cmin)
    Mt = max(M / dA, math.ceil(M * a / dB))
    dlA = math.exp(-4 * dA * a * M / _sq(nA))
    dlB = math.exp(-4 * dB * a * M / _sq(nB))
    tA = 2 * lam * Mt * dlA * math.exp(-2 * (M / max(1 - cmin, 1e-9) + 1)
                                       * _sq(dA) / _sq(1 + cmax - a * cmin)) \
        / max(1 - math.exp(-2 * _sq(dA) / _sq(nA)), 1e-300)
    tB = 2 * lam * Mt * dlB * math.exp(-2 * (a * M / max(1 - g - a * cmin, 1e-9) + 1)
                                       * _sq(dB) / _sq(nB)) \
        / max(1 - math.exp(-2 * _sq(dB) / _sq(nB)), 1e-300)
    tE = math.exp(-2 * _sq(lam - 1) * _sq(M) * _sq(a) / (lam * Mt * _sq(nB)))
    tF = math.exp(-2 * _sq(lam - 1) * _sq(M) / (lam * Mt * _sq(nA)))
    return max(a * M + a * c + g * p, M + p) * (tA + tB + tE + tF)


def thm5_sigma_upper(costs: HostingCosts, p: float, c: float,
                     lam_grid=None, denominator: str = "printed") -> float:
    """sigma(T) upper bound of Theorem 5; selects the case from (p, c),
    minimises over a lambda grid. Returns +inf if (p, c) falls on a case
    boundary where the theorem is silent."""
    a, g = costs.alpha, costs.g_alpha
    M, cmin, cmax = costs.M, costs.c_min, costs.c_max
    if lam_grid is None:
        lam_grid = np.linspace(1.05, 20.0, 200)

    def best(fn):
        vals = []
        for lam in lam_grid:
            try:
                vals.append(fn(lam, M, p, c, a, g, cmin, cmax))
            except (ValueError, OverflowError):
                continue
        return min(vals) if vals else math.inf

    if a * c / (1 - g) < p < (1 - a) * c / g:
        den = a * c + g * p
        return 1.0 + best(f_fn) / den
    if p > max(c, (1 - a) * c / g):
        den = (M + c) if denominator == "printed" else c
        return 1.0 + best(q_fn) / den
    if p < min(c, a * c / (1 - g)):
        den = c if denominator == "printed" else p
        return 1.0 + best(h_fn) / den
    return math.inf


def lemma14_opt_on_per_slot(costs: HostingCosts, p: float, c: float) -> float:
    """Lemma 14: E[C_t^{alpha-OPT-ON}] >= min{c, alpha*c + g(alpha)*p, p}."""
    return min(c, costs.alpha * c + costs.g_alpha * p, p)
