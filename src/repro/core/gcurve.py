"""g(.) curves — the edge-serviceability cost as a function of the hosted
fraction.

Three constructions:
  * ``interp_gcurve`` — piecewise-linear through measured (alpha, g) pairs
    (what §7.2 does with the GPS-trajectory curve, Fig. 23).
  * ``power_gcurve`` — the synthetic family g(a) = (1-a)^gamma (gamma > 1
    gives the concave "most value in the first bytes" shape seen in Fig 23).
  * ``moe_expert_gcurve`` — the MoE adaptation (DESIGN.md §4): hosting the
    top-(alpha*E) most popular routed experts, a top-k-routed request is
    edge-servable iff all its k experts are resident; 1 - g(alpha) is that
    probability under a Zipf expert-popularity law, estimated by Monte
    Carlo sampling without replacement.

All curves are clamped to the paper's contract: g(0)=1, g(1)=0,
non-increasing.
"""
from __future__ import annotations

import numpy as np


def _sanitize(alphas: np.ndarray, gs: np.ndarray):
    alphas = np.concatenate([[0.0], np.asarray(alphas, np.float64), [1.0]])
    gs = np.concatenate([[1.0], np.asarray(gs, np.float64), [0.0]])
    order = np.argsort(alphas)
    alphas, gs = alphas[order], gs[order]
    gs = np.minimum.accumulate(gs)          # enforce non-increasing
    return alphas, np.clip(gs, 0.0, 1.0)


def interp_gcurve(alphas, gs):
    xs, ys = _sanitize(np.asarray(alphas), np.asarray(gs))

    def g(a):
        return float(np.interp(a, xs, ys))

    return g


def power_gcurve(gamma: float = 2.0):
    def g(a):
        return float((1.0 - a) ** gamma)

    return g


def fig23_like_gcurve():
    """Anchored to the paper's Fig. 23 calibration points: the knapsack curve
    saturates below 1 (test-year queries miss paths unseen in training
    years); g(0.16) = 0.76 (the paper's chosen operating point) and the
    Fig. 24 optimum near alpha = 0.5."""
    anchors_a = [0.05, 0.16, 0.30, 0.50, 0.75, 1.00]
    anchors_served = [0.10, 0.24, 0.38, 0.52, 0.62, 0.68]
    # g = 1 - served, but force g(1)=0 per the cost-model contract: the
    # saturating tail is handled by never letting alpha-RR pick alpha=1 in
    # the geolife benchmarks (full hosting serves everything by definition
    # in the cost model; the dataset's residual 0.32 is cloud-side novelty).
    gs = [1.0 - s for s in anchors_served]
    xs = np.asarray(anchors_a[:-1])
    ys = np.asarray(gs[:-1])
    return interp_gcurve(xs, ys)


def zipf_popularity(n: int, s: float = 1.0) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1) ** s
    return p / p.sum()


def moe_expert_gcurve(popularity: np.ndarray, top_k: int, alphas,
                      n_samples: int = 20000, seed: int = 0):
    """Estimate g(alpha) for expert-subset hosting.

    Hosted set = the ceil(alpha * E) most popular experts. A request draws
    ``top_k`` distinct experts with probability proportional to popularity
    (a standard surrogate for learned-router skew). The request is fully
    edge-servable iff all drawn experts are hosted.

    Returns (alphas, g_values, g_callable).
    """
    rng = np.random.default_rng(seed)
    p = np.asarray(popularity, np.float64)
    E = len(p)
    order = np.argsort(-p)                      # most popular first
    rank_of = np.empty(E, np.int64)
    rank_of[order] = np.arange(E)
    # sample routed sets once; reuse across alphas (common random numbers)
    draws = np.empty((n_samples, top_k), np.int64)
    for i in range(n_samples):
        draws[i] = rng.choice(E, size=top_k, replace=False, p=p)
    worst_rank = rank_of[draws].max(axis=1)     # least-popular routed expert
    alphas = np.asarray(alphas, np.float64)
    gs = np.empty_like(alphas)
    for j, a in enumerate(alphas):
        hosted = int(np.ceil(a * E))
        gs[j] = 1.0 - float(np.mean(worst_rank < hosted))
    g = interp_gcurve(alphas, gs)
    return alphas, gs, g


def uniform_moe_gcurve_analytic(E: int, top_k: int):
    """Uniform-routing closed form: 1 - g(a) = C(hosted, k)/C(E, k)."""
    from math import comb

    def g(a):
        hosted = int(np.ceil(a * E))
        if hosted < top_k:
            return 1.0
        return 1.0 - comb(hosted, top_k) / comb(E, top_k)

    return g
