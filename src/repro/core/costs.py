"""Cost model for partial service hosting (Section 2.6 of the paper).

Levels are a strictly increasing tuple ``levels = (0, a_1, ..., 1)`` with a
matching non-increasing service-cost tuple ``g = (1, g(a_1), ..., 0)``.  The
paper's setting is the 3-level case ``(0, alpha, 1)``; ``multiple-RR``
(Figs 7/8) uses more levels, and RR/OPT (no partial hosting) is the 2-level
case ``(0, 1)``.

Per-slot cost of holding level ``r`` in slot ``t`` and switching to ``r'``
for slot ``t+1``:

    C_t = M * (r' - r)^+        fetch cost      (eviction is free)
        + c_t * r               rent cost       (linear in hosted fraction)
        + svc_t(r)              service cost    (Model 1: g(r) * x_t;
                                                 Model 2: realized Binomial)

All functions are pure and JAX-compatible; the simulator composes them under
``jax.lax.scan``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def default_float_dtype() -> jnp.dtype:
    """The float dtype JAX currently promotes Python floats to: float64 when
    ``jax_enable_x64`` is on, float32 otherwise.  Computed lazily (the flag
    can be toggled after import) — use this everywhere instead of probing
    ``jnp.array(0.).dtype`` inline."""
    return jnp.result_type(float)


@dataclasses.dataclass(frozen=True)
class HostingCosts:
    """Static cost parameters of one hosting problem instance.

    Attributes:
      M: fetch cost for the full service (Assumption 5: ``M > 1``).
      levels: hosting levels, ascending, ``levels[0] == 0``, ``levels[-1] == 1``.
      g: service cost per request at each level, ``g[0] == 1``, ``g[-1] == 0``.
      c_min / c_max: rent-cost bounds (Assumption 3).
    """

    M: float
    levels: Tuple[float, ...]
    g: Tuple[float, ...]
    c_min: float = 0.0
    c_max: float = float("inf")

    def __post_init__(self):
        if len(self.levels) != len(self.g):
            raise ValueError("levels and g must have equal length")
        if len(self.levels) < 2:
            raise ValueError("need at least levels (0, 1)")
        lv = np.asarray(self.levels, dtype=np.float64)
        gv = np.asarray(self.g, dtype=np.float64)
        if not (lv[0] == 0.0 and abs(lv[-1] - 1.0) < 1e-12):
            raise ValueError(f"levels must span [0, 1], got {self.levels}")
        if np.any(np.diff(lv) <= 0):
            raise ValueError("levels must be strictly increasing")
        if not (abs(gv[0] - 1.0) < 1e-12 and abs(gv[-1]) < 1e-12):
            raise ValueError("g must have g(0)=1 and g(1)=0")
        if np.any(np.diff(gv) > 1e-12):
            raise ValueError("g must be non-increasing in the hosted fraction")

    # ---- constructors -------------------------------------------------
    @staticmethod
    def three_level(M: float, alpha: float, g_alpha: float,
                    c_min: float = 0.0, c_max: float = float("inf")) -> "HostingCosts":
        """The paper's Assumption-4 setting: r in {0, alpha, 1}."""
        return HostingCosts(M=M, levels=(0.0, float(alpha), 1.0),
                            g=(1.0, float(g_alpha), 0.0), c_min=c_min, c_max=c_max)

    @staticmethod
    def two_level(M: float, c_min: float = 0.0, c_max: float = float("inf")) -> "HostingCosts":
        """No partial hosting (the RR / OPT setting of [22])."""
        return HostingCosts(M=M, levels=(0.0, 1.0), g=(1.0, 0.0), c_min=c_min, c_max=c_max)

    # ---- derived ------------------------------------------------------
    @property
    def K(self) -> int:
        return len(self.levels)

    @property
    def alpha(self) -> float:
        """The (single) intermediate level; only defined for the 3-level case."""
        if self.K != 3:
            raise ValueError("alpha only defined for 3-level instances")
        return self.levels[1]

    @property
    def g_alpha(self) -> float:
        if self.K != 3:
            raise ValueError("g_alpha only defined for 3-level instances")
        return self.g[1]

    def levels_arr(self) -> jnp.ndarray:
        return jnp.asarray(self.levels, dtype=default_float_dtype())

    def g_arr(self) -> jnp.ndarray:
        return jnp.asarray(self.g, dtype=default_float_dtype())

    # ---- predicates from the paper ------------------------------------
    def partial_is_useful(self) -> bool:
        """Theorem 1 contrapositive: partial hosting can only help if
        ``alpha + g(alpha) < 1``."""
        if self.K != 3:
            return self.K > 2
        return self.alpha + self.g_alpha < 1.0

    def rr_is_optimal(self) -> bool:
        """Theorem 2(a): alpha-RR matches alpha-OPT when
        ``alpha*c_min + g(alpha) >= 1`` and ``c_min >= 1``."""
        if self.K != 3:
            return self.c_min >= 1.0
        return (self.alpha * self.c_min + self.g_alpha >= 1.0) and self.c_min >= 1.0

    def assumption6_holds(self) -> bool:
        """M > max{1, (1 - g(alpha)) / alpha} (Assumption 6)."""
        if self.K != 3:
            return self.M > 1.0
        return self.M > max(1.0, (1.0 - self.g_alpha) / self.alpha)


# ----------------------------------------------------------------------
# Per-slot cost pieces (vectorised over the level axis K).
# ----------------------------------------------------------------------

def fetch_cost(levels: jnp.ndarray, r_from: jnp.ndarray, r_to: jnp.ndarray, M) -> jnp.ndarray:
    """Actual fetch cost M * (levels[r_to] - levels[r_from])^+ (indices)."""
    delta = levels[r_to] - levels[r_from]
    return M * jnp.maximum(delta, 0.0)


def retro_fetch_cost(levels: jnp.ndarray, r_from: jnp.ndarray, M) -> jnp.ndarray:
    """Retrospective fetch charge used inside Algorithm 1's totalCost:
    M * |levels[j] - levels[r]| for every candidate level j (vector [K]).

    Note the *absolute value* (line 22 of Algorithm 1): the retrospection
    charges hypothetical evictions too, which is the hysteresis that gives
    RetroRenting its competitive ratio. The *actual* system only pays on
    fetches (``fetch_cost`` above)."""
    return M * jnp.abs(levels - levels[r_from])


def rent_cost(levels: jnp.ndarray, c_t) -> jnp.ndarray:
    """Rent cost at every level for one slot: c_t * levels  (vector [K])."""
    return c_t * levels


def service_cost_model1(g: jnp.ndarray, x_t) -> jnp.ndarray:
    """Model 1 service cost at every level: g[k] * x_t (vector [K])."""
    return g * x_t


def service_cost_model2_coupled(g: jnp.ndarray, uniforms: jnp.ndarray, x_t) -> jnp.ndarray:
    """Model 2 realized service cost at every level, with *coupled* randomness.

    Each arriving request i draws one uniform u_i; at hosting level k it is
    forwarded to the cloud (cost 1) iff ``u_i < g[k]``.  Because g is
    non-increasing in the level, the coupling is monotone: a request served
    at the edge under level k is also served under any higher level.  This
    matches the proof of Theorem 5, whose events use the realized S_l
    irrespective of the actual hosting state.

    Args:
      g: [K] service-cost probabilities.
      uniforms: [R] uniforms for the (up to) R requests of this slot.
      x_t: scalar int, number of requests actually arriving (<= R).

    Returns:
      [K] realized service cost at each level.
    """
    R = uniforms.shape[0]
    live = (jnp.arange(R) < x_t)[None, :]          # [1, R]
    fwd = uniforms[None, :] < g[:, None]           # [K, R]
    return jnp.sum(jnp.where(live & fwd, 1.0, 0.0), axis=1)


# ----------------------------------------------------------------------
# Stacked array-form instances (the batched engine's input).
# ----------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class HostingGrid:
    """B hosting instances stacked into arrays, padded to a common K.

    Padding scheme (mixed-K batches): instance ``i`` with ``K_i`` levels
    occupies columns ``[0, K_i)``; columns ``[K_i, K)`` repeat the top level
    (``levels=1.0, g=0.0``) and are marked invalid in ``mask``.  Batched
    policies and the batched DP add a large penalty to invalid columns so a
    padded column is never selected — valid level *indices* therefore mean
    the same thing as in the unpadded per-instance run.

    Attributes:
      M:      [B]    fetch costs — or [B, K, K] *explicit fetch matrices*
              (joint multi-service grids, see below).
      levels: [B, K] hosting levels (padded).
      g:      [B, K] service costs per level (padded).
      mask:   [B, K] True on real levels.

    A ``HostingGrid`` is a pytree, so it can be passed through ``jax.jit`` /
    ``jax.vmap`` directly (vmap over the leading instance axis).

    Matrix-valued M (joint multi-service grids)
    -------------------------------------------
    When ``M`` has a per-instance matrix shape (``M.ndim >= 2``), entry
    ``M[j, j']`` is the *explicit* fetch cost of the transition j -> j'
    instead of the scalar rank-one form ``M * (lv[j'] - lv[j])^+``.  This
    is how ``ServiceSet.joint_grid`` encodes N services sharing one edge:
    states are feasible per-service level combinations, ``levels`` holds
    the TOTAL hosted fraction (so rent ``c_t * levels[j]`` stays correct)
    and the fetch matrix sums the per-service increments.  The simulator's
    chunk kernels, ``evaluate_schedule*`` and every offline-DP driver
    (``dp_fetch_matrix`` passes an explicit matrix through untouched)
    consume such grids transparently; *online* policies do not — they need
    the scalar rank-one structure and raise on matrix grids (host each
    service as its own fleet lane instead, ``core.services``).
    """

    M: jnp.ndarray
    levels: jnp.ndarray
    g: jnp.ndarray
    mask: jnp.ndarray

    # ---- pytree protocol ---------------------------------------------
    def tree_flatten(self):
        return (self.M, self.levels, self.g, self.mask), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # ---- constructors -------------------------------------------------
    @staticmethod
    def from_costs(costs_list: Sequence[HostingCosts],
                   K: Optional[int] = None) -> "HostingGrid":
        """Stack a list of per-instance ``HostingCosts``, padding to max K.

        ``K=`` overrides the padded width (must be >= every instance's K).
        Multi-host fleets need it: each process builds only its own rows,
        so all processes must pad to the GLOBAL max K or their shards
        won't assemble into one global array.
        """
        if not costs_list:
            raise ValueError("need at least one instance")
        dt = default_float_dtype()
        K_min = max(cc.K for cc in costs_list)
        K = K_min if K is None else int(K)
        if K < K_min:
            raise ValueError(f"K={K} < max instance K {K_min}")
        B = len(costs_list)
        M = np.zeros((B,), np.float64)
        lv = np.ones((B, K), np.float64)
        g = np.zeros((B, K), np.float64)
        mask = np.zeros((B, K), bool)
        for i, cc in enumerate(costs_list):
            M[i] = cc.M
            lv[i, :cc.K] = cc.levels
            g[i, :cc.K] = cc.g
            mask[i, :cc.K] = True
        return HostingGrid(M=jnp.asarray(M, dt), levels=jnp.asarray(lv, dt),
                           g=jnp.asarray(g, dt), mask=jnp.asarray(mask))

    # ---- derived ------------------------------------------------------
    @property
    def B(self) -> int:
        return self.levels.shape[0]

    @property
    def K(self) -> int:
        return self.levels.shape[1]

    def k_eff(self) -> jnp.ndarray:
        """[B] number of real levels per instance."""
        return jnp.sum(self.mask.astype(jnp.int32), axis=1)

    def top_index(self) -> jnp.ndarray:
        """[B] index of each instance's real top level (``levels == 1``)."""
        return self.k_eff() - 1

    def restrict_to_endpoints(self) -> "HostingGrid":
        """The no-partial-hosting (RetroRenting / OPT) view: levels (0, 1)
        for every instance, K == 2, nothing padded."""
        dt = default_float_dtype()
        B = self.B
        lv = jnp.tile(jnp.asarray([0.0, 1.0], dt), (B, 1))
        g = jnp.tile(jnp.asarray([1.0, 0.0], dt), (B, 1))
        return HostingGrid(M=self.M, levels=lv, g=g,
                           mask=jnp.ones((B, 2), bool))

    def endpoint_columns(self) -> jnp.ndarray:
        """[B, 2] int32 column indices of the endpoint levels (0, top) in
        this grid — the ``PolicyLane.svc_cols`` map that scores a
        no-partial-hosting lane on the service slab generated once on the
        full grid (same coupled Model-2 uniforms, so the gathered columns
        equal ``endpoint_service`` / direct endpoint-grid generation
        bitwise)."""
        zeros = jnp.zeros((self.B,), jnp.int32)
        return jnp.stack([zeros, self.top_index().astype(jnp.int32)], axis=1)

    def endpoint_service(self, svc: jnp.ndarray) -> jnp.ndarray:
        """Gather a stacked [B, T, K] service matrix down to the endpoint
        levels: [B, T, 2] columns (level 0, top level) — the realized costs a
        no-partial policy sees on the same sample path."""
        top = self.top_index()[:, None, None]                     # [B,1,1]
        hi = jnp.take_along_axis(svc, jnp.broadcast_to(top, svc.shape[:2] + (1,)), axis=2)
        return jnp.concatenate([svc[:, :, :1], hi], axis=2)


# ----------------------------------------------------------------------
# Multi-service sets: N services sharing one edge under a storage-capacity
# constraint (Online Service Caching and Routing at the Edge, 2107.10446).
# ----------------------------------------------------------------------

#: Feasibility slack for the capacity constraint: a state whose hosted
#: fractions sum *exactly* to the capacity is feasible even when the float64
#: sum lands an ulp above it (0.3 + 0.7 style); "just over" by any real
#: margin is excluded.
CAPACITY_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class ServiceSet:
    """N hosting services sharing ONE edge node's storage.

    Each service keeps its own ``HostingCosts`` (levels, g-curve, fetch
    cost); the edge constrains the *sum* of hosted fractions to
    ``capacity`` (default ``None`` = N, i.e. unconstrained — every service
    can be fully hosted at once).  The joint problem's state space is the
    set of feasible per-service level-index tuples; ``joint_grid`` lowers
    it to an ordinary ``HostingGrid`` with a matrix-valued ``M`` so the
    existing offline-DP / schedule-eval engines solve it unchanged.

    The joint state enumeration is row-major over the per-service level
    indices (``np.ndindex`` order), filtered by feasibility — state 0 is
    always the all-off tuple, matching the engine's "start off-edge"
    convention (``dp_frontier0``).
    """

    services: Tuple[HostingCosts, ...]
    capacity: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "services", tuple(self.services))
        if not self.services:
            raise ValueError("need at least one service")
        if self.capacity is not None and self.capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {self.capacity}")
        if not self.joint_states().size:
            raise ValueError(
                f"capacity {self.capacity} excludes even the all-off state")

    # ---- derived ------------------------------------------------------
    @property
    def N(self) -> int:
        return len(self.services)

    @property
    def cap(self) -> float:
        """Effective capacity (``None`` means N: unconstrained)."""
        return float(self.N) if self.capacity is None else float(self.capacity)

    def joint_states(self) -> np.ndarray:
        """[J, N] int32 per-service level indices of every FEASIBLE joint
        state, row-major (all-off first).  Feasible iff the float64 sum of
        hosted fractions is ``<= capacity + CAPACITY_EPS``."""
        Ks = tuple(cc.K for cc in self.services)
        idx = np.array(list(np.ndindex(*Ks)), np.int32).reshape(-1, len(Ks))
        frac = np.zeros((idx.shape[0],), np.float64)
        for n, cc in enumerate(self.services):
            frac += np.asarray(cc.levels, np.float64)[idx[:, n]]
        return idx[frac <= self.cap + CAPACITY_EPS]

    @property
    def J(self) -> int:
        """Number of feasible joint states."""
        return self.joint_states().shape[0]

    def joint_levels(self) -> np.ndarray:
        """[J] float32 TOTAL hosted fraction per joint state (n-ascending
        float32 accumulation; at N=1 this is exactly the service's own
        level vector) — the ``levels`` column of the joint grid, so rent
        ``c_t * levels[j]`` prices the whole edge."""
        idx = self.joint_states()
        tot = np.zeros((idx.shape[0],), np.float32)
        for n, cc in enumerate(self.services):
            tot = tot + np.asarray(cc.levels, np.float32)[idx[:, n]]
        return tot

    def joint_g(self) -> np.ndarray:
        """[J] float32 summed service-cost curve ``sum_n g_n(lv_n[j])`` —
        the Model-1 price of a joint state under a COMMON arrival stream
        (per-service arrivals need per-service slabs; see
        ``services.joint_scenario``)."""
        idx = self.joint_states()
        g = np.zeros((idx.shape[0],), np.float32)
        for n, cc in enumerate(self.services):
            g = g + np.asarray(cc.g, np.float32)[idx[:, n]]
        return g

    def joint_fetch_matrix(self) -> np.ndarray:
        """[J, J] float32 explicit fetch matrix: ``sum_n M_n *
        (lv_n[j'] - lv_n[j])^+`` — per-service terms in ascending n, each
        computed in float32 with exactly ``dp_fetch_matrix``'s op order, so
        at N=1 the matrix is bitwise the rank-one matrix every
        single-service DP driver builds on the fly."""
        idx = self.joint_states()
        fm = None
        for n, cc in enumerate(self.services):
            lvn = np.asarray(cc.levels, np.float32)[idx[:, n]]       # [J]
            term = np.float32(cc.M) * np.maximum(
                lvn[None, :] - lvn[:, None], np.float32(0.0))
            fm = term if fm is None else fm + term
        return fm

    def joint_grid(self) -> "HostingGrid":
        """This set's joint problem as a B=1 matrix-M ``HostingGrid`` (see
        ``joint_hosting_grid`` for stacking several sets)."""
        return joint_hosting_grid([self])


def joint_hosting_grid(sets: Sequence[ServiceSet],
                       J: Optional[int] = None) -> "HostingGrid":
    """Stack B ``ServiceSet`` joint problems into one matrix-M
    ``HostingGrid``, padding mixed state counts to a common J.

    Padding repeats each set's LAST feasible state (levels/g) with
    ``mask=False`` — the DP prices padded states ``+inf`` exactly as it
    prices padded K levels, and their fetch rows/columns are zero (never
    reached: a padded predecessor carries ``+inf`` value).  ``J=``
    overrides the padded width for multi-host assembly, as in
    ``HostingGrid.from_costs``.
    """
    if not sets:
        raise ValueError("need at least one service set")
    dt = default_float_dtype()
    J_min = max(ss.J for ss in sets)
    J = J_min if J is None else int(J)
    if J < J_min:
        raise ValueError(f"J={J} < max set J {J_min}")
    B = len(sets)
    M = np.zeros((B, J, J), np.float32)
    lv = np.ones((B, J), np.float32)
    g = np.zeros((B, J), np.float32)
    mask = np.zeros((B, J), bool)
    for i, ss in enumerate(sets):
        Ji = ss.J
        M[i, :Ji, :Ji] = ss.joint_fetch_matrix()
        lv[i, :Ji] = ss.joint_levels()
        lv[i, Ji:] = lv[i, Ji - 1]
        g[i, :Ji] = ss.joint_g()
        g[i, Ji:] = g[i, Ji - 1]
        mask[i, :Ji] = True
    return HostingGrid(M=jnp.asarray(M, dt), levels=jnp.asarray(lv, dt),
                       g=jnp.asarray(g, dt), mask=jnp.asarray(mask))


def per_slot_cost_matrix(costs: HostingCosts, x: jnp.ndarray, c: jnp.ndarray,
                         svc: jnp.ndarray | None = None) -> jnp.ndarray:
    """w[t, k] = rent + service cost of *holding* level k during slot t.

    Args:
      x: [T] request counts.
      c: [T] rent costs.
      svc: optional [T, K] realized service costs (Model 2). If None, Model 1
        deterministic costs g[k] * x_t are used.
    Returns:
      [T, K] float array.
    """
    lv = jnp.asarray(costs.levels, dtype=jnp.float32)
    gv = jnp.asarray(costs.g, dtype=jnp.float32)
    rentm = c[:, None].astype(jnp.float32) * lv[None, :]
    if svc is None:
        svcm = x[:, None].astype(jnp.float32) * gv[None, :]
    else:
        svcm = svc.astype(jnp.float32)
    return rentm + svcm
