"""Shortest-path query service and its g(alpha) curve (paper §7.2).

The paper builds a navigation service from the Geolife GPS trajectories:
queries are (source, destination) pairs; the service's database is the set
of all shortest paths; hosting a fraction of the database at the edge lets
the edge answer a query iff both endpoints lie on a cached path.  Cache
contents are chosen greedily by *normalised hit rate* (hits per node of
path length) — a fractional-knapsack policy — using the first three years
of queries; the served-fraction curve is evaluated on the fourth year.

The Geolife archive is not available offline, so we reproduce the exact
pipeline on a synthetic city: a perturbed grid road network with random
edge weights and Zipf-popular landmark endpoints, Dijkstra shortest paths,
the same normalised-hit-rate knapsack, and a train/test split.  The curve
shape (concave, saturating below 1 because test queries include unseen
endpoints — footnote 1 of the paper) matches Fig. 23 qualitatively; the
anchor (alpha=0.16 -> g≈0.76) is used as a calibration check in the tests.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


@dataclasses.dataclass
class RoadNetwork:
    n_nodes: int
    adj: list                     # adj[u] = list[(v, w)]


def make_city(n_side: int = 20, seed: int = 0, drop: float = 0.1) -> RoadNetwork:
    """Perturbed grid with random weights; ``drop`` fraction of edges removed
    (one-way streets / rivers) while keeping connectivity likely."""
    rng = np.random.default_rng(seed)
    n = n_side * n_side
    adj = [[] for _ in range(n)]

    def nid(i, j):
        return i * n_side + j

    for i in range(n_side):
        for j in range(n_side):
            for di, dj in ((0, 1), (1, 0)):
                ii, jj = i + di, j + dj
                if ii < n_side and jj < n_side and rng.random() > drop:
                    w = float(rng.uniform(0.5, 2.0))
                    adj[nid(i, j)].append((nid(ii, jj), w))
                    adj[nid(ii, jj)].append((nid(i, j), w))
    return RoadNetwork(n, adj)


def dijkstra_path(net: RoadNetwork, src: int, dst: int):
    dist = {src: 0.0}
    prev = {}
    pq = [(0.0, src)]
    seen = set()
    while pq:
        d, u = heapq.heappop(pq)
        if u in seen:
            continue
        seen.add(u)
        if u == dst:
            break
        for v, w in net.adj[u]:
            nd = d + w
            if nd < dist.get(v, np.inf):
                dist[v] = nd
                prev[v] = u
                heapq.heappush(pq, (nd, v))
    if dst not in seen:
        return None
    path = [dst]
    while path[-1] != src:
        path.append(prev[path[-1]])
    return path[::-1]


def city_landmarks(net: RoadNetwork, n_landmarks: int = 30, seed: int = 100):
    """The city's fixed popular places — shared by every 'year' of queries
    (the paper's train/test years see the same city)."""
    rng = np.random.default_rng(seed)
    return rng.choice(net.n_nodes, size=n_landmarks, replace=False)


def sample_queries(net: RoadNetwork, n_queries: int, seed: int = 1,
                   zipf_s: float = 0.8, landmarks=None, n_landmarks: int = 100):
    """Queries with Zipf-popular landmark endpoints (commuting patterns)."""
    rng = np.random.default_rng(seed)
    if landmarks is None:
        landmarks = city_landmarks(net, n_landmarks)
    n_landmarks = len(landmarks)
    p = 1.0 / np.arange(1, n_landmarks + 1) ** zipf_s
    p /= p.sum()
    src = landmarks[rng.choice(n_landmarks, size=n_queries, p=p)]
    dst = landmarks[rng.choice(n_landmarks, size=n_queries, p=p)]
    keep = src != dst
    return np.stack([src[keep], dst[keep]], axis=1)


@dataclasses.dataclass
class PathDB:
    paths: list                   # list[np.ndarray] of node ids
    node_sets: list               # list[frozenset]
    sizes: np.ndarray             # nodes per path
    total_nodes: int


def build_path_db(net: RoadNetwork, queries: np.ndarray) -> PathDB:
    """One shortest path per distinct query (the service database)."""
    seen = {}
    paths, sets = [], []
    for s, d in queries:
        key = (int(s), int(d))
        if key in seen:
            continue
        p = dijkstra_path(net, int(s), int(d))
        if p is None:
            continue
        seen[key] = len(paths)
        paths.append(np.asarray(p))
        sets.append(frozenset(p))
    sizes = np.array([len(p) for p in paths], np.int64)
    return PathDB(paths, sets, sizes, int(sizes.sum()))


def hit(db_sets, s, d, cached_idx) -> bool:
    for i in cached_idx:
        st = db_sets[i]
        if s in st and d in st:
            return True
    return False


def knapsack_order(db: PathDB, train_queries: np.ndarray) -> np.ndarray:
    """Greedy order by normalised hit rate = (#train hits on path)/(#nodes)."""
    hits = np.zeros(len(db.paths), np.float64)
    for s, d in train_queries:
        for i, st in enumerate(db.node_sets):
            if s in st and d in st:
                hits[i] += 1.0
    score = hits / np.maximum(db.sizes, 1)
    return np.argsort(-score)


def gcurve_from_city(n_side: int = 16, n_train: int = 3000, n_test: int = 1000,
                     alphas=None, seed: int = 0):
    """End-to-end §7.2 pipeline; returns (alphas, g_values, cache order).

    alpha is measured as cached-nodes / total-db-nodes, exactly as the paper
    measures cache size."""
    if alphas is None:
        alphas = np.linspace(0.05, 1.0, 20)
    net = make_city(n_side, seed=seed)
    lm = city_landmarks(net, n_landmarks=100, seed=seed + 100)
    train_q = sample_queries(net, n_train, seed=seed + 1, landmarks=lm)
    test_q = sample_queries(net, n_test, seed=seed + 2, landmarks=lm)
    db = build_path_db(net, train_q)
    order = knapsack_order(db, train_q)
    csize = np.cumsum(db.sizes[order])
    gs = []
    # precompute per-test-query the first cache rank that serves it
    first_rank = np.full(len(test_q), np.inf)
    for qi, (s, d) in enumerate(test_q):
        for rank, i in enumerate(order):
            st = db.node_sets[i]
            if s in st and d in st:
                first_rank[qi] = rank
                break
    for a in alphas:
        budget = a * db.total_nodes
        k = int(np.searchsorted(csize, budget, side="right"))  # paths cached
        served = float(np.mean(first_rank < k))
        gs.append(1.0 - served)
    return np.asarray(alphas), np.asarray(gs), order
