"""Policy interface for the slotted hosting simulator.

An *online* policy is a pair of pure functions:

    state0 = policy.init()
    state' = policy.step(state, obs)     # jax-traceable

where ``obs = SlotObs(x, c, svc)`` carries this slot's arrivals, rent cost
and the per-level service-cost vector (deterministic ``g*x`` for Model 1,
realized for Model 2), plus an optional side-channel (e.g. Markov state for
MDP/ABC baselines).  ``state["r"]`` is the index (into ``costs.levels``) of
the level the policy will hold during the *next* slot.  The simulator runs
policies under ``jax.lax.scan``.

Sequence of events in a slot (paper §2.5): arrivals happen and are served at
the current level; the provider announces the next rent; the policy picks
``r_{t+1}``; any fetch for the increment is paid now.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax.numpy as jnp

from repro.core.costs import HostingCosts


class SlotObs(NamedTuple):
    x: jnp.ndarray        # scalar int32: arrivals this slot
    c: jnp.ndarray        # scalar float: rent this slot
    svc: jnp.ndarray      # [K]: realized service cost at every level this slot
    side: jnp.ndarray     # scalar int32: optional side info (e.g. Markov state)


State = Dict[str, Any]


class OnlinePolicy:
    """Base class; subclasses must be immutable (used inside jit)."""

    def __init__(self, costs: HostingCosts):
        self.costs = costs

    @property
    def name(self) -> str:
        return type(self).__name__

    def init(self) -> State:  # pragma: no cover - interface
        raise NotImplementedError

    def step(self, state: State, obs: SlotObs) -> State:  # pragma: no cover
        raise NotImplementedError
