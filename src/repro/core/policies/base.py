"""Policy interface for the slotted hosting simulator.

An *online* policy is, at bottom, a pair of **pure functions** over a pytree
of array parameters:

    state0 = init_fn(params)
    state' = step_fn(params, state, obs)     # jax-traceable, no closure state

where ``obs = SlotObs(x, c, svc, side)`` carries this slot's arrivals, rent
cost and the per-level service-cost vector (deterministic ``g*x`` for
Model 1, realized for Model 2), plus an optional side-channel (e.g. Markov
state for MDP/ABC baselines).  ``state["r"]`` is the index (into the level
grid) of the level the policy will hold during the *next* slot.

Because ``params`` is a pytree of arrays and both functions are pure, a
policy family vmaps over the instance axis: stack B per-instance params
(leading [B] axis on every leaf) and the whole horizon runs as one
``jit(vmap(scan))`` — see ``simulator.run_policy_batch``.

``OnlinePolicy`` is the thin class wrapper kept for API compatibility: it
binds ``params`` built from one ``HostingCosts`` and forwards ``init`` /
``step`` to the pure pair.  Legacy subclasses that override ``init``/``step``
directly (without defining ``init_fn``/``step_fn``) keep working — the
simulator falls back to a closure over the bound methods.

Mixed-horizon (fleet) convention
--------------------------------
Policies never see horizon padding: when a ``core.fleet.FleetBatch`` stacks
instances with different horizons T_i, the engine calls ``step_fn`` on every
(padded) slot and then applies ``freeze_invalid`` — on slots at or past the
instance's own T the proposed state is discarded and the previous state kept,
and every cost accumulator receives exactly ``0.0``.  A policy therefore
needs no awareness of T at all; its only obligations are the existing ones
(pure, pytree state with stable structure, ``state["r"]`` the next level
index).  Concrete policies expose ``.fleet(...)`` classmethods mirroring
``.batch(...)`` that bind stacked params from a ``FleetBatch``.

Policy fan-out (multi-policy) convention
----------------------------------------
``core.fleet.run_fleet`` accepts a *sequence* of policies — the fan-out
axis.  Each entry is a **lane**: a ``PolicyFns`` (scored on the fleet's own
grid) or a ``PolicyLane`` binding the pair to its *own* accounting grid
(e.g. the endpoint restriction for RR) plus, for Model-2 scenarios, the
``svc_cols`` column map that gathers the lane's per-level service costs out
of the slab generated once on the fleet grid.  Lane states are
**heterogeneous** — different policies carry different state pytrees over
different K — so the fan-out carry is a *tuple of per-lane (state, acc)
pytrees*, never a stacked array: a Python tuple is itself a pytree, which
is exactly what lets ``freeze_invalid`` (applied inside each lane's own
``sim_chunk_core`` call) keep masking per policy with zero shared
structure.  See ``simulator.sim_chunk_lanes`` and the "Policy fan-out"
section of ``core/fleet.py``.

Sequence of events in a slot (paper §2.5): arrivals happen and are served at
the current level; the provider announces the next rent; the policy picks
``r_{t+1}``; any fetch for the increment is paid now.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.costs import HostingCosts


class SlotObs(NamedTuple):
    x: jnp.ndarray        # scalar int32: arrivals this slot
    c: jnp.ndarray        # scalar float: rent this slot
    svc: jnp.ndarray      # [K]: realized service cost at every level this slot
    side: jnp.ndarray     # scalar int32: optional side info (e.g. Markov state)


State = Dict[str, Any]


def freeze_invalid(valid, new_state: State, old_state: State) -> State:
    """The mixed-horizon masking rule (see module docstring): keep
    ``new_state`` on valid slots, the unchanged ``old_state`` on slots past
    the instance's own horizon.  On valid slots ``jnp.where`` *selects* (it
    never recomputes), so a uniform-horizon run is bitwise unchanged by the
    mask."""
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(valid, n, o), new_state, old_state)


class PolicyFns(NamedTuple):
    """A policy in pure-function form, ready for scan/vmap.

    ``params`` is a pytree of arrays.  Per-instance shapes give a single
    simulation; add a leading [B] axis to every leaf (see the ``.batch``
    classmethods on the concrete policies) and ``run_policy_batch`` vmaps
    the same ``init_fn``/``step_fn`` over the instance axis.
    """

    name: str
    init_fn: Callable[[Any], State]
    step_fn: Callable[[Any, State, SlotObs], State]
    params: Any


class PolicyLane(NamedTuple):
    """ONE entry of the policy fan-out axis (see module docstring).

    ``grid=None`` means the lane runs on the fleet's own grid.  A lane with
    its own grid (same B, its own K/levels/g — e.g.
    ``grid.restrict_to_endpoints()`` for RR) must also say how it prices
    service under a Model-2 scenario: ``svc_cols`` is a [B, K_lane] int map
    gathering the lane's columns out of the [chunk, K_fleet] svc slab that
    the scenario generates ONCE on the fleet grid (coupled Model-2 uniforms
    make the gathered columns bitwise equal to generating on the lane grid
    directly — ``scenarios.model2_service``).  Model-1 lanes leave
    ``svc_cols=None`` and price ``g_lane * x`` from their own g row.
    """

    fns: PolicyFns
    grid: Optional[Any] = None       # HostingGrid; None -> fleet.grid
    svc_cols: Optional[Any] = None   # [B, K_lane] int32 columns into fleet svc

    @property
    def name(self) -> str:
        return self.fns.name


def as_policy_lanes(policy) -> Optional[tuple]:
    """``None`` for a single ``PolicyFns`` (the classic path); otherwise the
    normalized tuple of ``PolicyLane`` entries of a fan-out request."""
    if isinstance(policy, PolicyFns):
        return None
    if isinstance(policy, PolicyLane):
        return (policy,)
    lanes = []
    for entry in policy:
        if isinstance(entry, PolicyLane):
            lanes.append(entry)
        elif isinstance(entry, PolicyFns):
            lanes.append(PolicyLane(entry))
        else:
            raise TypeError(
                f"policy fan-out entries must be PolicyFns or PolicyLane, "
                f"got {type(entry).__name__}")
    if not lanes:
        raise ValueError("policy fan-out needs at least one lane")
    return tuple(lanes)


class OnlinePolicy:
    """Thin class wrapper over a pure ``(init_fn, step_fn)`` pair.

    Subclasses define ``init_fn`` / ``step_fn`` as staticmethods plus a
    ``params`` property; they must stay immutable (used inside jit).
    """

    #: pure (params) -> state; None means the subclass overrides init()
    init_fn: Callable[[Any], State] | None = None
    #: pure (params, state, obs) -> state; None means the subclass overrides step()
    step_fn: Callable[[Any, State, SlotObs], State] | None = None

    def __init__(self, costs: HostingCosts):
        self.costs = costs

    @property
    def name(self) -> str:
        return type(self).__name__

    @property
    def params(self) -> Any:
        """Pytree of arrays parameterising the pure pair for ``self.costs``."""
        raise NotImplementedError

    def fns(self) -> PolicyFns:
        """This policy as a ``PolicyFns`` (falls back to bound methods for
        legacy subclasses that never defined the pure pair)."""
        cls = type(self)
        if cls.init_fn is not None and cls.step_fn is not None:
            return PolicyFns(self.name, cls.init_fn, cls.step_fn, self.params)
        return PolicyFns(self.name,
                         lambda _params: self.init(),
                         lambda _params, state, obs: self.step(state, obs),
                         None)

    def init(self) -> State:
        if type(self).init_fn is None:  # pragma: no cover - interface
            raise NotImplementedError
        return type(self).init_fn(self.params)

    def step(self, state: State, obs: SlotObs) -> State:
        if type(self).step_fn is None:  # pragma: no cover - interface
            raise NotImplementedError
        return type(self).step_fn(self.params, state, obs)
