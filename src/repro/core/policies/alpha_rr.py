"""alpha-RetroRenting (Algorithm 1 of the paper), in two implementations.

1. ``AlphaRR`` — the O(1)-per-slot / O(K)-state formulation (Remark 3, via
   the technique of [19]/[22]).  The key identity: with ``w_t[k]`` the
   rent+service cost of holding level k during slot t and ``r`` the current
   level, Algorithm 1's candidate comparison collapses to a *minimum suffix
   sum*.  For candidate level j and switch slot ``tau`` in the open window
   ``(t_recent, t)``:

       totalCost(R_j^{(tau)}, I_t) - totalCost(all-r, I_t)
           = M * |lv[j] - lv[r]|  +  sum_{l=tau+1}^{t} (w_l[j] - w_l[r])

   so  minCost(j) - minCost(r) = M|lv_j - lv_r| + S_j(t)  where

       S_j(t) = min_{s in [t_recent+2, t]} sum_{l=s}^{t} d_l[j],
       d_l[j] = w_l[j] - w_l[r]

   and S_j obeys the scan recursion ``S_j(t) = d_t[j] + min(0, S_j(t-1))``
   with ``S_j = +inf`` right after a switch (the window must contain at
   least one old-level slot and one new-level slot, so the first candidate
   switch point is t_recent+1, i.e. the first accumulated slot is
   t_recent+2).  Algorithm 1 switches to ``argmin_j`` when the margin is
   negative.  Note the retrospective fetch charge uses ``|.|`` (line 22),
   while the real system pays only on increments — that asymmetry is
   RetroRenting's hysteresis and we keep it faithfully.

   ``AlphaRR`` works for any number of levels: K=2 gives RetroRenting [22]
   (policy "RR" in the figures), K=3 the paper's alpha-RR, K>3 multiple-RR
   (Figs 7/8).

2. ``alpha_rr_literal`` — a plain-numpy transliteration of Algorithm 1
   (recomputing totalCost over the whole window each slot, O(t) work).  It
   exists to *prove the O(1) version equivalent* (property test
   ``tests/test_policies.py::test_alpha_rr_scan_matches_literal``).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.costs import HostingCosts, HostingGrid
from repro.core.policies.base import (OnlinePolicy, PolicyFns, PolicyLane,
                                      SlotObs, State)

_BIG = jnp.float32(3.4e38)  # acts as +inf for min(0, .) gating
_TIE_EPS = 1e-6             # ties break toward staying (no spurious fetch)


# ----------------------------------------------------------------------
# Pure (init_fn, step_fn) pair.  ``params`` leaves: M scalar, levels [K],
# mask [K] (True on real levels — padded columns of a mixed-K batch get a
# _BIG margin so they are never selected).  Stacking a leading [B] axis on
# every leaf makes the same pair vmap over instances.
# ----------------------------------------------------------------------

def alpha_rr_params(costs: HostingCosts) -> dict:
    return {
        "M": jnp.asarray(costs.M, jnp.float32),
        "levels": jnp.asarray(costs.levels, jnp.float32),
        "mask": jnp.ones((costs.K,), bool),
    }


def alpha_rr_grid_params(grid: HostingGrid) -> dict:
    """Stacked [B]-leading params for ``run_policy_batch``."""
    if jnp.ndim(grid.M) > 1:
        raise ValueError(
            "online policies need a scalar per-instance fetch cost; joint "
            "multi-service grids (matrix-valued M) are for the offline DP "
            "and schedule evaluation only — run each service as its own "
            "fleet lane instead (core.services.run_fleet_services)")
    return {
        "M": grid.M.astype(jnp.float32),
        "levels": grid.levels.astype(jnp.float32),
        "mask": grid.mask,
    }


def alpha_rr_init(params) -> State:
    K = params["levels"].shape[-1]
    return {
        "r": jnp.asarray(0, jnp.int32),            # level index held next slot
        "S": jnp.full((K,), _BIG, jnp.float32),    # suffix minima vs current level
        "age": jnp.asarray(0, jnp.int32),          # slots since last switch
    }


def alpha_rr_step(params, state: State, obs: SlotObs) -> State:
    # NB: index-r selections are phrased as one-hot where/sum/min instead of
    # w[r]-style gathers and .at[r].set scatters.  Bit-identical (the sum has
    # exactly one nonzero term), but the elementwise form vectorises across
    # the vmapped instance axis where batched gathers do not (~3x per-slot
    # throughput on CPU for a 64-instance batch).
    lv = params["levels"]
    mask = params["mask"]
    K = lv.shape[-1]
    r = state["r"]
    onehot_r = jnp.arange(K) == r
    age = state["age"] + 1                          # this slot's index - t_recent

    # per-level cost of this slot; d relative to the held level
    w = obs.c * lv + obs.svc                        # [K]
    d = w - jnp.sum(jnp.where(onehot_r, w, 0.0))

    # accumulate suffix minima only once the candidate window is non-empty
    S_prev = state["S"]
    S_new = d + jnp.minimum(0.0, S_prev)
    S = jnp.where(age >= 2, S_new, S_prev)

    # margins: retrospective fetch charge uses |.| per Algorithm 1 line 22
    lv_r = jnp.sum(jnp.where(onehot_r, lv, 0.0))
    margins = params["M"] * jnp.abs(lv - lv_r) + jnp.where(age >= 2, S, _BIG)
    margins = jnp.where(mask, margins, _BIG)        # padded levels never win
    margins = jnp.where(onehot_r, 0.0, margins)
    j_star = jnp.argmin(margins + _TIE_EPS * ~onehot_r)
    margin_star = jnp.sum(jnp.where(jnp.arange(K) == j_star, margins, 0.0))
    switch = margin_star < -0.0
    r_next = jnp.where(switch, j_star, r).astype(jnp.int32)

    return {
        "r": r_next,
        "S": jnp.where(switch, jnp.full((K,), _BIG, jnp.float32), S),
        "age": jnp.where(switch, jnp.asarray(0, jnp.int32), age),
    }


class AlphaRR(OnlinePolicy):
    """O(1)-per-slot alpha-RetroRenting over an arbitrary level grid."""

    init_fn = staticmethod(alpha_rr_init)
    step_fn = staticmethod(alpha_rr_step)

    @property
    def params(self):
        return alpha_rr_params(self.costs)

    @classmethod
    def batch(cls, grid: HostingGrid) -> PolicyFns:
        """The whole grid as one vmap-able policy batch."""
        return PolicyFns("alpha-RR", alpha_rr_init, alpha_rr_step,
                         alpha_rr_grid_params(grid))

    @classmethod
    def fleet(cls, fleet: "FleetBatch") -> PolicyFns:  # noqa: F821
        """Policy batch for a mixed-horizon fleet (``core.fleet.run_fleet``).
        alpha-RR carries no horizon state, so fleet params == batch params;
        the engine handles per-instance T masking."""
        return cls.batch(fleet.grid)

    @classmethod
    def fleet_lane(cls, fleet: "FleetBatch",  # noqa: F821
                   with_svc: bool = False) -> PolicyLane:
        """This policy as ONE entry of ``run_fleet``'s policy fan-out axis.
        alpha-RR scores on the fleet's own grid, so the lane carries no
        grid/column map of its own (the shared svc slab applies directly)."""
        del with_svc
        return PolicyLane(cls.fleet(fleet))


class RetroRenting(AlphaRR):
    """RR of [22]: AlphaRR restricted to levels (0, 1).  Provided as a named
    class so benchmark legends match the paper."""

    def __init__(self, costs: HostingCosts):
        super().__init__(HostingCosts.two_level(costs.M, costs.c_min, costs.c_max))

    @classmethod
    def batch(cls, grid: HostingGrid) -> PolicyFns:
        """RR over every instance of ``grid``: same pure pair on the 2-level
        endpoint restriction (level indices are then 0 = off, 1 = full)."""
        g2 = grid.restrict_to_endpoints()
        return PolicyFns("RR", alpha_rr_init, alpha_rr_step,
                         alpha_rr_grid_params(g2))

    @classmethod
    def fleet(cls, fleet: "FleetBatch") -> PolicyFns:  # noqa: F821
        """RR policy batch for a fleet; run it on
        ``fleet.restrict_to_endpoints()`` (the accounting grid must match)."""
        return cls.batch(fleet.grid)

    @classmethod
    def fleet_lane(cls, fleet: "FleetBatch",  # noqa: F821
                   with_svc: bool = False) -> PolicyLane:
        """RR as a fan-out lane on its OWN endpoint accounting grid; under a
        Model-2 svc slab (``with_svc=True``) the lane gathers its two
        columns out of the fleet-grid slab (bitwise equal to generating on
        the endpoint grid directly — coupled uniforms)."""
        grid = fleet.grid
        return PolicyLane(cls.fleet(fleet), grid=grid.restrict_to_endpoints(),
                          svc_cols=grid.endpoint_columns() if with_svc
                          else None)


# ----------------------------------------------------------------------
# Literal Algorithm 1 (numpy, O(t) per slot) — test oracle.
# ----------------------------------------------------------------------

def alpha_rr_literal(costs: HostingCosts, x: np.ndarray, c: np.ndarray,
                     svc: np.ndarray | None = None) -> np.ndarray:
    """Run Algorithm 1 exactly as printed; returns r_hist (level index held
    during each slot, length T).

    ``svc`` is the [T, K] realized service-cost matrix; None means Model 1
    (g[k] * x_t), matching the printed totalCost which uses x_j * g(R(j)).
    """
    lv = np.asarray(costs.levels, np.float64)
    g = np.asarray(costs.g, np.float64)
    T = len(x)
    K = costs.K
    if svc is None:
        svc = np.asarray(x, np.float64)[:, None] * g[None, :]
    svc = np.asarray(svc, np.float64)
    c = np.asarray(c, np.float64)

    def total_cost(seq_levels: np.ndarray, lo: int, hi: int) -> float:
        """Cost of holding seq_levels[t] during slots lo..hi (inclusive,
        0-based), with Algorithm-1's |delta| fetch charges inside the window."""
        idx = np.arange(lo, hi + 1)
        ks = seq_levels
        cost = float(np.sum(c[idx] * lv[ks]) + np.sum(svc[idx, ks]))
        cost += costs.M * float(np.sum(np.abs(lv[ks[1:]] - lv[ks[:-1]])))
        return cost

    r_hist = np.zeros(T, np.int64)
    r = 0          # r_1 = 0
    t_recent = 0   # 1-based slot of last change; 0 = before the horizon
    for t in range(1, T + 1):     # 1-based slots
        r_hist[t - 1] = r
        lo, hi = t_recent, t - 1  # 0-based window [t_recent+1 .. t] -> [lo..hi]
        n = hi - lo + 1           # t - t_recent
        best = np.full(K, np.inf)
        for j in range(K):
            # candidates: tau - t_recent slots at r then the rest at j,
            # tau in (t_recent, t) open, i.e. 1 <= stay < n
            for stay in range(1, n):
                seq = np.concatenate([np.full(stay, r), np.full(n - stay, j)])
                v = total_cost(seq, lo, hi)
                if v < best[j]:
                    best[j] = v
        best[r] = min(best[r], total_cost(np.full(n, r), lo, hi))
        j_star = int(np.argmin(best + _TIE_EPS * (np.arange(K) != r)))
        if j_star != r and best[j_star] < best[r]:
            r = j_star
            t_recent = t
    return r_hist


def alpha_rr_hosting(costs: HostingCosts, x, c, svc=None) -> jnp.ndarray:
    """Convenience: run the scan policy over full arrays; returns r_hist [T]."""
    from repro.core.simulator import run_policy
    return run_policy(AlphaRR(costs), costs, x, c, svc).r_hist
