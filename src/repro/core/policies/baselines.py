"""Baseline policies from the paper's simulation sections.

* ``StaticPolicy`` — hold one level forever (never/always-partial/always-full).
* ``MDPPolicy`` — §7.1.2's "MDP policy": knows the arrival statistics (the
  Gilbert-Elliot chain and per-state rates) and the mean rent cost; solves
  the average-cost MDP over (chain state, hosting level) by relative value
  iteration and plays the resulting stationary policy, observing the current
  chain state.
* ``ABCPolicy`` — "Arrival Based Caching" [26]: decides from the *current
  slot's arrival rate* and the arrival statistics only.  Our operational
  reading (the reference is summarised in one sentence in the paper): infer
  the chain state from x_t, then pick the level minimising the expected
  per-slot cost with the fetch price amortised over the expected sojourn of
  the inferred state:

      r' = argmin_k  lv_k * c_mean + g_k * rate(s_hat)
                     + M * (lv_k - lv_r)^+ / sojourn(s_hat).

Both baselines get statistics that alpha-RR never sees — the paper's point
(Figs 17-22) is that alpha-RR is competitive with them anyway.

All three are pure ``(init_fn, step_fn)`` pairs over array params (a
stationary decision table for MDP/ABC), so they vmap over a stacked
``HostingGrid`` via their ``.batch`` classmethods just like ``AlphaRR``.
"""
from __future__ import annotations

import itertools
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import GilbertElliot
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.policies.base import OnlinePolicy, PolicyFns, SlotObs, State


# ----------------------------------------------------------------------
# StaticPolicy
# ----------------------------------------------------------------------

def static_init(params) -> State:
    # slot 1 must start at 0 (service initially not hosted); we upgrade
    # to the target level at the first decision point.
    return {"r": jnp.asarray(0, jnp.int32)}


def static_step(params, state: State, obs: SlotObs) -> State:
    return {"r": params["level_idx"]}


class StaticPolicy(OnlinePolicy):
    init_fn = staticmethod(static_init)
    step_fn = staticmethod(static_step)

    def __init__(self, costs: HostingCosts, level_idx: int):
        super().__init__(costs)
        self.level_idx = int(level_idx)

    @property
    def name(self):
        return f"static[{self.costs.levels[self.level_idx]}]"

    @property
    def params(self):
        return {"level_idx": jnp.asarray(self.level_idx, jnp.int32)}

    @classmethod
    def batch(cls, grid: HostingGrid, level_idx) -> PolicyFns:
        """``level_idx`` is a scalar or a [B] array of per-instance target
        levels (e.g. ``grid.top_index()`` for always-full on mixed-K grids)."""
        idx = jnp.broadcast_to(jnp.asarray(level_idx, jnp.int32), (grid.B,))
        return PolicyFns("static", static_init, static_step,
                         {"level_idx": idx})

    @classmethod
    def fleet(cls, fleet: "FleetBatch", level_idx) -> PolicyFns:  # noqa: F821
        return cls.batch(fleet.grid, level_idx)


# ----------------------------------------------------------------------
# MDP / ABC: stationary decision tables pi[s, k] -> k'.
# ----------------------------------------------------------------------

def _expected_svc_rates(costs: HostingCosts, rates: np.ndarray) -> np.ndarray:
    """E[service cost | chain state s, level k] = g_k * rate_s  (Model 1 and
    Model 2 agree in expectation)."""
    g = np.asarray(costs.g, np.float64)
    return rates[:, None] * g[None, :]          # [S, K]


def solve_mdp(costs: HostingCosts, ge: GilbertElliot, c_mean: float,
              iters: int = 2000, tol: float = 1e-10) -> np.ndarray:
    """Relative value iteration for the average-cost MDP.

    States: (chain s in {0=L, 1=H}, level k).  Action: next level k'.
    Timing: choose k' at the end of a slot knowing s_t; pay fetch now; next
    slot's service cost is drawn at s_{t+1} ~ P(.|s_t).

    Returns pi [S, K] -> next-level index.
    """
    lv = np.asarray(costs.levels, np.float64)
    K = costs.K
    P = np.array([[1 - ge.p_lh, ge.p_lh], [ge.p_hl, 1 - ge.p_hl]])  # [s, s']
    rates = np.array([ge.rate_l, ge.rate_h])
    svc = _expected_svc_rates(costs, rates)     # [S, K]
    hold = c_mean * lv[None, :] + svc           # E[cost | s', k'] for holding
    fetch = costs.M * np.maximum(lv[None, :] - lv[:, None], 0.0)  # [k, k']

    V = np.zeros((2, K))
    for _ in range(iters):
        # Q[s, k, k'] = fetch[k,k'] + sum_s' P[s,s'] (hold[s',k'] + V[s',k'])
        cont = np.einsum("st,tk->sk", P, hold + V)   # [s, k']
        Q = fetch[None, :, :] + cont[:, None, :]
        V_new = Q.min(axis=2)
        V_new = V_new - V_new[0, 0]                  # relative VI normalisation
        if np.max(np.abs(V_new - V)) < tol:
            V = V_new
            break
        V = V_new
    cont = np.einsum("st,tk->sk", P, hold + V)
    Q = fetch[None, :, :] + cont[:, None, :]
    return np.argmin(Q, axis=2)                      # [S, K]


def solve_abc(costs: HostingCosts, ge: GilbertElliot, c_mean: float) -> np.ndarray:
    """ABC's stationary table (see module docstring); returns pi [S, K]."""
    rates = np.array([ge.rate_l, ge.rate_h])
    sojourn = np.array([1.0 / max(ge.p_lh, 1e-9), 1.0 / max(ge.p_hl, 1e-9)])
    lv = np.asarray(costs.levels, np.float64)
    g = np.asarray(costs.g, np.float64)
    # score[s, k, k'] of choosing k' at current level k in inferred state s
    hold = float(c_mean) * lv[None, :] + rates[:, None] * g[None, :]
    fetch = costs.M * np.maximum(lv[None, :] - lv[:, None], 0.0)
    score = hold[:, None, :] + fetch[None, :, :] / sojourn[:, None, None]
    return np.argmin(score, axis=2)                  # [S, K]


def _pad_tables(tables: Sequence[np.ndarray], K: int) -> jnp.ndarray:
    """Stack per-instance [S, K_i] decision tables, padding the level axis.
    Padded entries map to themselves so they are inert (never reached anyway:
    the state starts at 0 and valid tables map valid -> valid)."""
    out = []
    for pi in tables:
        S, Ki = pi.shape
        pad = np.tile(np.arange(K)[None, :], (S, 1))
        pad[:, :Ki] = pi
        out.append(pad)
    return jnp.asarray(np.stack(out), jnp.int32)     # [B, S, K]


def table_init(params) -> State:
    return {"r": jnp.asarray(0, jnp.int32)}


def mdp_step(params, state: State, obs: SlotObs) -> State:
    pi = params["pi"]
    s = jnp.clip(obs.side, 0, pi.shape[-2] - 1)
    return {"r": pi[s, state["r"]]}


def abc_step(params, state: State, obs: SlotObs) -> State:
    pi = params["pi"]
    s_hat = (obs.x.astype(jnp.float32) >= params["x_threshold"]).astype(jnp.int32)
    return {"r": pi[s_hat, state["r"]]}


class MDPPolicy(OnlinePolicy):
    """Plays the precomputed average-cost-optimal stationary policy; observes
    the chain state via ``obs.side`` (0=L, 1=H)."""

    init_fn = staticmethod(table_init)
    step_fn = staticmethod(mdp_step)

    def __init__(self, costs: HostingCosts, ge: GilbertElliot, c_mean: float):
        super().__init__(costs)
        self.pi = jnp.asarray(solve_mdp(costs, ge, c_mean), jnp.int32)  # [S, K]

    @property
    def params(self):
        return {"pi": self.pi}

    @classmethod
    def batch(cls, grid: HostingGrid, costs_list: Sequence[HostingCosts],
              ges: Sequence[GilbertElliot], c_means: Sequence[float]) -> PolicyFns:
        """Solve each instance's MDP on the host, stack the tables."""
        tables = [solve_mdp(cc, ge, cm)
                  for cc, ge, cm in zip(costs_list, ges, c_means)]
        return PolicyFns("MDP", table_init, mdp_step,
                         {"pi": _pad_tables(tables, grid.K)})

    @classmethod
    def fleet(cls, fleet: "FleetBatch", costs_list, ges,  # noqa: F821
              c_means) -> PolicyFns:
        return cls.batch(fleet.grid, costs_list, ges, c_means)


class ABCPolicy(OnlinePolicy):
    """Arrival Based Caching [26] (see module docstring for the reading)."""

    init_fn = staticmethod(table_init)
    step_fn = staticmethod(abc_step)

    def __init__(self, costs: HostingCosts, ge: GilbertElliot, c_mean: float):
        super().__init__(costs)
        self.ge = ge
        self.c_mean = float(c_mean)
        # threshold to classify the state from x_t
        self.x_threshold = 0.5 * (ge.rate_h + ge.rate_l)
        self.pi = jnp.asarray(solve_abc(costs, ge, c_mean), jnp.int32)  # [S, K]

    @property
    def params(self):
        return {"pi": self.pi,
                "x_threshold": jnp.asarray(self.x_threshold, jnp.float32)}

    @classmethod
    def batch(cls, grid: HostingGrid, costs_list: Sequence[HostingCosts],
              ges: Sequence[GilbertElliot], c_means: Sequence[float]) -> PolicyFns:
        tables = [solve_abc(cc, ge, cm)
                  for cc, ge, cm in zip(costs_list, ges, c_means)]
        thr = jnp.asarray([0.5 * (ge.rate_h + ge.rate_l) for ge in ges],
                          jnp.float32)
        return PolicyFns("ABC", table_init, abc_step,
                         {"pi": _pad_tables(tables, grid.K), "x_threshold": thr})

    @classmethod
    def fleet(cls, fleet: "FleetBatch", costs_list, ges,  # noqa: F821
              c_means) -> PolicyFns:
        return cls.batch(fleet.grid, costs_list, ges, c_means)
