"""Baseline policies from the paper's simulation sections.

* ``StaticPolicy`` — hold one level forever (never/always-partial/always-full).
* ``MDPPolicy`` — §7.1.2's "MDP policy": knows the arrival statistics (the
  Gilbert-Elliot chain and per-state rates) and the mean rent cost; solves
  the average-cost MDP over (chain state, hosting level) by relative value
  iteration and plays the resulting stationary policy, observing the current
  chain state.
* ``ABCPolicy`` — "Arrival Based Caching" [26]: decides from the *current
  slot's arrival rate* and the arrival statistics only.  Our operational
  reading (the reference is summarised in one sentence in the paper): infer
  the chain state from x_t, then pick the level minimising the expected
  per-slot cost with the fetch price amortised over the expected sojourn of
  the inferred state:

      r' = argmin_k  lv_k * c_mean + g_k * rate(s_hat)
                     + M * (lv_k - lv_r)^+ / sojourn(s_hat).

Both baselines get statistics that alpha-RR never sees — the paper's point
(Figs 17-22) is that alpha-RR is competitive with them anyway.
"""
from __future__ import annotations

import itertools

import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import GilbertElliot
from repro.core.costs import HostingCosts
from repro.core.policies.base import OnlinePolicy, SlotObs, State


class StaticPolicy(OnlinePolicy):
    def __init__(self, costs: HostingCosts, level_idx: int):
        super().__init__(costs)
        self.level_idx = int(level_idx)

    @property
    def name(self):
        return f"static[{self.costs.levels[self.level_idx]}]"

    def init(self) -> State:
        # slot 1 must start at 0 (service initially not hosted); we upgrade
        # to the target level at the first decision point.
        return {"r": jnp.asarray(0, jnp.int32)}

    def step(self, state: State, obs: SlotObs) -> State:
        return {"r": jnp.asarray(self.level_idx, jnp.int32)}


def _expected_svc_rates(costs: HostingCosts, rates: np.ndarray) -> np.ndarray:
    """E[service cost | chain state s, level k] = g_k * rate_s  (Model 1 and
    Model 2 agree in expectation)."""
    g = np.asarray(costs.g, np.float64)
    return rates[:, None] * g[None, :]          # [S, K]


def solve_mdp(costs: HostingCosts, ge: GilbertElliot, c_mean: float,
              iters: int = 2000, tol: float = 1e-10) -> np.ndarray:
    """Relative value iteration for the average-cost MDP.

    States: (chain s in {0=L, 1=H}, level k).  Action: next level k'.
    Timing: choose k' at the end of a slot knowing s_t; pay fetch now; next
    slot's service cost is drawn at s_{t+1} ~ P(.|s_t).

    Returns pi [S, K] -> next-level index.
    """
    lv = np.asarray(costs.levels, np.float64)
    K = costs.K
    P = np.array([[1 - ge.p_lh, ge.p_lh], [ge.p_hl, 1 - ge.p_hl]])  # [s, s']
    rates = np.array([ge.rate_l, ge.rate_h])
    svc = _expected_svc_rates(costs, rates)     # [S, K]
    hold = c_mean * lv[None, :] + svc           # E[cost | s', k'] for holding
    fetch = costs.M * np.maximum(lv[None, :] - lv[:, None], 0.0)  # [k, k']

    V = np.zeros((2, K))
    for _ in range(iters):
        # Q[s, k, k'] = fetch[k,k'] + sum_s' P[s,s'] (hold[s',k'] + V[s',k'])
        cont = np.einsum("st,tk->sk", P, hold + V)   # [s, k']
        Q = fetch[None, :, :] + cont[:, None, :]
        V_new = Q.min(axis=2)
        V_new = V_new - V_new[0, 0]                  # relative VI normalisation
        if np.max(np.abs(V_new - V)) < tol:
            V = V_new
            break
        V = V_new
    cont = np.einsum("st,tk->sk", P, hold + V)
    Q = fetch[None, :, :] + cont[:, None, :]
    return np.argmin(Q, axis=2)                      # [S, K]


class MDPPolicy(OnlinePolicy):
    """Plays the precomputed average-cost-optimal stationary policy; observes
    the chain state via ``obs.side`` (0=L, 1=H)."""

    def __init__(self, costs: HostingCosts, ge: GilbertElliot, c_mean: float):
        super().__init__(costs)
        self.pi = jnp.asarray(solve_mdp(costs, ge, c_mean), jnp.int32)  # [S, K]

    def init(self) -> State:
        return {"r": jnp.asarray(0, jnp.int32)}

    def step(self, state: State, obs: SlotObs) -> State:
        s = jnp.clip(obs.side, 0, self.pi.shape[0] - 1)
        return {"r": self.pi[s, state["r"]]}


class ABCPolicy(OnlinePolicy):
    """Arrival Based Caching [26] (see module docstring for the reading)."""

    def __init__(self, costs: HostingCosts, ge: GilbertElliot, c_mean: float):
        super().__init__(costs)
        self.ge = ge
        self.c_mean = float(c_mean)
        # threshold to classify the state from x_t
        self.x_threshold = 0.5 * (ge.rate_h + ge.rate_l)
        rates = np.array([ge.rate_l, ge.rate_h])
        sojourn = np.array([1.0 / max(ge.p_lh, 1e-9), 1.0 / max(ge.p_hl, 1e-9)])
        lv = np.asarray(costs.levels, np.float64)
        g = np.asarray(costs.g, np.float64)
        # score[s, k, k'] of choosing k' at current level k in inferred state s
        hold = self.c_mean * lv[None, :] + rates[:, None] * g[None, :]
        fetch = costs.M * np.maximum(lv[None, :] - lv[:, None], 0.0)
        score = hold[:, None, :] + fetch[None, :, :] / sojourn[:, None, None]
        self.pi = jnp.asarray(np.argmin(score, axis=2), jnp.int32)   # [S, K]

    def init(self) -> State:
        return {"r": jnp.asarray(0, jnp.int32)}

    def step(self, state: State, obs: SlotObs) -> State:
        s_hat = (obs.x.astype(jnp.float32) >= self.x_threshold).astype(jnp.int32)
        return {"r": self.pi[s_hat, state["r"]]}
