from repro.core.policies.base import OnlinePolicy, SlotObs
from repro.core.policies.alpha_rr import AlphaRR, RetroRenting, alpha_rr_literal
from repro.core.policies.offline_opt import (offline_opt, offline_opt_no_partial,
                                             brute_force_opt, OfflineResult)
from repro.core.policies.baselines import StaticPolicy, MDPPolicy, ABCPolicy, solve_mdp

__all__ = [
    "OnlinePolicy", "SlotObs", "AlphaRR", "RetroRenting", "alpha_rr_literal",
    "offline_opt", "offline_opt_no_partial", "brute_force_opt", "OfflineResult",
    "StaticPolicy", "MDPPolicy", "ABCPolicy", "solve_mdp",
]
