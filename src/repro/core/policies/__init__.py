from repro.core.policies.base import (OnlinePolicy, PolicyFns, PolicyLane,
                                      SlotObs, as_policy_lanes)
from repro.core.policies.alpha_rr import (AlphaRR, RetroRenting,
                                          alpha_rr_literal, alpha_rr_params,
                                          alpha_rr_grid_params, alpha_rr_init,
                                          alpha_rr_step)
from repro.core.policies.offline_opt import (offline_opt, offline_opt_batch,
                                             offline_opt_no_partial,
                                             brute_force_opt, OfflineResult,
                                             BatchOfflineResult)
from repro.core.policies.baselines import (StaticPolicy, MDPPolicy, ABCPolicy,
                                           solve_mdp, solve_abc)

__all__ = [
    "OnlinePolicy", "PolicyFns", "PolicyLane", "SlotObs", "as_policy_lanes",
    "AlphaRR", "RetroRenting",
    "alpha_rr_literal", "alpha_rr_params", "alpha_rr_grid_params",
    "alpha_rr_init", "alpha_rr_step",
    "offline_opt", "offline_opt_batch", "offline_opt_no_partial",
    "brute_force_opt", "OfflineResult", "BatchOfflineResult",
    "StaticPolicy", "MDPPolicy", "ABCPolicy", "solve_mdp", "solve_abc",
]
