"""Offline optimal hosting (alpha-OPT / OPT) by exact dynamic programming.

State = level index, K states; transition cost = fetch on increments only
(eviction free).  ``J_t(k) = min_k' [J_{t-1}(k') + M (lv_k - lv_k')^+] + w_t[k]``
with ``J_0 = [0, inf, ...]`` (service starts off-edge, like all policies).
Runs as one lax.scan over the horizon; argmins are emitted so the optimal
schedule can be backtracked for the hosting-status histograms (Figs 2, 8,
12-22).

``OPT`` (no partial hosting, the benchmark of [22]) is the same DP on the
2-level instance. Exhaustive-search cross-checks live in the tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import HostingCosts, per_slot_cost_matrix


def _eval(costs, r_hist, x, c, svc=None):
    # local import: simulator imports policies.base, whose package __init__
    # imports this module — keep the edge lazy to break the cycle.
    from repro.core.simulator import evaluate_schedule
    return evaluate_schedule(costs, r_hist, x, c, svc)


@dataclasses.dataclass
class OfflineResult:
    cost: float
    r_hist: np.ndarray
    sim: object  # repro.core.simulator.SimResult


def offline_opt(costs: HostingCosts, x, c, svc=None) -> OfflineResult:
    """Exact alpha-OPT over the instance; also returns the argmin schedule."""
    x = jnp.asarray(x, jnp.int32)
    c = jnp.asarray(c, jnp.float32)
    w = per_slot_cost_matrix(costs, x, c, None if svc is None else jnp.asarray(svc))
    lv = jnp.asarray(costs.levels, jnp.float32)
    K = costs.K
    # fetch_mat[k_prev, k_next] = M * (lv_next - lv_prev)^+
    fetch_mat = costs.M * jnp.maximum(lv[None, :] - lv[:, None], 0.0)

    def step(J_prev, w_t):
        # trans[k_prev, k_next] = J_prev[k_prev] + fetch
        trans = J_prev[:, None] + fetch_mat
        arg = jnp.argmin(trans, axis=0)          # [K] best predecessor per level
        J = jnp.min(trans, axis=0) + w_t
        return J, arg

    J0 = jnp.full((K,), jnp.inf, jnp.float32).at[0].set(0.0)
    J_T, args = jax.lax.scan(step, J0, w)
    args = np.asarray(args)                       # [T, K]
    # backtrack
    T = args.shape[0]
    r_hist = np.zeros(T, np.int64)
    k = int(np.argmin(np.asarray(J_T)))
    for t in range(T - 1, -1, -1):
        r_hist[t] = k
        k = int(args[t, k])
    sim = _eval(costs, r_hist, x, c, svc)
    return OfflineResult(cost=float(jnp.min(J_T)), r_hist=r_hist, sim=sim)


def offline_opt_no_partial(costs: HostingCosts, x, c, svc=None) -> OfflineResult:
    """OPT of [22]: offline optimum restricted to levels {0, 1}."""
    c2 = HostingCosts.two_level(costs.M, costs.c_min, costs.c_max)
    svc2 = None
    if svc is not None:
        svc = np.asarray(svc)
        svc2 = svc[:, [0, costs.K - 1]]
    return offline_opt(c2, x, c, svc2)


def brute_force_opt(costs: HostingCosts, x, c, svc=None) -> OfflineResult:
    """Exhaustive search over all K^T schedules (tests only; tiny T)."""
    x = np.asarray(x)
    T = len(x)
    K = costs.K
    best, best_seq = np.inf, None
    for code in range(K ** T):
        seq = np.base_repr(code, K).zfill(T)
        r = np.array([int(ch) for ch in seq], np.int64)
        res = _eval(costs, r, x, c, svc)
        if res.total < best - 1e-9:
            best, best_seq = res.total, r
    sim = _eval(costs, best_seq, x, c, svc)
    return OfflineResult(cost=best, r_hist=best_seq, sim=sim)
