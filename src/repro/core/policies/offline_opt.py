"""Offline optimal hosting (alpha-OPT / OPT) by exact dynamic programming.

State = level index, K states; transition cost = fetch on increments only
(eviction free).  ``J_t(k) = min_k' [J_{t-1}(k') + M (lv_k - lv_k')^+] + w_t[k]``
with ``J_0 = [0, inf, ...]`` (service starts off-edge, like all policies).

Both passes are ``lax.scan``s: the forward value recursion emits the argmin
table, and the backtrack is a *reverse* scan over that table (no Python
loop), so the whole DP jits — and vmaps over a stacked ``HostingGrid``
(``offline_opt_batch``), with padded levels priced at +inf so mixed-K
batches stay exact.  Argmins are kept so the optimal schedule feeds the
hosting-status histograms (Figs 2, 8, 12-22).

``core.fleet.offline_opt_fleet`` is the fleet form of this DP: the same
forward recursion op-for-op, device-sharded over the instance axis, chunked
over time, and frozen past each instance's own horizon (identity
backpointers on padded slots) — bit-identical to ``offline_opt_batch`` on
uniform-horizon fleets.  The chunk-level kernel lives HERE
(``dp_fwd_chunk`` / ``dp_backtrack_chunk``): one forward recursion shared
verbatim by the materialized-backpointer cores and the checkpointed ones,
so every driver is op-for-op the same recursion.

**Checkpointed backtracking** (``offline_opt_fleet(checkpointed=True)``)
removes the last O(T) DP buffer: the forward value pass stores only one
[K] value-frontier checkpoint per chunk (plus the generator state for
scenario-fused runs), and the backtrack pass replays each chunk *in
reverse order* from its checkpoint, recomputing that chunk's argmin table
on the fly — device memory is O(chunk * K + n_chunks * K) per instance
instead of O(T * K), at the price of a second forward sweep.  Because the
recomputed tables are produced by the identical ``dp_fwd_chunk`` from the
identical frontier, the checkpointed schedule is **bit-identical** to the
materialized one wherever both fit, which is what extends exact OPT to the
same T = 10^6-10^7 horizons as ``run_fleet(collect_trace=False)``.

**Kernel/reference split** — ``dp_fwd_chunk`` is also the engine's
backend-dispatch point: ``backend="xla"`` (the default everywhere) runs
the ``lax.scan`` written below, which is the *canonical reference*
semantics of the recursion; ``backend="pallas"`` routes the identical
per-slot op sequence through the fused ``kernels.hosting.dp_minplus_kc``
kernel (frontier held in VMEM across the chunk, interpret mode on CPU).
The two are proven **bit-identical** — exact equality of ``(J', args)``,
not allclose — in tests/test_kernels.py and tests/test_backend_dispatch.py
for every driver configuration; any future backend must ship the same
proof before the fleet layer will thread it (ROADMAP engine invariants).

``OPT`` (no partial hosting, the benchmark of [22]) is the same DP on the
2-level instance. Exhaustive-search cross-checks live in the tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import (HostingCosts, HostingGrid, ServiceSet,
                              default_float_dtype, per_slot_cost_matrix)


def _eval(costs, r_hist, x, c, svc=None):
    # local import: simulator imports policies.base, whose package __init__
    # imports this module — keep the edge lazy to break the cycle.
    from repro.core.simulator import evaluate_schedule
    return evaluate_schedule(costs, r_hist, x, c, svc)


@dataclasses.dataclass
class OfflineResult:
    cost: float
    r_hist: np.ndarray
    sim: object  # repro.core.simulator.SimResult


@dataclasses.dataclass
class BatchOfflineResult:
    cost: np.ndarray          # [B]
    r_hist: np.ndarray        # [B, T]
    sim: object               # repro.core.simulator.BatchSimResult


# ----------------------------------------------------------------------
# The chunk-level DP kernel (shared by every fleet driver in core/fleet.py).
# ----------------------------------------------------------------------

def dp_frontier0(K: int, dtype=jnp.float32):
    """The initial value frontier ``J_0 = [0, inf, ...]`` (service starts
    off-edge, like every policy)."""
    return jnp.full((K,), jnp.inf, dtype).at[0].set(0.0)


def dp_fetch_matrix(M32, lv32):
    """``fetch_mat[k_prev, k_next] = M * (lv_next - lv_prev)^+``.

    A matrix-valued ``M32`` (per-instance ``[K, K]``, ``ndim >= 2``) is an
    *explicit* fetch matrix and passes through untouched — the joint
    multi-service grids of ``costs.ServiceSet`` (whose host-side
    construction uses exactly this function's float32 op order per
    service, so an N=1 joint matrix is bitwise the rank-one product
    below).  Every DP driver builds its fetch matrix here, inside its
    per-instance vmap, which is what threads matrix-M grids through the
    materialized, checkpointed, streamed, scenario-fused and Pallas paths
    with no driver changes."""
    if jnp.ndim(M32) >= 2:
        return M32
    return M32 * jnp.maximum(lv32[None, :] - lv32[:, None], 0.0)


#: Valid ``backend=`` values for ``dp_fwd_chunk`` (and the ``dp_backend=``
#: arguments threaded through ``core.fleet``): "xla" is the canonical
#: ``lax.scan`` reference, "pallas" the fused ``kernels.hosting`` kernel —
#: bit-identical by the engine's backend-dispatch invariant (ROADMAP.md).
DP_BACKENDS = ("xla", "pallas")


def dp_fwd_chunk(J, tids, cck, sck, lv32, kmask, fetch_mat, T_len,
                 backend: str = "xla"):
    """One chunk of the forward value recursion — THE one copy every fleet
    DP driver shares (materialized backpointers, checkpointed two-pass,
    obs-backed and scenario-fused, scan and streamed), so all of them are
    op-for-op the same recursion.  Invalid slots (``t >= T_len``) keep the
    frontier frozen and write identity argmins; padded K levels are priced
    ``+inf`` via ``kmask`` exactly as in ``offline_opt_batch``.

    ``backend`` selects the relaxation engine *under* the shared cost
    assembly: "xla" (default) is the ``lax.scan`` below — the canonical
    reference — and "pallas" routes the identical per-slot op sequence
    through ``kernels.hosting.dp_minplus_kc``, which keeps the [K]
    frontier kernel-resident across the whole chunk.  Both emit
    bit-identical ``(J', args)`` for every input.

    Returns ``(J', args [chunk, K])``.
    """
    K = lv32.shape[-1]
    # the same float32 w as offline_opt_batch: rent + svc, +inf pads
    wck = (cck[:, None].astype(jnp.float32) * lv32[None, :]
           + sck.astype(jnp.float32))
    wck = jnp.where(kmask[None, :], wck, jnp.inf)

    if backend == "pallas":
        # lazy import: the kernels package (and Pallas) loads only when a
        # non-default backend is actually requested
        from repro.kernels.hosting import dp_minplus_kc
        return dp_minplus_kc(J, wck, fetch_mat, tids < T_len)
    if backend != "xla":
        raise ValueError(f"backend must be one of {DP_BACKENDS}, "
                         f"got {backend!r}")

    def fwd(J_prev, inp):
        t, w_t = inp
        valid_t = t < T_len
        trans = J_prev[:, None] + fetch_mat
        arg = jnp.argmin(trans, axis=0)
        J = jnp.min(trans, axis=0) + w_t
        J = jnp.where(valid_t, J, J_prev)
        arg = jnp.where(valid_t, arg, jnp.arange(K))
        return J, arg

    return jax.lax.scan(fwd, J, (tids, wck))


def dp_backtrack_chunk(k, args):
    """Backtrack one ``[chunk, K]`` argmin table from terminal level ``k``:
    returns ``(k at chunk entry, r_hist [chunk])``.  The checkpointed
    drivers chain this right-to-left over recomputed per-chunk tables; the
    materialized drivers call it once on the whole-horizon table — the
    (k, arg) op sequence is identical either way."""

    def back(k, arg_t):
        return arg_t[k], k

    return jax.lax.scan(back, k, args, reverse=True)


def dp_backtrack(J_T, args):
    """Terminal min + whole-table backtrack (the materialized path)."""
    k_T = jnp.argmin(J_T)
    _, r_hist = dp_backtrack_chunk(k_T, args)
    return jnp.min(J_T), r_hist.astype(jnp.int32)


def _dp_core(M, lv, w):
    """Forward DP + reverse-scan backtrack for one instance.

    Args: M scalar (or an explicit [K, K] fetch matrix — joint
    multi-service states), lv [K], w [T, K] per-slot holding costs (+inf on
    padded levels).  Returns (cost scalar, r_hist [T]).
    """
    K = lv.shape[-1]
    fetch_mat = dp_fetch_matrix(M, lv)

    def fwd(J_prev, w_t):
        # trans[k_prev, k_next] = J_prev[k_prev] + fetch
        trans = J_prev[:, None] + fetch_mat
        arg = jnp.argmin(trans, axis=0)          # [K] best predecessor per level
        J = jnp.min(trans, axis=0) + w_t
        return J, arg

    J0 = jnp.full((K,), jnp.inf, w.dtype).at[0].set(0.0)
    J_T, args = jax.lax.scan(fwd, J0, w)

    def back(k, arg_t):
        return arg_t[k], k

    k_T = jnp.argmin(J_T)
    _, r_hist = jax.lax.scan(back, k_T, args, reverse=True)
    return jnp.min(J_T), r_hist.astype(jnp.int32)


_dp_one = jax.jit(_dp_core)
_dp_vmapped = jax.jit(jax.vmap(_dp_core))


def offline_opt(costs: HostingCosts, x, c, svc=None) -> OfflineResult:
    """Exact alpha-OPT over the instance; also returns the argmin schedule."""
    dt = default_float_dtype()
    x = jnp.asarray(x, jnp.int32)
    c = jnp.asarray(c, dt)
    w = per_slot_cost_matrix(costs, x, c, None if svc is None else jnp.asarray(svc))
    lv = jnp.asarray(costs.levels, jnp.float32)
    cost, r_hist = _dp_one(jnp.asarray(costs.M, jnp.float32), lv, w)
    r_hist = np.asarray(r_hist).astype(np.int64)
    sim = _eval(costs, r_hist, x, c, svc)
    return OfflineResult(cost=float(cost), r_hist=r_hist, sim=sim)


def offline_opt_batch(grid: HostingGrid, x, c, svc=None) -> BatchOfflineResult:
    """Batched alpha-OPT: the DP + backtrack vmapped over a stacked grid.

    ``x``/``c`` are [T] or [B, T]; ``svc`` optional [B, T, K].  Padded levels
    of mixed-K grids are priced at +inf, so each instance's schedule uses
    only its real levels.
    """
    from repro.core.simulator import _batch_obs, evaluate_schedule_batch
    x, c, svc_full, _ = _batch_obs(grid, x, c, svc, None)
    lv = grid.levels.astype(jnp.float32)
    rent = c[:, :, None].astype(jnp.float32) * lv[:, None, :]
    w = rent + svc_full.astype(jnp.float32)                     # [B, T, K]
    w = jnp.where(grid.mask[:, None, :], w, jnp.inf)
    cost, r_hist = _dp_vmapped(grid.M.astype(jnp.float32), lv, w)
    sim = evaluate_schedule_batch(grid, r_hist, x, c, svc)
    return BatchOfflineResult(cost=np.asarray(cost).astype(np.float64),
                              r_hist=np.asarray(r_hist).astype(np.int64),
                              sim=sim)


def offline_opt_no_partial(costs: HostingCosts, x, c, svc=None) -> OfflineResult:
    """OPT of [22]: offline optimum restricted to levels {0, 1}."""
    c2 = HostingCosts.two_level(costs.M, costs.c_min, costs.c_max)
    svc2 = None
    if svc is not None:
        svc = np.asarray(svc)
        svc2 = svc[:, [0, costs.K - 1]]
    return offline_opt(c2, x, c, svc2)


# ----------------------------------------------------------------------
# Joint multi-service OPT: the same DP on a ServiceSet's feasible joint
# states (explicit fetch matrix, shared-capacity constraint baked into the
# state enumeration — see costs.ServiceSet).
# ----------------------------------------------------------------------

@dataclasses.dataclass
class JointOfflineResult:
    """Joint capacity-respecting optimum of one ``ServiceSet``.

    ``states`` are joint-state indices into ``sset.joint_states()``;
    ``r_hist[n]`` is service n's per-slot level-index schedule (every slot
    feasible by construction — infeasible combinations are never states).
    """

    cost: float
    states: np.ndarray        # [T] joint-state indices
    r_hist: np.ndarray        # [N, T] per-service level indices


def _joint_slot_costs(sset: ServiceSet, xs, c, svcs):
    """([T, J] float32 holding costs, [N, T] arrivals) for the joint DP.

    Op order matches the single-service w assembly exactly (rent product
    first, then one svc addition per service, n-ascending): at N=1 the
    matrix is bitwise ``per_slot_cost_matrix``'s.
    """
    idx = sset.joint_states()
    xs = np.asarray(xs)
    if xs.ndim == 1:
        xs = np.broadcast_to(xs[None], (sset.N,) + xs.shape)
    if xs.shape[0] != sset.N:
        raise ValueError(f"xs has {xs.shape[0]} arrival rows for "
                         f"{sset.N} services")
    c32 = np.asarray(c, np.float32)
    w = c32[:, None] * sset.joint_levels()[None, :]            # [T, J]
    for n, cc in enumerate(sset.services):
        if svcs is not None and svcs[n] is not None:
            svc_n = np.asarray(svcs[n], np.float32)
        else:
            svc_n = (xs[n][:, None].astype(np.float32)
                     * np.asarray(cc.g, np.float32)[None, :])
        w = w + svc_n[:, idx[:, n]]
    return w, xs


def offline_opt_joint(sset: ServiceSet, xs, c,
                      svcs=None) -> JointOfflineResult:
    """Exact joint OPT for N services sharing one edge: the standard DP
    (``_dp_core`` — the same jitted core as ``offline_opt``) over the
    feasible joint states, with the capacity constraint enforced by the
    state enumeration and fetches priced by the explicit joint matrix.

    Args:
      xs: [T] (common arrivals) or [N, T] per-service arrival counts.
      c: [T] rent costs (one edge, one rent stream).
      svcs: optional list of per-service realized [T, K_n] service costs
        (Model 2); ``None`` entries fall back to Model-1 ``g_n * x_n``.

    At N=1 (unconstrained) this is bitwise ``offline_opt`` — same w, same
    fetch matrix, same DP ops (tests/test_multi_service.py).
    """
    w, _ = _joint_slot_costs(sset, xs, c, svcs)
    fm = jnp.asarray(sset.joint_fetch_matrix())
    lv = jnp.asarray(sset.joint_levels())
    cost, states = _dp_one(fm, lv, jnp.asarray(w))
    states = np.asarray(states).astype(np.int64)
    return JointOfflineResult(cost=float(cost), states=states,
                              r_hist=sset.joint_states()[states].T
                                         .astype(np.int64))


def brute_force_joint_opt(sset: ServiceSet, xs, c,
                          svcs=None) -> JointOfflineResult:
    """Exhaustive joint oracle (tests only; tiny J**T): enumerates every
    joint-state sequence, accumulating in float32 with the DP's exact
    association ``(cost + fetch) + w`` per slot — so the minimum equals
    ``offline_opt_joint``'s cost EXACTLY (float equality, no tolerance),
    which is what the oracle suites assert."""
    w, xs = _joint_slot_costs(sset, xs, c, svcs)
    fm = sset.joint_fetch_matrix()
    T = w.shape[0]
    J = fm.shape[0]
    best, best_seq = np.inf, None
    for code in range(J ** T):
        cost = np.float32(0.0)
        prev = 0
        seq = np.empty((T,), np.int64)
        for t in range(T):
            k = (code // (J ** t)) % J
            cost = (cost + fm[prev, k]) + w[t, k]
            prev = k
            seq[t] = k
        if cost < best:
            best, best_seq = cost, seq
    return JointOfflineResult(cost=float(best), states=best_seq,
                              r_hist=sset.joint_states()[best_seq].T
                                         .astype(np.int64))


def brute_force_opt(costs: HostingCosts, x, c, svc=None) -> OfflineResult:
    """Exhaustive search over all K^T schedules (tests only; tiny T)."""
    x = np.asarray(x)
    T = len(x)
    K = costs.K
    best, best_seq = np.inf, None
    for code in range(K ** T):
        seq = np.base_repr(code, K).zfill(T)
        r = np.array([int(ch) for ch in seq], np.int64)
        res = _eval(costs, r, x, c, svc)
        if res.total < best - 1e-9:
            best, best_seq = res.total, r
    sim = _eval(costs, best_seq, x, c, svc)
    return OfflineResult(cost=best, r_hist=best_seq, sim=sim)
