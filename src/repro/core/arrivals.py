"""Request-arrival processes used in the paper's analysis and simulations.

 - Bernoulli(p)                    (Assumptions 1/2, Figs 1-6)
 - Poisson(lam)                    (Model 2 synthetic, Figs 12-15)
 - Gilbert-Elliot 2-state Markov   (Figs 7/8 and 17-22) with Bernoulli or
   Poisson emissions per state
 - adversarial worst-case sequences (Theorem 4's constructions)
 - bursty "cluster-trace-like" generator standing in for the Google cluster
   trace [14] (offline container: see DESIGN.md §2)

Everything returns int32 arrays of shape [T] and is deterministic given a
``jax.random`` key.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def bernoulli(key, p: float, T: int) -> jnp.ndarray:
    return jax.random.bernoulli(key, p, (T,)).astype(jnp.int32)


def poisson(key, lam: float, T: int) -> jnp.ndarray:
    return jax.random.poisson(key, lam, (T,)).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class GilbertElliot:
    """Two-state Markov-modulated arrivals (Fig. 9 / 16 of the paper).

    State H emits ``rate_h`` arrivals in expectation, state L ``rate_l``.
    ``p_hl`` = P(H->L), ``p_lh`` = P(L->H).  ``emission`` is "bernoulli"
    (rates are probabilities) or "poisson" (rates are intensities).
    """

    p_hl: float
    p_lh: float
    rate_h: float
    rate_l: float
    emission: str = "poisson"

    @property
    def stationary_h(self) -> float:
        return self.p_lh / (self.p_lh + self.p_hl)

    @property
    def mean_rate(self) -> float:
        ph = self.stationary_h
        return ph * self.rate_h + (1.0 - ph) * self.rate_l

    def sample(self, key, T: int, return_states: bool = False):
        kc, ke = jax.random.split(key)
        flips = jax.random.uniform(kc, (T,))

        def step(state, u):
            # state: 1 = H, 0 = L
            stay_h = u >= self.p_hl
            go_h = u < self.p_lh
            nxt = jnp.where(state == 1, jnp.where(stay_h, 1, 0), jnp.where(go_h, 1, 0))
            return nxt, nxt

        # start from the stationary distribution to avoid burn-in artifacts
        s0 = (jax.random.uniform(jax.random.fold_in(kc, 1)) < self.stationary_h).astype(jnp.int32)
        _, states = jax.lax.scan(step, s0, flips)
        rates = jnp.where(states == 1, self.rate_h, self.rate_l)
        if self.emission == "poisson":
            x = jax.random.poisson(ke, rates, (T,)).astype(jnp.int32)
        elif self.emission == "bernoulli":
            x = (jax.random.uniform(ke, (T,)) < rates).astype(jnp.int32)
        else:
            raise ValueError(self.emission)
        if return_states:
            return x, states
        return x


def cluster_trace_like(key, T: int, base_rate: float = 2.0,
                       burst_rate: float = 20.0, burst_p: float = 0.05,
                       diurnal_period: int = 0) -> jnp.ndarray:
    """Synthetic stand-in for the Google cluster-usage trace [14]: a
    low-intensity Poisson background with geometric-length bursts, optionally
    modulated by a diurnal sinusoid. Statistically bursty + autocorrelated,
    which is what matters to RetroRenting-style policies."""
    kb, kp, kd = jax.random.split(key, 3)
    ge = GilbertElliot(p_hl=0.2, p_lh=burst_p, rate_h=burst_rate, rate_l=base_rate,
                       emission="poisson")
    x = ge.sample(kb, T).astype(jnp.float32)
    if diurnal_period:
        t = jnp.arange(T, dtype=jnp.float32)
        mod = 1.0 + 0.5 * jnp.sin(2 * jnp.pi * t / diurnal_period)
        lam = x * mod
        x = jax.random.poisson(kd, jnp.maximum(lam, 0.0), (T,)).astype(jnp.float32)
    return x.astype(jnp.int32)


# ----------------------------------------------------------------------
# Adversarial constructions (proof of Theorem 4)
# ----------------------------------------------------------------------

def adversarial_fetch_bait(tau: int, T: int) -> np.ndarray:
    """Arrivals every slot until slot ``tau`` (when the online policy is
    goaded into fetching), then silence — the Theorem-4 lower-bound
    construction for a policy starting at r=0."""
    x = np.zeros(T, dtype=np.int32)
    x[:tau] = 1
    return x


def adversarial_evict_bait(tau_bar: int, tau: int, T: int) -> np.ndarray:
    """No arrivals until the policy evicts (slot ``tau_bar``), then arrivals
    every slot until ``tau_bar + tau``, then silence (second construction in
    the proof of Theorem 4)."""
    x = np.zeros(T, dtype=np.int32)
    x[tau_bar:tau_bar + tau] = 1
    return x
