"""Request-arrival processes used in the paper's analysis and simulations.

 - Bernoulli(p)                    (Assumptions 1/2, Figs 1-6)
 - Poisson(lam)                    (Model 2 synthetic, Figs 12-15)
 - Gilbert-Elliot 2-state Markov   (Figs 7/8 and 17-22) with Bernoulli or
   Poisson emissions per state
 - adversarial worst-case sequences (Theorem 4's constructions)
 - bursty "cluster-trace-like" generator standing in for the Google cluster
   trace [14] (offline container: see DESIGN.md §2)

Everything returns int32 arrays of shape [T] and is deterministic given a
``jax.random`` key.

Since the scenario engine landed, the *generation* lives in
``core.scenarios.streams`` as counter-based ``Stream``s that fuse into the
fleet scan (``run_fleet(scenario=...)``); the functions here are the
whole-horizon materializations of those streams (bit-identical under the
same key — tests/test_scenarios.py) kept for the classic array-building
API.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.scenarios import base as _base
from repro.core.scenarios import streams as _streams


def _mat1(stream, T: int):
    """Materialize a B=1 stream; returns the values pytree minus the
    instance axis."""
    vals = _base.materialize_stream(stream, int(T))
    return jax.tree_util.tree_map(lambda a: a[0], vals)


def bernoulli(key, p: float, T: int):
    return _mat1(_streams.bernoulli_arrivals(key, p, B=1), T)[0]


def poisson(key, lam: float, T: int):
    return _mat1(_streams.poisson_arrivals(key, lam, B=1), T)[0]


@dataclasses.dataclass(frozen=True)
class GilbertElliot:
    """Two-state Markov-modulated arrivals (Fig. 9 / 16 of the paper).

    State H emits ``rate_h`` arrivals in expectation, state L ``rate_l``.
    ``p_hl`` = P(H->L), ``p_lh`` = P(L->H).  ``emission`` is "bernoulli"
    (rates are probabilities) or "poisson" (rates are intensities).
    """

    p_hl: float
    p_lh: float
    rate_h: float
    rate_l: float
    emission: str = "poisson"

    @property
    def stationary_h(self) -> float:
        return self.p_lh / (self.p_lh + self.p_hl)

    @property
    def mean_rate(self) -> float:
        ph = self.stationary_h
        return ph * self.rate_h + (1.0 - ph) * self.rate_l

    def stream(self, key, B: int = 1) -> "_base.Stream":
        """This chain as a fleet-fusable arrival stream (side = state)."""
        return _streams.ge_arrivals(key, self.p_hl, self.p_lh, self.rate_h,
                                    self.rate_l, B=B, emission=self.emission)

    def sample(self, key, T: int, return_states: bool = False):
        x, states = _mat1(self.stream(key), T)
        if return_states:
            return x, states
        return x


def cluster_trace_like(key, T: int, base_rate: float = 2.0,
                       burst_rate: float = 20.0, burst_p: float = 0.05,
                       diurnal_period: int = 0):
    """Synthetic stand-in for the Google cluster-usage trace [14]: a
    low-intensity Poisson background with geometric-length bursts, optionally
    modulated by a diurnal sinusoid. Statistically bursty + autocorrelated,
    which is what matters to RetroRenting-style policies."""
    return _mat1(_streams.bursty_arrivals(key, B=1, base_rate=base_rate,
                                          burst_rate=burst_rate,
                                          burst_p=burst_p,
                                          diurnal_period=diurnal_period), T)[0]


# ----------------------------------------------------------------------
# Adversarial constructions (proof of Theorem 4)
# ----------------------------------------------------------------------

def adversarial_fetch_bait(tau: int, T: int) -> np.ndarray:
    """Arrivals every slot until slot ``tau`` (when the online policy is
    goaded into fetching), then silence — the Theorem-4 lower-bound
    construction for a policy starting at r=0."""
    return np.asarray(
        _mat1(_streams.adversarial_fetch_bait(tau, B=1), T)[0])


def adversarial_evict_bait(tau_bar: int, tau: int, T: int) -> np.ndarray:
    """No arrivals until the policy evicts (slot ``tau_bar``), then arrivals
    every slot until ``tau_bar + tau``, then silence (second construction in
    the proof of Theorem 4)."""
    return np.asarray(
        _mat1(_streams.adversarial_evict_bait(tau_bar, tau, B=1), T)[0])
