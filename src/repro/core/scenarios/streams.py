"""Primitive workload streams (counter-based; see ``base`` for the contract).

Arrival streams: ``bernoulli_arrivals``, ``poisson_arrivals``,
``ge_arrivals`` (Gilbert-Elliot, side = chain state), ``bursty_arrivals``
(the cluster-trace stand-in), ``adversarial_fetch_bait`` /
``adversarial_evict_bait`` (Theorem-4 constructions), ``trace_arrivals``.

Rent streams: ``uniform_rents``, ``na_rents`` (antithetic time-pairs,
Assumption 7), ``arma_rents`` / ``spot_rents`` (ARMA(p,q) spot prices),
``constant_rents``, ``trace_rents``.

Service streams: ``model2_service`` (coupled per-request uniforms, the
``model2_service_matrix`` construction as a stream).

Randomness per slot ``t`` comes from ``fold_in(key, t)`` (plus small salts
for independent sub-draws within a slot), so every stream is invariant to
chunking; the stateful ones (GE chain, ARMA histories) draw their
innovations that way and thread only the recursion through ``gen_state``.

``bernoulli_arrivals`` and ``uniform_rents`` carry a boolean ``flip`` param
(default False) that maps each slot uniform ``u -> 1 - u``: the hook
``combinators.antithetic_pairing`` uses to build negatively-associated
instance pairs from shared keys.

Every stream's ``init_fn``/``chunk_fn`` is a module-level function (or
comes from a small ``lru_cache``d factory keyed on the static config):
constructing the "same" stream twice yields the *same* function objects,
so the identity-keyed compile caches (``base._compiled_gen``, the fleet
engine's scenario cores) hit instead of re-tracing per construction —
the legacy ``arrivals.py``/``rentcosts.py`` wrappers build a fresh Stream
per call and rely on this.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import default_float_dtype
from repro.core.scenarios.base import (Stream, as_keys, bcast, slot_keys,
                                       slot_uniform)

# Salt for draws that must not collide with any per-slot counter (slot
# counters are the nonnegative slot indices).
_INIT_SALT = 0x7FFFFFFF


def _no_state(params):
    return ()


def _zeros_side(x):
    return jnp.zeros(x.shape, jnp.int32)


def _flip(u, flip):
    return jnp.where(flip, 1.0 - u, u)


# ----------------------------------------------------------------------
# Arrival streams.
# ----------------------------------------------------------------------

def _bernoulli_chunk(params, state, tids):
    u = _flip(slot_uniform(params["key"], tids), params["flip"])
    x = (u < params["p"]).astype(jnp.int32)
    return state, (x, _zeros_side(x))


def bernoulli_arrivals(key, p, B: int) -> Stream:
    """Bernoulli(p) arrivals; ``p`` scalar or per-instance [B]."""
    return Stream("bernoulli", "arrivals", _no_state, _bernoulli_chunk,
                  {"key": as_keys(key, B), "p": bcast(p, B, jnp.float32),
                   "flip": jnp.zeros((B,), bool)})


def _poisson_chunk(params, state, tids):
    ks = slot_keys(params["key"], tids)
    x = jax.vmap(lambda k: jax.random.poisson(k, params["lam"], ()))(ks)
    return state, (x.astype(jnp.int32), _zeros_side(x))


def poisson_arrivals(key, lam, B: int) -> Stream:
    return Stream("poisson", "arrivals", _no_state, _poisson_chunk,
                  {"key": as_keys(key, B),
                   "lam": bcast(lam, B, jnp.float32)})


def _ge_emit(key, tids, rates, emission: str, salt: int):
    """Per-slot emissions at per-slot rates (counter-keyed)."""
    if emission == "poisson":
        ks = slot_keys(key, tids)
        ks = jax.vmap(lambda k: jax.random.fold_in(k, salt))(ks)
        return jax.vmap(
            lambda k, r: jax.random.poisson(k, r, ()))(ks, rates).astype(jnp.int32)
    if emission == "bernoulli":
        # the fold/salt/uniform chain IS slot_uniform's — draw through it
        # so GE bernoulli emissions ride the PRNG backend dispatch too
        u = slot_uniform(key, tids, salt=salt)
        return (u < rates).astype(jnp.int32)
    raise ValueError(emission)


def _ge_states(params, state, tids):
    """Advance the 2-state chain over one chunk; returns (s', states
    [chunk])."""
    u = slot_uniform(params["key"], tids, salt=0)

    def step(s, u_t):
        nxt = jnp.where(s == 1,
                        jnp.where(u_t >= params["p_hl"], 1, 0),
                        jnp.where(u_t < params["p_lh"], 1, 0)).astype(jnp.int32)
        return nxt, nxt

    return jax.lax.scan(step, state["s"], u)


def _ge_init(params):
    # start from the stationary distribution (no burn-in artifacts)
    ph = params["p_lh"] / (params["p_lh"] + params["p_hl"])
    u0 = jax.random.uniform(jax.random.fold_in(params["key"], _INIT_SALT))
    return {"s": (u0 < ph).astype(jnp.int32)}


def _ge_chunk(params, state, tids, emission):
    s, states = _ge_states(params, state, tids)
    rates = jnp.where(states == 1, params["rate_h"], params["rate_l"])
    x = _ge_emit(params["key"], tids, rates, emission, salt=1)
    return {"s": s}, (x, states)


def _ge_chunk_poisson(params, state, tids):
    return _ge_chunk(params, state, tids, "poisson")


def _ge_chunk_bernoulli(params, state, tids):
    return _ge_chunk(params, state, tids, "bernoulli")


def ge_arrivals(key, p_hl, p_lh, rate_h, rate_l, B: int,
                emission: str = "poisson") -> Stream:
    """Gilbert-Elliot Markov-modulated arrivals; ``side`` carries the chain
    state (1 = H), which is what the MDP/ABC baselines observe."""
    chunk = {"poisson": _ge_chunk_poisson,
             "bernoulli": _ge_chunk_bernoulli}[emission]
    return Stream(f"ge-{emission}", "arrivals", _ge_init, chunk,
                  {"key": as_keys(key, B),
                   "p_hl": bcast(p_hl, B, jnp.float32),
                   "p_lh": bcast(p_lh, B, jnp.float32),
                   "rate_h": bcast(rate_h, B, jnp.float32),
                   "rate_l": bcast(rate_l, B, jnp.float32)},
                  has_side=True)


# burst-exit rate of the bursty (cluster-trace-like) GE background — public
# so callers computing the process's stationary mean stay in lockstep
BURSTY_EXIT_P = 0.2


@functools.lru_cache(maxsize=None)
def _bursty_chunk_fn(diurnal_period: int):
    def chunk(params, state, tids):
        state, (x, _) = _ge_chunk_poisson(params, state, tids)
        if diurnal_period:
            t = tids.astype(jnp.float32)
            mod = 1.0 + 0.5 * jnp.sin(2 * jnp.pi * t / diurnal_period)
            lam = jnp.maximum(x.astype(jnp.float32) * mod, 0.0)
            x = _ge_emit(params["key"], tids, lam, "poisson", salt=2)
        return state, (x, _zeros_side(x))

    return chunk


def bursty_arrivals(key, B: int, base_rate=2.0, burst_rate=20.0,
                    burst_p=0.05, diurnal_period: int = 0) -> Stream:
    """The cluster-trace stand-in: GE-Poisson bursts over a low-rate
    background, optionally remodulated by a diurnal sinusoid
    (``arrivals.cluster_trace_like``)."""
    ge = ge_arrivals(key, p_hl=BURSTY_EXIT_P, p_lh=burst_p,
                     rate_h=burst_rate, rate_l=base_rate, B=B)
    return Stream("bursty", "arrivals", _ge_init,
                  _bursty_chunk_fn(int(diurnal_period)), ge.params)


def _fetch_bait_chunk(params, state, tids):
    x = (tids < params["tau"]).astype(jnp.int32)
    return state, (x, _zeros_side(x))


def adversarial_fetch_bait(tau, B: int) -> Stream:
    """Arrivals every slot until ``tau``, then silence (Theorem 4)."""
    return Stream("fetch-bait", "arrivals", _no_state, _fetch_bait_chunk,
                  {"tau": bcast(tau, B, jnp.int32)})


def _evict_bait_chunk(params, state, tids):
    lo, hi = params["tau_bar"], params["tau_bar"] + params["tau"]
    x = ((tids >= lo) & (tids < hi)).astype(jnp.int32)
    return state, (x, _zeros_side(x))


def adversarial_evict_bait(tau_bar, tau, B: int) -> Stream:
    """Silence until ``tau_bar``, arrivals for ``tau`` slots, silence."""
    return Stream("evict-bait", "arrivals", _no_state, _evict_bait_chunk,
                  {"tau_bar": bcast(tau_bar, B, jnp.int32),
                   "tau": bcast(tau, B, jnp.int32)})


def _slice_trace(trace, tids):
    # clipped gather, NOT dynamic_slice: when the engine pads the horizon to
    # a chunk multiple the tail tids overrun the trace, and dynamic_slice
    # would clamp the *start* and shift the whole window.  Clipped indices
    # repeat the last sample on (invalid, masked-out) tail slots and keep
    # the values a pure function of tids — chunk-decomposition invariant.
    return jnp.take(trace, jnp.minimum(tids, trace.shape[0] - 1), axis=0)


def _trace_arrivals_chunk(params, state, tids):
    x = _slice_trace(params["trace"], tids).astype(jnp.int32)
    side = _slice_trace(params["side"], tids).astype(jnp.int32)
    return state, (x, side)


def _trace_arrivals_chunk_sideless(params, state, tids):
    x = _slice_trace(params["trace"], tids).astype(jnp.int32)
    return state, (x, _zeros_side(x))


def trace_arrivals(x, B: Optional[int] = None, side=None) -> Stream:
    """Deterministic playback of a recorded [T] / [B, T] arrival trace.

    The trace rides in params (resident on device), so playback keeps the
    fused-scan plumbing but not the O(B * chunk) memory bound — it is the
    bridge for real traces, not a synthetic generator.  Without ``side``,
    the zeros side channel is emitted per chunk, not stored as a second
    [B, T] trace.
    """
    x = jnp.asarray(x, jnp.int32)
    if x.ndim == 1:
        x = jnp.broadcast_to(x[None, :], (B or 1, x.shape[0]))
    if side is None:
        return Stream("trace", "arrivals", _no_state,
                      _trace_arrivals_chunk_sideless, {"trace": x})
    side = jnp.broadcast_to(jnp.asarray(side, jnp.int32), x.shape)
    return Stream("trace", "arrivals", _no_state, _trace_arrivals_chunk,
                  {"trace": x, "side": side}, has_side=True)


# ----------------------------------------------------------------------
# Rent streams.
# ----------------------------------------------------------------------

def _uniform_rents_chunk(params, state, tids):
    dt = params["lo"].dtype
    u = _flip(slot_uniform(params["key"], tids, dtype=dt), params["flip"])
    c = params["lo"] + u * (params["hi"] - params["lo"])
    return state, c


def uniform_rents(key, c_mean, half_width, B: int, c_min=1e-3) -> Stream:
    """i.i.d. U[c_mean - hw, c_mean + hw] rents (lower-clamped at c_min)."""
    dt = default_float_dtype()
    mean = bcast(c_mean, B, dt)
    hw = bcast(half_width, B, dt)
    return Stream("uniform", "rents", _no_state, _uniform_rents_chunk,
                  {"key": as_keys(key, B),
                   "lo": jnp.maximum(mean - hw, bcast(c_min, B, dt)),
                   "hi": mean + hw,
                   "flip": jnp.zeros((B,), bool)})


def _na_rents_chunk(params, state, tids):
    dt = params["lo"].dtype
    # antithetic time-pairs: slots (2m, 2m+1) share the pair counter m and
    # see (u_m, 1 - u_m) — negatively associated (Assumption 7)
    m = tids // 2
    u = slot_uniform(params["key"], m, dtype=dt)
    v = jnp.where(tids % 2 == 0, u, 1.0 - u)
    return state, params["lo"] + v * (params["hi"] - params["lo"])


def na_rents(key, c_mean, half_width, B: int) -> Stream:
    """Negatively-associated rents via antithetic (U, 1-U) time-pairs."""
    dt = default_float_dtype()
    mean = bcast(c_mean, B, dt)
    hw = bcast(half_width, B, dt)
    return Stream("na-pairs", "rents", _no_state, _na_rents_chunk,
                  {"key": as_keys(key, B), "lo": mean - hw, "hi": mean + hw})


def _constant_rents_chunk(params, state, tids):
    return state, jnp.broadcast_to(params["c"], tids.shape)


def constant_rents(c, B: int) -> Stream:
    return Stream("constant", "rents", _no_state, _constant_rents_chunk,
                  {"c": bcast(c, B, default_float_dtype())})


def _trace_rents_chunk(params, state, tids):
    return state, _slice_trace(params["trace"], tids)


def trace_rents(c, B: Optional[int] = None) -> Stream:
    """Deterministic playback of a recorded rent trace."""
    c = jnp.asarray(c, default_float_dtype())
    if c.ndim == 1:
        c = jnp.broadcast_to(c[None, :], (B or 1, c.shape[0]))
    return Stream("trace", "rents", _no_state, _trace_rents_chunk,
                  {"trace": c})


def _arma_eps_at(params, counters):
    ks = slot_keys(params["key"], counters)
    return params["sigma"] * jax.vmap(
        lambda k: jax.random.normal(k, (), jnp.float32))(ks)


def _arma_init(params):
    p = params["phi"].shape[-1]
    q = params["th"].shape[-1]
    # eps_hist holds (eps_{-1}, ..., eps_{-q}): counters q-1 .. 0
    eps0 = _arma_eps_at(params, jnp.arange(q - 1, -1, -1, dtype=jnp.int32))
    return {"hist": jnp.zeros((p,), jnp.float32), "eps": eps0}


def _arma_chunk(params, state, tids):
    q = params["th"].shape[-1]
    eps = _arma_eps_at(params, tids + q)

    def step(carry, e_t):
        hist, eps_hist = carry
        dev = (jnp.dot(params["phi"], hist) + e_t
               + jnp.dot(params["th"], eps_hist))
        hist = jnp.concatenate([dev[None], hist[:-1]])
        eps_hist = jnp.concatenate([e_t[None], eps_hist[:-1]])
        return (hist, eps_hist), dev

    (hist, eps_hist), devs = jax.lax.scan(step, (state["hist"],
                                                 state["eps"]), eps)
    c = jnp.clip(params["mean"] + devs, params["c_min"], params["c_max"])
    return ({"hist": hist, "eps": eps_hist},
            c.astype(default_float_dtype()))


def arma_rents(key, mean, B: int, ar=None, ma=None, sigma=0.05,
               c_min=0.05, c_max=10.0) -> Stream:
    """ARMA(p, q) rents, clipped to Assumption-3 bounds.

    The AR/MA recursion state (last p deviations, last q innovations) rides
    in ``gen_state``; innovation ``eps_t`` uses counter ``t + q`` (counters
    [0, q) seed the pre-horizon innovations in ``init_fn``), so any chunking
    replays the identical series.  ``ar`` / ``ma`` are per-family tuples
    (static lengths); all coefficients may be per-instance [B, p] / [B, q].
    """
    from repro.core.rentcosts import DEFAULT_AR, DEFAULT_MA
    ar = DEFAULT_AR if ar is None else ar
    ma = DEFAULT_MA if ma is None else ma
    phi = jnp.asarray(ar, jnp.float32)
    th = jnp.asarray(ma, jnp.float32)
    if phi.ndim == 1:
        phi = jnp.broadcast_to(phi[None], (B,) + phi.shape)
    if th.ndim == 1:
        th = jnp.broadcast_to(th[None], (B,) + th.shape)
    return Stream("arma", "rents", _arma_init, _arma_chunk,
                  {"key": as_keys(key, B), "mean": bcast(mean, B, jnp.float32),
                   "phi": phi, "th": th,
                   "sigma": bcast(sigma, B, jnp.float32),
                   "c_min": bcast(c_min, B, jnp.float32),
                   "c_max": bcast(c_max, B, jnp.float32)})


def spot_rents(key, c_mean, B: int, rel_sigma=0.15, c_min=None,
               c_max=None) -> Stream:
    """AWS-spot-like rents: default ARMA(4,2) scaled to a target mean, the
    stream form of ``rentcosts.aws_spot_like`` (same default clip bounds —
    figure modules can therefore set ``HostingCosts`` c_min/c_max a priori
    instead of from the realized trace)."""
    c_mean = np.asarray(c_mean, np.float64)
    return arma_rents(
        key, c_mean, B, sigma=rel_sigma * c_mean,
        c_min=np.maximum(0.2 * c_mean, 1e-3) if c_min is None else c_min,
        c_max=3.0 * c_mean if c_max is None else c_max)


def spot_bounds(c_mean):
    """(c_min, c_max) a ``spot_rents`` stream can ever emit (clip rails)."""
    return float(max(0.2 * c_mean, 1e-3)), float(3.0 * c_mean)


# ----------------------------------------------------------------------
# Service streams (Model 2).
# ----------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _model2_chunk_fn(R: int):
    def chunk(params, state, tids, x):
        ks = slot_keys(params["key"], tids)
        u = jax.vmap(lambda k: jax.random.uniform(k, (R,)))(ks)  # [chunk, R]
        live = jnp.arange(R)[None, :] < x[:, None]               # [chunk, R]
        fwd = u[:, :, None] < params["g"][None, None, :]         # [chunk,R,K]
        svc = jnp.sum(jnp.where(live[:, :, None] & fwd, 1.0, 0.0), axis=1)
        return state, svc.astype(params["g"].dtype)

    return chunk


def model2_service(key, g, B: int, max_per_slot: int) -> Stream:
    """Realized Model-2 service costs, coupled across levels: request i of
    slot t draws one uniform; it is forwarded (cost 1) at level k iff
    ``u < g[k]``.  Same construction as ``simulator.model2_service_matrix``
    but counter-keyed per slot.  ``g`` is [K] or [B, K] (pass ``grid.g`` —
    the endpoint-restricted grid then yields exactly the endpoint-gathered
    service costs on the same uniforms)."""
    g = jnp.asarray(g, default_float_dtype())
    if g.ndim == 1:
        g = jnp.broadcast_to(g[None], (B,) + g.shape)
    return Stream("model2", "svc", _no_state,
                  _model2_chunk_fn(int(max_per_slot)),
                  {"key": as_keys(key, B), "g": g})
