"""Scenario abstraction: composable on-device workload generators.

A *scenario* is to observations what ``PolicyFns`` is to policies — a pure
``(init_fn, chunk_fn)`` pair over a pytree of array params:

    gen_state0          = init_fn(params)
    gen_state', slab    = chunk_fn(params, gen_state, tids)

where ``tids`` is the ``[chunk]`` int32 vector of *global* slot indices this
call must emit and ``slab`` is an ``ObsSlab`` of per-slot observations
(arrivals ``x``, rents ``c``, optional realized Model-2 service costs
``svc`` and an int32 ``side`` channel such as the Gilbert-Elliot regime).
Params follow the policy convention: per-instance shapes describe one
instance; stack a leading ``[B]`` axis on every leaf and the same pair vmaps
over the fleet (``core.fleet.run_fleet(..., scenario=...)`` fuses generation
into the chunked scan, so device memory stays O(B * chunk) and no
observation array ever crosses the host->device boundary).

Counter-based keys — THE invariant
----------------------------------
Every random stream derives its slot-t randomness from
``jax.random.fold_in(key, t)`` (a counter-based construction), never from a
position inside a bulk ``(T,)`` draw.  Recursive state (the GE chain, ARMA
histories) rides in ``gen_state`` across chunk boundaries, but the
*innovations* feeding the recursion are counter-based.  Consequently a
stream's output is a pure function of ``(params, t)`` given the carried
state, and is **invariant to the chunk decomposition**: materializing the
whole horizon in one chunk, in 64-slot chunks, or generating slabs inside
the fleet scan all produce bit-identical observations.  That is what makes
``run_fleet(scenario=...)`` == materialize-then-run exact rather than
merely statistical (tests/test_scenarios.py).  The full set of key-folding
and bit-identity rules lives in ``docs/CONVENTIONS.md``; the engine layer
map in ``docs/ARCHITECTURE.md``.

Channel conventions
-------------------
* arrival streams emit ``(x [chunk] int32, side [chunk] int32)`` — ``side``
  is zeros when the process has no hidden state;
* rent streams emit ``c [chunk]`` in ``costs.default_float_dtype()``;
* service streams emit ``svc [chunk, K]`` and receive the slab's arrivals
  (``chunk_fn(params, state, tids, x)``) so Model-2 draws couple to the
  arrival process exactly like ``simulator.model2_service_matrix``.

``combinators.combine`` fuses one stream per channel into a ``Scenario``;
``mixture`` / ``regime_switch`` / ``antithetic_pairing`` / ``trace_*``
compose streams without touching the engine.
"""
from __future__ import annotations

import contextlib
import functools
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class ObsSlab(NamedTuple):
    """One ``[chunk]``-shaped window of generated observations (per
    instance; the engine vmaps a leading [B] axis on top)."""

    x: jnp.ndarray                     # [chunk] int32 arrivals
    c: jnp.ndarray                     # [chunk] float rents
    svc: Optional[jnp.ndarray] = None  # [chunk, K] realized service costs
    side: Optional[jnp.ndarray] = None # [chunk] int32 side channel


class Stream(NamedTuple):
    """One generated channel (arrivals, rents, or service costs).

    ``chunk_fn(params, state, tids) -> (state', values)`` where ``values``
    is the channel's per-slot payload (see module docstring).  Service
    streams take an extra ``x`` argument.  ``params`` leaves all carry a
    leading [B] axis (constructors broadcast); ``kind`` is one of
    ``"arrivals" | "rents" | "svc"`` and is checked by the combinators.
    ``has_side`` marks arrival streams whose side channel carries real
    information (the GE chain state; zeros otherwise) — materialization
    drops the channel when it doesn't.
    """

    name: str
    kind: str
    init_fn: Callable[[Any], Any]
    chunk_fn: Callable[..., Any]
    params: Any
    has_side: bool = False


class Scenario(NamedTuple):
    """A full workload generator: ``chunk_fn(params, gen_state, tids) ->
    (gen_state', ObsSlab)``.  ``has_svc`` declares whether slabs carry a
    realized service matrix (the engine falls back to Model-1 ``g * x``
    otherwise)."""

    name: str
    init_fn: Callable[[Any], Any]
    chunk_fn: Callable[[Any, Any, jnp.ndarray], Any]
    params: Any
    has_svc: bool = False
    has_side: bool = False

    @property
    def B(self) -> int:
        return jax.tree_util.tree_leaves(self.params)[0].shape[0]


# ----------------------------------------------------------------------
# Param/key plumbing shared by every stream constructor.
# ----------------------------------------------------------------------

def bcast(v, B: int, dtype=None) -> jnp.ndarray:
    """Broadcast a scalar / [B] value to a [B] param leaf."""
    a = jnp.asarray(v, dtype)
    return jnp.broadcast_to(a, (B,) + a.shape[1:] if a.ndim > 1 else (B,))


def split_keys(key, B: int) -> jnp.ndarray:
    """[B, 2] *independent* per-instance keys from one base key."""
    return jax.random.split(jnp.asarray(key), B)


def shared_keys(key, B: int) -> jnp.ndarray:
    """[B, 2] copies of ONE key: every instance replays the same sample
    path (the sweep-figure idiom — one trace scored at many grid points)."""
    return jnp.broadcast_to(jnp.asarray(key)[None, :], (B, 2))


def as_keys(key, B: int) -> jnp.ndarray:
    """Accept a single key (-> independent splits) or an explicit [B, 2]
    key array (returned as-is)."""
    key = jnp.asarray(key)
    if key.ndim == 1:
        return split_keys(key, B)
    if key.shape[0] != B:
        raise ValueError(f"key batch {key.shape[0]} != B={B}")
    return key


def slot_keys(key, tids: jnp.ndarray) -> jnp.ndarray:
    """[chunk, 2] counter-based per-slot keys: ``fold_in(key, t)``."""
    return jax.vmap(lambda t: jax.random.fold_in(key, t))(tids)


#: Valid PRNG backends: "xla" is the canonical vmapped ``jax.random``
#: chain, "pallas" the fused ``kernels.hosting.slot_uniform_tc`` kernel
#: (bit-identical; see the ROADMAP backend-dispatch invariant).  Selected
#: per trace via ``prng_dispatch`` — use ``combinators.with_prng_backend``
#: (or the engine entry points' ``prng_backend=``) rather than calling the
#: context manager directly.
PRNG_BACKENDS = ("xla", "pallas")

# trace-time backend stack; slot_uniform consults the top.  A plain list,
# not a contextvar: dispatch happens while *tracing* a chunk_fn, which the
# with_prng_backend wrapper brackets synchronously.
_PRNG_BACKEND = ["xla"]


@contextlib.contextmanager
def prng_dispatch(backend: str):
    """Route ``slot_uniform`` through ``backend`` for the enclosed trace."""
    if backend not in PRNG_BACKENDS:
        raise ValueError(f"prng backend must be one of {PRNG_BACKENDS}, "
                         f"got {backend!r}")
    _PRNG_BACKEND.append(backend)
    try:
        yield
    finally:
        _PRNG_BACKEND.pop()


def slot_uniform(key, tids: jnp.ndarray, salt: Optional[int] = None,
                 dtype=jnp.float32) -> jnp.ndarray:
    """[chunk] independent U(0,1) draws, one per global slot index.

    THE counter-keyed uniform primitive every hot stream draws through
    (``bernoulli_arrivals``, ``uniform_rents`` / ``na_rents``, the GE chain
    and its bernoulli emissions) — and therefore the PRNG backend-dispatch
    point: under ``prng_dispatch("pallas")`` the whole fold/salt/uniform
    chain runs as one fused ``kernels.hosting`` pass, bit-identical to the
    vmapped ``jax.random`` chain below (non-float32 ``dtype`` — the x64
    path — always uses the reference chain).
    """
    if (_PRNG_BACKEND[-1] == "pallas"
            and jnp.dtype(dtype) == jnp.dtype(jnp.float32)):
        from repro.kernels.hosting import slot_uniform_tc
        return slot_uniform_tc(jnp.asarray(key), tids, salt)
    ks = slot_keys(key, tids)
    if salt is not None:
        ks = jax.vmap(lambda k: jax.random.fold_in(k, salt))(ks)
    return jax.vmap(lambda k: jax.random.uniform(k, (), dtype))(ks)


# ----------------------------------------------------------------------
# Materialization: run the same chunk_fn outside the simulator.
# ----------------------------------------------------------------------

def chunk_geometry(T: int, chunk_size: Optional[int]):
    """(n_chunks, padded T) for cutting a horizon into fixed chunks.  The
    ONE copy shared by ``materialize`` and the fleet engine — fused ==
    materialized bit-identity relies on both sides padding identically."""
    if chunk_size is None:
        return 1, T
    chunk = int(chunk_size)
    n = max(1, math.ceil(T / chunk))
    return n, n * chunk


@functools.lru_cache(maxsize=64)
def _compiled_gen(init_fn, chunk_fn, n_chunks: int, T_pad: int, extra_x: bool):
    """vmapped whole-horizon generator for one (init_fn, chunk_fn) pair."""
    chunk = T_pad // n_chunks

    def gen_one(params, *xs):
        state = init_fn(params)

        def run(state, t0):
            tids = t0 + jnp.arange(chunk, dtype=jnp.int32)
            args = (params, state, tids)
            if extra_x:
                args += (jax.lax.dynamic_slice_in_dim(xs[0], t0, chunk),)
            return chunk_fn(*args)

        if n_chunks == 1:
            _, vals = run(state, jnp.asarray(0, jnp.int32))
            return vals
        t0s = jnp.arange(n_chunks, dtype=jnp.int32) * chunk
        _, vals = jax.lax.scan(run, state, t0s)
        return jax.tree_util.tree_map(
            lambda a: a.reshape((T_pad,) + a.shape[2:]), vals)

    return jax.jit(jax.vmap(gen_one))


def materialize_stream(stream: Stream, T: int, chunk_size: Optional[int] = None,
                       x=None):
    """Run one stream over the whole horizon; returns its values pytree with
    leaves shaped ``[B, T, ...]``.  Chunk-invariant: any ``chunk_size``
    produces bit-identical values (the counter-key construction)."""
    n_chunks, T_pad = chunk_geometry(T, chunk_size)
    args = (stream.params,)
    if stream.kind == "svc":
        if x is None:
            raise ValueError("service streams need the arrival slab x")
        x = jnp.asarray(x, jnp.int32)
        if T_pad > T:
            x = jnp.pad(x, ((0, 0), (0, T_pad - T)))
        args += (x,)
    gen = _compiled_gen(stream.init_fn, stream.chunk_fn, n_chunks, T_pad,
                        stream.kind == "svc")
    vals = gen(*args)
    return jax.tree_util.tree_map(lambda a: a[:, :T], vals)


def materialize(scenario: Scenario, T: int, chunk_size: Optional[int] = None):
    """Materialize a scenario's observations: ``(x, c, svc, side)`` numpy
    arrays shaped [B, T] (svc [B, T, K]; svc/side None when absent).

    This is the reference the fused engine is proven against: for any
    ``chunk_size`` here and any chunk/stream configuration in ``run_fleet``,
    observations (and therefore simulation results) are bit-identical.
    """
    n_chunks, T_pad = chunk_geometry(T, chunk_size)
    gen = _compiled_gen(scenario.init_fn, scenario.chunk_fn, n_chunks, T_pad,
                        False)
    slab = gen(scenario.params)
    crop = lambda a: None if a is None else np.asarray(a[:, :T])
    # an all-zeros side channel (side-less arrival process) is the engine
    # default anyway — don't materialize dead [B, T] bytes for it
    side = crop(slab.side) if scenario.has_side else None
    return crop(slab.x), crop(slab.c), crop(slab.svc), side
