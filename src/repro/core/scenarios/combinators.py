"""Scenario combinators: declare mixed workloads instead of hand-assembling
observation arrays.

* ``combine``             — one stream per channel -> a full ``Scenario``.
* ``mixture``             — per-instance mixture over [B]: instance b plays
                            component ``component[b]``'s stream.
* ``mixture_from_weights``— sample that assignment from mixture weights.
* ``regime_switch``       — time-based switching at fixed slot boundaries.
* ``antithetic_pairing``  — negatively-associated instance pairs: (2m, 2m+1)
                            share a key, the odd member flips its uniforms.
* ``trace_scenario``      — deterministic playback of recorded [B, T] obs.
* ``with_seed``           — fold one Monte-Carlo seed into every stream key
                            (before the per-slot counter fold).
* ``with_prng_backend``   — route a scenario's (or stream's) counter-keyed
                            uniforms through a kernel backend
                            (``base.PRNG_BACKENDS``); bit-identical by the
                            backend-dispatch invariant.
* ``replicate_seeds``     — the MC axis: S seed-replicas of a B-instance
                            scenario as one [B*S] scenario
                            (``antithetic=True`` pairs replicas (2m, 2m+1)
                            on flip-capable streams).
* ``tile_services``       — the per-service axis: N service-replicas of a
                            B-instance scenario as one [B*N] scenario,
                            keys salted per service except in ``shared``
                            channel groups (default: one rent stream per
                            instance across its services).

Composition happens at the *stream* level, so combinator outputs are
ordinary streams: mixtures of regime-switched antithetic pairs are
one-liners and everything still fuses into the fleet scan.  Selection uses
compute-all-then-select (``jnp.where``), the same trick the policies use
for one-hot levels: every component advances its state and draws every
slot, which keeps the combinators vmap/shard_map-transparent and makes the
selected rows *bitwise equal* to running the selected component alone
(tests/test_scenarios.py::test_mixture_selects_components).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scenarios.base import (ObsSlab, PRNG_BACKENDS, Scenario,
                                       Stream, prng_dispatch)
from repro.core.scenarios import streams as _streams


@functools.lru_cache(maxsize=64)
def _backend_fns(init_fn, chunk_fn, backend: str):
    """Backend-bracketed (init_fn, chunk_fn), memoized on the wrapped
    *functions* + backend so repeated with_prng_backend() constructions
    yield identical function objects (the identity-keyed compile caches —
    ``base._compiled_gen``, the fleet engine cores — then key correctly on
    the backend choice, like ``_combine_fns``)."""

    def init2(params):
        with prng_dispatch(backend):
            return init_fn(params)

    def chunk2(params, state, tids, *extra):
        with prng_dispatch(backend):
            return chunk_fn(params, state, tids, *extra)

    return init2, chunk2


def with_prng_backend(scenario, backend: str):
    """Route every ``slot_uniform`` draw of a Scenario (or a single Stream)
    through ``backend`` (see ``base.PRNG_BACKENDS``).  "xla" — the
    canonical reference — returns the input unchanged; any other backend
    wraps ``init_fn``/``chunk_fn`` so the dispatch is baked in at trace
    time.  Observations are **bit-identical** across backends (the
    backend-dispatch invariant); draws the kernel does not cover (poisson,
    normal, float64 uniforms) silently stay on the reference path."""
    if backend not in PRNG_BACKENDS:
        raise ValueError(f"prng backend must be one of {PRNG_BACKENDS}, "
                         f"got {backend!r}")
    if backend == "xla":
        return scenario
    init2, chunk2 = _backend_fns(scenario.init_fn, scenario.chunk_fn,
                                 backend)
    return scenario._replace(init_fn=init2, chunk_fn=chunk2,
                             name=f"{scenario.name}@{backend}")


@functools.lru_cache(maxsize=256)
def _combine_fns(arr_fns, rent_fns, svc_fns):
    """(init_fn, chunk_fn) for a channel combination, memoized on the
    component *functions* (not params): combining the same stream families
    twice yields identical function objects, so the identity-keyed compile
    caches downstream hit instead of re-tracing per Scenario construction."""
    arr_init, arr_chunk = arr_fns
    rent_init, rent_chunk = rent_fns

    def init_fn(params):
        st = {"arr": arr_init(params["arr"]),
              "rent": rent_init(params["rent"])}
        if svc_fns is not None:
            st["svc"] = svc_fns[0](params["svc"])
        return st

    def chunk_fn(params, state, tids):
        sa, (x, side) = arr_chunk(params["arr"], state["arr"], tids)
        sr, c = rent_chunk(params["rent"], state["rent"], tids)
        st = {"arr": sa, "rent": sr}
        svc_v = None
        if svc_fns is not None:
            st["svc"], svc_v = svc_fns[1](params["svc"], state["svc"],
                                          tids, x)
        return st, ObsSlab(x=x, c=c, svc=svc_v, side=side)

    return init_fn, chunk_fn


def combine(arrivals: Stream, rents: Stream, svc: Optional[Stream] = None,
            name: Optional[str] = None) -> Scenario:
    """Fuse per-channel streams into one Scenario."""
    for s, kind in ((arrivals, "arrivals"), (rents, "rents")):
        if s.kind != kind:
            raise ValueError(f"{s.name} is a {s.kind} stream, expected {kind}")
    if svc is not None and svc.kind != "svc":
        raise ValueError(f"{svc.name} is a {svc.kind} stream, expected svc")
    params = {"arr": arrivals.params, "rent": rents.params}
    if svc is not None:
        params["svc"] = svc.params
    init_fn, chunk_fn = _combine_fns(
        (arrivals.init_fn, arrivals.chunk_fn),
        (rents.init_fn, rents.chunk_fn),
        None if svc is None else (svc.init_fn, svc.chunk_fn))
    name = name or f"{arrivals.name}+{rents.name}" + \
        (f"+{svc.name}" if svc is not None else "")
    return Scenario(name, init_fn, chunk_fn, params,
                    has_svc=svc is not None, has_side=arrivals.has_side)


def _check_same_kind(components: Sequence[Stream]) -> str:
    kinds = {s.kind for s in components}
    if len(kinds) != 1:
        raise ValueError(f"cannot mix stream kinds {sorted(kinds)}")
    return kinds.pop()


@functools.lru_cache(maxsize=256)
def _select_fns(comp_fns, by_time: bool):
    """(init_fn, chunk_fn) for compute-all-then-select composition, memoized
    on the component *functions* so repeated mixture()/regime_switch()
    constructions reuse the same function objects (and therefore hit the
    identity-keyed compile caches downstream, like ``_combine_fns``).

    ``by_time=False`` selects per instance by ``params["component"]``;
    ``by_time=True`` selects per slot by ``params["bounds"]`` boundaries.
    """

    def init_fn(params):
        return tuple(f[0](p) for f, p in zip(comp_fns, params["subs"]))

    def chunk_fn(params, state, tids, *extra):
        states, values = [], []
        for f, p, st in zip(comp_fns, params["subs"], state):
            st2, v = f[1](p, st, tids, *extra)
            states.append(st2)
            values.append(v)
        if by_time:
            sel = jnp.sum(tids[:, None] >= params["bounds"][None, :],
                          axis=1)                                # [chunk]
        else:
            sel = params["component"]                            # scalar
        out = values[0]
        for i in range(1, len(values)):
            pick = sel == i
            out = jax.tree_util.tree_map(
                lambda a, b: jnp.where(
                    pick.reshape(pick.shape + (1,) * (a.ndim - pick.ndim))
                    if by_time else pick, b, a),
                out, values[i])
        return tuple(states), out

    return init_fn, chunk_fn


def _component_B(components: Sequence[Stream]) -> int:
    return jax.tree_util.tree_leaves(components[0].params)[0].shape[0]


def mixture(components: Sequence[Stream], component) -> Stream:
    """Per-instance mixture: instance b emits component ``component[b]``'s
    stream (all components must be the same channel kind).  Every
    component's state advances on every instance; the winner is selected
    per instance, so row b is bitwise the winner's own output."""
    kind = _check_same_kind(components)
    comp = np.asarray(component, np.int32)
    if np.any((comp < 0) | (comp >= len(components))):
        raise ValueError(f"component indices must be in [0, "
                         f"{len(components)}), got {comp}")
    params = {"component": jnp.asarray(comp),
              "subs": tuple(s.params for s in components)}
    init_fn, chunk_fn = _select_fns(
        tuple((s.init_fn, s.chunk_fn) for s in components), False)
    name = "mix(" + ",".join(s.name for s in components) + ")"
    return Stream(name, kind, init_fn, chunk_fn, params,
                  has_side=any(s.has_side for s in components))


def mixture_from_weights(components: Sequence[Stream], weights, key,
                         B: int) -> Stream:
    """Mixture with the per-instance assignment sampled once from
    ``weights`` (the declarative form of "30% bursty, 70% Bernoulli")."""
    w = np.asarray(weights, np.float64)
    comp = jax.random.choice(jnp.asarray(key), len(components), (B,),
                             p=jnp.asarray(w / w.sum()))
    return mixture(components, comp)


def regime_switch(components: Sequence[Stream],
                  boundaries: Sequence[int]) -> Stream:
    """Time-based switching: slots ``[boundaries[i-1], boundaries[i])`` play
    component i (``boundaries`` are global slot indices, strictly
    increasing, one fewer than components).  Every component keeps
    advancing its own state through foreign regimes, so for counter-based
    (stateless) components each regime's slots are bitwise the component's
    own slots."""
    kind = _check_same_kind(components)
    if len(boundaries) != len(components) - 1:
        raise ValueError("need len(components) - 1 boundaries")
    bounds = np.asarray(boundaries, np.int32)
    if bounds.size and np.any(np.diff(bounds) <= 0):
        raise ValueError("boundaries must be strictly increasing")
    B = _component_B(components)
    # [B, n-1] params leaf (every leaf needs the instance axis for vmap)
    params = {"bounds": jnp.broadcast_to(jnp.asarray(bounds)[None],
                                         (B,) + bounds.shape),
              "subs": tuple(s.params for s in components)}
    init_fn, chunk_fn = _select_fns(
        tuple((s.init_fn, s.chunk_fn) for s in components), True)
    name = "switch(" + ",".join(s.name for s in components) + ")"
    return Stream(name, kind, init_fn, chunk_fn, params,
                  has_side=any(s.has_side for s in components))


def antithetic_pairing(stream: Stream) -> Stream:
    """Negatively-associated instance pairs: instances (2m, 2m+1) share
    instance 2m's key and the odd member flips every slot uniform
    ``u -> 1 - u``.  Requires a stream with ``key`` and ``flip`` params
    (``bernoulli_arrivals``, ``uniform_rents``); pair sums of uniforms are
    exactly ``lo + hi`` (variance-reduction law in the tests)."""
    if not (isinstance(stream.params, dict) and "flip" in stream.params
            and "key" in stream.params):
        raise ValueError(f"{stream.name} does not support antithetic "
                         "pairing (no flip/key params)")
    B = stream.params["flip"].shape[0]
    even = (np.arange(B) // 2) * 2
    params = dict(stream.params)
    params["key"] = jnp.asarray(stream.params["key"])[even]
    params["flip"] = jnp.asarray(np.arange(B) % 2 == 1)
    return Stream(f"antithetic({stream.name})", stream.kind, stream.init_fn,
                  stream.chunk_fn, params, has_side=stream.has_side)


# ----------------------------------------------------------------------
# Monte-Carlo seed replication (the fleet engine's ``n_seeds=`` axis).
# ----------------------------------------------------------------------

def _map_key_leaves(params, leaf_fn, key_fn, pair_fn=None):
    """Structurally walk a params pytree, applying ``key_fn`` to every
    ``"key"`` dict entry (the stream-constructor convention: counter-based
    PRNG keys live under that name on every random stream) and ``leaf_fn``
    to every other array leaf.  Dict-name-aware on purpose — ``tree_map``
    cannot tell a key leaf from a coefficient leaf.

    ``pair_fn(key, flip) -> (key', flip')``, when given, takes over dicts
    that carry BOTH ``"key"`` and ``"flip"`` — the flip-capable streams
    (``bernoulli_arrivals``, ``uniform_rents``) that antithetic seed
    replication pairs up; every other keyed dict still goes through
    ``key_fn``."""
    if isinstance(params, dict):
        if pair_fn is not None and "key" in params and "flip" in params:
            key2, flip2 = pair_fn(params["key"], params["flip"])
            return {k: (key2 if k == "key" else flip2 if k == "flip"
                        else _map_key_leaves(v, leaf_fn, key_fn, pair_fn))
                    for k, v in params.items()}
        return {k: (key_fn(v) if k == "key"
                    else _map_key_leaves(v, leaf_fn, key_fn, pair_fn))
                for k, v in params.items()}
    if isinstance(params, (tuple, list)):
        return type(params)(_map_key_leaves(v, leaf_fn, key_fn, pair_fn)
                            for v in params)
    return leaf_fn(params)


def _fold_stacked(k, seeds):
    """``fold_in`` over a stacked key leaf ``[R, ..., 2]`` with per-row
    seeds ``[R]``.  Rows may carry extra stacked axes between the row axis
    and the key words — e.g. the joint multi-service scenario's
    ``[B, N, 2]`` sub-stream keys — and the row's seed broadcasts over
    them.  For the ordinary ``[R, 2]`` leaf the reshape is a no-op and
    this IS the plain ``vmap(fold_in)`` (bitwise)."""
    k = jnp.asarray(k)
    flat = k.reshape((-1,) + k.shape[-1:])
    s = jnp.repeat(seeds, flat.shape[0] // seeds.shape[0])
    return jax.vmap(jax.random.fold_in)(flat, s).reshape(k.shape)


def _bcast_rows(flag, like):
    """Right-pad a per-row ``[R]`` flag with singleton axes to broadcast
    against a stacked ``[R, ...]`` leaf."""
    return flag.reshape((-1,) + (1,) * (jnp.ndim(like) - 1))


def with_seed(obj, seed: int):
    """Fold one Monte-Carlo seed into every stream key of a ``Scenario`` or
    ``Stream``: ``key -> fold_in(key, seed)``.

    The fold happens *before* any per-slot ``fold_in(key, t)`` (and before
    the init-salt draws), so the result is an ordinary, legal standalone
    scenario — exactly the replica ``replicate_seeds`` packs at rows
    ``(b, seed)``.  Keyless streams (traces, constants, adversarial baits)
    are untouched: deterministic channels do not vary with the seed.
    """
    def fold(k):
        k = jnp.asarray(k)
        return _fold_stacked(k, jnp.full((k.shape[0],), seed, jnp.int32))
    params = _map_key_leaves(obj.params, lambda a: a, fold)
    return obj._replace(params=params, name=f"seed{seed}({obj.name})")


def replicate_seeds(obj, n_seeds: int, antithetic: bool = False):
    """S seed-replicas of a B-instance ``Scenario`` (or ``Stream``) as one
    [B*S] scenario — the Monte-Carlo axis folded into the stream keys.

    Row ``b * S + s`` (instance-major, seed-minor) carries instance ``b``'s
    params with ``fold_in(key, s)`` applied to every stream key, so it is
    **bit-identical** to running instance ``b`` standalone under
    ``with_seed(obj, s)``: no obs materialization, no benchmark-side key
    plumbing, and every downstream engine guarantee (chunk invariance,
    mesh transparency) holds per replica because a replica *is* a legal
    standalone instance.  Non-key param leaves are replicated row-wise.

    ``antithetic=True`` (even S required) pairs consecutive replicas on
    *flip-capable* streams (those carrying a ``flip`` next to their
    ``key``, i.e. ``bernoulli_arrivals`` / ``uniform_rents``): replicas
    ``(b, 2m)`` and ``(b, 2m + 1)`` share the pair fold ``fold_in(key, m)``
    and the odd member flips every slot uniform ``u -> 1 - u`` — the
    ``antithetic_pairing`` trick moved onto the seed axis, so pair sums of
    uniforms are exactly ``lo + hi`` and seed-mean CIs tighten at the same
    S for monotone statistics.  Even replicas are bitwise
    ``with_seed(obj, m)``'s rows on those streams; streams WITHOUT a flip
    param (GE chains, ARMA rents, Poisson, traces) keep the plain
    independent per-replica fold — antithesis only ever replaces
    independent replicas where the flip trick is exact.
    """
    S = int(n_seeds)
    if S < 1:
        raise ValueError(f"n_seeds must be >= 1, got {n_seeds}")
    B = jax.tree_util.tree_leaves(obj.params)[0].shape[0]
    seeds = jnp.tile(jnp.arange(S, dtype=jnp.int32), B)       # [B*S]
    rep = lambda a: jnp.repeat(jnp.asarray(a), S, axis=0)
    if not antithetic:
        params = _map_key_leaves(obj.params, rep,
                                 lambda k: _fold_stacked(rep(k), seeds))
        return obj._replace(params=params, name=f"mc{S}({obj.name})")
    if S % 2:
        raise ValueError(f"antithetic replication needs an even n_seeds, "
                         f"got {n_seeds}")
    odd = (seeds % 2).astype(bool)
    params = _map_key_leaves(
        obj.params, rep, lambda k: _fold_stacked(rep(k), seeds),
        pair_fn=lambda k, f: (_fold_stacked(rep(k), seeds // 2),
                              jnp.logical_xor(rep(f),
                                              _bcast_rows(odd, rep(f)))))
    return obj._replace(params=params, name=f"mc{S}a({obj.name})")


def tile_services(obj, n_services: int, shared: Sequence[str] = ("rent",)):
    """N service-replicas of a B-instance ``Scenario`` (or ``Stream``) as
    one [B*N] object — the per-service arrival axis of a multi-service
    fleet (``core.services``).

    Row ``b * N + n`` (instance-major, service-minor) carries instance
    ``b``'s params with ``fold_in(key, n)`` applied to every stream key —
    the same counter-key salting discipline as ``replicate_seeds``, so
    each service's stream is an independent draw yet fully deterministic
    and chunk-invariant.  Non-key leaves are replicated row-wise.

    ``shared`` names top-level param groups (the ``combine`` channel names
    ``"arr"`` / ``"rent"`` / ``"svc"``) whose keys are replicated WITHOUT
    the service fold: the default ``("rent",)`` gives all N services of an
    instance the identical rent stream — one edge, one spot price — while
    arrivals (and Model-2 service draws) vary per service.  Service n's
    rows are bitwise the rows of a standalone scenario built with the same
    folds, and ``n_services=1`` returns ``obj`` unchanged (the N=1
    bit-identity anchor).  The service fold composes *before* the engine's
    seed fold (``replicate_seeds`` runs inside ``run_fleet``), so MC rows
    are ``fold_in(fold_in(key, n), s)`` — service-major, seed-minor.
    """
    N = int(n_services)
    if N < 1:
        raise ValueError(f"n_services must be >= 1, got {n_services}")
    if N == 1:
        return obj
    B = jax.tree_util.tree_leaves(obj.params)[0].shape[0]
    svc_ids = jnp.tile(jnp.arange(N, dtype=jnp.int32), B)      # [B*N]
    rep = lambda a: jnp.repeat(jnp.asarray(a), N, axis=0)
    folded = lambda p: _map_key_leaves(
        p, rep, lambda k: _fold_stacked(rep(k), svc_ids))
    plain = lambda p: _map_key_leaves(p, rep, rep)
    if isinstance(obj.params, dict):
        params = {k: (plain(v) if k in shared else folded(v))
                  for k, v in obj.params.items()}
    else:
        params = folded(obj.params)
    return obj._replace(params=params, name=f"svc{N}({obj.name})")


def _trace_svc_chunk(params, state, tids, x):
    tr = params["trace"]
    return state, jnp.take(tr, jnp.minimum(tids, tr.shape[0] - 1), axis=0)


def _trace_svc_init(params):
    return ()


def trace_scenario(x, c, B: Optional[int] = None, svc=None,
                   side=None) -> Scenario:
    """Deterministic playback of recorded observations through the fused
    engine (g-curve pipelines, real traces).  ``svc`` rides as a [B, T, K]
    trace when given."""
    arr = _streams.trace_arrivals(x, B=B, side=side)
    B_eff = arr.params["trace"].shape[0]
    rent = _streams.trace_rents(c, B=B_eff)
    svc_stream = None
    if svc is not None:
        svc_arr = jnp.asarray(svc)
        if svc_arr.ndim == 2:
            svc_arr = jnp.broadcast_to(svc_arr[None], (B_eff,) + svc_arr.shape)
        svc_stream = Stream("trace", "svc", _trace_svc_init, _trace_svc_chunk,
                            {"trace": svc_arr})
    return combine(arr, rent, svc=svc_stream, name="trace")
