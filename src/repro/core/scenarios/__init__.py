"""Composable on-device workload generators fused into the fleet scan.

``Scenario`` mirrors ``PolicyFns``: a pure ``(init_fn, chunk_fn)`` pair over
[B]-stacked array params that emits ``[B, chunk]`` observation slabs on
device, deterministically from counter-based PRNG state threaded through
the scan carry.  ``core.fleet.run_fleet(..., scenario=...)`` fuses
generation into the chunked simulation (device memory O(B * chunk), zero
host->device observation transfer) and is bit-identical to materializing
the same scenario and running the classic path.

See ``base`` for the contract, ``streams`` for the migrated generator
families, ``combinators`` for mixtures / regime switching / antithetic
pairing / trace playback.
"""
from repro.core.scenarios.base import (ObsSlab, PRNG_BACKENDS, Scenario,
                                       Stream, as_keys, bcast, materialize,
                                       materialize_stream, shared_keys,
                                       slot_keys, slot_uniform, split_keys)
from repro.core.scenarios.combinators import (antithetic_pairing, combine,
                                              mixture, mixture_from_weights,
                                              regime_switch, replicate_seeds,
                                              tile_services, trace_scenario,
                                              with_prng_backend, with_seed)
from repro.core.scenarios.streams import (adversarial_evict_bait,
                                          adversarial_fetch_bait, arma_rents,
                                          bernoulli_arrivals, bursty_arrivals,
                                          constant_rents, ge_arrivals,
                                          model2_service, na_rents,
                                          poisson_arrivals, spot_bounds,
                                          spot_rents, trace_arrivals,
                                          trace_rents, uniform_rents)

__all__ = [
    "ObsSlab", "PRNG_BACKENDS", "Scenario", "Stream", "as_keys", "bcast",
    "materialize", "materialize_stream", "shared_keys", "slot_keys",
    "slot_uniform", "split_keys",
    "antithetic_pairing", "combine", "mixture", "mixture_from_weights",
    "regime_switch", "replicate_seeds", "tile_services", "trace_scenario",
    "with_prng_backend", "with_seed",
    "adversarial_evict_bait", "adversarial_fetch_bait", "arma_rents",
    "bernoulli_arrivals", "bursty_arrivals", "constant_rents", "ge_arrivals",
    "model2_service", "na_rents", "poisson_arrivals", "spot_bounds",
    "spot_rents", "trace_arrivals", "trace_rents", "uniform_rents",
]
