"""Asynchronous slab ingestion for the streaming fleet drivers.

The ``stream=True`` drivers in ``core/fleet.py`` feed one [B, chunk] slab
per iteration to a pre-compiled device step.  Synchronously, every
iteration serializes host work (trace/obs slicing, dtype casts, the
host->device put) with device compute.  ``SlabPrefetcher`` overlaps them:
a daemon thread runs ``make_slab(i)`` for chunk ``n+1`` — the numpy
slicing plus ``jnp.asarray`` device puts — while the main thread blocks
inside the XLA execute for chunk ``n`` (which releases the GIL, so the
overlap is real even on CPU).

Correctness contract: ``make_slab`` must be a pure function of the chunk
index (the streaming drivers' slab builders are — they slice host-resident
arrays), and slabs are delivered strictly in index order, so an async feed
is **bit-identical** to the synchronous loop it replaces.  The bounded
queue (``depth`` slabs, default 2 = classic double buffering) caps device
memory at O(depth * B * chunk) for in-flight slabs.

Worker exceptions propagate to the consumer at the next ``__iter__``
step; ``close()`` (also via context manager exit) stops the worker early
without joining on a full queue.

**Multi-host**: nothing here changes on a process-spanning mesh — each
process runs its OWN prefetcher over its OWN [B_local, chunk] rows.  The
drivers' slab builders call ``jax.make_array_from_process_local_data``,
which is metadata-only (no collective, no cross-host bytes), so it is
safe on the prefetch thread and the zero-cross-host-obs-bytes property
of sharded ingestion is preserved under overlap.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator


class SlabPrefetcher:
    """Double-buffered background slab preparation.

    Iterating yields ``make_slab(0), make_slab(1), ..., make_slab(n_chunks
    - 1)`` in order, each prepared on the worker thread up to ``depth``
    chunks ahead of the consumer.
    """

    def __init__(self, make_slab: Callable[[int], object], n_chunks: int,
                 depth: int = 2):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._n = int(n_chunks)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            try:
                for i in range(self._n):
                    if self._stop.is_set():
                        return
                    slab = make_slab(i)
                    # bounded put with a stop check so close() never
                    # deadlocks against a full queue
                    while not self._stop.is_set():
                        try:
                            self._q.put((slab, None), timeout=0.05)
                            break
                        except queue.Full:
                            continue
            except BaseException as exc:  # propagate to the consumer
                self._q.put((None, exc))

        self._thread = threading.Thread(target=worker, daemon=True,
                                        name="slab-prefetch")
        self._thread.start()

    def __iter__(self) -> Iterator:
        for _ in range(self._n):
            slab, exc = self._q.get()
            if exc is not None:
                self.close()
                raise exc
            yield slab

    def close(self) -> None:
        """Stop the worker (idempotent); pending slabs are dropped."""
        self._stop.set()
        while True:  # unblock a worker stuck on put()
            try:
                self._q.get_nowait()
            except queue.Empty:
                break

    def __enter__(self) -> "SlabPrefetcher":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()


def slab_feed(make_slab: Callable[[int], object], n_chunks: int,
              async_ingest: bool, depth: int = 2) -> Iterator:
    """The one slab source every streaming driver uses: ``make_slab(i)``
    for each chunk, prefetched on a background thread when ``async_ingest``
    (bit-identical either way — same slabs, same order)."""
    if async_ingest:
        return iter(SlabPrefetcher(make_slab, n_chunks, depth=depth))
    return (make_slab(i) for i in range(n_chunks))
