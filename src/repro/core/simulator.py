"""Slotted hosting simulator: one scan per instance, one jit(vmap(scan)) per
*fleet*.

Conventions (paper §2.5/§2.6):
  * slots are 1..T; ``r_hist[t]`` is the level *held during* slot t
    (r_1 = 0 for all online policies);
  * per-slot cost = rent + service while holding, plus fetch
    ``M * (lv[r_{t+1}] - lv[r_t])^+`` paid when the policy upgrades for the
    next slot.  Online policies also pay for a final upgrade decided at slot
    T (they cannot know the horizon ended); offline policies never upgrade
    at T.  ``evaluate_schedule`` charges fetches on entry so both styles are
    scored identically.

Batched / fleet engine
----------------------
Policies are pure ``(init_fn, step_fn)`` pairs over a pytree of array
params (see ``policies/base.py``).  ``run_policy`` runs ONE instance;
``run_policy_batch`` takes a ``PolicyFns`` whose params carry a leading
[B] axis (built by the policies' ``.batch`` classmethods from a stacked
``costs.HostingGrid``) plus [B, T]-shaped observations, and runs all B
independent hosting problems as a single compiled ``jit(vmap(scan))``.
``core/fleet.py`` layers device sharding (``shard_map`` over the ``fleet``
mesh axis), mixed per-instance horizons, T-chunked streaming, and fused
on-device workload generation (``run_fleet(scenario=...)`` feeds
``sim_chunk_core`` slabs emitted by a ``core.scenarios.Scenario`` inside
the scan instead of slices of a resident obs array — bit-identical, with
O(B * chunk) device memory) on top.

The shared kernel is ``sim_chunk_core``: it scans a ``[t0, t0 + chunk)``
slot window carrying ``(policy state, accumulator)``, so chaining it over
chunks reduces in exactly the same sequential order as one long scan
(chunked == unchunked bit-for-bit), and its valid-slot mask freezes state /
adds exactly 0.0 past an instance's own horizon (mixed-T batches match
per-instance runs bit-for-bit).  The whole-horizon entry points here are
its one-chunk, full-T_len special case.

Mixed-K batches are padded to a common K with a validity ``mask`` (see
``HostingGrid``); padded levels cost ``+BIG``/``+inf`` so they are never
selected, which makes batched level indices mean exactly what they mean in
the unpadded per-instance run — ``run_policy_batch`` output matches
``run_policy`` bit-for-bit instance by instance (tests/test_batched_engine,
tests/test_fleet_engine).

All entry points finish with one *fused* device reduction: the [3] totals
vector (rent/service/fetch), the [K] level-occupancy histogram and the
trace leave the device in a single transfer instead of four ``jnp.sum``
round-trips plus a host-side ``np.bincount``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import HostingCosts, HostingGrid, default_float_dtype
from repro.core.policies.base import (OnlinePolicy, PolicyFns, SlotObs,
                                      freeze_invalid)


@dataclasses.dataclass
class SimResult:
    total: float
    fetch: float
    rent: float
    service: float
    r_hist: np.ndarray        # [T] int level indices
    level_slots: np.ndarray   # [K] #slots spent at each level (the histograms)
    route: float = 0.0        # routing-cost term (``route=`` runs only)

    @property
    def per_slot(self) -> float:
        return self.total / len(self.r_hist)


@dataclasses.dataclass
class BatchSimResult:
    """[B]-structured results of one batched simulation."""

    total: np.ndarray         # [B]
    fetch: np.ndarray         # [B]
    rent: np.ndarray          # [B]
    service: np.ndarray      # [B]
    r_hist: np.ndarray        # [B, T] int level indices
    level_slots: np.ndarray   # [B, K] slots spent at each level

    @property
    def B(self) -> int:
        return self.total.shape[0]

    @property
    def per_slot(self) -> np.ndarray:
        return self.total / self.r_hist.shape[1]

    def instance(self, i: int) -> SimResult:
        return SimResult(total=float(self.total[i]), fetch=float(self.fetch[i]),
                         rent=float(self.rent[i]), service=float(self.service[i]),
                         r_hist=self.r_hist[i], level_slots=self.level_slots[i])


def _obs_arrays(costs: HostingCosts, x, c, svc, side):
    dt = default_float_dtype()
    x = jnp.asarray(x, jnp.int32)
    c = jnp.asarray(c, dt)
    T = x.shape[0]
    if svc is None:
        gv = jnp.asarray(costs.g, dt)
        svc = x[:, None].astype(dt) * gv[None, :]
    else:
        svc = jnp.asarray(svc, dt)
    if side is None:
        side = jnp.zeros((T,), jnp.int32)
    return x, c, svc, side


# ----------------------------------------------------------------------
# Fused simulation core (shared by the single, the batched and the fleet
# entry points).
# ----------------------------------------------------------------------

def sim_acc0(K: int, dt, n_sums: int = 3) -> dict:
    """Zero accumulator for the in-carry reductions: [3] rent/service/fetch
    sums (plus a 4th routing slot when the chunk runs with ``route=``) and
    the [K] level-occupancy histogram."""
    return {"sums": jnp.zeros((n_sums,), dt),
            "counts": jnp.zeros((K,), jnp.int32)}


def _fetch_between(M, K, r_from, r_to, lv_from, lv_to):
    """Fetch cost of the transition ``r_from -> r_to``.

    Scalar ``M`` is the paper's rank-one form ``M * (lv_to - lv_from)^+``;
    a matrix ``M`` ([K, K] per instance, see ``HostingGrid``'s
    "Matrix-valued M") prices the transition explicitly — the joint
    multi-service grids of ``costs.ServiceSet``.  The branch is static
    (ndim at trace time), so scalar-M programs are op-for-op what they
    were before the matrix form existed."""
    if jnp.ndim(M) >= 2:
        sel = (jnp.arange(K) == r_from)[:, None] & \
              (jnp.arange(K) == r_to)[None, :]
        return jnp.sum(jnp.where(sel, M, 0.0))
    return M * jnp.maximum(lv_to - lv_from, 0.0)


def sim_chunk_core(step_fn, include_final_fetch: bool,
                   params, lv, M, T_len, t0, carry, x, c, svc, side,
                   route=None):
    """Scan slots ``[t0, t0 + chunk)`` of ONE instance, carrying
    ``(policy state, accumulator)`` across chunk boundaries.

    This is the fleet engine's unit of work (``core/fleet.py`` chains it over
    T-chunks and vmaps/shard_maps it over instances); the whole-horizon run
    is the one-chunk special case.  Two masking rules make mixed horizons and
    chunking exact:

      * **valid slots** — global slot index ``t < T_len`` (``T_len`` is this
        instance's own horizon).  Invalid (padded-tail) slots add exactly
        ``0.0`` to every accumulator and leave the policy state *frozen*, so
        a fleet instance stops evolving at its own T and padded tails are a
        bitwise no-op (float ``a + 0.0 == a`` for the finite, non-negative
        costs here).
      * **last slot** — ``t == T_len - 1``: the speculative final fetch is
        zeroed here when ``include_final_fetch=False`` (per-instance, so
        mixed-T batches charge each instance at its own horizon).

    ``route`` (optional) is a ``[chunk, K]`` per-level routing-cost slab
    (2107.10446's request-routing term: what the slot's requests cost to
    route given each hosting level); it accumulates as a 4th ``sums`` slot
    selected by the SAME one-hot as the service channel.  ``route=None``
    (the default everywhere in the fleet engine) leaves the scan inputs
    and the [3] cost vector literally as they were — bitwise no-op.
    Matrix-valued ``M`` prices fetches explicitly (``_fetch_between``).

    The running totals ride along in the scan carry — strictly sequential
    accumulation, so the vmapped batch reduces in exactly the same order as a
    single run, and a chunked run in exactly the same order as an unchunked
    one (a post-hoc ``jnp.sum`` is not: XLA picks a different reduction tree
    for [B, T] than for [T]).

    Returns ``(carry', r_hist [chunk])``.
    """
    K = lv.shape[-1]
    chunk = x.shape[-1]
    tids = t0 + jnp.arange(chunk, dtype=jnp.int32)

    def step(carry, inp):
        state, acc = carry
        if route is None:
            t, x_t, c_t, svc_t, side_t = inp
        else:
            t, x_t, c_t, svc_t, side_t, route_t = inp
        valid_t = t < T_len
        last_t = t == T_len - 1
        r_t = state["r"]
        # one-hot selections instead of gathers/scatters: bit-identical, but
        # elementwise ops vectorise across the vmapped instance axis where
        # per-row dynamic indexing does not (see alpha_rr_step)
        onehot_t = jnp.arange(K) == r_t
        lv_t = jnp.sum(jnp.where(onehot_t, lv, 0.0))
        rent_t = c_t * lv_t
        svc_cost_t = jnp.sum(jnp.where(onehot_t, svc_t, 0.0))
        new_state = step_fn(params, state, SlotObs(x_t, c_t, svc_t, side_t))
        new_state = freeze_invalid(valid_t, new_state, state)
        r_next = new_state["r"]
        lv_next = jnp.sum(jnp.where(jnp.arange(K) == r_next, lv, 0.0))
        fetch_t = _fetch_between(M, K, r_t, r_next, lv_t, lv_next)
        if not include_final_fetch:
            fetch_t = jnp.where(last_t, 0.0, fetch_t)
        if route is None:
            vec = jnp.stack([rent_t, svc_cost_t, fetch_t])
        else:
            route_cost_t = jnp.sum(jnp.where(onehot_t, route_t, 0.0))
            vec = jnp.stack([rent_t, svc_cost_t, fetch_t, route_cost_t])
        acc = {
            "sums": acc["sums"] + jnp.where(valid_t, vec, 0.0),
            "counts": acc["counts"]
                      + jnp.where(valid_t, onehot_t.astype(jnp.int32), 0),
        }
        return (new_state, acc), r_t

    xs = (tids, x, c, svc, side)
    if route is not None:
        xs = xs + (route,)
    return jax.lax.scan(step, carry, xs)


def sim_chunk_lanes(step_fns, include_final_fetch: bool,
                    lane_params, lane_lv, lane_M, T_len, t0, carries,
                    x, c, lane_svc, side):
    """Step P heterogeneous policy *lanes* over ONE shared ``[chunk]`` obs
    slab — the stacked-policy carry path of the fan-out axis.

    ``carries`` is a tuple of per-lane ``(state, acc)`` pytrees (states are
    heterogeneous — different policies, different K — so a tuple, never a
    stacked array).  Each lane's slabs differ only in the per-level service
    channel (``lane_svc[p]`` is [chunk, K_p]: Model-1 prices from the lane's
    own g, Model-2 gathers the lane's columns out of the shared slab); x, c
    and side are the single generated stream.  Every lane is literally one
    ``sim_chunk_core`` call — the same op chain, the same in-carry reduction
    order, the same ``freeze_invalid`` masking as its standalone run — so
    fan-out == standalone holds *by construction*, not by accident of
    compilation.

    Returns ``(carries', r_hists)`` — tuples of per-lane chunk results.
    """
    new_carries, r_hists = [], []
    for step_fn, params, lv, M, carry, svc in zip(
            step_fns, lane_params, lane_lv, lane_M, carries, lane_svc):
        carry, r = sim_chunk_core(step_fn, include_final_fetch, params, lv, M,
                                  T_len, t0, carry, x, c, svc, side)
        new_carries.append(carry)
        r_hists.append(r)
    return tuple(new_carries), tuple(r_hists)


def _sim_core(init_fn, step_fn, include_final_fetch: bool,
              params, lv, M, x, c, svc, side, route=None):
    """One instance, whole horizon: the one-chunk case of ``sim_chunk_core``.

    Returns (r_hist [T], sums [3] = rent/service/fetch ([4] with a routing
    slab), counts [K]).
    """
    K = lv.shape[-1]
    T = x.shape[-1]
    carry0 = (init_fn(params),
              sim_acc0(K, lv.dtype, 3 if route is None else 4))
    (_, acc), r_hist = sim_chunk_core(
        step_fn, include_final_fetch, params, lv, M,
        jnp.asarray(T, jnp.int32), jnp.asarray(0, jnp.int32), carry0,
        x, c, svc, side, route)
    return r_hist, acc["sums"], acc["counts"]


@functools.lru_cache(maxsize=64)
def _compiled_core(init_fn, step_fn, include_final_fetch: bool, batched: bool,
                   has_route: bool = False):
    # has_route only keys the cache: a route-carrying call re-traces with
    # the extra operand, so it must not share a wrapper with routing-free
    # callers (whose traced program stays exactly the pre-routing one)
    core = functools.partial(_sim_core, init_fn, step_fn, include_final_fetch)
    if batched:
        core = jax.vmap(core)
    return jax.jit(core)


def run_policy(policy: OnlinePolicy, costs: HostingCosts, x, c,
               svc=None, side=None, include_final_fetch: bool = True,
               route=None) -> SimResult:
    """Simulate an online policy over the whole horizon (one instance).

    ``route`` (optional [T, K]) adds the per-level routing-cost term to the
    accounting (``SimResult.route``); omitted, the program is bitwise the
    routing-free one."""
    x, c, svc, side = _obs_arrays(costs, x, c, svc, side)
    dt = default_float_dtype()
    lv = jnp.asarray(costs.levels, dt)
    M = jnp.asarray(costs.M, dt)
    fns = policy.fns()
    args = () if route is None else (jnp.asarray(route, dt),)
    if fns.params is not None:
        core = _compiled_core(fns.init_fn, fns.step_fn, include_final_fetch,
                              False, route is not None)
    else:
        # legacy policy subclass (bound init/step, no pure pair): fresh
        # closures can't key a compile cache — run the same core uncompiled.
        core = functools.partial(_sim_core, fns.init_fn, fns.step_fn,
                                 include_final_fetch)
    r_hist, sums, counts = core(fns.params, lv, M, x, c, svc, side, *args)
    r_np = np.asarray(r_hist)
    sums = np.asarray(sums)
    rent_s, svc_s, fetch_s = (float(v) for v in sums[:3])
    route_s = float(sums[3]) if route is not None else 0.0
    return SimResult(
        total=rent_s + svc_s + fetch_s + route_s,
        fetch=fetch_s, rent=rent_s, service=svc_s,
        r_hist=r_np,
        level_slots=np.asarray(counts).astype(np.int64),
        route=route_s,
    )


def _batch_obs(grid: HostingGrid, x, c, svc, side):
    """Broadcast observations to [B, T] / [B, T, K] stacked form."""
    dt = default_float_dtype()
    B = grid.B
    x = jnp.asarray(x, jnp.int32)
    if x.ndim == 1:
        x = jnp.broadcast_to(x[None, :], (B, x.shape[0]))
    T = x.shape[1]
    c = jnp.asarray(c, dt)
    if c.ndim == 1:
        c = jnp.broadcast_to(c[None, :], (B, T))
    if svc is None:
        svc = x[:, :, None].astype(dt) * grid.g.astype(dt)[:, None, :]
    else:
        svc = jnp.asarray(svc, dt)
        if svc.ndim == 2:
            svc = jnp.broadcast_to(svc[None, :, :], (B,) + svc.shape)
    if side is None:
        side = jnp.zeros((B, T), jnp.int32)
    else:
        side = jnp.asarray(side, jnp.int32)
        if side.ndim == 1:
            side = jnp.broadcast_to(side[None, :], (B, T))
    return x, c, svc, side


def run_policy_batch(policy: PolicyFns, grid: HostingGrid, x, c,
                     svc=None, side=None,
                     include_final_fetch: bool = True) -> BatchSimResult:
    """Simulate B independent hosting instances as one ``jit(vmap(scan))``.

    Args:
      policy: pure-function policy batch (``AlphaRR.batch(grid)``, ...);
        every params leaf carries a leading [B] axis.
      grid: the stacked instances the *accounting* runs on.  Must match the
        grid the policy batch was built from (for RR-style restrictions,
        pass the restricted grid, e.g. ``grid.restrict_to_endpoints()``).
      x: [T] or [B, T] arrivals ([T] broadcasts across the batch).
      c: [T] or [B, T] rent costs.
      svc: optional [B, T, K] (or [T, K]) realized service costs; None means
        Model 1 (``g * x``) on each instance's own g row.
      side: optional [T] or [B, T] side-channel.

    Returns a ``BatchSimResult`` with one fused device->host transfer for
    all totals and histograms.
    """
    x, c, svc, side = _batch_obs(grid, x, c, svc, side)
    dt = default_float_dtype()
    core = _compiled_core(policy.init_fn, policy.step_fn, include_final_fetch,
                          True)
    r_hist, sums, counts = core(policy.params, grid.levels.astype(dt),
                                grid.M.astype(dt), x, c, svc, side)
    # float64 accumulation to match the scalar path's host-side addition
    sums = np.asarray(sums).astype(np.float64)    # [B, 3]
    return BatchSimResult(
        total=sums.sum(axis=1),
        rent=sums[:, 0], service=sums[:, 1], fetch=sums[:, 2],
        r_hist=np.asarray(r_hist),
        level_slots=np.asarray(counts).astype(np.int64),
    )


# ----------------------------------------------------------------------
# Schedule evaluation (offline schedules are arrays, not policies).
# ----------------------------------------------------------------------

def schedule_chunk_core(lv, M, T_len, t0, carry, r, c, svc, route=None):
    """Chunk of schedule evaluation for ONE instance; ``carry`` is
    ``(prev level entering the chunk, accumulator)``.

    Same sequential in-scan accumulation and the same valid-slot masking as
    ``sim_chunk_core``, for the same reasons: batched / single / chunked /
    unchunked evaluations must all reduce in the same order, and slots past
    an instance's own ``T_len`` must be bitwise no-ops (the held level is
    frozen too, so a padded tail never charges a fetch).  ``route`` and
    matrix-valued ``M`` behave exactly as in ``sim_chunk_core``.
    """
    K = lv.shape[-1]
    chunk = r.shape[-1]
    tids = t0 + jnp.arange(chunk, dtype=jnp.int32)

    def step(carry, inp):
        prev_t, acc = carry
        if route is None:
            t, r_t, c_t, svc_t = inp
        else:
            t, r_t, c_t, svc_t, route_t = inp
        valid_t = t < T_len
        onehot_t = jnp.arange(K) == r_t
        lv_t = jnp.sum(jnp.where(onehot_t, lv, 0.0))
        lv_prev = jnp.sum(jnp.where(jnp.arange(K) == prev_t, lv, 0.0))
        fetch_t = _fetch_between(M, K, prev_t, r_t, lv_prev, lv_t)
        rent_t = c_t * lv_t
        svc_cost_t = jnp.sum(jnp.where(onehot_t, svc_t, 0.0))
        if route is None:
            vec = jnp.stack([rent_t, svc_cost_t, fetch_t])
        else:
            route_cost_t = jnp.sum(jnp.where(onehot_t, route_t, 0.0))
            vec = jnp.stack([rent_t, svc_cost_t, fetch_t, route_cost_t])
        acc = {
            "sums": acc["sums"] + jnp.where(valid_t, vec, 0.0),
            "counts": acc["counts"]
                      + jnp.where(valid_t, onehot_t.astype(jnp.int32), 0),
        }
        prev_next = jnp.where(valid_t, r_t, prev_t).astype(jnp.int32)
        return (prev_next, acc), None

    xs = (tids, r, c, svc)
    if route is not None:
        xs = xs + (route,)
    return jax.lax.scan(step, carry, xs)


def _schedule_core(lv, M, r, x, c, svc, route=None):
    K = lv.shape[-1]
    T = r.shape[-1]
    carry0 = (jnp.asarray(0, jnp.int32),
              sim_acc0(K, lv.dtype, 3 if route is None else 4))
    (_, acc), _ = schedule_chunk_core(
        lv, M, jnp.asarray(T, jnp.int32), jnp.asarray(0, jnp.int32), carry0,
        r, c, svc, route)
    return acc["sums"], acc["counts"]


_schedule_one = jax.jit(_schedule_core)
_schedule_vmapped = jax.jit(jax.vmap(_schedule_core))


def evaluate_schedule(costs: HostingCosts, r_hist, x, c, svc=None,
                      route=None) -> SimResult:
    """Cost of an arbitrary hosting schedule ``r_hist`` ([T] level indices,
    entered from r=0 before slot 1; fetches charged on entry to each slot).
    ``route`` (optional [T, K]) adds the routing-cost term."""
    x, c, svc, _ = _obs_arrays(costs, x, c, svc, None)
    dt = default_float_dtype()
    lv = jnp.asarray(costs.levels, dt)
    r = jnp.asarray(r_hist, jnp.int32)
    args = () if route is None else (jnp.asarray(route, dt),)
    sums, counts = _schedule_one(lv, jnp.asarray(costs.M, dt), r, x, c, svc,
                                 *args)
    sums = np.asarray(sums)
    rent_s, svc_s, fetch_s = (float(v) for v in sums[:3])
    route_s = float(sums[3]) if route is not None else 0.0
    return SimResult(
        total=rent_s + svc_s + fetch_s + route_s,
        fetch=fetch_s, rent=rent_s, service=svc_s,
        r_hist=np.asarray(r),
        level_slots=np.asarray(counts).astype(np.int64),
        route=route_s,
    )


def evaluate_schedule_batch(grid: HostingGrid, r_hist, x, c,
                            svc=None) -> BatchSimResult:
    """Batched ``evaluate_schedule``: ``r_hist`` is [B, T]."""
    x, c, svc, _ = _batch_obs(grid, x, c, svc, None)
    dt = default_float_dtype()
    r = jnp.asarray(r_hist, jnp.int32)
    sums, counts = _schedule_vmapped(grid.levels.astype(dt),
                                     grid.M.astype(dt), r, x, c, svc)
    sums = np.asarray(sums).astype(np.float64)
    return BatchSimResult(
        total=sums.sum(axis=1),
        rent=sums[:, 0], service=sums[:, 1], fetch=sums[:, 2],
        r_hist=np.asarray(r),
        level_slots=np.asarray(counts).astype(np.int64),
    )


def model2_service_matrix(key, costs: HostingCosts, x, max_per_slot: int | None = None):
    """Realized Model-2 service costs, coupled across levels (one uniform per
    request; forwarded at level k iff u < g[k]).  Returns [T, K]."""
    x = jnp.asarray(x, jnp.int32)
    T = int(x.shape[0])
    R = int(max_per_slot if max_per_slot is not None else max(int(jnp.max(x)), 1))
    u = jax.random.uniform(key, (T, R))
    gv = jnp.asarray(costs.g, default_float_dtype())
    live = jnp.arange(R)[None, :] < x[:, None]              # [T, R]
    fwd = u[:, :, None] < gv[None, None, :]                 # [T, R, K]
    return jnp.sum(jnp.where(live[:, :, None] & fwd, 1.0, 0.0), axis=1)  # [T, K]
