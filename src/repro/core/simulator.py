"""Slotted hosting simulator (jax.lax.scan) + schedule evaluator.

Conventions (paper §2.5/§2.6):
  * slots are 1..T; ``r_hist[t]`` is the level *held during* slot t
    (r_1 = 0 for all online policies);
  * per-slot cost = rent + service while holding, plus fetch
    ``M * (lv[r_{t+1}] - lv[r_t])^+`` paid when the policy upgrades for the
    next slot.  Online policies also pay for a final upgrade decided at slot
    T (they cannot know the horizon ended); offline policies never upgrade
    at T.  ``evaluate_schedule`` charges fetches on entry so both styles are
    scored identically.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import HostingCosts, per_slot_cost_matrix
from repro.core.policies.base import OnlinePolicy, SlotObs


@dataclasses.dataclass
class SimResult:
    total: float
    fetch: float
    rent: float
    service: float
    r_hist: np.ndarray        # [T] int level indices
    level_slots: np.ndarray   # [K] #slots spent at each level (the histograms)

    @property
    def per_slot(self) -> float:
        return self.total / len(self.r_hist)


def _obs_arrays(costs: HostingCosts, x, c, svc, side):
    x = jnp.asarray(x, jnp.int32)
    c = jnp.asarray(c, jnp.float32)
    T = x.shape[0]
    if svc is None:
        gv = jnp.asarray(costs.g, jnp.float32)
        svc = x[:, None].astype(jnp.float32) * gv[None, :]
    else:
        svc = jnp.asarray(svc, jnp.float32)
    if side is None:
        side = jnp.zeros((T,), jnp.int32)
    return x, c, svc, side


def run_policy(policy: OnlinePolicy, costs: HostingCosts, x, c,
               svc=None, side=None, include_final_fetch: bool = True) -> SimResult:
    """Simulate an online policy over the whole horizon."""
    x, c, svc, side = _obs_arrays(costs, x, c, svc, side)
    lv = jnp.asarray(costs.levels, jnp.float32)
    T = x.shape[0]

    def step(carry, inp):
        state = carry
        x_t, c_t, svc_t, side_t = inp
        r_t = state["r"]
        rent_t = c_t * lv[r_t]
        svc_cost_t = svc_t[r_t]
        new_state = policy.step(state, SlotObs(x_t, c_t, svc_t, side_t))
        r_next = new_state["r"]
        fetch_t = costs.M * jnp.maximum(lv[r_next] - lv[r_t], 0.0)
        return new_state, (r_t, rent_t, svc_cost_t, fetch_t)

    state0 = policy.init()
    _, (r_hist, rent, svc_cost, fetch) = jax.lax.scan(
        step, state0, (x, c, svc, side))
    if not include_final_fetch:
        fetch = fetch.at[-1].set(0.0)
    r_np = np.asarray(r_hist)
    counts = np.bincount(r_np, minlength=costs.K).astype(np.int64)
    return SimResult(
        total=float(jnp.sum(rent) + jnp.sum(svc_cost) + jnp.sum(fetch)),
        fetch=float(jnp.sum(fetch)),
        rent=float(jnp.sum(rent)),
        service=float(jnp.sum(svc_cost)),
        r_hist=r_np,
        level_slots=counts,
    )


def evaluate_schedule(costs: HostingCosts, r_hist, x, c, svc=None) -> SimResult:
    """Cost of an arbitrary hosting schedule ``r_hist`` ([T] level indices,
    entered from r=0 before slot 1; fetches charged on entry to each slot)."""
    x, c, svc, _ = _obs_arrays(costs, x, c, svc, None)
    lv = jnp.asarray(costs.levels, jnp.float32)
    r = jnp.asarray(r_hist, jnp.int32)
    prev = jnp.concatenate([jnp.zeros((1,), jnp.int32), r[:-1]])
    fetch = costs.M * jnp.maximum(lv[r] - lv[prev], 0.0)
    rent = c * lv[r]
    svc_cost = jnp.take_along_axis(svc, r[:, None], axis=1)[:, 0]
    r_np = np.asarray(r)
    counts = np.bincount(r_np, minlength=costs.K).astype(np.int64)
    return SimResult(
        total=float(jnp.sum(fetch) + jnp.sum(rent) + jnp.sum(svc_cost)),
        fetch=float(jnp.sum(fetch)),
        rent=float(jnp.sum(rent)),
        service=float(jnp.sum(svc_cost)),
        r_hist=r_np,
        level_slots=counts,
    )


def model2_service_matrix(key, costs: HostingCosts, x, max_per_slot: int | None = None):
    """Realized Model-2 service costs, coupled across levels (one uniform per
    request; forwarded at level k iff u < g[k]).  Returns [T, K]."""
    x = jnp.asarray(x, jnp.int32)
    T = int(x.shape[0])
    R = int(max_per_slot if max_per_slot is not None else max(int(jnp.max(x)), 1))
    u = jax.random.uniform(key, (T, R))
    gv = jnp.asarray(costs.g, jnp.float32)
    live = jnp.arange(R)[None, :] < x[:, None]              # [T, R]
    fwd = u[:, :, None] < gv[None, None, :]                 # [T, R, K]
    return jnp.sum(jnp.where(live[:, :, None] & fwd, 1.0, 0.0), axis=1)  # [T, K]
