"""Core library: the paper's contribution (partial service hosting at the
edge, alpha-RetroRenting and its analysis) as composable JAX modules."""
from repro.core.costs import HostingCosts
from repro.core.simulator import (run_policy, evaluate_schedule, SimResult,
                                  model2_service_matrix)
from repro.core.fleet import (FleetBatch, FleetResult, mc_stats, mc_summary,
                              run_fleet, offline_opt_fleet,
                              evaluate_schedule_fleet)
from repro.core import arrivals, rentcosts, bounds, gcurve

__all__ = [
    "HostingCosts", "run_policy", "evaluate_schedule", "SimResult",
    "model2_service_matrix", "FleetBatch", "FleetResult", "run_fleet",
    "offline_opt_fleet", "evaluate_schedule_fleet", "mc_stats", "mc_summary",
    "arrivals", "rentcosts", "bounds", "gcurve",
]
