"""Trace-time sharding context.

Model code is mesh-agnostic; distributed paths (shard_map MoE dispatch,
flash-decode KV sharding) need to know the active mesh + batch axes.  Step
builders install this context inside the step function body so it is live
exactly while jit traces the model.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

from jax.sharding import Mesh

_TLS = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    batch_axes: Tuple[str, ...]         # mesh axes the batch dim is sharded over
    model_axis: Optional[str] = "model"

    @property
    def tp(self) -> int:
        if self.model_axis and self.model_axis in self.mesh.shape:
            return self.mesh.shape[self.model_axis]
        return 1

    @property
    def dp(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n


def current_ctx() -> Optional[ShardCtx]:
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def shard_ctx(mesh: Mesh, batch_axes: Tuple[str, ...], model_axis="model"):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = ShardCtx(mesh=mesh, batch_axes=tuple(batch_axes), model_axis=model_axis)
    try:
        yield _TLS.ctx
    finally:
        _TLS.ctx = prev
