"""Expert-parallel MoE dispatch under shard_map.

Layout at entry: activations x [B, S, D] sharded over the batch axes and
*replicated* over ``model``; expert weights [E, D, F] sharded over ``model``
(E_loc = E/tp experts per rank).  Because every model rank already holds its
data-row's tokens, dispatch needs **no token exchange at all**: each rank
gathers the tokens routed to its local experts (a local sort), runs its
expert GEMMs, scatters contributions back, and a single psum over ``model``
combines — the same one all-reduce a dense TP MLP pays.  The global-sort
collective pathology of naive GSPMD dispatch disappears.

(An all-to-all variant for fully token-sharded activations is the documented
next step in EXPERIMENTS.md §Perf; this gather+psum scheme is what the
baseline lowers.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.sharding.context import ShardCtx


def _local_expert_pass(x_loc, w, ids, w_gate, w_in, w_out, expert_mask_loc,
                       e0, e_total, capacity):
    """x_loc [N, D]; w/ids [N, k]; w_* [E_loc, ...]. Returns partial y [N, D]
    containing only the local experts' contributions."""
    n, d = x_loc.shape
    k = ids.shape[1]
    e_loc = w_in.shape[0]
    nk = n * k

    mine = (ids >= e0) & (ids < e0 + e_loc)
    le = jnp.where(mine, ids - e0, e_loc)            # e_loc = trash bucket
    le_flat = le.reshape(nk)
    tok_flat = jnp.repeat(jnp.arange(n), k)
    w_flat = w.reshape(nk)

    order = jnp.argsort(le_flat)
    se = le_flat[order]
    st = tok_flat[order]
    sw = w_flat[order]

    counts = jnp.bincount(se, length=e_loc + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(nk) - starts[se]
    keep = (se < e_loc) & (pos < capacity)
    pos_c = jnp.where(keep, pos, capacity - 1)
    se_c = jnp.where(keep, se, 0)

    buf = jnp.zeros((e_loc, capacity, d), x_loc.dtype)
    src = jnp.where(keep[:, None], x_loc[st], 0.0)
    buf = buf.at[se_c, pos_c].add(src)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w_in)
    out = jnp.einsum("ecf,efd->ecd", h, w_out)
    if expert_mask_loc is not None:
        out = out * expert_mask_loc[:, None, None].astype(out.dtype)

    gathered = out[se_c, pos_c]
    contrib = jnp.where(keep[:, None], gathered * sw[:, None].astype(out.dtype), 0.0)
    return jnp.zeros((n, d), out.dtype).at[st].add(contrib)


def moe_shardmap_apply(ctx: ShardCtx, x, w, ids, w_gate, w_in, w_out,
                       expert_mask, capacity_factor: float):
    """x [B, S, D] (batch sharded over ctx.batch_axes, replicated over model);
    w/ids [B, S, k]; expert weights [E, D, F] sharded over model on E."""
    b, s, d = x.shape
    k = ids.shape[-1]
    e_total = w_in.shape[0]
    tp = ctx.tp
    n_loc = (b // ctx.dp) * s
    capacity = int(np.ceil(n_loc * k * capacity_factor / e_total))
    capacity = max(capacity, k, 8)
    baxes = ctx.batch_axes if len(ctx.batch_axes) != 1 else ctx.batch_axes[0]
    bspec = baxes if ctx.batch_axes else None
    ma = ctx.model_axis

    def local_fn(x_l, w_l, ids_l, wg_l, wi_l, wo_l, mask_l):
        bl, sl = x_l.shape[0], x_l.shape[1]
        m = jax.lax.axis_index(ma)
        e0 = m * (e_total // tp)
        y = _local_expert_pass(x_l.reshape(bl * sl, d), w_l.reshape(-1, k),
                               ids_l.reshape(-1, k), wg_l, wi_l, wo_l,
                               mask_l, e0, e_total, capacity)
        y = jax.lax.psum(y, ma)
        return y.reshape(bl, sl, d)

    mask_arg = expert_mask if expert_mask is not None else jnp.ones((e_total,), jnp.float32)
    mask_spec = P(ma)

    fn = shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(bspec, None, None), P(bspec, None, None), P(bspec, None, None),
                  P(ma, None, None), P(ma, None, None), P(ma, None, None),
                  mask_spec),
        out_specs=P(bspec, None, None),
        check_rep=False,
    )
    return fn(x, w, ids, w_gate, w_in, w_out,
              mask_arg if expert_mask is not None else mask_arg)
