"""jax.distributed lifecycle + a single-machine multi-process test harness.

The fleet engine scales the instance axis across hosts by letting the 1-D
``fleet`` mesh span *processes*: ``jax.devices()`` is global once
``jax.distributed`` is initialized, so ``sharding.specs.fleet_mesh()``
already covers every process's devices — what this module adds is the
lifecycle around it:

* ``initialize()`` / ``shutdown()`` — idempotent wrappers over
  ``jax.distributed.initialize`` that (a) default the coordinator address,
  process count and process id from the ``REPRO_DIST_*`` environment the
  local-cluster harness sets, and (b) select the ``gloo`` CPU collectives
  layer so cross-process gathers (``gather=True`` readbacks) work on
  CPU-only hosts.  Call ``initialize()`` before the first touch of
  ``jax.devices()``.
* ``run_local_cluster()`` — the ``REPRO_FORCE_PROCESSES=N`` analogue of the
  forced-device trick: spawn N subprocess workers on one machine, each a
  full JAX process with its own ``--xla_force_host_platform_device_count``
  CPU devices, all joined to one coordinator on a freshly-picked local
  port.  Used by ``tests/test_multihost.py`` and the ``multihost_scaling``
  kernel-bench row to prove N-process == 1-process bit-identity without
  real multi-host hardware.

Workers NEVER inherit the parent's JAX runtime: each one is a fresh
``sys.executable`` subprocess, so the parent process (e.g. pytest) can stay
single-process and compute reference results in-process.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

# Environment keys the harness sets for each worker and ``initialize()``
# reads back.  REPRO_FORCE_PROCESSES only sets the harness's default
# process count (mirroring REPRO_FORCE_DEVICES for devices).
ENV_COORD = "REPRO_DIST_COORDINATOR"
ENV_NPROCS = "REPRO_DIST_NUM_PROCESSES"
ENV_PID = "REPRO_DIST_PROCESS_ID"
ENV_FORCE_PROCESSES = "REPRO_FORCE_PROCESSES"

_STATE = {"initialized": False}


def initialize(coordinator_address: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None, *,
               cpu_collectives: str = "gloo") -> bool:
    """Bring up the multi-process JAX runtime.  Arguments default from the
    ``REPRO_DIST_*`` environment (set by ``run_local_cluster`` or a real
    launcher); with no arguments and no environment this is a no-op that
    returns False, so single-process callers can call it unconditionally.

    Returns True iff a multi-process runtime is (now) initialized.
    Idempotent: a second call is a no-op returning the current state.
    """
    if _STATE["initialized"]:
        return True
    coordinator_address = coordinator_address or os.environ.get(ENV_COORD)
    if num_processes is None and ENV_NPROCS in os.environ:
        num_processes = int(os.environ[ENV_NPROCS])
    if process_id is None and ENV_PID in os.environ:
        process_id = int(os.environ[ENV_PID])
    if coordinator_address is None or not (num_processes or 0) > 1:
        return False
    import jax
    # CPU collectives must be picked before the backend initializes; gloo
    # is what makes cross-process psum/allgather work on CPU-only hosts.
    try:
        jax.config.update("jax_cpu_collectives_implementation", cpu_collectives)
    except Exception:
        pass  # option absent on this jax version; distributed may still work
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    _STATE["initialized"] = True
    return True


def shutdown() -> None:
    """Tear down the multi-process runtime started by ``initialize()``.
    Idempotent; a no-op when single-process."""
    if not _STATE["initialized"]:
        return
    import jax
    jax.distributed.shutdown()
    _STATE["initialized"] = False


def is_initialized() -> bool:
    return _STATE["initialized"]


def pick_free_port() -> int:
    """An OS-assigned free TCP port on localhost (bind port 0, read it
    back).  Raceable in principle; in practice the coordinator binds it
    within milliseconds and the harness retries are the workers' own
    jax.distributed connection retries."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return int(s.getsockname()[1])


def default_num_processes(fallback: int = 2) -> int:
    """Harness default process count: ``REPRO_FORCE_PROCESSES`` if set,
    else ``fallback``."""
    return int(os.environ.get(ENV_FORCE_PROCESSES, str(fallback)))


def _src_root() -> str:
    # .../src/repro/sharding/distributed.py -> .../src
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def worker_env(coordinator_address: str, num_processes: int, process_id: int,
               devices_per_process: int = 1,
               extra_env: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """The environment for one local-cluster worker: coordinator wiring via
    ``REPRO_DIST_*``, ``devices_per_process`` forced CPU devices, CPU
    platform pinned, and ``src`` on PYTHONPATH."""
    env = dict(os.environ)
    env.update(extra_env or {})
    env[ENV_COORD] = coordinator_address
    env[ENV_NPROCS] = str(num_processes)
    env[ENV_PID] = str(process_id)
    env["JAX_PLATFORMS"] = "cpu"
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in f]
    kept.append(f"--xla_force_host_platform_device_count={devices_per_process}")
    env["XLA_FLAGS"] = " ".join(kept)
    path = env.get("PYTHONPATH", "")
    src = _src_root()
    if src not in path.split(os.pathsep):
        env["PYTHONPATH"] = src + (os.pathsep + path if path else "")
    return env


def run_local_cluster(worker_argv: Sequence[str],
                      n_processes: Optional[int] = None, *,
                      devices_per_process: int = 1,
                      timeout: float = 600.0,
                      cwd: Optional[str] = None,
                      extra_env: Optional[Dict[str, str]] = None) -> List[str]:
    """Run ``python *worker_argv`` as an ``n_processes``-process local JAX
    cluster and return each worker's stdout (index == process id).

    Every worker gets the same argv and a ``worker_env(...)`` environment;
    workers discover their role via ``repro.sharding.distributed
    .initialize()`` (no arguments).  On ANY worker failure or timeout the
    whole cluster is killed before raising, so no orphan workers hold the
    coordinator port across tests.
    """
    n = n_processes if n_processes is not None else default_num_processes()
    port = pick_free_port()
    coord = f"127.0.0.1:{port}"
    procs: List[subprocess.Popen] = []
    try:
        for pid in range(n):
            env = worker_env(coord, n, pid, devices_per_process, extra_env)
            procs.append(subprocess.Popen(
                [sys.executable, *worker_argv], env=env, cwd=cwd,
                stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
        deadline = time.monotonic() + timeout
        outs: List[str] = []
        errs: List[str] = []
        for pid, p in enumerate(procs):
            left = deadline - time.monotonic()
            if left <= 0:
                raise subprocess.TimeoutExpired(p.args, timeout)
            out, err = p.communicate(timeout=left)
            outs.append(out)
            errs.append(err)
        bad = [pid for pid, p in enumerate(procs) if p.returncode != 0]
        if bad:
            tails = "\n".join(
                f"--- worker {pid} (rc={procs[pid].returncode}) stderr tail ---\n"
                + "\n".join(errs[pid].splitlines()[-15:]) for pid in bad)
            raise RuntimeError(
                f"local cluster workers {bad} failed (n={n}, coord={coord})\n{tails}")
        return outs
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        for p in procs:
            if p.poll() is None:
                try:
                    p.wait(timeout=10)
                except Exception:
                    pass
