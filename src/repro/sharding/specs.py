"""Partitioning rules: param tree -> PartitionSpec tree (DP/TP/EP + pod axis).

Strategy (Megatron-style TP + EP over the ``model`` axis, batch over
``(pod, data)``):

  embed [V, D]           (model, None)   vocab-parallel (falls back to
                                         (None, model) if V not divisible)
  lm_head [D, V]         (None, model)
  attn wq [D, Hq*hd]     (None, model)   column-parallel
  attn wk/wv [D,Hkv*hd]  (None, model) if divisible else replicated (GQA with
                                         few KV heads keeps KV per-group)
  attn wo [Hq*hd, D]     (model, None)   row-parallel (psum after)
  mlp w_in/w_gate [D,F]  (None, model)
  mlp w_out [F, D]       (model, None)
  MoE experts [E, D, F]  (model, None, None)   expert-parallel
  MoE router [D, E]      replicated
  MLA down-proj          replicated (small); up-projs column-parallel
  SSM mixers             replicated (see per-arch notes) — the assigned SSM
                         archs are small; they run DP-only with the batch
                         sharded over (data, model) when divisible.

Stacked (scanned) layers get a leading None axis.  Anything not matched is
replicated.  All rules check divisibility against the actual mesh shape and
fall back to replication rather than failing — the dry-run prints any
fallbacks so they are visible in the roofline notes.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import ModelConfig


def axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        return int(np.prod([axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.shape else 1


def batch_axes(mesh: Mesh, batch: int, allow_model: bool = False) -> Tuple[str, ...]:
    """Largest prefix of (pod, data[, model]) whose product divides batch —
    used to shard the batch dim as widely as the shape allows.  ``model``
    participates only for replicated-param (DP-only) archs."""
    names = ("pod", "data", "model") if allow_model else ("pod", "data")
    axes: List[str] = []
    prod = 1
    for name in names:
        if name in mesh.shape and batch % (prod * mesh.shape[name]) == 0:
            axes.append(name)
            prod *= mesh.shape[name]
    return tuple(axes)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(n for n in ("pod", "data") if n in mesh.shape)


FLEET_AXIS = "fleet"


def fleet_mesh(devices=None) -> Mesh:
    """1-D mesh over all (or the given) devices, axis name ``fleet`` — the
    hosting fleet engine (``core/fleet.py``) shards its [B] instance axis
    over it.  Embarrassingly parallel: no collectives cross this axis.

    **Process-spanning:** once ``repro.sharding.distributed.initialize()``
    has brought up ``jax.distributed``, ``jax.devices()`` is the *global*
    device list, so this mesh spans every process.  Devices are ordered
    ``(process_index, id)`` — process p owns a contiguous block of mesh
    positions, which is what lets ``core/fleet.py`` map process p's local
    rows to global rows ``[p*B_pad_local, (p+1)*B_pad_local)`` and keep
    ingestion host-local (zero cross-host obs bytes)."""
    devs = jax.devices() if devices is None else list(devices)
    devs = sorted(devs, key=lambda d: (d.process_index, d.id))
    return Mesh(np.asarray(devs), (FLEET_AXIS,))


def mesh_process_count(mesh: Mesh) -> int:
    """Number of distinct processes whose devices participate in ``mesh``."""
    return len({d.process_index for d in mesh.devices.flat})


def mesh_is_multiprocess(mesh: Mesh) -> bool:
    return mesh_process_count(mesh) > 1


def mesh_local_device_count(mesh: Mesh) -> int:
    """Devices of ``mesh`` owned by THIS process.  For multi-process fleet
    meshes the engine requires this to be uniform across processes (every
    process contributes the same device count), so per-process row padding
    lines up with a contiguous slice of the global instance axis."""
    import jax as _jax
    me = _jax.process_index()
    n = sum(1 for d in mesh.devices.flat if d.process_index == me)
    n_procs = mesh_process_count(mesh)
    if n_procs > 1 and n * n_procs != mesh.devices.size:
        raise ValueError(
            f"fleet mesh devices are not uniform across processes: "
            f"{mesh.devices.size} total over {n_procs} processes, "
            f"{n} local to process {me}")
    return n


class ShardingRules:
    """Resolves a PartitionSpec for every param leaf of a model config."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, replicate_all: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.tp = axis_size(mesh, "model")
        self.replicate_all = replicate_all
        self.fallbacks: List[str] = []

    def _div(self, dim: int) -> bool:
        return self.tp > 1 and dim % self.tp == 0

    def _col(self, shape, stacked, path=""):
        """Column-parallel: shard last dim over model."""
        if self.replicate_all or not self._div(shape[-1]):
            if not self.replicate_all:
                self.fallbacks.append(f"{path}: out-dim {shape[-1]} !% {self.tp}")
            return P(*([None] * len(shape)))
        return P(*([None] * (len(shape) - 1)), "model")

    def _row(self, shape, stacked, path=""):
        """Row-parallel: shard the first non-stack dim."""
        i = 1 if stacked else 0
        if self.replicate_all or not self._div(shape[i]):
            if not self.replicate_all:
                self.fallbacks.append(f"{path}: in-dim {shape[i]} !% {self.tp}")
            return P(*([None] * len(shape)))
        spec = [None] * len(shape)
        spec[i] = "model"
        return P(*spec)

    def spec_for(self, path: str, shape: Tuple[int, ...], stacked: bool) -> P:
        cfg = self.cfg
        name = path.split("/")[-1]
        if self.replicate_all:
            return P(*([None] * len(shape)))
        # embeddings
        if name == "embed":
            if self._div(shape[0]):
                return P("model", None)
            return self._col(shape, False, path)
        if name == "lm_head":
            return self._col(shape, False, path)
        if name == "frontend_proj":
            return self._col(shape, False, path)
        # attention
        if name in ("wq", "wk", "wv", "w_uq", "w_uk", "w_uv", "bq", "bk", "bv"):
            return self._col(shape, stacked, path)
        if name == "wo":
            return self._row(shape, stacked, path)
        if name in ("w_dq", "w_dkv", "q_norm", "kv_norm", "router"):
            return P(*([None] * len(shape)))
        # MoE experts: [.., E, D, F] -> expert-parallel on E; optionally
        # FSDP-style sharding of the F (w_gate/w_in) or D-in (w_out) dim over
        # the data axis — GSPMD then all-gathers each layer's expert weights
        # just-in-time inside the scan (weight-gather FSDP), which is what
        # lets 236B-scale expert stacks fit 16 GiB chips.
        if name in ("w_gate", "w_in", "w_out") and len(shape) >= 3 + (1 if stacked else 0):
            e_dim = 1 if stacked else 0
            if shape[e_dim] % self.tp == 0 and self.tp > 1:
                spec = [None] * len(shape)
                spec[e_dim] = "model"
                dp = axis_size(self.mesh, "data")
                if getattr(self.cfg, "fsdp_experts", False) and dp > 1:
                    f_dim = len(shape) - 1 if name in ("w_gate", "w_in") else len(shape) - 2
                    if shape[f_dim] % dp == 0:
                        spec[f_dim] = "data"
                return P(*spec)
            self.fallbacks.append(f"{path}: experts {shape[e_dim]} !% {self.tp}")
            return P(*([None] * len(shape)))
        # dense MLP
        if name in ("w_gate", "w_in"):
            return self._col(shape, stacked, path)
        if name == "w_out":
            return self._row(shape, stacked, path)
        # SSM (split projections): per-head tensors shard over model
        if name in ("w_z", "w_x", "w_dt", "conv_x", "conv_x_b",
                    "A_log", "D", "dt_bias", "ssm_norm"):
            return self._col(shape, stacked, path)
        if name == "out_proj":
            return self._row(shape, stacked, path)
        if name in ("w_B", "w_C", "conv_B", "conv_B_b", "conv_C", "conv_C_b"):
            return P(*([None] * len(shape)))   # group-shared, small
        # norms + everything else: replicated
        return P(*([None] * len(shape)))


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape, replicate_all=False):
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    rules = ShardingRules(cfg, mesh, replicate_all=replicate_all)

    def walk(tree, prefix, stacked):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}", stacked or k == "segments")
                    for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            out = [walk(v, f"{prefix}/{i}", stacked) for i, v in enumerate(tree)]
            return type(tree)(out) if isinstance(tree, tuple) else out
        return rules.spec_for(prefix, tree.shape, stacked)

    # "segments" subtrees are stacked on a leading layer axis; the shared
    # block and top-level params are not.
    def walk_top(tree):
        out = {}
        for k, v in tree.items():
            if k == "segments":
                out[k] = [walk(seg, f"segments/{i}", True) for i, seg in enumerate(v)]
            elif k == "shared_block":
                out[k] = walk(v, "shared_block", False)
            else:
                out[k] = walk(v, k, False)
        return out

    specs = walk_top(params_shape)
    return specs, rules.fallbacks


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, replicate_all=False):
    """PartitionSpecs for the decode caches (layout from models.cache_spec):
    batch over (pod, data); heads / latent dim over model when divisible;
    sequence dim left unsharded here (the flash-decode shard_map path in
    serve/ owns sequence sharding explicitly)."""
    tp = 1 if replicate_all else axis_size(mesh, "model")
    baxes = batch_axes(mesh, batch, allow_model=replicate_all)
    b = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    def model_if(dim):
        return "model" if (tp > 1 and dim % tp == 0) else None

    out = []
    hd = cfg.head_dim
    seq_shard = getattr(cfg, "decode_impl", "auto") == "flash_decode" and tp > 1
    for kind, n in cfg.segments:
        if kind in ("dense", "moe"):
            if seq_shard:
                out.append((P(None, b, "model", None, None),
                            P(None, b, "model", None, None)))
                continue
            out.append((P(None, b, None, model_if(cfg.n_kv_heads), None),
                        P(None, b, None, model_if(cfg.n_kv_heads), None)))
        elif kind in ("mla_dense", "mla_moe"):
            out.append((P(None, b, None, model_if(cfg.kv_lora_rank)),
                        P(None, b, None, None)))
        elif kind == "ssm":
            out.append((P(None, b, model_if(cfg.ssm_n_heads), None, None),
                        P(None, b, None, None)))
        elif kind == "shared_ref":
            if seq_shard:
                out.append((P(b, "model", None, None), P(b, "model", None, None)))
            else:
                out.append((P(b, None, model_if(cfg.n_kv_heads), None),
                            P(b, None, model_if(cfg.n_kv_heads), None)))
        elif kind == "cross":
            out.append(None)
        else:
            raise ValueError(kind)
    return out
