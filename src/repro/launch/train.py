"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --tiny \
        --steps 50 --ckpt-dir /tmp/ck

On this CPU container use --tiny (reduced config, local mesh).  On a real
pod, omit --tiny: the production mesh, shardings and the full config are
used (the same build the dry-run compiles).  Checkpoint/restart is always
on; the data pipeline is step-addressed so resume is exact.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import ShapeSpec, SHAPES
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_production_mesh, make_local_mesh
from repro.models.transformer import init_params
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.steps import build_train
from repro.train import checkpoint as ckpt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--shape", default=None, help="production ShapeSpec name")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=25)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    if args.tiny:
        spec = dataclasses.replace(spec, model=spec.tiny)
        mesh = make_local_mesh()
        shape = ShapeSpec("cli", "train", seq=args.seq, batch=args.batch)
    else:
        mesh = make_production_mesh()
        shape = SHAPES[args.shape or "train_4k"]

    built = build_train(spec, mesh, shape)
    cfg = spec.model
    data = SyntheticLM(DataConfig(cfg.vocab_size, shape.batch, shape.seq, seed=0))

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        start, state, _ = ckpt.restore(args.ckpt_dir, {"p": params, "o": opt})
        params, opt = state["p"], state["o"]
        start += 1
        print(f"resumed at step {start}")

    with mesh:
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            if cfg.frontend == "vision":
                batch["frontend_embeds"] = jnp.zeros(
                    (shape.batch, cfg.frontend_tokens, cfg.frontend_dim), cfg.param_dtype)
            elif cfg.frontend == "audio":
                batch["frontend_embeds"] = jnp.zeros(
                    (shape.batch, shape.seq, cfg.frontend_dim), cfg.param_dtype)
            t0 = time.time()
            params, opt, metrics = built["fn"](params, opt, batch)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step}: loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({time.time() - t0:.2f}s)")
            if args.ckpt_dir and ((step + 1) % args.save_every == 0
                                  or step == args.steps - 1):
                ckpt.save(args.ckpt_dir, step, {"p": params, "o": opt})
    print("done")


if __name__ == "__main__":
    main()
