"""Serving driver: batched requests + alpha-RR hosting controller.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-moe-16b \
        --slots 120 --M 20

Runs the tiny config end-to-end on CPU (real model execution per slot);
the full configs are exercised via the dry-run / a real pod.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import rentcosts
from repro.data.pipeline import request_stream
from repro.serve.scheduler import EdgeServingScheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-moe-16b")
    ap.add_argument("--slots", type=int, default=120)
    ap.add_argument("--M", type=float, default=20.0)
    ap.add_argument("--alpha", type=float, default=None)
    ap.add_argument("--rent-mean", type=float, default=1.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    arrivals = request_stream(args.seed, args.slots, "gilbert",
                              rate_h=6.0, rate_l=0.5, p_hl=0.3, p_lh=0.3)
    rents = np.asarray(rentcosts.aws_spot_like(
        jax.random.PRNGKey(args.seed + 1), args.rent_mean, args.slots))
    sched = EdgeServingScheduler(spec, M=args.M, alpha=args.alpha,
                                 seed=args.seed)
    rep = sched.run(arrivals, rents)
    print(f"arch={args.arch} plan={spec.partial_plan} "
          f"alpha={sched.costs.alpha} g(alpha)={sched.costs.g_alpha:.3f}")
    print(rep.summary())


if __name__ == "__main__":
    main()
