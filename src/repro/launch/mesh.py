"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing never touches jax
device state.  Single pod: 16x16 = 256 chips ("data", "model").  Multi-pod:
2 pods x 16 x 16 = 512 chips ("pod", "data", "model") — the pod axis is the
DCN dimension; gradient all-reduce crosses it.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devices)} — "
            "run under dryrun.py which forces 512 host devices")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_local_mesh(axes=("data", "model")):
    """Whatever devices exist, as a 1 x N or N x 1 mesh (tests/examples)."""
    n = len(jax.devices())
    shape = (1, n) if len(axes) == 2 else (n,)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
