import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
        --shape train_4k [--multi-pod]

Outputs one JSON per cell under benchmarks/results/dryrun/ containing
memory_analysis, cost_analysis, parsed collective stats and the three
roofline terms.  Skipped cells (long_500k on full-attention archs) emit a
JSON with {"skipped": reason} so the table stays complete.
"""
import argparse     # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from pathlib import Path  # noqa: E402

import jax          # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import SHAPES, all_archs, get_arch          # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.launch import roofline as rf                        # noqa: E402
from repro.train.steps import (build_train, build_serve,       # noqa: E402
                               abstract_params)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def count_params(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def active_params(spec) -> tuple[int, int]:
    """(total, active) param counts; active discounts un-routed experts."""
    cfg = spec.model
    p = abstract_params(cfg)
    total = count_params(p)
    embed = int(np.prod(p["embed"].shape)) if "embed" in p else 0
    routed_total = 0
    for kind, n in cfg.segments:
        if kind in ("moe", "mla_moe"):
            routed_total += n * cfg.n_routed_experts * 3 * cfg.d_model * cfg.d_expert
    active = total - embed - routed_total * (1.0 - cfg.moe_top_k / max(cfg.n_routed_experts, 1))
    return total, int(active)


def model_flops(spec, shape) -> float:
    _, n_active = active_params(spec)
    if shape.kind == "train":
        return 6.0 * n_active * shape.batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.batch * shape.seq
    return 2.0 * n_active * shape.batch          # decode: one token per seq


def input_specs(arch_id: str, shape_name: str, mesh=None):
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    spec = get_arch(arch_id)
    shape = SHAPES[shape_name]
    mesh = mesh or make_production_mesh()
    if shape.kind == "train":
        built = build_train(spec, mesh, shape)
    else:
        built = build_serve(spec, mesh, shape)
    return built["abstract_inputs"], built


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: Path = RESULTS_DIR, verbose: bool = True,
             analysis: str = "extrapolate", suffix: str = "",
             arch_override=None) -> dict:
    """analysis='extrapolate': exact roofline terms via incremental-layer
    extrapolation (see launch/analysis.py) on top of the full scanned
    compile; 'scanned': raw cost_analysis of the scanned program (undercounts
    loop bodies — kept for comparison)."""
    spec = arch_override if arch_override is not None else get_arch(arch_id)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch_id}__{shape_name}__{mesh_name}{suffix}.json"

    if shape.name == "long_500k" and not spec.long_context_ok:
        rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
               "skipped": "full-attention arch: 500k dense prefill/decode is "
                          "quadratic; see DESIGN.md §Arch-applicability"}
        out_path.write_text(json.dumps(rec, indent=2))
        if verbose:
            print(f"[dryrun] SKIP {arch_id} x {shape_name} ({mesh_name})")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    if shape.kind == "train":
        built = build_train(spec, mesh, shape)
    else:
        built = build_serve(spec, mesh, shape)

    with mesh:
        lowered = built["fn"].lower(*built["abstract_inputs"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    try:
        mem = compiled.memory_analysis()
        mem_rec = {k: int(getattr(mem, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                   if hasattr(mem, k)}
    except Exception as e:                                    # pragma: no cover
        mem_rec = {"error": repr(e)}
    try:
        cost_list = compiled.cost_analysis()
        cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "bytes accessed output", "optimal_seconds")}
    except Exception as e:                                    # pragma: no cover
        cost = {"error": repr(e)}

    hlo = compiled.as_text()
    mf = model_flops(spec, shape)
    if analysis == "extrapolate":
        from repro.launch.analysis import extrapolated_terms, roofline_from_terms
        terms = extrapolated_terms(spec, shape, mesh)
        roof = roofline_from_terms(terms, n_chips, mf)
    else:
        roof = rf.analyze(cost, hlo, n_chips, mf)
    total, n_active = active_params(spec)

    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "n_chips": n_chips,
        "params_total": total, "params_active": n_active,
        "model_flops_global": mf,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec,
        "cost_analysis": cost,
        "sharding_fallbacks": built["fallbacks"],
        "roofline": {
            "flops_per_chip": roof.flops,
            "hbm_bytes_per_chip": roof.hbm_bytes,
            "ici_wire_bytes": roof.ici_bytes,
            "dcn_wire_bytes": roof.dcn_bytes,
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "bottleneck": roof.bottleneck,
            "useful_ratio": roof.useful_ratio,
            "roofline_fraction": roof.roofline_fraction,
            "collective_op_counts": roof.op_counts,
            "collective_op_bytes": roof.op_bytes,
        },
    }
    out_path.write_text(json.dumps(rec, indent=2))
    if verbose:
        r = rec["roofline"]
        print(f"[dryrun] OK {arch_id} x {shape_name} ({mesh_name}) "
              f"compile={t_compile:.1f}s bottleneck={r['bottleneck']} "
              f"terms=({r['compute_s']:.3e},{r['memory_s']:.3e},"
              f"{r['collective_s']:.3e})s frac={r['roofline_fraction']:.3f}",
              flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every arch x shape; single-pod for all + multi-pod pass")
    ap.add_argument("--multi-pod-all", action="store_true",
                    help="with --all: also run every cell on the 2-pod mesh")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args(argv)
    out_dir = Path(args.out)

    cells = []
    if args.all:
        for arch_id in sorted(all_archs()):
            for shape_name in SHAPES:
                cells.append((arch_id, shape_name, False))
                if args.multi_pod_all:
                    cells.append((arch_id, shape_name, True))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = []
    for arch_id, shape_name, mp in cells:
        try:
            run_cell(arch_id, shape_name, mp, out_dir)
        except Exception:
            failures.append((arch_id, shape_name, mp))
            print(f"[dryrun] FAIL {arch_id} x {shape_name} multi_pod={mp}",
                  flush=True)
            traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}")
        sys.exit(1)
    print("[dryrun] all cells passed")


if __name__ == "__main__":
    main()
