"""Exact roofline terms by incremental-layer extrapolation.

Problem: XLA's ``cost_analysis()`` prices a ``while`` (lax.scan) body ONCE,
so scanned layer stacks undercount flops/bytes/collectives by the trip
count; fully unrolling the production configs makes CPU compiles take tens
of minutes.

Solution: every per-layer cost is *linear in the layer count* within a
segment kind (homogeneous layers).  So we lower tiny loop-free variants —
base config A with ONE layer per segment kind, and B_k with one extra layer
of kind k — all at the full d_model/width/batch/seq on the production mesh,
and extrapolate:

    cost_full = cost(A) + sum_k (n_k - A_k) * (cost(B_k) - cost(A))

flops, HBM bytes and parsed collective wire bytes extrapolate this way;
memory_analysis (buffer fitting) is taken from the full *scanned* compile,
which stays the runnable artifact.  A validation test cross-checks the
extrapolation against a true full unroll on a small config
(tests/test_roofline.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.configs.base import ArchSpec, ShapeSpec
from repro.launch import roofline as rf


def _kind_counts(segments) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for kind, n in segments:
        out[kind] = out.get(kind, 0) + n
    return out


def base_and_bumped(spec: ArchSpec, shape: ShapeSpec = None):
    """Reduced specs: A (one layer per distinct kind, original kind order of
    first appearance) and {kind: B_kind} with one extra layer of that kind."""
    order: List[str] = []
    for kind, _ in spec.model.segments:
        if kind not in order:
            order.append(kind)
    seg_a = tuple((k, 1) for k in order)

    def mk(segs):
        model = spec.model.with_(segments=segs, scan_unroll=True)
        if model.ssm_state:
            model = model.with_(ssm_chunk=max(model.ssm_chunk, 2048))
        if shape is not None and shape.kind == "decode":
            # unrolling a 512-chunk flash scan over a 500k cache explodes
            # compile time for zero flop difference; coarsen chunks
            model = model.with_(attn_chunk=max(model.attn_chunk, 65536))
        return dataclasses.replace(spec, model=model)

    spec_a = mk(seg_a)
    bumped = {}
    for k in order:
        seg_b = tuple((kk, 2 if kk == k else 1) for kk in order)
        bumped[k] = mk(seg_b)
    return spec_a, bumped, _kind_counts(spec.model.segments)


def _terms_of(spec: ArchSpec, shape: ShapeSpec, mesh) -> Dict[str, float]:
    from repro.train.steps import build_train, build_serve
    built = (build_train(spec, mesh, shape) if shape.kind == "train"
             else build_serve(spec, mesh, shape))
    with mesh:
        compiled = built["fn"].lower(*built["abstract_inputs"]).compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    hlo = compiled.as_text()
    st = rf.collective_stats(hlo)
    return {
        "flops": float(ca.get("flops", 0.0)),
        # fusion-aware HBM estimate (see roofline.hbm_bytes_fused); raw
        # cost_analysis bytes kept alongside for reference
        "bytes": rf.hbm_bytes_fused(hlo),
        "bytes_raw": float(ca.get("bytes accessed", 0.0)),
        "ici": st.ici_bytes,
        "dcn": st.dcn_bytes,
        "op_bytes": dict(st.op_bytes),
        "op_counts": dict(st.op_counts),
    }


def _combine(a, b, w):
    """a + w * (b - a), elementwise over the term dicts."""
    out = {}
    for key in ("flops", "bytes", "bytes_raw", "ici", "dcn"):
        out[key] = a[key] + w * (b[key] - a[key])
    return out


def extrapolated_terms(spec: ArchSpec, shape: ShapeSpec, mesh,
                       verbose: bool = False) -> Dict[str, float]:
    spec_a, bumped, counts = base_and_bumped(spec, shape)
    ta = _terms_of(spec_a, shape, mesh)
    total = {k: ta[k] for k in ("flops", "bytes", "bytes_raw", "ici", "dcn")}
    op_bytes: Dict[str, float] = dict(ta["op_bytes"])
    op_counts: Dict[str, int] = dict(ta["op_counts"])
    base_per_kind = {k: 1 for k in bumped}
    for kind, spec_b in bumped.items():
        tb = _terms_of(spec_b, shape, mesh)
        extra = counts[kind] - base_per_kind[kind]
        for key in ("flops", "bytes", "bytes_raw", "ici", "dcn"):
            total[key] += extra * (tb[key] - ta[key])
        for op, v in tb["op_bytes"].items():
            op_bytes[op] = op_bytes.get(op, 0.0) + extra * (v - ta["op_bytes"].get(op, 0.0))
        for op, v in tb["op_counts"].items():
            op_counts[op] = op_counts.get(op, 0) + extra * (v - ta["op_counts"].get(op, 0))
        if verbose:
            print(f"  [analysis] {spec.arch_id} x {shape.name}: kind={kind} "
                  f"marginal flops={tb['flops'] - ta['flops']:.3e} x{extra}")
    total["op_bytes"] = op_bytes
    total["op_counts"] = op_counts
    return total


def roofline_from_terms(terms, n_chips: int, model_flops_global: float) -> rf.Roofline:
    compute_s = terms["flops"] / rf.PEAK_FLOPS
    memory_s = terms["bytes"] / rf.HBM_BW
    collective_s = terms["ici"] / rf.ICI_BW + terms["dcn"] / rf.DCN_BW
    tt = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(tt, key=tt.get)
    mf = model_flops_global / n_chips
    return rf.Roofline(
        flops=terms["flops"], hbm_bytes=terms["bytes"], ici_bytes=terms["ici"],
        dcn_bytes=terms["dcn"], compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, bottleneck=bottleneck, model_flops=mf,
        useful_ratio=(mf / terms["flops"] if terms["flops"] else 0.0),
        op_counts=terms["op_counts"], op_bytes=terms["op_bytes"])
