"""Roofline analysis from a compiled dry-run artifact.

Three terms per (arch, shape, mesh):

    compute    = HLO_FLOPs_per_chip / 197e12            (v5e bf16 peak)
    memory     = HLO_bytes_per_chip / 819e9             (HBM bandwidth)
    collective = wire_bytes_per_chip / 50e9             (ICI per link)
                 + dcn_wire_bytes_per_chip / 25e9       (pod axis, DCN)

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-partition program
under SPMD).  Collective bytes are NOT in cost_analysis: we parse the
optimized HLO and sum per-op wire traffic with ring-algorithm factors:

    all-reduce      2 (g-1)/g * bytes
    all-gather        (g-1)/g * bytes(out)
    reduce-scatter    (g-1)/g * bytes(in)
    all-to-all        (g-1)/g * bytes
    collective-permute          bytes

A group is DCN-crossing when its replica ids span pods (id // 256 differs).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
ICI_BW = 50e9                # bytes / s / link
DCN_BW = 25e9                # bytes / s / chip (cross-pod)
CHIPS_PER_POD = 256

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^=]*\)\s*)?[a-z0-9\[\],{}\s]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[0-9,{}\s]*\})\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                             r"(?:T\(([0-9,]+)\))?")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of one HLO type string (may be a tuple)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_groups(line: str) -> List[List[int]]:
    m = _GROUPS_RE.search(line)
    if m:
        return [[int(x) for x in grp.split(",") if x.strip()]
                for grp in re.findall(r"\{([0-9,\s]*)\}", m.group(1))]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = list(range(int(math.prod(dims))))
        perm = m.group(4)
        if perm:
            import numpy as np
            arr = np.arange(int(math.prod(dims))).reshape(dims)
            arr = np.transpose(arr, [int(x) for x in perm.split(",")])
            ids = list(arr.reshape(-1))
        return [ids[i * gsize:(i + 1) * gsize] for i in range(ngroups)]
    return []


# Ops that stay HBM-resident after TPU-style fusion: matrix units, data
# movement/layout, RNG-free gathers/scatters, fusion boundaries.  Elementwise
# chains fuse into them on TPU, so counting every op (what XLA-CPU
# cost_analysis does) overstates HBM traffic by 1-2 orders of magnitude.
_HBM_OPS = {
    "dot", "convolution", "fusion", "scatter", "gather", "dynamic-slice",
    "dynamic-update-slice", "reduce", "reduce-window", "sort", "transpose",
    "copy", "pad", "concatenate", "slice", "iota-free-select"
}
_OPCODE_RE = re.compile(r"^\s*(?:ROOT\s+)?%\S+\s*=\s*[^=]*?\s([a-z][a-z0-9-]*)\(")


def hbm_bytes_fused(hlo_text: str) -> float:
    """Fusion-aware HBM-traffic estimate: sum operand+result bytes of the
    _HBM_OPS above plus entry parameters/root (weights read, outputs
    written); collectives are excluded here (they live in the collective
    term)."""
    total = 0.0
    in_entry = False
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("ENTRY"):
            in_entry = True
            continue
        if ls == "}":
            in_entry = False
            continue
        m = _OPCODE_RE.match(line)
        if not m:
            continue
        op = m.group(1)
        if in_entry and op == "parameter":
            total += _shape_bytes(line.split("=", 1)[0] + line.split("=", 1)[1].split("parameter")[0])
            continue
        if op in _HBM_OPS:
            total += _shape_bytes(line)
    return total


@dataclasses.dataclass
class CollectiveStats:
    ici_bytes: float = 0.0       # wire bytes per chip over ICI
    dcn_bytes: float = 0.0       # wire bytes per chip over DCN
    op_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    op_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)


def collective_stats(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # result type = lhs of '='; operand bytes ~ result bytes for these ops
        lhs = line.split("=", 1)[0] if "=" in line else line
        rhs_head = line.split("=", 1)[1] if "=" in line else line
        bytes_total = _shape_bytes(rhs_head.split("(", 1)[0]) or _shape_bytes(lhs)
        groups = _parse_groups(line)
        gsize = max((len(g) for g in groups), default=2)
        if op == "all-reduce":
            wire = 2.0 * (gsize - 1) / gsize * bytes_total
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            wire = (gsize - 1) / gsize * bytes_total
        else:  # collective-permute
            wire = float(bytes_total)
            pairs = _SRC_TGT_RE.search(line)
            groups = []
            if pairs:
                groups = [[int(a), int(b)] for a, b in
                          re.findall(r"\{(\d+),(\d+)\}", pairs.group(1))]
        crosses = any(len({i // CHIPS_PER_POD for i in g}) > 1 for g in groups)
        st.op_counts[op] = st.op_counts.get(op, 0) + 1
        st.op_bytes[op] = st.op_bytes.get(op, 0.0) + wire
        if crosses:
            st.dcn_bytes += wire
        else:
            st.ici_bytes += wire
    return st


@dataclasses.dataclass
class Roofline:
    flops: float                 # per chip
    hbm_bytes: float             # per chip
    ici_bytes: float
    dcn_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # 6*N_active*D (train) or 2*N_active*tokens
    useful_ratio: float          # model_flops / hlo_flops_total
    op_counts: Dict[str, int]
    op_bytes: Dict[str, float]

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound on step latency."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs utilisation at the bound: how close the step is to
        pure-compute at peak on its useful work (the score we hillclimb)."""
        if self.step_time_s <= 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.step_time_s


def analyze(cost: dict, hlo_text: str, n_chips: int, model_flops: float,
            flops_are_global: bool = False, fused_bytes: bool = True) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = hbm_bytes_fused(hlo_text) if fused_bytes else float(cost.get("bytes accessed", 0.0))
    if flops_are_global:
        flops /= n_chips
        hbm /= n_chips
    st = collective_stats(hlo_text)
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = st.ici_bytes / ICI_BW + st.dcn_bytes / DCN_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf_per_chip = model_flops / n_chips
    return Roofline(
        flops=flops, hbm_bytes=hbm, ici_bytes=st.ici_bytes, dcn_bytes=st.dcn_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=mf_per_chip,
        useful_ratio=(mf_per_chip / flops if flops else 0.0),
        op_counts=st.op_counts, op_bytes=st.op_bytes,
    )
