"""Flash-decoding adapted to TPU/ICI: decode attention over a KV cache that
is sharded along the SEQUENCE dimension across the ``model`` axis.

Why: GQA archs with n_kv_heads < model-axis size (qwen kv=8, llama kv=8,
granite kv=1 on a 16-wide axis) cannot head-shard their caches; replicating
them explodes HBM and the naive GSPMD lowering all-gathers the whole cache
every step (the collective-bound decode cells in the baseline roofline
table).  Sequence-sharding instead gives every rank S/tp cache slots; each
rank computes a partial online-softmax over its slots and the results merge
with one tiny (max, sum, weighted-psum) exchange of [B, H, hd]-sized
statistics — O(B*H*hd) wire bytes instead of O(B*S*Hkv*hd).

The cache write is also local: the rank owning slot ``pos`` does the
dynamic-update-slice; everyone else no-ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

NEG_INF = -1e30


def flash_decode_ref(q, k, v, pos):
    """q [B,1,Hq,hd]; k/v [B,S,Hkv,hd]; attend over slots <= pos."""
    b, _, hq, hd = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, hd).astype(jnp.float32) / np.sqrt(hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32))
    mask = jnp.arange(s)[None, None, None, :] <= pos
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, 1, hq, hd).astype(q.dtype)


def _local_partial(q, k_loc, v_loc, pos, s_start):
    """Partial flash statistics over one sequence shard."""
    b, _, hq, hd = q.shape
    s_loc, hkv = k_loc.shape[1], k_loc.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, hd).astype(jnp.float32) / np.sqrt(hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, k_loc.astype(jnp.float32))
    kpos = s_start + jnp.arange(s_loc)
    scores = jnp.where(kpos[None, None, None, :] <= pos, scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                          # [b,hkv,g]
    p = jnp.exp(scores - m[..., None])
    p = jnp.where(jnp.isfinite(m)[..., None], p, 0.0)     # all-masked shard
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_loc.astype(jnp.float32))
    return m, l, o


def _merge(m, l, o, axis):
    gmax = jax.lax.pmax(m, axis)
    scale = jnp.exp(m - gmax)
    l_g = jax.lax.psum(l * scale, axis)
    o_g = jax.lax.psum(o * scale[..., None], axis)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]


def flash_decode(q, k, v, pos, mesh: Mesh, axis: str = "model",
                 batch_spec=None):
    """Standalone sequence-sharded decode attention (no cache write).
    q [B,1,Hq,hd]; k/v [B,S,Hkv,hd] (S divisible by mesh.shape[axis])."""
    tp = mesh.shape[axis]
    b, _, hq, hd = q.shape
    s = k.shape[1]
    assert s % tp == 0, (s, tp)

    def local(qb, kb, vb):
        idx = jax.lax.axis_index(axis)
        m, l, o = _local_partial(qb, kb, vb, pos, idx * (s // tp))
        out = _merge(m, l, o, axis)
        return out.reshape(qb.shape).astype(qb.dtype)

    bs = batch_spec
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(bs, None, None, None), P(bs, axis, None, None),
                             P(bs, axis, None, None)),
                   out_specs=P(bs, None, None, None),
                   check_rep=False)
    return fn(q, k, v)


def flash_decode_update(q, k_new, v_new, k_cache, v_cache, pos, mesh: Mesh,
                        axis: str = "model", batch_spec=None):
    """Cache-updating variant: writes (k_new, v_new) at slot ``pos`` into the
    sequence-sharded caches (local write on the owning rank) and returns
    (attn_out, k_cache, v_cache)."""
    tp = mesh.shape[axis]
    s = k_cache.shape[1]
    assert s % tp == 0
    s_loc = s // tp

    def local(qb, knb, vnb, kcb, vcb):
        idx = jax.lax.axis_index(axis)
        start = idx * s_loc
        off = pos - start
        in_range = (off >= 0) & (off < s_loc)
        off_c = jnp.clip(off, 0, s_loc - 1)
        kw = jax.lax.dynamic_update_slice_in_dim(kcb, knb.astype(kcb.dtype), off_c, 1)
        vw = jax.lax.dynamic_update_slice_in_dim(vcb, vnb.astype(vcb.dtype), off_c, 1)
        kcb = jnp.where(in_range, kw, kcb)
        vcb = jnp.where(in_range, vw, vcb)
        m, l, o = _local_partial(qb, kcb, vcb, pos, start)
        out = _merge(m, l, o, axis).reshape(qb.shape).astype(qb.dtype)
        return out, kcb, vcb

    bs = batch_spec
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(bs, None, None, None), P(bs, None, None, None),
                             P(bs, None, None, None), P(bs, axis, None, None),
                             P(bs, axis, None, None)),
                   out_specs=(P(bs, None, None, None), P(bs, axis, None, None),
                              P(bs, axis, None, None)),
                   check_rep=False)
    return fn(q, k_new, v_new, k_cache, v_cache)
