"""Batched serving engine with plan-aware execution.

The engine owns model params + KV caches and executes whichever
``HostingPlan`` the controller has made resident:

  * none          -> every request is forwarded (cloud serves; cost 1/req)
  * layer_prefix  -> run the resident segment prefix + LM head (early-exit
                     draft); the cloud completes the residual (cost g(a)/req)
  * expert_subset -> run the full stack with an expert mask; requests whose
                     routed experts are all resident finish at the edge,
                     the rest are forwarded (cost 1/req on those — the
                     engine *measures* the realized fraction, which is the
                     Model-2 coin flip made physical)
  * full          -> everything served at the edge (cost 0/req)

This is a single-host engine for the runnable examples/tests (tiny
configs); the distributed decode path shares the same forward() via
train/steps.build_serve.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchSpec
from repro.models.transformer import (forward, init_params, logits_fn,
                                      make_caches)
from repro.serve.partial import HostingPlan


@dataclasses.dataclass
class SlotServiceResult:
    n_requests: int
    served_edge: int          # fully served at the edge
    served_partial: int       # draft at edge, completed by cloud
    forwarded: int            # fully cloud-served
    service_cost: float       # the paper's C_S for this slot
    edge_tokens: np.ndarray | None = None


class ServingEngine:
    def __init__(self, spec: ArchSpec, params=None, key=None, max_len: int = 64,
                 use_tiny: bool = True, decode_steps: int = 4):
        self.spec = spec
        self.cfg = spec.tiny if use_tiny else spec.model
        key = key if key is not None else jax.random.PRNGKey(0)
        self.params = params if params is not None else init_params(self.cfg, key)
        self.max_len = max_len
        self.decode_steps = decode_steps
        self._decode = jax.jit(self._decode_fn, static_argnames=("n_segments",))

    # ---- model execution ------------------------------------------------
    def _decode_fn(self, params, batch, expert_mask, n_segments=None):
        hidden, _, _ = forward(params, self.cfg, batch
                               if expert_mask is None else
                               {**batch, "expert_mask": expert_mask},
                               n_segments=n_segments)
        return jnp.argmax(logits_fn(params, self.cfg, hidden)[:, -1], axis=-1)

    def _run_batch(self, prompts: np.ndarray, plan: HostingPlan):
        batch = {"tokens": jnp.asarray(prompts)}
        if self.cfg.frontend == "audio":
            batch["frontend_embeds"] = jnp.zeros(
                (prompts.shape[0], prompts.shape[1], self.cfg.frontend_dim),
                self.cfg.param_dtype)
        elif self.cfg.frontend == "vision":
            batch["frontend_embeds"] = jnp.zeros(
                (prompts.shape[0], self.cfg.frontend_tokens, self.cfg.frontend_dim),
                self.cfg.param_dtype)
        mask = (jnp.asarray(plan.expert_mask)
                if plan.expert_mask is not None else None)
        n_seg = plan.n_segments if plan.kind == "layer_prefix" else None
        return np.asarray(self._decode(self.params, batch, mask, n_segments=n_seg))

    # ---- the slot-level service contract --------------------------------
    def serve_slot(self, prompts: Optional[np.ndarray], plan: HostingPlan,
                   rng: np.random.Generator) -> SlotServiceResult:
        """Serve one scheduler slot's batch under ``plan`` and account the
        paper's service cost."""
        n = 0 if prompts is None else len(prompts)
        if n == 0:
            return SlotServiceResult(0, 0, 0, 0, 0.0)
        if plan.kind == "none":
            return SlotServiceResult(n, 0, 0, n, float(n))
        if plan.kind == "full":
            toks = self._run_batch(prompts, plan)
            return SlotServiceResult(n, n, 0, 0, 0.0, toks)
        if plan.kind == "layer_prefix":
            toks = self._run_batch(prompts, plan)   # early-exit draft
            # Model 1: every request gets a partial answer now; residual
            # value g(a) per request comes from the cloud.
            return SlotServiceResult(n, 0, n, 0, plan.g_value * n, toks)
        if plan.kind == "expert_subset":
            toks = self._run_batch(prompts, plan)
            # Model 2 realized: a request finishes at the edge iff all its
            # routed experts are resident; engine-level measurement uses the
            # plan's g as the routing-hit probability (coupled draw).
            hits = rng.random(n) >= plan.g_value
            served = int(hits.sum())
            return SlotServiceResult(n, served, 0, n - served,
                                     float(n - served), toks)
        raise ValueError(plan.kind)

    # ---- fleet-level grouped serving ------------------------------------
    def serve_groups(self, groups, rng: np.random.Generator
                     ) -> List[SlotServiceResult]:
        """Serve one live-fleet slot: ``groups`` is ``[(plan, prompts),
        ...]`` where every instance currently hosting the same plan has had
        its requests concatenated into one batch — so a B-wide fleet costs
        one decode per *distinct resident plan*, not one per instance.
        Returns one ``SlotServiceResult`` per group, in order (the
        fleet-level analogue of ``serve_slot``; see
        ``serve.scheduler.LiveFleetScheduler``)."""
        return [self.serve_slot(prompts, plan, rng)
                for plan, prompts in groups]
