"""Slot-based request scheduler wiring arrivals + spot rents + the
HostingController (alpha-RR) + the ServingEngine into the paper's
edge-hosting loop.  This is deliverable (b)'s end-to-end driver core.

Two drivers live here:

* ``EdgeServingScheduler`` — ONE instance, host-side ``HostingController``
  loop; the original runnable example.
* ``LiveFleetScheduler`` — B instances on the persistent
  ``core.fleet.FleetStepper``: one host admits per-instance arrival/rent
  telemetry slot by slot, every admit is a single pre-compiled
  donated-carry device step (zero retraces after warmup), and per-instance
  hosting levels/fractions are read straight off the device carry to drive
  plan-grouped serving (one decode per distinct resident plan).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchSpec
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import FleetBatch, FleetResult, fleet_stepper
from repro.core.hosting_controller import HostingController
from repro.core.policies.alpha_rr import AlphaRR
from repro.core.policies.base import PolicyFns, PolicyLane
from repro.serve.engine import ServingEngine
from repro.serve.partial import HostingPlan, make_plans


@dataclasses.dataclass
class EdgeServingReport:
    total_cost: float
    breakdown: Dict[str, float]
    level_histogram: np.ndarray
    served_edge: int
    served_partial: int
    forwarded: int
    n_requests: int
    n_slots: int

    def summary(self) -> str:
        h = self.level_histogram
        return (f"slots={self.n_slots} requests={self.n_requests} "
                f"edge={self.served_edge} partial={self.served_partial} "
                f"cloud={self.forwarded} | cost={self.total_cost:.2f} "
                f"(fetch={self.breakdown['fetch']:.2f} rent={self.breakdown['rent']:.2f} "
                f"svc={self.breakdown['service']:.2f}) | slots@level={h.tolist()}")


class EdgeServingScheduler:
    """One slot = one batched decode opportunity.  The engine executes, the
    controller (alpha-RR) re-plans; weight 'fetches' switch the active plan
    (in production this is the weight-streaming path; here plan switching is
    immediate and the fetch cost is accounted by the controller)."""

    def __init__(self, spec: ArchSpec, M: float, alpha: Optional[float] = None,
                 policy_cls=AlphaRR, seed: int = 0, engine: ServingEngine = None,
                 use_model2: bool = None):
        self.spec = spec
        self.engine = engine or ServingEngine(spec)
        self.plans, g_alpha = make_plans(spec, alpha, model_cfg=self.engine.cfg)
        alpha = [l for l in self.plans if 0.0 < l < 1.0][0]
        self.costs = HostingCosts.three_level(M=M, alpha=alpha, g_alpha=g_alpha)
        self.controller = HostingController(self.costs, policy_cls)
        # the controller's grid may be coarser than the plan set (e.g. a
        # RetroRenting controller never uses the partial plan)
        self.rng = np.random.default_rng(seed)
        self.use_model2 = (use_model2 if use_model2 is not None
                           else spec.partial_plan == "expert_subset")
        self.levels = sorted(self.plans)
        self.stats = {"edge": 0, "partial": 0, "cloud": 0, "requests": 0}

    def _prompts(self, n: int, seq: int = 8) -> Optional[np.ndarray]:
        if n == 0:
            return None
        return self.rng.integers(0, self.spec.tiny.vocab_size, size=(n, seq))

    def run(self, arrivals: np.ndarray, rents: np.ndarray,
            run_model: bool = True) -> EdgeServingReport:
        assert len(arrivals) == len(rents)
        for t, (x_t, c_t) in enumerate(zip(arrivals, rents)):
            lv = self.controller.level          # policy's own level value
            plan = self.plans[min(self.plans, key=lambda l: abs(l - lv))]
            x_t = int(x_t)
            if run_model:
                res = self.engine.serve_slot(self._prompts(x_t), plan, self.rng)
                self.stats["edge"] += res.served_edge
                self.stats["partial"] += res.served_partial
                self.stats["cloud"] += res.forwarded
                self.stats["requests"] += res.n_requests
                realized = res.service_cost
            else:
                realized = None
            # realized per-level service costs for the controller's
            # retrospection (coupled across levels, Model 2) or Model-1 g*x
            if self.use_model2:
                u = self.rng.random(max(x_t, 1))[:x_t]
                svc = np.array([float(np.sum(u < gk))
                                for gk in self.controller.costs.g])
                if realized is not None and plan.kind == "expert_subset":
                    svc[self.controller.level_idx] = realized
            else:
                svc = None
            self.controller.step(x_t, float(c_t), svc)
        br = self.controller.cost_breakdown()
        return EdgeServingReport(
            total_cost=br["total"], breakdown=br,
            level_histogram=self.controller.level_histogram(),
            served_edge=self.stats["edge"], served_partial=self.stats["partial"],
            forwarded=self.stats["cloud"], n_requests=self.stats["requests"],
            n_slots=len(arrivals))


class LiveFleetScheduler:
    """Real-time fleet controller on the persistent ``FleetStepper``.

    One host manages B edge instances (one ``HostingCosts`` each, e.g. one
    per edge site).  Every ``admit(x, c)`` call feeds ONE slot of
    per-instance arrival counts and spot rents and advances *all* B
    controllers through a single pre-compiled donated-carry device step —
    zero retraces after the first slot, whatever the values, because all
    shapes are fixed and the slot offset is a traced scalar.  The horizon
    is open-ended: ``horizon`` only bounds the traced horizon mask (a huge
    value costs nothing — no [B, T] array is ever materialized).

    Readbacks come straight off the device carry: ``hosting_levels()`` /
    ``hosting_fractions()`` per instance, ``report()`` for the accumulated
    rent/service/fetch breakdown.  With ``spec=...`` (or ``engine=...``)
    the fractions drive plan assignment and ``serve(prompts_by_instance,
    rng)`` batches one decode per distinct resident plan via
    ``ServingEngine.serve_groups``.

    Service accounting on device is Model 1 (``g(level) * x`` per slot);
    the Model-2 realized-coupling loop stays on the single-instance
    ``EdgeServingScheduler``.

    **Shadow scoring**: ``shadow_policies=[...]`` rides candidate policy
    families on the stepper's policy fan-out axis — every ``admit`` steps
    the live policy AND each shadow against the *same* telemetry slab in
    the one compiled device step, so counterfactual cost curves accrue at
    zero extra ingestion cost.  Each entry is a policy class with a
    ``.fleet`` classmethod, a ready ``PolicyFns``, or a ``PolicyLane``
    (own accounting grid).  ``with_opt_forward=True`` additionally
    co-executes the offline DP forward frontier per instance, so
    ``opt_cost()`` reads the running offline-optimum lower bound.
    ``report()`` stays policy-major (``FleetResult.policy_view``); lane 0
    is always the live policy and is what ``admit`` returns and what plan
    assignment serves from.

    **Multi-host**: on a process-spanning mesh (``repro.sharding
    .distributed.initialize()`` + a global ``fleet_mesh()``), construct
    the scheduler on each process with that process's OWN ``costs_list``
    rows (local B), feed ``admit`` that process's local telemetry rows,
    and read local views back; ``hosting_levels(gather=True)`` /
    ``report(gather=True)`` opt into the cross-host allgather.  ``grid_K``
    must then be the GLOBAL max K so every process's grid pads alike.
    """

    def __init__(self, costs_list: Sequence[HostingCosts], *,
                 policy_cls=AlphaRR, horizon: int = 1 << 20,
                 spec: Optional[ArchSpec] = None,
                 engine: Optional[ServingEngine] = None,
                 alpha: Optional[float] = None, mesh=None, seed: int = 0,
                 grid_K: Optional[int] = None, shadow_policies: Sequence = (),
                 with_opt_forward: bool = False):
        grid = HostingGrid.from_costs(list(costs_list), K=grid_K)
        self.fleet = FleetBatch.for_scenario(grid, horizon)
        lanes = [policy_cls.fleet(self.fleet)]
        for entry in shadow_policies:
            if isinstance(entry, (PolicyFns, PolicyLane)):
                lanes.append(entry)
            elif hasattr(entry, "fleet_lane"):
                lanes.append(entry.fleet_lane(self.fleet))
            else:
                lanes.append(entry.fleet(self.fleet))
        self.n_policies = len(lanes)
        policy = lanes if (len(lanes) > 1 or with_opt_forward) else lanes[0]
        self.stepper = fleet_stepper(policy, self.fleet, mesh=mesh,
                                     chunk_size=1,
                                     with_opt_forward=with_opt_forward)
        self._fanout = self.stepper.n_policies > 1 or with_opt_forward
        self._with_opt = with_opt_forward
        self.B = grid.B
        self.rng = np.random.default_rng(seed)
        self.engine = engine or (ServingEngine(spec) if spec is not None
                                 else None)
        if self.engine is not None:
            self.plans, _ = make_plans(self.engine.spec, alpha,
                                       model_cfg=self.engine.cfg)
            self.plan_levels = np.asarray(sorted(self.plans))
        self.stats = {"edge": 0, "partial": 0, "cloud": 0, "requests": 0}
        self.n_slots = 0

    # ---- telemetry admission -------------------------------------------
    def admit(self, x, c) -> np.ndarray:
        """Admit one slot of per-instance telemetry: ``x`` [B] arrival
        counts, ``c`` [B] spot rents.  One device step advancing the live
        policy and every shadow lane; returns the [B] hosting-level
        indices the LIVE controllers chose for this slot."""
        r = self.stepper.step(x=np.asarray(x), c=np.asarray(c))
        self.n_slots += 1
        if self._fanout:
            r = r[0]
        return r[:, 0]

    # ---- device-carry readbacks ----------------------------------------
    # Process-local [B] views by default; gather=True allgathers the full
    # global fleet onto every process (multi-host meshes only — a no-op
    # single-process).
    def hosting_levels(self, gather: bool = False,
                       policy: int = 0) -> np.ndarray:
        return self.stepper.hosting_levels(gather=gather, policy=policy)

    def hosting_fractions(self, gather: bool = False,
                          policy: int = 0) -> np.ndarray:
        return self.stepper.hosting_fractions(gather=gather, policy=policy)

    def report(self, gather: bool = False) -> FleetResult:
        """Accumulated per-instance cost breakdown (rent/service/fetch and
        slots-at-level counts) up to the last admitted slot.  With shadow
        lanes the result is policy-major — ``report().policy_view(...)``
        splits it back out; lane 0 is the live policy."""
        return self.stepper.result(None, gather=gather)

    def opt_cost(self, gather: bool = False) -> np.ndarray:
        """[n_policies, B] running offline-DP lower bound per lane (needs
        ``with_opt_forward=True``)."""
        if not self._with_opt:
            raise ValueError("opt_cost requires with_opt_forward=True")
        return self.stepper.opt_cost(gather=gather)

    # ---- plan assignment + grouped serving -----------------------------
    def plan_assignment(self) -> List[HostingPlan]:
        """Per-instance ``HostingPlan``: each instance's current hosting
        fraction snapped to the nearest level in the plan set."""
        if self.engine is None:
            raise ValueError("plan_assignment requires spec= or engine=")
        frac = self.hosting_fractions()
        idx = np.abs(frac[:, None] - self.plan_levels[None, :]).argmin(axis=1)
        return [self.plans[self.plan_levels[i]] for i in idx]

    def serve(self, prompts_by_instance: Sequence[Optional[np.ndarray]],
              rng: Optional[np.random.Generator] = None) -> Dict[str, int]:
        """Serve one slot's requests: group the B instances by their
        current plan, concatenate each group's prompts, and run one decode
        per distinct plan.  Returns the updated cumulative serve stats."""
        rng = rng or self.rng
        plans = self.plan_assignment()
        groups: Dict[float, Tuple[HostingPlan, list]] = {}
        for plan, prompts in zip(plans, prompts_by_instance):
            if prompts is None or len(prompts) == 0:
                continue
            groups.setdefault(plan.level, (plan, []))[1].append(
                np.asarray(prompts))
        batched = [(plan, np.concatenate(parts, axis=0))
                   for plan, parts in groups.values()]
        for res in self.engine.serve_groups(batched, rng):
            self.stats["edge"] += res.served_edge
            self.stats["partial"] += res.served_partial
            self.stats["cloud"] += res.forwarded
            self.stats["requests"] += res.n_requests
        return dict(self.stats)
