"""Slot-based request scheduler wiring arrivals + spot rents + the
HostingController (alpha-RR) + the ServingEngine into the paper's
edge-hosting loop.  This is deliverable (b)'s end-to-end driver core.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.configs.base import ArchSpec
from repro.core.costs import HostingCosts
from repro.core.hosting_controller import HostingController
from repro.core.policies.alpha_rr import AlphaRR
from repro.serve.engine import ServingEngine
from repro.serve.partial import make_plans


@dataclasses.dataclass
class EdgeServingReport:
    total_cost: float
    breakdown: Dict[str, float]
    level_histogram: np.ndarray
    served_edge: int
    served_partial: int
    forwarded: int
    n_requests: int
    n_slots: int

    def summary(self) -> str:
        h = self.level_histogram
        return (f"slots={self.n_slots} requests={self.n_requests} "
                f"edge={self.served_edge} partial={self.served_partial} "
                f"cloud={self.forwarded} | cost={self.total_cost:.2f} "
                f"(fetch={self.breakdown['fetch']:.2f} rent={self.breakdown['rent']:.2f} "
                f"svc={self.breakdown['service']:.2f}) | slots@level={h.tolist()}")


class EdgeServingScheduler:
    """One slot = one batched decode opportunity.  The engine executes, the
    controller (alpha-RR) re-plans; weight 'fetches' switch the active plan
    (in production this is the weight-streaming path; here plan switching is
    immediate and the fetch cost is accounted by the controller)."""

    def __init__(self, spec: ArchSpec, M: float, alpha: Optional[float] = None,
                 policy_cls=AlphaRR, seed: int = 0, engine: ServingEngine = None,
                 use_model2: bool = None):
        self.spec = spec
        self.engine = engine or ServingEngine(spec)
        self.plans, g_alpha = make_plans(spec, alpha, model_cfg=self.engine.cfg)
        alpha = [l for l in self.plans if 0.0 < l < 1.0][0]
        self.costs = HostingCosts.three_level(M=M, alpha=alpha, g_alpha=g_alpha)
        self.controller = HostingController(self.costs, policy_cls)
        # the controller's grid may be coarser than the plan set (e.g. a
        # RetroRenting controller never uses the partial plan)
        self.rng = np.random.default_rng(seed)
        self.use_model2 = (use_model2 if use_model2 is not None
                           else spec.partial_plan == "expert_subset")
        self.levels = sorted(self.plans)
        self.stats = {"edge": 0, "partial": 0, "cloud": 0, "requests": 0}

    def _prompts(self, n: int, seq: int = 8) -> Optional[np.ndarray]:
        if n == 0:
            return None
        return self.rng.integers(0, self.spec.tiny.vocab_size, size=(n, seq))

    def run(self, arrivals: np.ndarray, rents: np.ndarray,
            run_model: bool = True) -> EdgeServingReport:
        assert len(arrivals) == len(rents)
        for t, (x_t, c_t) in enumerate(zip(arrivals, rents)):
            lv = self.controller.level          # policy's own level value
            plan = self.plans[min(self.plans, key=lambda l: abs(l - lv))]
            x_t = int(x_t)
            if run_model:
                res = self.engine.serve_slot(self._prompts(x_t), plan, self.rng)
                self.stats["edge"] += res.served_edge
                self.stats["partial"] += res.served_partial
                self.stats["cloud"] += res.forwarded
                self.stats["requests"] += res.n_requests
                realized = res.service_cost
            else:
                realized = None
            # realized per-level service costs for the controller's
            # retrospection (coupled across levels, Model 2) or Model-1 g*x
            if self.use_model2:
                u = self.rng.random(max(x_t, 1))[:x_t]
                svc = np.array([float(np.sum(u < gk))
                                for gk in self.controller.costs.g])
                if realized is not None and plan.kind == "expert_subset":
                    svc[self.controller.level_idx] = realized
            else:
                svc = None
            self.controller.step(x_t, float(c_t), svc)
        br = self.controller.cost_breakdown()
        return EdgeServingReport(
            total_cost=br["total"], breakdown=br,
            level_histogram=self.controller.level_histogram(),
            served_edge=self.stats["edge"], served_partial=self.stats["partial"],
            forwarded=self.stats["cloud"], n_requests=self.stats["requests"],
            n_slots=len(arrivals))
