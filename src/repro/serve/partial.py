"""Partial-hosting plans: how a hosting level r in {0, alpha, 1} is realised
for each architecture family (DESIGN.md §4).

Model 1 (layer_prefix): host the first ceil(alpha * n_segments) segments +
the LM head; the edge produces an early-exit draft (partial response of
independent value); the cloud completes.  g(alpha) is the residual value
fraction the cloud must still provide.

Model 2 (expert_subset): host all non-expert weights + the ceil(alpha * E)
most popular routed experts.  A request is fully edge-servable iff all its
top-k routed experts are resident — exactly the paper's random-service
model, with g(alpha) measured from router statistics
(core.gcurve.moe_expert_gcurve).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import ArchSpec
from repro.core.gcurve import moe_expert_gcurve, zipf_popularity


@dataclasses.dataclass(frozen=True)
class HostingPlan:
    level: float                      # fraction of the service hosted
    kind: str                         # none | layer_prefix | expert_subset | full
    n_segments: Optional[int] = None  # layer_prefix: segments resident
    expert_mask: Optional[np.ndarray] = None   # expert_subset: [E] 0/1
    bytes_fraction: float = 0.0       # actual fraction of weight bytes resident
    g_value: float = 1.0              # service cost per request at this level


def _expert_bytes_fraction(spec: ArchSpec, n_hosted: int, cfg=None) -> float:
    cfg = cfg if cfg is not None else spec.model
    total_expert = 0
    for kind, n in cfg.segments:
        if kind in ("moe", "mla_moe"):
            total_expert += n * cfg.n_routed_experts * 3 * cfg.d_model * cfg.d_expert
    from repro.train.steps import abstract_params
    import jax
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract_params(cfg)))
    frac_expert = total_expert / total
    return (1.0 - frac_expert) + frac_expert * n_hosted / max(cfg.n_routed_experts, 1)


def make_plans(spec: ArchSpec, alpha: Optional[float] = None,
               popularity: Optional[np.ndarray] = None,
               top_k_samples: int = 4000, seed: int = 0, model_cfg=None):
    """Returns {0.0: none-plan, alpha: partial-plan, 1.0: full-plan} and the
    measured g(alpha).  ``model_cfg`` overrides spec.model (e.g. the engine
    actually serves the reduced config in CPU tests)."""
    alpha = alpha if alpha is not None else spec.alpha_default
    cfg = model_cfg if model_cfg is not None else spec.model
    plans = {0.0: HostingPlan(level=0.0, kind="none", g_value=1.0),
             1.0: HostingPlan(level=1.0, kind="full", bytes_fraction=1.0,
                              g_value=0.0)}
    if spec.partial_plan == "expert_subset" and cfg.n_routed_experts:
        e = cfg.n_routed_experts
        pop = popularity if popularity is not None else zipf_popularity(e, 1.0)
        n_hosted = int(np.ceil(alpha * e))
        order = np.argsort(-pop)
        mask = np.zeros(e, np.float32)
        mask[order[:n_hosted]] = 1.0
        _, gs, _ = moe_expert_gcurve(pop, cfg.moe_top_k, [alpha],
                                     n_samples=top_k_samples, seed=seed)
        g_alpha = float(gs[0])
        plans[alpha] = HostingPlan(
            level=alpha, kind="expert_subset", expert_mask=mask,
            bytes_fraction=_expert_bytes_fraction(spec, n_hosted, cfg),
            g_value=g_alpha)
    else:
        n_seg = max(1, int(round(alpha * len(cfg.segments))))
        g_alpha = spec.g_alpha_default
        plans[alpha] = HostingPlan(
            level=alpha, kind="layer_prefix", n_segments=n_seg,
            bytes_fraction=alpha, g_value=g_alpha)
    return plans, g_alpha
