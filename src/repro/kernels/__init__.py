"""Pallas kernels for the repo's compute hot-spots.

Layout: one ``<name>.py`` per kernel family (``flash_attention``,
``ssd_scan``, ``hosting`` — the DP min-plus recursion and the counter-keyed
PRNG), jitted public wrappers in ``ops.py``, pure-jnp oracles in ``ref.py``,
shared padding/block plumbing in ``utils.py``.

The ``interpret=True``-on-CPU convention
----------------------------------------
Every wrapper takes an ``interpret`` flag.  On CPU (the test/CI platform)
there is no Mosaic backend, so ``interpret=True`` is the only executable
path: the kernel body runs through the Pallas interpreter as plain XLA
ops — semantically (and for the hosting kernels *bitwise*) identical to
the compiled lowering, but NOT a TPU performance proxy.  Wrappers called
from the engine resolve ``interpret=None`` via ``utils.default_interpret``
(True iff ``jax.default_backend() == "cpu"``); benchmarks record which leg
they measured (``backend`` / ``device_kind`` keys in ``benchmarks/run.py
--json``).  On real TPU pass ``interpret=False`` (or rely on the default
resolution) to get the compiled kernel.

Backend-dispatch rules (pure XLA stays canonical; any alternative kernel
must prove exact bit-identity before it can be selected) are documented in
``docs/CONVENTIONS.md``; how the kernel rows are benchmarked and gated in
``docs/BENCHMARKS.md``.
"""
