"""Pallas kernels for the hosting engine's two per-slot hot paths.

1. ``dp_minplus_kc`` — the offline-OPT forward recursion
   (``offline_opt.dp_fwd_chunk``'s scan body) fused over a whole [chunk] of
   slots: the [K] value frontier stays in registers/VMEM across the slot
   loop instead of round-tripping through a ``lax.scan`` carry, and the
   kernel emits the [chunk, K] argmin table for backtracking.  Frontier
   freezing past ``T_len`` (identity argmins on invalid slots) and ``+inf``
   pricing of padded K levels ride in unchanged: invalid slots carry ``J``
   through and write ``iota`` rows, and ``+inf`` entries of ``w``/``fetch``
   propagate through min/argmin exactly as in the XLA reference.

2. ``slot_uniform_tc`` — the counter-keyed uniform draw of
   ``scenarios.base.slot_uniform`` with the whole threefry2x32 chain
   (``fold_in(key, t)`` -> optional salt fold -> uniform bits) fused into
   one kernel pass per [chunk] of slots, instead of 2-3 vmapped
   ``jax.random`` dispatches per chunk.

Both kernels are **bit-identical** to their ``jax.random`` / ``lax.scan``
references — same hash, same u->bits mapping, same float op order — which
is what lets the engine treat backend choice as a pure performance knob
(see the backend-dispatch invariant in ROADMAP.md).  The batched [B] form
is ``jax.vmap`` of the per-instance kernel: Pallas lifts the vmap onto a
leading grid axis, so the fleet engine's existing per-instance vmap is the
blocking over [B].

The threefry2x32 implementation below (rotation schedule, key schedule,
counter layout) mirrors jax's; ``tests/test_kernels.py`` pins exact bit
equality against ``jax.random.fold_in`` / ``uniform`` across random keys,
salts and non-aligned chunk sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.utils import default_interpret, pad_to

# Slot axis is padded to this multiple (f32 sublane count on TPU); padded
# DP slots run as frozen (valid=False) slots, padded PRNG counters draw
# dead uniforms — both sliced off by the wrappers.
_SLOT_MULT = 8


# ----------------------------------------------------------------------
# threefry2x32 (the jax.random hash), as plain jnp ops: traceable inside a
# Pallas kernel body and usable standalone as an XLA reference.
# ----------------------------------------------------------------------

_ROTS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = np.uint32(0x1BD11BDA)


def threefry2x32(k0, k1, x0, x1):
    """One threefry2x32 block: hash counter words ``(x0, x1)`` under key
    ``(k0, k1)``; all args uint32 arrays (broadcastable).  Bit-identical to
    jax's ``threefry2x32`` primitive — 20 rounds, 5 key injections."""
    k2 = k0 ^ k1 ^ _PARITY
    ks = (k0, k1, k2)
    x0 = x0 + k0
    x1 = x1 + k1
    for r in range(5):
        for rot in _ROTS[r % 2]:
            x0 = x0 + x1
            x1 = (x1 << rot) | (x1 >> (32 - rot))
            x1 = x0 ^ x1
        x0 = x0 + ks[(r + 1) % 3]
        x1 = x1 + ks[(r + 2) % 3] + np.uint32(r + 1)
    return x0, x1


def threefry_fold(k0, k1, d):
    """``jax.random.fold_in((k0, k1), d)`` on raw uint32 words: hash the
    fold data as a 1-word counter; the output pair is the folded key."""
    return threefry2x32(k0, k1, jnp.zeros_like(d), d)


def uniform_from_bits(bits):
    """jax's uint32 -> U(0,1) float32 mapping: splice the top 23 random
    bits into a [1, 2) float, subtract 1.  The trailing ``maximum`` mirrors
    ``jax.random.uniform``'s clamp op-for-op (a bitwise no-op here since
    the result is already >= 0)."""
    fb = (bits >> np.uint32(9)) | np.uint32(0x3F800000)
    u = jax.lax.bitcast_convert_type(fb, jnp.float32) - np.float32(1.0)
    return jnp.maximum(np.float32(0.0), u)


# ----------------------------------------------------------------------
# Kernel 1: fused DP min-plus forward chunk.
# ----------------------------------------------------------------------

def _dp_minplus_kernel(j_ref, w_ref, f_ref, valid_ref, jout_ref, args_ref,
                       *, chunk: int, K: int):
    fetch = f_ref[...]                            # [K, K], VMEM-resident
    iota = jax.lax.iota(jnp.int32, K)

    def body(t, J):
        # the exact op order of dp_fwd_chunk's scan body — argmin before
        # min matters for nothing, but where/add order does for bits
        trans = J[:, None] + fetch                # [K_prev, K_next]
        arg = jnp.argmin(trans, axis=0)
        Jn = jnp.min(trans, axis=0) + w_ref[t, :]
        v = valid_ref[t]
        Jn = jnp.where(v, Jn, J)
        arg = jnp.where(v, arg, iota)
        args_ref[t, :] = arg
        return Jn

    jout_ref[...] = jax.lax.fori_loop(0, chunk, body, j_ref[...])


def dp_minplus_kc(J, wck, fetch_mat, valid, *, interpret=None):
    """One instance, one chunk of the DP forward recursion.

    Args: ``J`` [K] float32 entry frontier; ``wck`` [chunk, K] float32
    per-slot holding costs (``+inf`` on masked levels); ``fetch_mat``
    [K, K] float32; ``valid`` [chunk] bool (``tids < T_len``).
    Returns ``(J' [K], args [chunk, K] int32)`` — bit-identical to the
    ``lax.scan`` body in ``offline_opt.dp_fwd_chunk``.

    Batched use is ``jax.vmap`` over a leading [B] axis (Pallas turns that
    into the batch grid dimension).  The slot axis is padded to a sublane
    multiple with *frozen* slots (valid=False carries J through and writes
    identity argmins), so padding is exact by the same invariant that
    freezes real slots past ``T_len``.
    """
    chunk, K = wck.shape
    if interpret is None:
        interpret = default_interpret()
    wck, _ = pad_to(wck, 0, _SLOT_MULT)
    valid, _ = pad_to(valid, 0, _SLOT_MULT)       # pads False -> frozen
    chunk_p = wck.shape[0]
    kernel = functools.partial(_dp_minplus_kernel, chunk=chunk_p, K=K)
    Jout, args = pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct((K,), jnp.float32),
                   jax.ShapeDtypeStruct((chunk_p, K), jnp.int32)],
        interpret=interpret,
    )(J.astype(jnp.float32), wck.astype(jnp.float32),
      fetch_mat.astype(jnp.float32), valid)
    return Jout, args[:chunk]


# ----------------------------------------------------------------------
# Kernel 2: fused counter-keyed uniform generation.
# ----------------------------------------------------------------------

def _slot_uniform_kernel(key_ref, t_ref, u_ref, *, salt):
    k0 = key_ref[0]
    k1 = key_ref[1]
    t = t_ref[...].astype(jnp.uint32)
    z = jnp.zeros_like(t)
    a0, a1 = threefry2x32(k0, k1, z, t)           # fold_in(key, t)
    if salt is not None:
        a0, a1 = threefry2x32(a0, a1, z, jnp.full_like(t, np.uint32(salt)))
    bits, _ = threefry2x32(a0, a1, z, z)          # random_bits(key, 32, ())
    u_ref[...] = uniform_from_bits(bits)


def slot_uniform_tc(key, tids, salt=None, *, interpret=None):
    """One instance, one chunk of counter-keyed U(0,1) draws.

    Args: ``key`` raw uint32 [2] PRNG key; ``tids`` [chunk] int32 global
    slot counters; ``salt`` optional *static* int sub-stream fold.
    Returns [chunk] float32 — bit-identical to
    ``scenarios.base.slot_uniform``'s vmapped ``fold_in`` + ``uniform``
    chain.  Batched use is ``jax.vmap`` over [B, 2] keys.
    """
    chunk = tids.shape[0]
    if interpret is None:
        interpret = default_interpret()
    tids, _ = pad_to(tids, 0, _SLOT_MULT)         # dead counters, sliced off
    kernel = functools.partial(_slot_uniform_kernel,
                               salt=None if salt is None else int(salt))
    u = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(tids.shape, jnp.float32),
        interpret=interpret,
    )(jnp.asarray(key, jnp.uint32), tids)
    return u[:chunk]
