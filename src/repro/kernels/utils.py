"""Shared wrapper plumbing for the Pallas kernels: padding to block
multiples, block-size fitting, and the interpret-default resolution.

Every public wrapper in ``ops.py`` (and the hosting kernels'
``dp_minplus_kc`` / ``slot_uniform_tc``) pads its inputs up to the kernel's
block multiple, runs the kernel, and slices the pad back off — this module
is the ONE copy of that arithmetic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_to(x, axis: int, mult: int, value=0):
    """Pad ``x`` along ``axis`` up to the next multiple of ``mult`` with
    ``value`` (0/False by default).  Returns ``(padded, pad)``."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), pad


def fit_block(block: int, n: int, floor: int = 16) -> int:
    """Shrink a requested block size to the next power of two covering
    ``n`` (never below ``floor``): tiny inputs then run as one block
    instead of padding up to the full requested block."""
    return min(block, max(floor, 1 << (n - 1).bit_length()))


def default_interpret() -> bool:
    """Resolve ``interpret=None``: True on CPU (no Mosaic backend — the
    kernel body runs through the Pallas interpreter, bit-identical to the
    compiled lowering), False on TPU.  See ``kernels.__init__``."""
    return jax.default_backend() == "cpu"
