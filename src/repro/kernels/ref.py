"""Pure-jnp oracles for every Pallas kernel (the ground truth the tests
sweep against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, causal: bool = True, q_offset: int = 0):
    """q [B,S,Hq,hd]; k/v [B,Skv,Hkv,hd] -> [B,S,Hq,hd]; fp32 softmax."""
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, sq, hkv, g, hd).astype(jnp.float32) / np.sqrt(hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool) if not causal else (
        kpos[None, :] <= qpos[:, None])
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C, h0=None):
    """Token-level recurrence. x [b,s,nh,dh]; dt [b,s,nh]; A [nh];
    B/C [b,s,ng,ds]. Returns (y [b,s,nh,dh] fp32-accurate, hT)."""
    b, s, nh, dh = x.shape
    ng, ds = B.shape[2], B.shape[3]
    rep = nh // ng
    h = jnp.zeros((b, nh, dh, ds), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        la = dtt * A[None, :]
        bth = jnp.repeat(bt, rep, axis=1)
        cth = jnp.repeat(ct, rep, axis=1)
        u = (xt * dtt[..., None]).astype(jnp.float32)
        h = jnp.exp(la)[:, :, None, None] * h + u[..., None] * bth[:, :, None, :]
        y = jnp.einsum("bhdn,bhn->bhd", h, cth.astype(jnp.float32))
        return h, y

    hT, ys = jax.lax.scan(step, h, (jnp.moveaxis(x, 1, 0),
                                    jnp.moveaxis(dt, 1, 0),
                                    jnp.moveaxis(B, 1, 0),
                                    jnp.moveaxis(C, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hT


def topk_gate_ref(logits, k: int):
    """Softmax -> top-k -> renormalise. logits [N, E] fp32."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ids = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, ids
