"""Jitted public wrappers around the Pallas kernels: layout handling,
padding to block multiples, and dtype plumbing.  ``interpret`` defaults to
True (CPU validation); on real TPU pass interpret=False.

Padding/block-fitting arithmetic lives in ``kernels.utils`` (one shared
copy, also used by the hosting kernels' own wrappers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.hosting import dp_minplus_kc, slot_uniform_tc
from repro.kernels.ssd_scan import ssd_scan_bhcqd
from repro.kernels.utils import fit_block, pad_to as _pad_to


@functools.partial(jax.jit, static_argnames=("causal", "q_offset", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, q_offset: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q [B,S,Hq,hd]; k/v [B,Skv,Hkv,hd] -> [B,S,Hq,hd]."""
    b, sq, hq, hd = q.shape
    qb = jnp.moveaxis(q, 2, 1)                    # [B,H,S,hd]
    kb = jnp.moveaxis(k, 2, 1)
    vb = jnp.moveaxis(v, 2, 1)
    bq = fit_block(bq, sq)
    bk = fit_block(bk, k.shape[1])
    qb, pq = _pad_to(qb, 2, bq)
    kb, pk = _pad_to(kb, 2, bk)
    vb, _ = _pad_to(vb, 2, bk)
    out = flash_attention_bhsd(qb, kb, vb, causal=causal, q_offset=q_offset,
                               bq=bq, bk=bk, interpret=interpret)
    out = out[:, :, :sq]
    return jnp.moveaxis(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, h0=None, chunk: int = 128, interpret: bool = True):
    """Mamba2 SSD. x [b,s,nh,dh]; dt [b,s,nh]; A [nh]; B/C [b,s,ng,ds];
    h0 [b,nh,dh,ds] or None.  Returns (y [b,s,nh,dh], hT)."""
    b, s, nh, dh = x.shape
    ng, ds = B.shape[2], B.shape[3]
    q = fit_block(chunk, s)
    xp, pad = _pad_to(x, 1, q)
    dtp, _ = _pad_to(dt, 1, q)         # padded dt=0 -> decay 1, input 0: no-op
    Bp, _ = _pad_to(B, 1, q)
    Cp, _ = _pad_to(C, 1, q)
    nc = xp.shape[1] // q
    xr = jnp.moveaxis(xp.reshape(b, nc, q, nh, dh), 3, 1)     # [b,nh,nc,q,dh]
    dtr = jnp.moveaxis(dtp.reshape(b, nc, q, nh), 3, 1)       # [b,nh,nc,q]
    Br = jnp.moveaxis(Bp.reshape(b, nc, q, ng, ds), 3, 1)     # [b,ng,nc,q,ds]
    Cr = jnp.moveaxis(Cp.reshape(b, nc, q, ng, ds), 3, 1)
    if h0 is None:
        h0 = jnp.zeros((b, nh, dh, ds), jnp.float32)
    y, hT = ssd_scan_bhcqd(xr, dtr, A.astype(jnp.float32), Br, Cr,
                           h0.astype(jnp.float32), interpret=interpret)
    y = jnp.moveaxis(y, 1, 3).reshape(b, nc * q, nh, dh)[:, :s]
    return y, hT


@functools.partial(jax.jit, static_argnames=("interpret",))
def dp_minplus(J, wck, fetch_mat, valid, interpret: bool = True):
    """Fused DP min-plus forward chunk (``hosting.dp_minplus_kc``).

    Per-instance: J [K], wck [chunk, K], fetch_mat [K, K], valid [chunk];
    batched: a leading [B] axis on every arg.  Returns ``(J', args)`` —
    bit-identical to ``offline_opt.dp_fwd_chunk``'s scan.
    """
    if J.ndim == 2:
        return jax.vmap(lambda j, w, f, v: dp_minplus_kc(
            j, w, f, v, interpret=interpret))(J, wck, fetch_mat, valid)
    return dp_minplus_kc(J, wck, fetch_mat, valid, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("salt", "interpret"))
def counter_uniforms(keys, tids, salt=None, interpret: bool = True):
    """Fused counter-keyed uniforms (``hosting.slot_uniform_tc``).

    ``keys`` raw uint32 [2] (one instance) or [B, 2]; ``tids`` [chunk]
    int32 slot counters; ``salt`` optional static int.  Returns [chunk]
    or [B, chunk] float32, bit-identical to
    ``scenarios.base.slot_uniform``.
    """
    keys = jnp.asarray(keys)
    if keys.ndim == 2:
        return jax.vmap(lambda k: slot_uniform_tc(
            k, tids, salt, interpret=interpret))(keys)
    return slot_uniform_tc(keys, tids, salt, interpret=interpret)
