"""Pallas TPU flash-attention forward kernel.

TPU adaptation notes (DESIGN.md §6): the GPU flash algorithm maps onto the
TPU by (a) tiling Q/K/V into MXU-aligned [128, head_dim] VMEM blocks via
BlockSpec, (b) carrying the online-softmax statistics (m, l, acc) in VMEM
scratch across the innermost (KV) grid dimension — TPU grids iterate
sequentially minor-to-major, so the scratch plays the role of the GPU's
per-CTA registers, and (c) letting the pallas pipeline double-buffer the
HBM->VMEM block streams (no manual cp.async equivalent needed).

Grid: (B, Hq, num_q_blocks, num_kv_blocks), KV innermost.
Block shapes: q/o [1, 1, bq, hd]; k/v [1, 1, bk, hd] (GQA maps q-head h to
kv-head h // group inside the index map).  VMEM footprint per step:
(2*bq + 2*bk) * hd * bytes + scratch — ~132 KiB at bq=bk=128, hd=128, bf16,
comfortably inside the ~16 MiB v5e VMEM budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, q_offset: int,
                  bq: int, bk: int, seq_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = q @ k.T                                          # [bq, bk] on the MXU
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < seq_kv
    if causal:
        valid = valid & (kpos <= qpos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, q_offset: int = 0,
                         bq: int = 128, bk: int = 128,
                         interpret: bool = True):
    """q [B,Hq,Sq,hd]; k/v [B,Hkv,Skv,hd] -> [B,Hq,Sq,hd].

    Sq/Skv are padded to block multiples by the caller (ops.py)."""
    b, hq, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    bq_ = min(bq, sq)
    bk_ = min(bk, skv)
    assert sq % bq_ == 0 and skv % bk_ == 0
    grid = (b, hq, sq // bq_, skv // bk_)
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, q_offset=q_offset,
        bq=bq_, bk=bk_, seq_kv=skv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq_, hd), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk_, hd), lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk_, hd), lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, hd), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),    # m: running row max
            pltpu.VMEM((bq_, 1), jnp.float32),    # l: running row sum
            pltpu.VMEM((bq_, hd), jnp.float32),   # acc: unnormalised output
        ],
        interpret=interpret,
    )(q, k, v)
