"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU adaptation: the SSD "state-space dual" form exposes the intra-chunk term
as a [Q, Q] masked matmul — MXU food — while the inter-chunk recurrence is a
tiny [dh, ds] state update.  We put the chunk loop on the innermost grid
dimension (TPU grids are sequential minor-to-major) and carry the state in
VMEM scratch, which is exactly the role thread-block-resident shared memory
plays in the CUDA implementation; BlockSpec streams x/dt/B/C chunk blocks
HBM->VMEM with automatic double buffering.

Grid: (batch, heads, num_chunks).  Per-step VMEM: x [Q, dh], B/C [Q, ds],
dt [Q], state [dh, ds] — at Q=128, dh=64, ds=128, fp32: ~0.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hT_ref,
                h_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # [Q, dh]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # [Q]
    A = a_ref[0].astype(jnp.float32)             # scalar (per head)
    B = b_ref[0, 0, 0].astype(jnp.float32)       # [Q, ds]
    C = c_ref[0, 0, 0].astype(jnp.float32)       # [Q, ds]

    la = dt * A                                  # [Q], negative
    L = jnp.cumsum(la)                           # inclusive
    u = x * dt[:, None]                          # [Q, dh]

    # intra-chunk: y_i += sum_{j<=i} exp(L_i - L_j) (C_i . B_j) u_j
    g = C @ B.T                                  # [Q, Q] MXU
    dec = L[:, None] - L[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = iq >= jq
    m = jnp.where(causal, g, 0.0) * jnp.exp(jnp.where(causal, dec, -jnp.inf))
    y = m @ u                                    # [Q, dh]

    # inter-chunk: y_i += exp(L_i) C_i h_in
    h = h_scr[...]                               # [dh, ds]
    y = y + (jnp.exp(L)[:, None] * C) @ h.T      # [Q, ds] @ [ds, dh]

    # state update: h_out = exp(L_Q) h_in + sum_j exp(L_Q - L_j) u_j B_j^T
    w = jnp.exp(L[-1] - L)                       # [Q]
    h_scr[...] = jnp.exp(L[-1]) * h + (u * w[:, None]).T @ B

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        hT_ref[0, 0] = h_scr[...].astype(hT_ref.dtype)


def ssd_scan_bhcqd(x, dt, A, B, C, h0, *, interpret: bool = True):
    """x [b, nh, nc, Q, dh]; dt [b, nh, nc, Q]; A [nh];
    B/C [b, ng, nc, Q, ds] (ng groups, heads map h -> h * ng // nh);
    h0 [b, nh, dh, ds].  Returns (y like x, hT [b, nh, dh, ds])."""
    b, nh, nc, q, dh = x.shape
    ng, ds = B.shape[1], B.shape[4]
    rep = nh // ng
    grid = (b, nh, nc)
    kernel = functools.partial(_ssd_kernel, chunk=q)
    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, dh), lambda bi, h, c: (bi, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bi, h, c: (bi, h, c, 0)),
            pl.BlockSpec((1,), lambda bi, h, c: (h,)),
            pl.BlockSpec((1, 1, 1, q, ds), lambda bi, h, c: (bi, h // rep, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, ds), lambda bi, h, c: (bi, h // rep, c, 0, 0)),
            pl.BlockSpec((1, 1, dh, ds), lambda bi, h, c: (bi, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, dh), lambda bi, h, c: (bi, h, c, 0, 0)),
            pl.BlockSpec((1, 1, dh, ds), lambda bi, h, c: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, nc, q, dh), x.dtype),
            jax.ShapeDtypeStruct((b, nh, dh, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, h0)
    return y, hT
