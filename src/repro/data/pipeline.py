"""Deterministic synthetic token pipeline.

Sequences follow a learnable order-1 Markov process over the vocabulary
(token_{t+1} = (a * token_t + b + eps) mod V with small-support noise), so a
few hundred training steps visibly reduce loss — which is what the
end-to-end example driver demonstrates.  Sharded loading: each data shard
seeds from (seed, shard_index, step) so restarts and elastic re-sharding
reproduce the exact same global batch ordering.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int                  # global batch
    seq: int
    seed: int = 0
    a: int = 5
    b: int = 17
    noise: int = 3              # eps in [-noise, noise]


class SyntheticLM:
    def __init__(self, cfg: DataConfig, shard_index: int = 0, n_shards: int = 1):
        assert cfg.batch % n_shards == 0
        self.cfg = cfg
        self.shard_index = shard_index
        self.n_shards = n_shards
        self.local_batch = cfg.batch // n_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a given step (restart-stable)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, self.shard_index, step]))
        x = np.empty((self.local_batch, cfg.seq + 1), np.int64)
        x[:, 0] = rng.integers(0, cfg.vocab_size, self.local_batch)
        eps = rng.integers(-cfg.noise, cfg.noise + 1,
                           (self.local_batch, cfg.seq))
        for t in range(cfg.seq):
            x[:, t + 1] = (cfg.a * x[:, t] + cfg.b + eps[:, t]) % cfg.vocab_size
        return {"tokens": x[:, :-1].astype(np.int32),
                "labels": x[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def request_stream(key_seed: int, n_slots: int, process: str = "gilbert",
                   **kw) -> np.ndarray:
    """Request arrivals for the serving drivers (shared with core.arrivals)."""
    import jax
    from repro.core import arrivals
    key = jax.random.PRNGKey(key_seed)
    if process == "bernoulli":
        return np.asarray(arrivals.bernoulli(key, kw.get("p", 0.35), n_slots))
    if process == "poisson":
        return np.asarray(arrivals.poisson(key, kw.get("lam", 4.0), n_slots))
    if process == "gilbert":
        ge = arrivals.GilbertElliot(
            p_hl=kw.get("p_hl", 0.4), p_lh=kw.get("p_lh", 0.4),
            rate_h=kw.get("rate_h", 8.0), rate_l=kw.get("rate_l", 1.0))
        return np.asarray(ge.sample(key, n_slots))
    if process == "cluster":
        return np.asarray(arrivals.cluster_trace_like(key, n_slots, **kw))
    raise ValueError(process)
