"""stablelm-1.6b [dense]: 24L d_model=2048 32H MHA (kv=32) d_ff=5632
vocab=100352, partial rotary (25%).  [hf:stabilityai/stablelm-2-1_6b;
unverified]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig

MODEL = ModelConfig(
    name="stablelm-1.6b",
    d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632, vocab_size=100352,
    segments=(("dense", 24),),
    rope_theta=10000.0, rotary_dim=16,        # 25% of head_dim 64
)

TINY = ModelConfig(
    name="stablelm-tiny",
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
    segments=(("dense", 2),), rotary_dim=8,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    attn_impl="naive", remat=False, loss_chunk=16,
)

ARCH = register(ArchSpec(
    arch_id="stablelm-1.6b", family="dense", model=MODEL, tiny=TINY,
    partial_plan="layer_prefix", alpha_default=0.5, g_alpha_default=0.55,
    long_context_ok=False,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
    notes="long_500k skipped (full attention).",
))
