"""llama3.2-3b [dense]: 28L d_model=3072 24H (kv=8) d_ff=8192 vocab=128256,
tied embeddings, rope theta 500k.  [hf:meta-llama/Llama-3.2-3B; unverified]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig

MODEL = ModelConfig(
    name="llama3.2-3b",
    d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192, vocab_size=128256,
    segments=(("dense", 28),),
    rope_theta=500000.0, tie_embeddings=True,
)

TINY = ModelConfig(
    name="llama3.2-tiny",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    segments=(("dense", 2),), tie_embeddings=True,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    attn_impl="naive", remat=False, loss_chunk=16,
)

ARCH = register(ArchSpec(
    arch_id="llama3.2-3b", family="dense", model=MODEL, tiny=TINY,
    partial_plan="layer_prefix", alpha_default=0.5, g_alpha_default=0.55,
    long_context_ok=False,
    source="hf:meta-llama/Llama-3.2-3B; unverified",
    notes="alpha+g(alpha)>=1 at the default point: Theorem 1 predicts "
          "alpha-RR degenerates to RR here (verified in benchmarks). "
          "long_500k skipped (full attention).",
))
