"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (kv=8) d_ff=14336
vocab=128256 with gated cross-attention image layers every 5th layer.
Vision frontend is a STUB: input_specs provides precomputed patch
embeddings [B, 1601, 1280]; the in-model projection maps them to d_model.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig

_SEGMENTS = tuple([("dense", 4), ("cross", 1)] * 8)   # 40 layers, cross at every 5th

MODEL = ModelConfig(
    name="llama-3.2-vision-11b",
    d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=128256,
    segments=_SEGMENTS,
    rope_theta=500000.0,
    frontend="vision", frontend_dim=1280, frontend_tokens=1601,
)

TINY = ModelConfig(
    name="llama-vision-tiny",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    segments=tuple([("dense", 2), ("cross", 1)] * 2),
    frontend="vision", frontend_dim=32, frontend_tokens=17,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    attn_impl="naive", remat=False, loss_chunk=16,
)

ARCH = register(ArchSpec(
    arch_id="llama-3.2-vision-11b", family="vlm", model=MODEL, tiny=TINY,
    partial_plan="layer_prefix", alpha_default=0.6, g_alpha_default=0.45,
    long_context_ok=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    notes="Partial plan hosts the text-only prefix (cross-attn dropped): "
          "text answer at the edge now, image grounding from the cloud. "
          "long_500k skipped (full attention).",
))
