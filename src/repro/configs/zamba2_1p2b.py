"""zamba2-1.2b [hybrid]: 38 Mamba2 layers + a weight-tied shared attention
block applied every ~6 layers.  38L d_model=2048 32H (kv=32) d_ff=8192
vocab=32000 ssm_state=64.  [arXiv:2411.15242; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig

# 6 shared-attention applications interleaved with 38 mamba2 layers
_SEGMENTS = tuple([("shared_ref", 1), ("ssm", 6)] * 6 + [("ssm", 2)])

MODEL = ModelConfig(
    name="zamba2-1.2b",
    d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32000,
    segments=_SEGMENTS,
    rope_theta=10000.0,
    ssm_state=64, ssm_d_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_n_groups=1,
)

TINY = ModelConfig(
    name="zamba2-tiny",
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
    segments=tuple([("shared_ref", 1), ("ssm", 2)] * 2),
    ssm_state=16, ssm_d_conv=4, ssm_expand=2, ssm_head_dim=32, ssm_n_groups=1,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    attn_impl="naive", remat=False, ssm_chunk=8, loss_chunk=16,
)

ARCH = register(ArchSpec(
    arch_id="zamba2-1.2b", family="hybrid", model=MODEL, tiny=TINY,
    partial_plan="layer_prefix", alpha_default=0.4, g_alpha_default=0.45,
    long_context_ok=True,
    source="arXiv:2411.15242; hf",
    notes="Hybrid SSM: long_500k runs (decode state is O(1) for SSM layers; "
          "the 6 shared-attn applications decode one query against the cache).",
))
