"""Architecture registry: each assigned arch contributes an ArchSpec with the
exact published config, a reduced ``tiny`` variant for CPU smoke tests, its
partial-hosting plan (the paper's technique), and the input-shape grid.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.models.transformer import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int           # train/prefill length, or KV-cache length for decode
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    model: ModelConfig
    tiny: ModelConfig
    partial_plan: str                 # "layer_prefix" (Model 1) | "expert_subset" (Model 2)
    alpha_default: float              # default partial hosting level
    g_alpha_default: float            # measured/assumed g(alpha) for the plan
    long_context_ok: bool             # run long_500k? (sub-quadratic families only)
    source: str
    notes: str = ""

    def shapes(self):
        for s in SHAPES.values():
            if s.name == "long_500k" and not self.long_context_ok:
                continue
            yield s

    def param_count(self) -> int:
        """Analytic param count (no allocation)."""
        import jax
        from repro.models.transformer import init_params
        tree = jax.eval_shape(lambda k: init_params(self.model, k),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
        return sum(int(jnp.prod(jnp.array(l.shape))) for l in jax.tree.leaves(tree))


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    if spec.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch {spec.arch_id}")
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    return _REGISTRY[arch_id]


def all_archs() -> Dict[str, ArchSpec]:
    _ensure_loaded()
    return dict(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    from repro.configs import (zamba2_1p2b, deepseek_moe_16b, deepseek_v2_236b,  # noqa
                               musicgen_medium, llama32_vision_11b, llama32_3b,  # noqa
                               qwen25_14b, granite_20b, stablelm_1p6b, mamba2_130m)  # noqa
