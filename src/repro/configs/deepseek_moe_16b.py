"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) fine-grained MoE —
2 shared + 64 routed top-6 experts of d_expert=1408; first layer dense;
vocab=102400.  [arXiv:2401.06066; hf]

This is the canonical arch for the paper's Model-2 partial hosting: host the
alpha most popular routed experts; a request is edge-servable iff its top-6
experts are all resident (g(alpha) from router statistics)."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig

MODEL = ModelConfig(
    name="deepseek-moe-16b",
    d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944, vocab_size=102400,
    segments=(("dense", 1), ("moe", 27)),
    rope_theta=10000.0,
    n_routed_experts=64, n_shared_experts=2, moe_top_k=6, d_expert=1408,
)

TINY = ModelConfig(
    name="deepseek-moe-tiny",
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab_size=256,
    segments=(("dense", 1), ("moe", 2)),
    n_routed_experts=8, n_shared_experts=2, moe_top_k=2, d_expert=32,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    attn_impl="naive", remat=False, loss_chunk=16,
    moe_capacity_factor=8.0,   # dropless at tiny scale: decode == full forward
)

ARCH = register(ArchSpec(
    arch_id="deepseek-moe-16b", family="moe", model=MODEL, tiny=TINY,
    partial_plan="expert_subset", alpha_default=0.5, g_alpha_default=0.25,
    long_context_ok=False,
    source="arXiv:2401.06066; hf",
    notes="Model-2 expert-subset hosting; g(alpha) derived from expert "
          "popularity (core/gcurve.py:moe_expert_gcurve). long_500k skipped "
          "(full attention).",
))
