"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048,
decoder-only over EnCodec tokens.  Modality frontend is a STUB: input_specs
provides precomputed frame embeddings added to the code embeddings.
[arXiv:2306.05284; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig

MODEL = ModelConfig(
    name="musicgen-medium",
    d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144, vocab_size=2048,
    segments=(("dense", 48),),
    rope_theta=10000.0,
    frontend="audio", frontend_dim=128, frontend_tokens=0,  # frames == seq
)

TINY = ModelConfig(
    name="musicgen-tiny",
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
    segments=(("dense", 2),),
    frontend="audio", frontend_dim=16, frontend_tokens=0,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    attn_impl="naive", remat=False, loss_chunk=16,
)

ARCH = register(ArchSpec(
    arch_id="musicgen-medium", family="audio", model=MODEL, tiny=TINY,
    partial_plan="layer_prefix", alpha_default=0.5, g_alpha_default=0.5,
    long_context_ok=False,
    source="arXiv:2306.05284; hf",
    notes="Layer-prefix partial hosting = coarse-codebook draft at the edge "
          "(partial response of independent value). long_500k skipped.",
))
