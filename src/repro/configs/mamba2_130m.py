"""mamba2-130m [ssm]: 24L d_model=768, attention-free SSD, ssm_state=128,
vocab=50280.  [arXiv:2405.21060; unverified]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig

MODEL = ModelConfig(
    name="mamba2-130m",
    d_model=768, n_heads=12, n_kv_heads=12, d_ff=0, vocab_size=50280,
    segments=(("ssm", 24),),
    ssm_state=128, ssm_d_conv=4, ssm_expand=2, ssm_head_dim=64, ssm_n_groups=1,
)

TINY = ModelConfig(
    name="mamba2-tiny",
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
    segments=(("ssm", 2),),
    ssm_state=16, ssm_d_conv=4, ssm_expand=2, ssm_head_dim=32, ssm_n_groups=1,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    attn_impl="naive", remat=False, ssm_chunk=8, loss_chunk=16,
)

ARCH = register(ArchSpec(
    arch_id="mamba2-130m", family="ssm", model=MODEL, tiny=TINY,
    partial_plan="layer_prefix", alpha_default=0.5, g_alpha_default=0.5,
    long_context_ok=True,
    source="arXiv:2405.21060; unverified",
    notes="Attention-free: long_500k runs (O(1) decode state). Model too "
          "small for TP on a 16-wide model axis: sharded DP-only with "
          "params replicated (see sharding rules).",
))
