"""deepseek-v2-236b [moe]: 60L d_model=5120 128H MLA (kv_lora=512), MoE with
2 shared + 160 routed top-6 experts (d_expert=1536); vocab=102400; first
layer dense.  [arXiv:2405.04434; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig

MODEL = ModelConfig(
    name="deepseek-v2-236b",
    d_model=5120, n_heads=128, n_kv_heads=128, d_ff=12288, vocab_size=102400,
    segments=(("mla_dense", 1), ("mla_moe", 59)),
    rope_theta=10000.0,
    kv_lora_rank=512, q_lora_rank=1536,
    mla_nope_dim=128, mla_rope_dim=64, mla_v_dim=128,
    n_routed_experts=160, n_shared_experts=2, moe_top_k=6, d_expert=1536,
    fsdp_experts=True,   # 472 GB of bf16 expert params: must shard over data too
)

TINY = ModelConfig(
    name="deepseek-v2-tiny",
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab_size=256,
    segments=(("mla_dense", 1), ("mla_moe", 2)),
    kv_lora_rank=32, q_lora_rank=48,
    mla_nope_dim=16, mla_rope_dim=8, mla_v_dim=16,
    n_routed_experts=8, n_shared_experts=2, moe_top_k=2, d_expert=32,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    attn_impl="naive", remat=False, loss_chunk=16,
    moe_capacity_factor=8.0,   # dropless at tiny scale: decode == full forward
)

ARCH = register(ArchSpec(
    arch_id="deepseek-v2-236b", family="moe", model=MODEL, tiny=TINY,
    partial_plan="expert_subset", alpha_default=0.4, g_alpha_default=0.35,
    long_context_ok=False,
    source="arXiv:2405.04434; hf",
    notes="MLA compressed KV (kv_lora 512 + rope 64) makes edge decode cheap; "
          "Model-2 expert-subset hosting over 160 routed experts. long_500k "
          "skipped (full attention).",
))
