"""granite-20b [dense]: 52L d_model=6144 48H MQA (kv=1) d_ff=24576
vocab=49152, code model.  [arXiv:2405.04324; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig

MODEL = ModelConfig(
    name="granite-20b",
    d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576, vocab_size=49152,
    segments=(("dense", 52),),
    rope_theta=10000.0,
)

TINY = ModelConfig(
    name="granite-tiny",
    d_model=64, n_heads=4, n_kv_heads=1, d_ff=128, vocab_size=256,
    segments=(("dense", 2),),
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    attn_impl="naive", remat=False, loss_chunk=16,
)

ARCH = register(ArchSpec(
    arch_id="granite-20b", family="dense", model=MODEL, tiny=TINY,
    partial_plan="layer_prefix", alpha_default=0.5, g_alpha_default=0.55,
    long_context_ok=False,
    source="arXiv:2405.04324; hf",
    notes="MQA kv=1: KV replicated across TP ranks; decode KV cache is tiny. "
          "long_500k skipped (full attention).",
))
