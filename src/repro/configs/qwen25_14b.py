"""qwen2.5-14b [dense]: 48L d_model=5120 40H (kv=8) d_ff=13824 vocab=152064,
QKV bias, rope theta 1e6.  [hf:Qwen/Qwen2.5-14B; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, register
from repro.models.transformer import ModelConfig

MODEL = ModelConfig(
    name="qwen2.5-14b",
    d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824, vocab_size=152064,
    segments=(("dense", 48),),
    rope_theta=1000000.0, qkv_bias=True,
)

TINY = ModelConfig(
    name="qwen2.5-tiny",
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    segments=(("dense", 2),), qkv_bias=True,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    attn_impl="naive", remat=False, loss_chunk=16,
)

ARCH = register(ArchSpec(
    arch_id="qwen2.5-14b", family="dense", model=MODEL, tiny=TINY,
    partial_plan="layer_prefix", alpha_default=0.5, g_alpha_default=0.55,
    long_context_ok=False,
    source="hf:Qwen/Qwen2.5-14B; hf",
    notes="long_500k skipped (full attention).",
))
