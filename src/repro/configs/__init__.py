from repro.configs.base import (ArchSpec, ShapeSpec, SHAPES, get_arch,
                                all_archs, register)

__all__ = ["ArchSpec", "ShapeSpec", "SHAPES", "get_arch", "all_archs", "register"]
