"""Monte-Carlo driver correctness: the seed axis as a first-class fleet
dimension (``run_fleet(..., n_seeds=S)`` et al.), per the PR-4 acceptance
bar:

* **Seed-fold law** — ``n_seeds=S`` is bit-identical to S independently
  seed-keyed stacked runs (``scenarios.with_seed``) for every policy
  family, the offline DP and schedule evaluation, under chunked / streamed
  drivers, mixed horizons, and a forced-4-CPU-device mesh (subprocess);
* **Replica legality** — ``replicate_seeds`` packs at row ``(b, s)``
  exactly the params ``with_seed`` builds for a standalone run (the seed
  fold happens before the per-slot counter fold, so every replica is a
  legal standalone scenario);
* **Summary consistency** — ``mc_summary`` means/CI bounds equal classic
  dict-row ``mc_aggregate`` on the same per-seed rows (hypothesis property
  test; both sides share ``student_t975``).
"""
import os
import subprocess
import sys
import textwrap

# the summary-consistency test crosses into the benchmark layer
# (benchmarks/ is a repo-root namespace package, like `python -m
# benchmarks.run` uses it)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import scenarios as S
from repro.core.arrivals import GilbertElliot
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import (FleetBatch, FleetResult,
                              evaluate_schedule_fleet, mc_summary,
                              offline_opt_fleet, run_fleet)
from repro.core.policies import (ABCPolicy, AlphaRR, MDPPolicy, RetroRenting,
                                 StaticPolicy)

T = 40
KEY = jax.random.PRNGKey(7)
CHUNKS = [16, 20]      # 20 does not divide 40+pad: exercises the padded tail
NSEEDS = 3


def mixed_costs():
    return [HostingCosts.two_level(4.0),
            HostingCosts.three_level(6.0, 0.25, 0.5),
            HostingCosts.three_level(3.0, 0.5, 0.25),
            HostingCosts(M=5.0, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                         g=(1.0, 0.4, 0.3, 0.15, 0.0)),
            HostingCosts.three_level(8.0, 0.375, 0.375)]


@pytest.fixture(scope="module")
def stacked():
    costs_list = mixed_costs()
    grid = HostingGrid.from_costs(costs_list)
    B = grid.B
    ges = [GilbertElliot(p_hl=0.3, p_lh=0.2 + 0.1 * (i % 3),
                         rate_h=2.0 + i % 2, rate_l=0.2) for i in range(B)]
    sc = S.combine(
        S.ge_arrivals(S.split_keys(KEY, B), np.array([g.p_hl for g in ges]),
                      np.array([g.p_lh for g in ges]),
                      np.array([g.rate_h for g in ges]),
                      np.array([g.rate_l for g in ges]), B),
        S.spot_rents(jax.random.PRNGKey(1), 0.5, B))
    c_means = [0.5] * B
    fleet = FleetBatch.for_scenario(grid, T)
    return costs_list, grid, ges, c_means, sc, fleet


def policy_cases(fleet, costs_list, ges, c_means):
    return [
        ("alpha-RR", AlphaRR.fleet(fleet), False),
        ("RR", RetroRenting.fleet(fleet), True),
        ("static", StaticPolicy.fleet(fleet, fleet.grid.top_index()), False),
        ("MDP", MDPPolicy.fleet(fleet, costs_list, ges, c_means), False),
        ("ABC", ABCPolicy.fleet(fleet, costs_list, ges, c_means), False),
    ]


def interleave(arrays):
    """[S] list of [B, ...] arrays -> the fused row layout [B*S, ...]
    (instance-major, seed-minor)."""
    a = np.stack([np.asarray(x) for x in arrays], axis=1)
    return a.reshape((-1,) + a.shape[2:])


# ----------------------------------------------------------------------
# (a) replica legality: replicate_seeds rows ARE with_seed's params.
# ----------------------------------------------------------------------

def test_replicate_seeds_rows_are_standalone_replicas(stacked):
    *_, sc, fleet = stacked
    rep = S.replicate_seeds(sc, NSEEDS)
    assert (rep.init_fn, rep.chunk_fn) == (sc.init_fn, sc.chunk_fn)
    assert rep.B == sc.B * NSEEDS
    rep_leaves = jax.tree_util.tree_leaves(rep.params)
    for s in range(NSEEDS):
        ws = S.with_seed(sc, s)
        for rl, wl in zip(rep_leaves, jax.tree_util.tree_leaves(ws.params)):
            assert np.array_equal(np.asarray(rl)[s::NSEEDS], np.asarray(wl))


def test_keyless_streams_replicate_identically():
    tr = S.trace_arrivals(np.arange(2 * T, dtype=np.int32).reshape(2, T))
    rep = S.replicate_seeds(tr, NSEEDS)
    x, _ = S.materialize_stream(rep, T)
    x = np.asarray(x).reshape(2, NSEEDS, T)
    for s in range(1, NSEEDS):
        assert np.array_equal(x[:, s], x[:, 0])


# ----------------------------------------------------------------------
# (b) the seed-fold law, every policy x driver config.
# ----------------------------------------------------------------------

def test_seed_fold_law_every_policy(stacked):
    costs_list, grid, ges, c_means, sc, fleet = stacked
    for name, fns, endpoints in policy_cases(fleet, costs_list, ges, c_means):
        fl = fleet.restrict_to_endpoints() if endpoints else fleet
        refs = [run_fleet(fns, fl, scenario=S.with_seed(sc, s))
                for s in range(NSEEDS)]
        for kw in ({}, {"chunk_size": CHUNKS[0]},
                   {"chunk_size": CHUNKS[1], "stream": True}):
            fused = run_fleet(fns, fl, scenario=sc, n_seeds=NSEEDS, **kw)
            assert fused.n_seeds == NSEEDS and fused.B == fl.B * NSEEDS
            for f in ("total", "rent", "service", "fetch", "r_hist",
                      "level_slots", "T"):
                want = interleave([getattr(r, f) for r in refs])
                assert np.array_equal(getattr(fused, f), want), (name, kw, f)


def test_seed_fold_law_offline_dp(stacked):
    costs_list, grid, ges, c_means, sc, fleet = stacked
    refs = [offline_opt_fleet(fleet, scenario=S.with_seed(sc, s))
            for s in range(NSEEDS)]
    for kw in ({}, {"chunk_size": CHUNKS[1]}):
        fo = offline_opt_fleet(fleet, scenario=sc, n_seeds=NSEEDS, **kw)
        assert fo.n_seeds == NSEEDS
        assert np.array_equal(fo.cost, interleave([r.cost for r in refs]))
        assert np.array_equal(fo.r_hist,
                              interleave([r.r_hist for r in refs]))
        assert np.array_equal(fo.sim.total,
                              interleave([r.sim.total for r in refs]))


def test_seed_fold_law_schedule_eval(stacked):
    costs_list, grid, ges, c_means, sc, fleet = stacked
    rng = np.random.default_rng(3)
    r = np.stack([rng.integers(0, cc.K, T) for cc in costs_list])
    refs = [evaluate_schedule_fleet(fleet, r, scenario=S.with_seed(sc, s))
            for s in range(NSEEDS)]
    for kw in ({}, {"chunk_size": CHUNKS[0]}):
        ev = evaluate_schedule_fleet(fleet, r, scenario=sc, n_seeds=NSEEDS,
                                     **kw)
        assert np.array_equal(ev.total, interleave([x.total for x in refs]))
        assert np.array_equal(ev.r_hist, np.repeat(r, NSEEDS, axis=0))
        # already-replicated [B*S] schedules are accepted as-is
        ev2 = evaluate_schedule_fleet(fleet, np.repeat(r, NSEEDS, axis=0),
                                      scenario=sc, n_seeds=NSEEDS, **kw)
        assert np.array_equal(ev2.total, ev.total)


def test_seed_fold_law_mixed_horizons(stacked):
    costs_list, grid, ges, c_means, sc, fleet = stacked
    Ts = [40, 23, 11, 40, 7]
    fl = FleetBatch.for_scenario(grid, Ts)
    fns = AlphaRR.fleet(fl)
    refs = [run_fleet(fns, fl, scenario=S.with_seed(sc, s))
            for s in range(NSEEDS)]
    for kw in ({}, {"chunk_size": CHUNKS[1]},
               {"chunk_size": CHUNKS[1], "stream": True}):
        fused = run_fleet(fns, fl, scenario=sc, n_seeds=NSEEDS, **kw)
        assert np.array_equal(fused.T, interleave([r.T for r in refs]))
        for f in ("total", "r_hist", "level_slots"):
            want = interleave([getattr(r, f) for r in refs])
            assert np.array_equal(getattr(fused, f), want), (kw, f)
    bo = offline_opt_fleet(fl, scenario=sc, n_seeds=NSEEDS,
                           chunk_size=CHUNKS[0])
    per = [offline_opt_fleet(fl, scenario=S.with_seed(sc, s))
           for s in range(NSEEDS)]
    assert np.array_equal(bo.cost, interleave([r.cost for r in per]))


def test_n_seeds_requires_scenario(stacked):
    costs_list, grid, ges, c_means, sc, fleet = stacked
    fleet_m = FleetBatch.from_scenario(grid, sc, T)
    with pytest.raises(ValueError, match="n_seeds"):
        run_fleet(AlphaRR.fleet(fleet_m), fleet_m, n_seeds=2)
    with pytest.raises(ValueError, match="n_seeds"):
        offline_opt_fleet(fleet_m, n_seeds=2)


def test_seed_view_layout(stacked):
    *_, sc, fleet = stacked
    fused = run_fleet(AlphaRR.fleet(fleet), fleet, scenario=sc,
                      n_seeds=NSEEDS)
    assert fused.B_instances == fleet.B
    v = fused.seed_view(fused.total)
    assert v.shape == (fleet.B, NSEEDS)
    assert np.array_equal(v.reshape(-1), fused.total)
    vh = fused.seed_view(fused.r_hist)
    assert vh.shape == (fleet.B, NSEEDS, T)


# ----------------------------------------------------------------------
# (c) mc_summary == mc_aggregate on the same rows (property test).
# ----------------------------------------------------------------------

@st.composite
def seed_tables(draw):
    B = draw(st.integers(1, 5))
    Sn = draw(st.integers(1, 6))
    cells = draw(st.lists(st.integers(-4000, 4000).map(lambda k: k / 8.0),
                          min_size=B * Sn, max_size=B * Sn))
    return B, Sn, np.asarray(cells, np.float64).reshape(B, Sn)


@settings(max_examples=60, deadline=None)
@given(seed_tables())
def test_mc_summary_matches_mc_aggregate(table):
    from benchmarks.common import mc_aggregate
    B, Sn, totals = table
    flat = totals.reshape(-1)
    res = FleetResult(total=flat, fetch=np.zeros_like(flat),
                      rent=np.zeros_like(flat), service=np.zeros_like(flat),
                      r_hist=None, level_slots=np.zeros((B * Sn, 2), np.int64),
                      T=np.full((B * Sn,), T, np.int64), n_seeds=Sn)
    summ = mc_summary(res)
    rows = [{"instance": b, "seed": s, "total": float(totals[b, s])}
            for b in range(B) for s in range(Sn)]
    agg = mc_aggregate(rows, ["instance"], drop=("seed",))
    assert len(agg) == B
    for b, r in enumerate(agg):
        assert r["total"] == pytest.approx(summ["total_mean"][b],
                                           rel=1e-12, abs=1e-12)
        ci = r.get("total_ci95", 0.0)
        assert ci == pytest.approx(summ["total_ci95"][b],
                                   rel=1e-12, abs=1e-12)
    # the FleetResult branch of mc_aggregate reports the same numbers
    direct = mc_aggregate(res)
    for b, r in enumerate(direct):
        assert r["total"] == pytest.approx(summ["total_mean"][b],
                                           rel=1e-12, abs=1e-12)
        assert r.get("total_ci95", 0.0) == pytest.approx(
            summ["total_ci95"][b], rel=1e-12, abs=1e-12)


# ----------------------------------------------------------------------
# (d) antithetic seed pairs: replicate_seeds(..., antithetic=True).
# ----------------------------------------------------------------------

def flip_scenario(B):
    """Both channels flip-capable (bernoulli arrivals + uniform rents)."""
    return S.combine(
        S.bernoulli_arrivals(S.split_keys(KEY, B), 0.4, B),
        S.uniform_rents(S.split_keys(jax.random.PRNGKey(2), B), 0.5, 0.3, B))


def test_antithetic_pair_sum_law_on_seed_axis():
    """Replica pairs (2m, 2m+1) of a flip-capable rent stream share the
    pair fold and flip, so every slot's pair sum is exactly lo + hi; even
    replicas are bitwise ``with_seed``'s standalone rows."""
    B, NS_A = 3, 4
    st_ = S.uniform_rents(S.split_keys(jax.random.PRNGKey(2), B), 0.5, 0.3, B)
    rep = S.replicate_seeds(st_, NS_A, antithetic=True)
    c = np.asarray(S.materialize_stream(rep, T)).reshape(B, NS_A, T)
    # the pair-sum law (lo + hi = 2 * c_mean = 1.0), every pair, every slot
    assert np.allclose(c[:, 0] + c[:, 1], 1.0, atol=1e-6)
    assert np.allclose(c[:, 2] + c[:, 3], 1.0, atol=1e-6)
    # even members ARE the plain seed-m replicas, bit for bit
    for m in range(NS_A // 2):
        ws = np.asarray(S.materialize_stream(S.with_seed(st_, m), T))
        assert np.array_equal(c[:, 2 * m], ws)
    # odd members are the flipped twins: bitwise equal to flipping the
    # pair's stream by hand
    flipped = st_._replace(params={**st_.params,
                                   "flip": jnp.ones((B,), bool)})
    for m in range(NS_A // 2):
        wf = np.asarray(S.materialize_stream(S.with_seed(flipped, m), T))
        assert np.array_equal(c[:, 2 * m + 1], wf)


def test_antithetic_requires_even_seeds_and_scenario(stacked):
    *_, sc, fleet = stacked
    with pytest.raises(ValueError, match="even"):
        S.replicate_seeds(sc, 3, antithetic=True)
    with pytest.raises(ValueError, match="n_seeds"):
        run_fleet(AlphaRR.fleet(fleet), fleet, scenario=sc, antithetic=True)


def test_antithetic_keeps_non_flip_streams_independent(stacked):
    """Streams without a flip param (GE arrivals, ARMA rents) keep the
    plain per-replica fold under antithetic replication — their replicas
    match the non-antithetic ones bitwise."""
    *_, sc, fleet = stacked
    plain = S.replicate_seeds(sc, 4)
    anti = S.replicate_seeds(sc, 4, antithetic=True)
    for pl, al in zip(jax.tree_util.tree_leaves(plain.params),
                      jax.tree_util.tree_leaves(anti.params)):
        assert np.array_equal(np.asarray(pl), np.asarray(al))


def test_antithetic_ci_width_shrinks():
    """The mc_stats comparison: same S, antithetic pairs summarised by
    pair-means vs plain independent seeds — CI half-widths shrink on this
    monotone (rent-dominated static-policy) workload.  Deterministic for
    fixed keys, so the inequality is a stable assertion, not a flaky
    sample."""
    B, NS_A = 4, 8
    costs_list = [HostingCosts.three_level(5.0 + i, 0.25, 0.4)
                  for i in range(B)]
    grid = HostingGrid.from_costs(costs_list)
    fleet = FleetBatch.for_scenario(grid, 256)
    sc = flip_scenario(B)
    fns = StaticPolicy.fleet(fleet, fleet.grid.top_index())
    plain = run_fleet(fns, fleet, scenario=sc, n_seeds=NS_A)
    anti = run_fleet(fns, fleet, scenario=sc, n_seeds=NS_A, antithetic=True)
    sp = mc_summary(plain)
    sa = mc_summary(anti, antithetic=True)
    # unchanged estimator target: seed-means agree between the designs
    assert np.allclose(sp["total_mean"], sa["total_mean"], rtol=0.05)
    # and the antithetic pair-mean CI is strictly tighter on every instance
    assert np.all(sa["total_ci95"] < sp["total_ci95"])
    # the naive S-sample formula must refuse odd pairings
    with pytest.raises(ValueError, match="even"):
        mc_summary(run_fleet(fns, fleet, scenario=sc, n_seeds=3),
                   antithetic=True)


def test_antithetic_seed_fold_law_engine(stacked):
    """antithetic=True still satisfies the fold law: fused [B*S] rows ==
    standalone runs of the antithetic-replicated scenario, sliced per
    replica (a replica of an antithetic pair is itself a legal standalone
    scenario row)."""
    costs_list, grid, ges, c_means, sc, fleet = stacked
    NS_A = 4
    fns = AlphaRR.fleet(fleet)
    fused = run_fleet(fns, fleet, scenario=sc, n_seeds=NS_A, antithetic=True)
    rep = S.replicate_seeds(sc, NS_A, antithetic=True)
    grid_rep = HostingGrid(
        M=jnp.repeat(grid.M, NS_A, 0), levels=jnp.repeat(grid.levels, NS_A, 0),
        g=jnp.repeat(grid.g, NS_A, 0), mask=jnp.repeat(grid.mask, NS_A, 0))
    fleet_rep = FleetBatch.for_scenario(grid_rep,
                                        np.repeat(np.asarray(fleet.T), NS_A))
    ref = run_fleet(AlphaRR.fleet(fleet_rep), fleet_rep, scenario=rep)
    assert np.array_equal(fused.total, ref.total)
    assert np.array_equal(fused.r_hist, ref.r_hist)


# ----------------------------------------------------------------------
# (e) forced multi-device mesh (subprocess: this process is pinned to one
# device by conftest).  B * S = 9 is not a multiple of 4, exercising the
# dummy-instance padding of replicated scenario params.
# ----------------------------------------------------------------------

_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    assert jax.device_count() == 4, jax.devices()
    from repro.core import scenarios as S
    from repro.core.costs import HostingCosts, HostingGrid
    from repro.core.fleet import FleetBatch, offline_opt_fleet, run_fleet
    from repro.core.policies import AlphaRR
    from repro.sharding.specs import fleet_mesh

    costs_list = [HostingCosts.three_level(4.0 + i, 0.3, 0.4) for i in range(2)]
    costs_list.append(HostingCosts.two_level(4.0))
    grid = HostingGrid.from_costs(costs_list)
    B, T, NS = grid.B, 40, 3
    sc = S.combine(
        S.ge_arrivals(S.split_keys(jax.random.PRNGKey(0), B), 0.3, 0.2,
                      2.0, 0.2, B),
        S.spot_rents(jax.random.PRNGKey(1), 0.5, B))
    fleet = FleetBatch.for_scenario(grid, T)
    fns = AlphaRR.fleet(fleet)
    one = fleet_mesh(jax.devices()[:1])
    refs = [run_fleet(fns, fleet, scenario=S.with_seed(sc, s), mesh=one)
            for s in range(NS)]
    want = np.stack([r.total for r in refs], axis=1).reshape(-1)
    want_hist = np.stack([r.r_hist for r in refs], axis=1).reshape(-1, T)
    for mesh in (one, fleet_mesh()):
        for kw in ({}, {"chunk_size": 20}, {"chunk_size": 20, "stream": True}):
            fr = run_fleet(fns, fleet, scenario=sc, mesh=mesh, n_seeds=NS, **kw)
            assert np.array_equal(fr.total, want), (mesh, kw)
            assert np.array_equal(fr.r_hist, want_hist), (mesh, kw)
    dp = [offline_opt_fleet(fleet, scenario=S.with_seed(sc, s), mesh=one)
          for s in range(NS)]
    fo = offline_opt_fleet(fleet, scenario=sc, mesh=fleet_mesh(),
                           n_seeds=NS, chunk_size=20)
    assert np.array_equal(fo.cost,
                          np.stack([d.cost for d in dp], axis=1).reshape(-1))
    print("MULTI-DEVICE-MC-OK")
""")


def test_mc_multi_device_bitwise():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MULTI-DEVICE-MC-OK" in out.stdout
