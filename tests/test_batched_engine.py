"""Batched engine correctness: ``run_policy_batch`` on a stacked
``HostingGrid`` must match per-instance ``run_policy`` **bit-for-bit** for
every policy family (including mixed-K padding), and the scanned backtrack
in ``offline_opt`` must reproduce ``brute_force_opt`` on small horizons."""
import numpy as np
import pytest

from repro.core.arrivals import GilbertElliot
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.policies import (ABCPolicy, AlphaRR, MDPPolicy, RetroRenting,
                                 StaticPolicy, brute_force_opt, offline_opt,
                                 offline_opt_batch)
from repro.core.simulator import (evaluate_schedule, evaluate_schedule_batch,
                                  model2_service_matrix, run_policy,
                                  run_policy_batch)

T = 60


def mixed_costs(seed=0, B=9):
    """Instances with K in {2, 3, 5} interleaved, exercising the padding."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(B):
        M = float(rng.choice([2.0, 4.0, 10.0]))
        kind = i % 3
        if kind == 0:
            out.append(HostingCosts.two_level(M))
        elif kind == 1:
            alpha = 0.25 + 0.125 * int(rng.integers(0, 3))
            g_alpha = 0.125 * int(rng.integers(1, 6))
            out.append(HostingCosts.three_level(M, alpha, g_alpha))
        else:
            out.append(HostingCosts(M=M, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                                    g=(1.0, 0.4, 0.3, 0.15, 0.0)))
    return out


@pytest.fixture(scope="module")
def stacked():
    costs_list = mixed_costs()
    grid = HostingGrid.from_costs(costs_list)
    rng = np.random.default_rng(7)
    x = rng.integers(0, 3, (grid.B, T))
    c = rng.integers(1, 16, (grid.B, T)) / 8.0
    return costs_list, grid, x, c


def assert_instance_equal(batch, i, single, K_i):
    assert np.array_equal(batch.r_hist[i], single.r_hist)
    for field in ("total", "fetch", "rent", "service"):
        assert getattr(batch, field)[i] == getattr(single, field), field
    assert np.array_equal(batch.level_slots[i][:K_i], single.level_slots)
    assert batch.level_slots[i][K_i:].sum() == 0   # padding never selected


@pytest.mark.parametrize("include_final_fetch", [True, False])
def test_alpha_rr_batch_matches_per_instance(stacked, include_final_fetch):
    costs_list, grid, x, c = stacked
    batch = run_policy_batch(AlphaRR.batch(grid), grid, x, c,
                             include_final_fetch=include_final_fetch)
    for i, cc in enumerate(costs_list):
        single = run_policy(AlphaRR(cc), cc, x[i], c[i],
                            include_final_fetch=include_final_fetch)
        assert_instance_equal(batch, i, single, cc.K)


def test_retro_renting_batch_matches_per_instance(stacked):
    costs_list, grid, x, c = stacked
    g2 = grid.restrict_to_endpoints()
    batch = run_policy_batch(RetroRenting.batch(grid), g2, x, c)
    for i, cc in enumerate(costs_list):
        rr = RetroRenting(cc)
        single = run_policy(rr, rr.costs, x[i], c[i])
        assert_instance_equal(batch, i, single, 2)


def test_static_batch_matches_per_instance(stacked):
    costs_list, grid, x, c = stacked
    # always-full on a mixed-K grid: per-instance top index
    batch = run_policy_batch(StaticPolicy.batch(grid, grid.top_index()),
                             grid, x, c)
    for i, cc in enumerate(costs_list):
        single = run_policy(StaticPolicy(cc, cc.K - 1), cc, x[i], c[i])
        assert_instance_equal(batch, i, single, cc.K)


def test_mdp_abc_batch_match_per_instance(stacked):
    costs_list, grid, x, c = stacked
    rng = np.random.default_rng(3)
    ges = [GilbertElliot(p_hl=0.3, p_lh=0.2 + 0.1 * (i % 3),
                         rate_h=2.0 + i % 2, rate_l=0.2)
           for i in range(grid.B)]
    c_means = [float(np.mean(c[i])) for i in range(grid.B)]
    side = rng.integers(0, 2, (grid.B, T))
    for cls, step_name in ((MDPPolicy, "MDP"), (ABCPolicy, "ABC")):
        batch = run_policy_batch(cls.batch(grid, costs_list, ges, c_means),
                                 grid, x, c, side=side)
        for i, cc in enumerate(costs_list):
            single = run_policy(cls(cc, ges[i], c_means[i]), cc, x[i], c[i],
                                side=side[i])
            assert_instance_equal(batch, i, single, cc.K)


def test_alpha_rr_batch_model2_service(stacked):
    """Stacked realized Model-2 service costs (padded columns are inert)."""
    import jax
    costs_list, grid, x, c = stacked
    R = int(x.max())
    svc_stack = np.zeros((grid.B, T, grid.K), np.float64)
    for i, cc in enumerate(costs_list):
        svc_i = np.asarray(model2_service_matrix(
            jax.random.PRNGKey(i), cc, x[i], max_per_slot=R))
        svc_stack[i, :, :cc.K] = svc_i
    batch = run_policy_batch(AlphaRR.batch(grid), grid, x, c, svc=svc_stack)
    for i, cc in enumerate(costs_list):
        single = run_policy(AlphaRR(cc), cc, x[i], c[i],
                            svc=svc_stack[i, :, :cc.K])
        assert_instance_equal(batch, i, single, cc.K)


def test_offline_opt_batch_matches_per_instance(stacked):
    costs_list, grid, x, c = stacked
    batch = offline_opt_batch(grid, x, c)
    for i, cc in enumerate(costs_list):
        single = offline_opt(cc, x[i], c[i])
        assert np.array_equal(batch.r_hist[i], single.r_hist)
        assert batch.cost[i] == pytest.approx(single.cost, abs=1e-9)
        assert batch.sim.total[i] == single.sim.total
        assert np.all(batch.r_hist[i] < cc.K)       # padding priced out


def test_evaluate_schedule_batch_matches_per_instance(stacked):
    costs_list, grid, x, c = stacked
    rng = np.random.default_rng(11)
    r = np.stack([rng.integers(0, cc.K, T) for cc in costs_list])
    batch = evaluate_schedule_batch(grid, r, x, c)
    for i, cc in enumerate(costs_list):
        single = evaluate_schedule(cc, r[i], x[i], c[i])
        assert batch.total[i] == single.total
        assert np.array_equal(batch.level_slots[i][:cc.K], single.level_slots)


def test_scanned_backtrack_matches_brute_force():
    """The reverse-scan backtrack reproduces exhaustive search on T<=8,
    K<=3 (costs exactly; schedules up to cost ties)."""
    rng = np.random.default_rng(5)
    for trial in range(12):
        K3 = bool(trial % 2)
        M = float(rng.choice([1.5, 2.0, 4.0]))
        cc = (HostingCosts.three_level(M, 0.5, 0.25) if K3
              else HostingCosts.two_level(M))
        T_small = int(rng.integers(5, 9))
        x = rng.integers(0, 2, T_small)
        c = rng.integers(1, 16, T_small) / 8.0
        dp = offline_opt(cc, x, c)
        bf = brute_force_opt(cc, x, c)
        assert dp.cost == pytest.approx(bf.cost, abs=1e-5)
        # the backtracked schedule must achieve the DP's claimed cost
        assert dp.sim.total == pytest.approx(dp.cost, abs=1e-5)


def test_broadcast_shared_instance_axis(stacked):
    """[T]-shaped x/c broadcast across the batch."""
    costs_list, grid, x, c = stacked
    batch = run_policy_batch(AlphaRR.batch(grid), grid, x[0], c[0])
    for i, cc in enumerate(costs_list):
        single = run_policy(AlphaRR(cc), cc, x[0], c[0])
        assert_instance_equal(batch, i, single, cc.K)
