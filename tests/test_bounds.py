"""Bound formulas (Theorems 2/4/5) — unit tests."""
import math

import numpy as np
import pytest

from repro.core.costs import HostingCosts
from repro.core import bounds


def test_thm2_optimal_regime():
    c = HostingCosts.three_level(M=5, alpha=0.5, g_alpha=0.6, c_min=1.0, c_max=2.0)
    # alpha*c_min + g = 1.1 >= 1 and c_min >= 1 -> optimal
    assert bounds.thm2_ratio_upper(c) == 1.0


def test_thm2_bound_formula():
    c = HostingCosts.three_level(M=10, alpha=0.4, g_alpha=0.3, c_min=0.2, c_max=1.0)
    want = 4 + 1 / 10 + max(1 / 10, (1 - 0.3) / (10 * 0.4))
    assert bounds.thm2_ratio_upper(c) == pytest.approx(want)


def test_corollary3_under_assumption6():
    for alpha in (0.25, 0.5, 0.75):
        for g in (0.1, 0.4, 0.7):
            M = max(1.0, (1 - g) / alpha) * 1.01
            c = HostingCosts.three_level(M, alpha, g, 0.1, 1.0)
            assert c.assumption6_holds()
            assert bounds.corollary3_six(c) <= 6.0


def test_thm4_cases():
    # (a) c_min < 1, alpha c_min + g < 1
    a = HostingCosts.three_level(10, 0.4, 0.3, c_min=0.5, c_max=1.0)
    assert bounds.thm4_lower(a) > 1.0
    # (b) c_min < 1, alpha c_min + g >= 1
    b = HostingCosts.three_level(10, 0.5, 0.9, c_min=0.5, c_max=1.0)
    assert bounds.thm4_lower(b) > 1.0
    # (c) c_min >= 1, alpha c_min + g < 1
    c = HostingCosts.three_level(10, 0.3, 0.2, c_min=1.2, c_max=2.0)
    assert bounds.thm4_lower(c) > 1.0
    # trivial regime: both conditions fail -> bound 1 (alpha-RR optimal)
    d = HostingCosts.three_level(10, 0.5, 0.9, c_min=1.5, c_max=2.0)
    assert bounds.thm4_lower(d) == 1.0
    # no-partial bound <= ... also > 1 when c_min < 1
    assert bounds.thm4_lower_no_partial(a) > 1.0


def test_thm5_fqh_positive_and_decay():
    c = lambda M: HostingCosts.three_level(M, 0.3, 0.5, c_min=0.8, c_max=1.2)
    # case regions
    f1 = bounds.f_fn(2.0, 50, 0.9, 1.0, 0.3, 0.5, 0.8, 1.2)
    q1 = bounds.q_fn(2.0, 50, 1.5, 1.0, 0.3, 0.5, 0.8, 1.2)
    h1 = bounds.h_fn(2.0, 50, 0.1, 1.0, 0.3, 0.5, 0.8, 1.2)
    assert f1 > 0 and q1 > 0 and h1 > 0
    for fn, p in [(bounds.f_fn, 0.9), (bounds.q_fn, 1.5), (bounds.h_fn, 0.1)]:
        lo = fn(2.0, 400, p, 1.0, 0.3, 0.5, 0.8, 1.2)
        hi = fn(2.0, 40, p, 1.0, 0.3, 0.5, 0.8, 1.2)
        assert lo < hi  # Remark 4: decays with M
    with pytest.raises(ValueError):
        bounds.f_fn(2.0, 50, 0.1, 1.0, 0.3, 0.5, 0.8, 1.2)  # outside region


def test_thm5_sigma_cases_and_lemma14():
    costs = HostingCosts.three_level(100.0, 0.3, 0.5, c_min=0.8, c_max=1.2)
    s1 = bounds.thm5_sigma_upper(costs, p=0.9, c=1.0)
    s2 = bounds.thm5_sigma_upper(costs, p=1.8, c=1.0)
    s3 = bounds.thm5_sigma_upper(costs, p=0.1, c=1.0)
    assert all(s >= 1.0 for s in (s1, s2, s3))
    assert bounds.lemma14_opt_on_per_slot(costs, 0.5, 1.0) == pytest.approx(
        min(1.0, 0.3 * 1.0 + 0.5 * 0.5, 0.5))
