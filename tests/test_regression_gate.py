"""Unit tests for the CI perf-regression gate
(``benchmarks/check_regression.py``): drop detection on ratio and rate
keys, machine-speed normalization of rates, additive-key tolerance, and
the disappeared-entry failure.  Pure python — no jax involved.
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_regression import compare, main


def entry(rate, ratio, **extra):
    row = {
        "slots_instances_per_sec": rate,
        "speedup_vs_loop": ratio,
        "B": 64,
        "T": 4096,
    }
    row.update(extra)
    return row


def baseline_tp():
    return {
        "a": entry(1000.0, 12.0),
        "b": entry(2000.0, 1.5),
        "c": entry(500.0, 4.0),
    }


def test_identical_reports_pass():
    base = baseline_tp()
    failures, _ = compare(json.loads(json.dumps(base)), base)
    assert failures == []


def test_ratio_drop_fails_and_metadata_is_ignored():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    new["a"]["speedup_vs_loop"] = 12.0 * 0.7  # 30% drop
    new["a"]["B"] = 1  # metadata: never guarded
    failures, _ = compare(new, base)
    assert len(failures) == 1
    assert "a.speedup_vs_loop" in failures[0]


def test_small_ratio_drop_passes():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    new["a"]["speedup_vs_loop"] = 12.0 * 0.8  # 20% < threshold
    failures, _ = compare(new, base)
    assert failures == []


def test_uniform_rate_shift_is_calibrated_away():
    """Half-speed runner: every rate drops 50% together — the median
    machine-speed factor absorbs it and the gate stays green."""
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    for row in new.values():
        row["slots_instances_per_sec"] *= 0.5
    failures, _ = compare(new, base)
    assert failures == []


def test_single_rate_regression_still_fails():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    new["b"]["slots_instances_per_sec"] *= 0.5  # alone among its peers
    failures, _ = compare(new, base)
    assert any("b.slots_instances_per_sec" in f for f in failures)


def test_additive_keys_and_entries_pass():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    new["zz_new_row"] = entry(123.0, 9.9)
    new["a"]["brand_new_ratio"] = 0.001
    failures, notes = compare(new, base)
    assert failures == []
    assert any("zz_new_row" in n and "additive" in n for n in notes)


def test_disappeared_entry_fails():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    del new["c"]
    failures, _ = compare(new, base)
    assert any("c:" in f and "disappeared" in f for f in failures)


def test_none_values_skip_with_note():
    base = baseline_tp()
    base["a"]["fused_vs_host_e2e"] = 1.7
    new = json.loads(json.dumps(base))
    new["a"]["fused_vs_host_e2e"] = None  # recorded measurement failure
    failures, notes = compare(new, base)
    assert failures == []
    assert any("fused_vs_host_e2e" in n and "skipped" in n for n in notes)


def test_machine_dependent_scaling_key_is_not_guarded():
    """scaling_vs_1dev tracks the runner's cores, not the code — a slow
    runner must not fail the gate on it (kernel_bench.check owns it)."""
    base = baseline_tp()
    base["a"]["scaling_vs_1dev"] = 1.99
    new = json.loads(json.dumps(base))
    new["a"]["scaling_vs_1dev"] = 1.05  # 2-vCPU runner
    failures, _ = compare(new, base)
    assert failures == []


def test_lower_is_better_ratio_guards_rises_not_drops():
    base = baseline_tp()
    base["a"]["antithetic_ci_ratio"] = 0.13
    new = json.loads(json.dumps(base))
    new["a"]["antithetic_ci_ratio"] = 0.05  # improvement: passes
    failures, _ = compare(new, base)
    assert failures == []
    new["a"]["antithetic_ci_ratio"] = 0.50  # variance reduction lost
    failures, _ = compare(new, base)
    assert any("a.antithetic_ci_ratio" in f and "rose" in f for f in failures)


def test_guarded_key_missing_from_surviving_entry_fails():
    """A guarded key silently dropped from a still-present entry is a
    schema regression, distinct from an explicit None measurement."""
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    del new["a"]["speedup_vs_loop"]
    failures, _ = compare(new, base)
    assert any(
        "a.speedup_vs_loop" in f and "missing" in f for f in failures
    )


def test_threshold_is_respected():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    new["a"]["speedup_vs_loop"] = 12.0 * 0.7
    failures, _ = compare(new, base, threshold=0.5)
    assert failures == []


def test_main_end_to_end(tmp_path):
    report = {"schema_version": 1, "throughput": baseline_tp()}
    good = tmp_path / "bench.json"
    basef = tmp_path / "BENCH_baseline.json"
    good.write_text(json.dumps(report))
    basef.write_text(json.dumps(report))
    assert main([str(good), str(basef)]) == 0
    bad = dict(report)
    bad["throughput"] = json.loads(json.dumps(baseline_tp()))
    bad["throughput"]["a"]["speedup_vs_loop"] = 1.0
    badf = tmp_path / "bad.json"
    badf.write_text(json.dumps(bad))
    assert main([str(badf), str(basef)]) == 1
    wrong = tmp_path / "wrong.json"
    wrong.write_text(json.dumps({"schema_version": 2}))
    assert main([str(wrong), str(basef)]) == 1
