"""Unit tests for the CI perf-regression gate
(``benchmarks/check_regression.py``): drop detection on ratio and rate
keys, machine-speed normalization of rates, additive-key tolerance, and
the disappeared-entry failure.  Also pins ``benchmarks.kernel_bench
.check``'s cores-aware gating through its ``cores`` injection point
(synthetic rows — no benchmark runs).
"""

import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_regression import compare, main
from benchmarks.kernel_bench import check as kernel_check


def entry(rate, ratio, **extra):
    row = {
        "slots_instances_per_sec": rate,
        "speedup_vs_loop": ratio,
        "B": 64,
        "T": 4096,
    }
    row.update(extra)
    return row


def baseline_tp():
    return {
        "a": entry(1000.0, 12.0),
        "b": entry(2000.0, 1.5),
        "c": entry(500.0, 4.0),
    }


def test_identical_reports_pass():
    base = baseline_tp()
    failures, _ = compare(json.loads(json.dumps(base)), base)
    assert failures == []


def test_ratio_drop_fails_and_metadata_is_ignored():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    new["a"]["speedup_vs_loop"] = 12.0 * 0.7  # 30% drop
    new["a"]["B"] = 1  # metadata: never guarded
    failures, _ = compare(new, base)
    assert len(failures) == 1
    assert "a.speedup_vs_loop" in failures[0]


def test_small_ratio_drop_passes():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    new["a"]["speedup_vs_loop"] = 12.0 * 0.8  # 20% < threshold
    failures, _ = compare(new, base)
    assert failures == []


def test_uniform_rate_shift_is_calibrated_away():
    """Half-speed runner: every rate drops 50% together — the median
    machine-speed factor absorbs it and the gate stays green."""
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    for row in new.values():
        row["slots_instances_per_sec"] *= 0.5
    failures, _ = compare(new, base)
    assert failures == []


def test_single_rate_regression_still_fails():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    new["b"]["slots_instances_per_sec"] *= 0.5  # alone among its peers
    failures, _ = compare(new, base)
    assert any("b.slots_instances_per_sec" in f for f in failures)


def test_additive_keys_and_entries_pass():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    new["zz_new_row"] = entry(123.0, 9.9)
    new["a"]["brand_new_ratio"] = 0.001
    failures, notes = compare(new, base)
    assert failures == []
    assert any("zz_new_row" in n and "additive" in n for n in notes)


def test_disappeared_entry_fails():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    del new["c"]
    failures, _ = compare(new, base)
    assert any("c:" in f and "disappeared" in f for f in failures)


def test_none_values_skip_with_note():
    base = baseline_tp()
    base["a"]["fused_vs_host_e2e"] = 1.7
    new = json.loads(json.dumps(base))
    new["a"]["fused_vs_host_e2e"] = None  # recorded measurement failure
    failures, notes = compare(new, base)
    assert failures == []
    assert any("fused_vs_host_e2e" in n and "skipped" in n for n in notes)


def test_machine_dependent_scaling_key_is_not_guarded():
    """scaling_vs_1dev tracks the runner's cores, not the code — a slow
    runner must not fail the gate on it (kernel_bench.check owns it)."""
    base = baseline_tp()
    base["a"]["scaling_vs_1dev"] = 1.99
    new = json.loads(json.dumps(base))
    new["a"]["scaling_vs_1dev"] = 1.05  # 2-vCPU runner
    failures, _ = compare(new, base)
    assert failures == []


def test_lower_is_better_ratio_guards_rises_not_drops():
    base = baseline_tp()
    base["a"]["antithetic_ci_ratio"] = 0.13
    new = json.loads(json.dumps(base))
    new["a"]["antithetic_ci_ratio"] = 0.05  # improvement: passes
    failures, _ = compare(new, base)
    assert failures == []
    new["a"]["antithetic_ci_ratio"] = 0.50  # variance reduction lost
    failures, _ = compare(new, base)
    assert any("a.antithetic_ci_ratio" in f and "rose" in f for f in failures)


def test_guarded_key_missing_from_surviving_entry_fails():
    """A guarded key silently dropped from a still-present entry is a
    schema regression, distinct from an explicit None measurement."""
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    del new["a"]["speedup_vs_loop"]
    failures, _ = compare(new, base)
    assert any(
        "a.speedup_vs_loop" in f and "missing" in f for f in failures
    )


def test_threshold_is_respected():
    base = baseline_tp()
    new = json.loads(json.dumps(base))
    new["a"]["speedup_vs_loop"] = 12.0 * 0.7
    failures, _ = compare(new, base, threshold=0.5)
    assert failures == []


def test_main_end_to_end(tmp_path):
    report = {"schema_version": 1, "throughput": baseline_tp()}
    good = tmp_path / "bench.json"
    basef = tmp_path / "BENCH_baseline.json"
    good.write_text(json.dumps(report))
    basef.write_text(json.dumps(report))
    assert main([str(good), str(basef)]) == 0
    bad = dict(report)
    bad["throughput"] = json.loads(json.dumps(baseline_tp()))
    bad["throughput"]["a"]["speedup_vs_loop"] = 1.0
    badf = tmp_path / "bad.json"
    badf.write_text(json.dumps(bad))
    assert main([str(badf), str(basef)]) == 1
    wrong = tmp_path / "wrong.json"
    wrong.write_text(json.dumps({"schema_version": 2}))
    assert main([str(wrong), str(basef)]) == 1


# ----------------------------------------------------------------------
# kernel_bench.check: cores-aware gating, pinned through the `cores`
# injection point (so the logic is tested, not the CI machine's cores).
# ----------------------------------------------------------------------


def healthy_rows():
    """A minimal synthetic row set that clears every acceptance bar in
    ``kernel_bench.check`` at any core count."""
    return [
        {"name": "hosting_batch_throughput", "speedup_vs_loop": 20.0},
        {
            "name": "fleet_throughput",
            "fleet_vs_batched_1dev": 1.0,
            "scaling_vs_1dev": 2.0,
            "scale_devices": 4,
        },
        {
            "name": "mc_driver_throughput",
            "fused_vs_per_seed": 1.2,
            "antithetic_ci_ratio": 0.1,
        },
        {
            "name": "offline_dp_streaming",
            "identical_bits": True,
            "peak_mem_ratio": 4.0,
            "ckpt_vs_materialized": 1.0,
        },
        {
            "name": "scenario_fused_throughput",
            "fused_slots_instances_per_sec": 1.0,
            "fused_vs_host_e2e": 1.0,
        },
        {
            "name": "live_fleet_step",
            "zero_retraces": True,
            "per_width": [
                {"slots_admitted_per_sec": 1.0, "p99_step_latency_us": 1.0},
            ],
        },
        {
            "name": "multihost_scaling",
            "identical_bits": True,
            "multihost_scaling_vs_1proc": 1.8,
        },
        {
            "name": "stream_overlap",
            "identical_bits": True,
            "async_vs_sync": 1.0,
        },
        {
            "name": "policy_fanout",
            "identical_bits": True,
            "fanout_vs_separate": 1.5,
        },
        {
            "name": "multi_service",
            "identical_bits": True,
            "slots_instances_per_sec": 1.0,
            "joint_dp_seconds": 1.0,
        },
        {
            "name": "dp_minplus_kernel",
            "identical_bits": True,
            "xla_dp_slots_instances_per_sec": 1.0,
            "pallas_dp_slots_instances_per_sec": 1.0,
            "backend": "pallas-interpret",
        },
        {
            "name": "counter_prng_kernel",
            "identical_bits": True,
            "xla_prng_draws_per_sec": 1.0,
            "pallas_prng_draws_per_sec": 1.0,
            "backend": "pallas-interpret",
        },
    ]


def _with(name, key, value):
    rows = copy.deepcopy(healthy_rows())
    next(r for r in rows if r["name"] == name)[key] = value
    return rows


def test_kernel_check_healthy_rows_pass_any_cores():
    assert kernel_check(healthy_rows(), cores=1) is True
    assert kernel_check(healthy_rows(), cores=8) is True


@pytest.mark.parametrize(
    "name,key,bad",
    [
        ("mc_driver_throughput", "fused_vs_per_seed", 0.2),
        ("stream_overlap", "async_vs_sync", 0.2),
        ("fleet_throughput", "scaling_vs_1dev", 1.0),
        ("multihost_scaling", "multihost_scaling_vs_1proc", 0.5),
    ],
)
def test_cores_aware_bars_gate_only_with_spare_cores(name, key, bad):
    """The throughput bars that need a spare core are scheduling noise on
    a 1-core container: they must pass at cores=1 and fail at cores=2."""
    rows = _with(name, key, bad)
    assert kernel_check(rows, cores=1) is True
    assert kernel_check(rows, cores=2) is False


@pytest.mark.parametrize(
    "name,key,bad",
    [
        ("stream_overlap", "identical_bits", False),
        ("multihost_scaling", "identical_bits", False),
        ("policy_fanout", "identical_bits", False),
        ("policy_fanout", "fanout_vs_separate", 0.9),
        ("multi_service", "identical_bits", False),
        ("offline_dp_streaming", "identical_bits", False),
    ],
)
def test_bit_flags_and_fanout_gate_unconditionally(name, key, bad):
    """Bit-equality flags — and the engine-vs-engine fan-out ratio, which
    needs no spare core — gate even on a 1-core container."""
    assert kernel_check(_with(name, key, bad), cores=1) is False


def test_multihost_skip_marker_row_passes():
    """The fast-mode skip-marker entry (explicit nulls, FULL-mode-only
    cluster legs) must not trip the gate at any core count."""
    rows = _with("multihost_scaling", "multihost_scaling_vs_1proc", None)
    for r in rows:
        if r["name"] == "multihost_scaling":
            r["single_process_slots_instances_per_sec"] = None
            r["multi_process_slots_instances_per_sec"] = None
            del r["identical_bits"]
    assert kernel_check(rows, cores=1) is True
    assert kernel_check(rows, cores=8) is True
