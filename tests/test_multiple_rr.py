"""multiple-RR (K > 3 hosting levels): scan policy == literal Algorithm 1
generalisation, plus level-grid sanity properties."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.costs import HostingCosts
from repro.core.policies import AlphaRR, alpha_rr_literal
from repro.core.simulator import run_policy

GRID = 1.0 / 8.0


@st.composite
def multi_instances(draw, max_T=30):
    k_mid = draw(st.integers(2, 3))
    # strictly increasing dyadic levels in (0,1), non-increasing dyadic g
    lv_all = [0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875]
    mids = sorted(draw(st.permutations(lv_all)).copy()[:k_mid])
    g_all = sorted([draw(st.sampled_from([0.125, 0.25, 0.375, 0.5, 0.625, 0.75]))
                    for _ in range(k_mid)], reverse=True)
    M = draw(st.sampled_from([2.0, 4.0, 8.0]))
    T = draw(st.integers(4, max_T))
    x = draw(st.lists(st.integers(0, 1), min_size=T, max_size=T))
    c = draw(st.lists(st.integers(1, 16).map(lambda k: k * GRID),
                      min_size=T, max_size=T))
    costs = HostingCosts(M=M, levels=tuple([0.0] + mids + [1.0]),
                         g=tuple([1.0] + g_all + [0.0]),
                         c_min=min(c), c_max=max(c))
    return costs, np.asarray(x, np.int64), np.asarray(c, np.float64)


@settings(max_examples=60, deadline=None)
@given(multi_instances())
def test_multiple_rr_scan_matches_literal(inst):
    costs, x, c = inst
    r_scan = run_policy(AlphaRR(costs), costs, x, c).r_hist
    r_lit = alpha_rr_literal(costs, x, c)
    assert np.array_equal(r_scan, r_lit), (costs.levels, r_scan.tolist(),
                                           r_lit.tolist())


@settings(max_examples=30, deadline=None)
@given(multi_instances())
def test_more_levels_never_hurt_much(inst):
    """Fig 7's qualitative claim at property level: the K-level policy is not
    dramatically worse than its own 3-level restriction (same alpha grid
    point), since it could always emulate it modulo hysteresis noise."""
    costs, x, c = inst
    multi = run_policy(AlphaRR(costs), costs, x, c).total
    mid = len(costs.levels) // 2
    three = HostingCosts.three_level(costs.M, costs.levels[mid], costs.g[mid],
                                     costs.c_min, costs.c_max)
    tr = run_policy(AlphaRR(three), three, x, c).total
    assert multi <= tr * 1.5 + 3 * costs.M, (multi, tr)
