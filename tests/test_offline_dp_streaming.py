"""Checkpointed offline-DP correctness: the two-pass backtracking of
``offline_opt_fleet(checkpointed=True)`` per the PR-5 acceptance bar:

* **Bit-identity** — checkpointed == materialized backpointers for every
  driver (device scan / host-streamed), obs-backed and scenario-fused,
  chunked at sizes that do and do not divide the horizon, under mixed
  horizons, mixed K, ``n_seeds`` replication and (on a forced-multi-device
  platform — the CI leg sets ``REPRO_FORCE_DEVICES=4``) a sharded mesh;
  a hypothesis property test walks random config combinations.
* **Memory** — ``offline_dp_memory_stats`` (the XLA-reported footprint of
  the exact compiled core) confirms no [B, T, K]-sized buffer exists on
  the checkpointed path, while the materialized path provably holds one.
* **Cost-only mode** — ``collect_schedule=False`` skips backtrack +
  evaluation and returns the identical costs with no O(T) output.
"""
import numpy as np
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import scenarios as S
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import (FleetBatch, offline_dp_memory_stats,
                              offline_opt_fleet)
from repro.sharding.specs import fleet_mesh

T = 40
KEY = jax.random.PRNGKey(13)
CHUNKS = [16, 20]      # 20 does not divide 40+pad: exercises the padded tail


COST_POOL = [HostingCosts.two_level(4.0),
             HostingCosts.three_level(6.0, 0.25, 0.5),
             HostingCosts.three_level(3.0, 0.5, 0.25),
             HostingCosts(M=5.0, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                          g=(1.0, 0.4, 0.3, 0.15, 0.0)),
             HostingCosts.three_level(8.0, 0.375, 0.375)]


def make_scenario(B, stateful=True):
    """GE arrivals (carried chain state — the hard case for backtrack
    regeneration) + ARMA rents (carried histories), or stateless streams."""
    kx = S.split_keys(KEY, B)
    if stateful:
        return S.combine(S.ge_arrivals(kx, 0.3, 0.2, 2.0, 0.2, B),
                         S.spot_rents(jax.random.PRNGKey(1), 0.5, B))
    return S.combine(S.bernoulli_arrivals(kx, 0.4, B),
                     S.uniform_rents(jax.random.PRNGKey(1), 0.5, 0.3, B))


def assert_same_offline(a, b):
    assert np.array_equal(a.cost, b.cost)
    assert np.array_equal(a.r_hist, b.r_hist)
    assert np.array_equal(a.sim.total, b.sim.total)
    assert np.array_equal(a.sim.level_slots, b.sim.level_slots)


@pytest.fixture(scope="module")
def stacked():
    grid = HostingGrid.from_costs(COST_POOL)
    sc = make_scenario(grid.B)
    fleet = FleetBatch.for_scenario(grid, [T, 23, 11, T, 7])
    return grid, sc, fleet


# ----------------------------------------------------------------------
# (a) scenario-fused: checkpointed == materialized, every driver.
# ----------------------------------------------------------------------

def test_ckpt_matches_materialized_scenario(stacked):
    grid, sc, fleet = stacked
    base = offline_opt_fleet(fleet, scenario=sc)
    for kw in ({"checkpointed": True},
               {"checkpointed": True, "chunk_size": CHUNKS[0]},
               {"checkpointed": True, "chunk_size": CHUNKS[1]},
               {"checkpointed": True, "chunk_size": CHUNKS[0],
                "stream": True},
               {"checkpointed": True, "chunk_size": CHUNKS[1],
                "stream": True}):
        ck = offline_opt_fleet(fleet, scenario=sc, **kw)
        assert_same_offline(ck, base)


def test_ckpt_matches_materialized_obs(stacked):
    grid, sc, fleet = stacked
    x, c, svc, side = S.materialize(sc, T)
    fl = FleetBatch.from_dense(grid, x, c, T=np.asarray(fleet.T))
    base = offline_opt_fleet(fl)
    assert np.array_equal(base.cost, offline_opt_fleet(fleet,
                                                       scenario=sc).cost)
    for kw in ({"checkpointed": True, "chunk_size": CHUNKS[0]},
               {"checkpointed": True, "chunk_size": CHUNKS[1],
                "stream": True}):
        ck = offline_opt_fleet(fl, **kw)
        assert_same_offline(ck, base)


def test_ckpt_with_model2_service(stacked):
    """Realized [chunk, K] service slabs ride through both passes."""
    grid, _, _ = stacked
    B = grid.B
    sc = S.combine(
        S.poisson_arrivals(S.split_keys(KEY, B), 2.0, B),
        S.uniform_rents(jax.random.PRNGKey(2), 0.5, 0.3, B),
        svc=S.model2_service(jax.random.PRNGKey(3), grid.g, B,
                             max_per_slot=8))
    fleet = FleetBatch.for_scenario(grid, T)
    base = offline_opt_fleet(fleet, scenario=sc)
    ck = offline_opt_fleet(fleet, scenario=sc, checkpointed=True,
                           chunk_size=CHUNKS[0])
    assert_same_offline(ck, base)
    # obs-backed with a materialized svc matrix (the has_svc core variants)
    x, c, svc, _ = S.materialize(sc, T)
    fl = FleetBatch.from_dense(grid, x, c, svc=svc)
    base_m = offline_opt_fleet(fl)
    assert np.array_equal(base_m.cost, base.cost)
    for kw in ({"checkpointed": True, "chunk_size": CHUNKS[0]},
               {"checkpointed": True, "chunk_size": CHUNKS[0],
                "stream": True}):
        assert_same_offline(offline_opt_fleet(fl, **kw), base_m)


def test_ckpt_n_seeds(stacked):
    grid, sc, fleet = stacked
    NS = 3
    refs = [offline_opt_fleet(fleet, scenario=S.with_seed(sc, s))
            for s in range(NS)]
    want = np.stack([r.cost for r in refs], axis=1).reshape(-1)
    for kw in ({"chunk_size": CHUNKS[0]},
               {"chunk_size": CHUNKS[1], "stream": True}):
        fo = offline_opt_fleet(fleet, scenario=sc, n_seeds=NS,
                               checkpointed=True, **kw)
        assert fo.n_seeds == NS
        assert np.array_equal(fo.cost, want)


def test_cost_only_mode(stacked):
    grid, sc, fleet = stacked
    base = offline_opt_fleet(fleet, scenario=sc)
    for kw in ({"chunk_size": CHUNKS[0]},
               {"chunk_size": CHUNKS[0], "stream": True}):
        co = offline_opt_fleet(fleet, scenario=sc, checkpointed=True,
                               collect_schedule=False, **kw)
        assert np.array_equal(co.cost, base.cost)
        assert co.r_hist is None and co.sim is None


def test_driver_argument_validation(stacked):
    grid, sc, fleet = stacked
    with pytest.raises(ValueError, match="checkpointed"):
        offline_opt_fleet(fleet, scenario=sc, stream=True, chunk_size=16)
    with pytest.raises(ValueError, match="chunk_size"):
        offline_opt_fleet(fleet, scenario=sc, checkpointed=True, stream=True)
    with pytest.raises(ValueError, match="checkpointed"):
        offline_opt_fleet(fleet, scenario=sc, collect_schedule=False)


# ----------------------------------------------------------------------
# (b) hypothesis property: random (B, K, T, chunk, mesh, n_seeds) configs.
# ----------------------------------------------------------------------

@st.composite
def dp_configs(draw):
    n = draw(st.integers(1, 4))
    idx = draw(st.permutations(range(len(COST_POOL))))[:n]
    horizon = draw(st.sampled_from([24, 40]))
    Ts = [draw(st.sampled_from([horizon, 23, 11, 7])) for _ in range(n)]
    chunk = draw(st.sampled_from([None, 8, 12, 20]))
    stream = draw(st.sampled_from([False, True])) and chunk is not None
    n_seeds = draw(st.sampled_from([None, 2]))
    all_devs = draw(st.sampled_from([False, True]))
    stateful = draw(st.sampled_from([False, True]))
    return idx, Ts, chunk, stream, n_seeds, all_devs, stateful


# compile-bound: each distinct (B, n_chunks, driver) combination traces a
# fresh core, so examples cost seconds — 12 deterministic draws already
# cover every axis pairwise
@settings(max_examples=12, deadline=None)
@given(dp_configs())
def test_ckpt_bit_identity_property(cfg):
    idx, Ts, chunk, stream, n_seeds, all_devs, stateful = cfg
    grid = HostingGrid.from_costs([COST_POOL[i] for i in idx])
    sc = make_scenario(grid.B, stateful=stateful)
    fleet = FleetBatch.for_scenario(grid, Ts)
    # single device by default; the forced-4-device CI leg makes the
    # all-devices mesh a genuinely sharded one
    mesh = fleet_mesh() if all_devs else fleet_mesh(jax.devices()[:1])
    base = offline_opt_fleet(fleet, scenario=sc, mesh=mesh,
                             n_seeds=n_seeds)
    ck = offline_opt_fleet(fleet, scenario=sc, mesh=mesh, n_seeds=n_seeds,
                           checkpointed=True, chunk_size=chunk,
                           stream=stream)
    assert_same_offline(ck, base)


# ----------------------------------------------------------------------
# (c) memory: the checkpointed core never holds a [B, T, K] buffer.
# ----------------------------------------------------------------------

def test_ckpt_core_has_no_backpointer_table():
    B, horizon, chunk = 4, 4096, 256
    grid = HostingGrid.from_costs([COST_POOL[1]] * B)
    sc = make_scenario(B, stateful=False)
    fleet = FleetBatch.for_scenario(grid, horizon)
    # pin to ONE device: the [B, T, K]-sized bound below is a per-program
    # number, and on a forced-multi-device platform the default mesh
    # shards the instance axis (each device then holds B/n rows)
    mesh = fleet_mesh(jax.devices()[:1])
    m_mat = offline_dp_memory_stats(fleet, scenario=sc, chunk_size=chunk,
                                    mesh=mesh)
    m_ck = offline_dp_memory_stats(fleet, scenario=sc, chunk_size=chunk,
                                   checkpointed=True, mesh=mesh)
    btk = B * horizon * grid.K * 4          # one [B, T, K] int32/f32 table
    # the materialized core holds at least the argmin table...
    assert m_mat["temp_bytes"] >= btk
    # ...the checkpointed one cannot even fit one ([B, chunk, K] recompute
    # buffers + [B, n_chunks, K] frontier checkpoints only)
    assert m_ck["temp_bytes"] < btk
    assert m_ck["temp_bytes"] < m_mat["temp_bytes"]
    # cost-only additionally drops the [B, T] schedule output
    m_co = offline_dp_memory_stats(fleet, scenario=sc, chunk_size=chunk,
                                   checkpointed=True,
                                   collect_schedule=False, mesh=mesh)
    assert m_co["output_bytes"] < m_ck["output_bytes"]


def test_long_horizon_cost_only_smoke():
    """A T >> chunk solve streams through without any O(T) device buffer
    (the T = 10^6 acceptance run lives in kernel_bench's
    ``offline_dp_streaming`` row; this is its fast sibling)."""
    B, horizon = 4, 120_000
    grid = HostingGrid.from_costs([COST_POOL[1]] * B)
    sc = make_scenario(B, stateful=False)
    fleet = FleetBatch.for_scenario(grid, horizon)
    co = offline_opt_fleet(fleet, scenario=sc, checkpointed=True,
                           chunk_size=4096, collect_schedule=False)
    assert co.cost.shape == (B,) and np.all(np.isfinite(co.cost))
    # spot-check against the materialized path on a truncated horizon: the
    # first-chunk frontier evolution is shared, so a full-horizon mismatch
    # would already show up at scale; here we just pin the long run's
    # finiteness and the short run's exactness in one test
    short = FleetBatch.for_scenario(grid, 512)
    a = offline_opt_fleet(short, scenario=sc)
    b = offline_opt_fleet(short, scenario=sc, checkpointed=True,
                          chunk_size=128, stream=True)
    assert_same_offline(b, a)
