"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes in Python on CPU) + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

ATTN_SHAPES = [
    # (B, Sq, Skv, Hq, Hkv, hd)
    (1, 16, 16, 1, 1, 16),
    (2, 64, 64, 4, 4, 32),
    (2, 128, 128, 4, 2, 64),      # GQA
    (1, 80, 80, 8, 1, 64),        # MQA, ragged seq (padding path)
    (1, 256, 256, 2, 2, 128),
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(shape, dtype):
    b, sq, skv, hq, hkv, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, hd), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 32, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 96, 4, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 96, 4, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.integers(1, 96), st.sampled_from([1, 2, 4]),
       st.sampled_from([16, 32, 64]), st.integers(0, 2 ** 31 - 1))
def test_flash_attention_property(b, s, h, hd, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5, atol=3e-5)


SSD_SHAPES = [
    # (b, s, nh, dh, ng, ds, chunk)
    (1, 32, 2, 16, 1, 16, 16),
    (2, 64, 4, 32, 1, 32, 32),
    (1, 100, 4, 32, 2, 16, 32),    # ragged + grouped
    (2, 128, 8, 64, 1, 64, 64),
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(shape, dtype):
    b, s, nh, dh, ng, ds, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, nh, dh), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, ng, ds), dtype)
    C = jax.random.normal(ks[4], (b, s, ng, ds), dtype)
    y, hT = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    y_ref, hT_ref = ref.ssd_scan_ref(x, dt, A, B, C)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref),
                               rtol=1e-2, atol=1e-2)


def test_ssd_scan_with_initial_state():
    b, s, nh, dh, ng, ds = 1, 48, 2, 16, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    x = jax.random.normal(ks[0], (b, s, nh, dh), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, ng, ds), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, ng, ds), jnp.float32)
    h0 = jax.random.normal(ks[5], (b, nh, dh, ds), jnp.float32)
    y, hT = ops.ssd_scan(x, dt, A, B, C, h0=h0, chunk=16)
    y_ref, hT_ref = ref.ssd_scan_ref(x, dt, A, B, C, h0=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref), rtol=1e-3, atol=1e-3)


def test_ssd_scan_state_continuation():
    """Running two halves with state carry == running the whole sequence
    (the decode-from-prefill contract)."""
    b, s, nh, dh, ng, ds = 1, 64, 2, 16, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    x = jax.random.normal(ks[0], (b, s, nh, dh), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, ng, ds), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, ng, ds), jnp.float32)
    y_full, hT_full = ops.ssd_scan(x, dt, A, B, C, chunk=16)
    h = s // 2
    y1, h1 = ops.ssd_scan(x[:, :h], dt[:, :h], A, B[:, :h], C[:, :h], chunk=16)
    y2, h2 = ops.ssd_scan(x[:, h:], dt[:, h:], A, B[:, h:], C[:, h:], h0=h1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hT_full), rtol=1e-3, atol=1e-3)


def test_model_attention_pallas_path_matches_xla():
    """The model-level attend() with impl=pallas agrees with xla_flash."""
    from repro.models.attention import attend
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 64, 2, 32), jnp.float32)
    a = attend(q, k, v, causal=True, impl="pallas")
    b = attend(q, k, v, causal=True, impl="xla_flash", chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)
