"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes in Python on CPU) + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

ATTN_SHAPES = [
    # (B, Sq, Skv, Hq, Hkv, hd)
    (1, 16, 16, 1, 1, 16),
    (2, 64, 64, 4, 4, 32),
    (2, 128, 128, 4, 2, 64),      # GQA
    (1, 80, 80, 8, 1, 64),        # MQA, ragged seq (padding path)
    (1, 256, 256, 2, 2, 128),
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(shape, dtype):
    b, sq, skv, hq, hkv, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, hd), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 32, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 96, 4, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 96, 4, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.integers(1, 96), st.sampled_from([1, 2, 4]),
       st.sampled_from([16, 32, 64]), st.integers(0, 2 ** 31 - 1))
def test_flash_attention_property(b, s, h, hd, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=3e-5, atol=3e-5)


SSD_SHAPES = [
    # (b, s, nh, dh, ng, ds, chunk)
    (1, 32, 2, 16, 1, 16, 16),
    (2, 64, 4, 32, 1, 32, 32),
    (1, 100, 4, 32, 2, 16, 32),    # ragged + grouped
    (2, 128, 8, 64, 1, 64, 64),
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(shape, dtype):
    b, s, nh, dh, ng, ds, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, nh, dh), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, ng, ds), dtype)
    C = jax.random.normal(ks[4], (b, s, ng, ds), dtype)
    y, hT = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    y_ref, hT_ref = ref.ssd_scan_ref(x, dt, A, B, C)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref),
                               rtol=1e-2, atol=1e-2)


def test_ssd_scan_with_initial_state():
    b, s, nh, dh, ng, ds = 1, 48, 2, 16, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    x = jax.random.normal(ks[0], (b, s, nh, dh), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, ng, ds), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, ng, ds), jnp.float32)
    h0 = jax.random.normal(ks[5], (b, nh, dh, ds), jnp.float32)
    y, hT = ops.ssd_scan(x, dt, A, B, C, h0=h0, chunk=16)
    y_ref, hT_ref = ref.ssd_scan_ref(x, dt, A, B, C, h0=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref), rtol=1e-3, atol=1e-3)


def test_ssd_scan_state_continuation():
    """Running two halves with state carry == running the whole sequence
    (the decode-from-prefill contract)."""
    b, s, nh, dh, ng, ds = 1, 64, 2, 16, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    x = jax.random.normal(ks[0], (b, s, nh, dh), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, ng, ds), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, ng, ds), jnp.float32)
    y_full, hT_full = ops.ssd_scan(x, dt, A, B, C, chunk=16)
    h = s // 2
    y1, h1 = ops.ssd_scan(x[:, :h], dt[:, :h], A, B[:, :h], C[:, :h], chunk=16)
    y2, h2 = ops.ssd_scan(x[:, h:], dt[:, h:], A, B[:, h:], C[:, h:], h0=h1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hT_full), rtol=1e-3, atol=1e-3)


def test_model_attention_pallas_path_matches_xla():
    """The model-level attend() with impl=pallas agrees with xla_flash."""
    from repro.models.attention import attend
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 64, 2, 32), jnp.float32)
    a = attend(q, k, v, causal=True, impl="pallas")
    b = attend(q, k, v, causal=True, impl="xla_flash", chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------------
# Hosting kernels (kernels.hosting): DP min-plus + counter-keyed threefry.
# Unlike the float kernels above these are bit-EXACT vs the engine's XLA
# references — array_equal, never allclose (the backend-dispatch
# invariant: a backend is a performance knob, not a numerics choice).
# ----------------------------------------------------------------------

from repro.core.policies.offline_opt import (dp_fetch_matrix, dp_frontier0,
                                             dp_fwd_chunk)
from repro.kernels.hosting import slot_uniform_tc, threefry_fold


def _dp_case(seed, chunk, K, k_used, T_len):
    """Random per-chunk DP inputs in dp_fwd_chunk's calling convention."""
    rng = np.random.default_rng(seed)
    lv32 = jnp.asarray(np.sort(rng.random(K)).astype(np.float32))
    fetch = dp_fetch_matrix(jnp.float32(rng.uniform(2, 8)), lv32)
    kmask = jnp.arange(K) < k_used
    cck = jnp.asarray(rng.uniform(0.1, 2.0, chunk).astype(np.float32))
    sck = jnp.asarray(rng.uniform(0.0, 3.0, (chunk, K)).astype(np.float32))
    tids = jnp.arange(chunk, dtype=jnp.int32)
    return (dp_frontier0(K), tids, cck, sck, lv32, kmask, fetch,
            jnp.asarray(T_len, jnp.int32))


DP_CASES = [
    # (chunk, K, k_used, T_len): aligned/odd chunks, +inf kmask pads,
    # frozen tails (T_len < chunk) and fully-frozen (T_len = 0)
    (16, 2, 2, 16),
    (8, 5, 5, 8),
    (37, 5, 3, 37),
    (37, 4, 4, 20),
    (64, 3, 2, 0),
    (1, 6, 4, 1),
]


@pytest.mark.parametrize("chunk,K,k_used,T_len", DP_CASES)
def test_dp_minplus_matches_xla_reference(chunk, K, k_used, T_len):
    case = _dp_case(chunk * 7 + K, chunk, K, k_used, T_len)
    Jx, ax = dp_fwd_chunk(*case, "xla")
    Jp, ap = dp_fwd_chunk(*case, "pallas")
    assert np.array_equal(np.asarray(Jx), np.asarray(Jp))
    assert np.array_equal(np.asarray(ax), np.asarray(ap))


def test_dp_minplus_chained_chunks_match():
    """The frontier carried across chunk boundaries stays exact: two
    16-slot pallas chunks == one 32-slot xla chunk, J and args."""
    (J0, _, cck, sck, lv32, kmask, fetch, _) = _dp_case(3, 32, 5, 4, 32)
    Jx, ax = dp_fwd_chunk(J0, jnp.arange(32, dtype=jnp.int32), cck, sck,
                          lv32, kmask, fetch, jnp.int32(27), "xla")
    J, parts = J0, []
    for t0 in (0, 16):
        tids = t0 + jnp.arange(16, dtype=jnp.int32)
        J, a = dp_fwd_chunk(J, tids, cck[t0:t0 + 16], sck[t0:t0 + 16],
                            lv32, kmask, fetch, jnp.int32(27), "pallas")
        parts.append(np.asarray(a))
    assert np.array_equal(np.asarray(Jx), np.asarray(J))
    assert np.array_equal(np.asarray(ax), np.concatenate(parts))


def test_dp_minplus_numpy_oracle():
    """Independent float32 numpy replay of the recursion — same values AND
    first-occurrence argmin (np.argmin's documented tie rule)."""
    chunk, K = 24, 4
    case = _dp_case(11, chunk, K, K, chunk)
    J0, tids, cck, sck, lv32, kmask, fetch, T_len = case
    w = np.asarray(cck)[:, None] * np.asarray(lv32)[None, :] + np.asarray(sck)
    J, args_ref = np.asarray(J0), []
    fm = np.asarray(fetch)
    for t in range(chunk):
        trans = (J[:, None] + fm).astype(np.float32)
        args_ref.append(trans.argmin(axis=0))
        J = (trans.min(axis=0) + w[t]).astype(np.float32)
    for backend in ("xla", "pallas"):
        Jb, ab = dp_fwd_chunk(*case, backend)
        assert np.array_equal(np.asarray(Jb), J), backend
        assert np.array_equal(np.asarray(ab), np.stack(args_ref)), backend


def test_dp_argmin_ties_resolve_to_lowest_index():
    """Crafted all-equal-cost fixture: with a zero fetch matrix every
    predecessor ties, so the argmin table must be the lowest index holding
    the running min — for both backends, identically."""
    K, chunk = 4, 6
    lv32 = jnp.linspace(0.0, 1.0, K, dtype=jnp.float32)
    fetch = dp_fetch_matrix(jnp.float32(0.0), lv32)     # all-zero fetch
    J0 = jnp.asarray([1.0, 0.0, 0.0, 0.0], jnp.float32)
    cck = jnp.zeros((chunk,), jnp.float32)
    sck = jnp.zeros((chunk, K), jnp.float32)            # w == 0
    tids = jnp.arange(chunk, dtype=jnp.int32)
    kmask = jnp.ones((K,), bool)
    for backend in ("xla", "pallas"):
        J, args = dp_fwd_chunk(J0, tids, cck, sck, lv32, kmask, fetch,
                               jnp.int32(chunk), backend)
        # slot 0: levels {1,2,3} tie at 0 -> index 1; after that J == 0
        # everywhere so all K levels tie -> index 0
        want = np.ones((chunk, K), np.int64)
        want[1:] = 0
        assert np.array_equal(np.asarray(args), want), backend
        assert np.array_equal(np.asarray(J), np.zeros(K, np.float32)), backend


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 40), st.integers(2, 6))
def test_dp_argmin_tie_property(seed, chunk, K):
    """Hypothesis: costs drawn from a coarse half-integer grid force
    frequent exact ties; both backends must match the numpy
    first-occurrence (lowest-predecessor-index) oracle bit-for-bit."""
    rng = np.random.default_rng(seed)
    lv32 = jnp.linspace(0.0, 1.0, K, dtype=jnp.float32)
    fetch = dp_fetch_matrix(jnp.float32(rng.integers(0, 3) * 2.0), lv32)
    w = (rng.integers(0, 4, (chunk, K)) / 2.0).astype(np.float32)
    J0 = jnp.asarray(rng.integers(0, 3, K) / 2.0, jnp.float32)
    tids = jnp.arange(chunk, dtype=jnp.int32)
    kmask = jnp.ones((K,), bool)
    J, args_ref = np.asarray(J0), []
    fm = np.asarray(fetch)
    for t in range(chunk):
        trans = (J[:, None] + fm).astype(np.float32)
        args_ref.append(trans.argmin(axis=0))
        J = (trans.min(axis=0) + w[t]).astype(np.float32)
    for backend in ("xla", "pallas"):
        Jb, ab = dp_fwd_chunk(J0, tids, jnp.zeros(chunk, jnp.float32),
                              jnp.asarray(w), lv32, kmask, fetch,
                              jnp.int32(chunk), backend)
        assert np.array_equal(np.asarray(ab), np.stack(args_ref)), backend
        assert np.array_equal(np.asarray(Jb), J), backend


def test_dp_minplus_batched_wrapper():
    """ops.dp_minplus vmaps the kernel over [B]; rows match per-instance
    XLA references exactly."""
    cases = [_dp_case(s, 20, 5, k, t)
             for s, (k, t) in enumerate([(5, 20), (3, 7), (2, 0)])]
    J = jnp.stack([c[0] for c in cases])
    w = []
    valid = []
    for c in cases:
        _, tids, cck, sck, lv32, kmask, _, T_len = c
        wck = cck[:, None] * lv32[None, :] + sck
        w.append(jnp.where(kmask[None, :], wck, jnp.inf))
        valid.append(tids < T_len)
    Jb, ab = ops.dp_minplus(J, jnp.stack(w),
                            jnp.stack([c[6] for c in cases]),
                            jnp.stack(valid))
    for i, c in enumerate(cases):
        Jx, ax = dp_fwd_chunk(*c, "xla")
        assert np.array_equal(np.asarray(Jb[i]), np.asarray(Jx)), i
        assert np.array_equal(np.asarray(ab[i]), np.asarray(ax)), i


# ---------------------------------------------------------------------
# Counter-PRNG kernel vs jax.random primitives (bit-equality).
# ---------------------------------------------------------------------

def _ref_uniform(key, tids, salt):
    """The canonical vmapped chain from scenarios.base.slot_uniform."""
    ks = jax.vmap(lambda t: jax.random.fold_in(key, t))(tids)
    if salt is not None:
        ks = jax.vmap(lambda k: jax.random.fold_in(k, salt))(ks)
    return jax.vmap(lambda k: jax.random.uniform(k, (), jnp.float32))(ks)


@pytest.mark.parametrize("salt", [None, 0, 1, 2, 0x7FFFFFFF])
@pytest.mark.parametrize("chunk", [1, 8, 37, 129])
def test_slot_uniform_bits_match_jax_random(salt, chunk):
    key = jax.random.PRNGKey(42)
    tids = jnp.arange(chunk, dtype=jnp.int32) + 5
    got = slot_uniform_tc(jnp.asarray(key, jnp.uint32), tids, salt)
    assert np.array_equal(np.asarray(got), np.asarray(_ref_uniform(key, tids, salt)))


def test_threefry_fold_matches_fold_in():
    """The in-kernel threefry2x32 reimplementation == jax.random.fold_in
    at the key level, not just after the uniform mapping."""
    key = jax.random.PRNGKey(3)
    d = jnp.arange(64, dtype=jnp.uint32) * 977 + 13
    x0, x1 = threefry_fold(jnp.uint32(key[0]), jnp.uint32(key[1]), d)
    want = jax.vmap(lambda t: jax.random.fold_in(key, t))(d)
    assert np.array_equal(np.asarray(jnp.stack([x0, x1], -1)),
                          np.asarray(want))


def test_bernoulli_bits_match_jax_random():
    """(kernel uniform < p) == jax.random.bernoulli on the folded key —
    the exact op chain bernoulli_arrivals / the GE emitter use."""
    key = jax.random.PRNGKey(7)
    tids = jnp.arange(37, dtype=jnp.int32)
    for p in (0.0, 0.25, 0.4, 1.0):
        u = slot_uniform_tc(jnp.asarray(key, jnp.uint32), tids, None)
        want = jax.vmap(lambda t: jax.random.bernoulli(
            jax.random.fold_in(key, t), p))(tids)
        assert np.array_equal(np.asarray(u < p), np.asarray(want)), p


def test_counter_uniforms_batched_wrapper():
    keys = jax.random.split(jax.random.PRNGKey(5), 4)
    tids = jnp.arange(23, dtype=jnp.int32) + 100
    got = ops.counter_uniforms(jnp.asarray(keys, jnp.uint32), tids, salt=2)
    for i in range(4):
        want = _ref_uniform(keys[i], tids, 2)
        assert np.array_equal(np.asarray(got[i]), np.asarray(want)), i


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 70),
       st.one_of(st.none(), st.integers(0, 2 ** 31 - 1)),
       st.integers(0, 10 ** 6))
def test_slot_uniform_property(seed, chunk, salt, t0):
    """Random keys x random salts x non-aligned chunk sizes x arbitrary
    counter offsets: always the exact jax.random bits."""
    key = jax.random.PRNGKey(seed)
    tids = t0 + jnp.arange(chunk, dtype=jnp.int32)
    got = slot_uniform_tc(jnp.asarray(key, jnp.uint32), tids, salt)
    assert np.array_equal(np.asarray(got),
                          np.asarray(_ref_uniform(key, tids, salt)))


@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="compiled (non-interpret) Pallas needs an "
                           "accelerator backend; CPU covers interpret mode")
def test_hosting_kernels_compiled_mode():
    """On an accelerator the compiled kernels must match too (interpret
    mode is what the CPU suite proves)."""
    case = _dp_case(1, 32, 5, 4, 25)
    Jx, ax = dp_fwd_chunk(*case, "xla")
    from repro.kernels.hosting import dp_minplus_kc
    J0, tids, cck, sck, lv32, kmask, fetch, T_len = case
    wck = jnp.where(kmask[None, :],
                    cck[:, None] * lv32[None, :] + sck, jnp.inf)
    Jp, ap = dp_minplus_kc(J0, wck, fetch, tids < T_len, interpret=False)
    assert np.array_equal(np.asarray(Jx), np.asarray(Jp))
    assert np.array_equal(np.asarray(ax), np.asarray(ap))
    key = jax.random.PRNGKey(9)
    u = slot_uniform_tc(jnp.asarray(key, jnp.uint32),
                        jnp.arange(37, dtype=jnp.int32), 1, interpret=False)
    want = _ref_uniform(key, jnp.arange(37, dtype=jnp.int32), 1)
    assert np.array_equal(np.asarray(u), np.asarray(want))
