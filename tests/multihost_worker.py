"""Worker + shared workload builders for tests/test_multihost.py.

NOT a test module (no ``test_`` prefix): the pytest process imports the
``build_*`` helpers to construct the single-process reference workload,
and ``run_local_cluster`` runs this file as the per-process worker
(``python tests/multihost_worker.py <mode> <outdir>``).  Every builder is
parameterized on a GLOBAL row range ``[lo, hi)`` so a worker's local shard
is by construction the same rows the reference holds at ``[lo:hi]`` —
mixed K, mixed T, per-row obs from a per-global-row generator, and
counter-keyed scenario streams sliced from one global key set.

Worker modes:
  * ``engine <outdir>`` — join the cluster, run the sim / DP / stepper
    config matrix on this process's shard, save exact result bits to
    ``<outdir>/out_<pid>.npz``.
  * ``meshinfo`` — join the cluster, print one JSON line of mesh facts
    (process-spanning construction assertions run in the parent).
"""
import json
import os
import sys

import numpy as np

B_GLOBAL = 8
T_MAX = 40
SEED = 7
T_CHOICES = (24, 32, 40, 28, 36)       # mixed horizons, max == T_MAX
K_GLOBAL = 5   # global grid K padding: every shard pads to this (the
               # multi-host convention — see HostingGrid.from_costs)


def costs_for_row(i: int):
    """Mixed-K costs keyed on the GLOBAL row index (same scheme as
    test_fleet_engine.mixed_costs, made slice-stable)."""
    from repro.core.costs import HostingCosts
    M = [2.0, 4.0, 10.0][i % 3]
    kind = (i // 2) % 3
    if kind == 0:
        return HostingCosts.two_level(M)
    if kind == 1:
        return HostingCosts.three_level(M, 0.25 + 0.125 * (i % 3),
                                        0.125 * (1 + i % 5))
    return HostingCosts(M=M, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                        g=(1.0, 0.4, 0.3, 0.15, 0.0))


def build_obs_fleet(lo: int, hi: int):
    """Obs-backed FleetBatch for global rows [lo, hi): each row's trace
    comes from its OWN ``default_rng(1000 + row)``, so any shard equals the
    same rows of the global build with zero cross-row coupling."""
    from repro.core.costs import HostingGrid
    from repro.core.fleet import FleetBatch
    grid = HostingGrid.from_costs([costs_for_row(i) for i in range(lo, hi)],
                                  K=K_GLOBAL)
    B = hi - lo
    x = np.zeros((B, T_MAX), np.int32)
    c = np.zeros((B, T_MAX), np.float32)
    T = np.zeros((B,), np.int32)
    for j, i in enumerate(range(lo, hi)):
        rng = np.random.default_rng(1000 + i)
        Ti = T_CHOICES[i % len(T_CHOICES)]
        x[j, :Ti] = rng.integers(0, 3, Ti)
        c[j, :Ti] = rng.integers(1, 16, Ti) / 8.0
        T[j] = Ti
    return FleetBatch.from_dense(grid, x, c, T=T)


def build_scenario_fleet(lo: int, hi: int):
    """(obs-less FleetBatch, Scenario) for global rows [lo, hi): streams
    take explicit per-row keys sliced from the GLOBAL ``split_keys`` set —
    the counter-keyed convention that makes per-host shard generation
    trivially consistent."""
    import jax
    from repro.core import scenarios as S
    from repro.core.costs import HostingGrid
    from repro.core.fleet import FleetBatch
    B = hi - lo
    kx = S.split_keys(jax.random.PRNGKey(SEED), B_GLOBAL)[lo:hi]
    kc = S.split_keys(jax.random.PRNGKey(SEED + 1), B_GLOBAL)[lo:hi]
    p = np.asarray([0.2 + 0.05 * (i % 4) for i in range(lo, hi)], np.float32)
    sc = S.combine(S.bernoulli_arrivals(kx, p, B),
                   S.spot_rents(kc, 0.5, B))
    grid = HostingGrid.from_costs([costs_for_row(i) for i in range(lo, hi)],
                                  K=K_GLOBAL)
    T = np.asarray([T_CHOICES[i % len(T_CHOICES)] for i in range(lo, hi)],
                   np.int32)
    return FleetBatch.for_scenario(grid, T), sc


def run_engine_configs(lo: int, hi: int, mesh=None, gather: bool = False):
    """The sim + DP + stepper config matrix on rows [lo, hi); returns a
    flat dict of numpy arrays (exact bits — the test compares with
    np.array_equal, never allclose)."""
    from repro.core.fleet import fleet_stepper, offline_opt_fleet, run_fleet
    from repro.core.policies import AlphaRR
    out = {}

    # ---- obs-backed ---------------------------------------------------
    fleet = build_obs_fleet(lo, hi)
    policy = AlphaRR.fleet(fleet)
    r = run_fleet(policy, fleet, mesh=mesh, chunk_size=8)
    out.update(o_run_total=r.total, o_run_fetch=r.fetch, o_run_rent=r.rent,
               o_run_service=r.service, o_run_rhist=r.r_hist,
               o_run_levels=r.level_slots)
    rs = run_fleet(policy, fleet, mesh=mesh, chunk_size=8, stream=True,
                   async_ingest=True)
    out.update(o_stream_total=rs.total, o_stream_rhist=rs.r_hist)
    dpm = offline_opt_fleet(fleet, mesh=mesh, chunk_size=8)
    out.update(o_dpmat_cost=dpm.cost, o_dpmat_rhist=dpm.r_hist,
               o_dpmat_simtotal=dpm.sim.total)
    dpc = offline_opt_fleet(fleet, mesh=mesh, chunk_size=8,
                            checkpointed=True, stream=True, async_ingest=True)
    out.update(o_dpck_cost=dpc.cost, o_dpck_rhist=dpc.r_hist)

    stepper = fleet_stepper(policy, fleet, mesh=mesh, chunk_size=4)
    x, c = np.asarray(fleet.x), np.asarray(fleet.c)
    parts = [stepper.step(x=x[:, t:t + 4], c=c[:, t:t + 4])
             for t in range(0, T_MAX, 4)]
    sr = stepper.result(np.concatenate(parts, axis=1))
    out.update(o_step_total=sr.total, o_step_rhist=sr.r_hist,
               o_step_levels=stepper.hosting_levels())
    if gather:
        rg = run_fleet(policy, fleet, mesh=mesh, chunk_size=8, gather=True)
        out.update(o_gather_total=rg.total, o_gather_rhist=rg.r_hist)

    # ---- scenario-fused, n_seeds=2 ------------------------------------
    sfleet, sc = build_scenario_fleet(lo, hi)
    spolicy = AlphaRR.fleet(sfleet)
    r = run_fleet(spolicy, sfleet, scenario=sc, mesh=mesh, chunk_size=8,
                  n_seeds=2)
    out.update(s_run_total=r.total, s_run_rhist=r.r_hist)
    rs = run_fleet(spolicy, sfleet, scenario=sc, mesh=mesh, chunk_size=8,
                   stream=True, collect_trace=False, n_seeds=2)
    out.update(s_stream_total=rs.total, s_stream_rent=rs.rent)
    dpc = offline_opt_fleet(sfleet, scenario=sc, mesh=mesh, chunk_size=8,
                            checkpointed=True, stream=True, n_seeds=2)
    out.update(s_dpck_cost=dpc.cost, s_dpck_rhist=dpc.r_hist,
               s_dpck_simtotal=dpc.sim.total)
    stepper = fleet_stepper(spolicy, sfleet, scenario=sc, mesh=mesh,
                            chunk_size=8, n_seeds=2)
    for _ in range(T_MAX // 8):
        stepper.step()
    out["s_step_total"] = stepper.result().total
    return {k: np.asarray(v) for k, v in out.items()}


def _engine_main(outdir: str) -> None:
    from repro.sharding import distributed
    distributed.initialize()
    import jax
    lo = jax.process_index() * (B_GLOBAL // jax.process_count())
    hi = lo + B_GLOBAL // jax.process_count()
    out = run_engine_configs(lo, hi, gather=True)
    out["meta"] = np.asarray([jax.process_index(), jax.process_count(),
                              lo, hi])
    np.savez(os.path.join(outdir, f"out_{jax.process_index()}.npz"), **out)
    distributed.shutdown()


def _meshinfo_main() -> None:
    from repro.sharding import distributed
    multi = distributed.initialize()
    import jax
    from repro.sharding.specs import (fleet_mesh, mesh_is_multiprocess,
                                      mesh_local_device_count,
                                      mesh_process_count)
    mesh = fleet_mesh()
    procs = [d.process_index for d in mesh.devices.flat]
    print(json.dumps({
        "pid": jax.process_index(),
        "nprocs": jax.process_count(),
        "initialized": bool(multi),
        "global_devices": jax.device_count(),
        "local_devices": jax.local_device_count(),
        "mesh_size": int(mesh.devices.size),
        "mesh_procs": procs,
        "process_contiguous": procs == sorted(procs),
        "mesh_process_count": mesh_process_count(mesh),
        "mesh_is_multiprocess": mesh_is_multiprocess(mesh),
        "mesh_local_device_count": mesh_local_device_count(mesh),
    }))
    distributed.shutdown()


if __name__ == "__main__":
    mode = sys.argv[1]
    if mode == "engine":
        _engine_main(sys.argv[2])
    elif mode == "meshinfo":
        _meshinfo_main()
    else:
        raise SystemExit(f"unknown worker mode {mode!r}")
