"""Backend dispatch bit-identity: the ``dp_backend=`` / ``prng_backend=``
knobs threaded through the fleet engine are pure performance choices —
every driver configuration must produce results EXACTLY equal (array_equal,
never allclose) to the canonical XLA path, per the engine's
backend-dispatch invariant (ROADMAP.md):

* ``offline_opt_fleet`` — materialized / checkpointed / chunked (divisor
  and non-divisor sizes) / host-streamed / cost-only, mixed horizons,
  mixed K, ``n_seeds`` replication, dp and prng backends independently
  and together;
* ``run_fleet`` / ``evaluate_schedule_fleet`` — prng backend through the
  fused scan, including the GE *bernoulli-emission* path (the one arrival
  stream whose innovations AND emissions both ride ``slot_uniform``);
* argument validation (unknown backends; prng reroute without a scenario);
* a forced-4-CPU-device subprocess leg proving the pallas legs shard (the
  compiled cores drop ``check_rep`` — pallas_call has no replication
  rule — so the mesh path needs its own proof).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import scenarios as S
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import (FleetBatch, evaluate_schedule_fleet,
                              offline_opt_fleet, run_fleet)
from repro.core.policies import AlphaRR

T = 40
KEY = jax.random.PRNGKey(29)
CHUNKS = [16, 20]      # 20 does not divide 40+pad: padded-tail leg

COST_POOL = [HostingCosts.two_level(4.0),
             HostingCosts.three_level(6.0, 0.25, 0.5),
             HostingCosts.three_level(3.0, 0.5, 0.25),
             HostingCosts(M=5.0, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                          g=(1.0, 0.4, 0.3, 0.15, 0.0)),
             HostingCosts.three_level(8.0, 0.375, 0.375)]


def make_scenario(B, kind="ge"):
    """"ge": GE arrivals with BERNOULLI emissions (chain innovations and
    emissions both draw through slot_uniform -> the full pallas chain) +
    ARMA spot rents; "iid": stateless bernoulli + uniform."""
    kx = S.split_keys(KEY, B)
    if kind == "ge":
        return S.combine(
            S.ge_arrivals(kx, 0.3, 0.2, 0.9, 0.2, B, emission="bernoulli"),
            S.spot_rents(jax.random.PRNGKey(1), 0.5, B))
    return S.combine(S.bernoulli_arrivals(kx, 0.4, B),
                     S.uniform_rents(jax.random.PRNGKey(1), 0.5, 0.3, B))


def assert_same_offline(a, b):
    assert np.array_equal(a.cost, b.cost)
    if a.r_hist is None:
        assert b.r_hist is None
        return
    assert np.array_equal(a.r_hist, b.r_hist)
    assert np.array_equal(a.sim.total, b.sim.total)
    assert np.array_equal(a.sim.level_slots, b.sim.level_slots)


@pytest.fixture(scope="module", params=["ge", "iid"])
def stacked(request):
    grid = HostingGrid.from_costs(COST_POOL)
    sc = make_scenario(grid.B, request.param)
    fleet = FleetBatch.for_scenario(grid, [T, 23, 11, T, 7])
    return grid, sc, fleet


DRIVER_CONFIGS = [
    {},
    {"checkpointed": True},
    {"chunk_size": CHUNKS[0]},
    {"checkpointed": True, "chunk_size": CHUNKS[1]},
    {"checkpointed": True, "chunk_size": CHUNKS[0], "stream": True},
    {"checkpointed": True, "chunk_size": CHUNKS[1],
     "collect_schedule": False},
]


@pytest.mark.parametrize("kw", DRIVER_CONFIGS)
def test_offline_opt_backends_bitwise(stacked, kw):
    _, sc, fleet = stacked
    base = offline_opt_fleet(fleet, scenario=sc, **kw)
    for bk in ({"dp_backend": "pallas"},
               {"prng_backend": "pallas"},
               {"dp_backend": "pallas", "prng_backend": "pallas"}):
        assert_same_offline(
            offline_opt_fleet(fleet, scenario=sc, **kw, **bk), base)


def test_offline_opt_backends_obs_backed(stacked):
    """dp_backend on materialized observations (no scenario at all)."""
    grid, sc, fleet = stacked
    x, c, svc, side = S.materialize(sc, T)
    fl = FleetBatch.from_dense(grid, x, c, T=np.asarray(fleet.T))
    base = offline_opt_fleet(fl)
    for kw in ({}, {"checkpointed": True, "chunk_size": CHUNKS[0]}):
        assert_same_offline(
            offline_opt_fleet(fl, dp_backend="pallas", **kw), base)


def test_offline_opt_backends_n_seeds(stacked):
    _, sc, fleet = stacked
    base = offline_opt_fleet(fleet, scenario=sc, n_seeds=3,
                             checkpointed=True, chunk_size=CHUNKS[0])
    assert_same_offline(
        offline_opt_fleet(fleet, scenario=sc, n_seeds=3, checkpointed=True,
                          chunk_size=CHUNKS[0], dp_backend="pallas",
                          prng_backend="pallas"), base)


def test_run_fleet_prng_backend_bitwise(stacked):
    _, sc, fleet = stacked
    fns = AlphaRR.fleet(fleet)
    for kw in ({}, {"chunk_size": CHUNKS[0]},
               {"chunk_size": CHUNKS[1], "stream": True},
               {"chunk_size": CHUNKS[0], "n_seeds": 3}):
        base = run_fleet(fns, fleet, scenario=sc, **kw)
        got = run_fleet(fns, fleet, scenario=sc, prng_backend="pallas", **kw)
        assert np.array_equal(got.total, base.total), kw
        assert np.array_equal(got.r_hist, base.r_hist), kw
        assert np.array_equal(got.level_slots, base.level_slots), kw


def test_evaluate_schedule_prng_backend_bitwise(stacked):
    _, sc, fleet = stacked
    r_hist = offline_opt_fleet(fleet, scenario=sc).r_hist
    base = evaluate_schedule_fleet(fleet, r_hist, scenario=sc,
                                   chunk_size=CHUNKS[0])
    got = evaluate_schedule_fleet(fleet, r_hist, scenario=sc,
                                  chunk_size=CHUNKS[0],
                                  prng_backend="pallas")
    assert np.array_equal(got.total, base.total)
    assert np.array_equal(got.level_slots, base.level_slots)


def test_backend_validation(stacked):
    _, sc, fleet = stacked
    with pytest.raises(ValueError, match="dp_backend"):
        offline_opt_fleet(fleet, scenario=sc, dp_backend="cuda")
    with pytest.raises(ValueError, match="prng_backend"):
        offline_opt_fleet(fleet, scenario=sc, prng_backend="tpu")
    with pytest.raises(ValueError, match="needs scenario"):
        offline_opt_fleet(fleet, prng_backend="pallas")
    with pytest.raises(ValueError, match="prng_backend"):
        run_fleet(AlphaRR.fleet(fleet), fleet, scenario=sc,
                  prng_backend="nope")
    with pytest.raises(ValueError):
        S.with_prng_backend(sc, "nope")


def test_with_prng_backend_identity(stacked):
    """"xla" is a no-op wrap; "pallas" renames and caches: wrapping the
    same scenario twice yields the SAME function objects (the identity-
    keyed compile caches depend on it)."""
    _, sc, _ = stacked
    assert S.with_prng_backend(sc, "xla") is sc
    a = S.with_prng_backend(sc, "pallas")
    b = S.with_prng_backend(sc, "pallas")
    assert a.name.endswith("@pallas")
    assert a.init_fn is b.init_fn and a.chunk_fn is b.chunk_fn


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(DRIVER_CONFIGS), st.sampled_from(["ge", "iid"]),
       st.sampled_from(["pallas-dp", "pallas-prng", "pallas-both"]))
def test_backend_config_walk(kw, kind, mode):
    """Hypothesis walk over (driver config) x (scenario kind) x (backend
    combination) — every cell bit-identical to XLA."""
    grid = HostingGrid.from_costs(COST_POOL[:3])
    sc = make_scenario(grid.B, kind)
    fleet = FleetBatch.for_scenario(grid, [T, 17, 9])
    bk = {}
    if mode in ("pallas-dp", "pallas-both"):
        bk["dp_backend"] = "pallas"
    if mode in ("pallas-prng", "pallas-both"):
        bk["prng_backend"] = "pallas"
    assert_same_offline(
        offline_opt_fleet(fleet, scenario=sc, **kw, **bk),
        offline_opt_fleet(fleet, scenario=sc, **kw))


# ----------------------------------------------------------------------
# Forced-multi-device leg (subprocess; conftest pins this process to one
# device).  The pallas cores run with check_rep=False, so sharded == XLA
# needs an explicit proof.
# ----------------------------------------------------------------------

_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    assert jax.device_count() == 4, jax.devices()
    from repro.core import scenarios as S
    from repro.core.costs import HostingCosts, HostingGrid
    from repro.core.fleet import FleetBatch, offline_opt_fleet, run_fleet
    from repro.core.policies import AlphaRR
    from repro.sharding.specs import fleet_mesh

    # B=5 is not a multiple of 4: dummy-instance padding on the mesh
    pool = [HostingCosts.two_level(4.0),
            HostingCosts.three_level(6.0, 0.25, 0.5),
            HostingCosts.three_level(3.0, 0.5, 0.25),
            HostingCosts(M=5.0, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                         g=(1.0, 0.4, 0.3, 0.15, 0.0)),
            HostingCosts.three_level(8.0, 0.375, 0.375)]
    grid = HostingGrid.from_costs(pool)
    kx = S.split_keys(jax.random.PRNGKey(29), grid.B)
    sc = S.combine(
        S.ge_arrivals(kx, 0.3, 0.2, 0.9, 0.2, grid.B, emission="bernoulli"),
        S.spot_rents(jax.random.PRNGKey(1), 0.5, grid.B))
    fleet = FleetBatch.for_scenario(grid, [40, 23, 11, 40, 7])
    mesh = fleet_mesh()
    for kw in ({}, {"checkpointed": True, "chunk_size": 16, "stream": True}):
        base = offline_opt_fleet(fleet, scenario=sc, mesh=mesh, **kw)
        got = offline_opt_fleet(fleet, scenario=sc, mesh=mesh,
                                dp_backend="pallas",
                                prng_backend="pallas", **kw)
        assert np.array_equal(got.cost, base.cost), kw
        assert np.array_equal(got.r_hist, base.r_hist), kw
        assert np.array_equal(got.sim.total, base.sim.total), kw
    fns = AlphaRR.fleet(fleet)
    base = run_fleet(fns, fleet, scenario=sc, mesh=mesh, chunk_size=16)
    got = run_fleet(fns, fleet, scenario=sc, mesh=mesh, chunk_size=16,
                    prng_backend="pallas")
    assert np.array_equal(got.total, base.total)
    assert np.array_equal(got.r_hist, base.r_hist)
    print("BACKEND-MULTI-DEVICE-OK")
""")


def test_backend_dispatch_multi_device_bitwise():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "BACKEND-MULTI-DEVICE-OK" in out.stdout
