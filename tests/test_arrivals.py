"""core/arrivals.py: shapes/dtypes, determinism given a key, and basic
distributional sanity of every arrival process."""
import jax
import numpy as np
import pytest

from repro.core import arrivals
from repro.core.arrivals import (GilbertElliot, adversarial_evict_bait,
                                 adversarial_fetch_bait, bernoulli,
                                 cluster_trace_like, poisson)

T = 4000
KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("sample", [
    lambda k: bernoulli(k, 0.3, T),
    lambda k: poisson(k, 2.5, T),
    lambda k: GilbertElliot(p_hl=0.2, p_lh=0.1, rate_h=3.0,
                            rate_l=0.2).sample(k, T),
    lambda k: GilbertElliot(p_hl=0.2, p_lh=0.1, rate_h=0.9, rate_l=0.1,
                            emission="bernoulli").sample(k, T),
    lambda k: cluster_trace_like(k, T),
    lambda k: cluster_trace_like(k, T, diurnal_period=500),
], ids=["bernoulli", "poisson", "ge-poisson", "ge-bernoulli",
        "cluster", "cluster-diurnal"])
def test_shape_dtype_determinism(sample):
    x1 = np.asarray(sample(KEY))
    x2 = np.asarray(sample(KEY))
    x3 = np.asarray(sample(jax.random.PRNGKey(43)))
    assert x1.shape == (T,)
    assert x1.dtype == np.int32
    assert np.all(x1 >= 0)
    assert np.array_equal(x1, x2), "same key must give the same trace"
    assert not np.array_equal(x1, x3), "different keys must differ"


def test_bernoulli_mean():
    x = np.asarray(bernoulli(KEY, 0.3, 20000))
    assert set(np.unique(x)) <= {0, 1}
    assert abs(x.mean() - 0.3) < 0.02


def test_poisson_moments():
    x = np.asarray(poisson(KEY, 2.5, 20000))
    assert abs(x.mean() - 2.5) < 0.1
    assert abs(x.var() - 2.5) < 0.2      # Poisson: var == mean


def test_gilbert_elliot_stationary_occupancy():
    ge = GilbertElliot(p_hl=0.2, p_lh=0.1, rate_h=3.0, rate_l=0.2)
    assert ge.stationary_h == pytest.approx(0.1 / 0.3)
    x, states = ge.sample(KEY, 60000, return_states=True)
    states = np.asarray(states)
    occ_h = states.mean()
    assert abs(occ_h - ge.stationary_h) < 0.02
    # empirical transition frequencies match the chain parameters
    h_to_l = np.mean(states[1:][states[:-1] == 1] == 0)
    l_to_h = np.mean(states[1:][states[:-1] == 0] == 1)
    assert abs(h_to_l - ge.p_hl) < 0.02
    assert abs(l_to_h - ge.p_lh) < 0.02
    # per-state emission rates
    x = np.asarray(x)
    assert abs(x[states == 1].mean() - ge.rate_h) < 0.1
    assert abs(x[states == 0].mean() - ge.rate_l) < 0.05
    assert abs(x.mean() - ge.mean_rate) < 0.15


def test_cluster_trace_burstiness():
    """The cluster-trace stand-in must be overdispersed (bursty), unlike a
    plain Poisson at the same mean."""
    x = np.asarray(cluster_trace_like(KEY, 50000, base_rate=2.0,
                                      burst_rate=20.0, burst_p=0.05)).astype(float)
    assert x.var() / x.mean() > 2.0
    # positive autocorrelation at lag 1 (state persistence)
    xc = x - x.mean()
    rho1 = np.mean(xc[1:] * xc[:-1]) / x.var()
    assert rho1 > 0.2


def test_adversarial_constructions():
    x = adversarial_fetch_bait(tau=10, T=30)
    assert x.shape == (30,) and x.dtype == np.int32
    assert np.all(x[:10] == 1) and np.all(x[10:] == 0)
    y = adversarial_evict_bait(tau_bar=5, tau=10, T=30)
    assert np.all(y[:5] == 0) and np.all(y[5:15] == 1) and np.all(y[15:] == 0)
