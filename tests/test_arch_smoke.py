"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch, run one forward + one SGD train step + one decode step on CPU,
assert output shapes and finiteness.  (Full configs are exercised only via
the dry-run — ShapeDtypeStructs, no allocation.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs
from repro.models import (init_params, forward, logits_fn, lm_loss, make_caches)

ARCHS = sorted(all_archs())


def _batch_for(cfg, b, s, key):
    kt, kf = jax.random.split(key)
    batch = {"tokens": jax.random.randint(kt, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(kf, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jax.random.normal(
            kf, (b, cfg.frontend_tokens, cfg.frontend_dim), cfg.param_dtype)
    elif cfg.frontend == "audio":
        batch["frontend_embeds"] = jax.random.normal(
            kf, (b, s, cfg.frontend_dim), cfg.param_dtype)
    return batch


@pytest.mark.parametrize("arch_id", ARCHS)
def test_forward_shapes_and_finiteness(arch_id):
    spec = all_archs()[arch_id]
    cfg = spec.tiny
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    b, s = 2, 16
    batch = _batch_for(cfg, b, s, jax.random.PRNGKey(1))
    hidden, _, aux = forward(params, cfg, batch)
    assert hidden.shape == (b, s, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(hidden, np.float32)))
    logits = logits_fn(params, cfg, hidden)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("arch_id", ARCHS)
def test_one_train_step_reduces_loss_or_stays_finite(arch_id):
    spec = all_archs()[arch_id]
    cfg = spec.tiny
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 16, jax.random.PRNGKey(1))

    def loss_fn(p):
        hidden, _, aux = forward(p, cfg, batch)
        return lm_loss(p, cfg, hidden, batch["labels"]) + 0.01 * aux

    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype), params, grads)
    l1 = loss_fn(params2)
    assert np.isfinite(float(l1))
    # tiny models + one SGD step on random data: loss should not explode
    assert float(l1) < float(l0) * 1.5 + 1.0


@pytest.mark.parametrize("arch_id", ARCHS)
def test_decode_matches_full_forward(arch_id):
    """Prefill + single-token decode agrees with running the full sequence
    in one shot (the KV-cache/state plumbing is correct)."""
    spec = all_archs()[arch_id]
    cfg = spec.tiny
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    batch = _batch_for(cfg, b, s, jax.random.PRNGKey(1))

    hidden_full, _, _ = forward(params, cfg, batch)

    smax = 16
    caches = make_caches(cfg, b, smax)
    prefill_batch = dict(batch)
    prefill_batch["tokens"] = batch["tokens"][:, :s - 1]
    if cfg.frontend == "audio":
        prefill_batch["frontend_embeds"] = batch["frontend_embeds"][:, :s - 1]
    _, caches, _ = forward(params, cfg, prefill_batch, caches=caches,
                           cache_pos=jnp.int32(0))
    step_batch = dict(batch)
    step_batch["tokens"] = batch["tokens"][:, s - 1:s]
    if cfg.frontend == "audio":
        step_batch["frontend_embeds"] = batch["frontend_embeds"][:, s - 1:s]
    hid_step, _, _ = forward(params, cfg, step_batch, caches=caches,
                             cache_pos=jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(hid_step[:, 0], np.float32),
                               np.asarray(hidden_full[:, -1], np.float32),
                               rtol=2e-3, atol=2e-3)


def test_registry_complete():
    archs = all_archs()
    assert len(archs) == 10
    fams = {a.family for a in archs.values()}
    assert {"dense", "moe", "ssm", "hybrid", "audio", "vlm"} <= fams
    # exact published dims spot-checks
    a = archs["deepseek-v2-236b"].model
    assert (a.d_model, a.n_heads, a.kv_lora_rank, a.n_routed_experts) == (5120, 128, 512, 160)
    q = archs["qwen2.5-14b"].model
    assert q.qkv_bias and q.vocab_size == 152064
    g = archs["granite-20b"].model
    assert g.n_kv_heads == 1 and g.d_ff == 24576
    z = archs["zamba2-1.2b"].model
    assert sum(n for k, n in z.segments if k == "ssm") == 38
    m = archs["mamba2-130m"].model
    assert m.ssm_state == 128 and m.vocab_size == 50280
