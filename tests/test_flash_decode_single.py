"""flash_decode property tests on a single-device mesh (the 8-device variant
lives in test_distributed.py)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.serve.flash_decode import flash_decode, flash_decode_ref


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.sampled_from([8, 32, 64]),
       st.sampled_from([(4, 4), (4, 2), (8, 1)]), st.integers(0, 2 ** 31 - 1))
def test_flash_decode_matches_ref(b, s, heads, seed):
    hq, hkv = heads
    hd = 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, 1, hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, hd), jnp.float32)
    pos = int(jax.random.randint(ks[3], (), 0, s))
    out = flash_decode(q, k, v, jnp.int32(pos), mesh=_mesh(), axis="model")
    ref = flash_decode_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_pos_zero_and_last():
    """Boundary positions: only slot 0 visible; all slots visible."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 32, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 32, 2, 16), jnp.float32)
    for pos in (0, 31):
        out = flash_decode(q, k, v, jnp.int32(pos), mesh=_mesh(), axis="model")
        ref = flash_decode_ref(q, k, v, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
