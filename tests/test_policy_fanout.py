"""Shared-stream policy fan-out, per the PR-9 acceptance bar:

* **Single-lane law** — ``run_fleet([p], ...)`` is bit-identical to
  ``run_fleet(p, ...)`` (exact equality, never allclose).
* **Lane independence** — every lane of a heterogeneous fan-out (fleet
  grid, own-grid + ``svc_cols`` gather, static) equals its standalone
  dispatch bit for bit, under chunked / streamed drivers, ``n_seeds``
  replication, obs-backed and scenario-fused generation (hypothesis
  property walk over the config space).
* **Co-executed DP** — ``with_opt_forward=True`` frontiers equal
  ``offline_opt_fleet(checkpointed=True, collect_schedule=False)`` per
  lane grid.
* **Stepper + live serving** — ``fleet_stepper`` fan-out readbacks match
  the one-shot driver; ``LiveFleetScheduler`` shadow lanes never perturb
  the admitted (lane-0) decisions.
* **Forced 4 devices / 2 processes** — the same lane equalities on a
  forced-4-device mesh (subprocess) and on a 2-process local cluster
  (each worker's shard rows == the single-process global run).

Under a forced multi-device platform the obs-backed and scenario-fused
generation paths differ bitwise from EACH OTHER (pre-existing, documented
in CHANGES.md) — every assertion here therefore compares like mode
against like mode; the one cross-mode check runs on 1 device only.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from repro.core import scenarios as S
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import (FleetBatch, fleet_stepper, offline_opt_fleet,
                              run_fleet)
from repro.core.policies import (AlphaRR, PolicyLane, RetroRenting,
                                 StaticPolicy)

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))

T = 48
B = 3
KEY = jax.random.PRNGKey(42)
CHUNKS = [16, 20]          # 20 does not divide 48: exercises the padded tail
HORIZONS = [48, 40, 48]
FIELDS = ["total", "rent", "service", "fetch"]


def _scenario(grid):
    return S.combine(
        S.ge_arrivals(S.split_keys(KEY, B), 0.3, 0.2, 2.0, 0.2, B),
        S.spot_rents(jax.random.PRNGKey(1), 0.5, B),
        svc=S.model2_service(jax.random.PRNGKey(2), grid.g, B,
                             max_per_slot=6))


_ENV = {}


def _env():
    """Shared workload + lane set (module-level memo, NOT a fixture: the
    hypothesis shim's ``@given`` erases the signature, so property tests
    cannot take fixtures)."""
    if _ENV:
        return _ENV
    costs_list = [HostingCosts.two_level(4.0),
                  HostingCosts.three_level(6.0, 0.3, 0.2),
                  HostingCosts(M=10.0, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                               g=(1.0, 0.4, 0.3, 0.15, 0.0))]
    grid = HostingGrid.from_costs(costs_list)
    fleet = FleetBatch.for_scenario(grid, HORIZONS)
    egrid = grid.restrict_to_endpoints()
    efleet = FleetBatch.for_scenario(egrid, HORIZONS)
    # the endpoint-grid reference scenario: same keys, endpoint g columns
    # (the coupled Model-2 uniforms make the fan-out lane's svc gather
    # bitwise identical to direct generation on the lane grid)
    _ENV.update(
        grid=grid, fleet=fleet, egrid=egrid, efleet=efleet,
        sc=_scenario(grid), sc_e=_scenario(egrid),
        lanes=[AlphaRR.fleet_lane(fleet),
               RetroRenting.fleet_lane(fleet, with_svc=True),
               StaticPolicy.fleet(fleet, grid.top_index())],
        refs={}, opt_refs={})
    return _ENV


def _ref(lane_id, n_seeds):
    """Standalone (classic-path) run of one lane's policy — the bitwise
    reference.  Cached per (lane, n_seeds); the drivers' own bitwise
    chunk/stream invariance (PR 3/6 suites) makes one reference serve
    every driver configuration."""
    e = _env()
    key = (lane_id, n_seeds)
    if key not in e["refs"]:
        if lane_id == 1:
            fns = RetroRenting.fleet(e["efleet"])
            e["refs"][key] = run_fleet(fns, e["efleet"], scenario=e["sc_e"],
                                       n_seeds=n_seeds)
        else:
            fns = (AlphaRR.fleet(e["fleet"]) if lane_id == 0
                   else StaticPolicy.fleet(e["fleet"],
                                           e["grid"].top_index()))
            e["refs"][key] = run_fleet(fns, e["fleet"], scenario=e["sc"],
                                       n_seeds=n_seeds)
    return e["refs"][key]


def _opt_ref(lane_id, n_seeds):
    """Offline DP reference for one lane's grid (lanes 0 and 2 share the
    fleet grid and therefore the frontier)."""
    e = _env()
    key = (lane_id == 1, n_seeds)
    if key not in e["opt_refs"]:
        fleet, sc = ((e["efleet"], e["sc_e"]) if lane_id == 1
                     else (e["fleet"], e["sc"]))
        e["opt_refs"][key] = offline_opt_fleet(
            fleet, scenario=sc, checkpointed=True, collect_schedule=False,
            n_seeds=n_seeds)
    return e["opt_refs"][key]


def assert_lane_equals(res, p, ref, label=""):
    pv_ls = res.policy_view(res.level_slots)
    for f in FIELDS:
        got = res.policy_view(getattr(res, f))[p]
        want = np.asarray(getattr(ref, f))
        assert np.array_equal(got, want), (label, p, f)
    assert np.array_equal(res.policy_view(res.r_hist)[p],
                          np.asarray(ref.r_hist)), (label, p, "r_hist")
    k = ref.level_slots.shape[-1]
    assert np.array_equal(pv_ls[p][..., :k],
                          np.asarray(ref.level_slots)), (label, p, "slots")


# ----------------------------------------------------------------------
# Single-lane law + heterogeneous lanes, fixed configs.
# ----------------------------------------------------------------------

def test_single_lane_matches_standalone():
    e = _env()
    fns = AlphaRR.fleet(e["fleet"])
    base = run_fleet(fns, e["fleet"], scenario=e["sc"])
    one = run_fleet([fns], e["fleet"], scenario=e["sc"])
    for f in FIELDS:
        assert np.array_equal(getattr(one, f), getattr(base, f)), f
    assert np.array_equal(one.r_hist, base.r_hist)
    assert np.array_equal(one.level_slots[..., :base.level_slots.shape[-1]],
                          base.level_slots)


@pytest.mark.parametrize("chunk", CHUNKS)
def test_heterogeneous_lanes_match_standalone(chunk):
    e = _env()
    res = run_fleet(e["lanes"], e["fleet"], scenario=e["sc"],
                    chunk_size=chunk)
    for p in range(3):
        assert_lane_equals(res, p, _ref(p, None), f"chunk={chunk}")


def test_opt_forward_matches_offline_dp():
    e = _env()
    res = run_fleet(e["lanes"], e["fleet"], scenario=e["sc"], chunk_size=16,
                    with_opt_forward=True)
    opt = res.policy_view(res.opt_cost)
    for p in range(3):
        assert np.array_equal(opt[p], np.asarray(_opt_ref(p, None).cost)), p


def test_obs_mode_fanout_matches_standalone():
    """Materialized-telemetry fan-out vs materialized standalone runs —
    like mode against like mode, so it holds on any device count."""
    e = _env()
    fleet_m = FleetBatch.from_scenario(e["grid"], e["sc"], HORIZONS)
    efleet_m = FleetBatch.from_scenario(e["egrid"], e["sc_e"], HORIZONS)
    lanes_m = [AlphaRR.fleet_lane(fleet_m),
               PolicyLane(RetroRenting.fleet(fleet_m),
                          grid=e["egrid"],
                          svc_cols=e["grid"].endpoint_columns()),
               StaticPolicy.fleet(fleet_m, e["grid"].top_index())]
    res = run_fleet(lanes_m, fleet_m, chunk_size=16)
    refs = [run_fleet(AlphaRR.fleet(fleet_m), fleet_m, chunk_size=16),
            run_fleet(RetroRenting.fleet(efleet_m), efleet_m,
                      chunk_size=16),
            run_fleet(StaticPolicy.fleet(fleet_m, e["grid"].top_index()),
                      fleet_m, chunk_size=16)]
    for p, ref in enumerate(refs):
        assert_lane_equals(res, p, ref, "obs")
    if jax.device_count() == 1:
        # cross-mode identity holds on a single device only (the forced
        # multi-device generation path predates this PR, see module doc)
        scen = run_fleet(e["lanes"], e["fleet"], scenario=e["sc"],
                         chunk_size=16)
        for f in FIELDS:
            assert np.array_equal(getattr(res, f), getattr(scen, f)), f


# ----------------------------------------------------------------------
# Hypothesis walk over the driver config space.
# ----------------------------------------------------------------------

@st.composite
def fanout_configs(draw):
    ids = draw(st.permutations([0, 1, 2]))
    ids = ids[:draw(st.integers(1, 3))]
    chunk = draw(st.sampled_from([None, 16, 20]))
    stream = draw(st.sampled_from([False, True]))
    if stream and chunk is None:
        chunk = 16
    n_seeds = draw(st.sampled_from([None, 2]))
    with_opt = draw(st.sampled_from([False, True]))
    return ids, chunk, stream, n_seeds, with_opt


@settings(max_examples=15, deadline=None)
@given(fanout_configs())
def test_fanout_property_walk(cfg):
    ids, chunk, stream, n_seeds, with_opt = cfg
    e = _env()
    res = run_fleet([e["lanes"][i] for i in ids], e["fleet"],
                    scenario=e["sc"], chunk_size=chunk, stream=stream,
                    n_seeds=n_seeds, with_opt_forward=with_opt)
    for p, lane_id in enumerate(ids):
        assert_lane_equals(res, p, _ref(lane_id, n_seeds), str(cfg))
        if with_opt:
            got = res.policy_view(res.opt_cost)[p]
            want = np.asarray(_opt_ref(lane_id, n_seeds).cost)
            assert np.array_equal(got, want), (cfg, lane_id, "opt")


# ----------------------------------------------------------------------
# Stepper readbacks + live scheduler shadow lanes.
# ----------------------------------------------------------------------

def test_stepper_fanout_matches_run_fleet():
    e = _env()
    ref = run_fleet(e["lanes"], e["fleet"], scenario=e["sc"], chunk_size=16,
                    with_opt_forward=True)
    st_ = fleet_stepper(e["lanes"], e["fleet"], scenario=e["sc"],
                        chunk_size=16, with_opt_forward=True)
    parts = []
    while st_.t < T:
        parts.append(st_.step())
    assert all(p.shape[0] == 3 for p in parts)    # [P, B, chunk]
    res = st_.result(tuple(np.concatenate([p[i] for p in parts], axis=1)
                           for i in range(3)))
    for f in FIELDS:
        assert np.array_equal(getattr(res, f), getattr(ref, f)), f
    assert np.array_equal(res.r_hist, ref.r_hist)
    assert np.array_equal(res.opt_cost, ref.opt_cost)
    assert np.array_equal(st_.opt_cost().reshape(-1), ref.opt_cost)
    for p in range(3):
        assert st_.hosting_levels(policy=p).shape == (B,)


def test_scheduler_shadow_lanes_do_not_perturb_admission():
    from repro.serve.scheduler import LiveFleetScheduler
    costs = [HostingCosts.two_level(4.0),
             HostingCosts.three_level(6.0, 0.3, 0.2)]
    plain = LiveFleetScheduler(costs, horizon=64)
    shadow = LiveFleetScheduler(costs, horizon=64,
                                shadow_policies=[RetroRenting],
                                with_opt_forward=True)
    rng = np.random.default_rng(0)
    for _ in range(8):
        x, c = rng.integers(0, 5, size=2), rng.random(2)
        assert np.array_equal(shadow.admit(x, c), plain.admit(x, c))
    rep = shadow.report()
    tot = rep.policy_view(rep.total)
    assert tot.shape == (2, 2)
    oc = shadow.opt_cost()
    assert oc.shape == (2, 2)
    assert np.all(oc <= tot + 1e-9)
    assert shadow.hosting_levels(policy=1).shape == (2,)
    with pytest.raises(ValueError):
        plain.opt_cost()


# ----------------------------------------------------------------------
# Forced multi-device mesh (subprocess — this process may be pinned to
# one device by conftest).
# ----------------------------------------------------------------------

_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    assert jax.device_count() == 4, jax.devices()
    from repro.core import scenarios as S
    from repro.core.costs import HostingCosts, HostingGrid
    from repro.core.fleet import FleetBatch, offline_opt_fleet, run_fleet
    from repro.core.policies import AlphaRR, RetroRenting, StaticPolicy
    from repro.sharding.specs import fleet_mesh

    # B=6 is not a multiple of 4: exercises dummy-instance padding
    costs_list = [HostingCosts.three_level(4.0 + i, 0.3, 0.4)
                  for i in range(5)]
    costs_list.append(HostingCosts.two_level(4.0))
    grid = HostingGrid.from_costs(costs_list)
    B, T = 6, 48

    def scenario(g):
        kx = S.split_keys(jax.random.PRNGKey(13), B)
        return S.combine(
            S.ge_arrivals(kx, 0.3, 0.2, 2.0, 0.2, B),
            S.spot_rents(jax.random.PRNGKey(1), 0.5, B),
            svc=S.model2_service(jax.random.PRNGKey(2), g.g, B,
                                 max_per_slot=6))

    sc = scenario(grid)
    fleet = FleetBatch.for_scenario(grid, T)
    egrid = grid.restrict_to_endpoints()
    sc_e = scenario(egrid)
    efleet = FleetBatch.for_scenario(egrid, T)
    mesh = fleet_mesh()
    lanes = [AlphaRR.fleet_lane(fleet),
             RetroRenting.fleet_lane(fleet, with_svc=True),
             StaticPolicy.fleet(fleet, grid.top_index())]
    res = run_fleet(lanes, fleet, scenario=sc, mesh=mesh, chunk_size=16,
                    n_seeds=2, with_opt_forward=True)
    refs = [run_fleet(AlphaRR.fleet(fleet), fleet, scenario=sc, mesh=mesh,
                      chunk_size=16, n_seeds=2),
            run_fleet(RetroRenting.fleet(efleet), efleet, scenario=sc_e,
                      mesh=mesh, chunk_size=16, n_seeds=2),
            run_fleet(StaticPolicy.fleet(fleet, grid.top_index()), fleet,
                      scenario=sc, mesh=mesh, chunk_size=16, n_seeds=2)]
    for f in ("total", "rent", "service", "fetch", "r_hist"):
        pv = res.policy_view(getattr(res, f))
        for p, ref in enumerate(refs):
            assert np.array_equal(pv[p], np.asarray(getattr(ref, f))), (f, p)
    opt = res.policy_view(res.opt_cost)
    off = offline_opt_fleet(fleet, scenario=sc, mesh=mesh, n_seeds=2,
                            checkpointed=True, collect_schedule=False)
    off_e = offline_opt_fleet(efleet, scenario=sc_e, mesh=mesh, n_seeds=2,
                              checkpointed=True, collect_schedule=False)
    assert np.array_equal(opt[0], np.asarray(off.cost))
    assert np.array_equal(opt[1], np.asarray(off_e.cost))
    assert np.array_equal(opt[2], np.asarray(off.cost))
    print("FANOUT-MULTI-DEVICE-OK")
""")


def test_fanout_multi_device_bitwise():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(TESTS_DIR, "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "FANOUT-MULTI-DEVICE-OK" in out.stdout


# ----------------------------------------------------------------------
# 2-process local cluster: each worker's shard rows == the single-process
# global run (same convention as tests/test_multihost.py).
# ----------------------------------------------------------------------

_CLUSTER_SCRIPT = textwrap.dedent("""
    import os, sys
    sys.path.insert(0, {tests_dir!r})
    import numpy as np
    from repro.sharding import distributed
    distributed.initialize()
    import jax
    import multihost_worker as W
    from repro.core.fleet import run_fleet
    from repro.core.policies import AlphaRR, RetroRenting
    from repro.sharding.specs import fleet_mesh

    pid, nprocs = jax.process_index(), jax.process_count()
    lo = pid * (W.B_GLOBAL // nprocs)
    hi = lo + W.B_GLOBAL // nprocs
    fleet, sc = W.build_scenario_fleet(lo, hi)
    lanes = [AlphaRR.fleet_lane(fleet), RetroRenting.fleet_lane(fleet)]
    mesh = fleet_mesh()
    kw = dict(scenario=sc, mesh=mesh, chunk_size=8, n_seeds=2,
              with_opt_forward=True)
    res = run_fleet(lanes, fleet, **kw)
    gres = run_fleet(lanes, fleet, gather=True, **kw)
    np.savez(os.path.join({outdir!r}, f"fanout_{{pid}}.npz"),
             total=np.asarray(res.policy_view(res.total)),
             rhist=np.asarray(res.policy_view(res.r_hist)),
             opt=np.asarray(res.policy_view(res.opt_cost)),
             g_total=np.asarray(gres.policy_view(gres.total)),
             meta=np.asarray([pid, nprocs, lo, hi]))
    distributed.shutdown()
""")


def test_fanout_two_process_bit_identity(tmp_path):
    from repro.sharding import distributed
    import multihost_worker as W

    n_procs = distributed.default_num_processes(2)
    devices = int(os.environ.get("REPRO_MULTIHOST_DEVICES", "1"))
    distributed.run_local_cluster(
        ["-c", _CLUSTER_SCRIPT.format(tests_dir=TESTS_DIR,
                                      outdir=str(tmp_path))],
        n_processes=n_procs, devices_per_process=devices, timeout=900.0)

    fleet, sc = W.build_scenario_fleet(0, W.B_GLOBAL)
    lanes = [AlphaRR.fleet_lane(fleet), RetroRenting.fleet_lane(fleet)]
    ref = run_fleet(lanes, fleet, scenario=sc, chunk_size=8, n_seeds=2,
                    with_opt_forward=True)
    r_tot = np.asarray(ref.policy_view(ref.total))
    r_rh = np.asarray(ref.policy_view(ref.r_hist))
    r_opt = np.asarray(ref.policy_view(ref.opt_cost))
    for pid in range(n_procs):
        with np.load(tmp_path / f"fanout_{pid}.npz") as z:
            lo, hi = int(z["meta"][2]), int(z["meta"][3])
            sl = slice(lo * 2, hi * 2)       # n_seeds=2: seed-major blocks
            assert np.array_equal(z["total"], r_tot[:, sl]), pid
            assert np.array_equal(z["rhist"], r_rh[:, sl]), pid
            assert np.array_equal(z["opt"], r_opt[:, sl]), pid
            # gather=True: every process sees the full global fan-out
            assert np.array_equal(z["g_total"], r_tot), pid
