"""core/rentcosts.py: shapes/dtypes, determinism given a key, Assumption-3
bound clipping, negative association of the antithetic construction, and the
Hannan-Rissanen fitter's round-trip sanity."""
import jax
import numpy as np
import pytest

from repro.core import rentcosts
from repro.core.rentcosts import (ARMAProcess, aws_spot_like, constant,
                                  fit_arma, iid_uniform,
                                  negatively_associated)

T = 4000
KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("sample", [
    lambda k: ARMAProcess(mean=0.5).sample(k, T),
    lambda k: aws_spot_like(k, 0.35, T),
    lambda k: iid_uniform(k, 0.5, 0.2, T),
    lambda k: negatively_associated(k, 0.5, 0.2, T),
], ids=["arma", "aws-spot", "iid-uniform", "neg-assoc"])
def test_shape_dtype_determinism(sample):
    c1 = np.asarray(sample(KEY))
    c2 = np.asarray(sample(KEY))
    c3 = np.asarray(sample(jax.random.PRNGKey(8)))
    assert c1.shape == (T,)
    assert np.issubdtype(c1.dtype, np.floating)
    assert np.all(np.isfinite(c1))
    assert np.array_equal(c1, c2), "same key must give the same trace"
    assert not np.array_equal(c1, c3), "different keys must differ"


def test_arma_respects_assumption3_bounds():
    proc = ARMAProcess(mean=0.5, sigma=2.0, c_min=0.1, c_max=1.0)
    c = np.asarray(proc.sample(KEY, 20000))
    assert c.min() >= 0.1 - 1e-6
    assert c.max() <= 1.0 + 1e-6
    # a huge sigma must actually hit both clip rails
    assert np.any(c <= 0.1 + 1e-6) and np.any(c >= 1.0 - 1e-6)


def test_arma_mean_reversion():
    c = np.asarray(aws_spot_like(KEY, 0.35, 50000))
    assert abs(c.mean() - 0.35) < 0.05
    # slow mean reversion: positively autocorrelated at lag 1
    cc = c - c.mean()
    rho1 = np.mean(cc[1:] * cc[:-1]) / c.var()
    assert rho1 > 0.3


def test_iid_uniform_bounds_and_mean():
    c = np.asarray(iid_uniform(KEY, 0.5, 0.2, 20000))
    assert c.min() >= 0.3 - 1e-6 and c.max() <= 0.7 + 1e-6
    assert abs(c.mean() - 0.5) < 0.01


def test_negatively_associated_pairs():
    """Antithetic pairs (U, 1-U): consecutive pair members must be perfectly
    anticorrelated and each uniform on the band."""
    c = np.asarray(negatively_associated(KEY, 0.5, 0.2, 20000))
    assert c.min() >= 0.3 - 1e-6 and c.max() <= 0.7 + 1e-6
    u, v = c[0::2], c[1::2]
    assert np.allclose(u + v, 1.0, atol=1e-6)        # v = 1 - u mapped to band
    corr = np.corrcoef(u, v)[0, 1]
    assert corr < -0.999


def test_constant():
    c = np.asarray(constant(0.35, 100))
    assert c.shape == (100,) and np.all(c == np.float32(0.35))


def test_fit_arma_roundtrip():
    """Hannan-Rissanen on a long synthetic series recovers a process with
    the right mean and bounds, and its samples stay inside them."""
    series = np.asarray(aws_spot_like(KEY, 0.5, 8000))
    proc = fit_arma(series, p=4, q=2)
    assert isinstance(proc, ARMAProcess)
    assert abs(proc.mean - float(series.mean())) < 1e-6
    assert len(proc.ar) == 4 and len(proc.ma) == 2
    assert proc.sigma > 0
    c = np.asarray(proc.sample(jax.random.PRNGKey(1), 2000))
    assert c.min() >= proc.c_min - 1e-6 and c.max() <= proc.c_max + 1e-6
    assert abs(c.mean() - proc.mean) < 0.1
