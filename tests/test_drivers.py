"""CLI drivers (launch/train.py, launch/serve.py) smoke tests (subprocess,
tiny configs)."""
import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    return out.stdout


def test_train_driver_tiny_with_resume(tmp_path):
    out = _run(["repro.launch.train", "--arch", "llama3.2-3b", "--tiny",
                "--steps", "6", "--batch", "4", "--seq", "16",
                "--ckpt-dir", str(tmp_path), "--save-every", "3"])
    assert "done" in out and "loss=" in out
    out2 = _run(["repro.launch.train", "--arch", "llama3.2-3b", "--tiny",
                 "--steps", "8", "--batch", "4", "--seq", "16",
                 "--ckpt-dir", str(tmp_path), "--save-every", "3"])
    assert "resumed at step 6" in out2


def test_serve_driver_tiny():
    out = _run(["repro.launch.serve", "--arch", "stablelm-1.6b",
                "--slots", "40", "--M", "10"])
    assert "plan=layer_prefix" in out and "cost=" in out
