"""Unit tests for the cost model (paper §2.6)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.costs import (HostingCosts, fetch_cost, retro_fetch_cost,
                              per_slot_cost_matrix, service_cost_model2_coupled)


def test_three_level_contract():
    c = HostingCosts.three_level(M=10, alpha=0.4, g_alpha=0.5)
    assert c.K == 3 and c.alpha == 0.4 and c.g_alpha == 0.5
    assert c.partial_is_useful()  # 0.4 + 0.5 < 1


def test_invalid_instances_rejected():
    with pytest.raises(ValueError):
        HostingCosts(M=10, levels=(0.0, 0.5), g=(1.0, 0.5))  # last level != 1
    with pytest.raises(ValueError):
        HostingCosts(M=10, levels=(0.0, 0.6, 0.5, 1.0), g=(1.0, 0.5, 0.4, 0.0))
    with pytest.raises(ValueError):
        HostingCosts(M=10, levels=(0.0, 0.5, 1.0), g=(1.0, 1.1, 0.0))  # g increases


def test_fetch_cost_only_on_increment():
    lv = jnp.asarray([0.0, 0.4, 1.0])
    assert float(fetch_cost(lv, jnp.int32(0), jnp.int32(2), 10.0)) == 10.0
    assert float(fetch_cost(lv, jnp.int32(0), jnp.int32(1), 10.0)) == pytest.approx(4.0)
    assert float(fetch_cost(lv, jnp.int32(1), jnp.int32(2), 10.0)) == pytest.approx(6.0)
    assert float(fetch_cost(lv, jnp.int32(2), jnp.int32(0), 10.0)) == 0.0  # eviction free


def test_retro_fetch_uses_absolute_value():
    lv = jnp.asarray([0.0, 0.4, 1.0])
    v = retro_fetch_cost(lv, jnp.int32(2), 10.0)
    assert np.allclose(np.asarray(v), [10.0, 6.0, 0.0])


def test_per_slot_cost_matrix_model1():
    costs = HostingCosts.three_level(M=10, alpha=0.4, g_alpha=0.5)
    x = jnp.asarray([0, 1, 2])
    c = jnp.asarray([0.5, 0.5, 1.0])
    w = np.asarray(per_slot_cost_matrix(costs, x, c))
    # slot 2 (x=1, c=0.5): levels (0, .4, 1) -> rent (0,.2,.5) + svc (1,.5,0)
    assert np.allclose(w[1], [1.0, 0.7, 0.5])
    # slot 3 (x=2, c=1): rent (0,.4,1) + svc (2,1,0)
    assert np.allclose(w[2], [2.0, 1.4, 1.0])


def test_model2_coupling_monotone():
    g = jnp.asarray([1.0, 0.5, 0.0])
    u = jnp.asarray([0.1, 0.6, 0.9, 0.4])
    svc = np.asarray(service_cost_model2_coupled(g, u, jnp.int32(3)))
    # only first 3 requests live; at level0 all forwarded; higher levels serve more
    assert svc[0] == 3.0 and svc[2] == 0.0
    assert svc[0] >= svc[1] >= svc[2]
    # u=0.1 < 0.5 forwarded at level alpha; u=0.6,0.9 not (0.9 is not live)
    assert svc[1] == 1.0
