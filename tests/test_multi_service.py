"""Multi-service engine suite, per the PR-10 acceptance bar:

* **N=1 bitwise identity** — every ``core.services`` entry point
  (``run_fleet_services``, both ``offline_opt_fleet`` passes via
  ``offline_opt_services`` / ``offline_opt_per_service``,
  ``evaluate_schedule_services``, ``fleet_stepper_services``) collapses to
  its single-service counterpart bit for bit (``np.array_equal``, never
  allclose) across chunked / streamed / stepper drivers, ``n_seeds``
  replication, and policy fan-out lanes.
* **Joint DP == oracle** — the capacity-respecting joint DP (fixed cases +
  a hypothesis walk over N x K x capacity configs) equals the brute-force
  ``J**T`` enumeration with EXACT float equality (both accumulate float32
  with the same association), and the fleet-engine path through the
  matrix-M grid equals the standalone ``offline_opt_joint`` helper.
* **Capacity boundaries** — level sums exactly AT capacity are feasible
  (including float-noise sums like 1/3 + 2/3, absorbed by
  ``CAPACITY_EPS``), just-over sums are excluded, and
  ``capacity_overflow`` separates oblivious lanes from the joint OPT.
* **Trace playback** — recorded per-service traces through the fused
  engine equal the numpy-side joint helper on the same arrays.
* **Forced 4 devices / 2 processes** — the same N=1 and joint-DP
  equalities on a forced-4-device mesh (subprocess) and on a 2-process
  local cluster (each worker's shard rows == the single-process global
  run).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from repro.core import scenarios as S
from repro.core import services as SV
from repro.core.costs import (CAPACITY_EPS, HostingCosts, HostingGrid,
                              ServiceSet)
from repro.core.fleet import (FleetBatch, evaluate_schedule_fleet,
                              fleet_stepper, offline_opt_fleet, run_fleet)
from repro.core.policies import AlphaRR, RetroRenting
from repro.core.policies.offline_opt import (brute_force_joint_opt,
                                             offline_opt_joint)
from repro.core.scenarios.base import materialize

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))

T = 48
B = 3
HORIZONS = [48, 40, 48]
FIELDS = ["total", "rent", "service", "fetch"]

COSTS = [HostingCosts.two_level(4.0),
         HostingCosts.three_level(6.0, 0.3, 0.2),
         HostingCosts(M=10.0, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                      g=(1.0, 0.4, 0.3, 0.15, 0.0))]


def _scenario(grid, B_rows, seed=42):
    return S.combine(
        S.ge_arrivals(S.split_keys(jax.random.PRNGKey(seed), B_rows),
                      0.3, 0.2, 2.0, 0.2, B_rows),
        S.spot_rents(jax.random.PRNGKey(seed + 1), 0.5, B_rows),
        svc=S.model2_service(jax.random.PRNGKey(seed + 2), grid.g, B_rows,
                             max_per_slot=6))


_ENV = {}


def _env():
    """Shared single-service reference + its N=1 ServiceFleet wrapping, and
    an N=2 mixed-K capacity-constrained fleet (module memo, not a fixture —
    the hypothesis shim erases signatures)."""
    if _ENV:
        return _ENV
    grid = HostingGrid.from_costs(COSTS)
    fleet = FleetBatch.for_scenario(grid, HORIZONS)
    sf1 = SV.service_fleet([ServiceSet(services=(cc,)) for cc in COSTS],
                           HORIZONS)
    # N=2: per-instance pairs under a shared unit capacity
    sets2 = [ServiceSet(services=(COSTS[0], COSTS[1]), capacity=1.0),
             ServiceSet(services=(COSTS[1], COSTS[2]), capacity=1.0)]
    sf2 = SV.service_fleet(sets2, 32)
    _ENV.update(grid=grid, fleet=fleet, sc=_scenario(grid, B),
                sf1=sf1, sf2=sf2,
                sc2=_scenario(sf2.lane_grid(), sf2.B * sf2.N, seed=11))
    return _ENV


def _assert_fields_equal(got, ref, label=""):
    for f in FIELDS:
        assert np.array_equal(np.asarray(getattr(got, f)),
                              np.asarray(getattr(ref, f))), (label, f)
    assert np.array_equal(np.asarray(got.r_hist),
                          np.asarray(ref.r_hist)), (label, "r_hist")


# ----------------------------------------------------------------------
# N=1 bitwise identity, per driver.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("chunk,stream", [(None, False), (16, False),
                                          (20, False), (16, True)])
def test_n1_lane_identity(chunk, stream):
    e = _env()
    ref = run_fleet(AlphaRR.fleet(e["fleet"]), e["fleet"], scenario=e["sc"],
                    chunk_size=chunk, stream=stream)
    got = SV.run_fleet_services(SV.alpha_rr_per_service(e["sf1"]), e["sf1"],
                                scenario=e["sc"], chunk_size=chunk,
                                stream=stream)
    _assert_fields_equal(got.fleet, ref, f"chunk={chunk} stream={stream}")
    assert got.total.shape == (1, B, 1, 1)
    assert np.array_equal(got.edge_total[0, :, 0],
                          np.asarray(ref.total))


def test_n1_lane_identity_n_seeds():
    e = _env()
    ref = run_fleet(AlphaRR.fleet(e["fleet"]), e["fleet"], scenario=e["sc"],
                    chunk_size=16, n_seeds=2)
    got = SV.run_fleet_services(SV.alpha_rr_per_service(e["sf1"]), e["sf1"],
                                scenario=e["sc"], chunk_size=16, n_seeds=2)
    _assert_fields_equal(got.fleet, ref, "n_seeds=2")
    assert got.total.shape == (1, B, 1, 2)


def test_n1_fanout_lanes_identity():
    """Policy fan-out composes with the service axis: each lane of a
    heterogeneous fan-out on the N=1 lane fleet equals its standalone
    single-service dispatch."""
    e = _env()
    lf = e["sf1"].lane_fleet()
    lanes = [AlphaRR.fleet_lane(lf), RetroRenting.fleet_lane(lf,
                                                            with_svc=True)]
    got = SV.run_fleet_services(lanes, e["sf1"], scenario=e["sc"],
                                chunk_size=16)
    egrid = e["grid"].restrict_to_endpoints()
    efleet = FleetBatch.for_scenario(egrid, HORIZONS)
    refs = [run_fleet(AlphaRR.fleet(e["fleet"]), e["fleet"],
                      scenario=e["sc"], chunk_size=16),
            run_fleet(RetroRenting.fleet(efleet), efleet,
                      scenario=_scenario(egrid, B), chunk_size=16)]
    for p, ref in enumerate(refs):
        for f in FIELDS:
            assert np.array_equal(
                got.fleet.policy_view(getattr(got.fleet, f))[p],
                np.asarray(getattr(ref, f))), (p, f)
        assert np.array_equal(got.fleet.policy_view(got.fleet.r_hist)[p],
                              np.asarray(ref.r_hist)), p
    assert got.total.shape == (2, B, 1, 1)


@pytest.mark.parametrize("checkpointed,stream,n_seeds",
                         [(False, False, None), (True, False, None),
                          (True, True, 2)])
def test_n1_offline_opt_identity(checkpointed, stream, n_seeds):
    e = _env()
    kw = dict(scenario=e["sc"], chunk_size=16, checkpointed=checkpointed,
              stream=stream, n_seeds=n_seeds)
    ref = offline_opt_fleet(e["fleet"], **kw)
    got = SV.offline_opt_services(e["sf1"], **kw)
    assert np.array_equal(np.asarray(got.cost), np.asarray(ref.cost))
    assert np.array_equal(got.service_schedules()[:, 0, :],
                          np.asarray(ref.r_hist))
    # per-service (capacity-oblivious) OPT is the same run at N=1
    lane = SV.offline_opt_per_service(e["sf1"], **kw)
    assert np.array_equal(np.asarray(lane.cost), np.asarray(ref.cost))


def test_n1_schedule_eval_identity():
    e = _env()
    opt = offline_opt_fleet(e["fleet"], scenario=e["sc"], chunk_size=16)
    r = np.asarray(opt.r_hist)
    ref = evaluate_schedule_fleet(e["fleet"], r, scenario=e["sc"],
                                  chunk_size=16)
    # exercise the [B, N, T] entry shape
    got = SV.evaluate_schedule_services(e["sf1"], r[:, None, :],
                                        scenario=e["sc"], chunk_size=16)
    _assert_fields_equal(got.fleet, ref, "schedule-eval")


def test_n1_stepper_identity():
    e = _env()
    ref = SV.run_fleet_services(SV.alpha_rr_per_service(e["sf1"]), e["sf1"],
                                scenario=e["sc"], chunk_size=16)
    stp = SV.fleet_stepper_services(SV.alpha_rr_per_service(e["sf1"]),
                                    e["sf1"], scenario=e["sc"],
                                    chunk_size=16)
    parts = []
    while stp.t < T:
        parts.append(stp.step())
    _assert_fields_equal(stp.result(np.concatenate(parts, axis=1)),
                         ref.fleet, "stepper")


def test_n1_tile_services_is_identity():
    e = _env()
    assert SV.service_scenario(e["sf1"], e["sc"]) is e["sc"]
    assert S.tile_services(e["sc"], 1) is e["sc"]


# ----------------------------------------------------------------------
# Joint DP vs brute-force oracle (exact float equality).
# ----------------------------------------------------------------------

def _oracle_case(sset, T_len, seed):
    rng = np.random.default_rng(seed)
    xs = rng.integers(0, 4, (sset.N, T_len))
    c = (rng.integers(1, 16, T_len) / 8.0).astype(np.float32)
    return xs, c


@pytest.mark.parametrize("sset,T_len", [
    # N=2 mixed-K under unit capacity
    (ServiceSet((COSTS[0], COSTS[1]), capacity=1.0), 4),
    # N=3 two-level services, capacity admits at most one hosted
    (ServiceSet((HostingCosts.two_level(2.0),
                 HostingCosts.two_level(3.0),
                 HostingCosts.two_level(2.5)), capacity=1.0), 3),
    # N=2 unconstrained (capacity None -> N): reduces to independent DPs
    (ServiceSet((COSTS[1], COSTS[1])), 4),
])
def test_joint_dp_matches_oracle(sset, T_len):
    xs, c = _oracle_case(sset, T_len, seed=5)
    got = offline_opt_joint(sset, xs, c)
    want = brute_force_joint_opt(sset, xs, c)
    assert float(got.cost) == float(want.cost)          # EXACT, no tolerance
    assert np.array_equal(got.r_hist, want.r_hist)
    # every slot of the optimal schedule is feasible by construction
    lv = [np.asarray(cc.levels, np.float64) for cc in sset.services]
    tot = sum(lv[n][got.r_hist[n]] for n in range(sset.N))
    assert np.all(tot <= sset.cap + CAPACITY_EPS)


def test_joint_fleet_path_matches_helper():
    """The fleet-engine path (matrix-M grid through ``offline_opt_fleet``)
    equals the standalone joint helper and the oracle on the same
    materialized observations, across DP driver configs."""
    T2 = 5
    ss = ServiceSet((HostingCosts.three_level(3.0, 0.5, 0.4),
                     HostingCosts.two_level(2.5)), capacity=1.0)
    sf = SV.service_fleet([ss], T2)
    sc = _scenario(sf.lane_grid(), 2, seed=3)
    res = SV.offline_opt_services(sf, scenario=sc)
    ck = SV.offline_opt_services(sf, scenario=sc, checkpointed=True,
                                 stream=True, chunk_size=2)
    x, c, svc, _ = materialize(sc, T2, chunk_size=T2)
    svcs = [svc[n][:, :ss.services[n].K] for n in range(2)]
    ref = offline_opt_joint(ss, x[:2], c[0], svcs=svcs)
    oracle = brute_force_joint_opt(ss, x[:2], c[0], svcs=svcs)
    assert float(np.asarray(res.cost)[0]) == float(ref.cost) \
        == float(oracle.cost)
    assert np.array_equal(res.service_schedules()[0], ref.r_hist)
    assert np.array_equal(np.asarray(ck.cost), np.asarray(res.cost))
    assert np.array_equal(ck.joint.r_hist, res.joint.r_hist)
    assert np.all(SV.capacity_overflow(sf, res.service_schedules()[0][None])
                  == 0.0)


@st.composite
def joint_configs(draw):
    N = draw(st.integers(1, 2))
    Ks = [draw(st.sampled_from([2, 3])) for _ in range(N)]
    cap = draw(st.sampled_from([None, 1.0, 0.75]))
    services = []
    for K in Ks:
        M = draw(st.integers(2, 8))
        if K == 2:
            services.append(HostingCosts.two_level(float(M)))
        else:
            alpha = draw(st.sampled_from([0.25, 0.5, 0.75]))
            g_a = draw(st.sampled_from([0.1, 0.4, 0.6]))
            services.append(HostingCosts.three_level(float(M), alpha, g_a))
    seed = draw(st.integers(0, 10 ** 6))
    return services, cap, seed


@settings(max_examples=8, deadline=None)
@given(joint_configs())
def test_joint_dp_oracle_walk(cfg):
    services, cap, seed = cfg
    try:
        sset = ServiceSet(tuple(services), capacity=cap)
    except ValueError:
        assert cap is not None        # only the all-off-infeasible reject
        return
    xs, c = _oracle_case(sset, 3, seed)
    got = offline_opt_joint(sset, xs, c)
    want = brute_force_joint_opt(sset, xs, c)
    assert float(got.cost) == float(want.cost), cfg
    assert np.array_equal(got.r_hist, want.r_hist), cfg


# ----------------------------------------------------------------------
# Capacity boundaries.
# ----------------------------------------------------------------------

def test_capacity_boundary_exact_and_just_over():
    svc3 = HostingCosts.three_level(2.0, 0.5, 0.4)
    at = ServiceSet((svc3, svc3), capacity=1.0)
    states = {tuple(s) for s in at.joint_states()}
    assert (1, 1) in states            # 0.5 + 0.5 == capacity: feasible
    assert (2, 1) not in states        # 1.0 + 0.5: over
    just_under = ServiceSet((svc3, svc3), capacity=0.99)
    assert (1, 1) not in {tuple(s) for s in just_under.joint_states()}
    assert at.J == len(states) == 6    # (0,0)(0,1)(0,2)(1,0)(1,1)(2,0)


def test_capacity_eps_absorbs_float_noise():
    # 0.1 + 0.2 lands one ulp above 0.3 in float64; CAPACITY_EPS keeps the
    # exactly-at-capacity combination feasible
    assert 0.1 + 0.2 > 0.3
    svcs = (HostingCosts.three_level(2.0, 0.1, 0.3),
            HostingCosts.three_level(2.0, 0.2, 0.3))
    states = {tuple(s) for s in
              ServiceSet(svcs, capacity=0.3).joint_states()}
    assert (1, 1) in states
    assert (2, 0) not in states        # 1.0 really is over capacity


def test_all_off_must_be_feasible():
    with pytest.raises(ValueError):
        ServiceSet((COSTS[0],), capacity=-1.0)


def test_capacity_overflow_flags_oblivious_lanes():
    """Independent lanes under heavy arrivals both host fully; the
    diagnostic reports the excess while the joint OPT never exceeds."""
    two = HostingCosts.two_level(2.0, c_min=0.05, c_max=0.1)
    sf = SV.service_fleet([ServiceSet((two, two), capacity=1.0)], 24)
    BN = 2
    sc = S.combine(
        S.bernoulli_arrivals(S.split_keys(jax.random.PRNGKey(0), BN),
                             0.95, BN),
        S.constant_rents(0.05, BN))
    res = SV.run_fleet_services(SV.alpha_rr_per_service(sf), sf, scenario=sc)
    r = res.service_view(res.fleet.r_hist)[0, :, :, 0]      # [B, N, T]
    ov = SV.capacity_overflow(sf, r)
    assert ov.max() > 0.0              # both lanes host 1.0 simultaneously
    opt = SV.offline_opt_services(sf, scenario=sc)
    assert np.all(SV.capacity_overflow(sf, opt.service_schedules()[0][None])
                  == 0.0)
    # relaxation bound: oblivious per-service OPT <= joint OPT
    lane = SV.offline_opt_per_service(sf, scenario=sc)
    assert np.asarray(lane.cost).sum() <= float(np.asarray(opt.cost)[0]) \
        + 1e-6


# ----------------------------------------------------------------------
# Shared-rent tiling + trace playback.
# ----------------------------------------------------------------------

def test_tile_services_shared_rent():
    e = _env()
    tiled = S.tile_services(e["sc"], 2)
    x, c, svc, _ = materialize(tiled, T, chunk_size=16)
    for b in range(B):
        # one edge, one spot price: both service rows carry the SAME rents
        assert np.array_equal(c[2 * b], c[2 * b + 1]), b
    # arrivals are salted per service: some instance must differ
    assert any(not np.array_equal(x[2 * b], x[2 * b + 1]) for b in range(B))
    # service row n is bitwise a standalone fold_in(key, n) scenario row
    x1, c1, _, _ = materialize(e["sc"], T, chunk_size=16)
    assert np.array_equal(c[0::2], c1)


def test_trace_playback_multi_service():
    rng = np.random.default_rng(9)
    T2, N = 8, 2
    ss = ServiceSet((COSTS[0], COSTS[1]), capacity=1.0)
    sf = SV.service_fleet([ss], T2)
    xs = rng.integers(0, 4, (N, T2))
    c = (rng.integers(1, 16, T2) / 8.0).astype(np.float32)
    sc = S.trace_scenario(xs.astype(np.int32),
                          np.broadcast_to(c, (N, T2)).copy())
    res = SV.offline_opt_services(sf, scenario=sc)
    ref = offline_opt_joint(ss, xs, c)          # Model-1 g * x pricing
    oracle = brute_force_joint_opt(ss, xs, c)
    assert float(np.asarray(res.cost)[0]) == float(ref.cost) \
        == float(oracle.cost)
    assert np.array_equal(res.service_schedules()[0], ref.r_hist)
    # the online lanes also play the traces back deterministically
    on = SV.run_fleet_services(SV.alpha_rr_per_service(sf), sf, scenario=sc)
    assert np.asarray(on.fleet.total).sum() \
        >= float(np.asarray(res.cost)[0])       # OPT is a lower bound


def test_alpha_rr_rejects_joint_grid():
    e = _env()
    with pytest.raises(ValueError, match="fleet lane"):
        AlphaRR.fleet(e["sf2"].joint_fleet())


def test_n2_lane_driver_invariance():
    """N=2 lanes: chunked == streamed == stepper, and n_seeds rows are
    bitwise standalone replicas (engine guarantees surviving the tiling)."""
    e = _env()
    pol = SV.alpha_rr_per_service(e["sf2"])
    a = SV.run_fleet_services(pol, e["sf2"], scenario=e["sc2"],
                              chunk_size=16)
    b = SV.run_fleet_services(pol, e["sf2"], scenario=e["sc2"],
                              chunk_size=12, stream=True)
    _assert_fields_equal(b.fleet, a.fleet, "n2 chunk/stream")
    stp = SV.fleet_stepper_services(pol, e["sf2"], scenario=e["sc2"],
                                    chunk_size=16)
    parts = []
    while stp.t < 32:
        parts.append(stp.step())
    _assert_fields_equal(stp.result(np.concatenate(parts, axis=1)),
                         a.fleet, "n2 stepper")


# ----------------------------------------------------------------------
# Forced 4 devices (subprocess) + 2-process local cluster.
# ----------------------------------------------------------------------

_MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    assert jax.device_count() == 4, jax.devices()
    from repro.core import scenarios as S
    from repro.core import services as SV
    from repro.core.costs import HostingCosts, HostingGrid, ServiceSet
    from repro.core.fleet import FleetBatch, offline_opt_fleet, run_fleet
    from repro.core.policies import AlphaRR
    from repro.sharding.specs import fleet_mesh

    COSTS = [HostingCosts.two_level(4.0),
             HostingCosts.three_level(6.0, 0.3, 0.2),
             HostingCosts(M=10.0, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                          g=(1.0, 0.4, 0.3, 0.15, 0.0))]

    def scn(grid, Bn, seed=42):
        return S.combine(
            S.ge_arrivals(S.split_keys(jax.random.PRNGKey(seed), Bn),
                          0.3, 0.2, 2.0, 0.2, Bn),
            S.spot_rents(jax.random.PRNGKey(seed + 1), 0.5, Bn),
            svc=S.model2_service(jax.random.PRNGKey(seed + 2), grid.g, Bn,
                                 max_per_slot=6))

    mesh = fleet_mesh()
    # N=1 lane identity on the mesh (B=3 lanes: exercises padding to 4)
    grid = HostingGrid.from_costs(COSTS)
    fleet = FleetBatch.for_scenario(grid, [48, 40, 48])
    sf1 = SV.service_fleet([ServiceSet((cc,)) for cc in COSTS],
                           [48, 40, 48])
    sc = scn(grid, 3)
    ref = run_fleet(AlphaRR.fleet(fleet), fleet, scenario=sc, mesh=mesh,
                    chunk_size=16, n_seeds=2)
    got = SV.run_fleet_services(SV.alpha_rr_per_service(sf1), sf1,
                                scenario=sc, mesh=mesh, chunk_size=16,
                                n_seeds=2)
    for f in ("total", "rent", "service", "fetch", "r_hist"):
        assert np.array_equal(np.asarray(getattr(got.fleet, f)),
                              np.asarray(getattr(ref, f))), f
    oref = offline_opt_fleet(fleet, scenario=sc, mesh=mesh, chunk_size=16)
    ogot = SV.offline_opt_services(sf1, scenario=sc, mesh=mesh,
                                   chunk_size=16)
    assert np.array_equal(np.asarray(ogot.cost), np.asarray(oref.cost))
    assert np.array_equal(ogot.service_schedules()[:, 0, :],
                          np.asarray(oref.r_hist))

    # N=2 joint DP on the mesh == unsharded (B=2 joint instances pad to 4;
    # the 4 lanes divide the mesh exactly)
    sets2 = [ServiceSet((COSTS[0], COSTS[1]), capacity=1.0),
             ServiceSet((COSTS[1], COSTS[2]), capacity=1.0)]
    sf2 = SV.service_fleet(sets2, 32)
    sc2 = scn(sf2.lane_grid(), 4, seed=11)
    j_mesh = SV.offline_opt_services(sf2, scenario=sc2, mesh=mesh,
                                     chunk_size=16)
    j_ref = SV.offline_opt_services(sf2, scenario=sc2, chunk_size=16)
    assert np.array_equal(np.asarray(j_mesh.cost), np.asarray(j_ref.cost))
    assert np.array_equal(j_mesh.joint.r_hist, j_ref.joint.r_hist)
    lanes_mesh = SV.run_fleet_services(SV.alpha_rr_per_service(sf2), sf2,
                                       scenario=sc2, mesh=mesh,
                                       chunk_size=16)
    lanes_ref = SV.run_fleet_services(SV.alpha_rr_per_service(sf2), sf2,
                                      scenario=sc2, chunk_size=16)
    assert np.array_equal(np.asarray(lanes_mesh.fleet.total),
                          np.asarray(lanes_ref.fleet.total))
    assert np.array_equal(np.asarray(lanes_mesh.fleet.r_hist),
                          np.asarray(lanes_ref.fleet.r_hist))
    print("MULTI-SERVICE-MULTI-DEVICE-OK")
""")


def test_multi_service_multi_device_bitwise():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(TESTS_DIR, "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _MULTI_DEVICE_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MULTI-SERVICE-MULTI-DEVICE-OK" in out.stdout


# One global-row-keyed builder, exec'd by BOTH the parent (reference) and
# the cluster workers — the multihost convention: explicit per-lane keys
# sliced from one global key set make any shard bitwise the same rows of
# the global build.
_MS_BUILDER = textwrap.dedent("""
    import jax, numpy as np
    from repro.core import scenarios as S
    from repro.core import services as SV
    from repro.core.costs import HostingCosts, ServiceSet

    B_GLOBAL, N_SVC, T_MS = 4, 2, 32

    def _svc_costs(i, n):
        M = [2.0, 4.0, 6.0][(2 * i + n) % 3]
        if (i + n) % 2:
            return HostingCosts.two_level(M)
        return HostingCosts.three_level(M, 0.25 + 0.125 * (i % 3), 0.3)

    def build(lo, hi):
        sets = [ServiceSet(tuple(_svc_costs(i, n) for n in range(N_SVC)),
                           capacity=1.0) for i in range(lo, hi)]
        sf = SV.service_fleet(sets, T_MS)
        Bn = (hi - lo) * N_SVC
        kx = S.split_keys(jax.random.PRNGKey(5),
                          B_GLOBAL * N_SVC)[lo * N_SVC:hi * N_SVC]
        kc = S.split_keys(jax.random.PRNGKey(6),
                          B_GLOBAL * N_SVC)[lo * N_SVC:hi * N_SVC]
        sc = S.combine(S.bernoulli_arrivals(kx, 0.35, Bn),
                       S.spot_rents(kc, 0.5, Bn))
        return sf, sc
""")

_CLUSTER_SCRIPT = textwrap.dedent("""
    import os
    from repro.sharding import distributed
    distributed.initialize()
""") + _MS_BUILDER + textwrap.dedent("""
    import jax
    from repro.sharding.specs import fleet_mesh

    pid, nprocs = jax.process_index(), jax.process_count()
    lo = pid * (B_GLOBAL // nprocs)
    hi = lo + B_GLOBAL // nprocs
    sf, sc = build(lo, hi)
    mesh = fleet_mesh()
    res = SV.run_fleet_services(SV.alpha_rr_per_service(sf), sf,
                                scenario=sc, mesh=mesh, chunk_size=8)
    opt = SV.offline_opt_services(sf, scenario=sc, mesh=mesh, chunk_size=8)
    np.savez(os.path.join({outdir!r}, f"ms_{{pid}}.npz"),
             total=np.asarray(res.fleet.total),
             rhist=np.asarray(res.fleet.r_hist),
             opt_cost=np.asarray(opt.cost),
             opt_sched=opt.service_schedules(),
             meta=np.asarray([pid, nprocs, lo, hi]))
    distributed.shutdown()
""")


def test_multi_service_two_process_bit_identity(tmp_path):
    from repro.sharding import distributed

    n_procs = distributed.default_num_processes(2)
    devices = int(os.environ.get("REPRO_MULTIHOST_DEVICES", "1"))
    distributed.run_local_cluster(
        ["-c", _CLUSTER_SCRIPT.format(outdir=str(tmp_path))],
        n_processes=n_procs, devices_per_process=devices, timeout=900.0)

    ns = {}
    exec(_MS_BUILDER, ns)                       # the same builder, verbatim
    sf, sc = ns["build"](0, ns["B_GLOBAL"])
    ref = SV.run_fleet_services(SV.alpha_rr_per_service(sf), sf,
                                scenario=sc, chunk_size=8)
    opt = SV.offline_opt_services(sf, scenario=sc, chunk_size=8)
    r_tot = np.asarray(ref.fleet.total)
    r_rh = np.asarray(ref.fleet.r_hist)
    r_oc = np.asarray(opt.cost)
    r_os = opt.service_schedules()
    N = ns["N_SVC"]
    for pid in range(n_procs):
        with np.load(tmp_path / f"ms_{pid}.npz") as z:
            lo, hi = int(z["meta"][2]), int(z["meta"][3])
            lane_sl = slice(lo * N, hi * N)
            assert np.array_equal(z["total"], r_tot[lane_sl]), pid
            assert np.array_equal(z["rhist"], r_rh[lane_sl]), pid
            assert np.array_equal(z["opt_cost"], r_oc[lo:hi]), pid
            assert np.array_equal(z["opt_sched"], r_os[lo:hi]), pid
