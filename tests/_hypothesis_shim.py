"""Minimal, dependency-free stand-in for the `hypothesis` API surface this
test suite uses, installed by conftest.py only when the real package is
missing (offline containers).  Deterministic: every test draws from an RNG
seeded by its own name, so runs are reproducible; there is no shrinking.

Covered: given, settings, strategies.{integers, sampled_from, lists, none,
one_of, permutations, composite} and Strategy.map.  If a test starts using more of
hypothesis, extend this shim or add the real dependency
(requirements-dev.txt).
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 50


class Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def map(self, f):
        return Strategy(lambda rng: f(self._draw(rng)))


def integers(min_value, max_value):
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(seq):
    seq = list(seq)
    return Strategy(lambda rng: seq[rng.randrange(len(seq))])


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements._draw(rng) for _ in range(n)]
    return Strategy(draw)


def none():
    return Strategy(lambda rng: None)


def one_of(*strategies):
    return Strategy(
        lambda rng: strategies[rng.randrange(len(strategies))]._draw(rng))


def permutations(seq):
    seq = list(seq)
    def draw(rng):
        out = list(seq)
        rng.shuffle(out)
        return out
    return Strategy(draw)


def composite(fn):
    @functools.wraps(fn)
    def builder(*args, **kwargs):
        def draw_value(rng):
            return fn(lambda strategy: strategy._draw(rng), *args, **kwargs)
        return Strategy(draw_value)
    return builder


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", DEFAULT_MAX_EXAMPLES)
            base = zlib.adler32(fn.__qualname__.encode())
            for i in range(n):
                rng = random.Random(base * 100003 + i)
                vals = [s._draw(rng) for s in strategies]
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} (shimmed hypothesis): "
                        f"{vals!r}") from e
        wrapper.hypothesis_shim = True
        # all params are strategy-provided: hide the inner signature so
        # pytest does not mistake the drawn arguments for fixtures
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def install():
    """Register shim modules as `hypothesis` / `hypothesis.strategies`."""
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "lists", "none", "one_of",
                 "permutations", "composite"):
        setattr(st, name, globals()[name])
    st.Strategy = Strategy
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    hyp.__is_shim__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
