"""Integration: alpha-RR hosting controller driving the serving engine on a
tiny MoE model (the paper's technique end-to-end), plus checkpointable
controller state."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.costs import HostingCosts
from repro.core.hosting_controller import HostingController
from repro.core import rentcosts
from repro.data.pipeline import request_stream
from repro.serve.partial import make_plans
from repro.serve.scheduler import EdgeServingScheduler


def test_partial_plans_moe():
    spec = get_arch("deepseek-moe-16b")
    plans, g_alpha = make_plans(spec, alpha=0.5)
    p = plans[0.5]
    assert p.kind == "expert_subset"
    assert p.expert_mask.sum() == int(np.ceil(0.5 * 64))
    assert 0.0 < g_alpha < 1.0
    # hosting the most popular half of fine-grained experts serves far more
    # than uniform-random half^k would suggest
    assert p.bytes_fraction < 1.0
    full = plans[1.0]
    assert full.g_value == 0.0


def test_partial_plans_dense_prefix():
    spec = get_arch("qwen2.5-14b")
    plans, _ = make_plans(spec, alpha=0.5)
    p = plans[0.5]
    assert p.kind == "layer_prefix" and p.n_segments >= 1


def test_controller_accounting_matches_simulator():
    """HostingController slot accounting == the lax.scan simulator."""
    from repro.core.policies import AlphaRR
    from repro.core.simulator import run_policy
    costs = HostingCosts.three_level(M=6.0, alpha=0.5, g_alpha=0.25,
                                     c_min=0.1, c_max=2.0)
    rng = np.random.default_rng(0)
    x = rng.integers(0, 3, 200)
    c = rng.uniform(0.1, 2.0, 200).astype(np.float32)
    ctrl = HostingController(costs)
    for xt, ct in zip(x, c):
        ctrl.step(int(xt), float(ct))
    sim = run_policy(AlphaRR(costs), costs, x, c)
    # controller charges the final fetch one slot later than the scan; both
    # include identical per-slot rent+service
    assert ctrl.total_cost() == pytest.approx(sim.total, rel=1e-5, abs=0.2)
    np.testing.assert_array_equal(ctrl.level_histogram(), sim.level_slots)


def test_controller_checkpoint_roundtrip():
    costs = HostingCosts.three_level(M=6.0, alpha=0.5, g_alpha=0.25)
    rng = np.random.default_rng(1)
    x = rng.integers(0, 2, 120)
    c = rng.uniform(0.2, 1.5, 120)
    ctrl = HostingController(costs)
    for t in range(60):
        ctrl.step(int(x[t]), float(c[t]))
    sd = ctrl.state_dict()
    ctrl2 = HostingController(costs)
    ctrl2.load_state_dict(sd)
    for t in range(60, 120):
        ctrl.step(int(x[t]), float(c[t]))
        ctrl2.step(int(x[t]), float(c[t]))
    assert ctrl.total_cost() == pytest.approx(ctrl2.total_cost())
    assert ctrl.level_idx == ctrl2.level_idx


@pytest.mark.parametrize("arch_id", ["deepseek-moe-16b", "qwen2.5-14b"])
def test_edge_serving_scheduler_end_to_end(arch_id):
    spec = get_arch(arch_id)
    n = 60
    arrivals = request_stream(0, n, "gilbert", rate_h=3.0, rate_l=0.2,
                              p_hl=0.3, p_lh=0.3)
    rents = np.asarray(rentcosts.aws_spot_like(jax.random.PRNGKey(1), 1.0, n))
    sched = EdgeServingScheduler(spec, M=8.0, seed=0)
    rep = sched.run(arrivals, rents)
    assert rep.n_slots == n
    assert rep.n_requests == int(np.sum(arrivals))
    assert rep.served_edge + rep.served_partial + rep.forwarded == rep.n_requests
    assert rep.total_cost > 0
    # never-host static upper bound: forwarding everything costs sum(x)
    assert rep.total_cost <= float(np.sum(arrivals)) + sched.costs.M * 3
