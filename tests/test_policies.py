"""Policy correctness: alpha-RR O(1) scan == literal Algorithm 1; the DP
offline optimum == brute force; theorem-level invariants as property tests.

Instances are drawn on a dyadic grid (multiples of 1/8) so float32 scan
arithmetic is exact and trace equality is well-defined.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costs import HostingCosts
from repro.core.policies import (AlphaRR, RetroRenting, alpha_rr_literal,
                                 offline_opt, brute_force_opt, StaticPolicy)
from repro.core.simulator import run_policy, evaluate_schedule, model2_service_matrix
from repro.core import bounds

GRID = 1.0 / 8.0


def dyadic(lo, hi):
    return st.integers(int(lo / GRID), int(hi / GRID)).map(lambda k: k * GRID)


@st.composite
def instances(draw, max_T=40):
    alpha = draw(st.sampled_from([0.25, 0.375, 0.5, 0.625, 0.75]))
    g_alpha = draw(st.sampled_from([0.125, 0.25, 0.375, 0.5, 0.625, 0.75]))
    M = draw(st.sampled_from([1.5, 2.0, 4.0, 8.0, 16.0]))
    T = draw(st.integers(3, max_T))
    x = draw(st.lists(st.integers(0, 1), min_size=T, max_size=T))
    c = draw(st.lists(dyadic(GRID, 2.0), min_size=T, max_size=T))
    cost = HostingCosts.three_level(M=M, alpha=alpha, g_alpha=g_alpha,
                                    c_min=min(c), c_max=max(c))
    return cost, np.asarray(x, np.int64), np.asarray(c, np.float64)


@settings(max_examples=120, deadline=None)
@given(instances())
def test_alpha_rr_scan_matches_literal(inst):
    """The O(1)-per-slot scan formulation is trace-equivalent to the printed
    Algorithm 1."""
    costs, x, c = inst
    r_scan = run_policy(AlphaRR(costs), costs, x, c).r_hist
    r_lit = alpha_rr_literal(costs, x, c)
    assert np.array_equal(r_scan, r_lit), (r_scan.tolist(), r_lit.tolist())


@settings(max_examples=40, deadline=None)
@given(instances(max_T=7))
def test_offline_dp_matches_brute_force(inst):
    costs, x, c = inst
    dp = offline_opt(costs, x, c)
    bf = brute_force_opt(costs, x, c)
    assert dp.cost == pytest.approx(bf.cost, abs=1e-4)
    # the DP schedule must actually achieve its claimed cost
    assert dp.sim.total == pytest.approx(dp.cost, abs=1e-4)


@settings(max_examples=120, deadline=None)
@given(instances())
def test_thm2_competitive_ratio_bound_holds_per_instance(inst):
    """Theorem 2(b): on EVERY instance, C_RR <= bound * C_OPT (+ the final
    speculative fetch alpha-RR may pay at the horizon, which the adversarial
    analysis absorbs into the next frame)."""
    costs, x, c = inst
    rr = run_policy(AlphaRR(costs), costs, x, c, include_final_fetch=False)
    opt = offline_opt(costs, x, c)
    bound = bounds.thm2_ratio_upper(costs)
    if opt.cost <= 1e-9:
        assert rr.total <= 1e-9 + costs.M  # degenerate: nothing to do
        return
    assert rr.total <= bound * opt.cost + 1e-4, (
        rr.total, opt.cost, bound, x.tolist(), c.tolist())


@settings(max_examples=80, deadline=None)
@given(instances())
def test_thm1_no_partial_hosting(inst):
    """Theorem 1(b): if alpha + g(alpha) >= 1, alpha-RR never hosts alpha."""
    costs, x, c = inst
    if costs.alpha + costs.g_alpha < 1.0:
        return
    res = run_policy(AlphaRR(costs), costs, x, c)
    assert res.level_slots[1] == 0


@settings(max_examples=60, deadline=None)
@given(instances())
def test_rr_equals_alpha_rr_on_two_levels(inst):
    """RetroRenting == AlphaRR restricted to {0,1} and never at a partial
    level; also cross-checks cost accounting between run paths."""
    costs, x, c = inst
    rr = RetroRenting(costs)
    res = run_policy(rr, rr.costs, x, c)
    assert res.level_slots.shape[0] == 2
    res2 = evaluate_schedule(rr.costs, res.r_hist, x, c)
    # evaluate_schedule charges no final speculative fetch; allow that delta
    assert abs((res.total - res.fetch) - (res2.total - res2.fetch)) < 1e-4


def test_static_policy_cost_accounting():
    costs = HostingCosts.three_level(M=4.0, alpha=0.5, g_alpha=0.25)
    x = np.asarray([1, 1, 1, 1], np.int64)
    c = np.asarray([0.5, 0.5, 0.5, 0.5], np.float64)
    res = run_policy(StaticPolicy(costs, 2), costs, x, c)
    # slot1 at r=0 (cost 1 svc) + fetch 4; slots 2-4 hosted (rent .5)
    assert res.total == pytest.approx(1.0 + 4.0 + 3 * 0.5)
    res0 = run_policy(StaticPolicy(costs, 0), costs, x, c)
    assert res0.total == pytest.approx(4.0)  # all forwarded


def test_known_trace_alpha_rr_behaviour():
    """Hand-checkable trace: heavy arrivals with cheap rent -> alpha-RR ends
    fully hosted; silence with dear rent -> it evicts."""
    costs = HostingCosts.three_level(M=2.0, alpha=0.5, g_alpha=0.25, c_min=0.125, c_max=4.0)
    x = np.array([1] * 12 + [0] * 12)
    c = np.array([0.125] * 12 + [4.0] * 12)
    res = run_policy(AlphaRR(costs), costs, x, c)
    assert res.r_hist[0] == 0                  # starts empty
    assert res.r_hist[11] == 2                 # fully hosted by slot 12
    assert res.r_hist[-1] == 0                 # evicted in the dear-rent tail


def test_model2_service_matrix_shapes_and_bounds():
    import jax
    costs = HostingCosts.three_level(M=4.0, alpha=0.5, g_alpha=0.5)
    x = np.array([0, 3, 1, 5])
    svc = np.asarray(model2_service_matrix(jax.random.PRNGKey(0), costs, x))
    assert svc.shape == (4, 3)
    assert np.all(svc[:, 0] == x)              # level 0 forwards everything
    assert np.all(svc[:, 2] == 0)              # full hosting serves everything
    assert np.all(svc[:, 1] <= x) and np.all(svc[:, 1] >= 0)


@settings(max_examples=40, deadline=None)
@given(instances(max_T=25), st.integers(0, 2 ** 31 - 1))
def test_thm2_bound_holds_model2(inst, seed):
    """The competitive-ratio property under realized Model-2 service costs
    (coupled randomness; both policies scored on the same realization)."""
    import jax
    costs, x, c = inst
    svc = model2_service_matrix(jax.random.PRNGKey(seed), costs, x)
    rr = run_policy(AlphaRR(costs), costs, x, c, svc=svc, include_final_fetch=False)
    opt = offline_opt(costs, x, c, svc=svc)
    bound = bounds.thm2_ratio_upper(costs)
    if opt.cost <= 1e-9:
        return
    assert rr.total <= bound * opt.cost + 1e-4
