"""Persistent FleetStepper + async ingestion, per the PR-7 acceptance bar:

* **Bit-identity** — N ``fleet_stepper`` steps == ONE ``run_fleet`` call
  (exact equality, never allclose), obs-backed and scenario-fused, chunk
  sizes that do and do not divide the horizon, mixed per-instance
  horizons, ``n_seeds`` replication, and (subprocess) a forced-4-device
  mesh.
* **Zero retraces** — after warmup, >= 20 further steps (and constructing
  fresh steppers on the same config) bump no ``STREAM_TRACES`` counter.
* **Donation safety** — ``donate=True`` invalidates the old carry without
  ever reading it (stepping stays bit-identical to ``donate=False``);
  ``donate=False`` keeps the old carry readable.
* **Async ingestion** — ``async_ingest=True`` is bit-identical to the
  synchronous feed for ``run_fleet`` and ``offline_opt_fleet``.
* **Live serving** — ``LiveFleetScheduler.admit`` accounting ==
  ``run_fleet`` over the same telemetry.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import scenarios as S
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import (STREAM_TRACES, FleetBatch, fleet_stepper,
                              offline_opt_fleet, run_fleet)
from repro.core.ingest import SlabPrefetcher
from repro.core.policies import AlphaRR
import jax

T = 48
CHUNKS = [16, 20]          # 20 does not divide 48: exercises the padded tail
HORIZONS = [T, 23, 11, T, 7]


COST_POOL = [HostingCosts.two_level(4.0),
             HostingCosts.three_level(6.0, 0.25, 0.5),
             HostingCosts.three_level(3.0, 0.5, 0.25),
             HostingCosts(M=5.0, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                          g=(1.0, 0.4, 0.3, 0.15, 0.0)),
             HostingCosts.three_level(8.0, 0.375, 0.375)]


@pytest.fixture(scope="module")
def stacked():
    grid = HostingGrid.from_costs(COST_POOL)
    rng = np.random.default_rng(7)
    x = rng.integers(0, 3, (grid.B, T))
    c = rng.integers(1, 16, (grid.B, T)) / 8.0
    return grid, x, c


def make_scenario(B):
    kx = S.split_keys(jax.random.PRNGKey(13), B)
    return S.combine(S.ge_arrivals(kx, 0.3, 0.2, 2.0, 0.2, B),
                     S.spot_rents(jax.random.PRNGKey(1), 0.5, B))


def pad_cols(a, T_pad):
    """Zero-pad telemetry past the horizon (masked, so values don't
    matter — zeros keep it deterministic)."""
    out = np.zeros((a.shape[0], T_pad), a.dtype)
    out[:, :a.shape[1]] = a
    return out


def assert_result_equal(a, b):
    assert np.array_equal(a.total, b.total)
    assert np.array_equal(a.rent, b.rent)
    assert np.array_equal(a.service, b.service)
    assert np.array_equal(a.fetch, b.fetch)
    assert np.array_equal(a.level_slots, b.level_slots)


# ----------------------------------------------------------------------
# Bit-identity: N steps == one run_fleet call.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("chunk", CHUNKS)
def test_stepper_matches_run_fleet_obs(stacked, chunk):
    grid, x, c = stacked
    dense = FleetBatch.from_dense(grid, x, c, T=HORIZONS)
    fns = AlphaRR.fleet(dense)
    ref = run_fleet(fns, dense)
    st = fleet_stepper(fns, FleetBatch.for_scenario(grid, HORIZONS),
                       chunk_size=chunk)
    n = -(-T // chunk)
    xp, cp = pad_cols(x, n * chunk), pad_cols(c, n * chunk)
    parts = [st.step(x=xp[:, i*chunk:(i+1)*chunk],
                     c=cp[:, i*chunk:(i+1)*chunk]) for i in range(n)]
    res = st.result(np.concatenate(parts, axis=1))
    assert_result_equal(res, ref)
    assert np.array_equal(res.r_hist, ref.r_hist)
    # live readbacks: past-horizon slots are exact no-ops, so the carry's
    # level is each instance's level at its OWN final in-horizon slot
    final = ref.r_hist[np.arange(grid.B), np.asarray(HORIZONS) - 1]
    assert np.array_equal(st.hosting_levels(), final)
    lv = st.hosting_fractions()
    assert lv.shape == (grid.B,) and np.all((0.0 <= lv) & (lv <= 1.0))


@pytest.mark.parametrize("n_seeds", [None, 3])
def test_stepper_matches_run_fleet_scenario(n_seeds):
    grid = HostingGrid.from_costs(COST_POOL)
    fleet = FleetBatch.for_scenario(grid, HORIZONS)
    sc = make_scenario(grid.B)
    fns = AlphaRR.fleet(fleet)
    ref = run_fleet(fns, fleet, scenario=sc, n_seeds=n_seeds)
    for chunk in CHUNKS:
        st = fleet_stepper(fns, fleet, scenario=sc, chunk_size=chunk,
                           n_seeds=n_seeds)
        n = -(-T // chunk)
        parts = [st.step() for _ in range(n)]
        res = st.result(np.concatenate(parts, axis=1))
        assert_result_equal(res, ref)
        assert np.array_equal(res.r_hist, ref.r_hist)
        assert res.n_seeds == ref.n_seeds


# ----------------------------------------------------------------------
# Zero-retrace guard + donation safety.
# ----------------------------------------------------------------------

def test_zero_retraces_after_warmup(stacked):
    grid, x, c = stacked
    fleet = FleetBatch.for_scenario(grid, 1 << 20)
    fns = AlphaRR.fleet(fleet)
    rng = np.random.default_rng(0)
    st = fleet_stepper(fns, fleet, chunk_size=1)
    st.step(x=rng.integers(0, 3, grid.B), c=rng.uniform(0.1, 2.0, grid.B))
    warm = dict(STREAM_TRACES)
    for _ in range(24):
        st.step(x=rng.integers(0, 4, grid.B), c=rng.uniform(0.1, 3.0, grid.B))
    # a second stepper on the same config reuses the compiled step
    st2 = fleet_stepper(fns, fleet, chunk_size=1)
    st2.step(x=rng.integers(0, 3, grid.B), c=rng.uniform(0.1, 2.0, grid.B))
    assert dict(STREAM_TRACES) == warm, (warm, dict(STREAM_TRACES))
    assert st.steps == 25 and st.t == 25


def test_donation_invalidates_old_carry(stacked):
    grid, x, c = stacked
    fleet = FleetBatch.for_scenario(grid, T)
    fns = AlphaRR.fleet(fleet)
    donating = fleet_stepper(fns, fleet, chunk_size=16)
    keeping = fleet_stepper(fns, fleet, chunk_size=16, donate=False)
    for i in range(3):
        sl = slice(i * 16, (i + 1) * 16)
        old_d = jax.tree_util.tree_leaves(donating.carry)
        old_k = jax.tree_util.tree_leaves(keeping.carry)
        rd = donating.step(x=x[:, sl], c=c[:, sl])
        rk = keeping.step(x=x[:, sl], c=c[:, sl])
        # donated carry buffers are gone; undonated ones stay readable
        assert all(a.is_deleted() for a in old_d)
        assert all(not a.is_deleted() for a in old_k)
        np.asarray(old_k[0])
        # and donation never changes a bit
        assert np.array_equal(rd, rk)
    assert_result_equal(donating.result(), keeping.result())


# ----------------------------------------------------------------------
# Async ingestion == synchronous feed, drivers end to end.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("chunk", CHUNKS)
def test_async_run_fleet_bitwise(stacked, chunk):
    grid, x, c = stacked
    rng = np.random.default_rng(2)
    side = rng.integers(0, 2, (grid.B, T))
    fleet = FleetBatch.from_dense(grid, x, c, side=side, T=HORIZONS)
    fns = AlphaRR.fleet(fleet)
    sync = run_fleet(fns, fleet, chunk_size=chunk, stream=True)
    asyn = run_fleet(fns, fleet, chunk_size=chunk, stream=True,
                     async_ingest=True)
    assert_result_equal(asyn, sync)
    assert np.array_equal(asyn.r_hist, sync.r_hist)


def test_async_offline_dp_bitwise(stacked):
    grid, x, c = stacked
    fleet = FleetBatch.from_dense(grid, x, c, T=HORIZONS)
    sync = offline_opt_fleet(fleet, checkpointed=True, stream=True,
                             chunk_size=16)
    asyn = offline_opt_fleet(fleet, checkpointed=True, stream=True,
                             chunk_size=16, async_ingest=True)
    assert np.array_equal(asyn.cost, sync.cost)
    assert np.array_equal(asyn.r_hist, sync.r_hist)
    assert np.array_equal(asyn.sim.total, sync.sim.total)
    cost_only = offline_opt_fleet(fleet, checkpointed=True, stream=True,
                                  chunk_size=16, collect_schedule=False,
                                  async_ingest=True)
    assert np.array_equal(cost_only.cost, sync.cost)
    with pytest.raises(ValueError, match="async_ingest"):
        offline_opt_fleet(fleet, async_ingest=True)
    with pytest.raises(ValueError, match="async_ingest"):
        run_fleet(AlphaRR.fleet(fleet), fleet, async_ingest=True)


def test_slab_prefetcher_unit():
    got = list(SlabPrefetcher(lambda i: i * i, 7))
    assert got == [i * i for i in range(7)]

    def boom(i):
        if i == 2:
            raise RuntimeError("bad slab")
        return i

    it = iter(SlabPrefetcher(boom, 5))
    assert next(it) == 0 and next(it) == 1
    with pytest.raises(RuntimeError, match="bad slab"):
        list(it)
    # close is idempotent and never deadlocks against a full queue
    pf = SlabPrefetcher(lambda i: i, 100, depth=1)
    pf.close()
    pf.close()


# ----------------------------------------------------------------------
# Live fleet scheduler == run_fleet over the same telemetry.
# ----------------------------------------------------------------------

def test_live_fleet_scheduler_matches_run_fleet(stacked):
    from repro.serve.scheduler import LiveFleetScheduler
    grid, x, c = stacked
    n_slots = 30
    sched = LiveFleetScheduler(COST_POOL, horizon=1 << 20)
    chosen = [sched.admit(x[:, t], c[:, t]) for t in range(n_slots)]
    dense = FleetBatch.from_dense(grid, x[:, :n_slots], c[:, :n_slots])
    ref = run_fleet(AlphaRR.fleet(dense), dense, include_final_fetch=False)
    assert np.array_equal(np.stack(chosen, axis=1), ref.r_hist)
    rep = sched.report()
    assert_result_equal(rep, ref)
    assert np.array_equal(sched.hosting_levels(), ref.r_hist[:, -1])
    frac = sched.hosting_fractions()
    lv = np.asarray([cc.levels[r] for cc, r in
                     zip(COST_POOL, sched.hosting_levels())])
    assert np.array_equal(frac, lv.astype(frac.dtype))
    assert sched.n_slots == n_slots


# ----------------------------------------------------------------------
# Forced multi-device mesh (subprocess — this process is pinned to one
# device by conftest).
# ----------------------------------------------------------------------

_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    assert jax.device_count() == 4, jax.devices()
    from repro.core import scenarios as S
    from repro.core.costs import HostingCosts, HostingGrid
    from repro.core.fleet import FleetBatch, fleet_stepper, run_fleet
    from repro.core.policies import AlphaRR
    from repro.sharding.specs import fleet_mesh

    rng = np.random.default_rng(3)
    # B=6 is not a multiple of 4: exercises dummy-instance padding
    costs_list = [HostingCosts.three_level(4.0 + i, 0.3, 0.4) for i in range(5)]
    costs_list.append(HostingCosts.two_level(4.0))
    grid = HostingGrid.from_costs(costs_list)
    T = 48
    x = rng.integers(0, 3, (6, T)); c = rng.integers(1, 16, (6, T)) / 8.0
    dense = FleetBatch.from_dense(grid, x, c)
    fns = AlphaRR.fleet(dense)
    mesh = fleet_mesh()
    ref = run_fleet(fns, dense, mesh=mesh)
    st = fleet_stepper(fns, FleetBatch.for_scenario(grid, T), mesh=mesh,
                       chunk_size=16)
    parts = [st.step(x=x[:, i*16:(i+1)*16], c=c[:, i*16:(i+1)*16])
             for i in range(3)]
    res = st.result(np.concatenate(parts, axis=1))
    assert np.array_equal(res.total, ref.total)
    assert np.array_equal(res.r_hist, ref.r_hist)
    assert np.array_equal(res.level_slots, ref.level_slots)

    kx = S.split_keys(jax.random.PRNGKey(13), 6)
    sc = S.combine(S.ge_arrivals(kx, 0.3, 0.2, 2.0, 0.2, 6),
                   S.spot_rents(jax.random.PRNGKey(1), 0.5, 6))
    fleet = FleetBatch.for_scenario(grid, T)
    sref = run_fleet(fns, fleet, scenario=sc, mesh=mesh, n_seeds=2)
    sst = fleet_stepper(fns, fleet, scenario=sc, mesh=mesh, chunk_size=16,
                        n_seeds=2)
    sparts = [sst.step() for _ in range(3)]
    sres = sst.result(np.concatenate(sparts, axis=1))
    assert np.array_equal(sres.total, sref.total)
    assert np.array_equal(sres.r_hist, sref.r_hist)
    print("STEPPER-MULTI-DEVICE-OK")
""")


def test_fleet_stepper_multi_device_bitwise():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "STEPPER-MULTI-DEVICE-OK" in out.stdout
