"""Roofline machinery: HLO collective parsing and the incremental-layer
extrapolation (validated against a true full unroll on a small config)."""
import subprocess
import sys
import textwrap
from pathlib import Path
import os

import pytest

from repro.launch.roofline import collective_stats, _shape_bytes, _parse_groups

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_shape_bytes():
    assert _shape_bytes("bf16[128,256]") == 128 * 256 * 2
    assert _shape_bytes("f32[10]") == 40
    assert _shape_bytes("(bf16[4,4], f32[2])") == 32 + 8
    assert _shape_bytes("s8[1024]") == 1024


def test_parse_iota_groups():
    gs = _parse_groups("replica_groups=[2,4]<=[8], dims")
    assert len(gs) == 2 and gs[0] == [0, 1, 2, 3]


def test_collective_stats_ring_factors():
    hlo = """
  %all-reduce.1 = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-gather.2 = bf16[64,64]{1,0} all-gather(bf16[16,64]{1,0} %y), replica_groups=[2,4]<=[8], dimensions={0}
  %collective-permute.3 = f32[256]{0} collective-permute(f32[256]{0} %z), source_target_pairs={{0,256},{256,0}}
"""
    st = collective_stats(hlo)
    assert st.op_counts == {"all-reduce": 1, "all-gather": 1,
                            "collective-permute": 1}
    # all-reduce: 2*(4-1)/4*4096 bytes
    assert st.op_bytes["all-reduce"] == pytest.approx(2 * 0.75 * 4096)
    # all-gather: (4-1)/4 * out bytes (64*64*2)
    assert st.op_bytes["all-gather"] == pytest.approx(0.75 * 64 * 64 * 2)
    # permute crossing id 256 boundary counts as DCN
    assert st.dcn_bytes == pytest.approx(1024.0)


def test_extrapolation_matches_full_unroll_subprocess():
    """cost(A) + (L-1)*(cost(B)-cost(A)) == cost(full unroll) for a
    homogeneous 4-layer model (exactness of the linear model)."""
    code = textwrap.dedent("""
    import dataclasses, jax
    from repro.configs import get_arch
    from repro.configs.base import ShapeSpec
    from repro.launch.analysis import extrapolated_terms, _terms_of
    spec = get_arch('stablelm-1.6b')
    tiny = spec.tiny.with_(segments=(('dense', 4),), attn_impl='xla_flash',
                           attn_chunk=8, loss_chunk=8)
    spec = dataclasses.replace(spec, model=tiny)
    shape = ShapeSpec('t', 'train', seq=16, batch=8)
    mesh = jax.make_mesh((2, 4), ('data', 'model'), devices=jax.devices())
    terms = extrapolated_terms(spec, shape, mesh)
    full = dataclasses.replace(
        spec, model=tiny.with_(scan_unroll=True))
    truth = _terms_of(full, shape, mesh)
    assert abs(terms['flops'] - truth['flops']) <= 0.02 * max(truth['flops'], 1.0)
    for key in ('ici', 'dcn'):
        # XLA's collective strategy is NOT layerwise-uniform at tiny sizes:
        # measured on this config, the unrolled 'truth' lowers through
        # collective-matmul (collective-permute based) at L in {1, 3} but
        # pure all-reduce at L in {2, 4}, with total ICI bytes
        # 593654 / 694262 / 833654 / 1246454 for L = 1..4 — the L=4 jump is
        # a strategy switch, not a per-layer cost.  A linear-in-L model
        # cannot (and should not) track that oscillation; flops stay within
        # 2% and HBM bytes within 10%, so wire bytes get a factor-1.5 band:
        # still catches unit/multiplier regressions, tolerates XLA's
        # per-layer-count strategy noise.
        a, b = terms[key], truth[key]
        assert a <= 1.5 * b + 1e-6 and b <= 1.5 * a + 1e-6, (key, a, b)
    # bytes: buffer-level accounting differs slightly between programs
    assert abs(terms['bytes'] - truth['bytes']) <= 0.10 * truth['bytes']
    print('extrapolation ok', terms['flops'], truth['flops'])
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
