"""Multi-device correctness, run in subprocesses with 8 forced host devices
(XLA locks the device count at first jax import, so these cannot share the
main test process).

Covers: sharded train step == single-device step; shard_map MoE dispatch ==
dense dispatch; elastic checkpoint restore across meshes; ZeRO-1 sharding.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_py(body: str, n_dev: int = 8, timeout: int = 600):
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_moe_shardmap_matches_dense_dispatch():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs import get_arch
    from repro.models.moe import moe_init, moe_apply
    from repro.sharding.context import shard_ctx

    spec = get_arch('deepseek-moe-16b')
    cfg = spec.tiny.with_(moe_capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

    y_dense, aux_d, _ = moe_apply(p, cfg, x)

    mesh = jax.make_mesh((2, 4), ('data', 'model'), devices=jax.devices())
    def f(p, x):
        with shard_ctx(mesh, ('data',)):
            y, aux, _ = moe_apply(p, cfg, x)
        return y, aux
    with mesh:
        y_sm, aux_s = jax.jit(f)(p, x)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_sm),
                               rtol=2e-4, atol=2e-4)
    print('moe shardmap ok')
    """)


def test_sharded_train_step_matches_single_device():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs import get_arch
    from repro.configs.base import ShapeSpec
    from repro.train.steps import build_train, make_train_step
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.models.transformer import init_params

    spec = get_arch('llama3.2-3b')
    spec = dataclasses.replace(spec, model=spec.tiny)
    shape = ShapeSpec('t', 'train', seq=16, batch=8)
    mesh = jax.make_mesh((2, 4), ('data', 'model'), devices=jax.devices())

    params = init_params(spec.model, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 256),
             'labels': jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 256)}

    # single-device reference
    step = make_train_step(spec.model, AdamWConfig())
    p1, o1, m1 = jax.jit(step)(params, opt, batch)

    built = build_train(spec, mesh, shape, zero1=True)
    with mesh:
        p2, o2, m2 = built['fn'](params, opt, batch)
    assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-3, (m1, m2)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3, d
    print('sharded train step ok, loss', float(m2['loss']))
    """)


def test_elastic_checkpoint_across_meshes(tmp_path):
    run_py(f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.train import checkpoint as ckpt

    t = {{'w': jnp.arange(64.0).reshape(8, 8), 's': jnp.int32(3)}}
    mesh_a = jax.make_mesh((8,), ('data',), devices=jax.devices())
    sh_a = {{'w': NamedSharding(mesh_a, P('data', None)),
             's': NamedSharding(mesh_a, P())}}
    placed = jax.tree.map(jax.device_put, t, sh_a)
    ckpt.save(r'{tmp_path}', 0, placed)

    mesh_b = jax.make_mesh((2, 4), ('data', 'model'), devices=jax.devices())
    sh_b = {{'w': NamedSharding(mesh_b, P('model', 'data')),
             's': NamedSharding(mesh_b, P())}}
    step, restored, _ = ckpt.restore_sharded(r'{tmp_path}', t, sh_b)
    np.testing.assert_array_equal(np.asarray(restored['w']), np.asarray(t['w']))
    assert restored['w'].sharding.spec == P('model', 'data')
    print('elastic restore ok')
    """)


def test_flash_decode_shardmap_matches_reference():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.serve.flash_decode import flash_decode, flash_decode_ref
    mesh = jax.make_mesh((1, 8), ('data', 'model'), devices=jax.devices())
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, Hq, Hkv, hd = 4, 128, 8, 2, 32
    q = jax.random.normal(ks[0], (B, 1, Hq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    pos = jnp.int32(97)
    ref = flash_decode_ref(q, k, v, pos)
    out = flash_decode(q, k, v, pos, mesh=mesh, axis='model')
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
    print('flash decode ok')
    """)
