"""Multi-host fleet engine: 2-process local cluster == single process, bit
for bit.

The tentpole proof for the process-spanning ``fleet`` mesh: spawn a
2-process local JAX cluster (``repro.sharding.distributed
.run_local_cluster``), have each worker run the full sim + DP + stepper
config matrix (obs-backed and scenario-fused, chunked and streamed,
mixed K, mixed T, ``n_seeds``) on its OWN host-local rows only, and
assert ``np.array_equal`` — never allclose — against an in-process
single-process run of the same global workload.  Also unit-tests the
harness itself (port pick, worker failure teardown, forced process
count) and the process-spanning mesh construction, so a multihost CI
failure is attributable to harness vs mesh vs engine.
"""
import json
import os
import subprocess
import time

import numpy as np
import pytest

from repro.sharding import distributed

import multihost_worker as W

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
WORKER = os.path.join(TESTS_DIR, "multihost_worker.py")

N_PROCS = distributed.default_num_processes(2)
DEVICES_PER_PROCESS = int(os.environ.get("REPRO_MULTIHOST_DEVICES", "1"))


# ----------------------------------------------------------------------
# harness unit tests (no cluster spawn needed except where stated)
# ----------------------------------------------------------------------

def test_pick_free_port_is_bindable():
    import socket
    port = distributed.pick_free_port()
    assert 0 < port < 65536
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", port))   # freshly picked -> still free


def test_default_num_processes_env(monkeypatch):
    monkeypatch.delenv(distributed.ENV_FORCE_PROCESSES, raising=False)
    assert distributed.default_num_processes(3) == 3
    monkeypatch.setenv(distributed.ENV_FORCE_PROCESSES, "5")
    assert distributed.default_num_processes(3) == 5


def test_worker_env_wiring():
    env = distributed.worker_env("127.0.0.1:5555", 4, 2,
                                 devices_per_process=3,
                                 extra_env={"MARKER": "yes"})
    assert env[distributed.ENV_COORD] == "127.0.0.1:5555"
    assert env[distributed.ENV_NPROCS] == "4"
    assert env[distributed.ENV_PID] == "2"
    assert env["JAX_PLATFORMS"] == "cpu"
    assert "--xla_force_host_platform_device_count=3" in env["XLA_FLAGS"]
    # exactly one forced-device flag even if the parent already had one
    assert env["XLA_FLAGS"].count("xla_force_host_platform_device_count") == 1
    assert env["MARKER"] == "yes"
    src = os.path.join(os.path.dirname(TESTS_DIR), "src")
    assert src in env["PYTHONPATH"].split(os.pathsep)


def test_initialize_noop_without_env(monkeypatch):
    monkeypatch.delenv(distributed.ENV_COORD, raising=False)
    monkeypatch.delenv(distributed.ENV_NPROCS, raising=False)
    monkeypatch.delenv(distributed.ENV_PID, raising=False)
    assert distributed.initialize() is False
    assert distributed.is_initialized() is False
    distributed.shutdown()   # idempotent no-op


def test_run_local_cluster_worker_failure_teardown():
    """One worker exits nonzero -> RuntimeError naming it, and the whole
    cluster is reaped (no orphans holding the port)."""
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="worker"):
        distributed.run_local_cluster(
            ["-c", "import os, sys; sys.exit("
             f"3 if os.environ['{distributed.ENV_PID}'] == '1' else 0)"],
            n_processes=2, timeout=60.0)
    assert time.monotonic() - t0 < 60.0


def test_run_local_cluster_timeout_kills_workers():
    with pytest.raises(subprocess.TimeoutExpired):
        distributed.run_local_cluster(
            ["-c", "import time; time.sleep(600)"],
            n_processes=2, timeout=2.0)


def test_run_local_cluster_returns_stdout_per_pid():
    outs = distributed.run_local_cluster(
        ["-c", f"import os; print(os.environ['{distributed.ENV_PID}'])"],
        n_processes=3, timeout=60.0)
    assert [o.strip() for o in outs] == ["0", "1", "2"]


# ----------------------------------------------------------------------
# process-spanning mesh construction (needs a real cluster)
# ----------------------------------------------------------------------

def test_fleet_mesh_process_spanning():
    outs = distributed.run_local_cluster(
        [WORKER, "meshinfo"], n_processes=N_PROCS,
        devices_per_process=DEVICES_PER_PROCESS, timeout=300.0)
    infos = [json.loads(o.strip().splitlines()[-1]) for o in outs]
    assert sorted(i["pid"] for i in infos) == list(range(N_PROCS))
    for info in infos:
        assert info["initialized"] is True
        assert info["nprocs"] == N_PROCS
        assert info["local_devices"] == DEVICES_PER_PROCESS
        assert info["global_devices"] == N_PROCS * DEVICES_PER_PROCESS
        # the fleet mesh spans every process's devices, process-contiguous
        assert info["mesh_size"] == N_PROCS * DEVICES_PER_PROCESS
        assert sorted(set(info["mesh_procs"])) == list(range(N_PROCS))
        assert info["process_contiguous"] is True
        assert info["mesh_process_count"] == N_PROCS
        assert info["mesh_is_multiprocess"] is True
        assert info["mesh_local_device_count"] == DEVICES_PER_PROCESS


def test_mesh_helpers_single_process():
    from repro.sharding.specs import (fleet_mesh, mesh_is_multiprocess,
                                      mesh_local_device_count,
                                      mesh_process_count)
    mesh = fleet_mesh()
    assert mesh_process_count(mesh) == 1
    assert mesh_is_multiprocess(mesh) is False
    assert mesh_local_device_count(mesh) == mesh.devices.size


# ----------------------------------------------------------------------
# the tentpole: 2-process == 1-process bit-identity
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def cluster_outputs(tmp_path_factory):
    """Run the engine config matrix once on an N-process cluster; return
    {pid: npz dict} keyed by worker process id."""
    outdir = tmp_path_factory.mktemp("multihost")
    distributed.run_local_cluster(
        [WORKER, "engine", str(outdir)], n_processes=N_PROCS,
        devices_per_process=DEVICES_PER_PROCESS, timeout=900.0)
    out = {}
    for pid in range(N_PROCS):
        with np.load(outdir / f"out_{pid}.npz") as z:
            out[pid] = {k: z[k] for k in z.files}
    return out


@pytest.fixture(scope="module")
def reference():
    """Single-process run of the same GLOBAL workload, in this process."""
    return W.run_engine_configs(0, W.B_GLOBAL, gather=False)


def _row_range(meta):
    pid, nprocs, lo, hi = (int(v) for v in meta)
    assert hi - lo == W.B_GLOBAL // nprocs
    return lo, hi


ENGINE_KEYS = sorted([
    # obs-backed: full driver / streamed / DP materialized / DP ckpt / stepper
    "o_run_total", "o_run_fetch", "o_run_rent", "o_run_service",
    "o_run_rhist", "o_run_levels",
    "o_stream_total", "o_stream_rhist",
    "o_dpmat_cost", "o_dpmat_rhist", "o_dpmat_simtotal",
    "o_dpck_cost", "o_dpck_rhist",
    "o_step_total", "o_step_rhist", "o_step_levels",
    # scenario-fused with n_seeds=2
    "s_run_total", "s_run_rhist",
    "s_stream_total", "s_stream_rent",
    "s_dpck_cost", "s_dpck_rhist", "s_dpck_simtotal",
    "s_step_total",
])


@pytest.mark.parametrize("key", ENGINE_KEYS)
def test_two_process_bit_identity(cluster_outputs, reference, key):
    """Every engine output on a 2-process cluster equals the same rows of
    the single-process global run — np.array_equal, never allclose."""
    ref = reference[key]
    for pid in range(N_PROCS):
        z = cluster_outputs[pid]
        lo, hi = _row_range(z["meta"])
        if key.startswith("s_") and ref.shape[0] == W.B_GLOBAL * 2:
            want = ref[lo * 2:hi * 2]    # n_seeds=2: seed-major row blocks
        else:
            want = ref[lo:hi]
        got = z[key]
        assert got.dtype == want.dtype, (key, pid, got.dtype, want.dtype)
        assert np.array_equal(got, want), (
            f"{key}: worker {pid} rows [{lo}:{hi}] differ from "
            f"single-process reference")


def test_gather_returns_global_rows(cluster_outputs, reference):
    """gather=True: every process sees the full global result, equal to
    the single-process run."""
    for pid in range(N_PROCS):
        z = cluster_outputs[pid]
        assert np.array_equal(z["o_gather_total"], reference["o_run_total"])
        assert np.array_equal(z["o_gather_rhist"], reference["o_run_rhist"])
