"""Scenario engine correctness (core/scenarios/ + fleet fusion), per the
PR-3 acceptance bar:

* every migrated generator is **bit-identical** to its legacy
  ``arrivals.py`` / ``rentcosts.py`` counterpart under the same key, and
  invariant to the materialization chunking (the counter-key contract);
* fused ``run_fleet(scenario=...)`` == materialize-then-run **bit-for-bit**
  for every policy family, the offline DP and schedule evaluation, across
  chunked / streamed / multi-device (forced-CPU subprocess) configurations
  and mixed horizons;
* combinator laws: mixtures select components exactly, regime switches are
  exact at their boundaries, antithetic pairs sum to ``lo + hi``, trace
  playback reproduces recorded observations through the fused engine.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import arrivals, rentcosts
from repro.core import scenarios as S
from repro.core.arrivals import GilbertElliot
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import (FleetBatch, evaluate_schedule_fleet,
                              offline_opt_fleet, run_fleet)
from repro.core.policies import (ABCPolicy, AlphaRR, MDPPolicy, RetroRenting,
                                 StaticPolicy)

T = 48
KEY = jax.random.PRNGKey(42)
CHUNKS = [16, 20]      # 20 does not divide 48: exercises the padded tail


# ----------------------------------------------------------------------
# (a) migrated generators: legacy == stream, any materialization chunking.
# ----------------------------------------------------------------------

GEN_CASES = [
    ("bernoulli",
     lambda k, t: arrivals.bernoulli(k, 0.3, t),
     lambda k: S.bernoulli_arrivals(k, 0.3, B=1), 0),
    ("poisson",
     lambda k, t: arrivals.poisson(k, 2.5, t),
     lambda k: S.poisson_arrivals(k, 2.5, B=1), 0),
    ("ge-poisson",
     lambda k, t: GilbertElliot(p_hl=0.2, p_lh=0.1, rate_h=3.0,
                                rate_l=0.2).sample(k, t),
     lambda k: S.ge_arrivals(k, 0.2, 0.1, 3.0, 0.2, B=1), 0),
    ("ge-bernoulli",
     lambda k, t: GilbertElliot(p_hl=0.2, p_lh=0.1, rate_h=0.9, rate_l=0.1,
                                emission="bernoulli").sample(k, t),
     lambda k: S.ge_arrivals(k, 0.2, 0.1, 0.9, 0.1, B=1,
                             emission="bernoulli"), 0),
    ("cluster",
     lambda k, t: arrivals.cluster_trace_like(k, t),
     lambda k: S.bursty_arrivals(k, B=1), 0),
    ("cluster-diurnal",
     lambda k, t: arrivals.cluster_trace_like(k, t, diurnal_period=16),
     lambda k: S.bursty_arrivals(k, B=1, diurnal_period=16), 0),
    ("fetch-bait",
     lambda k, t: arrivals.adversarial_fetch_bait(10, t),
     lambda k: S.adversarial_fetch_bait(10, B=1), 0),
    ("evict-bait",
     lambda k, t: arrivals.adversarial_evict_bait(5, 10, t),
     lambda k: S.adversarial_evict_bait(5, 10, B=1), 0),
    ("arma",
     lambda k, t: rentcosts.ARMAProcess(mean=0.5).sample(k, t),
     lambda k: rentcosts.ARMAProcess(mean=0.5).stream(k), None),
    ("aws-spot",
     lambda k, t: rentcosts.aws_spot_like(k, 0.35, t),
     lambda k: S.spot_rents(k, 0.35, B=1), None),
    ("iid-uniform",
     lambda k, t: rentcosts.iid_uniform(k, 0.5, 0.2, t),
     lambda k: S.uniform_rents(k, 0.5, 0.2, B=1), None),
    ("neg-assoc",
     lambda k, t: rentcosts.negatively_associated(k, 0.5, 0.2, t),
     lambda k: S.na_rents(k, 0.5, 0.2, B=1), None),
]


@pytest.mark.parametrize("name,legacy,stream_fn,leaf",
                         GEN_CASES, ids=[c[0] for c in GEN_CASES])
def test_stream_matches_legacy_and_is_chunk_invariant(name, legacy,
                                                      stream_fn, leaf):
    """Same key -> the stream materialization IS the legacy array, and any
    materialization chunk size produces the identical bits."""
    ref = np.asarray(legacy(KEY, T))
    stream = stream_fn(KEY)
    for chunk in [None] + CHUNKS + [7]:
        vals = S.materialize_stream(stream, T, chunk_size=chunk)
        got = vals[leaf] if leaf is not None else vals
        assert np.array_equal(np.asarray(got)[0], ref), (name, chunk)


def test_ge_states_side_channel_matches_legacy():
    ge = GilbertElliot(p_hl=0.2, p_lh=0.1, rate_h=3.0, rate_l=0.2)
    x_ref, s_ref = ge.sample(KEY, T, return_states=True)
    x, side = S.materialize_stream(ge.stream(KEY), T, chunk_size=7)
    assert np.array_equal(np.asarray(x)[0], np.asarray(x_ref))
    assert np.array_equal(np.asarray(side)[0], np.asarray(s_ref))


def test_scenario_materialize_chunk_invariant():
    B = 3
    sc = S.combine(
        S.ge_arrivals(S.split_keys(KEY, B), 0.3, 0.2, 2.0, 0.2, B),
        S.spot_rents(jax.random.PRNGKey(1), 0.5, B),
        svc=S.model2_service(jax.random.PRNGKey(2),
                             np.array([1.0, 0.5, 0.0]), B, max_per_slot=6))
    base = S.materialize(sc, T)
    for chunk in CHUNKS + [7]:
        got = S.materialize(sc, T, chunk_size=chunk)
        for a, b in zip(base, got):
            assert np.array_equal(a, b), chunk


# ----------------------------------------------------------------------
# (b) fused run_fleet(scenario=...) == materialize-then-run, bit for bit.
# ----------------------------------------------------------------------

def mixed_costs(B=6):
    rng = np.random.default_rng(0)
    out = []
    for i in range(B):
        M = float(rng.choice([2.0, 4.0, 10.0]))
        kind = i % 3
        if kind == 0:
            out.append(HostingCosts.two_level(M))
        elif kind == 1:
            out.append(HostingCosts.three_level(M, 0.25 + 0.125 * (i % 3),
                                                0.125 * (1 + i % 5)))
        else:
            out.append(HostingCosts(M=M, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                                    g=(1.0, 0.4, 0.3, 0.15, 0.0)))
    return out


@pytest.fixture(scope="module")
def stacked():
    costs_list = mixed_costs()
    grid = HostingGrid.from_costs(costs_list)
    B = grid.B
    ges = [GilbertElliot(p_hl=0.3, p_lh=0.2 + 0.1 * (i % 3),
                         rate_h=2.0 + i % 2, rate_l=0.2) for i in range(B)]
    sc = S.combine(
        S.ge_arrivals(S.split_keys(KEY, B), np.array([g.p_hl for g in ges]),
                      np.array([g.p_lh for g in ges]),
                      np.array([g.rate_h for g in ges]),
                      np.array([g.rate_l for g in ges]), B),
        S.spot_rents(jax.random.PRNGKey(1), 0.5, B))
    fleet = FleetBatch.for_scenario(grid, T)
    fleet_m = FleetBatch.from_scenario(grid, sc, T)
    c_means = [float(np.mean(fleet_m.c[i])) for i in range(B)]
    return costs_list, grid, ges, c_means, sc, fleet, fleet_m


def policy_cases(fleet, costs_list, ges, c_means):
    return [
        ("alpha-RR", AlphaRR.fleet(fleet), False),
        ("RR", RetroRenting.fleet(fleet), True),
        ("static", StaticPolicy.fleet(fleet, fleet.grid.top_index()), False),
        ("MDP", MDPPolicy.fleet(fleet, costs_list, ges, c_means), False),
        ("ABC", ABCPolicy.fleet(fleet, costs_list, ges, c_means), False),
    ]


def assert_bitwise_equal(a, b):
    assert np.array_equal(a.total, b.total)
    assert np.array_equal(a.rent, b.rent)
    assert np.array_equal(a.service, b.service)
    assert np.array_equal(a.fetch, b.fetch)
    if a.r_hist is not None and b.r_hist is not None:
        assert np.array_equal(a.r_hist, b.r_hist)
    assert np.array_equal(a.level_slots, b.level_slots)


def test_fused_matches_materialized_every_policy(stacked):
    costs_list, grid, ges, c_means, sc, fleet, fleet_m = stacked
    for name, fns, endpoints in policy_cases(fleet, costs_list, ges,
                                             c_means):
        fl = fleet.restrict_to_endpoints() if endpoints else fleet
        flm = fleet_m.restrict_to_endpoints() if endpoints else fleet_m
        base = run_fleet(fns, flm)
        for kw in ({}, {"chunk_size": CHUNKS[0]}, {"chunk_size": CHUNKS[1]},
                   {"chunk_size": CHUNKS[1], "stream": True}):
            fused = run_fleet(fns, fl, scenario=sc, **kw)
            assert_bitwise_equal(fused, base)
        # collect_trace=False drops only the trace
        nt = run_fleet(fns, fl, scenario=sc, chunk_size=CHUNKS[0],
                       collect_trace=False)
        assert nt.r_hist is None
        assert np.array_equal(nt.total, base.total), name


def test_fused_matches_materialized_dp_and_schedule(stacked):
    costs_list, grid, ges, c_means, sc, fleet, fleet_m = stacked
    base = offline_opt_fleet(fleet_m)
    for kw in ({}, {"chunk_size": CHUNKS[1]}):
        fo = offline_opt_fleet(fleet, scenario=sc, **kw)
        assert np.array_equal(fo.cost, base.cost)
        assert np.array_equal(fo.r_hist, base.r_hist)
        assert np.array_equal(fo.sim.total, base.sim.total)
    rng = np.random.default_rng(11)
    r = np.stack([rng.integers(0, cc.K, T) for cc in costs_list])
    ev = evaluate_schedule_fleet(fleet_m, r)
    for kw in ({}, {"chunk_size": CHUNKS[1]}):
        assert_bitwise_equal(
            evaluate_schedule_fleet(fleet, r, scenario=sc, **kw), ev)


def test_fused_matches_materialized_mixed_horizons(stacked):
    costs_list, grid, ges, c_means, sc, fleet, fleet_m = stacked
    Ts = [48, 37, 23, 48, 11, 30]
    fl = FleetBatch.for_scenario(grid, Ts)
    flm = FleetBatch.from_scenario(grid, sc, Ts)
    fns = AlphaRR.fleet(fl)
    base = run_fleet(fns, flm)
    for kw in ({}, {"chunk_size": CHUNKS[1]},
               {"chunk_size": CHUNKS[1], "stream": True}):
        assert_bitwise_equal(run_fleet(fns, fl, scenario=sc, **kw), base)
    bo = offline_opt_fleet(flm)
    fo = offline_opt_fleet(fl, scenario=sc, chunk_size=CHUNKS[0])
    assert np.array_equal(bo.cost, fo.cost)
    assert np.array_equal(bo.r_hist, fo.r_hist)


def test_fused_model2_service_and_endpoint_coupling(stacked):
    """The service stream bound to the endpoint-restricted grid prices RR
    on exactly the endpoint gather of the full grid's coupled uniforms."""
    costs_list, grid, *_ = stacked
    B = grid.B
    ksvc = jax.random.PRNGKey(9)

    def scenario_fn(g):
        return S.combine(
            S.poisson_arrivals(S.shared_keys(jax.random.PRNGKey(3), B),
                               2.0, B),
            S.uniform_rents(jax.random.PRNGKey(4), 0.5, 0.2, B),
            svc=S.model2_service(S.shared_keys(ksvc, B), g.g, B,
                                 max_per_slot=8))
    sc = scenario_fn(grid)
    fleet = FleetBatch.for_scenario(grid, T)
    fleet_m = FleetBatch.from_scenario(grid, sc, T)
    base = run_fleet(AlphaRR.fleet(fleet), fleet_m)
    fused = run_fleet(AlphaRR.fleet(fleet), fleet, scenario=sc,
                      chunk_size=CHUNKS[1], stream=True)
    assert_bitwise_equal(fused, base)
    # endpoint coupling: materialized svc gathered to (0, top) == the
    # endpoint-grid stream's own draws
    g2 = grid.restrict_to_endpoints()
    x2, c2, svc2, _ = S.materialize(scenario_fn(g2), T)
    gathered = np.asarray(grid.endpoint_service(np.asarray(fleet_m.svc)))
    assert np.array_equal(svc2, gathered)
    fo = offline_opt_fleet(FleetBatch.for_scenario(g2, T),
                           scenario=scenario_fn(g2))
    bo = offline_opt_fleet(fleet_m.restrict_to_endpoints())
    assert np.array_equal(fo.cost, bo.cost)


def test_scenario_requires_obsless_fleet(stacked):
    costs_list, grid, ges, c_means, sc, fleet, fleet_m = stacked
    with pytest.raises(ValueError):
        run_fleet(AlphaRR.fleet(fleet_m), fleet_m, scenario=sc)


# ----------------------------------------------------------------------
# (c) combinator laws.
# ----------------------------------------------------------------------

def test_mixture_selects_components():
    B = 4
    comps = [S.bernoulli_arrivals(S.split_keys(KEY, B), 0.2, B),
             S.poisson_arrivals(S.split_keys(jax.random.PRNGKey(7), B),
                                2.0, B)]
    assign = [0, 1, 0, 1]
    mixed = S.mixture(comps, assign)
    xm, _ = S.materialize_stream(mixed, T, chunk_size=7)
    x0, _ = S.materialize_stream(comps[0], T)
    x1, _ = S.materialize_stream(comps[1], T)
    for b, comp in enumerate(assign):
        src = (x0, x1)[comp]
        assert np.array_equal(np.asarray(xm)[b], np.asarray(src)[b]), b


def test_mixture_from_weights_frequencies():
    B = 400
    comps = [S.constant_rents(1.0, B), S.constant_rents(2.0, B)]
    mixed = S.mixture_from_weights(comps, [0.25, 0.75],
                                   jax.random.PRNGKey(0), B)
    c = np.asarray(S.materialize_stream(mixed, 2))
    frac2 = float(np.mean(c[:, 0] == 2.0))
    assert abs(frac2 - 0.75) < 0.07


def test_regime_switch_boundaries():
    B = 3
    a = S.bernoulli_arrivals(S.split_keys(KEY, B), 0.9, B)
    b = S.bernoulli_arrivals(S.split_keys(jax.random.PRNGKey(5), B), 0.1, B)
    sw = S.regime_switch([a, b], [20])
    xs, _ = S.materialize_stream(sw, T, chunk_size=16)  # boundary mid-chunk
    xa, _ = S.materialize_stream(a, T)
    xb, _ = S.materialize_stream(b, T)
    assert np.array_equal(np.asarray(xs)[:, :20], np.asarray(xa)[:, :20])
    assert np.array_equal(np.asarray(xs)[:, 20:], np.asarray(xb)[:, 20:])


def test_antithetic_pairing_symmetry():
    B = 6
    paired = S.antithetic_pairing(S.uniform_rents(KEY, 0.5, 0.2, B))
    c = np.asarray(S.materialize_stream(paired, T))
    # pair members sum to lo + hi = 2 * c_mean on every slot...
    assert np.allclose(c[0::2] + c[1::2], 1.0, atol=1e-6)
    # ...and are genuinely antithetic, not constant
    assert np.std(c[0]) > 0.01
    # pairing a paired stream is idempotent on the even members
    c2 = np.asarray(S.materialize_stream(
        S.antithetic_pairing(S.uniform_rents(KEY, 0.5, 0.2, B)), T))
    assert np.array_equal(c, c2)


def test_antithetic_pairing_requires_flip_support():
    with pytest.raises(ValueError):
        S.antithetic_pairing(S.poisson_arrivals(KEY, 2.0, B=2))


def test_trace_playback_reproduces_obs_through_engine():
    """A recorded sample path replayed through the fused engine gives the
    exact obs-backed run (the geolife/g-curve port's contract)."""
    costs_list = mixed_costs(4)
    grid = HostingGrid.from_costs(costs_list)
    rng = np.random.default_rng(2)
    x = rng.integers(0, 3, (grid.B, T))
    c = rng.integers(1, 16, (grid.B, T)) / 8.0
    sc = S.trace_scenario(x, c)
    fleet_obs = FleetBatch.from_dense(grid, x, c)
    fleet = FleetBatch.for_scenario(grid, T)
    fns = AlphaRR.fleet(fleet)
    base = run_fleet(fns, fleet_obs)
    for kw in ({}, {"chunk_size": CHUNKS[1]},
               {"chunk_size": CHUNKS[1], "stream": True}):
        assert_bitwise_equal(run_fleet(fns, fleet, scenario=sc, **kw), base)
    fo = offline_opt_fleet(fleet, scenario=sc, chunk_size=CHUNKS[1])
    bo = offline_opt_fleet(fleet_obs)
    assert np.array_equal(fo.cost, bo.cost)
    assert np.array_equal(fo.r_hist, bo.r_hist)


# ----------------------------------------------------------------------
# Multi-device mesh (forced CPU devices; subprocess, since this process is
# pinned to one device by conftest).
# ----------------------------------------------------------------------

_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    assert jax.device_count() == 4, jax.devices()
    from repro.core import scenarios as S
    from repro.core.costs import HostingCosts, HostingGrid
    from repro.core.fleet import FleetBatch, offline_opt_fleet, run_fleet
    from repro.core.policies import AlphaRR
    from repro.sharding.specs import fleet_mesh

    # B=6 is not a multiple of 4: exercises dummy-instance padding of the
    # scenario params
    costs_list = [HostingCosts.three_level(4.0 + i, 0.3, 0.4) for i in range(5)]
    costs_list.append(HostingCosts.two_level(4.0))
    grid = HostingGrid.from_costs(costs_list)
    B, T = grid.B, 48
    sc = S.combine(
        S.ge_arrivals(S.split_keys(jax.random.PRNGKey(0), B), 0.3, 0.2,
                      2.0, 0.2, B),
        S.spot_rents(jax.random.PRNGKey(1), 0.5, B))
    fleet = FleetBatch.for_scenario(grid, T)
    fleet_m = FleetBatch.from_scenario(grid, sc, T)
    fns = AlphaRR.fleet(fleet)
    base = run_fleet(fns, fleet_m, mesh=fleet_mesh(jax.devices()[:1]))
    for mesh in (fleet_mesh(jax.devices()[:1]), fleet_mesh()):
        for kw in ({}, {"chunk_size": 20}, {"chunk_size": 20, "stream": True}):
            fr = run_fleet(fns, fleet, scenario=sc, mesh=mesh, **kw)
            assert np.array_equal(fr.total, base.total), (mesh, kw)
            assert np.array_equal(fr.r_hist, base.r_hist), (mesh, kw)
            assert np.array_equal(fr.level_slots, base.level_slots), (mesh, kw)
    bo = offline_opt_fleet(fleet_m, mesh=fleet_mesh(jax.devices()[:1]))
    fo = offline_opt_fleet(fleet, scenario=sc, mesh=fleet_mesh(),
                           chunk_size=20)
    assert np.array_equal(fo.cost, bo.cost)
    assert np.array_equal(fo.r_hist, bo.r_hist)
    assert np.array_equal(fo.sim.total, bo.sim.total)
    print("MULTI-DEVICE-SCENARIO-OK")
""")


def test_scenario_multi_device_bitwise():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MULTI-DEVICE-SCENARIO-OK" in out.stdout
