"""Checkpointing, restart, straggler mitigation, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (TrainSupervisor, accumulate_with_deadline,
                                         ef_int8_roundtrip, compressed_bytes_fraction)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": [jnp.ones((3,)), jnp.zeros((2, 2))]},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t, extras={"note": "hi"})
    step, restored, extras = ckpt.restore(tmp_path, t)
    assert step == 3 and extras["note"] == "hi"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_gc(tmp_path):
    t = _tree()
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(tmp_path, s, t, keep_last=2)
    assert ckpt.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2
    # a stale tmp dir never wins
    (tmp_path / "step_00000099.tmp").mkdir()
    assert ckpt.latest_step(tmp_path) == 5


def test_supervisor_recovers_from_injected_faults(tmp_path):
    state = {"x": jnp.zeros(()), "v": jnp.arange(4.0)}

    def step_fn(state, batch):
        return {"x": state["x"] + batch, "v": state["v"]}

    crashed = {"done": False}

    def injector(step, retries):
        if step == 7 and not crashed["done"] and retries == 0:
            crashed["done"] = True
            raise RuntimeError("simulated node failure")

    sup = TrainSupervisor(tmp_path, save_every=2, max_retries=2)
    out = sup.run(state, step_fn, lambda s: jnp.float32(1.0), 10,
                  fault_injector=injector)
    assert float(out["x"]) == 10.0          # retried step not double-counted
    assert sup.failures and sup.failures[0][0] == 7
    # resume path
    start, resumed = sup.resume_or_init(state)
    assert start == 10 and float(resumed["x"]) == 10.0


def test_straggler_deadline_skip():
    import time as _t
    calls = []

    def make(i, slow=False):
        def f():
            calls.append(i)
            if slow:
                _t.sleep(0.2)
            return {"g": jnp.float32(i)}
        return f

    fns = [make(0), make(1, slow=True), make(2), make(3)]
    acc, rep = accumulate_with_deadline(fns, deadline_s=0.05)
    assert rep.used >= 2 and rep.skipped >= 1
    assert float(acc["g"]) == pytest.approx(np.mean(calls[:rep.used]))
    with pytest.raises(TimeoutError):
        accumulate_with_deadline([make(0), make(1, slow=True)] * 4,
                                 deadline_s=1e-9, min_fraction=0.9)


def test_ef_int8_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    deq1, err1 = ef_int8_roundtrip(g, None)
    # bounded quantisation error
    assert float(jnp.max(jnp.abs(deq1["w"] - g["w"]))) < float(jnp.max(jnp.abs(g["w"]))) / 100
    # error feedback: residual is carried, so the running sum converges
    total_true = jax.tree.map(lambda a: a * 3.0, g)
    acc = jax.tree.map(jnp.zeros_like, g)
    err = None
    for _ in range(3):
        deq, err = ef_int8_roundtrip(g, err)
        acc = jax.tree.map(jnp.add, acc, deq)
    resid = float(jnp.max(jnp.abs(acc["w"] - total_true["w"])))
    one_shot = float(jnp.max(jnp.abs(deq1["w"] * 3 - total_true["w"]))) * 3
    assert resid <= one_shot + 1e-6
    assert compressed_bytes_fraction(g) < 0.27


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint written from one sharding restores onto another (the
    single-process stand-in for elastic rescaling; the 8-device variant is
    exercised in test_distributed.py)."""
    from jax.sharding import PartitionSpec as P, NamedSharding
    t = {"w": jnp.arange(32.0).reshape(8, 4)}
    ckpt.save(tmp_path, 0, t)
    mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
    sh = {"w": NamedSharding(mesh, P("data", None))}
    step, restored, _ = ckpt.restore_sharded(tmp_path, t, sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
    assert restored["w"].sharding == sh["w"]
