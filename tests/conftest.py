import os
import sys

# Tests see exactly ONE device by default (the dry-run sets its own
# XLA_FLAGS in a subprocess); make sure nothing leaked into the environment.
# REPRO_FORCE_DEVICES=N is the explicit opt-in the CI multi-device leg uses
# to run the sharding/MC/DP bit-identity suites on a forced-N-CPU-device
# platform directly (not just via their in-test subprocess spawns).
os.environ.pop("XLA_FLAGS", None)
_forced = os.environ.get("REPRO_FORCE_DEVICES")
if _forced:
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={int(_forced)}"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Offline containers lack `hypothesis`; install the deterministic shim so the
# property-test modules still collect and run (see _hypothesis_shim.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim
    _hypothesis_shim.install()


# pytest re-arms the default warning filters per test, overriding the
# module-level ignore in core/fleet.py; the donation advisory (a donated
# slab whose shape can't alias any output on CPU) is expected and benign.
def pytest_configure(config):
    config.addinivalue_line(
        "filterwarnings", "ignore:Some donated buffers were not usable")
