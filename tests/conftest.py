import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS in a
# subprocess); make sure nothing leaked into the environment.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Offline containers lack `hypothesis`; install the deterministic shim so the
# property-test modules still collect and run (see _hypothesis_shim.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim
    _hypothesis_shim.install()
