"""Fleet engine correctness (core/fleet.py), per the PR-2 acceptance bar:

* sharded fleet == ``run_policy_batch`` **bit-for-bit** on a 1-device mesh,
  and on a multi-device mesh (forced-CPU devices, run in a subprocess since
  the test process is pinned to one device);
* a mixed-horizon fleet matches per-instance ``run_policy`` /
  ``offline_opt`` at each instance's *own* T, for every policy family;
* chunked / streamed execution == unchunked, for every policy and the
  offline DP, including a chunk size that does not divide T.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.arrivals import GilbertElliot
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import (FleetBatch, evaluate_schedule_fleet,
                              offline_opt_fleet, run_fleet)
from repro.core.policies import (ABCPolicy, AlphaRR, MDPPolicy, RetroRenting,
                                 StaticPolicy, offline_opt, offline_opt_batch)
from repro.core.simulator import (evaluate_schedule_batch, run_policy,
                                  run_policy_batch)
from repro.sharding.specs import fleet_mesh

T = 48
CHUNKS = [16, 20]      # 20 does not divide 48: exercises the padded tail


def mixed_costs(B=6):
    """K in {2, 3, 5} interleaved (same scheme as test_batched_engine)."""
    rng = np.random.default_rng(0)
    out = []
    for i in range(B):
        M = float(rng.choice([2.0, 4.0, 10.0]))
        kind = i % 3
        if kind == 0:
            out.append(HostingCosts.two_level(M))
        elif kind == 1:
            out.append(HostingCosts.three_level(M, 0.25 + 0.125 * (i % 3),
                                                0.125 * (1 + i % 5)))
        else:
            out.append(HostingCosts(M=M, levels=(0.0, 0.3, 0.4, 0.5, 1.0),
                                    g=(1.0, 0.4, 0.3, 0.15, 0.0)))
    return out


@pytest.fixture(scope="module")
def stacked():
    costs_list = mixed_costs()
    grid = HostingGrid.from_costs(costs_list)
    rng = np.random.default_rng(7)
    x = rng.integers(0, 3, (grid.B, T))
    c = rng.integers(1, 16, (grid.B, T)) / 8.0
    side = rng.integers(0, 2, (grid.B, T))
    ges = [GilbertElliot(p_hl=0.3, p_lh=0.2 + 0.1 * (i % 3),
                         rate_h=2.0 + i % 2, rate_l=0.2)
           for i in range(grid.B)]
    c_means = [float(np.mean(c[i])) for i in range(grid.B)]
    return costs_list, grid, x, c, side, ges, c_means


def policy_cases(fleet, costs_list, ges, c_means):
    """(name, PolicyFns, accounting fleet, per-instance factory) for every
    policy family."""
    f2 = fleet.restrict_to_endpoints()
    return [
        ("alpha-RR", AlphaRR.fleet(fleet), fleet,
         lambda cc, i: AlphaRR(cc)),
        ("RR", RetroRenting.fleet(fleet), f2,
         lambda cc, i: RetroRenting(cc)),
        ("static", StaticPolicy.fleet(fleet, fleet.grid.top_index()), fleet,
         lambda cc, i: StaticPolicy(cc, cc.K - 1)),
        ("MDP", MDPPolicy.fleet(fleet, costs_list, ges, c_means), fleet,
         lambda cc, i: MDPPolicy(cc, ges[i], c_means[i])),
        ("ABC", ABCPolicy.fleet(fleet, costs_list, ges, c_means), fleet,
         lambda cc, i: ABCPolicy(cc, ges[i], c_means[i])),
    ]


def assert_bitwise_equal(fr, batch):
    assert np.array_equal(fr.total, batch.total)
    assert np.array_equal(fr.rent, batch.rent)
    assert np.array_equal(fr.service, batch.service)
    assert np.array_equal(fr.fetch, batch.fetch)
    assert np.array_equal(fr.r_hist, batch.r_hist)
    assert np.array_equal(fr.level_slots, batch.level_slots)


# ----------------------------------------------------------------------
# Sharded fleet == run_policy_batch (1-device mesh in-process).
# ----------------------------------------------------------------------

def test_fleet_matches_batch_one_device(stacked):
    costs_list, grid, x, c, side, ges, c_means = stacked
    fleet = FleetBatch.from_dense(grid, x, c, side=side)
    mesh = fleet_mesh()
    for name, fns, acct, _ in policy_cases(fleet, costs_list, ges, c_means):
        batch = run_policy_batch(fns, acct.grid, x, c, side=side)
        fr = run_fleet(fns, acct, mesh=mesh)
        assert_bitwise_equal(fr, batch)


def test_fleet_dp_matches_batch_dp(stacked):
    costs_list, grid, x, c, side, ges, c_means = stacked
    fleet = FleetBatch.from_dense(grid, x, c)
    bo = offline_opt_batch(grid, x, c)
    fo = offline_opt_fleet(fleet)
    assert np.array_equal(fo.cost, bo.cost)
    assert np.array_equal(fo.r_hist, bo.r_hist)
    assert np.array_equal(fo.sim.total, bo.sim.total)


def test_fleet_schedule_eval_matches_batch(stacked):
    costs_list, grid, x, c, side, ges, c_means = stacked
    rng = np.random.default_rng(11)
    r = np.stack([rng.integers(0, cc.K, T) for cc in costs_list])
    fleet = FleetBatch.from_dense(grid, x, c)
    batch = evaluate_schedule_batch(grid, r, x, c)
    fr = evaluate_schedule_fleet(fleet, r)
    assert_bitwise_equal(fr, batch)
    frc = evaluate_schedule_fleet(fleet, r, chunk_size=CHUNKS[1])
    assert_bitwise_equal(frc, batch)


# ----------------------------------------------------------------------
# Mixed horizons: each instance at its own T.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("include_final_fetch", [True, False])
def test_mixed_horizons_match_per_instance(stacked, include_final_fetch):
    costs_list, grid, x, c, side, ges, c_means = stacked
    Ts = [48, 37, 23, 48, 11, 30]
    xs = [x[i, :t] for i, t in enumerate(Ts)]
    cs = [c[i, :t] for i, t in enumerate(Ts)]
    sides = [side[i, :t] for i, t in enumerate(Ts)]
    fleet = FleetBatch.from_instances(costs_list, xs, cs, sides=sides)
    for name, fns, acct, make in policy_cases(fleet, costs_list, ges, c_means):
        fr = run_fleet(fns, acct, include_final_fetch=include_final_fetch)
        for i, cc in enumerate(costs_list):
            pol = make(cc, i)
            single = run_policy(pol, pol.costs, xs[i], cs[i], side=sides[i],
                                include_final_fetch=include_final_fetch)
            assert fr.total[i] == single.total, (name, i)
            assert fr.fetch[i] == single.fetch, (name, i)
            assert np.array_equal(fr.r_hist[i, :Ts[i]], single.r_hist), (name, i)
            K_i = 2 if name == "RR" else cc.K
            assert np.array_equal(fr.level_slots[i][:K_i],
                                  single.level_slots), (name, i)
            assert fr.level_slots[i][K_i:].sum() == 0, (name, i)


def test_mixed_horizons_dp_matches_per_instance(stacked):
    costs_list, grid, x, c, side, ges, c_means = stacked
    Ts = [48, 37, 23, 48, 11, 30]
    xs = [x[i, :t] for i, t in enumerate(Ts)]
    cs = [c[i, :t] for i, t in enumerate(Ts)]
    fleet = FleetBatch.from_instances(costs_list, xs, cs)
    fo = offline_opt_fleet(fleet)
    for i, cc in enumerate(costs_list):
        single = offline_opt(cc, xs[i], cs[i])
        assert fo.cost[i] == pytest.approx(single.cost, abs=1e-9)
        assert np.array_equal(fo.r_hist[i, :Ts[i]], single.r_hist)
        assert fo.sim.total[i] == single.sim.total
        # frozen past the horizon: the tail repeats the last valid level
        if Ts[i] < fleet.T_max:
            assert np.all(fo.r_hist[i, Ts[i]:] == fo.r_hist[i, Ts[i] - 1])


# ----------------------------------------------------------------------
# Chunked / streamed == unchunked.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("chunk", CHUNKS)
def test_chunked_equals_unchunked_every_policy(stacked, chunk):
    costs_list, grid, x, c, side, ges, c_means = stacked
    Ts = [48, 37, 23, 48, 11, 30]
    xs = [x[i, :t] for i, t in enumerate(Ts)]
    cs = [c[i, :t] for i, t in enumerate(Ts)]
    sides = [side[i, :t] for i, t in enumerate(Ts)]
    fleet = FleetBatch.from_instances(costs_list, xs, cs, sides=sides)
    for name, fns, acct, _ in policy_cases(fleet, costs_list, ges, c_means):
        base = run_fleet(fns, acct)
        chunked = run_fleet(fns, acct, chunk_size=chunk)
        streamed = run_fleet(fns, acct, chunk_size=chunk, stream=True)
        for fr in (chunked, streamed):
            assert_bitwise_equal(fr, base)


@pytest.mark.parametrize("chunk", CHUNKS)
def test_chunked_equals_unchunked_dp(stacked, chunk):
    costs_list, grid, x, c, side, ges, c_means = stacked
    Ts = [48, 37, 23, 48, 11, 30]
    xs = [x[i, :t] for i, t in enumerate(Ts)]
    cs = [c[i, :t] for i, t in enumerate(Ts)]
    fleet = FleetBatch.from_instances(costs_list, xs, cs)
    base = offline_opt_fleet(fleet)
    chunked = offline_opt_fleet(fleet, chunk_size=chunk)
    assert np.array_equal(chunked.cost, base.cost)
    assert np.array_equal(chunked.r_hist, base.r_hist)
    assert np.array_equal(chunked.sim.total, base.sim.total)


def test_model2_service_fleet_chunked(stacked):
    """Realized [B, T, K] service costs ride through chunking unchanged."""
    import jax
    from repro.core.simulator import model2_service_matrix
    costs_list, grid, x, c, side, ges, c_means = stacked
    R = int(x.max())
    svc = np.zeros((grid.B, T, grid.K))
    for i, cc in enumerate(costs_list):
        svc[i, :, :cc.K] = np.asarray(model2_service_matrix(
            jax.random.PRNGKey(i), cc, x[i], max_per_slot=R))
    fleet = FleetBatch.from_dense(grid, x, c, svc=svc)
    fns = AlphaRR.fleet(fleet)
    batch = run_policy_batch(AlphaRR.batch(grid), grid, x, c, svc=svc)
    base = run_fleet(fns, fleet)
    assert_bitwise_equal(base, batch)
    for fr in (run_fleet(fns, fleet, chunk_size=CHUNKS[1]),
               run_fleet(fns, fleet, chunk_size=CHUNKS[1], stream=True)):
        assert_bitwise_equal(fr, base)


# ----------------------------------------------------------------------
# Multi-device mesh (forced CPU devices; subprocess, since this process is
# pinned to one device by conftest).
# ----------------------------------------------------------------------

_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    assert jax.device_count() == 4, jax.devices()
    from repro.core.costs import HostingCosts, HostingGrid
    from repro.core.fleet import FleetBatch, offline_opt_fleet, run_fleet
    from repro.core.policies import AlphaRR, offline_opt_batch
    from repro.core.simulator import run_policy_batch
    from repro.sharding.specs import fleet_mesh

    rng = np.random.default_rng(3)
    # B=6 is not a multiple of 4: exercises dummy-instance padding
    costs_list = [HostingCosts.three_level(4.0 + i, 0.3, 0.4) for i in range(5)]
    costs_list.append(HostingCosts.two_level(4.0))
    grid = HostingGrid.from_costs(costs_list)
    x = rng.integers(0, 3, (6, 48)); c = rng.integers(1, 16, (6, 48)) / 8.0
    batch = run_policy_batch(AlphaRR.batch(grid), grid, x, c)
    fleet = FleetBatch.from_dense(grid, x, c)
    for mesh in (fleet_mesh(jax.devices()[:1]), fleet_mesh()):
        for kw in ({}, {"chunk_size": 20}, {"chunk_size": 20, "stream": True}):
            fr = run_fleet(AlphaRR.fleet(fleet), fleet, mesh=mesh, **kw)
            assert np.array_equal(fr.total, batch.total), (mesh, kw)
            assert np.array_equal(fr.r_hist, batch.r_hist), (mesh, kw)
            assert np.array_equal(fr.level_slots, batch.level_slots), (mesh, kw)
    bo = offline_opt_batch(grid, x, c)
    fo = offline_opt_fleet(fleet, mesh=fleet_mesh(), chunk_size=20)
    assert np.array_equal(fo.cost, bo.cost)
    assert np.array_equal(fo.r_hist, bo.r_hist)
    assert np.array_equal(fo.sim.total, bo.sim.total)
    print("MULTI-DEVICE-OK")
""")


def test_fleet_multi_device_bitwise():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MULTI-DEVICE-OK" in out.stdout
