"""Figs 17-22 (Model 2, Gilbert-Elliot Poisson arrivals): alpha-RR vs RR vs
the statistics-aware MDP and ABC baselines; three transition regimes;
alpha=0.16, g(alpha)=0.76 (the Fig-23 operating point), M=50 / c sweeps.

Fused MC driver: one instance per (regime x sweep point) grid point; the
(regime) cell shares one base sample path (shared keys) and the engine
folds the ``n_seeds`` Monte-Carlo axis into every stream key.  alpha-RR
and RR run as ONE fused ``run_fleet`` (family stacking — same step
function); MDP and ABC keep their own ``run_fleet`` each (different step
shapes), still seed-fused.  The GE scenario emits the chain state as
side-state, which is exactly what the batched MDP/ABC policies observe.
Rows are seed-means with 95% CIs.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import scenarios as S
from repro.core.arrivals import GilbertElliot
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import FleetBatch, mc_stats, run_fleet
from repro.core.policies import ABCPolicy, MDPPolicy
from benchmarks.common import fused_policy_families

ALPHA, G_ALPHA = 0.16, 0.76
REGIMES = {
    "sym":   dict(p_hl=0.4, p_lh=0.4, rate_h=200.0, rate_l=10.0),   # Figs 17/18
    "slow":  dict(p_hl=0.2, p_lh=0.1, rate_h=200.0, rate_l=10.0),   # Figs 19/20
    "asym":  dict(p_hl=0.8, p_lh=0.1, rate_h=200.0, rate_l=10.0),   # Figs 21/22
}
MAX_PER_SLOT = 260
C_SWEEP = [5.0, 20.0, 80.0, 160.0, 320.0]
M_SWEEP = [10.0, 50.0, 150.0]
CHUNK = 512    # bound the fused [chunk, R, K] service draws


def run(T=3000, seed=0, n_seeds=4):
    costs_list, ges, c_means, meta, kxs, kcs, ksvcs = [], [], [], [], [], [], []
    # dict.fromkeys dedups the (M=50, c=20) point the two sweeps share — a
    # duplicate instance would double-count nothing now (seeds live in the
    # engine) but would still plot twice
    sweep = list(dict.fromkeys([(50.0, cm) for cm in C_SWEEP]
                               + [(M, 20.0) for M in M_SWEEP]))
    for ri, (regime, kw) in enumerate(REGIMES.items()):
        ge = GilbertElliot(emission="poisson", **kw)
        kx, kc, ksvc = jax.random.split(jax.random.PRNGKey(seed + 101 * ri), 3)
        for M, c_mean in sweep:
            c_lo, c_hi = S.spot_bounds(c_mean)
            costs_list.append(HostingCosts.three_level(
                M, ALPHA, G_ALPHA, c_min=c_lo, c_max=c_hi))
            ges.append(ge)
            c_means.append(c_mean)
            # the whole regime cell shares one base sample path; the MC
            # axis comes from the engine's per-replica key fold
            kxs.append(kx)
            kcs.append(kc)
            ksvcs.append(ksvc)
            meta.append({"regime": regime, "M": M, "c": c_mean})

    grid = HostingGrid.from_costs(costs_list)
    B = grid.B
    kxs, kcs, ksvcs = np.stack(kxs), np.stack(kcs), np.stack(ksvcs)
    p_hl = np.asarray([ge.p_hl for ge in ges], np.float32)
    p_lh = np.asarray([ge.p_lh for ge in ges], np.float32)
    r_h = np.asarray([ge.rate_h for ge in ges], np.float32)
    r_l = np.asarray([ge.rate_l for ge in ges], np.float32)
    cm_arr = np.asarray(c_means, np.float32)

    def scenario_fn(g):
        return S.combine(
            S.ge_arrivals(kxs, p_hl, p_lh, r_h, r_l, B),
            S.spot_rents(kcs, cm_arr, B),
            svc=S.model2_service(ksvcs, g.g, B, MAX_PER_SLOT))

    # alpha-RR + RR: one fused family run; MDP/ABC: own step shapes
    fam = fused_policy_families(costs_list, scenario_fn, T, n_seeds=n_seeds,
                                chunk_size=CHUNK, run_opt=False)
    fleet = FleetBatch.for_scenario(grid, T)
    sc = scenario_fn(grid)
    kw = dict(scenario=sc, chunk_size=CHUNK, n_seeds=n_seeds)
    mdp = run_fleet(MDPPolicy.fleet(fleet, costs_list, ges, c_means),
                    fleet, **kw)
    abc = run_fleet(ABCPolicy.fleet(fleet, costs_list, ges, c_means),
                    fleet, **kw)

    ar_bs, rr_bs = fam.split(fam.online.total)
    cols = {"alpha-RR": ar_bs / T, "RR": rr_bs / T,
            "MDP": mdp.seed_view(mdp.total) / T,
            "ABC": abc.seed_view(abc.total) / T}
    stats = {k: mc_stats(v, axis=1) for k, v in cols.items()}
    hist_bs, _ = fam.split(fam.online.level_slots)
    rows = []
    for i, m in enumerate(meta):
        row = {**m, "n_seeds": n_seeds}
        for k, (mean, ci) in stats.items():
            row[k] = float(mean[i])
            row[f"{k}_ci95"] = float(ci[i])
        row["hist"] = hist_bs[i].mean(axis=0)[:costs_list[i].K].tolist()
        rows.append(row)
    return rows


def check(rows):
    """Paper's takeaways (Figs 17-22): alpha-RR is comparable with the
    statistics-aware MDP/ABC *without* knowing the statistics (within a small
    constant factor; Fig 17 itself shows alpha-RR above MDP for mid-range
    rents); all policies converge at extreme rents; in the slow/asymmetric
    regimes alpha-RR leverages partial hosting against RR."""
    for r in rows:
        assert r["alpha-RR"] <= 3.5 * max(r["MDP"], 1e-9) + 10.0, r
    hi = [r for r in rows if r["c"] >= 320.0]
    for r in hi:
        spread = (max(r["alpha-RR"], r["RR"], r["MDP"])
                  - min(r["alpha-RR"], r["RR"], r["MDP"]))
        assert spread <= 0.30 * max(r["MDP"], 1.0) + 5.0, r
    slow = [r for r in rows if r["regime"] in ("slow", "asym")]
    wins = sum(1 for r in slow if r["alpha-RR"] <= r["RR"] * 1.05 + 1.0)
    assert wins >= 0.6 * len(slow), (wins, len(slow))
    return True
