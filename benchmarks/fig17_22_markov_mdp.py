"""Figs 17-22 (Model 2, Gilbert-Elliot Poisson arrivals): alpha-RR vs RR vs
the statistics-aware MDP and ABC baselines; three transition regimes;
alpha=0.16, g(alpha)=0.76 (the Fig-23 operating point), M=50 / c sweeps."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts
from repro.core.costs import HostingCosts
from repro.core.policies import AlphaRR, RetroRenting, MDPPolicy, ABCPolicy
from repro.core.simulator import run_policy, model2_service_matrix

ALPHA, G_ALPHA = 0.16, 0.76
REGIMES = {
    "sym":   dict(p_hl=0.4, p_lh=0.4, rate_h=200.0, rate_l=10.0),   # Figs 17/18
    "slow":  dict(p_hl=0.2, p_lh=0.1, rate_h=200.0, rate_l=10.0),   # Figs 19/20
    "asym":  dict(p_hl=0.8, p_lh=0.1, rate_h=200.0, rate_l=10.0),   # Figs 21/22
}


def _suite(costs, x, c, states, ge, c_mean, key):
    svc = model2_service_matrix(key, costs, x, max_per_slot=260)
    svc2 = np.asarray(svc)[:, [0, costs.K - 1]]
    res = {}
    res["alpha-RR"] = run_policy(AlphaRR(costs), costs, x, c, svc=svc).total
    rr = RetroRenting(costs)
    res["RR"] = run_policy(rr, rr.costs, x, c, svc=svc2).total
    res["MDP"] = run_policy(MDPPolicy(costs, ge, c_mean), costs, x, c,
                            svc=svc, side=states).total
    res["ABC"] = run_policy(ABCPolicy(costs, ge, c_mean), costs, x, c,
                            svc=svc, side=states).total
    hist = run_policy(AlphaRR(costs), costs, x, c, svc=svc).level_slots
    res["hist"] = hist.tolist()
    return res


def run(T=3000, seed=0):
    rows = []
    for regime, kw in REGIMES.items():
        ge = arrivals.GilbertElliot(emission="poisson", **kw)
        kx, kc, ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        x, states = ge.sample(kx, T, return_states=True)
        for c_mean in [5.0, 20.0, 80.0, 160.0, 320.0]:
            c = rentcosts.aws_spot_like(kc, c_mean, T)
            costs = HostingCosts.three_level(
                50.0, ALPHA, G_ALPHA, c_min=float(np.min(np.asarray(c))),
                c_max=float(np.max(np.asarray(c))))
            r = _suite(costs, x, c, states, ge, c_mean, ks)
            rows.append({"regime": regime, "M": 50.0, "c": c_mean,
                         **{k: (v / T if isinstance(v, float) else v)
                            for k, v in r.items()}})
        for M in [10.0, 50.0, 150.0]:
            c = rentcosts.aws_spot_like(kc, 20.0, T)
            costs = HostingCosts.three_level(
                M, ALPHA, G_ALPHA, c_min=float(np.min(np.asarray(c))),
                c_max=float(np.max(np.asarray(c))))
            r = _suite(costs, x, c, states, ge, 20.0, ks)
            rows.append({"regime": regime, "M": M, "c": 20.0,
                         **{k: (v / T if isinstance(v, float) else v)
                            for k, v in r.items()}})
    return rows


def check(rows):
    """Paper's takeaways (Figs 17-22): alpha-RR is comparable with the
    statistics-aware MDP/ABC *without* knowing the statistics (within a small
    constant factor; Fig 17 itself shows alpha-RR above MDP for mid-range
    rents); all policies converge at extreme rents; in the slow/asymmetric
    regimes alpha-RR leverages partial hosting against RR."""
    for r in rows:
        assert r["alpha-RR"] <= 3.5 * max(r["MDP"], 1e-9) + 10.0, r
    hi = [r for r in rows if r["c"] >= 320.0]
    for r in hi:
        spread = (max(r["alpha-RR"], r["RR"], r["MDP"])
                  - min(r["alpha-RR"], r["RR"], r["MDP"]))
        assert spread <= 0.30 * max(r["MDP"], 1.0) + 5.0, r
    slow = [r for r in rows if r["regime"] in ("slow", "asym")]
    wins = sum(1 for r in slow if r["alpha-RR"] <= r["RR"] * 1.05 + 1.0)
    assert wins >= 0.6 * len(slow), (wins, len(slow))
    return True
