"""Theorem-level numerical checks (the paper's analytical 'tables'):
Thm 2 ratio bound on adversarial instances, Thm 4 lower bounds > 1,
Thm 5 sigma bounds decaying to 1 with M, Corollary 3's universal 6.

The Thm-2 empirical worst ratio runs its 120 random instances as ONE
mixed-horizon fleet (``FleetBatch.from_instances`` + ``run_fleet`` /
``offline_opt_fleet``) instead of a per-instance ``run_policy`` loop —
fleet == per-instance is bit-exact (tests/test_fleet_engine.py), so the
ratio is unchanged and benchmarks/ has no per-instance simulation loop
left anywhere."""
from __future__ import annotations

import numpy as np

from repro.core.costs import HostingCosts
from repro.core.fleet import FleetBatch, offline_opt_fleet, run_fleet
from repro.core.policies import AlphaRR
from repro.core import bounds


def run(seed=0):
    rng = np.random.default_rng(seed)
    rows = []
    costs_list, xs, cs = [], [], []
    for i in range(120):
        alpha = rng.choice([0.25, 0.375, 0.5, 0.75])
        g = rng.choice([0.125, 0.25, 0.5])
        M = rng.choice([2.0, 4.0, 8.0])
        T = int(rng.choice([24, 40, 64]))   # mixed horizons, one fleet
        x = rng.integers(0, 2, T)
        c = rng.integers(1, 17, T) / 8.0
        costs_list.append(HostingCosts.three_level(
            M, alpha, g, c_min=float(c.min()), c_max=float(c.max())))
        xs.append(x)
        cs.append(c)
    fleet = FleetBatch.from_instances(costs_list, xs, cs)
    rr = run_fleet(AlphaRR.fleet(fleet), fleet, include_final_fetch=False)
    opt = offline_opt_fleet(fleet)
    nz = opt.cost > 1e-9
    worst = float(np.max(rr.total[nz] / opt.cost[nz]))
    bound_max = 0.0
    for alpha in [0.25, 0.5, 0.75]:
        for g in [0.1, 0.3, 0.5]:
            costs = HostingCosts.three_level(
                max(1.01, (1 - g) / alpha) * 1.1, alpha, g, 0.1, 2.0)
            bound_max = max(bound_max, bounds.corollary3_six(costs))
    rows.append({"check": "thm2_empirical_worst_ratio", "value": worst,
                 "bound": 6.0})
    rows.append({"check": "corollary3_max_bound", "value": bound_max,
                 "bound": 6.0})
    # Thm 4: lower bounds exceed 1 in the non-trivial regime
    lb = bounds.thm4_lower(HostingCosts.three_level(10, 0.4, 0.3, 0.2, 2.0))
    rows.append({"check": "thm4_lower", "value": lb, "bound": 1.0})
    # Thm 5: sigma upper bound decreases toward 1 as M grows (Remark 5)
    sig = []
    for M in [20.0, 50.0, 100.0, 200.0]:
        costs = HostingCosts.three_level(M, 0.3, 0.5, c_min=0.8, c_max=1.2)
        sig.append(bounds.thm5_sigma_upper(costs, p=0.9, c=1.0))  # interior of case 1
    rows.append({"check": "thm5_sigma_M20_200", "value": sig[-1],
                 "series": [round(s, 4) for s in sig]})
    return rows


def check(rows):
    d = {r["check"]: r for r in rows}
    assert d["thm2_empirical_worst_ratio"]["value"] <= 6.0 + 1e-6
    assert d["corollary3_max_bound"]["value"] <= 6.0 + 1e-9
    assert d["thm4_lower"]["value"] > 1.0
    s = d["thm5_sigma_M20_200"]["series"]
    assert all(a >= b - 1e-9 for a, b in zip(s, s[1:])), s   # decreasing in M
    assert s[-1] < 1.05                                       # -> 1
    return True
