"""Figs 3-6: cost per slot vs fetch cost M (Figs 3/4) and vs arrival
probability p (Figs 5/6), in the alpha+g(alpha)<1 and >=1 regimes.
Paper values: c=0.35; (alpha, g) = (0.239, 0.380) / (0.5, 0.7)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts
from repro.core.costs import HostingCosts
from benchmarks.common import policy_suite

C_MEAN = 0.35
REGIMES = {"lt1": (0.239, 0.380), "ge1": (0.5, 0.7)}


def _instance(key, p, T):
    kx, kc = jax.random.split(key)
    x = arrivals.bernoulli(kx, p, T)
    c = rentcosts.aws_spot_like(kc, C_MEAN, T)
    return x, c


def run(T=8000, seed=0):
    rows = []
    for regime, (alpha, g_alpha) in REGIMES.items():
        x, c = _instance(jax.random.PRNGKey(seed), 0.42, T)
        for M in [2.0, 5.0, 10.0, 20.0, 40.0]:
            costs = HostingCosts.three_level(M, alpha, g_alpha,
                                             c_min=float(np.min(np.asarray(c))),
                                             c_max=float(np.max(np.asarray(c))))
            rows.append({"fig": "3_4", "regime": regime, "M": M, "p": 0.42,
                         **policy_suite(costs, x, c)})
        for p in [0.15, 0.25, 0.35, 0.45, 0.6, 0.8]:
            x2, c2 = _instance(jax.random.PRNGKey(seed + 1), p, T)
            costs = HostingCosts.three_level(10.0, alpha, g_alpha,
                                             c_min=float(np.min(np.asarray(c2))),
                                             c_max=float(np.max(np.asarray(c2))))
            rows.append({"fig": "5_6", "regime": regime, "M": 10.0, "p": p,
                         **policy_suite(costs, x2, c2)})
    return rows


def check(rows):
    for r in rows:
        # online never beats its offline optimal; partial-capable OPT <= OPT
        assert r["alpha-RR"] >= r["alpha-OPT"] - 1e-6
        assert r["alpha-OPT"] <= r["OPT"] + 1e-6
        if r["regime"] == "ge1":
            assert abs(r["alpha-OPT"] - r["OPT"]) < 5e-3   # gap vanishes (Thm 1)
            assert r["alpha-RR"] <= r["RR"] + 5e-3
    return True
