"""Figs 3-6: cost per slot vs fetch cost M (Figs 3/4) and vs arrival
probability p (Figs 5/6), in the alpha+g(alpha)<1 and >=1 regimes.
Paper values: c=0.35; (alpha, g) = (0.239, 0.380) / (0.5, 0.7).

Batched: all (regime x M) and (regime x p) grid points x n_seeds sample
paths are stacked into one batch; rows are seed-means with 95% CIs.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts
from repro.core.costs import HostingCosts
from benchmarks.common import batch_policy_suite, mc_aggregate

C_MEAN = 0.35
REGIMES = {"lt1": (0.239, 0.380), "ge1": (0.5, 0.7)}
MS = [2.0, 5.0, 10.0, 20.0, 40.0]
PS = [0.15, 0.25, 0.35, 0.45, 0.6, 0.8]


def _instance(key, p, T):
    kx, kc = jax.random.split(key)
    x = np.asarray(arrivals.bernoulli(kx, p, T))
    c = np.asarray(rentcosts.aws_spot_like(kc, C_MEAN, T))
    return x, c


def run(T=8000, seed=0, n_seeds=4):
    costs_list, xs, cs, meta = [], [], [], []
    for s in range(n_seeds):
        x_m, c_m = _instance(jax.random.PRNGKey(seed + 101 * s), 0.42, T)
        p_paths = {p: _instance(jax.random.PRNGKey(seed + 101 * s + 1 + i), p, T)
                   for i, p in enumerate(PS)}
        for regime, (alpha, g_alpha) in REGIMES.items():
            for M in MS:
                costs_list.append(HostingCosts.three_level(
                    M, alpha, g_alpha, c_min=float(c_m.min()),
                    c_max=float(c_m.max())))
                xs.append(x_m)
                cs.append(c_m)
                meta.append({"fig": "3_4", "regime": regime, "M": M,
                             "p": 0.42, "seed": s})
            for p in PS:
                x2, c2 = p_paths[p]
                costs_list.append(HostingCosts.three_level(
                    10.0, alpha, g_alpha, c_min=float(c2.min()),
                    c_max=float(c2.max())))
                xs.append(x2)
                cs.append(c2)
                meta.append({"fig": "5_6", "regime": regime, "M": 10.0,
                             "p": p, "seed": s})
    suite = batch_policy_suite(costs_list, np.stack(xs), np.stack(cs))
    rows = [{**m, **{k: v for k, v in r.items() if k != "hist"}}
            for m, r in zip(meta, suite)]
    return mc_aggregate(rows, ["fig", "regime", "M", "p"])


def check(rows):
    for r in rows:
        # online never beats its offline optimal; partial-capable OPT <= OPT
        assert r["alpha-RR"] >= r["alpha-OPT"] - 1e-6
        assert r["alpha-OPT"] <= r["OPT"] + 1e-6
        if r["regime"] == "ge1":
            assert abs(r["alpha-OPT"] - r["OPT"]) < 5e-3   # gap vanishes (Thm 1)
            assert r["alpha-RR"] <= r["RR"] + 5e-3
    return True
