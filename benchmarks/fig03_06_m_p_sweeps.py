"""Figs 3-6: cost per slot vs fetch cost M (Figs 3/4) and vs arrival
probability p (Figs 5/6), in the alpha+g(alpha)<1 and >=1 regimes.
Paper values: c=0.35; (alpha, g) = (0.239, 0.380) / (0.5, 0.7).

Fused MC driver: one instance per (regime x M) and (regime x p) grid point
— the M-sweep points share one base sample path (shared keys), each p gets
its own (per-p keys) — and the Monte-Carlo axis is ``n_seeds`` folded into
those keys by the engine.  The whole figure is one fused ``run_fleet``
(alpha-RR + RR stacked) plus one ``offline_opt_fleet``.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import scenarios as S
from repro.core.costs import HostingCosts
from benchmarks.common import scenario_policy_suite

C_MEAN = 0.35
REGIMES = {"lt1": (0.239, 0.380), "ge1": (0.5, 0.7)}
MS = [2.0, 5.0, 10.0, 20.0, 40.0]
PS = [0.15, 0.25, 0.35, 0.45, 0.6, 0.8]


def run(T=8000, seed=0, n_seeds=4):
    c_lo, c_hi = S.spot_bounds(C_MEAN)
    km = jax.random.split(jax.random.PRNGKey(seed))
    kp = {p: jax.random.split(jax.random.PRNGKey(seed + 1 + i))
          for i, p in enumerate(PS)}
    costs_list, meta, kxs, kcs, ps = [], [], [], [], []
    for regime, (alpha, g_alpha) in REGIMES.items():
        for M in MS:
            costs_list.append(HostingCosts.three_level(
                M, alpha, g_alpha, c_min=c_lo, c_max=c_hi))
            kxs.append(km[0])
            kcs.append(km[1])
            ps.append(0.42)
            meta.append({"fig": "3_4", "regime": regime, "M": M, "p": 0.42})
        for p in PS:
            costs_list.append(HostingCosts.three_level(
                10.0, alpha, g_alpha, c_min=c_lo, c_max=c_hi))
            kxs.append(kp[p][0])
            kcs.append(kp[p][1])
            ps.append(p)
            meta.append({"fig": "5_6", "regime": regime, "M": 10.0, "p": p})
    kxs, kcs = np.stack(kxs), np.stack(kcs)
    ps = np.asarray(ps, np.float32)

    def scenario_fn(grid):
        return S.combine(S.bernoulli_arrivals(kxs, ps, grid.B),
                         S.spot_rents(kcs, C_MEAN, grid.B))

    suite = scenario_policy_suite(costs_list, scenario_fn, T,
                                  n_seeds=n_seeds, x_means=ps, c_means=C_MEAN)
    return [{**m, **{k: v for k, v in r.items() if k != "hist"}}
            for m, r in zip(meta, suite)]


def check(rows):
    for r in rows:
        # online never beats its offline optimal; partial-capable OPT <= OPT
        assert r["alpha-RR"] >= r["alpha-OPT"] - 1e-6
        assert r["alpha-OPT"] <= r["OPT"] + 1e-6
        if r["regime"] == "ge1":
            assert abs(r["alpha-OPT"] - r["OPT"]) < 5e-3   # gap vanishes (Thm 1)
            assert r["alpha-RR"] <= r["RR"] + 5e-3
    return True
