"""Figs 23-25: the shortest-path-service pipeline — g(alpha) curve from the
(synthetic-city) trajectory dataset via Dijkstra + normalised-hit-rate
knapsack; then cost vs cache fraction (Fig 24) and cost vs M at the best
alpha (Fig 25).

Batched-engine port: the g-curve stays a host pipeline (Dijkstra /
knapsack), but the cost sweeps run as fleets on trace-playback scenarios —
ONE recorded (arrivals, rents) sample path replayed for every grid point
(``scenarios.trace_arrivals`` / ``trace_rents``), with the Model-2 service
uniforms drawn on device from a shared key so every alpha / M scores the
same realized requests (per-instance ``g`` columns bind each grid point's
knapsack operating point).  No per-instance ``run_policy`` loop remains.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts, geolife
from repro.core import scenarios as S
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import FleetBatch, offline_opt_fleet, run_fleet
from repro.core.policies import AlphaRR, RetroRenting

C_MEAN = 0.55   # operating point where the knapsack curve makes partial pay


def _sweep_scenario(grid, x, c, ksvc):
    """Trace playback of one shared sample path + fused coupled service
    draws at each instance's own g columns (Bernoulli arrivals: R=1)."""
    return S.combine(S.trace_arrivals(x, B=grid.B),
                     S.trace_rents(c, B=grid.B),
                     svc=S.model2_service(S.shared_keys(ksvc, grid.B),
                                          grid.g, grid.B, max_per_slot=1))


def run(T=4000, seed=0):
    alphas, gs, _ = geolife.gcurve_from_city(n_side=12, n_train=1200,
                                             n_test=400, seed=seed)
    rows = [{"fig": "23", "alpha": float(a), "g": float(g),
             "served": float(1 - g)} for a, g in zip(alphas, gs)]

    kx, kc, ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = np.asarray(arrivals.bernoulli(kx, 0.5, T))
    c = np.asarray(rentcosts.aws_spot_like(kc, C_MEAN, T))
    cmin, cmax = float(c.min()), float(c.max())

    # Fig 24: total cost vs cache fraction alpha (M = 10) — one fleet over
    # the whole knapsack curve
    points = [(float(a), float(g)) for a, g in zip(alphas, gs)
              if 0.0 < a < 1.0 and 0.0 < g < 1.0]
    costs24 = [HostingCosts.three_level(10.0, a, g, cmin, cmax)
               for a, g in points]
    grid24 = HostingGrid.from_costs(costs24)
    fleet24 = FleetBatch.for_scenario(grid24, T)
    ar24 = run_fleet(AlphaRR.fleet(fleet24), fleet24,
                     scenario=_sweep_scenario(grid24, x, c, ks))
    tots = ar24.total / T
    for (a, g), tot in zip(points, tots):
        rows.append({"fig": "24", "alpha": a, "alpha-RR": float(tot)})
    best = int(np.argmin(tots))
    a_star, g_star = points[best]

    # Fig 25: cost vs M at the best alpha — alpha-RR, RR and the
    # no-partial offline OPT as one fleet each
    Ms = [2.0, 5.0, 10.0, 20.0, 40.0]
    costs25 = [HostingCosts.three_level(M, a_star, g_star, cmin, cmax)
               for M in Ms]
    grid25 = HostingGrid.from_costs(costs25)
    fleet25 = FleetBatch.for_scenario(grid25, T)
    sc25 = _sweep_scenario(grid25, x, c, ks)
    g2 = grid25.restrict_to_endpoints()
    sc25_2 = _sweep_scenario(g2, x, c, ks)
    ar = run_fleet(AlphaRR.fleet(fleet25), fleet25, scenario=sc25)
    rr = run_fleet(RetroRenting.fleet(fleet25),
                   fleet25.restrict_to_endpoints(), scenario=sc25_2)
    opt = offline_opt_fleet(FleetBatch.for_scenario(g2, T), scenario=sc25_2)
    for i, M in enumerate(Ms):
        rows.append({"fig": "25", "alpha": a_star, "M": M,
                     "alpha-RR": ar.total[i] / T, "RR": rr.total[i] / T,
                     "OPT": opt.cost[i] / T,
                     "hist": ar.level_slots[i][:costs25[i].K].tolist()})
    return rows


def check(rows):
    curve = [(r["alpha"], r["g"]) for r in rows if r["fig"] == "23"]
    gs = [g for _, g in sorted(curve)]
    assert all(g1 >= g2 - 1e-9 for g1, g2 in zip(gs, gs[1:])), "g non-increasing"
    # footnote 1: saturates below full service even at alpha=1
    assert gs[-1] > 0.0
    f25 = [r for r in rows if r["fig"] == "25"]
    # Fig 25's headline: partial hosting pays — alpha-RR beats RR on average
    # over the M sweep and can even undercut the *no-partial offline* OPT.
    mean_ar = np.mean([r["alpha-RR"] for r in f25])
    mean_rr = np.mean([r["RR"] for r in f25])
    assert mean_ar <= mean_rr * 1.02 + 1e-6, (mean_ar, mean_rr)
    assert any(r["alpha-RR"] < r["OPT"] * 1.05 for r in f25)
    return True
