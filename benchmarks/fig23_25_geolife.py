"""Figs 23-25: the shortest-path-service pipeline — g(alpha) curve from the
(synthetic-city) trajectory dataset via Dijkstra + normalised-hit-rate
knapsack; then cost vs cache fraction (Fig 24) and cost vs M at the best
alpha (Fig 25).

Fused MC driver: the g-curve stays a host pipeline (Dijkstra / knapsack),
but the cost sweeps run as seed-fused fleets on trace-playback scenarios —
ONE recorded (arrivals, rents) sample path replayed for every grid point,
with the Model-2 service uniforms drawn on device from a shared key.  The
``n_seeds`` axis folds ONLY into the service-stream key (trace streams are
keyless and replicate identically), so the CIs quantify Model-2 service
randomness on a fixed workload; every alpha / M still scores the same
realized requests within a seed.  Fig 25 is one fused ``run_fleet``
(alpha-RR + RR stacked) plus one ``offline_opt_fleet``.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts, geolife
from repro.core import scenarios as S
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import FleetBatch, mc_stats, run_fleet
from repro.core.policies import AlphaRR
from benchmarks.common import scenario_policy_suite

C_MEAN = 0.55   # operating point where the knapsack curve makes partial pay


def _sweep_scenario_fn(x, c, ksvc):
    """Trace playback of one shared sample path + fused coupled service
    draws at each instance's own g columns (Bernoulli arrivals: R=1)."""
    def scenario_fn(grid):
        return S.combine(S.trace_arrivals(x, B=grid.B),
                         S.trace_rents(c, B=grid.B),
                         svc=S.model2_service(S.shared_keys(ksvc, grid.B),
                                              grid.g, grid.B, max_per_slot=1))
    return scenario_fn


def run(T=4000, seed=0, n_seeds=4):
    alphas, gs, _ = geolife.gcurve_from_city(n_side=12, n_train=1200,
                                             n_test=400, seed=seed)
    rows = [{"fig": "23", "alpha": float(a), "g": float(g),
             "served": float(1 - g)} for a, g in zip(alphas, gs)]

    kx, kc, ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = np.asarray(arrivals.bernoulli(kx, 0.5, T))
    c = np.asarray(rentcosts.aws_spot_like(kc, C_MEAN, T))
    cmin, cmax = float(c.min()), float(c.max())
    scenario_fn = _sweep_scenario_fn(x, c, ks)

    # Fig 24: total cost vs cache fraction alpha (M = 10) — one seed-fused
    # fleet over the whole knapsack curve
    points = [(float(a), float(g)) for a, g in zip(alphas, gs)
              if 0.0 < a < 1.0 and 0.0 < g < 1.0]
    costs24 = [HostingCosts.three_level(10.0, a, g, cmin, cmax)
               for a, g in points]
    grid24 = HostingGrid.from_costs(costs24)
    fleet24 = FleetBatch.for_scenario(grid24, T)
    ar24 = run_fleet(AlphaRR.fleet(fleet24), fleet24,
                     scenario=scenario_fn(grid24), n_seeds=n_seeds)
    mean24, ci24 = mc_stats(ar24.seed_view(ar24.total) / T, axis=1)
    for (a, g), tot, ci in zip(points, mean24, ci24):
        rows.append({"fig": "24", "alpha": a, "alpha-RR": float(tot),
                     "alpha-RR_ci95": float(ci), "n_seeds": n_seeds})
    best = int(np.argmin(mean24))
    a_star, g_star = points[best]

    # Fig 25: cost vs M at the best alpha — one fused fan-out run (alpha-RR
    # + RR lanes with both OPT frontiers co-executed in the same scan)
    Ms = [2.0, 5.0, 10.0, 20.0, 40.0]
    costs25 = [HostingCosts.three_level(M, a_star, g_star, cmin, cmax)
               for M in Ms]
    suite = scenario_policy_suite(costs25, scenario_fn, T, n_seeds=n_seeds,
                                  include_bounds=False,
                                  chunk_size=min(1000, T))
    for M, r in zip(Ms, suite):
        rows.append({"fig": "25", "alpha": a_star, "M": M, **r})
    return rows


def check(rows):
    curve = [(r["alpha"], r["g"]) for r in rows if r["fig"] == "23"]
    gs = [g for _, g in sorted(curve)]
    assert all(g1 >= g2 - 1e-9 for g1, g2 in zip(gs, gs[1:])), "g non-increasing"
    # footnote 1: saturates below full service even at alpha=1
    assert gs[-1] > 0.0
    f25 = [r for r in rows if r["fig"] == "25"]
    # Fig 25's headline: partial hosting pays — alpha-RR beats RR on average
    # over the M sweep and can even undercut the *no-partial offline* OPT.
    mean_ar = np.mean([r["alpha-RR"] for r in f25])
    mean_rr = np.mean([r["RR"] for r in f25])
    assert mean_ar <= mean_rr * 1.02 + 1e-6, (mean_ar, mean_rr)
    assert any(r["alpha-RR"] < r["OPT"] * 1.05 for r in f25)
    return True
