"""Figs 23-25: the shortest-path-service pipeline — g(alpha) curve from the
(synthetic-city) trajectory dataset via Dijkstra + normalised-hit-rate
knapsack; then cost vs cache fraction (Fig 24) and cost vs M at the best
alpha (Fig 25)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts, geolife
from repro.core.costs import HostingCosts
from repro.core.policies import AlphaRR, RetroRenting, offline_opt_no_partial
from repro.core.simulator import run_policy, model2_service_matrix

C_MEAN = 0.55   # operating point where the knapsack curve makes partial pay


def run(T=4000, seed=0):
    alphas, gs, _ = geolife.gcurve_from_city(n_side=12, n_train=1200,
                                             n_test=400, seed=seed)
    rows = [{"fig": "23", "alpha": float(a), "g": float(g),
             "served": float(1 - g)} for a, g in zip(alphas, gs)]

    kx, kc, ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = arrivals.bernoulli(kx, 0.5, T)
    c = rentcosts.aws_spot_like(kc, C_MEAN, T)
    cmin, cmax = float(np.min(np.asarray(c))), float(np.max(np.asarray(c)))

    # Fig 24: total cost vs cache fraction alpha (M = 10)
    best = (None, np.inf)
    for a, g in zip(alphas, gs):
        if not (0.0 < a < 1.0) or not (0.0 < g < 1.0):
            continue
        costs = HostingCosts.three_level(10.0, float(a), float(g), cmin, cmax)
        svc = model2_service_matrix(ks, costs, x)
        tot = run_policy(AlphaRR(costs), costs, x, c, svc=svc).total / T
        rows.append({"fig": "24", "alpha": float(a), "alpha-RR": tot})
        if tot < best[1]:
            best = (float(a), tot, float(g))
    a_star, _, g_star = best[0], best[1], best[2]

    # Fig 25: cost vs M at the best alpha
    for M in [2.0, 5.0, 10.0, 20.0, 40.0]:
        costs = HostingCosts.three_level(M, a_star, g_star, cmin, cmax)
        svc = model2_service_matrix(ks, costs, x)
        ar = run_policy(AlphaRR(costs), costs, x, c, svc=svc)
        rr = RetroRenting(costs)
        rrres = run_policy(rr, rr.costs, x, c,
                           svc=np.asarray(svc)[:, [0, 2]])
        opt = offline_opt_no_partial(costs, x, c, np.asarray(svc))
        rows.append({"fig": "25", "alpha": a_star, "M": M,
                     "alpha-RR": ar.total / T, "RR": rrres.total / T,
                     "OPT": opt.cost / T, "hist": ar.level_slots.tolist()})
    return rows


def check(rows):
    curve = [(r["alpha"], r["g"]) for r in rows if r["fig"] == "23"]
    gs = [g for _, g in sorted(curve)]
    assert all(g1 >= g2 - 1e-9 for g1, g2 in zip(gs, gs[1:])), "g non-increasing"
    # footnote 1: saturates below full service even at alpha=1
    assert gs[-1] > 0.0
    f25 = [r for r in rows if r["fig"] == "25"]
    # Fig 25's headline: partial hosting pays — alpha-RR beats RR on average
    # over the M sweep and can even undercut the *no-partial offline* OPT.
    mean_ar = np.mean([r["alpha-RR"] for r in f25])
    mean_rr = np.mean([r["RR"] for r in f25])
    assert mean_ar <= mean_rr * 1.02 + 1e-6, (mean_ar, mean_rr)
    assert any(r["alpha-RR"] < r["OPT"] * 1.05 for r in f25)
    return True
