"""Shared helpers for the paper-figure benchmarks.

Every paper figure is a Monte-Carlo estimate over sample paths of the
arrival/rent processes, evaluated for a handful of policy families on a
grid of cost parameters.  Both axes now live in the *engine*, not here:

* **MC axis** — figure modules declare one instance per *grid point* and
  pass ``n_seeds=S``; ``run_fleet`` / ``offline_opt_fleet`` fold the seed
  into every stream key server-side (``scenarios.replicate_seeds``) and
  return seed-replicated results with a ``[B, S]`` ``seed_view``.  No
  benchmark-layer per-seed stacking or key plumbing remains.
* **Policy-family axis** — ``fused_policy_families`` rides the engine's
  policy *fan-out* axis: one B-row fleet, lane 0 alpha-RR on the full
  grids, lane 1 RR on their endpoint restrictions, and (``run_opt``) the
  offline-DP forward frontier co-executed per lane — so a whole figure is
  ONE ``run_fleet`` call in which every workload slab is generated exactly
  once and stepped by every family.  Generation fuses into the scan — no
  observation array is ever materialized, on host or device.

``scenario_policy_suite`` builds the classic six-curve rows on top of
these (per grid point, seed-means with Student-t 95% CI columns);
``mc_aggregate`` collapses explicit per-seed dict rows the same way and
also accepts ``FleetResult`` / ``FleetOfflineResult`` objects directly
(expanding their seed axis internally — ``mc_summary`` in the engine is
the array-level equivalent, on the same t-quantiles).

The LB curves need arrival/rent *means*; the scenario suite takes them as
arguments (analytic means of the declared processes) since no realized
trace exists to average — the checks never read LB rows, they are plotted
reference curves.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import (FleetBatch, FleetOfflineResult, FleetResult,
                              mc_stats, run_fleet, student_t975)
from repro.core.policies import AlphaRR, RetroRenting, offline_opt_batch
from repro.core.simulator import run_policy_batch
from repro.core import bounds


def batch_policy_suite(costs_list: Sequence[HostingCosts], x, c, svc=None,
                       include_bounds: bool = True):
    """Cost-per-slot of the paper's curves for B stacked instances.

    Args:
      costs_list: B per-instance costs (mixed K allowed).
      x, c: [B, T] (or [T], broadcast) arrivals / rents.
      svc: optional [B, T, K] realized Model-2 service costs.

    Returns a list of B row dicts with the classic suite keys
    ('alpha-RR', 'RR', 'alpha-OPT', 'OPT', 'alpha-LB', 'LB'), the alpha-RR
    level histogram under 'hist', and '_us_per_slot' (batched alpha-RR
    wall time per simulated slot x instance).
    """
    grid = HostingGrid.from_costs(costs_list)
    B = grid.B
    x = np.asarray(x)
    c = np.asarray(c)
    xb = np.broadcast_to(x, (B, x.shape[-1]))
    cb = np.broadcast_to(c, (B, c.shape[-1]))
    T = xb.shape[1]

    fns = AlphaRR.batch(grid)
    run_policy_batch(fns, grid, xb, cb, svc=svc)   # warm the jit cache:
    t0 = time.time()                               # report steady-state, not
    ar = run_policy_batch(fns, grid, xb, cb, svc=svc)  # one-time compile
    us_per_slot = (time.time() - t0) / (B * T) * 1e6

    g2 = grid.restrict_to_endpoints()
    svc2 = None if svc is None else grid.endpoint_service(np.asarray(svc))
    rr = run_policy_batch(RetroRenting.batch(grid), g2, xb, cb, svc=svc2)
    aopt = offline_opt_batch(grid, xb, cb, svc=svc)
    opt = offline_opt_batch(g2, xb, cb, svc=svc2)

    rows = []
    for i, costs in enumerate(costs_list):
        row = {
            "alpha-RR": ar.total[i] / T,
            "RR": rr.total[i] / T,
            "alpha-OPT": aopt.cost[i] / T,
            "OPT": opt.cost[i] / T,
            "_us_per_slot": us_per_slot,
            "hist": ar.level_slots[i][:costs.K].tolist(),
        }
        if include_bounds:
            # the figures' LB curves are the Lemma-14 per-slot lower bounds
            # for any online policy, at the empirical arrival/rent means
            p_hat = float(np.mean(xb[i]))
            c_hat = float(np.mean(cb[i]))
            row["alpha-LB"] = bounds.lemma14_opt_on_per_slot(costs, p_hat, c_hat)
            row["LB"] = min(c_hat, p_hat)
        rows.append(row)
    return rows


# ----------------------------------------------------------------------
# The fused figure driver: one run_fleet for every online family of a
# figure, one offline_opt_fleet for both OPT curves, MC axis in the engine.
# ----------------------------------------------------------------------

class FamilyResults:
    """Results of one fused {full-grid, endpoint} family run.

    ``online`` / ``offline`` rows are laid out family-major (= policy-lane
    major) then instance-major then seed-minor: row ``(fam * B + b) * S +
    s`` — exactly ``FleetResult.policy_view``'s layout.  ``split(arr)``
    returns one ``[B, S, ...]`` view per family.
    """

    def __init__(self, online: FleetResult,
                 offline: Optional[FleetOfflineResult],
                 B: int, us_per_slot: float):
        self.online = online
        self.offline = offline
        self.B = B
        self.us_per_slot = us_per_slot

    def split(self, a):
        S = self.online.n_seeds
        a = np.asarray(a)
        a = a.reshape((-1, self.B, S) + a.shape[1:])
        return a[0], a[1]


def fused_policy_families(costs_list: Sequence[HostingCosts],
                          scenario_fn: Callable, T, *,
                          n_seeds: Optional[int] = None,
                          chunk_size: Optional[int] = None,
                          run_opt: bool = True) -> FamilyResults:
    """Run a figure's {alpha-RR, RR[, alpha-OPT, OPT]} curves as ONE fused
    ``run_fleet`` on the engine's policy fan-out axis.

    The family axis is the fan-out axis: lane 0 runs alpha-RR on the
    figure's own grids, lane 1 runs RR on their 2-level endpoint
    restrictions (``RetroRenting.fleet_lane`` — under a Model-2 scenario
    it gathers its two columns out of the shared svc slab, bitwise equal
    to a standalone endpoint run by stream-key coupling).  Each slab of
    the scenario is generated ONCE and stepped by both lanes — the fleet
    is B rows, not the old 2B stacked-row encoding, so generation work
    halves.  ``run_opt=True`` co-executes the per-lane offline-DP forward
    frontier inside the same fused scan (``with_opt_forward``); its
    per-lane minima ARE the OPT curve costs (bit-identical to
    ``offline_opt_fleet(checkpointed=True, collect_schedule=False)``), so
    a whole figure is literally one engine call.  ``scenario_fn(grid) ->
    Scenario`` is called once, on the full grid.  ``n_seeds`` rides
    through to the engine's MC axis.
    """
    B = len(costs_list)
    grid = HostingGrid.from_costs(list(costs_list))
    sc = scenario_fn(grid)
    Ts = np.broadcast_to(np.asarray(T, np.int32), (B,))
    fleet = FleetBatch.for_scenario(grid, Ts)
    lanes = [AlphaRR.fleet_lane(fleet),
             RetroRenting.fleet_lane(fleet, with_svc=sc.has_svc)]
    kw = dict(scenario=sc, chunk_size=chunk_size, n_seeds=n_seeds,
              with_opt_forward=run_opt)
    run_fleet(lanes, fleet, **kw)                  # warm the jit cache
    t0 = time.time()
    online = run_fleet(lanes, fleet, **kw)
    us = (time.time() - t0) / (float(np.sum(Ts)) * online.n_seeds) * 1e6
    offline = (FleetOfflineResult(cost=online.opt_cost, r_hist=None,
                                  sim=None, n_seeds=online.n_seeds)
               if run_opt else None)
    return FamilyResults(online, offline, B, us)


def scenario_policy_suite(costs_list: Sequence[HostingCosts],
                          scenario_fn: Callable, T: int, *,
                          n_seeds: Optional[int] = None,
                          x_means=None, c_means=None,
                          include_bounds: bool = True,
                          include_opt: bool = True,
                          chunk_size: Optional[int] = None):
    """The classic six-curve suite, one fused run per figure.

    Args:
      costs_list: B per-instance costs (mixed K allowed) — one per grid
        point; the Monte-Carlo axis is declared with ``n_seeds``, never by
        stacking replica rows here.
      scenario_fn: ``(grid: HostingGrid) -> Scenario`` factory; called
        once on the figure's grid — the RR lane gathers its endpoint
        columns out of the shared Model-2 svc slab.
      T: horizon (scalar or [B]).
      n_seeds: Monte-Carlo sample paths per grid point (engine-side seed
        fold).  When set, every numeric column gains a Student-t
        ``<col>_ci95`` sibling and rows carry ``n_seeds``.
      x_means / c_means: analytic per-instance arrival/rent means for the
        Lemma-14 LB curves (scalar or [B]); bounds are skipped if omitted.
      include_opt: False skips the offline DP (figures that only plot
        online curves), dropping the 'alpha-OPT'/'OPT' columns.
      chunk_size: forwarded to the engine (None = single chunk).

    Returns one row dict per *grid point* (seed axis already collapsed),
    with the same keys as ``batch_policy_suite`` plus the CI columns.
    """
    B = len(costs_list)
    fam = fused_policy_families(costs_list, scenario_fn, T,
                                n_seeds=n_seeds, chunk_size=chunk_size,
                                run_opt=include_opt)
    Ts = np.broadcast_to(np.asarray(T, np.float64), (B,))

    cols = OrderedDict()
    ar_bs, rr_bs = fam.split(fam.online.total)
    cols["alpha-RR"] = ar_bs / Ts[:, None]
    cols["RR"] = rr_bs / Ts[:, None]
    if include_opt:
        aopt_bs, opt_bs = fam.split(fam.offline.cost)
        cols["alpha-OPT"] = aopt_bs / Ts[:, None]
        cols["OPT"] = opt_bs / Ts[:, None]
    hist_bs, _ = fam.split(fam.online.level_slots)     # [B, S, K]

    if include_bounds and (x_means is None or c_means is None):
        include_bounds = False
    if include_bounds:
        x_means = np.broadcast_to(np.asarray(x_means, np.float64), (B,))
        c_means = np.broadcast_to(np.asarray(c_means, np.float64), (B,))

    stats = {k: mc_stats(v, axis=1) for k, v in cols.items()}
    rows = []
    for i, costs in enumerate(costs_list):
        row = {k: float(mean[i]) for k, (mean, _) in stats.items()}
        if n_seeds is not None:
            row.update({f"{k}_ci95": float(ci[i])
                        for k, (_, ci) in stats.items()})
            row["n_seeds"] = int(n_seeds)
        row["_us_per_slot"] = fam.us_per_slot
        row["hist"] = hist_bs[i].mean(axis=0)[:costs.K].tolist()
        if include_bounds:
            row["alpha-LB"] = bounds.lemma14_opt_on_per_slot(
                costs, float(x_means[i]), float(c_means[i]))
            row["LB"] = min(float(c_means[i]), float(x_means[i]))
        rows.append(row)
    return rows


def policy_suite(costs: HostingCosts, x, c, svc=None, include_bounds=True):
    """Cost-per-slot for the paper's six curves on ONE instance (the classic
    API, now a B=1 batch)."""
    svc_b = None if svc is None else np.asarray(svc)[None]
    row = batch_policy_suite([costs], np.asarray(x)[None], np.asarray(c)[None],
                             svc=svc_b, include_bounds=include_bounds)[0]
    row.pop("hist")
    return row


# ----------------------------------------------------------------------
# Monte-Carlo aggregation (explicit dict rows, or FleetResults directly).
# ----------------------------------------------------------------------

def fleet_result_rows(result):
    """Expand a seed-replicated ``FleetResult`` / ``FleetOfflineResult``
    into per-(instance, seed) dict rows — the bridge between the engine's
    array-shaped MC axis and the dict-row aggregation below."""
    if isinstance(result, FleetOfflineResult):
        fields = {"total": result.seed_view(result.cost)}
        S = result.n_seeds
    else:
        fields = {f: result.seed_view(getattr(result, f))
                  for f in ("total", "rent", "service", "fetch")}
        S = result.n_seeds
    B = next(iter(fields.values())).shape[0]
    return [{"instance": b, "seed": s,
             **{f: float(v[b, s]) for f, v in fields.items()}}
            for b in range(B) for s in range(S)]


def mc_aggregate(rows, group_keys: Sequence[str] = ("instance",),
                 drop=("seed", "hist")):
    """Collapse the seed axis: group ``rows`` by ``group_keys`` and replace
    every numeric value column v with its mean plus a ``v_ci95`` column
    (t_{.975, n-1} * sem).  Non-numeric / dropped columns keep the first
    row's value.  'hist' columns (lists) are averaged elementwise.

    ``rows`` may also be a ``FleetResult`` / ``FleetOfflineResult`` from a
    ``n_seeds=S`` engine run: its seed axis is expanded to per-seed rows
    (``fleet_result_rows``) and aggregated per instance — numerically
    identical to ``core.fleet.mc_summary`` on the same result (both use
    ``student_t975``)."""
    if isinstance(rows, (FleetResult, FleetOfflineResult)):
        rows = fleet_result_rows(rows)
        group_keys = ["instance"]
    groups: "OrderedDict[tuple, list]" = OrderedDict()
    for r in rows:
        groups.setdefault(tuple(r[k] for k in group_keys), []).append(r)
    out = []
    for key, grp in groups.items():
        agg = dict(zip(group_keys, key))
        agg["n_seeds"] = len(grp)
        for col, v0 in grp[0].items():
            if col in group_keys or col == "seed":
                continue
            if col == "hist" and isinstance(v0, list):
                agg["hist"] = np.mean([g["hist"] for g in grp], axis=0).tolist()
                continue
            if isinstance(v0, bool) or not isinstance(v0, (int, float, np.floating, np.integer)):
                agg[col] = v0
                continue
            vals = np.asarray([float(g[col]) for g in grp])
            agg[col] = float(vals.mean())
            if col not in drop and not col.startswith("_") and len(vals) > 1:
                mean, ci = mc_stats(vals, axis=0)
                agg[f"{col}_ci95"] = float(ci)
        out.append(agg)
    return out


def emit(rows, prefix):
    """rows: list of dicts -> CSV lines 'prefix,key=value,...'."""
    lines = []
    for r in rows:
        kv = ",".join(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                      for k, v in r.items())
        lines.append(f"{prefix},{kv}")
    return lines
