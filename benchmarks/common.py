"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.costs import HostingCosts
from repro.core.policies import (AlphaRR, RetroRenting, offline_opt,
                                 offline_opt_no_partial)
from repro.core.simulator import run_policy, model2_service_matrix
from repro.core import bounds


def policy_suite(costs: HostingCosts, x, c, svc=None, include_bounds=True):
    """Cost-per-slot for the paper's six curves on one instance."""
    T = len(x)
    out = {}
    t0 = time.time()
    out["alpha-RR"] = run_policy(AlphaRR(costs), costs, x, c, svc).total / T
    out["_us_per_slot"] = (time.time() - t0) / T * 1e6
    rr = RetroRenting(costs)
    svc2 = None if svc is None else np.asarray(svc)[:, [0, costs.K - 1]]
    out["RR"] = run_policy(rr, rr.costs, x, c, svc2).total / T
    aopt = offline_opt(costs, x, c, svc)
    out["alpha-OPT"] = aopt.cost / T
    opt = offline_opt_no_partial(costs, x, c, svc)
    out["OPT"] = opt.cost / T
    if include_bounds:
        # the figures' LB curves are the Lemma-14 per-slot lower bounds for
        # any online policy, evaluated at the empirical arrival/rent means
        p_hat = float(np.mean(np.asarray(x)))
        c_hat = float(np.mean(np.asarray(c)))
        out["alpha-LB"] = bounds.lemma14_opt_on_per_slot(costs, p_hat, c_hat)
        out["LB"] = min(c_hat, p_hat)
    return out


def hosting_histogram(costs: HostingCosts, x, c, svc=None):
    res = run_policy(AlphaRR(costs), costs, x, c, svc)
    return res.level_slots


def emit(rows, prefix):
    """rows: list of dicts -> CSV lines 'prefix,key=value,...'."""
    lines = []
    for r in rows:
        kv = ",".join(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                      for k, v in r.items())
        lines.append(f"{prefix},{kv}")
    return lines
