"""Shared helpers for the paper-figure benchmarks.

Sweep-style figures run on the batched/fleet engine: every (parameter-grid
point x Monte-Carlo seed) pair becomes one instance of a stacked
``HostingGrid`` and the whole sweep is a handful of compiled calls instead
of a Python loop of per-instance simulations.  ``mc_aggregate`` then
collapses the seed axis into mean / 95%-CI columns.

Two suite entry points:

* ``batch_policy_suite`` — classic: the figure module materializes [B, T]
  observation arrays and the suite runs ``run_policy_batch`` /
  ``offline_opt_batch`` on them.
* ``scenario_policy_suite`` — declarative: the figure module passes a
  ``scenario_fn(grid) -> Scenario`` and generation fuses into the fleet
  scan (``run_fleet(scenario=...)`` / ``offline_opt_fleet(scenario=...)``)
  — no observation array is ever materialized, on host or device.  The
  factory is called once per level grid (the full grid and its endpoint
  restriction) so Model-2 service streams bind the right ``g`` columns and
  RR prices the exact endpoint gather of the same coupled uniforms.

The LB curves need arrival/rent *means*; the scenario suite takes them as
arguments (analytic means of the declared processes) since no realized
trace exists to average — the checks never read LB rows, they are plotted
reference curves.
"""
from __future__ import annotations

import math
import time
from collections import OrderedDict
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import FleetBatch, offline_opt_fleet, run_fleet
from repro.core.policies import AlphaRR, RetroRenting, offline_opt_batch
from repro.core.simulator import run_policy_batch
from repro.core import bounds


def batch_policy_suite(costs_list: Sequence[HostingCosts], x, c, svc=None,
                       include_bounds: bool = True):
    """Cost-per-slot of the paper's curves for B stacked instances.

    Args:
      costs_list: B per-instance costs (mixed K allowed).
      x, c: [B, T] (or [T], broadcast) arrivals / rents.
      svc: optional [B, T, K] realized Model-2 service costs.

    Returns a list of B row dicts with the classic suite keys
    ('alpha-RR', 'RR', 'alpha-OPT', 'OPT', 'alpha-LB', 'LB'), the alpha-RR
    level histogram under 'hist', and '_us_per_slot' (batched alpha-RR
    wall time per simulated slot x instance).
    """
    grid = HostingGrid.from_costs(costs_list)
    B = grid.B
    x = np.asarray(x)
    c = np.asarray(c)
    xb = np.broadcast_to(x, (B, x.shape[-1]))
    cb = np.broadcast_to(c, (B, c.shape[-1]))
    T = xb.shape[1]

    fns = AlphaRR.batch(grid)
    run_policy_batch(fns, grid, xb, cb, svc=svc)   # warm the jit cache:
    t0 = time.time()                               # report steady-state, not
    ar = run_policy_batch(fns, grid, xb, cb, svc=svc)  # one-time compile
    us_per_slot = (time.time() - t0) / (B * T) * 1e6

    g2 = grid.restrict_to_endpoints()
    svc2 = None if svc is None else grid.endpoint_service(np.asarray(svc))
    rr = run_policy_batch(RetroRenting.batch(grid), g2, xb, cb, svc=svc2)
    aopt = offline_opt_batch(grid, xb, cb, svc=svc)
    opt = offline_opt_batch(g2, xb, cb, svc=svc2)

    rows = []
    for i, costs in enumerate(costs_list):
        row = {
            "alpha-RR": ar.total[i] / T,
            "RR": rr.total[i] / T,
            "alpha-OPT": aopt.cost[i] / T,
            "OPT": opt.cost[i] / T,
            "_us_per_slot": us_per_slot,
            "hist": ar.level_slots[i][:costs.K].tolist(),
        }
        if include_bounds:
            # the figures' LB curves are the Lemma-14 per-slot lower bounds
            # for any online policy, at the empirical arrival/rent means
            p_hat = float(np.mean(xb[i]))
            c_hat = float(np.mean(cb[i]))
            row["alpha-LB"] = bounds.lemma14_opt_on_per_slot(costs, p_hat, c_hat)
            row["LB"] = min(c_hat, p_hat)
        rows.append(row)
    return rows


def scenario_policy_suite(costs_list: Sequence[HostingCosts],
                          scenario_fn: Callable, T: int, *,
                          x_means=None, c_means=None,
                          include_bounds: bool = True,
                          chunk_size: Optional[int] = None):
    """The classic six-curve suite with *fused on-device generation*.

    Args:
      costs_list: B per-instance costs (mixed K allowed).
      scenario_fn: ``(grid: HostingGrid) -> Scenario`` factory; called for
        the stacked grid and again for its endpoint restriction (RR/OPT).
      T: horizon (scalar or [B]).
      x_means / c_means: analytic per-instance arrival/rent means for the
        Lemma-14 LB curves (scalar or [B]); bounds are skipped if omitted.
      chunk_size: forwarded to the engine (None = single chunk).

    Returns the same row dicts as ``batch_policy_suite``.
    """
    grid = HostingGrid.from_costs(costs_list)
    B = grid.B
    fleet = FleetBatch.for_scenario(grid, T)
    sc = scenario_fn(grid)

    fns = AlphaRR.fleet(fleet)
    run_fleet(fns, fleet, scenario=sc, chunk_size=chunk_size)  # warm jit
    t0 = time.time()
    ar = run_fleet(fns, fleet, scenario=sc, chunk_size=chunk_size)
    us_per_slot = (time.time() - t0) / float(np.sum(fleet.T)) * 1e6

    g2 = grid.restrict_to_endpoints()
    fleet2 = FleetBatch.for_scenario(g2, T)
    sc2 = scenario_fn(g2)
    rr = run_fleet(RetroRenting.fleet(fleet), fleet2, scenario=sc2,
                   chunk_size=chunk_size)
    aopt = offline_opt_fleet(fleet, scenario=sc, chunk_size=chunk_size)
    opt = offline_opt_fleet(fleet2, scenario=sc2, chunk_size=chunk_size)

    if include_bounds and (x_means is None or c_means is None):
        include_bounds = False
    if include_bounds:
        x_means = np.broadcast_to(np.asarray(x_means, np.float64), (B,))
        c_means = np.broadcast_to(np.asarray(c_means, np.float64), (B,))

    Ts = np.asarray(fleet.T, np.float64)
    rows = []
    for i, costs in enumerate(costs_list):
        row = {
            "alpha-RR": ar.total[i] / Ts[i],
            "RR": rr.total[i] / Ts[i],
            "alpha-OPT": aopt.cost[i] / Ts[i],
            "OPT": opt.cost[i] / Ts[i],
            "_us_per_slot": us_per_slot,
            "hist": ar.level_slots[i][:costs.K].tolist(),
        }
        if include_bounds:
            row["alpha-LB"] = bounds.lemma14_opt_on_per_slot(
                costs, float(x_means[i]), float(c_means[i]))
            row["LB"] = min(float(c_means[i]), float(x_means[i]))
        rows.append(row)
    return rows


def policy_suite(costs: HostingCosts, x, c, svc=None, include_bounds=True):
    """Cost-per-slot for the paper's six curves on ONE instance (the classic
    API, now a B=1 batch)."""
    svc_b = None if svc is None else np.asarray(svc)[None]
    row = batch_policy_suite([costs], np.asarray(x)[None], np.asarray(c)[None],
                             svc=svc_b, include_bounds=include_bounds)[0]
    row.pop("hist")
    return row


# ----------------------------------------------------------------------
# Monte-Carlo aggregation (the n_seeds axis of the sweep benchmarks).
# ----------------------------------------------------------------------

# two-sided 97.5% Student-t quantiles by degrees of freedom (n_seeds - 1);
# the normal 1.96 badly undercovers at the small n_seeds these sweeps use
_T975 = {1: 12.706, 2: 4.303, 3: 3.182, 4: 2.776, 5: 2.571, 6: 2.447,
         7: 2.365, 8: 2.306, 9: 2.262, 10: 2.228}


def _t975(df: int) -> float:
    if df in _T975:
        return _T975[df]
    return 2.04 if df <= 30 else 1.96


def mc_aggregate(rows, group_keys: Sequence[str], drop=("seed", "hist")):
    """Collapse the seed axis: group ``rows`` by ``group_keys`` and replace
    every numeric value column v with its mean plus a ``v_ci95`` column
    (t_{.975, n-1} * sem).  Non-numeric / dropped columns keep the first
    row's value.  'hist' columns (lists) are averaged elementwise."""
    groups: "OrderedDict[tuple, list]" = OrderedDict()
    for r in rows:
        groups.setdefault(tuple(r[k] for k in group_keys), []).append(r)
    out = []
    for key, grp in groups.items():
        agg = dict(zip(group_keys, key))
        agg["n_seeds"] = len(grp)
        for col, v0 in grp[0].items():
            if col in group_keys or col == "seed":
                continue
            if col == "hist" and isinstance(v0, list):
                agg["hist"] = np.mean([g["hist"] for g in grp], axis=0).tolist()
                continue
            if isinstance(v0, bool) or not isinstance(v0, (int, float, np.floating, np.integer)):
                agg[col] = v0
                continue
            vals = np.asarray([float(g[col]) for g in grp])
            agg[col] = float(vals.mean())
            if col not in drop and not col.startswith("_") and len(vals) > 1:
                agg[f"{col}_ci95"] = float(
                    _t975(len(vals) - 1) * vals.std(ddof=1)
                    / math.sqrt(len(vals)))
        out.append(agg)
    return out


def emit(rows, prefix):
    """rows: list of dicts -> CSV lines 'prefix,key=value,...'."""
    lines = []
    for r in rows:
        kv = ",".join(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                      for k, v in r.items())
        lines.append(f"{prefix},{kv}")
    return lines
