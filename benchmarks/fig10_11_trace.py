"""Figs 10-11: trace-driven Model 1 — cluster-trace-like arrivals (stand-in
for the Google cluster trace; see DESIGN.md) + AWS-spot-like ARMA rents,
c=0.135, regimes (0.239, 0.38) and (0.5, 0.7), cost vs M.

Fused MC driver: one instance per (regime x M) grid point, all sharing one
base sample path (shared bursty + spot keys); the Monte-Carlo axis is
``n_seeds`` folded into those keys by the engine, so the whole figure is
one fused ``run_fleet`` (alpha-RR + RR stacked) plus one
``offline_opt_fleet``.  Rows report seed-means with 95% CIs per (regime, M).
"""
from __future__ import annotations

import jax

from repro.core import scenarios as S
from repro.core.arrivals import GilbertElliot
from repro.core.costs import HostingCosts
from repro.core.scenarios.streams import BURSTY_EXIT_P
from benchmarks.common import scenario_policy_suite

C_MEAN = 0.135
BURST = dict(base_rate=0.15, burst_rate=1.2, burst_p=0.08)
REGIMES = {"lt1": (0.239, 0.380), "ge1": (0.5, 0.7)}
MS = [2.0, 5.0, 10.0, 20.0, 40.0]

# stationary mean rate of the bursty GE background (for the LB curves)
X_MEAN = GilbertElliot(p_hl=BURSTY_EXIT_P, p_lh=BURST["burst_p"],
                       rate_h=BURST["burst_rate"],
                       rate_l=BURST["base_rate"]).mean_rate


def run(T=8000, seed=0, n_seeds=4):
    c_lo, c_hi = S.spot_bounds(C_MEAN)
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    costs_list, meta = [], []
    for regime, (alpha, g_alpha) in REGIMES.items():
        for M in MS:
            costs_list.append(HostingCosts.three_level(
                M, alpha, g_alpha, c_min=c_lo, c_max=c_hi))
            meta.append({"regime": regime, "M": M})

    def scenario_fn(grid):
        return S.combine(
            S.bursty_arrivals(S.shared_keys(kx, grid.B), grid.B, **BURST),
            S.spot_rents(S.shared_keys(kc, grid.B), C_MEAN, grid.B))

    # the longest default horizon in the suite: OPT comes from the co-executed
    # forward frontier (O(B * K) DP memory, never a [B, T, K] table)
    suite = scenario_policy_suite(costs_list, scenario_fn, T,
                                  n_seeds=n_seeds, x_means=X_MEAN,
                                  c_means=C_MEAN, chunk_size=min(2000, T))
    rows = []
    for m, r in zip(meta, suite):
        r.pop("hist")
        rows.append({**m, **r})
    return rows


def check(rows):
    for r in rows:
        assert r["alpha-OPT"] <= r["OPT"] + 1e-6
        if r["regime"] == "ge1":
            assert abs(r["alpha-OPT"] - r["OPT"]) < 5e-3
    # in the <1 regime partial hosting should win somewhere on the sweep
    gaps = [r["RR"] - r["alpha-RR"] for r in rows if r["regime"] == "lt1"]
    assert max(gaps) > -1e-6
    return True
