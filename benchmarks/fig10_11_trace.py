"""Figs 10-11: trace-driven Model 1 — cluster-trace-like arrivals (stand-in
for the Google cluster trace; see DESIGN.md) + AWS-spot-like ARMA rents,
c=0.135, regimes (0.239, 0.38) and (0.5, 0.7), cost vs M.

Batched: the (regime x M grid) x (n_seeds sample paths) sweep runs as ONE
stacked batch per policy on the batched engine (each seed draws its own
arrival/rent trace); rows report seed-means with 95% CIs, keyed by
(regime, M) like the paper's curves.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts
from repro.core.costs import HostingCosts
from benchmarks.common import batch_policy_suite, mc_aggregate

C_MEAN = 0.135
REGIMES = {"lt1": (0.239, 0.380), "ge1": (0.5, 0.7)}
MS = [2.0, 5.0, 10.0, 20.0, 40.0]


def run(T=8000, seed=0, n_seeds=4):
    costs_list, xs, cs, meta = [], [], [], []
    for s in range(n_seeds):
        kx, kc = jax.random.split(jax.random.PRNGKey(seed + s))
        x = np.asarray(arrivals.cluster_trace_like(kx, T, base_rate=0.15,
                                                   burst_rate=1.2,
                                                   burst_p=0.08))
        c = np.asarray(rentcosts.aws_spot_like(kc, C_MEAN, T))
        for regime, (alpha, g_alpha) in REGIMES.items():
            for M in MS:
                costs_list.append(HostingCosts.three_level(
                    M, alpha, g_alpha, c_min=float(c.min()),
                    c_max=float(c.max())))
                xs.append(x)
                cs.append(c)
                meta.append({"regime": regime, "M": M, "seed": s})
    suite = batch_policy_suite(costs_list, np.stack(xs), np.stack(cs))
    rows = []
    for m, r in zip(meta, suite):
        r.pop("hist")
        rows.append({**m, **r})
    return mc_aggregate(rows, ["regime", "M"])


def check(rows):
    for r in rows:
        assert r["alpha-OPT"] <= r["OPT"] + 1e-6
        if r["regime"] == "ge1":
            assert abs(r["alpha-OPT"] - r["OPT"]) < 5e-3
    # in the <1 regime partial hosting should win somewhere on the sweep
    gaps = [r["RR"] - r["alpha-RR"] for r in rows if r["regime"] == "lt1"]
    assert max(gaps) > -1e-6
    return True
