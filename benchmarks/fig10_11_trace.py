"""Figs 10-11: trace-driven Model 1 — cluster-trace-like arrivals (stand-in
for the Google cluster trace; see DESIGN.md) + AWS-spot-like ARMA rents,
c=0.135, regimes (0.239, 0.38) and (0.5, 0.7), cost vs M."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts
from repro.core.costs import HostingCosts
from benchmarks.common import policy_suite

C_MEAN = 0.135
REGIMES = {"lt1": (0.239, 0.380), "ge1": (0.5, 0.7)}


def run(T=8000, seed=0):
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    x = arrivals.cluster_trace_like(kx, T, base_rate=0.15, burst_rate=1.2,
                                    burst_p=0.08)
    c = rentcosts.aws_spot_like(kc, C_MEAN, T)
    rows = []
    for regime, (alpha, g_alpha) in REGIMES.items():
        for M in [2.0, 5.0, 10.0, 20.0, 40.0]:
            costs = HostingCosts.three_level(
                M, alpha, g_alpha, c_min=float(np.min(np.asarray(c))),
                c_max=float(np.max(np.asarray(c))))
            rows.append({"regime": regime, "M": M, **policy_suite(costs, x, c)})
    return rows


def check(rows):
    for r in rows:
        assert r["alpha-OPT"] <= r["OPT"] + 1e-6
        if r["regime"] == "ge1":
            assert abs(r["alpha-OPT"] - r["OPT"]) < 5e-3
    # in the <1 regime partial hosting should win somewhere on the sweep
    gaps = [r["RR"] - r["alpha-RR"] for r in rows if r["regime"] == "lt1"]
    assert max(gaps) > -1e-6
    return True
