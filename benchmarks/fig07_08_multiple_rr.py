"""Figs 7-8: multiple-RR with extra intermediate levels (alpha, a1, a2) vs
alpha-RR vs RR, Gilbert-Elliot arrivals (Bern(0.9) in H, Bern(0.1) in L).
Paper values: alpha=.3 g=.4 | a1=.4 g=.3 | a2=.5 g=.15, c=0.5.

Fused MC driver: ALL THREE level-grid families — K=5 multiple-RR, K=3
alpha-RR and the K=2 endpoint RR — of every M live in ONE mixed-K
``HostingGrid`` (padded + masked) so the whole figure is a single
``run_fleet`` call; the Monte-Carlo axis is ``n_seeds`` folded into the
shared GE/spot stream keys by the engine (every instance replays the same
per-seed sample path).  Zero per-seed or per-policy loops remain.
"""
from __future__ import annotations

import jax

from repro.core import scenarios as S
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import FleetBatch, mc_stats, run_fleet
from repro.core.policies import AlphaRR

LEVELS = (0.0, 0.3, 0.4, 0.5, 1.0)
GS = (1.0, 0.4, 0.3, 0.15, 0.0)
GE = dict(p_hl=0.4, p_lh=0.4, rate_h=0.9, rate_l=0.1)
C_MEAN = 0.5
MS = [2.0, 5.0, 10.0, 20.0, 40.0]


def run(T=8000, seed=0, n_seeds=4):
    c_lo, c_hi = S.spot_bounds(C_MEAN)
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    costs_list, meta = [], []
    for M in MS:
        for fam, costs in (
                ("multiple-RR", HostingCosts(M=M, levels=LEVELS, g=GS,
                                             c_min=c_lo, c_max=c_hi)),
                ("alpha-RR", HostingCosts.three_level(M, 0.3, 0.4,
                                                      c_min=c_lo,
                                                      c_max=c_hi)),
                ("RR", HostingCosts.two_level(M, c_lo, c_hi))):
            costs_list.append(costs)
            meta.append({"M": M, "family": fam})
    grid = HostingGrid.from_costs(costs_list)       # mixed K: 5, 3 and 2
    B = grid.B
    sc = S.combine(
        S.ge_arrivals(S.shared_keys(kx, B), GE["p_hl"], GE["p_lh"],
                      GE["rate_h"], GE["rate_l"], B, emission="bernoulli"),
        S.spot_rents(S.shared_keys(kc, B), C_MEAN, B))
    fleet = FleetBatch.for_scenario(grid, T)
    res = run_fleet(AlphaRR.fleet(fleet), fleet, scenario=sc,
                    n_seeds=n_seeds)

    mean, ci = mc_stats(res.seed_view(res.total) / T, axis=1)   # [B]
    hist_bs = res.seed_view(res.level_slots)                    # [B, S, K]
    by_M = {M: {"M": M, "n_seeds": n_seeds} for M in MS}
    for i, m in enumerate(meta):
        row = by_M[m["M"]]
        row[m["family"]] = float(mean[i])
        row[f"{m['family']}_ci95"] = float(ci[i])
        if m["family"] == "multiple-RR":
            row["multi_hist"] = hist_bs[i].mean(axis=0)[:len(LEVELS)].tolist()
    return list(by_M.values())


def check(rows):
    # Fig 7's claim: extra intermediate hosting levels reduce cost
    better = sum(1 for r in rows if r["multiple-RR"] <= r["alpha-RR"] + 1e-6)
    assert better >= len(rows) - 1, rows
    return True
