"""Figs 7-8: multiple-RR with extra intermediate levels (alpha, a1, a2) vs
alpha-RR vs RR, Gilbert-Elliot arrivals (Bern(0.9) in H, Bern(0.1) in L).
Paper values: alpha=.3 g=.4 | a1=.4 g=.3 | a2=.5 g=.15, c=0.5.

Fused MC driver: the figure's three level-grid families — K=5 multiple-RR,
K=3 alpha-RR and the K=2 endpoint RR — ride the engine's policy *fan-out*
axis as three lanes over ONE B=|MS| fleet, each lane scoring on its own
accounting grid (Model 1: service is ``g_lane * x`` from the lane's own g
row).  Every GE/spot slab is generated exactly once per scan step and
stepped by all three families; the Monte-Carlo axis is ``n_seeds`` folded
into the shared stream keys by the engine.  Zero per-seed or per-policy
loops — and zero redundant row replication — remain.
"""
from __future__ import annotations

import jax

from repro.core import scenarios as S
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import FleetBatch, mc_stats, run_fleet
from repro.core.policies import AlphaRR, PolicyLane

LEVELS = (0.0, 0.3, 0.4, 0.5, 1.0)
GS = (1.0, 0.4, 0.3, 0.15, 0.0)
GE = dict(p_hl=0.4, p_lh=0.4, rate_h=0.9, rate_l=0.1)
C_MEAN = 0.5
MS = [2.0, 5.0, 10.0, 20.0, 40.0]
FAMILIES = ("multiple-RR", "alpha-RR", "RR")


def run(T=8000, seed=0, n_seeds=4):
    c_lo, c_hi = S.spot_bounds(C_MEAN)
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    fam_costs = {
        "multiple-RR": [HostingCosts(M=M, levels=LEVELS, g=GS,
                                     c_min=c_lo, c_max=c_hi) for M in MS],
        "alpha-RR": [HostingCosts.three_level(M, 0.3, 0.4, c_min=c_lo,
                                              c_max=c_hi) for M in MS],
        "RR": [HostingCosts.two_level(M, c_lo, c_hi) for M in MS],
    }
    grid = HostingGrid.from_costs(fam_costs["multiple-RR"])   # K=5 fleet grid
    B = grid.B
    sc = S.combine(
        S.ge_arrivals(S.shared_keys(kx, B), GE["p_hl"], GE["p_lh"],
                      GE["rate_h"], GE["rate_l"], B, emission="bernoulli"),
        S.spot_rents(S.shared_keys(kc, B), C_MEAN, B))
    fleet = FleetBatch.for_scenario(grid, T)
    # lane 0 scores on the fleet grid; lanes 1-2 on their own K=3 / K=2
    # grids (Model 1 -> no svc column map needed)
    lanes = [AlphaRR.fleet(fleet)]
    for fam in FAMILIES[1:]:
        g_fam = HostingGrid.from_costs(fam_costs[fam])
        lanes.append(PolicyLane(AlphaRR.batch(g_fam), grid=g_fam))
    res = run_fleet(lanes, fleet, scenario=sc, n_seeds=n_seeds)

    tot = res.policy_view(res.total).reshape(3, B, n_seeds) / T
    mean, ci = mc_stats(tot, axis=2)                            # [3, B]
    hist = res.policy_view(res.level_slots)[0].reshape(B, n_seeds, -1)
    rows = []
    for i, M in enumerate(MS):
        row = {"M": M, "n_seeds": n_seeds}
        for f, fam in enumerate(FAMILIES):
            row[fam] = float(mean[f, i])
            row[f"{fam}_ci95"] = float(ci[f, i])
        row["multi_hist"] = hist[i].mean(axis=0)[:len(LEVELS)].tolist()
        rows.append(row)
    return rows


def check(rows):
    # Fig 7's claim: extra intermediate hosting levels reduce cost
    better = sum(1 for r in rows if r["multiple-RR"] <= r["alpha-RR"] + 1e-6)
    assert better >= len(rows) - 1, rows
    return True
