"""Figs 7-8: multiple-RR with extra intermediate levels (alpha, a1, a2) vs
alpha-RR vs RR, Gilbert-Elliot arrivals (Bern(0.9) in H, Bern(0.1) in L).
Paper values: alpha=.3 g=.4 | a1=.4 g=.3 | a2=.5 g=.15, c=0.5.

Batched: the K=5 (multiple-RR) and K=3 (alpha-RR) instances for every
(M, seed) pair live in ONE mixed-K ``HostingGrid`` (padded + masked), so a
single vmapped scan serves both level-grid families; RR runs on the
endpoint restriction of the same grid.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.policies import AlphaRR, RetroRenting
from repro.core.simulator import run_policy_batch
from benchmarks.common import mc_aggregate

LEVELS = (0.0, 0.3, 0.4, 0.5, 1.0)
GS = (1.0, 0.4, 0.3, 0.15, 0.0)
C_MEAN = 0.5
MS = [2.0, 5.0, 10.0, 20.0, 40.0]


def run(T=8000, seed=0, n_seeds=4):
    ge = arrivals.GilbertElliot(p_hl=0.4, p_lh=0.4, rate_h=0.9, rate_l=0.1,
                                emission="bernoulli")
    costs_list, xs, cs, meta = [], [], [], []
    for s in range(n_seeds):
        kx, kc = jax.random.split(jax.random.PRNGKey(seed + s))
        x = np.asarray(ge.sample(kx, T))
        c = np.asarray(rentcosts.aws_spot_like(kc, C_MEAN, T))
        cmin, cmax = float(c.min()), float(c.max())
        for M in MS:
            for fam, costs in (
                    ("multiple-RR", HostingCosts(M=M, levels=LEVELS, g=GS,
                                                 c_min=cmin, c_max=cmax)),
                    ("alpha-RR", HostingCosts.three_level(M, 0.3, 0.4,
                                                          c_min=cmin,
                                                          c_max=cmax))):
                costs_list.append(costs)
                xs.append(x)
                cs.append(c)
                meta.append({"M": M, "family": fam, "seed": s})
    grid = HostingGrid.from_costs(costs_list)       # mixed K: 5 and 3
    x_b, c_b = np.stack(xs), np.stack(cs)
    multi = run_policy_batch(AlphaRR.batch(grid), grid, x_b, c_b)
    rr = run_policy_batch(RetroRenting.batch(grid),
                          grid.restrict_to_endpoints(), x_b, c_b)

    per_seed = {}
    for i, m in enumerate(meta):
        row = per_seed.setdefault((m["M"], m["seed"]),
                                  {"M": m["M"], "seed": m["seed"]})
        row[m["family"]] = multi.total[i] / T
        if m["family"] == "multiple-RR":
            row["RR"] = rr.total[i] / T             # RR only depends on M
            row["multi_hist"] = multi.level_slots[i][:len(LEVELS)].tolist()
    rows = [dict(r, hist=r.pop("multi_hist")) for r in per_seed.values()]
    agg = mc_aggregate(rows, ["M"])
    for r in agg:
        r["multi_hist"] = r.pop("hist")
    return agg


def check(rows):
    # Fig 7's claim: extra intermediate hosting levels reduce cost
    better = sum(1 for r in rows if r["multiple-RR"] <= r["alpha-RR"] + 1e-6)
    assert better >= len(rows) - 1, rows
    return True
