"""Figs 7-8: multiple-RR with extra intermediate levels (alpha, a1, a2) vs
alpha-RR vs RR, Gilbert-Elliot arrivals (Bern(0.9) in H, Bern(0.1) in L).
Paper values: alpha=.3 g=.4 | a1=.4 g=.3 | a2=.5 g=.15, c=0.5.

Declarative scenario spec: the K=5 (multiple-RR) and K=3 (alpha-RR)
instances for every (M, seed) pair live in ONE mixed-K ``HostingGrid``
(padded + masked) driven by a fused Gilbert-Elliot + spot-rent scenario
(per-seed shared keys), so a single fleet scan serves both level-grid
families with zero materialized observations; RR runs on the endpoint
restriction of the same grid/scenario.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import scenarios as S
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import FleetBatch, run_fleet
from repro.core.policies import AlphaRR, RetroRenting
from benchmarks.common import mc_aggregate

LEVELS = (0.0, 0.3, 0.4, 0.5, 1.0)
GS = (1.0, 0.4, 0.3, 0.15, 0.0)
GE = dict(p_hl=0.4, p_lh=0.4, rate_h=0.9, rate_l=0.1)
C_MEAN = 0.5
MS = [2.0, 5.0, 10.0, 20.0, 40.0]


def run(T=8000, seed=0, n_seeds=4):
    c_lo, c_hi = S.spot_bounds(C_MEAN)
    costs_list, meta, kxs, kcs = [], [], [], []
    for s in range(n_seeds):
        kx, kc = jax.random.split(jax.random.PRNGKey(seed + s))
        for M in MS:
            for fam, costs in (
                    ("multiple-RR", HostingCosts(M=M, levels=LEVELS, g=GS,
                                                 c_min=c_lo, c_max=c_hi)),
                    ("alpha-RR", HostingCosts.three_level(M, 0.3, 0.4,
                                                          c_min=c_lo,
                                                          c_max=c_hi))):
                costs_list.append(costs)
                kxs.append(kx)
                kcs.append(kc)
                meta.append({"M": M, "family": fam, "seed": s})
    grid = HostingGrid.from_costs(costs_list)       # mixed K: 5 and 3
    B = grid.B
    kxs, kcs = np.stack(kxs), np.stack(kcs)
    sc = S.combine(
        S.ge_arrivals(kxs, GE["p_hl"], GE["p_lh"], GE["rate_h"], GE["rate_l"],
                      B, emission="bernoulli"),
        S.spot_rents(kcs, C_MEAN, B))
    fleet = FleetBatch.for_scenario(grid, T)
    multi = run_fleet(AlphaRR.fleet(fleet), fleet, scenario=sc)
    rr = run_fleet(RetroRenting.fleet(fleet), fleet.restrict_to_endpoints(),
                   scenario=sc)

    per_seed = {}
    for i, m in enumerate(meta):
        row = per_seed.setdefault((m["M"], m["seed"]),
                                  {"M": m["M"], "seed": m["seed"]})
        row[m["family"]] = multi.total[i] / T
        if m["family"] == "multiple-RR":
            row["RR"] = rr.total[i] / T             # RR only depends on M
            row["multi_hist"] = multi.level_slots[i][:len(LEVELS)].tolist()
    rows = [dict(r, hist=r.pop("multi_hist")) for r in per_seed.values()]
    agg = mc_aggregate(rows, ["M"])
    for r in agg:
        r["multi_hist"] = r.pop("hist")
    return agg


def check(rows):
    # Fig 7's claim: extra intermediate hosting levels reduce cost
    better = sum(1 for r in rows if r["multiple-RR"] <= r["alpha-RR"] + 1e-6)
    assert better >= len(rows) - 1, rows
    return True
