"""Figs 7-8: multiple-RR with extra intermediate levels (alpha, a1, a2) vs
alpha-RR vs RR, Gilbert-Elliot arrivals (Bern(0.9) in H, Bern(0.1) in L).
Paper values: alpha=.3 g=.4 | a1=.4 g=.3 | a2=.5 g=.15, c=0.5."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts
from repro.core.costs import HostingCosts
from repro.core.policies import AlphaRR, RetroRenting
from repro.core.simulator import run_policy

LEVELS = (0.0, 0.3, 0.4, 0.5, 1.0)
GS = (1.0, 0.4, 0.3, 0.15, 0.0)
C_MEAN = 0.5


def run(T=8000, seed=0):
    ge = arrivals.GilbertElliot(p_hl=0.4, p_lh=0.4, rate_h=0.9, rate_l=0.1,
                                emission="bernoulli")
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    x = ge.sample(kx, T)
    c = rentcosts.aws_spot_like(kc, C_MEAN, T)
    cmin, cmax = float(np.min(np.asarray(c))), float(np.max(np.asarray(c)))
    rows = []
    for M in [2.0, 5.0, 10.0, 20.0, 40.0]:
        multi = HostingCosts(M=M, levels=LEVELS, g=GS, c_min=cmin, c_max=cmax)
        three = HostingCosts.three_level(M, 0.3, 0.4, c_min=cmin, c_max=cmax)
        r_multi = run_policy(AlphaRR(multi), multi, x, c)
        r_three = run_policy(AlphaRR(three), three, x, c)
        rr = RetroRenting(three)
        r_rr = run_policy(rr, rr.costs, x, c)
        rows.append({"M": M,
                     "multiple-RR": r_multi.total / T,
                     "alpha-RR": r_three.total / T,
                     "RR": r_rr.total / T,
                     "multi_hist": r_multi.level_slots.tolist()})
    return rows


def check(rows):
    # Fig 7's claim: extra intermediate hosting levels reduce cost
    better = sum(1 for r in rows if r["multiple-RR"] <= r["alpha-RR"] + 1e-6)
    assert better >= len(rows) - 1, rows
    return True
