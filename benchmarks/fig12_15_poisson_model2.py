"""Figs 12-15 (Model 2, Poisson arrivals): hosting-status histograms and
cost/slot vs fetch cost M for lambda in {2,4,8} (c=4.5, alpha=.3, g=.5), and
vs rent c for lambda=4, M=40.

Fused MC driver: one instance per (lambda, M) / (c,) grid point — arrivals
AND the coupled Model-2 service uniforms are drawn on device inside the
scan, with the Monte-Carlo axis ``n_seeds`` folded into every stream key
by the engine.  Key sharing reproduces the paper's common-sample-path
scoring: the M-sweep instances of a lambda cell share arrival AND service
keys (the service uniforms do not depend on M), so the same realized
requests score every M; RR prices the endpoint gather of the same uniforms
because the fused family driver binds the service stream to the endpoint
rows' own ``g`` columns.  One ``run_fleet`` serves both families (no DP:
the figure plots online curves against the analytic LBs).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import scenarios as S
from repro.core.costs import HostingCosts
from benchmarks.common import scenario_policy_suite

ALPHA, G_ALPHA = 0.30, 0.50
LAMS = [2.0, 4.0, 8.0]
M_GRID = [10.0, 20.0, 40.0, 80.0]
C_GRID = [1.0, 2.0, 3.0, 4.5, 6.0, 8.0, 10.0]
MAX_PER_SLOT = 24      # covers Poisson(8) tails (P[X>24] ~ 1e-6 per slot)


def run(T=6000, seed=0, n_seeds=4):
    key = jax.random.PRNGKey(seed)
    costs_list, meta, kxs, kcs, ksvcs, lams = [], [], [], [], [], []

    def add(costs, kx, kc, ksvc, **m):
        costs_list.append(costs)
        kxs.append(kx)
        kcs.append(kc)
        ksvcs.append(ksvc)
        lams.append(m["lam"])
        meta.append(m)

    for lam in LAMS:
        kx, kc, ksvc = jax.random.split(jax.random.fold_in(key, int(lam)), 3)
        c_lo, c_hi = S.spot_bounds(4.5)
        for M in M_GRID:
            costs = HostingCosts.three_level(M, ALPHA, G_ALPHA,
                                             c_min=c_lo, c_max=c_hi)
            add(costs, kx, kc, ksvc, fig="12_14", lam=lam, M=M, c_mean=4.5)
    # Fig 15: vs rent c at lam=4, M=40
    kx, ksvc = jax.random.split(jax.random.fold_in(key, 99))
    for cc in C_GRID:
        kc2 = jax.random.fold_in(key, int(cc * 10))
        c_lo, c_hi = S.spot_bounds(cc)
        costs = HostingCosts.three_level(40.0, ALPHA, G_ALPHA,
                                         c_min=c_lo, c_max=c_hi)
        add(costs, kx, kc2, ksvc, fig="15", lam=4.0, M=40.0, c_mean=cc)

    B = len(costs_list)
    kxs, kcs, ksvcs = np.stack(kxs), np.stack(kcs), np.stack(ksvcs)
    lams_a = np.asarray(lams, np.float32)
    c_means = np.asarray([m["c_mean"] for m in meta], np.float32)

    def scenario_fn(g):
        return S.combine(S.poisson_arrivals(kxs, lams_a, B),
                         S.spot_rents(kcs, c_means, B),
                         svc=S.model2_service(ksvcs, g.g, B, MAX_PER_SLOT))

    suite = scenario_policy_suite(costs_list, scenario_fn, T,
                                  n_seeds=n_seeds, x_means=lams_a,
                                  c_means=c_means, include_opt=False)
    return [{**m, **r} for m, r in zip(meta, suite)]


def check(rows):
    # Fig 13/15 claims: lam ~ c -> alpha-RR prefers the partial level and
    # beats RR; extreme c -> both converge.
    mid = [r for r in rows if r["fig"] == "12_14" and r["lam"] == 4.0]
    assert any(r["hist"][1] > r["hist"][0] + r["hist"][2] for r in mid), mid
    assert all(r["alpha-RR"] <= r["RR"] + 0.05 for r in mid)
    lam2 = [r for r in rows if r["fig"] == "12_14" and r["lam"] == 2.0]
    # lam << c: predominantly not hosted (paper: "both policies lean towards
    # not hosting"; ARMA rent dips make occasional hosting rational)
    assert all(r["hist"][0] >= 0.5 * sum(r["hist"]) for r in lam2), lam2
    return True
