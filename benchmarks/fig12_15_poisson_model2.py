"""Figs 12-15 (Model 2, Poisson arrivals): hosting-status histograms and
cost/slot vs fetch cost M for lambda in {2,4,8} (c=4.5, alpha=.3, g=.5), and
vs rent c for lambda=4, M=40."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts
from repro.core.costs import HostingCosts
from repro.core.policies import AlphaRR, RetroRenting
from repro.core.simulator import run_policy, model2_service_matrix
from repro.core import bounds

ALPHA, G_ALPHA = 0.30, 0.50


def _run_m2(costs, x, c, key):
    svc = model2_service_matrix(key, costs, x)
    ar = run_policy(AlphaRR(costs), costs, x, c, svc=svc)
    rr = RetroRenting(costs)
    svc2 = np.asarray(svc)[:, [0, costs.K - 1]]
    rrres = run_policy(rr, rr.costs, x, c, svc=svc2)
    return ar, rrres


def run(T=6000, seed=0):
    rows = []
    key = jax.random.PRNGKey(seed)
    for lam in [2.0, 4.0, 8.0]:
        kx, kc, ks = jax.random.split(jax.random.fold_in(key, int(lam)), 3)
        x = arrivals.poisson(kx, lam, T)
        c = rentcosts.aws_spot_like(kc, 4.5, T)
        for M in [10.0, 20.0, 40.0, 80.0]:
            costs = HostingCosts.three_level(M, ALPHA, G_ALPHA,
                                             c_min=float(np.min(np.asarray(c))),
                                             c_max=float(np.max(np.asarray(c))))
            ar, rrres = _run_m2(costs, x, c, ks)
            rows.append({"fig": "12_14", "lam": lam, "M": M, "c": 4.5,
                         "alpha-RR": ar.total / T, "RR": rrres.total / T,
                         "alpha-LB": bounds.lemma14_opt_on_per_slot(costs, lam, 4.5),
                         "LB": min(4.5, lam),
                         "hist": ar.level_slots.tolist()})
    # Fig 15: vs rent c at lam=4, M=40
    kx, ks = jax.random.split(jax.random.fold_in(key, 99))
    x = arrivals.poisson(kx, 4.0, T)
    for cc in [1.0, 2.0, 3.0, 4.5, 6.0, 8.0, 10.0]:
        kc2 = jax.random.fold_in(key, int(cc * 10))
        c = rentcosts.aws_spot_like(kc2, cc, T)
        costs = HostingCosts.three_level(40.0, ALPHA, G_ALPHA,
                                         c_min=float(np.min(np.asarray(c))),
                                         c_max=float(np.max(np.asarray(c))))
        ar, rrres = _run_m2(costs, x, c, ks)
        rows.append({"fig": "15", "lam": 4.0, "M": 40.0, "c": cc,
                     "alpha-RR": ar.total / T, "RR": rrres.total / T,
                     "alpha-LB": bounds.lemma14_opt_on_per_slot(costs, 4.0, cc),
                     "LB": min(cc, 4.0),
                     "hist": ar.level_slots.tolist()})
    return rows


def check(rows):
    # Fig 13/15 claims: lam ~ c -> alpha-RR prefers the partial level and
    # beats RR; extreme c -> both converge.
    mid = [r for r in rows if r["fig"] == "12_14" and r["lam"] == 4.0]
    assert any(r["hist"][1] > r["hist"][0] + r["hist"][2] for r in mid), mid
    assert all(r["alpha-RR"] <= r["RR"] + 0.05 for r in mid)
    lam2 = [r for r in rows if r["fig"] == "12_14" and r["lam"] == 2.0]
    # lam << c: predominantly not hosted (paper: "both policies lean towards
    # not hosting"; ARMA rent dips make occasional hosting rational)
    assert all(r["hist"][0] >= 0.5 * sum(r["hist"]) for r in lam2), lam2
    return True
