"""Figs 12-15 (Model 2, Poisson arrivals): hosting-status histograms and
cost/slot vs fetch cost M for lambda in {2,4,8} (c=4.5, alpha=.3, g=.5), and
vs rent c for lambda=4, M=40.

Batched: all (lambda, M) and (c,) grid points x n_seeds realized sample
paths (arrivals AND the coupled Model-2 service uniforms are redrawn per
seed) are stacked into one batch; rows are seed-means with 95% CIs.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.policies import AlphaRR, RetroRenting
from repro.core.simulator import model2_service_matrix, run_policy_batch
from repro.core import bounds
from benchmarks.common import mc_aggregate

ALPHA, G_ALPHA = 0.30, 0.50
LAMS = [2.0, 4.0, 8.0]
M_GRID = [10.0, 20.0, 40.0, 80.0]
C_GRID = [1.0, 2.0, 3.0, 4.5, 6.0, 8.0, 10.0]


def run(T=6000, seed=0, n_seeds=4):
    key = jax.random.PRNGKey(seed)
    costs_list, xs, cs, svcs, meta = [], [], [], [], []

    def add(costs, x, c, svc, **m):
        costs_list.append(costs)
        xs.append(x)
        cs.append(c)
        svcs.append(np.asarray(svc))
        meta.append(m)

    for s in range(n_seeds):
        ks = jax.random.fold_in(key, 7919 * s)
        for lam in LAMS:
            kx, kc, ksvc = jax.random.split(jax.random.fold_in(ks, int(lam)), 3)
            x = np.asarray(arrivals.poisson(kx, lam, T))
            c = np.asarray(rentcosts.aws_spot_like(kc, 4.5, T))
            # service realization is per (lam, seed): the same coupled
            # uniforms score every M (the matrix does not depend on M),
            # like the paper's common sample path
            svc = model2_service_matrix(
                ksvc, HostingCosts.three_level(10.0, ALPHA, G_ALPHA), x)
            for M in M_GRID:
                costs = HostingCosts.three_level(M, ALPHA, G_ALPHA,
                                                 c_min=float(c.min()),
                                                 c_max=float(c.max()))
                add(costs, x, c, svc, fig="12_14", lam=lam, M=M, c_mean=4.5,
                    seed=s)
        # Fig 15: vs rent c at lam=4, M=40
        kx, ksvc = jax.random.split(jax.random.fold_in(ks, 99))
        x = np.asarray(arrivals.poisson(kx, 4.0, T))
        svc = model2_service_matrix(
            ksvc, HostingCosts.three_level(40.0, ALPHA, G_ALPHA), x)
        for cc in C_GRID:
            kc2 = jax.random.fold_in(ks, int(cc * 10))
            c = np.asarray(rentcosts.aws_spot_like(kc2, cc, T))
            costs = HostingCosts.three_level(40.0, ALPHA, G_ALPHA,
                                             c_min=float(c.min()),
                                             c_max=float(c.max()))
            add(costs, x, c, svc, fig="15", lam=4.0, M=40.0, c_mean=cc, seed=s)

    grid = HostingGrid.from_costs(costs_list)
    x_b, c_b = np.stack(xs), np.stack(cs)
    svc_b = np.stack(svcs)
    ar = run_policy_batch(AlphaRR.batch(grid), grid, x_b, c_b, svc=svc_b)
    rr = run_policy_batch(RetroRenting.batch(grid),
                          grid.restrict_to_endpoints(), x_b, c_b,
                          svc=grid.endpoint_service(svc_b))
    rows = []
    for i, m in enumerate(meta):
        costs = costs_list[i]
        rows.append({**m,
                     "alpha-RR": ar.total[i] / T, "RR": rr.total[i] / T,
                     "alpha-LB": bounds.lemma14_opt_on_per_slot(
                         costs, m["lam"], m["c_mean"]),
                     "LB": min(m["c_mean"], m["lam"]),
                     "hist": ar.level_slots[i][:costs.K].tolist()})
    return mc_aggregate(rows, ["fig", "lam", "M", "c_mean"])


def check(rows):
    # Fig 13/15 claims: lam ~ c -> alpha-RR prefers the partial level and
    # beats RR; extreme c -> both converge.
    mid = [r for r in rows if r["fig"] == "12_14" and r["lam"] == 4.0]
    assert any(r["hist"][1] > r["hist"][0] + r["hist"][2] for r in mid), mid
    assert all(r["alpha-RR"] <= r["RR"] + 0.05 for r in mid)
    lam2 = [r for r in rows if r["fig"] == "12_14" and r["lam"] == 2.0]
    # lam << c: predominantly not hosted (paper: "both policies lean towards
    # not hosting"; ARMA rent dips make occasional hosting rational)
    assert all(r["hist"][0] >= 0.5 * sum(r["hist"]) for r in lam2), lam2
    return True
