"""Figs 1-2: total cost per slot and alpha-RR hosting-state histogram as a
function of alpha + g(alpha).  M=10, c=0.35, p=0.35, alpha=0.4 (paper values),
Bernoulli arrivals, ARMA(4,2) rent."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts
from repro.core.costs import HostingCosts
from benchmarks.common import policy_suite, hosting_histogram

M, C_MEAN, P, ALPHA = 10.0, 0.35, 0.35, 0.4
T = 10000


def run(T=T, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, kc = jax.random.split(key)
    x = arrivals.bernoulli(kx, P, T)
    c = rentcosts.aws_spot_like(kc, C_MEAN, T)
    rows = []
    for ag in np.linspace(0.5, 1.4, 10):
        g_alpha = float(np.clip(ag - ALPHA, 0.0, 1.0))
        costs = HostingCosts.three_level(M, ALPHA, g_alpha,
                                         c_min=float(np.min(np.asarray(c))),
                                         c_max=float(np.max(np.asarray(c))))
        suite = policy_suite(costs, x, c)
        hist = hosting_histogram(costs, x, c)
        rows.append({"alpha_plus_g": round(float(ag), 3), **suite,
                     "slots_r0": int(hist[0]), "slots_alpha": int(hist[1]),
                     "slots_r1": int(hist[2])})
    return rows


def check(rows):
    """Paper claims: the partial/no-partial gap is significant iff
    alpha+g(alpha) < 1, and alpha-RR never hosts alpha when >= 1 (Thm 1)."""
    for r in rows:
        if r["alpha_plus_g"] >= 1.0:
            assert r["slots_alpha"] == 0, r
            assert r["alpha-RR"] <= r["RR"] * 1.02 + 1e-6, r
    gaps_low = [r["RR"] - r["alpha-RR"] for r in rows if r["alpha_plus_g"] < 0.95]
    assert max(gaps_low) > 0.01, "partial hosting should help when a+g<1"
    return True
