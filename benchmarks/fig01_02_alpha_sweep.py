"""Figs 1-2: total cost per slot and alpha-RR hosting-state histogram as a
function of alpha + g(alpha).  M=10, c=0.35, p=0.35, alpha=0.4 (paper values),
Bernoulli arrivals, ARMA(4,2) rent.

Fused MC driver: one instance per alpha-grid point; the Monte-Carlo axis is
``n_seeds`` folded into the stream keys by the engine (every grid point
shares ONE base key, so all points of a seed-replica score the same sample
path — the classic reuse-one-trace idiom, now a key-sharing declaration
with the seed fold server-side).  The whole figure is one fused
``run_fleet`` (alpha-RR + RR families stacked) plus one
``offline_opt_fleet``; rows report seed-means with 95% CIs.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import scenarios as S
from repro.core.costs import HostingCosts
from benchmarks.common import scenario_policy_suite

M, C_MEAN, P, ALPHA = 10.0, 0.35, 0.35, 0.4
T = 10000
AGS = np.linspace(0.5, 1.4, 10)


def run(T=T, seed=0, n_seeds=4):
    c_lo, c_hi = S.spot_bounds(C_MEAN)
    kx, kc = jax.random.split(jax.random.PRNGKey(seed))
    costs_list, meta = [], []
    for ag in AGS:
        g_alpha = float(np.clip(ag - ALPHA, 0.0, 1.0))
        costs_list.append(HostingCosts.three_level(
            M, ALPHA, g_alpha, c_min=c_lo, c_max=c_hi))
        meta.append({"alpha_plus_g": round(float(ag), 3)})

    def scenario_fn(grid):
        return S.combine(
            S.bernoulli_arrivals(S.shared_keys(kx, grid.B), P, grid.B),
            S.spot_rents(S.shared_keys(kc, grid.B), C_MEAN, grid.B))

    suite = scenario_policy_suite(costs_list, scenario_fn, T,
                                  n_seeds=n_seeds, x_means=P, c_means=C_MEAN)
    rows = []
    for m, r in zip(meta, suite):
        hist = r.pop("hist")
        rows.append({**m, **r, "slots_r0": hist[0], "slots_alpha": hist[1],
                     "slots_r1": hist[2]})
    return rows


def check(rows):
    """Paper claims: the partial/no-partial gap is significant iff
    alpha+g(alpha) < 1, and alpha-RR never hosts alpha when >= 1 (Thm 1)."""
    for r in rows:
        if r["alpha_plus_g"] >= 1.0:
            assert r["slots_alpha"] == 0, r      # holds for EVERY seed
            assert r["alpha-RR"] <= r["RR"] * 1.02 + 1e-6, r
    gaps_low = [r["RR"] - r["alpha-RR"] for r in rows if r["alpha_plus_g"] < 0.95]
    assert max(gaps_low) > 0.01, "partial hosting should help when a+g<1"
    return True
