"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).parent / "results" / "dryrun"


def load_cells(mesh: str = "pod16x16"):
    cells = []
    for p in sorted(RESULTS.glob(f"*__{mesh}.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def fmt_row(c):
    if "skipped" in c:
        return (f"| {c['arch']} | {c['shape']} | — | — | — | — | — | skipped: "
                f"sub-quadratic attention required | — |")
    r = c["roofline"]
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    dom = r["bottleneck"]
    return ("| {arch} | {shape} | {c:.3e} | {m:.3e} | {x:.3e} | **{dom}** | "
            "{useful:.2f} | {frac:.3f} | {mem:.1f} |".format(
                arch=c["arch"], shape=c["shape"], c=terms["compute"],
                m=terms["memory"], x=terms["collective"], dom=dom,
                useful=r["useful_ratio"], frac=r["roofline_fraction"],
                mem=(c["memory_analysis"].get("argument_size_in_bytes", 0)
                     + c["memory_analysis"].get("temp_size_in_bytes", 0)) / 2**30))


def table(mesh="pod16x16"):
    rows = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
            "bottleneck | useful FLOP ratio | roofline frac | GiB/chip |",
            "|---|---|---|---|---|---|---|---|---|"]
    rows += [fmt_row(c) for c in load_cells(mesh)]
    return "\n".join(rows)


def summary_csv(mesh="pod16x16"):
    print("arch,shape,compute_s,memory_s,collective_s,bottleneck,roofline_frac")
    for c in load_cells(mesh):
        if "skipped" in c:
            print(f"{c['arch']},{c['shape']},,,,skipped,")
            continue
        r = c["roofline"]
        print(f"{c['arch']},{c['shape']},{r['compute_s']:.4e},{r['memory_s']:.4e},"
              f"{r['collective_s']:.4e},{r['bottleneck']},{r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    import sys
    if "--csv" in sys.argv:
        summary_csv()
    else:
        print(table())
