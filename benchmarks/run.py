"""Benchmark harness: one module per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig01]
                                            [--json OUT.json]

Each module exposes run() -> rows and check(rows) -> bool (the figure's
qualitative claims as assertions).  Output: 'module,status,seconds' summary
plus per-row CSV lines.  ``--json OUT.json`` additionally writes a stable
machine-readable report (schema below) used to track the perf trajectory
across PRs (BENCH_*.json):

    {
      "schema_version": 1,
      "fast": bool,
      "modules": [{"name", "status", "seconds", "n_rows"}, ...],
      "throughput": {<name>: {"slots_instances_per_sec", "speedup_vs_loop",
                              "B", "T"}},
      "totals": {"seconds", "failures"}
    }

``fleet_throughput`` rows add keys *inside* their throughput entry
(``fleet_vs_batched_1dev``, ``scaling_vs_1dev``, ``devices``) — additive,
so the schema version stays 1 and existing consumers keep working;
``scenario_fused_throughput`` rows likewise add ``fused_vs_stream`` and
``materialize_seconds`` (fused on-device generation vs host-materialized
streaming), ``mc_driver_throughput`` adds ``fused_vs_per_seed``,
``antithetic_ci_ratio`` and ``S`` (one fused seed-axis program vs S
per-seed dispatches), and ``offline_dp_streaming`` adds
``ckpt_vs_materialized`` and ``peak_mem_ratio`` (checkpointed two-pass DP
backtracking vs the materialized [B, T, K] table).  ``live_fleet_step``
adds ``live_slots_admitted_per_sec`` plus ``p50_step_latency_us`` /
``p99_step_latency_us`` (the persistent chunk=1 ``fleet_stepper`` at its
widest measured fleet), and ``stream_overlap`` adds
``async_stream_slots_instances_per_sec`` / ``async_vs_sync`` (double
buffered prefetch vs the synchronous slab feed, bit-equality asserted
in-row).  ``policy_fanout`` adds ``fanout_vs_separate`` /
``fanout_vs_separate_p2`` / ``generation_passes_saved`` (P policy
families fused on one generated stream vs P separate ``run_fleet``
dispatches, every lane bit-equality-asserted in-row; in fast mode the
``multihost_scaling`` entry instead carries explicit nulls — the cluster
leg runs in full mode only).  ``multi_service`` adds ``n_services`` /
``joint_states`` / ``joint_dp_seconds`` (B x N per-service fleet lanes
plus the capacity-respecting joint DP; the N=1 bitwise identity and
joint-DP-vs-oracle claims are asserted in-row and folded into its
``identical_bits``).  The hosting-kernel
backend rows (``dp_minplus_kernel`` / ``counter_prng_kernel``) add their
``*_pallas_vs_xla`` ratios, and the report itself gains top-level
``backend`` / ``device_kind`` keys (additive, still schema 1) recording
which Pallas mode the hosting rows measured ("pallas-interpret" on CPU)
and ``jax.devices()[0].device_kind`` — so baselines from different
machines/modes are distinguishable.  ``multihost_scaling`` adds its
2-process-vs-1 rates and ``multihost_scaling_vs_1proc`` ratio, and the
report gains top-level ``process_count`` / ``host_count`` /
``local_device_count`` keys (additive, still schema 1) recording the JAX
process topology the report was produced under — the benchmark process
itself is single-process (the row's cluster legs run in subprocesses),
but a report produced inside a real multi-host launch is then
distinguishable from a laptop run.

``benchmarks/check_regression.py`` compares a report's ``throughput``
section against the committed ``BENCH_baseline.json`` (the perf-regression
CI gate); regenerate the baseline with this command whenever a PR
intentionally shifts a gated number.

Sweep modules accept ``n_seeds`` (Monte-Carlo sample paths per grid point),
folded into the stream keys by the fleet engine (``run_fleet(n_seeds=)``);
``--fast`` shrinks both the horizon T and n_seeds for smoke runs.
"""
from __future__ import annotations

import json
import sys
import time

MODULES = [
    "fig01_02_alpha_sweep",
    "fig03_06_m_p_sweeps",
    "fig07_08_multiple_rr",
    "fig10_11_trace",
    "fig12_15_poisson_model2",
    "fig17_22_markov_mdp",
    "fig23_25_geolife",
    "beyond_knapsack_levels",
    "theorems",
    "kernel_bench",
]

FAST_T = 1500
FAST_SEEDS = 2


def main() -> None:
    import importlib
    import inspect
    only = None
    if "--only" in sys.argv:
        only = sys.argv[sys.argv.index("--only") + 1]
    json_out = None
    if "--json" in sys.argv:
        json_out = sys.argv[sys.argv.index("--json") + 1]
    fast = "--fast" in sys.argv
    failures = []
    import jax
    report = {"schema_version": 1, "fast": fast, "modules": [],
              "throughput": {},
              # JAX process topology of THIS benchmark process (additive,
              # schema stays 1).  Single-process on CI — the
              # multihost_scaling row's cluster legs are subprocesses —
              # but a report from a real multi-host launch self-labels.
              "process_count": jax.process_count(),
              "host_count": len({d.process_index for d in jax.devices()}),
              "local_device_count": jax.local_device_count()}
    t_all = time.time()
    print("module,status,seconds,rows")
    for name in MODULES:
        if only and only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            params = inspect.signature(mod.run).parameters
            kwargs = {}
            if fast and "T" in params:
                kwargs["T"] = FAST_T
            if fast and "n_seeds" in params:
                kwargs["n_seeds"] = FAST_SEEDS
            rows = mod.run(**kwargs)
            ok = mod.check(rows)
            status = "ok" if ok else "check-failed"
        except Exception as e:                      # pragma: no cover
            import traceback; traceback.print_exc()
            rows, status = [], f"error:{type(e).__name__}"
        if not status == "ok":
            failures.append(name)
        dt = time.time() - t0
        print(f"{name},{status},{dt:.1f},{len(rows)}")
        for r in rows:
            kv = ",".join(f"{k}={v}" for k, v in r.items())
            print(f"  {name},{kv}")
            if isinstance(r, dict) and "speedup_vs_loop" in r:
                report["throughput"][r.get("name", name)] = {
                    "slots_instances_per_sec":
                        r.get("batched_slots_instances_per_sec"),
                    "speedup_vs_loop": r["speedup_vs_loop"],
                    "B": r.get("B"), "T": r.get("T"),
                }
            if isinstance(r, dict) and "fleet_vs_batched_1dev" in r:
                report["throughput"][r.get("name", name)] = {
                    "slots_instances_per_sec":
                        r.get("fleet_slots_instances_per_sec"),
                    "fleet_vs_batched_1dev": r["fleet_vs_batched_1dev"],
                    "scaling_vs_1dev": r.get("scaling_vs_1dev"),
                    "devices": r.get("scale_devices"),
                    "B": r.get("B"), "T": r.get("T"),
                }
            if isinstance(r, dict) and "fused_vs_per_seed" in r:
                report["throughput"][r.get("name", name)] = {
                    "slots_instances_per_sec":
                        r.get("fused_slots_instances_seeds_per_sec"),
                    "fused_vs_per_seed": r["fused_vs_per_seed"],
                    "antithetic_ci_ratio": r.get("antithetic_ci_ratio"),
                    "B": r.get("B"), "S": r.get("S"), "T": r.get("T"),
                }
            if isinstance(r, dict) and "ckpt_vs_materialized" in r:
                report["throughput"][r.get("name", name)] = {
                    "slots_instances_per_sec":
                        r.get("ckpt_slots_instances_per_sec"),
                    "ckpt_vs_materialized": r["ckpt_vs_materialized"],
                    "peak_mem_ratio": r.get("peak_mem_ratio"),
                    "B": r.get("B"), "T": r.get("T"),
                }
            if isinstance(r, dict) and "live_slots_admitted_per_sec" in r:
                report["throughput"][r.get("name", name)] = {
                    "live_slots_admitted_per_sec":
                        r["live_slots_admitted_per_sec"],
                    "p50_step_latency_us": r.get("p50_step_latency_us"),
                    "p99_step_latency_us": r.get("p99_step_latency_us"),
                    "zero_retraces": r.get("zero_retraces"),
                    "widths": r.get("widths"), "n_steps": r.get("n_steps"),
                }
            if isinstance(r, dict) and "async_vs_sync" in r:
                report["throughput"][r.get("name", name)] = {
                    "sync_stream_slots_instances_per_sec":
                        r.get("sync_stream_slots_instances_per_sec"),
                    "async_stream_slots_instances_per_sec":
                        r.get("async_stream_slots_instances_per_sec"),
                    "async_vs_sync": r["async_vs_sync"],
                    "identical_bits": r.get("identical_bits"),
                    "B": r.get("B"), "T": r.get("T"),
                    "chunk": r.get("chunk"),
                }
            if isinstance(r, dict) and "fanout_vs_separate" in r:
                report["throughput"][r.get("name", name)] = {
                    "slots_instances_per_sec":
                        r.get("slots_instances_per_sec"),
                    "fanout_vs_separate": r["fanout_vs_separate"],
                    "fanout_vs_separate_p2": r.get("fanout_vs_separate_p2"),
                    "generation_passes_saved":
                        r.get("generation_passes_saved"),
                    "identical_bits": r.get("identical_bits"),
                    "B": r.get("B"), "T": r.get("T"),
                }
            if isinstance(r, dict) and "n_services" in r:
                report["throughput"][r.get("name", name)] = {
                    "slots_instances_per_sec":
                        r.get("slots_instances_per_sec"),
                    "joint_dp_seconds": r.get("joint_dp_seconds"),
                    "identical_bits": r.get("identical_bits"),
                    "n_services": r.get("n_services"),
                    "joint_states": r.get("joint_states"),
                    "B": r.get("B"), "T": r.get("T"),
                    "chunk": r.get("chunk"),
                }
            if isinstance(r, dict) and "multihost_scaling_vs_1proc" in r:
                report["throughput"][r.get("name", name)] = {
                    "single_process_slots_instances_per_sec":
                        r.get("single_process_slots_instances_per_sec"),
                    "multi_process_slots_instances_per_sec":
                        r.get("multi_process_slots_instances_per_sec"),
                    "multihost_scaling_vs_1proc":
                        r["multihost_scaling_vs_1proc"],
                    "identical_bits": r.get("identical_bits"),
                    "n_processes": r.get("n_processes"),
                    "B": r.get("B"), "T": r.get("T"),
                    "chunk": r.get("chunk"),
                }
            if isinstance(r, dict) and "fused_vs_stream" in r:
                report["throughput"][r.get("name", name)] = {
                    "slots_instances_per_sec":
                        r.get("fused_slots_instances_per_sec"),
                    "fused_vs_host_e2e": r.get("fused_vs_host_e2e"),
                    "fused_vs_stream": r["fused_vs_stream"],
                    "materialize_seconds": r.get("materialize_seconds"),
                    "B": r.get("B"), "T": r.get("T"),
                }
            if isinstance(r, dict) and "dp_pallas_vs_xla" in r:
                report["throughput"][r.get("name", name)] = {
                    "xla_dp_slots_instances_per_sec":
                        r.get("xla_dp_slots_instances_per_sec"),
                    "pallas_dp_slots_instances_per_sec":
                        r.get("pallas_dp_slots_instances_per_sec"),
                    "dp_pallas_vs_xla": r["dp_pallas_vs_xla"],
                    "identical_bits": r.get("identical_bits"),
                    "B": r.get("B"), "K": r.get("K"),
                    "chunk": r.get("chunk"),
                }
                report["backend"] = r.get("backend")
                report["device_kind"] = r.get("device_kind")
            if isinstance(r, dict) and "prng_pallas_vs_xla" in r:
                report["throughput"][r.get("name", name)] = {
                    "xla_prng_draws_per_sec":
                        r.get("xla_prng_draws_per_sec"),
                    "pallas_prng_draws_per_sec":
                        r.get("pallas_prng_draws_per_sec"),
                    "prng_pallas_vs_xla": r["prng_pallas_vs_xla"],
                    "identical_bits": r.get("identical_bits"),
                    "B": r.get("B"), "chunk": r.get("chunk"),
                }
                report["backend"] = r.get("backend")
                report["device_kind"] = r.get("device_kind")
        report["modules"].append({"name": name, "status": status,
                                  "seconds": round(dt, 2),
                                  "n_rows": len(rows)})
    report["totals"] = {"seconds": round(time.time() - t_all, 2),
                        "failures": len(failures)}
    if json_out:
        with open(json_out, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
        print(f"wrote {json_out}")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
