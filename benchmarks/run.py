"""Benchmark harness: one module per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig01]

Each module exposes run() -> rows and check(rows) -> bool (the figure's
qualitative claims as assertions).  Output: 'module,status,seconds' summary
plus per-row CSV lines.
"""
from __future__ import annotations

import sys
import time

MODULES = [
    "fig01_02_alpha_sweep",
    "fig03_06_m_p_sweeps",
    "fig07_08_multiple_rr",
    "fig10_11_trace",
    "fig12_15_poisson_model2",
    "fig17_22_markov_mdp",
    "fig23_25_geolife",
    "beyond_knapsack_levels",
    "theorems",
    "kernel_bench",
]


def main() -> None:
    import importlib
    only = None
    if "--only" in sys.argv:
        only = sys.argv[sys.argv.index("--only") + 1]
    fast = "--fast" in sys.argv
    failures = []
    print("module,status,seconds,rows")
    for name in MODULES:
        if only and only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            import inspect
            kwargs = {}
            if fast and "T" in inspect.signature(mod.run).parameters:
                kwargs["T"] = 1500
            rows = mod.run(**kwargs)
            ok = mod.check(rows)
            status = "ok" if ok else "check-failed"
        except Exception as e:                      # pragma: no cover
            import traceback; traceback.print_exc()
            rows, status = [], f"error:{type(e).__name__}"
            failures.append(name)
        dt = time.time() - t0
        print(f"{name},{status},{dt:.1f},{len(rows)}")
        for r in rows:
            kv = ",".join(f"{k}={v}" for k, v in r.items())
            print(f"  {name},{kv}")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
