"""BEYOND-PAPER: data-driven hosting-level grids.

The paper closes with "the benefits of using more than three levels of
service hosting is an open problem" and separately builds a measured
g(alpha) curve from trajectory data (§7.2).  We join the two: choose the
K intermediate levels *from the measured curve* (greedy max-marginal-gain
knee points, a knapsack-flavoured rule) and run multiple-RR on the
resulting grid, against the paper's 3-level alpha-RR at its best single
alpha, RR, and the uniform-grid multiple-RR.

Claim tested: measured-curve grids dominate uniform grids of the same K,
and more levels help monotonically (up to noise) — quantifying the open
problem on this instance family.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import arrivals, rentcosts, geolife
from repro.core.costs import HostingCosts
from repro.core.policies import AlphaRR, RetroRenting
from repro.core.simulator import run_policy, model2_service_matrix

C_MEAN = 0.55
M = 10.0


def pick_levels(alphas, gs, k: int):
    """Greedy: repeatedly add the level with the best marginal
    service-saving per byte ((g_prev - g) / (a - a_prev)) against the
    current grid — the fractional-knapsack rule on the measured curve."""
    pts = [(float(a), float(g)) for a, g in zip(alphas, gs) if 0.0 < a < 1.0]
    chosen = []
    for _ in range(k):
        best, best_score = None, -np.inf
        for a, g in pts:
            if any(abs(a - c[0]) < 1e-9 for c in chosen):
                continue
            grid = sorted(chosen + [(a, g)])
            # score: total envelope area improvement (lower g envelope)
            xs = [0.0] + [p[0] for p in grid] + [1.0]
            ys = [1.0] + [p[1] for p in grid] + [0.0]
            area = np.trapezoid(ys, xs)
            score = -area
            if score > best_score:
                best, best_score = (a, g), score
        chosen.append(best)
    chosen.sort()
    return chosen


def _grid_costs(levels_g, cmin, cmax):
    levels = tuple([0.0] + [a for a, _ in levels_g] + [1.0])
    gs = tuple([1.0] + [g for _, g in levels_g] + [0.0])
    return HostingCosts(M=M, levels=levels, g=gs, c_min=cmin, c_max=cmax)


def run(T=4000, seed=0):
    al, gl, _ = geolife.gcurve_from_city(n_side=12, n_train=1200, n_test=400,
                                         seed=seed)
    kx, kc, ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = arrivals.bernoulli(kx, 0.5, T)
    c = rentcosts.aws_spot_like(kc, C_MEAN, T)
    cmin, cmax = float(np.min(np.asarray(c))), float(np.max(np.asarray(c)))
    rows = []

    # paper's 3-level alpha-RR at its best measured alpha + plain RR
    best3 = None
    for a, g in zip(al, gl):
        if not (0.0 < a < 1.0 and 0.0 < g < 1.0):
            continue
        costs = HostingCosts.three_level(M, float(a), float(g), cmin, cmax)
        svc = model2_service_matrix(ks, costs, x)
        tot = run_policy(AlphaRR(costs), costs, x, c, svc=svc).total / T
        if best3 is None or tot < best3[1]:
            best3 = (float(a), tot)
    rows.append({"grid": "alpha-RR(best alpha)", "K": 1, "cost": best3[1],
                 "levels": [best3[0]]})
    costs2 = HostingCosts.two_level(M, cmin, cmax)
    svc2 = model2_service_matrix(ks, costs2, x)
    rows.append({"grid": "RR", "K": 0,
                 "cost": run_policy(AlphaRR(costs2), costs2, x, c,
                                    svc=svc2).total / T,
                 "levels": []})

    g_of = lambda a: float(np.interp(a, al, gl))
    for k in (2, 4, 6):
        # measured-curve (knapsack) grid
        kn = pick_levels(al, gl, k)
        costs_k = _grid_costs(kn, cmin, cmax)
        svc = model2_service_matrix(ks, costs_k, x)
        cost_kn = run_policy(AlphaRR(costs_k), costs_k, x, c, svc=svc).total / T
        # uniform grid of same K
        ua = [(i + 1) / (k + 1) for i in range(k)]
        un = [(a, g_of(a)) for a in ua]
        costs_u = _grid_costs(un, cmin, cmax)
        svc_u = model2_service_matrix(ks, costs_u, x)
        cost_un = run_policy(AlphaRR(costs_u), costs_u, x, c, svc=svc_u).total / T
        rows.append({"grid": "knapsack", "K": k, "cost": cost_kn,
                     "levels": [round(a, 3) for a, _ in kn]})
        rows.append({"grid": "uniform", "K": k, "cost": cost_un,
                     "levels": [round(a, 3) for a, _ in un]})
    return rows


def check(rows):
    d = {(r["grid"], r["K"]): r["cost"] for r in rows}
    rr = d[("RR", 0)]
    best3 = d[("alpha-RR(best alpha)", 1)]
    # multi-level grids should not lose to plain RR, and the best knapsack
    # grid should match or beat the best single-alpha 3-level policy
    for k in (2, 4, 6):
        assert d[("knapsack", k)] <= rr * 1.02 + 1e-6
        assert d[("knapsack", k)] <= d[("uniform", k)] * 1.10 + 1e-6
    assert min(d[("knapsack", k)] for k in (2, 4, 6)) <= best3 * 1.05 + 1e-6
    return True
