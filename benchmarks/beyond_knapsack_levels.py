"""BEYOND-PAPER: data-driven hosting-level grids.

The paper closes with "the benefits of using more than three levels of
service hosting is an open problem" and separately builds a measured
g(alpha) curve from trajectory data (§7.2).  We join the two: choose the
K intermediate levels *from the measured curve* (greedy max-marginal-gain
knee points, a knapsack-flavoured rule) and run multiple-RR on the
resulting grid, against the paper's 3-level alpha-RR at its best single
alpha, RR, and the uniform-grid multiple-RR.

Fleet-engine port: every candidate grid — each 3-level curve point for the
best-alpha search, plain RR, and the knapsack/uniform multi-level grids —
is one LANE of the engine's policy fan-out axis over a B=1 fleet whose
grid is the union of every candidate's (level, g) points.  The Bernoulli +
spot + coupled Model-2 service path is generated exactly ONCE per seed
(previously once per candidate row — all rows replayed the same
shared-key path); each lane gathers its own g columns out of the union
svc slab, which is bitwise identical to per-candidate generation because
the Model-2 uniforms are coupled across levels.  ``n_seeds`` Monte-Carlo
sample paths fold into the stream keys engine-side; costs are seed-means.
No per-instance ``run_policy`` loop remains anywhere in benchmarks/.

Claim tested: measured-curve grids dominate uniform grids of the same K,
and more levels help monotonically (up to noise) — quantifying the open
problem on this instance family.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import geolife
from repro.core import scenarios as S
from repro.core.costs import HostingCosts, HostingGrid
from repro.core.fleet import FleetBatch, mc_stats, run_fleet
from repro.core.policies import AlphaRR, PolicyLane

C_MEAN = 0.55
M = 10.0
P_ARRIVAL = 0.5


def pick_levels(alphas, gs, k: int):
    """Greedy: repeatedly add the level with the best marginal
    service-saving per byte ((g_prev - g) / (a - a_prev)) against the
    current grid — the fractional-knapsack rule on the measured curve."""
    pts = [(float(a), float(g)) for a, g in zip(alphas, gs) if 0.0 < a < 1.0]
    chosen = []
    for _ in range(k):
        best, best_score = None, -np.inf
        for a, g in pts:
            if any(abs(a - c[0]) < 1e-9 for c in chosen):
                continue
            grid = sorted(chosen + [(a, g)])
            # score: total envelope area improvement (lower g envelope)
            xs = [0.0] + [p[0] for p in grid] + [1.0]
            ys = [1.0] + [p[1] for p in grid] + [0.0]
            area = np.trapezoid(ys, xs)
            score = -area
            if score > best_score:
                best, best_score = (a, g), score
        chosen.append(best)
    chosen.sort()
    return chosen


def _grid_costs(levels_g, cmin, cmax):
    levels = tuple([0.0] + [a for a, _ in levels_g] + [1.0])
    gs = tuple([1.0] + [g for _, g in levels_g] + [0.0])
    return HostingCosts(M=M, levels=levels, g=gs, c_min=cmin, c_max=cmax)


def run(T=4000, seed=0, n_seeds=4):
    al, gl, _ = geolife.gcurve_from_city(n_side=12, n_train=1200, n_test=400,
                                         seed=seed)
    kx, kc, ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    cmin, cmax = S.spot_bounds(C_MEAN)

    # every candidate grid is one instance of a mixed-K fleet
    curve_pts = [(float(a), float(g)) for a, g in zip(al, gl)
                 if 0.0 < a < 1.0 and 0.0 < g < 1.0]
    costs_list = [HostingCosts.three_level(M, a, g, cmin, cmax)
                  for a, g in curve_pts]
    n_curve = len(costs_list)
    costs_list.append(HostingCosts.two_level(M, cmin, cmax))        # RR
    g_of = lambda a: float(np.interp(a, al, gl))
    grids_k = {}
    for k in (2, 4, 6):
        kn = pick_levels(al, gl, k)
        ua = [(i + 1) / (k + 1) for i in range(k)]
        un = [(a, g_of(a)) for a in ua]
        grids_k[k] = (kn, un)
        costs_list.append(_grid_costs(kn, cmin, cmax))
        costs_list.append(_grid_costs(un, cmin, cmax))

    # union fleet grid: one B=1 instance holding every distinct candidate
    # (level, g) point; each candidate lane gathers its columns out of it
    union = sorted({(float(lv), float(g))
                    for cc in costs_list for lv, g in zip(cc.levels, cc.g)})
    u_costs = HostingCosts(M=M, levels=tuple(a for a, _ in union),
                           g=tuple(g for _, g in union),
                           c_min=cmin, c_max=cmax)
    grid = HostingGrid.from_costs([u_costs])
    col_of = {lv: k for k, (lv, _) in enumerate(union)}
    sc = S.combine(
        S.bernoulli_arrivals(S.shared_keys(kx, 1), P_ARRIVAL, 1),
        S.spot_rents(S.shared_keys(kc, 1), C_MEAN, 1),
        svc=S.model2_service(S.shared_keys(ks, 1), grid.g, 1,
                             max_per_slot=1))
    fleet = FleetBatch.for_scenario(grid, T)
    lanes = []
    for cc in costs_list:
        g_c = HostingGrid.from_costs([cc])
        cols = np.array([[col_of[float(lv)] for lv in cc.levels]], np.int32)
        lanes.append(PolicyLane(AlphaRR.batch(g_c), grid=g_c, svc_cols=cols))
    res = run_fleet(lanes, fleet, scenario=sc, n_seeds=n_seeds)
    # policy-major, B=1: row p*S+s -> [P, S]
    mean, ci = mc_stats(res.total.reshape(len(lanes), n_seeds) / T, axis=1)

    rows = []
    best = int(np.argmin(mean[:n_curve]))
    rows.append({"grid": "alpha-RR(best alpha)", "K": 1,
                 "cost": float(mean[best]), "cost_ci95": float(ci[best]),
                 "levels": [curve_pts[best][0]], "n_seeds": n_seeds})
    rows.append({"grid": "RR", "K": 0, "cost": float(mean[n_curve]),
                 "cost_ci95": float(ci[n_curve]), "levels": [],
                 "n_seeds": n_seeds})
    for j, k in enumerate((2, 4, 6)):
        kn, un = grids_k[k]
        i_kn = n_curve + 1 + 2 * j
        rows.append({"grid": "knapsack", "K": k, "cost": float(mean[i_kn]),
                     "cost_ci95": float(ci[i_kn]),
                     "levels": [round(a, 3) for a, _ in kn],
                     "n_seeds": n_seeds})
        rows.append({"grid": "uniform", "K": k, "cost": float(mean[i_kn + 1]),
                     "cost_ci95": float(ci[i_kn + 1]),
                     "levels": [round(a, 3) for a, _ in un],
                     "n_seeds": n_seeds})
    return rows


def check(rows):
    d = {(r["grid"], r["K"]): r["cost"] for r in rows}
    rr = d[("RR", 0)]
    best3 = d[("alpha-RR(best alpha)", 1)]
    # multi-level grids should not lose to plain RR, and the best knapsack
    # grid should match or beat the best single-alpha 3-level policy
    for k in (2, 4, 6):
        assert d[("knapsack", k)] <= rr * 1.02 + 1e-6
        assert d[("knapsack", k)] <= d[("uniform", k)] * 1.10 + 1e-6
    assert min(d[("knapsack", k)] for k in (2, 4, 6)) <= best3 * 1.05 + 1e-6
    return True
