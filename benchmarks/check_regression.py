"""CI perf-regression gate: compare a fresh ``benchmarks.run --json`` report
against the committed ``BENCH_baseline.json``.

    python benchmarks/check_regression.py bench.json BENCH_baseline.json \
        [--threshold 0.25]

Every throughput entry in the baseline must still exist in the new report,
and every *guarded* key of it must not have dropped by more than
``threshold`` (default 25%).  Additive changes — new throughput entries,
new keys inside an entry — pass silently: the schema grows, the gate only
ever pins what a previous PR already achieved.

Three kinds of guarded keys:

* **dimensionless ratios** (``speedup_vs_loop``, ``fused_vs_per_seed``,
  ``peak_mem_ratio``, ...) are compared raw — they measure one engine path
  against another on the same machine in the same process, so they are
  runner-independent and a drop is a real regression;
* **absolute rates** (``*_per_sec``) are first normalized by the median
  new/baseline ratio across all rate keys — one shared machine-speed
  factor.  A uniformly slower runner moves every rate together and the
  median absorbs it; a *single* path regressing >25% against its peers
  still fails.  (With fewer than 3 common rate keys there is no robust
  factor; rates are then compared raw.)
* **lower-is-better ratios** (``antithetic_ci_ratio``) are guarded
  against *rises* past the same threshold — they are pure functions of
  fixed PRNG keys, so a rise is a real loss, not noise.

Explicit ``None`` values on either side (e.g. ``scaling_vs_1dev`` on a
1-core runner — a recorded measurement failure) skip that key with a
note; a guarded key *absent* from a surviving entry fails the gate like a
disappeared entry would.  Regenerate the baseline by committing
a fresh report whenever a PR intentionally shifts a gated number — the
workflow is documented in ROADMAP.md.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys

# dimensionless engine-vs-engine ratios: runner-independent, guarded raw.
# Extend this set when a new bench row adds a ratio the trajectory should
# pin (keys absent from it are additive/informational and never gate).
RATIO_KEYS = {
    "speedup_vs_loop",
    "fleet_vs_batched_1dev",
    "fused_vs_host_e2e",
    "fused_vs_per_seed",
    "ckpt_vs_materialized",
    "peak_mem_ratio",
    "fanout_vs_separate",
}
# NOT guarded: fused_vs_stream — kernel_bench documents it as
# informational (the streamed side's generation is untimed and its CPU
# "transfer" is a memcpy), and it swings ~20% between machines; gating it
# would fail clean PRs on runner noise.  scaling_vs_1dev — real multi-core
# speedup, so it tracks the runner's physical cores and contention, not
# the code; kernel_bench.check already gates it with cores-aware bars.
# dp_pallas_vs_xla / prng_pallas_vs_xla — the hosting-kernel backend
# ratios depend on the Pallas execution mode (interpret on CPU, compiled
# on accelerators; the report's top-level ``backend`` key records which)
# so a baseline from one mode would wrongly gate runs in the other;
# kernel_bench.check gates them >1 on compiled backends only, and the
# rows' absolute ``*_per_sec`` keys still ride the rate guard below.
# async_vs_sync — the ingestion-overlap win needs a spare physical core
# for the prefetch thread, so it tracks the runner's core count and load
# like scaling_vs_1dev does; kernel_bench.check gates it >= 0.9 in-row
# and the row's ``*_per_sec`` rates ride the machine-normalized guard.
# multihost_scaling_vs_1proc — real 2-process-vs-1 speedup, so exactly
# like scaling_vs_1dev it measures the runner's physical cores (two
# cluster workers on one core timeslice to ~0.5x, on two cores to ~2x),
# not the code; kernel_bench.check gates it > 1.0 with >= 2 cores, the
# in-row bit-equality assert is unconditional, and the row's
# ``{single,multi}_process_slots_instances_per_sec`` rates ride the
# machine-normalized rate guard so a real ingestion/engine regression
# still fails.
# ``*_latency_us`` keys (live_fleet_step p50/p99) are absolute wall times
# with no per-key normalization story; the row's
# ``live_slots_admitted_per_sec`` rate carries the gated trajectory.

# lower-is-better ratios: guarded against *rises* past the same threshold
# (a pure function of the fixed PRNG keys, so runner-independent).
LOWER_IS_BETTER_KEYS = {
    "antithetic_ci_ratio",
}

RATE_SUFFIX = "_per_sec"
MIN_RATES_FOR_CALIBRATION = 3


def _num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _rate_pairs(new_tp, base_tp):
    """All (entry, key, new, old) rate pairs present and numeric on both
    sides — the population the machine-speed factor is estimated from."""
    pairs = []
    for name, base_row in base_tp.items():
        new_row = new_tp.get(name)
        if not isinstance(new_row, dict):
            continue
        for key, old in base_row.items():
            if not key.endswith(RATE_SUFFIX):
                continue
            new = new_row.get(key)
            if _num(old) and _num(new) and old > 0:
                pairs.append((name, key, float(new), float(old)))
    return pairs


def compare(new_tp: dict, base_tp: dict, threshold: float = 0.25):
    """Gate ``new_tp`` (a report's ``throughput`` section) against
    ``base_tp``.  Returns ``(failures, notes)`` — lists of human-readable
    lines; empty ``failures`` means the gate passes."""
    failures, notes = [], []
    rates = _rate_pairs(new_tp, base_tp)
    if len(rates) >= MIN_RATES_FOR_CALIBRATION:
        factor = statistics.median(new / old for _, _, new, old in rates)
        notes.append(
            f"machine-speed factor (median rate ratio): "
            f"{factor:.3f} over {len(rates)} rate keys"
        )
    else:
        factor = 1.0
        notes.append(
            f"only {len(rates)} common rate keys — rates "
            f"compared raw (no machine-speed calibration)"
        )
    for name, base_row in sorted(base_tp.items()):
        new_row = new_tp.get(name)
        if new_row is None:
            failures.append(
                f"{name}: throughput entry disappeared from the new report"
            )
            continue
        for key, old in sorted(base_row.items()):
            is_rate = key.endswith(RATE_SUFFIX)
            lower_better = key in LOWER_IS_BETTER_KEYS
            if not (is_rate or key in RATIO_KEYS or lower_better):
                continue  # metadata / informational
            if key not in new_row:
                # a guarded key vanishing from a surviving entry is a
                # schema regression, not a skip — the gate must never
                # silently lose a metric the baseline pinned
                failures.append(
                    f"{name}.{key}: guarded key missing from the new "
                    f"report"
                )
                continue
            new = new_row[key]
            if old is None or new is None:
                # an explicit null is a recorded measurement failure
                # (e.g. the scaling subprocess on a starved runner) —
                # noted, not fatal
                notes.append(f"{name}.{key}: None on one side, skipped")
                continue
            if not (_num(old) and _num(new)) or old <= 0:
                notes.append(f"{name}.{key}: non-numeric, skipped")
                continue
            if lower_better:
                ceil = (1.0 + threshold) * float(old)
                if float(new) > ceil:
                    failures.append(
                        f"{name}.{key}: lower-is-better ratio rose "
                        f">{threshold:.0%}: {float(new):.4g} > ceiling "
                        f"{ceil:.4g} (baseline {float(old):.4g})"
                    )
                else:
                    notes.append(
                        f"{name}.{key}: ok "
                        f"({float(new):.4g} vs ceiling {ceil:.4g})"
                    )
                continue
            scale = factor if is_rate else 1.0
            floor = (1.0 - threshold) * float(old) * scale
            if float(new) < floor:
                kind = "rate (machine-normalized)" if is_rate else "ratio"
                failures.append(
                    f"{name}.{key}: {kind} dropped >{threshold:.0%}: "
                    f"{float(new):.4g} < floor {floor:.4g} "
                    f"(baseline {float(old):.4g})"
                )
            else:
                notes.append(
                    f"{name}.{key}: ok "
                    f"({float(new):.4g} vs floor {floor:.4g})"
                )
    for name in sorted(set(new_tp) - set(base_tp)):
        notes.append(
            f"{name}: additive entry (not in baseline) — update "
            f"the baseline to start gating it"
        )
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("report", help="fresh benchmarks.run --json output")
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="max fractional drop per guarded key (default .25)",
    )
    args = ap.parse_args(argv)
    with open(args.report) as fh:
        new = json.load(fh)
    with open(args.baseline) as fh:
        base = json.load(fh)
    for r in (new, base):
        if r.get("schema_version") != 1:
            print(f"unsupported schema_version {r.get('schema_version')}")
            return 1
    failures, notes = compare(
        new.get("throughput", {}),
        base.get("throughput", {}),
        threshold=args.threshold,
    )
    for line in notes:
        print(f"  note: {line}")
    if failures:
        print(f"PERF REGRESSION GATE FAILED ({len(failures)}):")
        for line in failures:
            print(f"  FAIL: {line}")
        print(
            "(intentional change? regenerate BENCH_baseline.json — see "
            "ROADMAP.md)"
        )
        return 1
    print(
        f"perf regression gate ok: {len(base.get('throughput', {}))} "
        f"baseline entries held (threshold {args.threshold:.0%})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
