"""Render the roofline table + perf log into EXPERIMENTS.md (replaces the
<!-- ROOFLINE_TABLE --> and <!-- PERF_LOG --> markers)."""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "benchmarks" / "results" / "dryrun"
PERF = ROOT / "benchmarks" / "results" / "perf"


def roofline_table() -> str:
    rows = ["| arch | shape | kind | compute (s) | memory (s) | collective (s) "
            "| bottleneck | useful ratio | roofline frac | arg+temp GiB/chip |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for p in sorted(DRY.glob("*__pod16x16.json")):
        c = json.loads(p.read_text())
        if "skipped" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | — | — "
                        f"| skipped (sub-quadratic attention required) |")
            continue
        r = c["roofline"]
        mem = (c["memory_analysis"].get("argument_size_in_bytes", 0)
               + c["memory_analysis"].get("temp_size_in_bytes", 0)) / 2 ** 30
        rows.append(
            "| {a} | {s} | {k} | {c:.3e} | {m:.3e} | {x:.3e} | **{b}** | "
            "{u:.2f} | {f:.3f} | {g:.1f} |".format(
                a=c["arch"], s=c["shape"], k=c["kind"], c=r["compute_s"],
                m=r["memory_s"], x=r["collective_s"], b=r["bottleneck"],
                u=r["useful_ratio"], f=r["roofline_fraction"], g=mem))
    return "\n".join(rows)


def perf_cell(name: str) -> dict | None:
    p = PERF / name
    if not p.exists():
        return None
    c = json.loads(p.read_text())
    return c["roofline"]


def fmt_terms(r) -> str:
    return (f"compute {r['compute_s']:.3e}s, memory {r['memory_s']:.3e}s, "
            f"collective {r['collective_s']:.3e}s "
            f"(bound {r['compute_s'] + r['memory_s'] + r['collective_s']:.3e}s)")


def perf_log() -> str:
    out = []

    def block(title, hypothesis, entries, verdict):
        out.append(f"### {title}\n")
        out.append(f"**Hypothesis (napkin math).** {hypothesis}\n")
        for label, fname in entries:
            r = perf_cell(fname)
            if r:
                out.append(f"- **{label}**: {fmt_terms(r)}; bottleneck "
                           f"{r['bottleneck']}; roofline frac "
                           f"{r['roofline_fraction']:.4f}")
        out.append(f"\n**Verdict.** {verdict}\n")

    block(
        "Cell 1 — qwen2.5-14b x decode_32k (most collective-bound)",
        "GQA kv=8 cannot head-shard across the 16-wide model axis, so GSPMD "
        "gathers KV (O(B*S*Hkv*hd) = ~100 GB wire/step -> ~2 s collective). "
        "Sequence-sharding the cache (flash-decoding) should cut the exchange "
        "to O(B*Hq*hd) merge statistics ~ MBs: predicted >100x on the "
        "collective term, and HBM traffic drops the gathered-copy term.",
        [("baseline (paper-faithful plan, head/replicated KV)",
          "qwen2.5-14b__decode_32k__pod16x16_baseline.json"),
         ("optimized (+flash_decode: sequence-sharded KV + LSE-merge psum)",
          "qwen2.5-14b__decode_32k__pod16x16_flashdecode.json")],
        "CONFIRMED: collective 1.93 s -> 0.81 ms (~2400x), memory 1.21 s -> "
        "0.13 s (9.4x), no-overlap step bound 3.14 s -> 0.13 s (24x). The "
        "cell flips from collective-bound to memory-bound (now dominated by "
        "the per-step cache read, which is physical). Beyond-paper change; "
        "enabled per-config via decode_impl='flash_decode'.")

    block(
        "Cell 2 — zamba2-1.2b x prefill_32k / train_4k (worst useful fraction)",
        "The baseline ran SSM archs DP-only with replicated params: every "
        "model-axis rank redundantly computes the same mamba math -> 16x "
        "wasted compute and memory traffic per chip. Splitting the fused "
        "in_proj into w_z/w_x/w_B/w_C/w_dt makes per-head tensors column-"
        "shardable (64 heads / 16 ranks), predicting ~16x lower compute and "
        "memory terms at the cost of new TP collectives (psum after "
        "out_proj, ~2*(15/16)*S*d bytes/layer).",
        [("baseline prefill (replicated / DP-only)",
          "zamba2-1.2b__prefill_32k__pod16x16_replicated.json"),
         ("optimized prefill (+split-projection SSM TP)",
          "zamba2-1.2b__prefill_32k__pod16x16_tp.json"),
         ("baseline train (replicated)",
          "zamba2-1.2b__train_4k__pod16x16_replicated.json"),
         ("optimized train (+SSM TP)",
          "zamba2-1.2b__train_4k__pod16x16_tp.json")],
        "CONFIRMED for serve shapes: prefill compute 1.25 s -> 0.078 s and "
        "memory 21.1 s -> 1.28 s (both ~16x, matching the parallelism math); "
        "new collective term 0.85 s as predicted -> net prefill bound "
        "22.4 s -> 2.2 s (10x). PARTIALLY for train_4k: batch=256 already "
        "saturated (data x model) as pure DP, so per-chip compute barely "
        "moves (0.201 -> 0.181 s); the win there is the 1.5x memory-term "
        "drop (3.53 -> 2.32 s) from de-replicated param/optimizer traffic + "
        "ZeRO-1, net bound 3.82 -> 3.33 s. A refuted sub-hypothesis worth "
        "recording: TP does NOT help SSM train compute when DP already "
        "covers the mesh — it helps the shapes whose batch cannot fill it "
        "(prefill b=32, decode b<=128). mamba2-130m keeps the replicated "
        "fallback (24 heads do not divide 16) per DESIGN.md.")

    block(
        "Cell 3 — deepseek-moe-16b x train_4k (paper-representative Model-2 arch)",
        "Memory-bound baseline. (i) remat_policy=dots saves matmul outputs "
        "instead of recomputing them in the backward pass: backward re-runs "
        "drop, predicting ~20-30% lower compute and memory terms at higher "
        "live-buffer cost (fine: 16 GB budget not binding at 16B scale). "
        "(ii) MoE capacity factor 1.25 -> 1.0 shrinks the [E, C, D] dispatch "
        "buffers and their gather/scatter traffic by 20%.",
        [("baseline (full remat, capacity 1.25)",
          "deepseek-moe-16b__train_4k__pod16x16_baseline.json"),
         ("iteration 1: remat_policy=dots",
          "deepseek-moe-16b__train_4k__pod16x16_rematdots.json"),
         ("iteration 2: capacity_factor=1.0",
          "deepseek-moe-16b__train_4k__pod16x16_cap1.json"),
         ("iteration 3: both",
          "deepseek-moe-16b__train_4k__pod16x16_rematdots_cap1.json")],
        "Iteration 1 CONFIRMED: remat=dots cuts compute 0.562 -> 0.459 s "
        "(-18%) and memory 4.63 -> 3.56 s (-23%): no-overlap bound 6.63 -> "
        "5.47 s (-17.5%), roofline frac 0.049 -> 0.060. Iteration 2 "
        "REFUTED-as-major: capacity 1.25 -> 1.0 moves the bound only ~1% "
        "alone and ~1.3% on top of iteration 1 — the dispatch buffers are "
        "NOT a dominant memory term (CE chunks + attention + activation "
        "traffic are). Stopping rule: two consecutive <5% candidates "
        "(capacity cut, further remat tweaks) end the loop. The dispatch "
        "path itself already uses the shard_map local-sort + single-psum "
        "scheme — a beyond-paper optimization over naive GSPMD dispatch, "
        "whose global token sort is pathological (verified equal to dense "
        "dispatch on 8 devices).")

    block(
        "Cell 4 — deepseek-v2-236b x train_4k (HBM capacity, beyond-paper)",
        "With expert weights sharded only over the 16-wide model axis, every "
        "data row replicates 472 GB of bf16 expert params: 29.5 GiB/chip of "
        "weight state > 16 GiB HBM — the biggest assigned config does not "
        "fit. FSDP-sharding the expert F-dim over the data axis should cut "
        "weight state 16x for ~0.6 s of per-layer just-in-time weight "
        "all-gathers (0.5 GiB/layer/chip over 59 layers at 50 GB/s).",
        [("baseline (1D expert sharding)",
          "deepseek-v2-236b__train_4k__pod16x16_1dshard.json"),
         ("optimized (+fsdp_experts: F-dim over data)",
          "deepseek-v2-236b__train_4k__pod16x16_fsdp.json")],
        "CONFIRMED: argument (weight-state) bytes 35.9 -> 11.6 GiB/chip — "
        "params+optimizer now fit the HBM budget; collective term grows "
        "8.02 -> 9.07 s (+1.05 s, the predicted gathers). Compute/memory "
        "terms unchanged. This is a capacity fix, not a bandwidth one: the "
        "roofline terms barely move but the config becomes *runnable*. "
        "decode_32k additionally drops its memory term 17.1 -> 2.7 s "
        "(weights dominate decode reads at batch 128). GSPMD synthesises "
        "the per-layer gather inside the scan from the sharding spec alone "
        "— no FSDP wrapper code.")

    return "\n".join(out)


def main():
    exp = (ROOT / "EXPERIMENTS.md").read_text()
    exp = exp.replace("<!-- ROOFLINE_TABLE -->", roofline_table())
    exp = exp.replace("<!-- PERF_LOG -->", perf_log())
    (ROOT / "EXPERIMENTS.md").write_text(exp)
    print("EXPERIMENTS.md rendered")


if __name__ == "__main__":
    main()
