"""Kernel microbenchmarks: wall time of the interpret-mode Pallas kernels vs
their jnp oracles (correctness-weighted; CPU wall times are NOT TPU
projections — see the roofline table for the perf story)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / reps * 1e6


def run():
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    rows.append({"name": "flash_attention_pallas_interp_us",
                 "us": _time(lambda a, b, c: ops.flash_attention(a, b, c), q, k, v)})
    rows.append({"name": "flash_attention_ref_us",
                 "us": _time(lambda a, b, c: ref.flash_attention_ref(a, b, c), q, k, v)})
    x = jax.random.normal(ks[0], (1, 256, 4, 32), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 256, 4)))
    A = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.3)
    B = jax.random.normal(ks[1], (1, 256, 1, 32), jnp.float32)
    C = jax.random.normal(ks[2], (1, 256, 1, 32), jnp.float32)
    rows.append({"name": "ssd_scan_pallas_interp_us",
                 "us": _time(lambda *a: ops.ssd_scan(*a, chunk=64), x, dt, A, B, C)})
    rows.append({"name": "ssd_scan_ref_us",
                 "us": _time(lambda *a: ref.ssd_scan_ref(*a), x, dt, A, B, C)})
    return rows


def check(rows):
    return all(r["us"] > 0 for r in rows)
